"""Observability layer: span tracing, metrics registry, headroom telemetry,
and the cluster-wide snapshot merge."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.configs.base import QuantConfig
from repro.models.lm import Runtime, init_lm
from repro.nn.module import unbox
from repro.obs import (
    NULL_SPAN, MetricsRegistry, Obs, Tracer, merge_snapshots, percentile,
)
from repro.obs.headroom import engine_headroom, static_headroom_report
from repro.serve.engine import PagedServeEngine, deploy_params

KEY = jax.random.PRNGKey(0)
KW = dict(batch=2, max_seq=64, block_size=4, prefill_chunk=4)


def _params(arch):
    return unbox(init_lm(KEY, arch))


# -- tracer ------------------------------------------------------------------


def test_span_nesting_child_before_parent():
    tr = Tracer()
    with tr.span("parent"):
        with tr.span("child"):
            pass
    names = [name for _, name, _, _, _ in tr.events]
    assert names == ["child", "parent"], "append-on-exit orders child first"
    (child, parent) = tr.spans("child")[0], tr.spans("parent")[0]
    # containment: the child starts no earlier and ends no later
    assert parent[1] <= child[1]
    assert child[1] + child[2] <= parent[1] + parent[2] + 1e-9


def test_disabled_tracer_is_null_span_identity():
    tr = Tracer(enabled=False)
    s1 = tr.span("a", {"k": 1})
    s2 = tr.span("b")
    assert s1 is NULL_SPAN and s2 is NULL_SPAN, "one shared no-op span"
    with s1:
        pass
    tr.instant("i", {"x": 2})
    assert tr.events == [], "disabled tracer records nothing"
    assert s1.dur_s == 0.0


def test_chrome_export_schema(tmp_path):
    tr = Tracer(pid=3, tid=7)
    with tr.span("outer", {"uid": 1}):
        tr.instant("mark")
    path = tmp_path / "trace.json"
    tr.export(str(path))
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    assert len(evs) == 2
    by_ph = {e["ph"]: e for e in evs}
    assert set(by_ph) == {"X", "i"}
    x, i = by_ph["X"], by_ph["i"]
    assert x["name"] == "outer" and x["args"] == {"uid": 1}
    assert x["dur"] >= 0 and x["ts"] >= 0  # microseconds from tracer origin
    assert i["s"] == "t" and "dur" not in i
    assert all(e["pid"] == 3 and e["tid"] == 7 for e in evs)


def test_tracer_clear_resets_origin_and_events():
    tr = Tracer()
    tr.instant("before")
    tr.clear()
    assert tr.events == []
    tr.instant("after")
    ts = tr.to_chrome()["traceEvents"][0]["ts"]
    assert 0 <= ts < 1e6, "timestamps rebase onto the cleared origin"


# -- metrics -----------------------------------------------------------------


def test_percentile_nearest_rank():
    assert percentile([], 99) == 0.0
    assert percentile([5.0], 50) == 5.0
    vals = [1.0, 2.0, 3.0, 4.0]
    # nearest-rank: rank = ceil(q/100 * n), 1-indexed
    assert percentile(vals, 50) == 2.0
    assert percentile(vals, 75) == 3.0
    assert percentile(vals, 99) == 4.0
    # order-independent
    assert percentile([4.0, 1.0, 3.0, 2.0], 50) == 2.0


def test_registry_snapshot_and_load_roundtrip():
    m = MetricsRegistry()
    m.counter("c", {"k": "v"}).inc(3)
    m.gauge("g").set(1.5)
    m.histogram("h").observe(2.0)
    m.histogram("h").observe(4.0)
    snap = m.snapshot()
    assert snap["c{k=v}"] == {"type": "counter", "value": 3}
    assert snap["g"] == {"type": "gauge", "value": 1.5}
    assert snap["h"]["values"] == [2.0, 4.0]
    m2 = MetricsRegistry()
    m2.load(snap)
    assert m2.snapshot() == snap
    assert m2.histogram("h").percentile(99) == 4.0


def test_registry_type_mismatch_raises():
    m = MetricsRegistry()
    m.counter("x")
    with pytest.raises(TypeError):
        m.gauge("x")


def test_merge_snapshots_associative_and_commutative():
    def mk(c, g, h):
        m = MetricsRegistry()
        m.counter("reqs").inc(c)
        m.gauge("peak").set(g)
        for v in h:
            m.histogram("lat").observe(v)
        return m.snapshot()

    a, b, c = mk(1, 5.0, [1.0]), mk(2, 3.0, [2.0, 9.0]), mk(4, 7.0, [0.5])
    ab_c = merge_snapshots(merge_snapshots(a, b), c)
    a_bc = merge_snapshots(a, merge_snapshots(b, c))
    ba = merge_snapshots(b, a)

    def canon(s):
        return {k: (sorted(v["values"]) if "values" in v else v["value"])
                for k, v in s.items()}

    assert canon(ab_c) == canon(a_bc), "merge is associative"
    assert canon(merge_snapshots(a, b)) == canon(ba), "merge is commutative"
    assert ab_c["reqs"]["value"] == 7, "counters add"
    assert ab_c["peak"]["value"] == 7.0, "gauges merge by max"
    assert sorted(ab_c["lat"]["values"]) == [0.5, 1.0, 2.0, 9.0], "histograms concat"


# -- accumulator headroom ----------------------------------------------------


def test_acc_probe_pow2_witness():
    """Exactly predictable accumulator magnitude through the fused path:
    q8 = all-ones (32, 4), unit scales, x = 4.0 broadcast -> every output
    accumulator is exactly 32 * 4 = 128 against a 16-bit bound of 32767."""
    from repro.nn.linear import acc_probe_scope, apply_linear

    cfg = QuantConfig(mode="a2q", weight_bits=8, act_bits=8, acc_bits=16)
    params = {
        "q8": jnp.ones((32, 4), jnp.int8),
        "s8": jnp.ones((4,), jnp.float32),
        "aq": {"log2_scale": jnp.zeros((), jnp.float32)},
    }
    x = jnp.full((1, 32), 4.0, jnp.float32)
    samples = []
    with acc_probe_scope(samples):
        y = apply_linear(params, x, cfg, int_forward=True, site="witness",
                         compute_dtype=jnp.float32)
    assert len(samples) == 1
    rec = samples[0]
    assert rec["site"] == "witness"
    assert rec["acc_max"] == 128, rec
    assert rec["acc_bits"] == 16 and rec["bound"] == 2 ** 15 - 1
    # the kernel really computed 4 * 32 per column (scale 1.0 end to end)
    np.testing.assert_allclose(np.asarray(y), 128.0)


def test_acc_probe_inactive_without_scope():
    from repro.nn.linear import _ACTIVE_ACC_PROBE

    assert _ACTIVE_ACC_PROBE == [], "no probe scope leaks across tests"


def test_static_headroom_all_layers_within_guarantee():
    arch = reduced(get_arch("yi-6b"))
    dep = deploy_params(_params(arch), arch.quant)
    report = static_headroom_report(dep, arch.quant)
    assert report, "deployed tree has q8 leaves"
    for rec in report:
        assert 0.0 <= rec["utilization"] < 1.0, rec
        assert rec["l1_max"] <= rec["l1_budget"], rec
        assert rec["site"]


def test_engine_headroom_gauges_and_zero_violations():
    arch = reduced(get_arch("yi-6b"))
    dep = deploy_params(_params(arch), arch.quant)
    e = PagedServeEngine(arch, dep, rt=Runtime(int_forward=True), **KW)
    hr = engine_headroom(e, seq=4)
    assert hr["violations"] == 0
    assert 0.0 < hr["util_max"] < 1.0
    assert hr["observed_sites"] > 0, "eager probe hit at least one fused site"
    assert 0.0 < hr["observed_frac_max"] <= hr["util_max"] + 1e-9, \
        "observed magnitude cannot exceed the static worst case"
    snap = e.obs.metrics.snapshot()
    assert snap["acc_headroom_violations"]["value"] == 0
    assert any(k.startswith("acc_headroom_utilization{") for k in snap)
    assert any(k.startswith("acc_observed_max{") for k in snap)


# -- engine integration ------------------------------------------------------


def _prompts(arch, n=3, rng=None):
    rng = rng or np.random.default_rng(0)
    return [rng.integers(0, arch.vocab, (int(L),)).astype(np.int32)
            for L in rng.integers(4, 9, size=n)]


def test_traced_engine_spans_and_parity():
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    plain = PagedServeEngine(arch, params, **KW)
    traced = PagedServeEngine(arch, params, obs=Obs(trace=True),
                              decode_steps=2, **KW)
    prompts = _prompts(arch)
    want = plain.generate(prompts, max_new=4)
    got = traced.generate(prompts, max_new=4)
    assert got == want, "tracing is observation only"
    names = traced.obs.trace.span_names()
    assert {"submit", "admit", "prefill_chunk", "block_alloc",
            "decode_megastep", "emit"} <= names, names
    # one submit and one emit instant per request
    assert len(traced.obs.trace.instants("submit")) == len(prompts)
    assert len(traced.obs.trace.instants("emit")) == len(prompts)
    # every admit span carries its request uid
    for _, _, _, args in traced.obs.trace.spans("admit"):
        assert "uid" in args and "slot" in args


def test_untraced_engine_records_no_events():
    arch = reduced(get_arch("yi-6b"))
    e = PagedServeEngine(arch, _params(arch), **KW)
    e.generate(_prompts(arch, n=2), max_new=3)
    assert e.obs.trace.events == []
    # ...but request-latency histograms still populate (metrics are cheap)
    assert e.obs.metrics.histogram("request_latency_s").count == 2


def test_metrics_snapshot_unifies_engine_and_cache_stats():
    arch = reduced(get_arch("yi-6b"))
    e = PagedServeEngine(arch, _params(arch), **KW)
    prompts = _prompts(arch)
    e.generate(prompts, max_new=4)
    snap = e.metrics_snapshot()
    assert snap["serve_decode_tokens"]["value"] == e.stats["decode_tokens"]
    assert snap["serve_prefill_tokens"]["value"] == e.stats["prefill_tokens"]
    assert snap["requests_completed"]["value"] == len(prompts)
    assert snap["kv_peak_blocks"]["value"] == e.cache.peak_blocks > 0
    assert len(snap["request_latency_s"]["values"]) == len(prompts)
    assert len(snap["request_ttft_s"]["values"]) == len(prompts)
    assert any(k.startswith("jit_cache_size{fn=") for k in snap)


def test_reset_stats_single_path_clears_everything():
    arch = reduced(get_arch("yi-6b"))
    e = PagedServeEngine(arch, _params(arch), obs=Obs(trace=True), **KW)
    e.generate(_prompts(arch, n=2), max_new=3)
    assert e.cache.peak_blocks > 0 and e.obs.trace.events
    e.reset_stats()
    assert e.stats["decode_tokens"] == 0
    assert e.obs.trace.events == [], "reset clears the trace buffer"
    assert all(v == 0 for v in e.cache.counters().values()), \
        "one reset path covers every cache counter"
    assert e.obs.metrics.histogram("request_latency_s").count == 0


def test_replica_merge_equals_fleet():
    """replica ⊕ replica == fleet: merging two engines' snapshots gives the
    totals a single fleet-wide registry would hold."""
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    rng = np.random.default_rng(1)
    e1 = PagedServeEngine(arch, params, **KW)
    e2 = PagedServeEngine(arch, params, **KW)
    e1.generate(_prompts(arch, n=2, rng=rng), max_new=3)
    e2.generate(_prompts(arch, n=3, rng=rng), max_new=3)
    s1, s2 = e1.metrics_snapshot(), e2.metrics_snapshot()
    fleet = merge_snapshots(s1, s2)
    assert fleet["requests_completed"]["value"] == 5
    assert fleet["serve_decode_tokens"]["value"] == (
        s1["serve_decode_tokens"]["value"] + s2["serve_decode_tokens"]["value"])
    assert fleet["kv_peak_blocks"]["value"] == max(
        s1["kv_peak_blocks"]["value"], s2["kv_peak_blocks"]["value"])
    lat = fleet["request_latency_s"]["values"]
    assert sorted(lat) == sorted(s1["request_latency_s"]["values"]
                                 + s2["request_latency_s"]["values"])
    assert percentile(lat, 99) == max(lat)
