"""Shared pytest config: register the ``slow`` marker and the ``--runslow``
flag.  ``slow`` tests spawn 8-fake-device subprocesses (tests must not set
``XLA_FLAGS`` in-process) and are skipped by default so the tier-1 command
stays fast; run them with ``pytest --runslow``.

The module-scoped cache purge below keeps the full suite viable in one
process: each module compiles its own engines/kernels (cross-module jit
reuse is ~zero — wrappers are per-instance), and with 300+ tests the
accumulated live XLA CPU executables eventually segfault the compiler on a
later, otherwise-innocent compile.  Dropping the caches at module teardown
bounds the live-executable count at no recompile cost."""

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _bound_live_executables():
    yield
    jax.clear_caches()


def pytest_addoption(parser):
    parser.addoption(
        "--runslow",
        action="store_true",
        default=False,
        help="run @pytest.mark.slow multi-device subprocess tests",
    )


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-device subprocess test (run with --runslow)"
    )


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow subprocess test: needs --runslow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)
