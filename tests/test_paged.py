"""Paged-KV serving subsystem: allocator invariants, scheduler policy,
paged-vs-contiguous engine parity, on-device sampling, kernel decode path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models.lm import Runtime, apply_lm, init_cache, init_lm
from repro.nn.module import unbox
from repro.serve.engine import PagedServeEngine, Request, ServeEngine
from repro.serve.paged_cache import PagedKVCache, TRASH_BLOCK
from repro.serve.sampling import SampleConfig, sample_tokens
from repro.serve.scheduler import Scheduler, ServeRequest

KEY = jax.random.PRNGKey(0)


def _params(arch):
    return unbox(init_lm(KEY, arch))


def _greedy_reference(arch, params, prompt, max_new, max_seq=64):
    """Step-by-step single-sequence decode as the oracle."""
    cache = init_cache(arch, 1, max_seq, dtype=jnp.dtype(arch.compute_dtype))
    logits = None
    for pos, t in enumerate(prompt):
        logits, cache, _ = apply_lm(
            params, arch, tokens=jnp.asarray([[t]], jnp.int32), cache=cache,
            start_pos=jnp.asarray(pos, jnp.int32),
        )
    out = []
    pos = len(prompt)
    for _ in range(max_new):
        nxt = int(jnp.argmax(logits[0, 0]))
        out.append(nxt)
        logits, cache, _ = apply_lm(
            params, arch, tokens=jnp.asarray([[nxt]], jnp.int32), cache=cache,
            start_pos=jnp.asarray(pos, jnp.int32),
        )
        pos += 1
    return out


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------


def test_allocator_orders_blocks_and_recycles():
    arch = reduced(get_arch("yi-6b"))
    cache = PagedKVCache(arch, slots=2, block_size=4, max_seq=32, num_blocks=9)
    cache.allocate(0, 10)  # 3 blocks
    cache.allocate(1, 5)  # 2 blocks
    assert list(cache.tables[0][:3]) == sorted(cache.tables[0][:3])  # logical order
    assert cache.free_blocks == 8 - 5
    assert TRASH_BLOCK not in set(cache.tables[0][:3]) | set(cache.tables[1][:2])
    assert not set(cache.tables[0][:3]) & set(cache.tables[1][:2])  # disjoint
    # growing reuses already-owned blocks first
    cache.allocate(0, 12)  # still 3 blocks
    assert cache.free_blocks == 3
    cache.release(0)
    assert cache.free_blocks == 6
    assert (cache.tables[0] == TRASH_BLOCK).all() and cache.lens[0] == 0
    assert cache.peak_blocks == 5


def test_allocator_exhaustion_and_bounds():
    arch = reduced(get_arch("yi-6b"))
    cache = PagedKVCache(arch, slots=2, block_size=4, max_seq=16, num_blocks=3)
    assert cache.can_allocate(8) and not cache.can_allocate(12)
    cache.allocate(0, 8)
    with pytest.raises(RuntimeError):
        cache.allocate(1, 8)
    with pytest.raises(ValueError):
        cache.allocate(1, 17)  # beyond max_seq


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------


def _req(uid, n, max_new=4):
    return ServeRequest(uid=uid, prompt=np.arange(n, dtype=np.int32), max_new=max_new)


def test_scheduler_fifo_admission_and_recycling():
    s = Scheduler(2, prefill_chunk=4)
    for i, n in enumerate((5, 3, 7)):
        s.submit(_req(i, n))
    admitted = s.admissions(lambda r: True)
    assert [slot for slot, _ in admitted] == [0, 1]
    assert [r.uid for _, r in admitted] == [0, 1]
    # chunked prefill plan covers the prompt exactly
    chunks = list(s.prefill_plan(0))
    assert [len(c) for c, _ in chunks] == [4, 1] and [st for _, st in chunks] == [0, 4]
    # head-of-queue blocking: nothing admitted when capacity says no
    assert s.admissions(lambda r: False) == []
    # finishing a request frees its slot for the queue
    for tok in range(4):
        done = s.record_token(0, tok)
    assert done and s.slots[0] is None
    assert [r.uid for _, r in s.admissions(lambda r: True)] == [2]


def test_scheduler_lockstep_groups_equal_lengths():
    s = Scheduler(4, prefill_chunk=4, lockstep=True)
    for i, n in enumerate((5, 5, 3, 5)):
        s.submit(_req(i, n))
    group = s.admissions(lambda r: True)
    assert [r.uid for _, r in group] == [0, 1]  # stops at the length change
    assert s.admissions(lambda r: True) == []  # engine busy -> no admission


def test_scheduler_eos_finishes_early_and_frees_slot():
    """Regression: record_token only ever checked max_new — an eos_id was
    never consulted, so real traffic decoded garbage past end-of-sequence
    and burned blocks until the length cap."""
    s = Scheduler(1, prefill_chunk=4)
    r = ServeRequest(uid=0, prompt=np.arange(3, dtype=np.int32), max_new=8, eos_id=42)
    s.submit(r)
    s.admissions(lambda q: True)
    assert not s.record_token(0, 7)
    assert s.record_token(0, 42)  # the EOS emit itself completes the request
    assert r.done and r.generated == [7, 42]
    assert s.slots[0] is None  # slot freed immediately, not at max_new
    assert r.latency >= 0


def test_request_latency_stats_guarded_before_events():
    """Regression: the timestamps defaulted to 0.0, so latency/ttft read on
    an in-flight request returned epoch-scale negative values that percentile
    aggregations would silently swallow; they now refuse instead of lying."""
    r = ServeRequest(uid=0, prompt=np.arange(2, dtype=np.int32), max_new=2)
    with pytest.raises(RuntimeError):
        r.latency
    with pytest.raises(RuntimeError):
        r.ttft
    s = Scheduler(1)
    s.submit(r)
    s.admissions(lambda q: True)
    with pytest.raises(RuntimeError):  # submitted, but no first token yet
        r.ttft
    with pytest.raises(RuntimeError):
        r.latency
    s.record_token(0, 5)
    assert r.ttft >= 0
    s.record_token(0, 6)
    assert r.done and r.latency >= r.ttft >= 0


# ---------------------------------------------------------------------------
# engine parity (the tentpole acceptance gate)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["yi-6b", "smollm-135m"])
def test_paged_engine_matches_contiguous_greedy(name):
    """Token-identical greedy outputs, mixed prompt lengths, more requests
    than slots (exercises slot recycling + block reuse).  yi-6b is GQA
    (kv_heads < heads); smollm ties embeddings."""
    arch = reduced(get_arch(name))
    params = _params(arch)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, arch.vocab, (n,)).astype(np.int32) for n in (5, 3, 9, 2)]
    contig = ServeEngine(arch, params, batch=2, max_seq=64)
    want = contig.generate(prompts, max_new=4)
    paged = PagedServeEngine(arch, params, batch=2, max_seq=64, block_size=4, prefill_chunk=4)
    got = paged.generate(prompts, max_new=4)
    assert got == want
    # every block returned to the free list once the workload drained
    assert paged.cache.free_blocks == paged.cache.num_blocks - 1


def test_paged_engine_mla_matches_reference():
    """MLA latent pools page the same way (deepseek-v3 reduced)."""
    arch = reduced(get_arch("deepseek-v3-671b"))
    params = _params(arch)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, arch.vocab, (n,)).astype(np.int32) for n in (4, 6)]
    paged = PagedServeEngine(arch, params, batch=2, max_seq=64, block_size=4, prefill_chunk=4)
    got = paged.generate(prompts, max_new=3)
    for p, o in zip(prompts, got):
        assert o == _greedy_reference(arch, params, list(p), 3)


def test_paged_engine_recurrent_continuous_batching():
    """Per-slot isolated prefill makes continuous batching sound for
    recurrent stacks — the seed engine's lockstep restriction is lifted.
    Unequal prompt lengths through fewer slots than requests."""
    arch = reduced(get_arch("rwkv6-7b"))
    params = _params(arch)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, arch.vocab, (n,)).astype(np.int32) for n in (5, 3, 7)]
    paged = PagedServeEngine(arch, params, batch=2, max_seq=64, block_size=4, prefill_chunk=4)
    got = paged.generate(prompts, max_new=3)
    for p, o in zip(prompts, got):
        assert o == _greedy_reference(arch, params, list(p), 3)


def test_paged_engine_lockstep_fallback():
    arch = reduced(get_arch("hymba-1.5b"))
    params = _params(arch)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, arch.vocab, (6,)).astype(np.int32) for _ in range(2)]
    lock = PagedServeEngine(arch, params, batch=2, max_seq=64, block_size=4,
                            prefill_chunk=4, lockstep=True)
    got = lock.generate(prompts, max_new=3)
    for p, o in zip(prompts, got):
        assert o == _greedy_reference(arch, params, list(p), 3)


def test_paged_engine_pallas_decode_kernel_path():
    """Runtime(decode_kernel=True) routes decode through the Pallas kernel;
    greedy tokens must match the gathered-view path."""
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, arch.vocab, (n,)).astype(np.int32) for n in (5, 8)]
    base = PagedServeEngine(arch, params, batch=2, max_seq=64, block_size=4, prefill_chunk=4)
    want = base.generate(prompts, max_new=3)
    kern = PagedServeEngine(arch, params, batch=2, max_seq=64, block_size=4,
                            prefill_chunk=4, rt=Runtime(decode_kernel=True))
    assert kern.generate(prompts, max_new=3) == want


@pytest.mark.parametrize("name", ["yi-6b", "deepseek-v3-671b"])
def test_int8_kv_parity_bound_vs_fp32(name):
    """int8 KV blocks (kv_quant=True) hold the parity bound against fp32-KV
    greedy decode on the reduced GQA and MLA archs: token-identical wherever
    the fp32 reference's top-2 logit margin exceeds the quantization-noise
    eps; a sub-margin mismatch is a tie and ends that request's comparison.
    The CI serve-smoke job gates the same property through launch/serve."""
    from repro.serve.engine import parity_up_to_ties

    arch = reduced(get_arch(name))
    params = _params(arch)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, arch.vocab, (n,)).astype(np.int32) for n in (10, 7, 13, 4)]
    kw = dict(batch=2, max_seq=64, block_size=8, prefill_chunk=8)
    ref_e = PagedServeEngine(arch, params, **kw)
    q8_e = PagedServeEngine(arch, params, kv_quant=True, **kw)
    outs_ref = ref_e.generate(prompts, max_new=6)
    outs_q8 = q8_e.generate(prompts, max_new=6)
    ok, ties, detail = parity_up_to_ties(ref_e.last_requests, outs_q8, eps=0.05)
    assert ok, detail
    # the bound must not be vacuous: most requests decode identically
    exact = sum(a == b for a, b in zip(outs_ref, outs_q8))
    assert exact >= len(prompts) - ties


def test_int8_kv_decode_kernel_matches_gathered_view():
    """The q8 Pallas decode kernel (in-register dequant) and the dequantized
    gathered-view path read the same int8 pools — greedy tokens identical."""
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, arch.vocab, (n,)).astype(np.int32) for n in (5, 8)]
    kw = dict(batch=2, max_seq=64, block_size=4, prefill_chunk=4, kv_quant=True)
    base = PagedServeEngine(arch, params, **kw)
    want = base.generate(prompts, max_new=4)
    kern = PagedServeEngine(arch, params, rt=Runtime(decode_kernel=True), **kw)
    assert kern.generate(prompts, max_new=4) == want


def test_int8_kv_bytes_per_token_ratio():
    """The headline: int8 pools cut seq-indexed KV bytes/token >= 3x on the
    reduced archs (head_dim=16: (16+4)B vs 64B per head = 3.2x; production
    head dims approach 4x) and the pools really are int8 + fp32 scales."""
    for name in ("yi-6b", "deepseek-v3-671b"):
        arch = reduced(get_arch(name))
        fp = PagedKVCache(arch, 2, block_size=8, max_seq=64, dtype=jnp.float32)
        q8 = PagedKVCache(arch, 2, block_size=8, max_seq=64, dtype=jnp.float32,
                          kv_quant=True)
        ratio = fp.kv_bytes_per_token() / q8.kv_bytes_per_token()
        assert ratio >= 3.0, (name, ratio)
        leaf = q8.pools["0"]["attn"]
        code_key = "kp" if "kp" in leaf else "ckvp"
        scale_key = "kps" if "kps" in leaf else "ckvs"
        assert leaf[code_key].dtype == jnp.int8
        assert leaf[scale_key].dtype == jnp.float32


def test_int8_kv_slot_recycling_resets_scales():
    """A recycled slot's blocks may carry stale int8 codes + scales; the
    allocator hands fresh blocks in logical order and lengths gate reads, so
    a new sequence in a recycled slot decodes exactly like a fresh engine."""
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    rng = np.random.default_rng(8)
    p1 = [rng.integers(0, arch.vocab, (6,)).astype(np.int32) for _ in range(3)]
    p2 = rng.integers(0, arch.vocab, (9,)).astype(np.int32)
    kw = dict(batch=1, max_seq=64, block_size=4, prefill_chunk=4, kv_quant=True)
    engine = PagedServeEngine(arch, params, **kw)
    engine.generate(p1, max_new=3)  # churn: 3 sequences recycle slot 0
    got = engine.generate([p2], max_new=3)
    fresh = PagedServeEngine(arch, params, **kw)
    assert got == fresh.generate([p2], max_new=3)


def test_paged_engine_empty_prompt_synthesizes_bos():
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    engine = PagedServeEngine(arch, params, batch=2, max_seq=32, block_size=4)
    outs = engine.generate([np.zeros((0,), np.int32)], max_new=2)
    assert len(outs[0]) == 2
    assert outs[0] == _greedy_reference(arch, params, [engine.bos_id], 2)


def test_admission_round_cannot_jointly_overcommit():
    """Two requests that each fit the free pool but not together: the same
    admissions round must admit only the first (round-local budget), stall
    the second, and still serve everything — never crash allocate()."""
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    # 3 usable blocks; each request needs 2 -> individually yes, jointly no
    engine = PagedServeEngine(arch, params, batch=2, max_seq=32, block_size=4,
                              prefill_chunk=4, num_blocks=4)
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, arch.vocab, (6,)).astype(np.int32) for _ in range(2)]
    outs = engine.generate(prompts, max_new=2)
    for p, o in zip(prompts, outs):
        assert o == _greedy_reference(arch, params, list(p), 2, max_seq=32)


def test_paged_engine_admission_stalls_until_blocks_free():
    """More concurrent tokens than blocks: the scheduler must queue the third
    request until a finished one releases its blocks — never crash."""
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    # 2 slots; blocks for ~2 requests of (6 prompt + 2 new) at block_size 4
    engine = PagedServeEngine(arch, params, batch=2, max_seq=32, block_size=4,
                              prefill_chunk=4, num_blocks=5)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, arch.vocab, (6,)).astype(np.int32) for _ in range(3)]
    outs = engine.generate(prompts, max_new=2)
    assert all(len(o) == 2 for o in outs)
    for p, o in zip(prompts, outs):
        assert o == _greedy_reference(arch, params, list(p), 2, max_seq=32)


# ---------------------------------------------------------------------------
# chunked prefill == token-by-token prefill (cache-view contract)
# ---------------------------------------------------------------------------


def test_chunked_prefill_wider_than_ring_window():
    """A prefill chunk longer than a sliding-window ring maps several tokens
    to the same slot; only the last write may survive (duplicate-scatter
    order is implementation-defined, so earlier ones are dropped up front).
    Regression: chunk 24 > reduced window 16 must equal token-by-token."""
    arch = reduced(get_arch("h2o-danube-1.8b"))
    params = _params(arch)
    prompts = [np.arange(24, dtype=np.int32) % arch.vocab]
    paged = PagedServeEngine(arch, params, batch=1, max_seq=64, block_size=4,
                             prefill_chunk=24)
    got = paged.generate(prompts, max_new=3)
    assert got[0] == _greedy_reference(arch, params, list(prompts[0]), 3)


@pytest.mark.parametrize("name", ["h2o-danube-1.8b", "rwkv6-7b"])
def test_chunked_prefill_matches_stepwise_on_contiguous_cache(name):
    """apply_lm with T > 1 against a cache (ring + recurrent layouts) equals
    feeding the same tokens one at a time."""
    arch = reduced(get_arch(name))
    params = _params(arch)
    toks = np.arange(7, dtype=np.int32) % arch.vocab

    step = init_cache(arch, 1, 32, dtype=jnp.dtype(arch.compute_dtype))
    logits_step = None
    for pos, t in enumerate(toks):
        logits_step, step, _ = apply_lm(
            params, arch, tokens=jnp.asarray([[t]], jnp.int32), cache=step,
            start_pos=jnp.asarray(pos, jnp.int32),
        )

    chunked = init_cache(arch, 1, 32, dtype=jnp.dtype(arch.compute_dtype))
    logits_chunk = None
    for lo in (0, 3):  # chunks of 3 and 4
        hi = lo + 3 if lo == 0 else 7
        logits_chunk, chunked, _ = apply_lm(
            params, arch, tokens=jnp.asarray(toks[None, lo:hi], jnp.int32),
            cache=chunked, start_pos=jnp.asarray(lo, jnp.int32),
        )
    np.testing.assert_allclose(
        np.asarray(logits_chunk[0, -1]), np.asarray(logits_step[0, 0]), atol=1e-4
    )


# ---------------------------------------------------------------------------
# on-device sampling
# ---------------------------------------------------------------------------


def test_sampling_greedy_matches_argmax():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(3, 17)), jnp.float32)
    got = sample_tokens(logits, SampleConfig(), KEY)
    np.testing.assert_array_equal(np.asarray(got), np.argmax(np.asarray(logits), -1))


def test_sampling_topk_stays_in_topk_set():
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    cfg = SampleConfig(method="topk", top_k=3, temperature=0.7)
    toks = np.asarray(sample_tokens(logits, cfg, KEY))
    top3 = np.argsort(np.asarray(logits), -1)[:, -3:]
    assert all(t in row for t, row in zip(toks, top3))


def test_sampling_temperature_is_key_deterministic():
    logits = jnp.asarray(np.random.default_rng(2).normal(size=(4, 11)), jnp.float32)
    cfg = SampleConfig(method="temperature", temperature=1.3)
    a = sample_tokens(logits, cfg, KEY)
    b = sample_tokens(logits, cfg, KEY)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError):
        SampleConfig(method="topk", top_k=0)
    with pytest.raises(ValueError):
        SampleConfig(method="nucleus")


def test_sampling_zero_temperature_is_greedy():
    """Regression: temperature 0 divided logits by the 1e-6 floor, inflating
    them to +/-inf and feeding NaN probabilities into jax.random.categorical
    (--temperature 0 decoded garbage); the zero-temperature limit IS argmax."""
    logits = jnp.asarray(np.random.default_rng(3).normal(size=(5, 13)), jnp.float32)
    want = np.argmax(np.asarray(logits), -1)
    for temp in (0.0, 1e-7):
        cfg = SampleConfig(method="temperature", temperature=temp)
        np.testing.assert_array_equal(np.asarray(sample_tokens(logits, cfg, KEY)), want)
    with pytest.raises(ValueError):
        SampleConfig(method="temperature", temperature=-0.5)


def test_sampling_topk_beyond_vocab_is_clamped():
    """Regression: top_k > vocab crashed inside lax.top_k; top-V-of-V is
    plain temperature sampling, so the clamp must sample identically to it."""
    logits = jnp.asarray(np.random.default_rng(4).normal(size=(4, 7)), jnp.float32)
    cfg = SampleConfig(method="topk", top_k=99, temperature=0.8)
    toks = np.asarray(sample_tokens(logits, cfg, KEY))
    assert ((0 <= toks) & (toks < 7)).all()
    plain = np.asarray(sample_tokens(
        logits, SampleConfig(method="temperature", temperature=0.8), KEY))
    np.testing.assert_array_equal(toks, plain)


def test_paged_engine_temperature_sampling_runs():
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    engine = PagedServeEngine(
        arch, params, batch=2, max_seq=32, block_size=4,
        sample=SampleConfig(method="temperature", temperature=0.9), seed=7,
    )
    outs = engine.generate([np.arange(4, dtype=np.int32)] * 2, max_new=3)
    assert all(len(o) == 3 for o in outs)
    assert all(0 <= t < arch.vocab for o in outs for t in o)


# ---------------------------------------------------------------------------
# MLA + int4 decode-kernel engine coverage
# ---------------------------------------------------------------------------


def test_mla_decode_kernel_matches_gathered_view():
    """Runtime(decode_kernel=True) on the MLA arch routes absorbed decode
    through the Pallas latent-attention kernel (scores + PV directly on the
    compressed pools); greedy tokens must match the gathered-view path."""
    arch = reduced(get_arch("deepseek-v3-671b"))
    params = _params(arch)
    rng = np.random.default_rng(41)
    prompts = [rng.integers(0, arch.vocab, (n,)).astype(np.int32) for n in (5, 8)]
    kw = dict(batch=2, max_seq=64, block_size=4, prefill_chunk=4)
    base = PagedServeEngine(arch, params, **kw)
    want = base.generate(prompts, max_new=4)
    kern = PagedServeEngine(arch, params, rt=Runtime(decode_kernel=True), **kw)
    assert kern.generate(prompts, max_new=4) == want


@pytest.mark.parametrize("kv_bits", [8, 4])
def test_mla_quantized_kv_decode_kernel_matches_gathered_view(kv_bits):
    """int8 / packed-int4 latent pools through the MLA kernel: the
    in-register dequant (+ nibble unpack) and the absorb path's activation
    fake-quant reproduce the gathered dequant path token-for-token."""
    arch = reduced(get_arch("deepseek-v3-671b"))
    params = _params(arch)
    rng = np.random.default_rng(42)
    prompts = [rng.integers(0, arch.vocab, (n,)).astype(np.int32) for n in (6, 9)]
    kw = dict(batch=2, max_seq=64, block_size=4, prefill_chunk=4,
              kv_quant=True, kv_bits=kv_bits)
    base = PagedServeEngine(arch, params, **kw)
    want = base.generate(prompts, max_new=4)
    kern = PagedServeEngine(arch, params, rt=Runtime(decode_kernel=True), **kw)
    assert kern.generate(prompts, max_new=4) == want


def test_int4_kv_decode_kernel_matches_gathered_view():
    """The packed-int4 GQA pools ride the decode kernel (PR 5 left them on
    the gathered path): in-register nibble unpack must match the gathered
    dequant path token-for-token."""
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    rng = np.random.default_rng(43)
    prompts = [rng.integers(0, arch.vocab, (n,)).astype(np.int32) for n in (5, 8)]
    kw = dict(batch=2, max_seq=64, block_size=4, prefill_chunk=4,
              kv_quant=True, kv_bits=4)
    base = PagedServeEngine(arch, params, **kw)
    want = base.generate(prompts, max_new=4)
    kern = PagedServeEngine(arch, params, rt=Runtime(decode_kernel=True), **kw)
    assert kern.generate(prompts, max_new=4) == want


# ---------------------------------------------------------------------------
# bursty / skewed-wave scheduler robustness
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("decode_steps", [1, 4])
def test_bursty_skewed_wave_completes_under_block_pressure(decode_steps):
    """The ROADMAP's heavy-traffic shape: one burst of many short prompts
    with a few 3x-long ones mixed in, against a block budget far below the
    wave's total demand.  The admission gate + strict-FIFO scheduler must
    drain the whole wave — no starvation of the long requests, no
    head-of-queue deadlock ("scheduler stalled" raises), and every request
    decodes its full budget with correct greedy tokens.  Swept per-tick and
    fused-megastep."""
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    rng = np.random.default_rng(44)
    short = [rng.integers(0, arch.vocab, (rng.integers(3, 7),)).astype(np.int32)
             for _ in range(7)]
    long = [rng.integers(0, arch.vocab, (24,)).astype(np.int32) for _ in range(2)]
    # interleave the long prompts mid-wave so they hit the queue head while
    # shorter requests still hold blocks
    prompts = short[:3] + long[:1] + short[3:6] + long[1:] + short[6:]
    engine = PagedServeEngine(
        arch, params, batch=2, max_seq=64, block_size=4, prefill_chunk=4,
        num_blocks=20, decode_steps=decode_steps,  # ~2 live requests' worth
    )
    outs = engine.generate(prompts, max_new=5)
    assert all(len(o) == 5 for o in outs)
    for p, o in zip(prompts, outs):
        assert o == _greedy_reference(arch, params, list(p), 5)


def test_bursty_wave_no_starvation_order():
    """Strict FIFO under pressure: a long request at the queue head must be
    admitted before later short ones finish leapfrogging it forever — its
    first token lands no later than the wave's last admission."""
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    rng = np.random.default_rng(45)
    long_p = rng.integers(0, arch.vocab, (20,)).astype(np.int32)
    shorts = [rng.integers(0, arch.vocab, (4,)).astype(np.int32) for _ in range(5)]
    engine = PagedServeEngine(
        arch, params, batch=1, max_seq=64, block_size=4, prefill_chunk=4,
        num_blocks=12,
    )
    outs = engine.generate([long_p] + shorts, max_new=3)
    assert outs[0] == _greedy_reference(arch, params, list(long_p), 3)
    assert all(len(o) == 3 for o in outs)
