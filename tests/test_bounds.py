"""Accumulator bound equations (paper Sec. 3, Fig. 3)."""

import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic parametrized sweep when hypothesis is absent
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.core import bounds


def test_paper_motivating_example():
    # App. A: K=784, M=8, N=1 unsigned -> 19-bit data-type bound.
    assert bounds.min_accumulator_bits_data_type(784, 1, 8, signed_input=False) == 19


def test_int_range_conventions():
    assert bounds.int_range(8, True) == (-128, 127)
    assert bounds.int_range(8, False) == (0, 255)
    assert bounds.int_range(1, False) == (0, 1)


@given(
    K=st.integers(1, 1 << 20),
    N=st.integers(1, 16),
    M=st.integers(2, 16),
    signed=st.booleans(),
)
@settings(max_examples=200, deadline=None)
def test_data_type_bound_is_sound(K, N, M, signed):
    """A P-bit accumulator at the bound must hold the worst-case sum exactly."""
    P = bounds.min_accumulator_bits_data_type(K, N, M, signed)
    x_mag = 2**N - 1 if not signed else 2 ** (N - 1)
    w_mag = 2 ** (M - 1)
    worst = K * x_mag * w_mag
    assert worst <= 2 ** (P - 1) - 1 or worst <= 2 ** (P - 1)
    # paper's simplification |x| <= 2^N makes the bound conservative; the
    # strictly-safe inequality always holds:
    assert K * (2 ** (N - int(signed))) * w_mag <= 2 ** (P - 1)


@given(
    K=st.integers(1, 4096),
    N=st.integers(1, 12),
    M=st.integers(2, 10),
    signed=st.booleans(),
)
@settings(max_examples=100, deadline=None)
def test_weight_bound_tighter_than_datatype(K, N, M, signed):
    """Eq. 12 with the worst-case l1 norm equals/never exceeds Eq. 8 usage."""
    rng = np.random.default_rng(K * 31 + N)
    w = rng.integers(-(2 ** (M - 1)), 2 ** (M - 1), K)
    l1 = float(np.abs(w).sum())
    if l1 == 0:
        return
    p_w = bounds.min_accumulator_bits_weights(l1, N, signed)
    p_d = bounds.min_accumulator_bits_data_type(K, N, M, signed)
    assert p_w <= p_d


@given(P=st.integers(2, 32), N=st.integers(1, 12), signed=st.booleans())
@settings(max_examples=100, deadline=None)
def test_l1_budget_inverts_weight_bound(P, N, signed):
    """Eq. 15 is the inverse of Eq. 12: a channel exactly at the budget needs
    exactly P bits (never more)."""
    budget = bounds.l1_budget(P, N, signed)
    if budget < 1:
        return
    p_needed = bounds.min_accumulator_bits_weights(budget, N, signed)
    assert p_needed <= P


def test_verify_no_overflow():
    w = np.array([[10, -20, 30]])
    # worst |sum| = 60 * (2^8-1 <= 2^8) for unsigned 8b input
    assert bounds.verify_no_overflow(w, N=8, signed_input=False, P=16)
    assert not bounds.verify_no_overflow(w * 1000, N=8, signed_input=False, P=16)


def test_phi_limits():
    assert float(bounds.phi(0.0)) == pytest.approx(1.0)
    assert float(bounds.phi(40.0)) == pytest.approx(0.0, abs=1e-9)
