"""THE property: A2Q-quantized weights can never overflow a P-bit accumulator
— for any inputs, any MAC order, any training-time parameter values.

Hypothesis drives (shapes, bit widths, parameter perturbations); the bit-exact
numpy simulator replays the dot products with wraparound and saturating
accumulators and must agree with the ideal wide accumulator everywhere.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic parametrized sweep when hypothesis is absent
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.core.a2q import (
    a2q_channel_l1,
    a2q_int_weights,
    a2q_norm_cap,
    a2q_penalty,
    apply_a2q,
    init_a2q,
)
from repro.core.bounds import l1_budget
from repro.core.integer import accumulate_dot, mac_order_audit, overflow_stats


@st.composite
def a2q_cases(draw):
    K = draw(st.integers(2, 96))
    C = draw(st.integers(1, 8))
    M = draw(st.integers(3, 8))
    N = draw(st.integers(1, 8))
    P = draw(st.integers(max(N + 2, 4), 24))
    signed = draw(st.booleans())
    seed = draw(st.integers(0, 2**16))
    # arbitrary (t, d) perturbations: the guarantee must hold at EVERY point in
    # parameter space, not just at init (training visits arbitrary values).
    dt = draw(st.floats(-4, 8))
    dd = draw(st.floats(-2, 2))
    return K, C, M, N, P, signed, seed, dt, dd


@given(a2q_cases())
@settings(max_examples=60, deadline=None)
def test_integer_weights_respect_l1_budget(case):
    K, C, M, N, P, signed, seed, dt, dd = case
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(0, 1.0, (K, C)), jnp.float32)
    params = init_a2q(w, M, P, N, signed)
    params = {
        "v": params["v"],
        "t": params["t"] + dt,  # push t above/below the cap arbitrarily
        "d": params["d"] + dd,
    }
    q, s = a2q_int_weights(params, M, P, N, signed)
    q = np.asarray(q)
    budget = l1_budget(P, N, signed)
    l1 = np.abs(q).sum(axis=0)
    assert (l1 <= budget + 1e-6).all(), (l1.max(), budget)


@given(a2q_cases())
@settings(max_examples=30, deadline=None)
def test_no_overflow_any_input_any_order(case):
    K, C, M, N, P, signed, seed, dt, dd = case
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(0, 1.0, (K, C)), jnp.float32)
    params = init_a2q(w, M, P, N, signed)
    params = {"v": params["v"], "t": params["t"] + dt, "d": params["d"] + dd}
    q, _ = a2q_int_weights(params, M, P, N, signed)
    q = np.asarray(q).astype(np.int64)

    # adversarial inputs: worst-case magnitudes with signs aligned to weights
    lo, hi = (-(2 ** (N - 1)), 2 ** (N - 1) - 1) if signed else (0, 2**N - 1)
    x_rand = rng.integers(lo, hi + 1, (4, K))
    x_worst = np.where(q.sum(1) >= 0, hi, lo)[None, :]  # align signs
    x = np.concatenate([x_rand, x_worst], axis=0)

    exact = accumulate_dot(x, q, 64, "exact")
    wrap = accumulate_dot(x, q, P, "wrap")
    np.testing.assert_array_equal(exact, wrap)
    for order_seed in range(2):
        order = np.random.default_rng(order_seed).permutation(K)
        sat = accumulate_dot(x, q, P, "saturate", order=order)
        np.testing.assert_array_equal(exact, sat)
    stats = overflow_stats(x, q, P)
    assert stats["events"] == 0


@given(a2q_cases())
@settings(max_examples=30, deadline=None)
def test_dequantized_matches_int_times_scale(case):
    K, C, M, N, P, signed, seed, dt, dd = case
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(0, 1.0, (K, C)), jnp.float32)
    params = init_a2q(w, M, P, N, signed)
    deq = apply_a2q(params, M, P, N, signed)
    q, s = a2q_int_weights(params, M, P, N, signed)
    np.testing.assert_allclose(np.asarray(deq), np.asarray(q * s), rtol=1e-6)


def test_penalty_zero_iff_under_cap():
    w = jnp.asarray(np.random.default_rng(0).normal(0, 1, (32, 4)), jnp.float32)
    params = init_a2q(w, 8, 16, 8, True)
    assert float(a2q_penalty(params, 16, 8, True)) == 0.0  # init clamps t <= T
    bumped = dict(params, t=params["t"] + 3.0)
    assert float(a2q_penalty(bumped, 16, 8, True)) > 0.0


def test_norm_cap_formula():
    d = jnp.zeros((3,))
    T = a2q_norm_cap(d, acc_bits=16, input_bits=8, input_signed=False)
    expect = 0 + np.log2(2**15 - 1) + 0 - 8
    np.testing.assert_allclose(np.asarray(T), expect, rtol=1e-6)


def test_gradients_flow_through_a2q():
    w = jnp.asarray(np.random.default_rng(0).normal(0, 1, (16, 4)), jnp.float32)
    params = init_a2q(w, 8, 20, 8, True)

    def loss(p):
        wq = apply_a2q(p, 8, 20, 8, True)
        return jnp.sum(wq**2)

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["v"]).sum()) > 0
    assert float(jnp.abs(g["t"]).sum()) > 0
    assert float(jnp.abs(g["d"]).sum()) >= 0  # d may sit on a flat region


def test_training_drives_sparsity_up_as_P_shrinks():
    """Fig. 5's mechanism: tighter budget -> more zero integer weights."""
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(0, 1, (128, 8)), jnp.float32)
    sparsities = []
    for P in (24, 16, 12, 9):
        params = init_a2q(w, 8, P, 8, False)
        q, _ = a2q_int_weights(params, 8, P, 8, False)
        sparsities.append(float(np.mean(np.asarray(q) == 0)))
    assert sparsities == sorted(sparsities), sparsities
    assert sparsities[-1] > sparsities[0]
