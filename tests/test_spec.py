"""Speculative decoding subsystem: lossless spec-vs-plain greedy parity,
drafters, copy-on-write rollback, refcount/prefix-registry invariants,
int4 KV codes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.configs import get_arch, reduced
from repro.models.lm import Runtime, init_lm
from repro.nn.module import unbox
from repro.serve.engine import PagedServeEngine, deploy_params, parity_up_to_ties
from repro.serve.paged_cache import PagedKVCache, TRASH_BLOCK
from repro.serve.spec import ModelDrafter, SelfDrafter, SpecServeEngine
from repro.serve.spec.verify import accept_prefix

KEY = jax.random.PRNGKey(0)


def _params(arch, seed=0):
    return unbox(init_lm(jax.random.PRNGKey(seed), arch))


def _prompts(arch, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, arch.vocab, (n,)).astype(np.int32) for n in lens]


# ---------------------------------------------------------------------------
# accept-prefix semantics (pure host logic)
# ---------------------------------------------------------------------------


def test_accept_prefix_cases():
    # full acceptance emits the bonus token
    assert accept_prefix([3, 5, 7], [3, 5, 7, 9]) == (3, [3, 5, 7, 9])
    # first mismatch emits the verifier's correction
    assert accept_prefix([3, 5, 7], [3, 4, 7, 9]) == (1, [3, 4])
    # immediate mismatch degenerates to one plain-decode token
    assert accept_prefix([3, 5, 7], [2, 5, 7, 9]) == (0, [2])


# ---------------------------------------------------------------------------
# spec-vs-plain greedy parity (the tentpole acceptance gate)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["yi-6b", "smollm-135m", "deepseek-v3-671b"])
def test_spec_matches_plain_greedy(name):
    """Token-identical greedy output vs non-speculative paged decode, mixed
    prompt lengths through fewer slots than requests, measured acceptance
    > 0, and every block back on the free list after the drain."""
    arch = reduced(get_arch(name))
    params = _params(arch)
    prompts = _prompts(arch, (5, 3, 9, 2), seed=0)
    plain = PagedServeEngine(arch, params, batch=2, max_seq=64, block_size=4, prefill_chunk=4)
    want = plain.generate(prompts, max_new=6)
    spec = SpecServeEngine(arch, params, batch=2, max_seq=64, block_size=4,
                           prefill_chunk=4, spec_k=3)
    got = spec.generate(prompts, max_new=6)
    assert got == want
    assert spec.acceptance_rate() > 0
    assert spec.spec_stats["rounds"] > 0
    assert spec.cache.free_blocks == spec.cache.num_blocks - 1
    assert int(spec.cache.refcounts.sum()) == 0
    # per-request acceptance bookkeeping rode along
    assert all(r.spec_proposed > 0 for r in spec.last_requests)


def test_spec_matches_plain_on_deployed_int8():
    """Precision-staged drafting for real: deployed q8/s8 weights, the draft
    scan runs the fused W8A8 path while verify keeps the dequant fp32 dot —
    output must still be token-identical to plain decode of the same
    artifact."""
    arch = reduced(get_arch("yi-6b"))
    params = deploy_params(_params(arch), arch.quant)
    prompts = _prompts(arch, (6, 4), seed=1)
    plain = PagedServeEngine(arch, params, batch=2, max_seq=64, block_size=4, prefill_chunk=4)
    want = plain.generate(prompts, max_new=5)
    spec = SpecServeEngine(arch, params, batch=2, max_seq=64, block_size=4,
                           prefill_chunk=4, spec_k=3)
    assert spec.generate(prompts, max_new=5) == want
    assert spec.acceptance_rate() > 0


def test_spec_composes_with_int8_kv_and_decode_kernel():
    """One shared int8 cache, two precision views: the draft reads int8
    codes (through the Pallas kernel), verify reads the dequant fp32 gather.
    Spec output is token-identical to plain decode of the SAME kv-int8
    config (losslessness is relative to the verify path)."""
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    prompts = _prompts(arch, (10, 7, 4), seed=2)
    kw = dict(batch=2, max_seq=64, block_size=8, prefill_chunk=8, kv_quant=True)
    plain = PagedServeEngine(arch, params, **kw)
    want = plain.generate(prompts, max_new=5)
    spec = SpecServeEngine(
        arch, params, spec_k=2,
        draft_rt=Runtime(int_forward=True, decode_kernel=True), **kw,
    )
    assert spec.generate(prompts, max_new=5) == want


def test_spec_recurrent_refuses_or_falls_back():
    """rwkv6 has recurrent state that cannot unwind a rejected draft:
    strict=True refuses; the default falls back to plain decode cleanly
    (token-identical, spec never activates)."""
    arch = reduced(get_arch("rwkv6-7b"))
    params = _params(arch)
    with pytest.raises(ValueError):
        SpecServeEngine(arch, params, batch=2, max_seq=64, strict=True)
    prompts = _prompts(arch, (5, 3), seed=3)
    plain = PagedServeEngine(arch, params, batch=2, max_seq=64, block_size=4, prefill_chunk=4)
    want = plain.generate(prompts, max_new=3)
    spec = SpecServeEngine(arch, params, batch=2, max_seq=64, block_size=4, prefill_chunk=4)
    assert spec.generate(prompts, max_new=3) == want
    assert not spec.spec_active()
    assert spec.spec_stats["rounds"] == 0
    assert spec.spec_stats["fallback_rounds"] > 0


def test_spec_rejects_non_greedy_sampling():
    from repro.serve.sampling import SampleConfig

    arch = reduced(get_arch("yi-6b"))
    with pytest.raises(ValueError):
        SpecServeEngine(arch, _params(arch), batch=2, max_seq=64,
                        sample=SampleConfig(method="temperature", temperature=0.9))


def test_spec_model_drafter_lossless_and_synced():
    """A separate small draft model (smollm drafting for yi): acceptance is
    near-chance on random weights, but output is STILL token-identical —
    losslessness comes from the verifier.  The draft cache must track the
    accepted stream (truncate rollback + pending delta on full accepts)."""
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    darch = reduced(get_arch("smollm-135m"))
    drafter = ModelDrafter(darch, _params(darch, seed=7), slots=2, max_seq=64,
                           spec_k=2, block_size=4, prefill_chunk=4)
    prompts = _prompts(arch, (5, 8, 3), seed=4)
    plain = PagedServeEngine(arch, params, batch=2, max_seq=64, block_size=4, prefill_chunk=4)
    want = plain.generate(prompts, max_new=5)
    spec = SpecServeEngine(arch, params, batch=2, max_seq=64, block_size=4,
                           prefill_chunk=4, spec_k=2, drafter=drafter,
                           min_accept=0.0)  # never fall back: exercise sync paths
    assert spec.generate(prompts, max_new=5) == want
    assert spec.cache.free_blocks == spec.cache.num_blocks - 1
    assert drafter.cache.free_blocks == drafter.cache.num_blocks - 1


def test_spec_self_drafter_syncs_draft_cache_on_full_accept():
    """Self-drafting with the model's own runtime accepts everything: every
    round must emit k+1 tokens (k drafts + bonus) and the pending-delta path
    in the next round must keep parity."""
    arch = reduced(get_arch("smollm-135m"))
    params = _params(arch)
    prompts = _prompts(arch, (4,), seed=5)
    plain = PagedServeEngine(arch, params, batch=1, max_seq=64, block_size=4, prefill_chunk=4)
    want = plain.generate(prompts, max_new=9)
    spec = SpecServeEngine(arch, params, batch=1, max_seq=64, block_size=4,
                           prefill_chunk=4, spec_k=4,
                           drafter=SelfDrafter(arch, Runtime()))
    assert spec.generate(prompts, max_new=9) == want
    # identical draft/verify runtimes: full acceptance, bonus every round
    assert spec.acceptance_rate() == 1.0
    assert spec.spec_stats["bonus"] == spec.spec_stats["rounds"]


class _GarbageDrafter(SelfDrafter):
    """Adversarial drafter: proposes (argmax + 1) mod vocab — always wrong."""

    def propose(self, engine, live, tok_in, k):
        good = super().propose(engine, live, tok_in, k)
        return (good + 1) % engine.arch.vocab


def test_spec_adaptive_fallback_on_collapsed_acceptance():
    """A drafter that stops guessing right must trip the acceptance EMA:
    the engine falls back to plain ticks (with periodic probes) and the
    output stays token-identical throughout."""
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    prompts = _prompts(arch, (4, 6), seed=6)
    plain = PagedServeEngine(arch, params, batch=2, max_seq=64, block_size=4, prefill_chunk=4)
    want = plain.generate(prompts, max_new=10)
    spec = SpecServeEngine(arch, params, batch=2, max_seq=64, block_size=4,
                           prefill_chunk=4, spec_k=3,
                           drafter=_GarbageDrafter(arch, Runtime()),
                           min_accept=0.5, probe_interval=3)
    assert spec.generate(prompts, max_new=10) == want
    assert spec.acceptance_rate() == 0.0
    assert spec.spec_stats["fallback_rounds"] > 0  # plain ticks happened
    assert spec.spec_stats["rounds"] >= 1  # including at least one probe


def test_spec_rollback_keeps_admission_reservation():
    """Regression: per-round rollback must NOT free blocks out of the
    request's admission reservation.  If it did, a lens at a block boundary
    would leave the next write position's table entry pointing at trash and
    the adaptive-fallback plain tick would silently write KV into the trash
    block (and a concurrent admission could claim the freed blocks,
    crashing the next round's allocate)."""
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    from repro.serve.engine import Request

    spec = SpecServeEngine(arch, params, batch=1, max_seq=64, block_size=4,
                           prefill_chunk=4, spec_k=3,
                           drafter=_GarbageDrafter(arch, Runtime()),
                           min_accept=0.9, probe_interval=100)
    req = Request(uid=0, prompt=np.arange(4, dtype=np.int32), max_new=12)
    spec.submit(req)
    need = spec.cache.blocks_needed(spec._slot_tokens(req))
    while not spec.sched.idle():
        spec.step()
        if spec.sched.slots[0] is not None:
            # reservation intact after every round: full block count owned,
            # no trash entry anywhere inside it (incl. the boundary block
            # the fallback tick will write next)
            assert len(spec.cache._owned[0]) == need
            assert all(spec.cache.tables[0, j] != TRASH_BLOCK for j in range(need))
    plain = PagedServeEngine(arch, params, batch=1, max_seq=64, block_size=4,
                             prefill_chunk=4)
    want = plain.generate([np.arange(4, dtype=np.int32)], max_new=12)
    assert req.generated == want[0]


def test_spec_headroom_guard_and_gate():
    """Speculative rounds write up to spec_k positions past the emitted
    stream: submit must reserve the headroom against max_seq and the
    admission gate against the block budget."""
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    spec = SpecServeEngine(arch, params, batch=1, max_seq=16, block_size=4,
                           prefill_chunk=4, spec_k=4)
    from repro.serve.engine import Request

    with pytest.raises(ValueError):
        # 8 + 6 fits max_seq=16 plainly, but not with k=4 headroom
        spec.submit(Request(uid=0, prompt=np.arange(8, dtype=np.int32), max_new=6))
    # a request that fits with headroom decodes to the end of max_seq range
    prompts = _prompts(arch, (6,), seed=7)
    plain = PagedServeEngine(arch, params, batch=1, max_seq=16, block_size=4, prefill_chunk=4)
    want = plain.generate(prompts, max_new=4)
    spec2 = SpecServeEngine(arch, params, batch=1, max_seq=16, block_size=4,
                            prefill_chunk=4, spec_k=4)
    assert spec2.generate(prompts, max_new=4) == want


# ---------------------------------------------------------------------------
# paged-cache refcount / CoW / rollback invariants (the satellite gate)
# ---------------------------------------------------------------------------


def _cache(slots=3, num_blocks=16, block_size=4, max_seq=32):
    arch = reduced(get_arch("yi-6b"))
    return PagedKVCache(arch, slots=slots, block_size=block_size,
                        max_seq=max_seq, num_blocks=num_blocks, dtype=jnp.float32)


def test_cow_shared_block_write_triggers_copy():
    """ensure_writable on a shared block must hand the writer a private copy
    with identical contents, leave the other reader's table untouched, and
    keep refcounts exact."""
    c = _cache()
    c.allocate(0, 8)  # blocks for tokens 0..7
    # stamp recognizable content into slot 0's second block
    b1 = c._owned[0][1]
    c.pools = jax.tree_util.tree_map_with_path(
        lambda p, l: l.at[:, b1].set(7.0) if p[-1].key in ("kp", "vp") else l, c.pools
    )
    c.adopt_prefix(1, 6, tuple(c._owned[0][:2]))  # slot 1 shares both blocks
    assert c.refcounts[b1] == 2
    free_before = c.free_blocks
    c.ensure_writable(1, 6, 8)  # slot 1 writes into the shared tail block
    assert c.cow_copies == 1
    nb = c._owned[1][1]
    assert nb != b1 and c.tables[1, 1] == nb
    assert c.tables[0, 1] == b1  # the donor still reads the original
    assert c.refcounts[b1] == 1 and c.refcounts[nb] == 1
    assert c.free_blocks == free_before - 1
    # the copy carried the contents
    leaf = c.pools["0"]["attn"]["kp"]
    np.testing.assert_array_equal(np.asarray(leaf[:, nb]), np.asarray(leaf[:, b1]))
    # unshared spans are a no-op
    copies = c.cow_copies
    c.ensure_writable(1, 6, 8)
    assert c.cow_copies == copies


def test_refcount_free_only_at_zero_and_trash_never_refcounted():
    c = _cache()
    c.allocate(0, 8)
    shared = tuple(c._owned[0])
    c.adopt_prefix(1, 7, shared)
    c.adopt_prefix(2, 7, shared)
    assert all(c.refcounts[b] == 3 for b in shared)
    free0 = c.free_blocks
    c.release(0)
    assert c.free_blocks == free0  # still held by 1 and 2
    c.release(1)
    assert c.free_blocks == free0  # still held by 2
    c.release(2)
    assert c.free_blocks == free0 + len(shared)  # refcount zero frees
    assert c.refcounts[TRASH_BLOCK] == 0
    assert TRASH_BLOCK not in c.free
    assert int(c.refcounts.sum()) == 0


def test_truncate_restores_allocator_state_exactly():
    """The speculative-round rollback: allocate headroom, write-watermark it,
    truncate back — free list, tables, owned lists, and refcounts must all
    equal the pre-round snapshot (garbage past lens is masked, not freed)."""
    c = _cache()
    c.allocate(0, 6)
    c.ensure_writable(0, 0, 6)
    c.lens[0] = 6
    snap = (list(c.free), c.tables.copy(), [list(o) for o in c._owned],
            c.refcounts.copy(), c.lens.copy())
    # a spec round: k=5 headroom, all rejected
    c.allocate(0, 6 + 5 + 1)
    c.ensure_writable(0, 6, 12)
    assert c.free_blocks < len(snap[0])
    c.truncate(0, 6)
    free, tables, owned, rc, lens = snap
    assert c.free == free  # exact order, not just the same set
    np.testing.assert_array_equal(c.tables, tables)
    assert [list(o) for o in c._owned] == owned
    np.testing.assert_array_equal(c.refcounts, rc)
    np.testing.assert_array_equal(c.lens, lens)
    assert c.watermarks[0] == 12  # the garbage extent stays recorded


def test_prefix_registry_pins_blocks_past_donor_release():
    """A registered prefix must survive its donor: blocks pinned by the
    entry's own refcount, freed only on eviction, and purged entries can
    never resurrect recycled blocks.  Only whole-prompt-covered blocks are
    registered (10 tokens at block_size 4 => 2 blocks / 8 tokens): the
    donor keeps writing into its partial tail, so pinning it would freeze
    content the donor is still producing."""
    c = _cache(num_blocks=32, max_seq=64)
    toks = np.arange(10, dtype=np.int32)
    c.allocate(0, 14)
    c.lens[0] = 10
    c.register_prefix(0, toks)
    entry_blocks = tuple(c._owned[0][:2])
    assert c.registry_size() == 2  # full blocks only, never the tail
    assert c.registered_blocks() == frozenset(entry_blocks)
    c.release(0)
    # pinned: blocks stayed allocated, lookup still serves them (capped at
    # the entry's full-block coverage)
    assert all(c.refcounts[b] == 1 for b in entry_blocks)
    shared, blocks = c.lookup_prefix(np.concatenate([toks, [99, 98]]).astype(np.int32))
    assert shared == 8 and tuple(blocks) == entry_blocks
    # reclaim evicts and frees; the registry then misses
    c.reclaim(c.num_blocks)
    assert c.free_blocks == c.num_blocks - 1
    assert int(c.refcounts.sum()) == 0
    assert c.lookup_prefix(np.concatenate([toks, [99]]).astype(np.int32))[0] == 0


def test_donor_never_cows_its_registered_blocks():
    """Regression (review finding): a live donor's own decode writes must
    never hit a registry-pinned block — that CoW fault would demand a free
    block no admission budget reserved and crash mid-decode under
    pressure.  With full-block-only registration the donor's write span
    [len(prompt), ...) is disjoint from every pinned block even with ZERO
    free blocks left."""
    c = _cache(slots=2, num_blocks=5, block_size=4, max_seq=16)
    c.allocate(0, 8)  # both usable... donor takes 2 of 4 blocks
    c.lens[0] = 6
    c.register_prefix(0, np.arange(6, dtype=np.int32))
    c.allocate(1, 8)  # a second admission drains the free list
    assert c.free_blocks == 0
    # donor decodes across the old partial-tail positions and onward —
    # must neither copy nor crash
    c.ensure_writable(0, 6, 8)
    assert c.cow_copies == 0


def test_prefix_lookup_caps_below_full_prompt():
    """A fully-covered prompt must still leave >= 1 token to prefill."""
    c = _cache(num_blocks=32, max_seq=64)
    toks = np.arange(12, dtype=np.int32)
    c.allocate(0, 12)
    c.lens[0] = 12
    c.register_prefix(0, toks)
    shared, _ = c.lookup_prefix(toks)
    assert shared == 11  # len - 1, never the whole prompt


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 5), min_size=4, max_size=24))
def test_allocator_invariants_random_ops(ops_seq):
    """Property sweep over random allocate/adopt/write/truncate/release
    schedules: refcounts always equal owners + registry pins, the free list
    is disjoint from owned blocks, the trash block is never touched, and a
    fully-released cache returns every block."""
    c = _cache(slots=3, num_blocks=24, max_seq=32)
    lens_target = [0, 0, 0]
    for step, op in enumerate(ops_seq):
        slot = step % 3
        try:
            if op == 0:
                n = 4 + 4 * (step % 3)
                c.allocate(slot, n)
                lens_target[slot] = max(lens_target[slot], n)
                c.lens[slot] = lens_target[slot]
            elif op == 1 and lens_target[slot] >= 2:
                c.register_prefix(slot, np.arange(lens_target[slot], dtype=np.int32) + step)
            elif op == 2:
                donor = (slot + 1) % 3
                if c._owned[donor] and not c._owned[slot] and lens_target[donor] >= 4:
                    c.adopt_prefix(slot, 3, tuple(c._owned[donor][:1]))
                    lens_target[slot] = 3
            elif op == 3 and c._owned[slot]:
                end = min(len(c._owned[slot]) * c.block_size, int(c.lens[slot]) + 2)
                c.ensure_writable(slot, max(0, end - 3), end)
            elif op == 4 and c._owned[slot]:
                keep = max(0, int(c.lens[slot]) - 2)
                c.truncate(slot, keep)
                lens_target[slot] = keep
            elif op == 5:
                c.release(slot)
                lens_target[slot] = 0
        except RuntimeError:
            pass  # out of blocks under adversarial schedules is legal
        # -- invariants after every op --
        assert c.refcounts[TRASH_BLOCK] == 0
        assert TRASH_BLOCK not in c.free
        owners = np.zeros(c.num_blocks, np.int32)
        for o in c._owned:
            for b in o:
                owners[b] += 1
        np.testing.assert_array_equal(c.refcounts, owners + c._entry_rc)
        owned_set = {b for o in c._owned for b in o}
        assert not owned_set & set(c.free)
        assert all(c.refcounts[b] == 0 for b in c.free)
    for s in range(3):
        c.release(s)
    c.reclaim(c.num_blocks)
    assert c.free_blocks == c.num_blocks - 1
    assert int(c.refcounts.sum()) == 0


# ---------------------------------------------------------------------------
# prefix sharing through the engine
# ---------------------------------------------------------------------------


def test_prefix_share_engine_lossless_with_hits():
    """Common-prompt workload: sharing must be token-identical to the
    non-sharing engine, register real hits, and trigger CoW copies when
    writes land in shared blocks.  block_size 8 > prefill_chunk 4 makes the
    chunk-aligned resume offset (12, for the 13-token common prefix) land
    mid-block, so the adopted run ends in a partial block the resumed
    prefill writes into — the CoW path stays exercised through the engine
    even though block-aligned configs avoid it entirely."""
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    rng = np.random.default_rng(8)
    common = rng.integers(0, arch.vocab, (13,)).astype(np.int32)
    prompts = [np.concatenate([common, rng.integers(0, arch.vocab, (n,)).astype(np.int32)])
               for n in (3, 5, 2)]
    base = PagedServeEngine(arch, params, batch=2, max_seq=64, block_size=8, prefill_chunk=4)
    want = base.generate(prompts, max_new=4)
    shared = PagedServeEngine(arch, params, batch=2, max_seq=64, block_size=8,
                              prefill_chunk=4, prefix_share=True)
    assert shared.generate(prompts, max_new=4) == want
    assert shared.cache.prefix_hits >= 2
    assert shared.cache.prefix_hit_tokens >= 16
    assert shared.cache.cow_copies > 0
    # sharing skips recompute: fewer prefill tokens than the baseline
    assert shared.stats["prefill_tokens"] < base.stats["prefill_tokens"]
    # pinned prefixes survive the drain; full reclaim returns every block
    shared.cache.reclaim(shared.cache.num_blocks)
    assert shared.cache.free_blocks == shared.cache.num_blocks - 1


def test_prefix_share_under_block_pressure_reclaims_not_stalls():
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    rng = np.random.default_rng(9)
    common = rng.integers(0, arch.vocab, (8,)).astype(np.int32)
    prompts = [np.concatenate([common, rng.integers(0, arch.vocab, (n,)).astype(np.int32)])
               for n in (2, 3, 4)]
    base = PagedServeEngine(arch, params, batch=2, max_seq=32, block_size=4, prefill_chunk=4)
    want = base.generate(prompts, max_new=3)
    tight = PagedServeEngine(arch, params, batch=2, max_seq=32, block_size=4,
                             prefill_chunk=4, prefix_share=True, num_blocks=9)
    assert tight.generate(prompts, max_new=3) == want


def test_prefix_share_composes_with_spec():
    """Prefix sharing + speculative decoding: the spec round's draft/verify
    writes land past shared blocks via CoW, output still token-identical."""
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    rng = np.random.default_rng(10)
    common = rng.integers(0, arch.vocab, (9,)).astype(np.int32)
    prompts = [np.concatenate([common, rng.integers(0, arch.vocab, (n,)).astype(np.int32)])
               for n in (2, 4, 3)]
    plain = PagedServeEngine(arch, params, batch=2, max_seq=64, block_size=4, prefill_chunk=4)
    want = plain.generate(prompts, max_new=5)
    spec = SpecServeEngine(arch, params, batch=2, max_seq=64, block_size=4,
                           prefill_chunk=4, spec_k=3, prefix_share=True)
    assert spec.generate(prompts, max_new=5) == want
    assert spec.cache.prefix_hits >= 1


# ---------------------------------------------------------------------------
# int4 KV codes (packed two-per-byte on the int8 scale-pool machinery)
# ---------------------------------------------------------------------------


def test_int4_pack_unpack_roundtrip():
    from repro.nn.attention import _kv_quantize, _pack_nibbles, _unpack_nibbles

    rng = np.random.default_rng(11)
    val = jnp.asarray(rng.normal(size=(2, 3, 2, 16)), jnp.float32)
    codes, scale = _kv_quantize(val, bits=4)
    assert int(jnp.max(jnp.abs(codes))) <= 7
    packed = _pack_nibbles(codes)
    assert packed.dtype == jnp.uint8 and packed.shape[-1] == 8
    np.testing.assert_array_equal(np.asarray(_unpack_nibbles(packed)), np.asarray(codes))


def test_int4_pools_layout_and_bytes():
    """uint8 packed pools at half feature width; scale pools unchanged; KV
    bytes/token beats int8 (5.3x vs fp32 on reduced GQA: 8 + 4 vs 64 bytes
    per head at head_dim 16; 4.8x on MLA whose tiny rope pool is
    scale-dominated)."""
    for name in ("yi-6b", "deepseek-v3-671b"):
        arch = reduced(get_arch(name))
        fp = PagedKVCache(arch, 2, block_size=8, max_seq=64, dtype=jnp.float32)
        q8 = PagedKVCache(arch, 2, block_size=8, max_seq=64, dtype=jnp.float32,
                          kv_quant=True)
        q4 = PagedKVCache(arch, 2, block_size=8, max_seq=64, dtype=jnp.float32,
                          kv_quant=True, kv_bits=4)
        assert q4.kv_bytes_per_token() < q8.kv_bytes_per_token()
        assert fp.kv_bytes_per_token() / q4.kv_bytes_per_token() >= 4.5, name
        leaf = q4.pools["0"]["attn"]
        code_key = "kp" if "kp" in leaf else "ckvp"
        scale_key = "kps" if "kps" in leaf else "ckvs"
        assert leaf[code_key].dtype == jnp.uint8
        assert leaf[code_key].shape[-1] * 2 == q8.pools["0"]["attn"][code_key].shape[-1]
        assert leaf[scale_key].shape == q8.pools["0"]["attn"][scale_key].shape
    with pytest.raises(ValueError):
        PagedKVCache(reduced(get_arch("yi-6b")), 2, kv_quant=True, kv_bits=3)


@pytest.mark.parametrize("name", ["yi-6b", "deepseek-v3-671b"])
def test_int4_kv_parity_bound_vs_fp32(name):
    """int4 KV blocks hold the parity bound against fp32-KV greedy decode:
    the quantization step is 8x coarser than int8, so the tie tolerance
    widens accordingly (eps 0.5 vs the int8 gate's 0.05), but a mismatch at
    a confidently-decided step still fails."""
    arch = reduced(get_arch(name))
    params = _params(arch)
    prompts = _prompts(arch, (10, 7, 4), seed=12)
    kw = dict(batch=2, max_seq=64, block_size=8, prefill_chunk=8)
    ref_e = PagedServeEngine(arch, params, **kw)
    q4_e = PagedServeEngine(arch, params, kv_quant=True, kv_bits=4, **kw)
    ref_e.generate(prompts, max_new=6)
    outs_q4 = q4_e.generate(prompts, max_new=6)
    ok, ties, detail = parity_up_to_ties(ref_e.last_requests, outs_q4, eps=0.5)
    assert ok, detail


def test_int4_spec_composes():
    """Spec decoding over a shared int4 cache: lossless vs plain int4."""
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    prompts = _prompts(arch, (6, 4), seed=13)
    kw = dict(batch=2, max_seq=64, block_size=4, prefill_chunk=4,
              kv_quant=True, kv_bits=4)
    plain = PagedServeEngine(arch, params, **kw)
    want = plain.generate(prompts, max_new=4)
    spec = SpecServeEngine(arch, params, spec_k=2, **kw)
    assert spec.generate(prompts, max_new=4) == want


def test_cache_specs_rc_wm_leaves():
    """cache_specs knows the allocator bookkeeping leaves: watermarks ride
    with the batch, refcounts replicate (block axis local)."""
    from jax.sharding import PartitionSpec as P

    from repro.dist.sharding import ShardingRules, cache_specs

    class _FakeMesh:
        def __init__(self, shape):
            self.shape = dict(shape)
            self.axis_names = tuple(shape)

    mesh = _FakeMesh({"data": 2, "model": 4})
    arch = get_arch("yi-6b")
    rules = ShardingRules.default(mesh, arch)
    cache = PagedKVCache(reduced(arch), 8, block_size=4, max_seq=32, dtype=jnp.float32)
    cache.lens[:] = 1  # make wm/bt non-trivial
    state = jax.eval_shape(cache.device_state)
    specs = cache_specs({"_paged": state}, mesh, rules)["_paged"]
    assert specs["bt"] == P("data", None)
    assert specs["wm"] == P("data")
    assert specs["rc"] == P(None)
