"""Training substrate: loss goes down, checkpoint/restart is exact, keep-k GC,
elastic mesh planning, straggler detection, optimizers."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.data.synthetic import TokenStream
from repro.models import Runtime, init_lm
from repro.models.steps import build_train_step
from repro.nn.module import unbox
from repro.optim.optimizers import adafactor, adamw, clip_by_global_norm, sgdm
from repro.train import checkpoint as ckpt
from repro.train.elastic import StragglerWatchdog, plan_mesh
from repro.train.trainer import Trainer

KEY = jax.random.PRNGKey(0)


def _setup(opt=None):
    arch = reduced(get_arch("smollm-135m"))
    params = unbox(init_lm(KEY, arch))
    opt = opt or adamw()
    state = {"params": params, "opt_state": opt.init(params), "step": jnp.zeros((), jnp.int32)}
    step = build_train_step(arch, opt, Runtime(), lr_schedule=lambda s: jnp.float32(2e-3))
    stream = TokenStream(vocab=arch.vocab, seq_len=32, global_batch=4)
    return arch, state, step, stream


def test_loss_decreases():
    _, state, step, stream = _setup()
    tr = Trainer(step, stream.batch, log_every=1)
    res = tr.run(state, 30)
    first = np.mean([r["loss"] for r in res.history[:5]])
    last = np.mean([r["loss"] for r in res.history[-5:]])
    assert last < first - 0.1, (first, last)


@pytest.mark.parametrize("optname", ["sgdm", "adamw", "adafactor"])
def test_optimizers_reduce_loss(optname):
    opt = {"sgdm": sgdm(), "adamw": adamw(), "adafactor": adafactor(min_dim_size_to_factor=8)}[optname]
    _, state, step, stream = _setup(opt)
    tr = Trainer(step, stream.batch, log_every=1)
    res = tr.run(state, 20)
    assert res.history[-1]["loss"] < res.history[0]["loss"]


def test_checkpoint_roundtrip_and_resume(tmp_path):
    d = str(tmp_path / "ckpt")
    _, state, step, stream = _setup()
    tr = Trainer(step, stream.batch, ckpt_dir=d, ckpt_every=5, log_every=1)
    res = tr.run(state, 10)
    # fresh trainer resumes from step 10 and reproduces the same trajectory as
    # an uninterrupted 15-step run (stateless data stream => exact resume)
    _, state2, step2, _ = _setup()
    tr2 = Trainer(step2, stream.batch, ckpt_dir=d, ckpt_every=100, log_every=1)
    restored, start = tr2.maybe_restore(state2)
    assert start == 10
    res2 = tr2.run(restored, 5, start_step=start)

    _, state3, step3, _ = _setup()
    tr3 = Trainer(step3, stream.batch, log_every=1)
    res3 = tr3.run(state3, 15)
    np.testing.assert_allclose(res2.history[-1]["loss"], res3.history[-1]["loss"], rtol=1e-4)


def test_checkpoint_atomicity_and_keepk(tmp_path):
    d = str(tmp_path / "c2")
    tree = {"a": jnp.arange(5), "b": {"c": jnp.ones((2, 2))}}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, tree, s, keep=2)
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(d) if n.startswith("step_"))
    assert steps == [4, 5]
    # incomplete checkpoint (no sentinel) is ignored
    os.makedirs(os.path.join(d, "step_00000099"))
    assert ckpt.latest_step(d) == 5
    restored, step = ckpt.restore(d, tree)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(5))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "c3")
    ckpt.save(d, {"a": jnp.ones((3,))}, 1)
    with pytest.raises(ValueError):
        ckpt.restore(d, {"a": jnp.ones((4,))})


def test_checkpoint_allow_missing_keeps_like_values(tmp_path):
    """Turning on grad compression mid-run: the grad_err residuals are not
    in older checkpoints; allow_missing restores them from the `like` tree
    (zeros) instead of raising."""
    d = str(tmp_path / "c4")
    ckpt.save(d, {"a": jnp.arange(3.0)}, 1)
    like = {"a": jnp.zeros((3,)), "grad_err": {"local": jnp.full((2, 3), 7.0)}}
    with pytest.raises(KeyError):
        ckpt.restore(d, like)
    restored, step = ckpt.restore(d, like, allow_missing=True)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(3.0))
    np.testing.assert_array_equal(np.asarray(restored["grad_err"]["local"]), np.full((2, 3), 7.0))


def test_grad_compress_without_mesh_falls_back_to_plain_step():
    """grad_compress on a single device (no mesh / axis extent 1) resolves
    to the uncompressed path: no grad_err in the returned state."""
    from repro.dist.collectives import GradCompressConfig

    arch = reduced(get_arch("smollm-135m"))
    params = unbox(init_lm(KEY, arch))
    opt = adamw()
    rt = Runtime(grad_compress=GradCompressConfig(bits=8))
    step = build_train_step(arch, opt, rt, lr_schedule=lambda s: jnp.float32(1e-3))
    state = {"params": params, "opt_state": opt.init(params), "step": jnp.zeros((), jnp.int32)}
    stream = TokenStream(vocab=arch.vocab, seq_len=16, global_batch=2)
    new_state, metrics = jax.jit(step)(state, {k: jnp.asarray(v) for k, v in stream.batch(0).items()})
    assert set(new_state) == {"params", "opt_state", "step"}
    assert float(metrics["loss"]) > 0


def test_plan_mesh_elastic():
    # full fleet
    assert plan_mesh(512, prefer_model=16)["shape"] == (2, 16, 16)
    # lost a pod -> single pod
    p = plan_mesh(256, prefer_model=16)
    assert np.prod(p["shape"]) == 256 and p["shape"][-1] == 16
    # TP divisibility degrades model axis (9 heads)
    p = plan_mesh(256, prefer_model=16, model_divisors=[9])
    assert p["shape"][-1] == 1
    # odd survivor count still plans
    p = plan_mesh(96, prefer_model=16)
    assert np.prod(p["shape"]) == 96


def test_straggler_watchdog():
    events = []
    wd = StragglerWatchdog(window=16, threshold=1.5, min_samples=8,
                           on_straggler=lambda s, t, p: events.append(s))
    for i in range(32):
        wd.observe(i, 0.1)
    assert not wd.observe(32, 0.12)
    assert wd.observe(33, 0.5)
    assert events == [33]


def test_grad_clip():
    g = {"w": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["w"])) == pytest.approx(1.0, rel=1e-5)
