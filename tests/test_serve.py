"""Serve engine: continuous batching correctness + int8 deployment."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.core.bounds import l1_budget
from repro.models import apply_lm, init_cache, init_lm
from repro.nn.module import unbox
from repro.serve.engine import ServeEngine, deploy_params

KEY = jax.random.PRNGKey(0)


def _greedy_reference(arch, params, prompt, max_new):
    """Step-by-step single-sequence decode as the oracle."""
    cache = init_cache(arch, 1, 64, dtype=jnp.dtype(arch.compute_dtype))
    toks = list(prompt)
    logits = None
    for pos, t in enumerate(toks):
        logits, cache, _ = apply_lm(
            params, arch, tokens=jnp.asarray([[t]], jnp.int32), cache=cache,
            start_pos=jnp.asarray(pos, jnp.int32),
        )
    out = []
    pos = len(toks)
    for _ in range(max_new):
        nxt = int(jnp.argmax(logits[0, 0]))
        out.append(nxt)
        logits, cache, _ = apply_lm(
            params, arch, tokens=jnp.asarray([[nxt]], jnp.int32), cache=cache,
            start_pos=jnp.asarray(pos, jnp.int32),
        )
        pos += 1
    return out


def test_continuous_batching_matches_single_sequence():
    arch = reduced(get_arch("yi-6b"))
    params = unbox(init_lm(KEY, arch))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, arch.vocab, (n,)).astype(np.int32) for n in (5, 3, 7)]
    engine = ServeEngine(arch, params, batch=2, max_seq=64)  # 3 reqs through 2 slots
    outs = engine.generate(prompts, max_new=4)
    for p, o in zip(prompts, outs):
        want = _greedy_reference(arch, params, list(p), 4)
        assert o == want, (o, want)


def test_recurrent_arch_lockstep_generation():
    arch = reduced(get_arch("rwkv6-7b"))
    params = unbox(init_lm(KEY, arch))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, arch.vocab, (4,)).astype(np.int32) for _ in range(2)]
    engine = ServeEngine(arch, params, batch=2, max_seq=64)
    assert engine.recurrent
    outs = engine.generate(prompts, max_new=3)
    assert all(len(o) == 3 for o in outs)


def test_empty_prompt_synthesizes_bos():
    """Regression: admitting a zero-length prompt raised NameError (``logits``
    unbound in ``_prefill_slot``); admit now synthesizes a BOS token."""
    arch = reduced(get_arch("yi-6b"))
    params = unbox(init_lm(KEY, arch))
    engine = ServeEngine(arch, params, batch=2, max_seq=32)
    outs = engine.generate([np.zeros((0,), np.int32), np.arange(3, dtype=np.int32)], max_new=2)
    assert all(len(o) == 2 for o in outs)
    assert outs[0] == _greedy_reference(arch, params, [engine.bos_id], 2)


def test_engine_stats_split_prefill_vs_decode():
    arch = reduced(get_arch("yi-6b"))
    params = unbox(init_lm(KEY, arch))
    engine = ServeEngine(arch, params, batch=2, max_seq=32)
    engine.generate([np.arange(5, dtype=np.int32)], max_new=3)
    assert engine.stats["prefill_tokens"] == 5
    # the first generated token comes from the prefill dispatch's logits and
    # is booked under prefill (the seed engine booked it under decode,
    # skewing decode_tok_s vs the paged engine by max_new/(max_new-1))
    assert engine.stats["decode_tokens"] == 2
    assert engine.stats["decode_dispatches"] == 2
    assert engine.stats["prefill_s"] > 0 and engine.stats["decode_s"] > 0
    assert engine.throughput()["dispatches_per_token"] == 1.0
    engine.reset_stats()
    assert engine.stats["prefill_tokens"] == 0
    assert engine.stats["decode_dispatches"] == 0


def test_decode_accounting_convention_matches_paged():
    """Regression (BENCH 64 vs 56): both engines must book the identical
    workload's tokens under the same prefill/decode split, or every
    cross-engine decode_tok_s comparison is skewed by max_new/(max_new-1)."""
    from repro.serve.engine import PagedServeEngine

    arch = reduced(get_arch("yi-6b"))
    params = unbox(init_lm(KEY, arch))
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, arch.vocab, (n,)).astype(np.int32) for n in (5, 3)]
    contig = ServeEngine(arch, params, batch=2, max_seq=32)
    paged = PagedServeEngine(arch, params, batch=2, max_seq=32, block_size=4,
                             prefill_chunk=4)
    assert contig.generate(prompts, max_new=4) == paged.generate(prompts, max_new=4)
    for k in ("prefill_tokens", "decode_tokens"):
        assert contig.stats[k] == paged.stats[k], (k, contig.stats, paged.stats)
    assert contig.stats["decode_tokens"] == 2 * (4 - 1)


def test_contiguous_engine_stops_on_eos():
    """The engine-level EOS default: requests finish the step they emit the
    id instead of decoding garbage to max_new (the seed engine never checked
    an EOS anywhere)."""
    arch = reduced(get_arch("yi-6b"))
    params = unbox(init_lm(KEY, arch))
    prompt = np.arange(5, dtype=np.int32)
    ref = ServeEngine(arch, params, batch=2, max_seq=32)
    full = ref.generate([prompt], max_new=6)[0]
    eos = full[2]  # provably emitted mid-stream under greedy determinism
    engine = ServeEngine(arch, params, batch=2, max_seq=32, eos_id=eos)
    out = engine.generate([prompt], max_new=6)[0]
    stop = full.index(eos)
    assert out == full[: stop + 1]  # EOS itself is recorded, nothing after
    req = engine.last_requests[0]
    assert req.done and req.latency >= 0 and req.ttft >= 0
    # per-request override beats the engine default
    engine2 = ServeEngine(arch, params, batch=2, max_seq=32, eos_id=eos)
    from repro.serve.engine import Request

    r = Request(uid=0, prompt=prompt, max_new=6, eos_id=-1)  # never emitted
    engine2.admit(r)
    while engine2.tick():
        pass
    assert r.generated == full


def test_deploy_int8_weights_respect_budget_and_serve():
    arch = reduced(get_arch("yi-6b"))
    q = arch.quant
    params = unbox(init_lm(KEY, arch))
    deployed = deploy_params(params, q)

    budget = l1_budget(q.acc_bits, q.act_bits, True)
    found = []

    def walk(node):
        if isinstance(node, dict):
            if "q8" in node:
                found.append(node)
            for v in node.values():
                walk(v)

    walk(deployed)
    assert found, "no layers deployed"
    for node in found:
        q8 = np.asarray(node["q8"], np.int64)
        assert q8.dtype == np.int64 and np.abs(q8).max() <= 127
        l1 = np.abs(q8).sum(axis=-2)  # per output channel
        assert (l1 <= budget + 1e-6).all()

    # deployed params still serve
    engine = ServeEngine(arch, deployed, batch=2, max_seq=32)
    outs = engine.generate([np.arange(4, dtype=np.int32)], max_new=2)
    assert len(outs[0]) == 2


def test_deployed_forward_close_to_fakequant():
    """int8 deployment is the same math as training fake-quant (exact up to
    bf16/f32 dot differences — here compute is f32 so it is tight)."""
    arch = reduced(get_arch("yi-6b"))
    params = unbox(init_lm(KEY, arch))
    deployed = deploy_params(params, arch.quant)
    toks = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    l1, _, _ = apply_lm(params, arch, tokens=toks)
    l2, _, _ = apply_lm(deployed, arch, tokens=toks)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-3)
