"""Per-assigned-architecture smoke tests: a REDUCED config of the same family
runs one forward/train step on CPU; output shapes + no NaNs (assignment (f))."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_arch, reduced
from repro.models import Runtime, apply_lm, init_cache, init_lm, lm_loss
from repro.models.steps import build_train_step
from repro.nn.module import unbox
from repro.optim.optimizers import adamw

KEY = jax.random.PRNGKey(0)


def _batch(arch, B=2, S=16):
    rng = np.random.default_rng(0)
    if arch.family == "audio":
        return {
            "frontend_embeds": jnp.asarray(rng.normal(size=(B, S, arch.d_model)), jnp.float32),
            "targets": jnp.asarray(rng.integers(0, arch.n_classes, (B, S)), jnp.int32),
        }
    if arch.family == "vlm":
        si = arch.frontend.seq_len
        return {
            "tokens": jnp.asarray(rng.integers(0, arch.vocab, (B, S - si)), jnp.int32),
            "frontend_embeds": jnp.asarray(rng.normal(size=(B, si, arch.d_model)), jnp.float32),
            "targets": jnp.asarray(rng.integers(0, arch.vocab, (B, S)), jnp.int32),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, arch.vocab, (B, S)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, arch.vocab, (B, S)), jnp.int32),
    }


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_reduced_forward_shapes_and_finite(name):
    arch = reduced(get_arch(name))
    params = unbox(init_lm(KEY, arch))
    batch = _batch(arch)
    logits, _, penalty = apply_lm(
        params, arch,
        tokens=batch.get("tokens"),
        frontend_embeds=batch.get("frontend_embeds"),
    )
    vocab_or_classes = arch.n_classes if arch.family == "audio" else arch.vocab
    assert logits.shape == (2, 16, vocab_or_classes)
    assert bool(jnp.isfinite(logits).all())
    assert float(penalty) >= 0.0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_reduced_train_step(name):
    arch = reduced(get_arch(name))
    params = unbox(init_lm(KEY, arch))
    opt = adamw()
    state = {"params": params, "opt_state": opt.init(params), "step": jnp.zeros((), jnp.int32)}
    step = build_train_step(arch, opt, Runtime())
    batch = _batch(arch)
    new_state, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_state["step"]) == 1
    # params actually moved
    delta = sum(
        float(jnp.abs(a - b).sum())
        for a, b in zip(jax.tree.leaves(new_state["params"]), jax.tree.leaves(params))
    )
    assert delta > 0


@pytest.mark.parametrize(
    "name",
    [n for n in ARCH_NAMES if get_arch(n).family in ("lm", "vlm")],
)
def test_reduced_decode_step(name):
    arch = reduced(get_arch(name))
    params = unbox(init_lm(KEY, arch))
    cache = init_cache(arch, 2, max_seq=32, dtype=jnp.float32)
    logits, cache2, _ = apply_lm(
        params, arch, tokens=jnp.zeros((2, 1), jnp.int32), cache=cache,
        start_pos=jnp.zeros((), jnp.int32),
    )
    assert logits.shape == (2, 1, arch.vocab)
    assert bool(jnp.isfinite(logits).all())
    # cache was updated somewhere
    changed = any(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).sum()) > 0
        for a, b in zip(jax.tree.leaves(cache2), jax.tree.leaves(cache))
        if a.dtype != jnp.int32
    )
    assert changed


def test_encoder_has_no_decode_shapes():
    from repro.configs import applicable_shapes

    hubert = get_arch("hubert-xlarge")
    shapes = applicable_shapes(hubert)
    assert "decode_32k" not in shapes and "long_500k" not in shapes


def test_long_context_only_for_subquadratic():
    from repro.configs import applicable_shapes

    runs = {n: "long_500k" in applicable_shapes(get_arch(n)) for n in ARCH_NAMES}
    assert runs["rwkv6-7b"] and runs["hymba-1.5b"] and runs["h2o-danube-1.8b"]
    assert runs["llama4-scout-17b-a16e"]
    assert not runs["yi-6b"] and not runs["command-r-35b"] and not runs["deepseek-v3-671b"]


def test_full_configs_match_assignment():
    """Exact dims from the assignment table."""
    a = get_arch("command-r-35b")
    assert (a.n_layers, a.d_model, a.vocab) == (40, 8192, 256000)
    assert a.stacks[0].attn.heads == 64 and a.stacks[0].attn.kv_heads == 8
    assert a.stacks[0].d_ff == 22528 and not a.use_bias

    y = get_arch("yi-6b")
    assert (y.n_layers, y.d_model, y.stacks[0].d_ff, y.vocab) == (32, 4096, 11008, 64000)

    d = get_arch("deepseek-v3-671b")
    assert d.n_layers == 61 and d.d_model == 7168 and d.vocab == 129280
    moe = d.stacks[1].moe
    assert moe.n_experts == 256 and moe.top_k == 8 and moe.d_ff == 2048
    assert d.stacks[1].attn.kind == "mla" and d.mtp_depth == 1

    l4 = get_arch("llama4-scout-17b-a16e")
    assert l4.n_layers == 48 and l4.d_model == 5120 and l4.vocab == 202048
    assert l4.stacks[0].moe.n_experts == 16 and l4.stacks[0].moe.top_k == 1

    r = get_arch("rwkv6-7b")
    assert r.n_layers == 32 and r.d_model == 4096 and r.vocab == 65536

    h = get_arch("hymba-1.5b")
    assert h.d_model == 1600 and h.stacks[0].attn.heads == 25 and h.stacks[0].ssm.state_dim == 16

    hb = get_arch("hubert-xlarge")
    assert hb.n_layers == 48 and hb.d_model == 1280 and hb.n_classes == 504

    lv = get_arch("llava-next-34b")
    assert lv.n_layers == 60 and lv.d_model == 7168 and lv.stacks[0].d_ff == 20480

    sm = get_arch("smollm-135m")
    assert sm.n_layers == 30 and sm.d_model == 576 and sm.vocab == 49152

    dn = get_arch("h2o-danube-1.8b")
    assert dn.n_layers == 24 and dn.stacks[0].attn.window == 4096
