"""Disaggregated serving cluster: KV-block migration wire format, router
policies/backpressure/stickiness, failover requeue with at-most-once token
emission, prefill/decode disaggregation parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models.lm import init_lm
from repro.nn.module import unbox
from repro.serve.cluster import (
    InProcessReplica,
    ReplicaConfig,
    Router,
    SubprocessReplica,
    build_engine,
    handoff_local,
    make_cluster_configs,
    parse_disagg,
)
from repro.serve.cluster.router import _ReplicaState
from repro.serve.engine import PagedServeEngine, Request

KEY = jax.random.PRNGKey(0)
ARCH = reduced(get_arch("yi-6b"))
PARAMS = unbox(init_lm(KEY, ARCH))


def _prompts(n, rng=None, lo=4, hi=10):
    rng = rng or np.random.default_rng(0)
    return [rng.integers(0, ARCH.vocab, (int(rng.integers(lo, hi)),)).astype(np.int32)
            for _ in range(n)]


def _engine(**kw):
    base = dict(batch=2, max_seq=64, block_size=4, prefill_chunk=4)
    base.update(kw)
    return PagedServeEngine(ARCH, PARAMS, **base)


def _cfg(**kw):
    base = dict(arch="yi-6b", reduced=True, batch=2, max_seq=64, block_size=4,
                prefill_chunk=4)
    base.update(kw)
    return ReplicaConfig(**base)


def _fleet(n=2, **kw):
    cfgs = make_cluster_configs(_cfg(**kw), replicas=n)
    return [InProcessReplica(c, params=PARAMS) for c in cfgs]


# ---------------------------------------------------------------------------
# KV-block migration wire format
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kv_quant,kv_bits,code_dtype", [
    (False, 8, None), (True, 8, np.int8), (True, 4, np.uint8),
])
def test_export_blocks_wire_dtypes(kv_quant, kv_bits, code_dtype):
    """Migration ships blocks at storage width: fp pools at cache dtype,
    int8 codes as int8, packed int4 as uint8 nibble pairs, scales fp32 —
    never a dequantized fp copy."""
    eng = _engine(kv_quant=kv_quant, kv_bits=kv_bits)
    req = Request(uid=0, prompt=np.arange(1, 8, dtype=np.int32), max_new=4)
    payload = eng.prefill_handoff(req)
    kv = payload["kv"]
    assert kv["tokens"] == 7
    assert kv["n_blocks"] == -(-7 // 4) == 2
    assert kv["kv_quant"] == kv_quant and kv["kv_bits"] == kv_bits
    code_keys = [k for k in kv["leaves"] if "kp'" in k or "vp'" in k]
    scale_keys = [k for k in kv["leaves"] if "kps'" in k or "vps'" in k]
    assert code_keys, "no pool leaves exported"
    for k in code_keys:
        arr = kv["leaves"][k]
        assert isinstance(arr, np.ndarray) and arr.shape[1] == kv["n_blocks"]
        if code_dtype is not None:
            assert arr.dtype == code_dtype, (k, arr.dtype)
    if kv_quant:
        assert scale_keys, "quantized pools must ship their scale pools"
        for k in scale_keys:
            assert kv["leaves"][k].dtype == np.float32
    assert eng.cache.migrated_blocks_out > 0
    assert eng.cache.migration_bytes_out > 0


def test_import_blocks_validates_geometry():
    eng = _engine()
    req = Request(uid=0, prompt=np.arange(1, 8, dtype=np.int32), max_new=4)
    payload = eng.prefill_handoff(req)
    other = _engine(block_size=8)
    req2 = Request(uid=0, prompt=np.arange(1, 8, dtype=np.int32), max_new=4)
    with pytest.raises(ValueError, match="block_size"):
        other.submit_handoff(req2, payload)
    q8 = _engine(kv_quant=True)
    with pytest.raises(ValueError, match="kv_quant"):
        q8.submit_handoff(req2, payload)


@pytest.mark.parametrize("kv_quant,kv_bits", [(False, 8), (True, 8), (True, 4)])
def test_handoff_local_token_identical(kv_quant, kv_bits):
    """Disaggregated prefill->migrate->decode must be token-identical to the
    same engine config running the request locally (greedy): migration moves
    the exact stored codes, so there is no re-quantization error."""
    prompts = _prompts(3, np.random.default_rng(1))
    kw = dict(kv_quant=kv_quant, kv_bits=kv_bits)
    single = _engine(**kw)
    want = single.generate([p.tolist() for p in prompts], max_new=5)
    pre, dec = _engine(**kw), _engine(**kw)
    reqs = [Request(uid=i, prompt=p, max_new=5) for i, p in enumerate(prompts)]
    for r in reqs:
        handoff_local(pre, dec, r)
    while not dec.sched.idle():
        dec.step()
    assert [r.generated for r in reqs] == want
    assert dec.cache.migrated_blocks_in == pre.cache.migrated_blocks_out > 0


# ---------------------------------------------------------------------------
# routed fleet: parity, balance, stickiness, backpressure
# ---------------------------------------------------------------------------


def test_two_replica_routed_parity_and_balance():
    """A 2-replica fleet returns exactly the single-engine greedy stream for
    every request, and least-loaded routing actually uses both replicas."""
    prompts = _prompts(6, np.random.default_rng(2))
    router = Router(_fleet(2), policy="least-loaded")
    rids = [router.submit(p, max_new=4) for p in prompts]
    res = router.drain()
    single = _engine()
    want = single.generate([p.tolist() for p in prompts], max_new=4)
    assert [res[r] for r in rids] == want
    dispatched = {n: st.dispatched for n, st in router.states.items()}
    assert all(v > 0 for v in dispatched.values()), dispatched
    router.close()


def test_sticky_prefix_routing():
    """Requests sharing a first prompt block ride the same replica (radix
    prompt-cache warmth); distinct prefixes spread out."""
    rng = np.random.default_rng(3)
    shared = rng.integers(0, ARCH.vocab, (4,)).astype(np.int32)  # one block
    group = [np.concatenate([shared, rng.integers(0, ARCH.vocab, (3,)).astype(np.int32)])
             for _ in range(3)]
    router = Router(_fleet(2, prefix_share=True), policy="least-loaded", sticky=True)
    rids = [router.submit(p, max_new=3) for p in group]
    router.drain()
    homes = {router.reqs[r].rid: None for r in rids}
    # dispatch bookkeeping: every rid of the group must have been served by
    # the same replica (stickiness pinned them)
    served_by = set()
    for name, st in router.states.items():
        for r in rids:
            if r in [k for k in st.inflight]:
                served_by.add(name)
    # inflight is empty after completion; use the sticky table instead
    key = tuple(int(t) for t in shared[:4])
    assert router._sticky.get(key) in router.states
    counts = {n: st.dispatched for n, st in router.states.items()}
    assert max(counts.values()) == len(group), counts  # all three on one replica
    router.close()


def test_backpressure_never_overcommits():
    """The router's commitment ledger must never exceed a replica's pool
    capacity at any step, even with a wave far larger than the fleet."""
    handles = _fleet(2, num_blocks=12, max_seq=32)
    router = Router(handles, policy="least-loaded")
    prompts = _prompts(8, np.random.default_rng(4), lo=4, hi=8)
    for p in prompts:
        router.submit(p, max_new=4)

    peak = {h.name: 0 for h in handles}

    def watch(r, step):
        for name, st in r.states.items():
            assert st.committed <= st.capacity, (name, st.committed, st.capacity)
            peak[name] = max(peak[name], st.committed)

    res = router.drain(on_step=watch)
    assert all(len(v) == 4 for v in res.values())
    assert max(peak.values()) > 0
    router.close()


def test_oversized_request_fails_loudly():
    router = Router(_fleet(1, num_blocks=8, max_seq=64))
    router.submit(np.arange(1, 40, dtype=np.int32), max_new=8)  # > whole pool
    with pytest.raises(RuntimeError, match="never be served"):
        router.drain()
    router.close()


def test_weighted_latency_policy_prefers_faster_replica():
    """Pure policy unit test on synthetic states: with EWMA signals the
    weighted-latency score ranks the faster-draining replica first; cold
    replicas (no signal) fall back to least-loaded ordering."""

    class _H:
        def __init__(self, name):
            self.name = name
            self.cfg = type("C", (), {"role": "both"})()

    router = Router.__new__(Router)  # policy math only; no fleet
    router.policy = "weighted-latency"
    fast, slow = _ReplicaState(_H("fast")), _ReplicaState(_H("slow"))
    for st, tok_s in ((fast, 100.0), (slow, 10.0)):
        st.hello = {"num_blocks": 33, "block_size": 4}
        st.hb = {"ewma_decode_tok_s": tok_s}
        st.committed = 10
    # same committed blocks: the faster replica has the shorter drain time
    assert router._score(fast) < router._score(slow)
    # a big backlog on the fast replica can still lose to an idle slow one
    fast.committed = 30
    slow.committed = 1
    assert router._score(slow) < router._score(fast)
    # cold replicas (ewma 0) order by committed blocks
    cold_a, cold_b = _ReplicaState(_H("a")), _ReplicaState(_H("b"))
    for st, c in ((cold_a, 5), (cold_b, 2)):
        st.hello = {"num_blocks": 33, "block_size": 4}
        st.committed = c
    assert router._score(cold_b) < router._score(cold_a)


# ---------------------------------------------------------------------------
# failover: death detection, requeue, at-most-once emission
# ---------------------------------------------------------------------------


def test_kill_mid_wave_requeues_and_streams_exactly_once():
    """Killing a replica mid-decode must (a) complete every request through
    requeue, (b) emit each client token at most once — the final streams are
    exactly the single-engine greedy streams, no duplicated prefix."""
    prompts = _prompts(6, np.random.default_rng(5))
    router = Router(_fleet(2), policy="least-loaded", heartbeat_timeout=5.0)
    rids = [router.submit(p, max_new=5) for p in prompts]

    state = {"killed": False}

    def chaos(r, step):
        if state["killed"]:
            return
        # kill the busier replica once tokens start flowing
        if sum(len(q.emitted) for q in r.reqs.values()) >= 3:
            victim = max(r.states.values(), key=lambda st: len(st.inflight))
            r.kill(victim.name)
            state["killed"] = True

    res = router.drain(on_step=chaos)
    assert state["killed"] and router.deaths == 1 and router.requeues >= 1
    single = _engine()
    want = single.generate([p.tolist() for p in prompts], max_new=5)
    assert [res[r] for r in rids] == want  # exact => no dup, no gap
    router.close()


def test_heartbeat_timeout_detects_silent_replica():
    """A replica that stops producing events (but whose handle still claims
    alive) is declared dead after heartbeat_timeout on the injected clock,
    and its in-flight work is requeued in order at the queue front."""

    class _SilentHandle:
        transport = "inproc"

        def __init__(self, name):
            self.name = name
            self.cfg = type("C", (), {"role": "both"})()
            self.sent = []

        def send(self, cmd):
            self.sent.append(cmd)

        def poll(self):
            return []

        def pump(self):
            return False

        def alive(self):
            return True  # lies: only the heartbeat timeout can catch it

        def kill(self):
            pass

        def close(self):
            pass

    t = {"now": 0.0}
    h = _SilentHandle("mute")
    router = Router([h], heartbeat_timeout=2.0, clock=lambda: t["now"])
    st = router.states["mute"]
    st.hello = {"num_blocks": 33, "block_size": 4, "batch": 2}
    st.last_seen = 0.0
    r1 = router.submit(np.arange(1, 6, dtype=np.int32), max_new=3)
    r2 = router.submit(np.arange(2, 7, dtype=np.int32), max_new=3)
    router.step(now=1.0)  # dispatches both to the silent replica
    assert set(st.inflight) == {r1, r2}
    router.step(now=1.5)
    assert st.alive
    router.step(now=4.0)  # > last_seen + timeout
    assert not st.alive and router.deaths == 1 and router.requeues == 2
    assert [c.rid for c in router.queue] == [r1, r2]  # front, original order
    assert st.committed == 0 and not st.inflight


# ---------------------------------------------------------------------------
# prefill/decode disaggregation through the router
# ---------------------------------------------------------------------------


def test_parse_disagg():
    assert parse_disagg("1:2") == (1, 2)
    with pytest.raises(ValueError):
        parse_disagg("3")
    with pytest.raises(ValueError):
        parse_disagg("0:2")


def test_disagg_fleet_routed_parity():
    """1 prefill + 1 decode replica: prompts run on the prefill replica,
    blocks migrate, decode happens elsewhere — token-identical to a single
    engine, prompt never recomputed (decode replica books no prompt-length
    prefill beyond the adopted first tokens)."""
    cfgs = make_cluster_configs(_cfg(), disagg=(1, 1))
    handles = [InProcessReplica(c, params=PARAMS) for c in cfgs]
    router = Router(handles, policy="least-loaded")
    prompts = _prompts(4, np.random.default_rng(6))
    rids = [router.submit(p, max_new=4) for p in prompts]
    res = router.drain()
    single = _engine()
    want = single.generate([p.tolist() for p in prompts], max_new=4)
    assert [res[r] for r in rids] == want
    stats = router.collect_stats()
    assert stats["p0"]["migrated_blocks_out"] > 0
    assert stats["d0"]["migrated_blocks_in"] == stats["p0"]["migrated_blocks_out"]
    # the decode replica re-ran no prompt tokens
    assert stats["d0"]["throughput"]["prefill_tokens"] == 0
    router.close()


def test_disagg_decode_death_reuses_handoff():
    """When a decode replica dies holding adopted requests, the router
    re-dispatches the *retained* handoff payload: the prefill replica is
    never asked to re-run the prompt."""
    cfgs = make_cluster_configs(_cfg(), disagg=(1, 2))
    handles = [InProcessReplica(c, params=PARAMS) for c in cfgs]
    router = Router(handles, policy="least-loaded")
    prompts = _prompts(4, np.random.default_rng(7))
    rids = [router.submit(p, max_new=5) for p in prompts]

    state = {"killed": False}

    def chaos(r, step):
        if state["killed"]:
            return
        for st in r.states.values():
            if st.role == "decode" and st.alive and st.inflight:
                r.kill(st.name)
                state["killed"] = True
                return

    res = router.drain(on_step=chaos)
    assert state["killed"] and router.requeues >= 1
    single = _engine()
    want = single.generate([p.tolist() for p in prompts], max_new=5)
    assert [res[r] for r in rids] == want
    stats = router.collect_stats()
    served_prefills = stats["p0"]["served"]
    assert served_prefills == len(prompts)  # one prefill per request, ever
    router.close()


# ---------------------------------------------------------------------------
# subprocess transport
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_subprocess_transport_smoke():
    """Two real spawn-context replica processes behind the router: the same
    protocol crosses a multiprocessing.Pipe, outputs match a local engine."""
    cfgs = make_cluster_configs(_cfg(), replicas=2)
    handles = [SubprocessReplica(c) for c in cfgs]
    router = Router(handles, policy="least-loaded", heartbeat_timeout=300.0)
    try:
        prompts = _prompts(3, np.random.default_rng(8))
        rids = [router.submit(p, max_new=3) for p in prompts]
        res = router.drain()
        single = _engine()
        want = single.generate([p.tolist() for p in prompts], max_new=3)
        assert [res[r] for r in rids] == want
    finally:
        router.close()


def test_build_engine_variants():
    """ReplicaConfig reaches every engine flag: megastep, int8 KV, spec."""
    e1 = build_engine(_cfg(decode_steps=4), params=PARAMS)
    assert e1.decode_steps == 4
    e2 = build_engine(_cfg(kv_quant=True, kv_bits=4), params=PARAMS)
    assert e2.cache.kv_quant and e2.cache.kv_bits == 4
    from repro.serve.spec import SpecServeEngine

    e3 = build_engine(_cfg(spec_k=2), params=PARAMS)
    assert isinstance(e3, SpecServeEngine)
