"""Int8-out chaining: end-to-end integer activation flow through the fused
W8A8 serve path.

Layers: the requantizing epilogue (int32 acc -> per-column rescale -> act
replay -> round/clamp -> int8 codes) is bit-exact vs its jnp oracle for pow2
AND arbitrary out scales; unsigned 8-bit activations ride via signed
symmetrization (codes travel as ``q - 128``, the kernel restores
``128 * colsum(w)`` at flush — exact in int32); the prologue fold
(``aq_scale``) quantizes fp inputs in-register to the same codes the host
act-quant dispatch would produce.

Linears: a chained producer->consumer pair (producer requantizes into the
consumer's quantizer, consumer eats the IntAct codes directly) matches the
unchained two-dispatch path bitwise under the pow2-scale witness; chain
repair re-materializes fp32 when the consumer can't take codes; stacked 3D
weight leaves vmap the fused kernel when the input batch lines up and fall
back (warning + chain-report entry) when it doesn't.

Engines: chained greedy decode is token-identical to the unchained integer
fast path across GQA (yi), MLA+MoE (deepseek), and recurrent (rwkv6) archs,
composes with the fused decode megastep and with speculative drafting, and
the stats contract holds — zero standalone act-quant dispatches under
``int_chain``, nonzero without it.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.configs.base import QuantConfig
from repro.kernels import ops, ref
from repro.models.lm import Runtime, init_lm
from repro.nn.linear import (
    IntAct,
    apply_linear,
    chain_out_aq,
    chain_report_scope,
    init_linear,
)
from repro.nn.module import unbox
from repro.serve.engine import PagedServeEngine, deploy_params

KEY = jax.random.PRNGKey(0)
CFG = QuantConfig(mode="a2q", weight_bits=8, act_bits=8, acc_bits=16)


# ---------------------------------------------------------------------------
# kernel: requantizing epilogue, u8 symmetrization, prologue fold
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pow2_scales", [True, False])
@pytest.mark.parametrize("act_fn", [None, "relu2", "gelu"])
def test_requant_epilogue_bit_exact_vs_oracle(pow2_scales, act_fn):
    """acc int32 -> f32 rescale (+bias) -> act replay -> round/clamp -> int8:
    the kernel and the jnp oracle run the identical f32 op sequence, so the
    emitted codes match bitwise for ANY scale, pow2 or not."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-128, 128, (16, 64)), jnp.int8)
    w = jnp.asarray(rng.integers(-16, 16, (64, 32)), jnp.int8)
    scale = jnp.asarray(rng.uniform(0.001, 0.1, (32,)), jnp.float32)
    bias = jnp.asarray(rng.normal(size=(32,)), jnp.float32)
    if pow2_scales:
        out_scale = jnp.exp2(jnp.asarray(rng.integers(-4, 1, (32,)), jnp.float32))
    else:
        out_scale = jnp.asarray(rng.uniform(0.01, 0.5, (32,)), jnp.float32)
    out_signed = act_fn != "relu2"  # relu2 output is nonnegative -> unsigned
    got = ops.int_matmul(x, w, scale=scale, bias=bias, out_scale=out_scale,
                         act_fn=act_fn, out_signed=out_signed, block_k=32)
    want = ref.ref_int_matmul_requant(x, w, scale, out_scale, bias=bias,
                                      act_fn=act_fn, out_signed=out_signed)
    assert got.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_requant_epilogue_composes_with_int16_spill():
    """The chaining epilogue must not disturb the A2Q int16 partial-sum
    spill: small-norm weights, acc_bits=16, requant output still bit-exact."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.integers(0, 8, (8, 32)), jnp.int8)
    w = jnp.asarray(rng.integers(-2, 3, (32, 16)), jnp.int8)
    scale = jnp.asarray(rng.uniform(0.01, 0.1, (16,)), jnp.float32)
    out_scale = jnp.exp2(jnp.asarray(rng.integers(-3, 0, (16,)), jnp.float32))
    got = ops.int_matmul(x, w, scale=scale, out_scale=out_scale,
                         acc_bits=16, spill_int16=True, block_k=32)
    want = ref.ref_int_matmul_requant(x, w, scale, out_scale, acc_bits=16)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_unsigned_codes_symmetrize_exactly():
    """u8 codes in [0, 255] travel as ``q - 128`` int8; the auto-offset
    ``128 * colsum(w)`` restores the true accumulator in int32 — the fused
    result equals the direct unsigned dot exactly."""
    rng = np.random.default_rng(2)
    q_true = rng.integers(0, 256, (8, 32))  # unsigned codes, past int8
    w = jnp.asarray(rng.integers(-16, 16, (32, 16)), jnp.int8)
    scale = jnp.asarray(rng.uniform(0.001, 0.1, (16,)), jnp.float32)
    sym = jnp.asarray(q_true - 128, jnp.int8)
    got = ops.int_matmul(sym, w, scale=scale, in_signed=False, block_k=32)
    acc = q_true @ np.asarray(w, np.int64)
    want = acc.astype(np.float32) * np.asarray(scale)[None, :]
    np.testing.assert_array_equal(np.asarray(got), want.astype(np.float32))


@pytest.mark.parametrize("signed", [True, False])
def test_prologue_quant_matches_host_act_quant(signed):
    """Folding the activation quantizer into the kernel prologue
    (``aq_scale``) produces the same codes — hence bitwise the same output —
    as the host act-quant dispatch feeding int8 into the kernel."""
    from repro.core.quantizers import act_quant_int

    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(8, 32)) * 3, jnp.float32)
    w = jnp.asarray(rng.integers(-16, 16, (32, 16)), jnp.int8)
    scale = jnp.asarray(rng.uniform(0.001, 0.1, (16,)), jnp.float32)
    aq = {"log2_scale": jnp.asarray(-2.0, jnp.float32)}
    xq, x_scale = act_quant_int(aq, x, 8, signed=signed)
    if not signed:
        xq = xq - 128.0
    want = ops.int_matmul(xq.astype(jnp.int8), w, scale=scale,
                          in_signed=signed, block_k=32)
    got = ops.int_matmul(x, w, scale=scale, aq_scale=x_scale,
                         in_signed=signed, block_k=32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# linear: chained pair parity, chain repair, stacked (vmapped) leaves
# ---------------------------------------------------------------------------


def _deployed(rng, d_in, d_out, log2_scale=0.0):
    """Pow2-witness deployed layer: integral products stay exact in f32."""
    return {
        "q8": jnp.asarray(rng.integers(-16, 16, (d_in, d_out)), jnp.int8),
        "s8": jnp.exp2(jnp.asarray(rng.integers(-6, -2, (d_out,)), jnp.float32)),
        "aq": {"log2_scale": jnp.asarray(log2_scale, jnp.float32)},
    }


def test_chained_pair_token_exact_pow2_witness():
    """producer -> relu2 -> consumer: the chained path (epilogue requant ->
    IntAct -> codes straight into the consumer) equals the unchained path
    (fp out, host act-quant, second kernel) bitwise under pow2 scales."""
    rng = np.random.default_rng(4)
    prod = _deployed(rng, 32, 48)
    cons = _deployed(rng, 48, 16, log2_scale=2.0)
    x = jnp.asarray(rng.integers(-20, 20, (4, 32)), jnp.float32)
    kw = dict(cfg=CFG, compute_dtype=jnp.float32, int_forward=True)

    h = apply_linear(prod, x, **kw)
    h = jnp.square(jax.nn.relu(h))
    want = apply_linear(cons, h, input_signed=False, **kw)

    out_aq = chain_out_aq(cons, CFG, input_signed=False, act_fn="relu2")
    assert out_aq is not None
    hq = apply_linear(prod, x, out_aq=out_aq, int_chain=True, **kw)
    assert isinstance(hq, IntAct) and not hq.signed
    got = apply_linear(cons, hq, input_signed=False, int_chain=True, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_prologue_fold_token_exact_at_chain_break():
    """At a chain break the consumer quantizes in the kernel prologue: same
    output as the standalone act-quant dispatch, and the chain report logs
    it as folded, not standalone."""
    rng = np.random.default_rng(5)
    dep = _deployed(rng, 32, 48)
    x = jnp.asarray(rng.integers(-20, 20, (4, 32)), jnp.float32)
    kw = dict(cfg=CFG, compute_dtype=jnp.float32, int_forward=True)
    rep: dict = {}
    with chain_report_scope(rep):
        want = apply_linear(dep, x, site="a", **kw)
        got = apply_linear(dep, x, site="b", int_chain=True, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert rep["standalone"] == ["a"] and rep["folded"] == ["b"]


def test_chain_repair_rematerializes_fp():
    """An IntAct reaching a non-deployed consumer is re-materialized to fp
    (codes * scale, unsigned un-symmetrized) — output matches feeding the
    equivalent fp activation, and the report counts a fallback."""
    rng = np.random.default_rng(6)
    p = unbox(init_linear(KEY, 48, 16, CFG))
    codes = jnp.asarray(rng.integers(0, 256, (4, 48)) - 128, jnp.int8)
    a = IntAct(codes=codes, scale=jnp.asarray(0.25, jnp.float32), bits=8, signed=False)
    x_fp = (codes.astype(jnp.float32) + 128.0) * 0.25
    rep: dict = {}
    with chain_report_scope(rep):
        got = apply_linear(p, a, cfg=CFG, compute_dtype=jnp.float32,
                           input_signed=False, int_chain=True, site="repair")
    want = apply_linear(p, x_fp, cfg=CFG, compute_dtype=jnp.float32,
                        input_signed=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert "repair" in rep["fallback"]


def test_stacked_weight_leaves_vmap_the_fused_kernel():
    """3D q8 (E, K, N) with a matching batched input (E, M, K) batches the
    fused kernel via vmap — per-slice output equals running each expert's 2D
    layer through the int path directly."""
    rng = np.random.default_rng(7)
    E = 3
    slices = [_deployed(rng, 32, 16) for _ in range(E)]
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *slices)
    x = jnp.asarray(rng.integers(-20, 20, (E, 4, 32)), jnp.float32)
    kw = dict(cfg=CFG, compute_dtype=jnp.float32, int_forward=True)
    got = apply_linear(stacked, x, **kw)
    for e in range(E):
        want = apply_linear(slices[e], x[e], **kw)
        np.testing.assert_array_equal(np.asarray(got[e]), np.asarray(want))


def test_stacked_weight_leaves_without_batched_input_fall_back():
    """3D q8 with a 2D input can't ride the fused kernel: one structured
    warning, a chain-report fallback entry, and dequant-path output."""
    rng = np.random.default_rng(8)
    slices = [_deployed(rng, 32, 16) for _ in range(2)]
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *slices)
    # stacked experts share one activation quantizer (the MoE layout)
    stacked["aq"] = {"log2_scale": jnp.asarray(0.0, jnp.float32)}
    x = jnp.asarray(rng.integers(-20, 20, (4, 32)), jnp.float32)
    rep: dict = {}
    import repro.nn.linear as linmod

    linmod._WARNED.clear()
    with chain_report_scope(rep):
        with warnings.catch_warnings(record=True) as wlist:
            warnings.simplefilter("always")
            got = apply_linear(stacked, x, cfg=CFG, compute_dtype=jnp.float32,
                               int_forward=True, site="stacked")
    assert any("stacked weight leaves" in str(w.message) for w in wlist)
    assert rep["fallback"] == ["stacked"]
    want = apply_linear(stacked, x, cfg=CFG, compute_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# engines: chained == unchained greedy decode; stats contract
# ---------------------------------------------------------------------------

EKW = dict(batch=2, max_seq=64, block_size=8, prefill_chunk=8)


def _arch_and_deployed(name):
    arch = reduced(get_arch(name))
    return arch, deploy_params(unbox(init_lm(KEY, arch)), arch.quant)


def _prompts(arch, lens=(6, 4, 9), seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, arch.vocab, (n,)).astype(np.int32) for n in lens]


@pytest.mark.parametrize("name", ["yi-6b", "deepseek-v3-671b", "rwkv6-7b"])
def test_chained_decode_token_identical_and_stats_contract(name):
    """Chaining is a pure dispatch fusion over the integer fast path: greedy
    tokens identical to unchained int-forward decode on GQA, MLA+MoE and
    recurrent archs, with zero standalone act-quant dispatches in the
    chained report and nonzero in the unchained one."""
    arch, dep = _arch_and_deployed(name)
    prompts = _prompts(arch)
    plain = PagedServeEngine(arch, dep, rt=Runtime(int_forward=True), **EKW)
    want = plain.generate(prompts, max_new=5)
    chained = PagedServeEngine(arch, dep, rt=Runtime(int_chain=True), **EKW)
    got = chained.generate(prompts, max_new=5)
    assert got == want
    tp_plain, tp_chain = plain.throughput(), chained.throughput()
    assert tp_plain["int_chain_requant_dispatches"] > 0
    assert tp_chain["int_chain_requant_dispatches"] == 0
    assert tp_chain["int_chain_folded"] > 0
    if name == "rwkv6-7b":  # the relu2 channel-mix is a true int8 chain
        assert tp_chain["int_chain_chained"] > 0


def test_chained_decode_composes_with_megastep():
    """int_chain under the N-tick fused decode megastep: the lax.scan body
    carries IntActs only inside a block (chain edges never cross ticks), and
    tokens stay identical to per-tick chained decode."""
    arch, dep = _arch_and_deployed("yi-6b")
    prompts = _prompts(arch, lens=(5, 3, 8), seed=1)
    tick = PagedServeEngine(arch, dep, rt=Runtime(int_chain=True), **EKW)
    want = tick.generate(prompts, max_new=6)
    mega = PagedServeEngine(arch, dep, rt=Runtime(int_chain=True),
                            decode_steps=8, **EKW)
    got = mega.generate(prompts, max_new=6)
    assert got == want
    assert mega.throughput()["int_chain_requant_dispatches"] == 0


def test_chained_draft_composes_with_spec():
    """Precision-staged drafting with a chained drafter: the draft scan runs
    the chained W8A8 path, verify keeps the dequant dot — output must stay
    token-identical to plain decode of the same deployed artifact."""
    from repro.serve.spec import SpecServeEngine

    arch, dep = _arch_and_deployed("yi-6b")
    prompts = _prompts(arch, lens=(6, 4), seed=2)
    plain = PagedServeEngine(arch, dep, **EKW)
    want = plain.generate(prompts, max_new=5)
    spec = SpecServeEngine(arch, dep, spec_k=3,
                           draft_rt=Runtime(int_chain=True), **EKW)
    assert spec.generate(prompts, max_new=5) == want
    assert spec.acceptance_rate() > 0
