"""Bit-exact integer simulator semantics (paper Fig. 2 / 8 machinery)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic parametrized sweep when hypothesis is absent
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.core.integer import (
    accumulate_dot,
    mac_order_audit,
    overflow_stats,
    saturate_to_bits,
    wrap_to_bits,
)


def test_wrap_two_complement():
    assert wrap_to_bits(np.int64(127), 8) == 127
    assert wrap_to_bits(np.int64(128), 8) == -128
    assert wrap_to_bits(np.int64(-129), 8) == 127
    assert wrap_to_bits(np.int64(256), 8) == 0


@given(
    vals=st.lists(st.integers(-(2**20), 2**20), min_size=1, max_size=64),
    bits=st.integers(4, 24),
)
@settings(max_examples=100, deadline=None)
def test_wrap_is_associative(vals, bits):
    """Wrapping at every step == wrapping the exact sum once (modular)."""
    acc = np.int64(0)
    for v in vals:
        acc = wrap_to_bits(acc + np.int64(v), bits)
    assert acc == wrap_to_bits(np.int64(sum(vals)), bits)


def test_saturate_is_order_dependent():
    # +100 then -100 saturates differently from -100 then +100 at 8 bits
    x = np.array([[1, 1]])
    w = np.array([[100], [-100]])
    a = accumulate_dot(x, w, 8, "saturate", order=np.array([0, 1]))
    b = accumulate_dot(x, w, 8, "saturate", order=np.array([1, 0]))
    assert a == 0 and b == 0  # both in range individually...
    w2 = np.array([[100], [100], [-100]])
    x2 = np.array([[1, 1, 1]])
    a = accumulate_dot(x2, w2, 8, "saturate", order=np.array([0, 1, 2]))
    # 100+100 -> 127 (sat), -100 -> 27 ; true sum is 100
    assert int(a[0, 0]) == 27


def test_mac_order_audit_flags_nonassociativity():
    rng = np.random.default_rng(0)
    x = rng.integers(0, 2, (8, 784))
    w = rng.integers(-128, 128, (784, 4))
    audit = mac_order_audit(x, w, acc_bits=10, n_orders=6)
    assert not audit["order_invariant"] or audit["matches_exact"]
    wide = mac_order_audit(x, w, acc_bits=32, n_orders=4)
    assert wide["order_invariant"] and wide["matches_exact"]


def test_overflow_rate_grows_as_P_shrinks():
    """Fig. 2: overflows per dot product grow ~exponentially below the bound."""
    rng = np.random.default_rng(0)
    x = rng.integers(0, 2, (64, 784))  # 1-bit unsigned inputs
    w = rng.integers(-128, 128, (784, 10))  # 8-bit weights
    rates = [overflow_stats(x, w, P)["overflows_per_dot"] for P in (19, 16, 14, 12, 10)]
    assert rates[0] == 0.0  # at the data-type bound: provably none
    assert all(b >= a for a, b in zip(rates, rates[1:])), rates
    assert rates[-1] > 1.0  # far below the bound: multiple per dot product


def test_exact_matches_numpy_matmul():
    rng = np.random.default_rng(1)
    x = rng.integers(-8, 8, (5, 33))
    w = rng.integers(-8, 8, (33, 7))
    np.testing.assert_array_equal(accumulate_dot(x, w, 64, "exact"), x @ w)


def test_rejects_non_permutation_order():
    with pytest.raises(ValueError):
        accumulate_dot(np.ones((1, 3)), np.ones((3, 1)), 8, "saturate", order=np.array([0, 0, 1]))
