"""Sharding rules (divisibility fallback) + real multi-device execution in an
8-fake-device subprocess (tests must not set XLA_FLAGS in-process)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.dist.sharding import ShardingRules, resolve_pspec

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)

    @property
    def size(self):
        out = 1
        for v in self.shape.values():
            out *= v
        return out


def test_resolve_divisibility_fallback():
    mesh = _FakeMesh({"data": 16, "model": 16})
    arch = get_arch("smollm-135m")
    rules = ShardingRules.default(mesh, arch)
    # 9 heads don't divide 16 -> replicated; embed 576 FSDPs over data=16
    spec = resolve_pspec(("embed", "heads"), (576, 576), mesh, rules)
    assert spec == P("data", None)
    # d_ff=1536 shards over model
    spec = resolve_pspec(("embed", "mlp"), (576, 1536), mesh, rules)
    assert spec == P("data", "model")


def test_resolve_unit_counts_respected():
    mesh = _FakeMesh({"data": 16, "model": 16})
    arch = get_arch("command-r-35b")
    rules = ShardingRules.default(mesh, arch)
    # fused (d, H*Dh) = (8192, 8192): heads=64 divisible by 16 -> sharded
    assert resolve_pspec(("embed", "heads"), (8192, 8192), mesh, rules) == P("data", "model")
    # kv fused dim: kv_heads=8 not divisible by 16 -> replicated on dim 1
    assert resolve_pspec(("embed", "kv_heads"), (8192, 1024), mesh, rules) == P("data", None)


def test_no_mesh_axis_reused_across_dims():
    mesh = _FakeMesh({"data": 4, "model": 4})
    rules = ShardingRules(
        rules={"a": ("model",), "b": ("model",)}, unit_counts={}
    )
    spec = resolve_pspec(("a", "b"), (16, 16), mesh, rules)
    assert spec == P("model", None)  # second dim can't reuse 'model'


def test_batch_axes_multi_pod():
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    rules = ShardingRules.default(mesh, None)
    assert rules.rules["batch"] == ("pod", "data")
    spec = resolve_pspec(("batch", None, None), (256, 4096, 1), mesh, rules)
    assert spec == P(("pod", "data"), None, None)


def _run_subprocess(body: str, n_dev: int = 8) -> str:
    code = textwrap.dedent(body)
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=600
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """The same reduced model + batch gives the same loss on a (2, 4) mesh as
    on one device — the distribution layer must not change the math."""
    out = _run_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_arch, reduced
        from repro.dist.sharding import ShardingRules, param_specs
        from repro.models import Runtime, init_lm
        from repro.models.steps import build_train_step
        from repro.nn.module import unbox
        from repro.optim.optimizers import adamw

        arch = reduced(get_arch("yi-6b"))
        key = jax.random.PRNGKey(0)
        boxed = init_lm(key, arch)
        params = unbox(boxed)
        opt = adamw()
        batch = {
            "tokens": jnp.asarray(np.random.default_rng(0).integers(0, arch.vocab, (8, 32)), jnp.int32),
            "targets": jnp.asarray(np.random.default_rng(1).integers(0, arch.vocab, (8, 32)), jnp.int32),
        }
        state = {"params": params, "opt_state": opt.init(params), "step": jnp.zeros((), jnp.int32)}

        # single device
        step1 = jax.jit(build_train_step(arch, opt, Runtime()))
        _, m1 = step1(jax.tree.map(lambda x: x, state), batch)

        # (data=2, model=4) mesh
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = ShardingRules.default(mesh, arch)
        rt = Runtime(mesh=mesh, rules=rules)
        stepm = jax.jit(build_train_step(arch, opt, rt))
        with mesh:
            _, m2 = stepm(state, batch)
        l1, l2 = float(m1["loss"]), float(m2["loss"])
        assert abs(l1 - l2) < 1e-3, (l1, l2)
        print("OK", l1, l2)
        """
    )
    assert "OK" in out


@pytest.mark.slow
def test_moe_ep_shard_map_matches_local():
    """MoE with experts sharded over 'model' == single-device dispatch."""
    out = _run_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import MoEConfig, QuantConfig
        from repro.nn import moe
        from repro.nn.module import unbox

        cfg = MoEConfig(n_experts=8, top_k=2, d_ff=16, capacity_factor=8.0)
        q = QuantConfig(mode="none")
        key = jax.random.PRNGKey(0)
        p = unbox(moe.init_moe(key, 8, cfg, q))
        x = jax.random.normal(key, (4, 8, 8), jnp.float32)
        local = moe.apply_moe(p, x, cfg, q, compute_dtype=jnp.float32)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with mesh:
            ep = jax.jit(lambda p, x: moe.apply_moe(p, x, cfg, q, ep_axis="model",
                                                    mesh=mesh, compute_dtype=jnp.float32))(p, x)
        err = float(jnp.abs(local - ep).max())
        assert err < 1e-4, err
        print("OK", err)
        """
    )
    assert "OK" in out


@pytest.mark.slow
def test_moe_ep_over_both_axes_matches_local():
    """Serving layout: experts sharded over (model, data), 1 expert/shard."""
    out = _run_subprocess(
        """
        import jax, jax.numpy as jnp
        from repro.configs.base import MoEConfig, QuantConfig
        from repro.nn import moe
        from repro.nn.module import unbox

        cfg = MoEConfig(n_experts=8, top_k=2, d_ff=16, capacity_factor=8.0)
        q = QuantConfig(mode="none")
        key = jax.random.PRNGKey(0)
        p = unbox(moe.init_moe(key, 8, cfg, q))
        x = jax.random.normal(key, (4, 8, 8), jnp.float32)
        local = moe.apply_moe(p, x, cfg, q, compute_dtype=jnp.float32)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with mesh:
            ep = jax.jit(lambda p, x: moe.apply_moe(p, x, cfg, q, ep_axis=("model", "data"),
                                                    mesh=mesh, compute_dtype=jnp.float32))(p, x)
        err = float(jnp.abs(local - ep).max())
        assert err < 1e-4, err
        print("OK", err)
        """
    )
    assert "OK" in out


@pytest.mark.slow
def test_compressed_psum_error_feedback():
    out = _run_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.dist.collectives import compressed_psum

        mesh = jax.make_mesh((8,), ("data",))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 64), jnp.float32)

        def f(xs, err):
            return compressed_psum(xs, "data", err, bits=8)

        g = jax.jit(jax.shard_map(f, mesh=mesh,
                                  in_specs=(P("data"), P("data")),
                                  out_specs=(P("data"), P("data")), check_vma=False))
        errs = jnp.zeros_like(x)
        total, errs = g(x, errs)
        exact = jnp.sum(x, axis=0, keepdims=True)
        rel = float(jnp.abs(total[0] - exact[0]).max() / jnp.abs(exact).max())
        assert rel < 0.05, rel
        # error feedback: residual equals what compression dropped
        assert float(jnp.abs(errs).max()) > 0
        print("OK", rel)
        """
    )
    assert "OK" in out


@pytest.mark.slow
def test_compressed_grad_training_tracks_uncompressed():
    """20 training steps on an 8-device data mesh: int8-compressed gradient
    reduction (error feedback on) stays within tolerance of the fp32 path,
    both residual trees are live, and the residual pair survives a
    checkpoint save/restore cycle (plus allow_missing restore from an
    uncompressed checkpoint)."""
    out = _run_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from repro.configs import get_arch, reduced
        from repro.data.synthetic import TokenStream
        from repro.dist.collectives import GradCompressConfig
        from repro.dist.sharding import ShardingRules, param_specs
        from repro.models import Runtime, init_lm
        from repro.models.steps import build_train_step
        from repro.nn.module import unbox
        from repro.optim.optimizers import adamw
        from repro.train import checkpoint as ckpt
        from repro.train.state import init_grad_err

        arch = reduced(get_arch("smollm-135m"))
        mesh = jax.make_mesh((8,), ("data",))
        rules = ShardingRules.default(mesh, arch)
        params = unbox(init_lm(jax.random.PRNGKey(0), arch))
        boxed = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), arch))
        pspecs = param_specs(boxed, mesh, rules)
        opt = adamw()
        stream = TokenStream(vocab=arch.vocab, seq_len=32, global_batch=8)

        def run(rt, extra):
            state = {"params": params, "opt_state": opt.init(params),
                     "step": jnp.zeros((), jnp.int32), **extra}
            step = jax.jit(build_train_step(arch, opt, rt,
                                            lr_schedule=lambda s: jnp.float32(2e-3)))
            losses = []
            for i in range(20):
                batch = {k: jnp.asarray(v) for k, v in stream.batch(i).items()}
                state, m = step(state, batch)
                losses.append(float(m["loss"]))
            return losses, state

        base, _ = run(Runtime(mesh=mesh, rules=rules), {})
        gc = GradCompressConfig(bits=8, axis="data")
        err0 = init_grad_err(params, 8, pspecs=pspecs, axis="data")
        comp, st = run(Runtime(mesh=mesh, rules=rules, grad_compress=gc),
                       {"grad_err": err0})
        # both learn, trajectories track (error feedback keeps the int8
        # path from drifting)
        assert base[-1] < base[0] - 0.5 and comp[-1] < comp[0] - 0.5
        diff = max(abs(a - b) for a, b in zip(base, comp))
        assert diff < 0.05, (diff, base[-1], comp[-1])
        local_nz = sum(float(jnp.abs(e).sum()) for e in jax.tree.leaves(st["grad_err"]["local"]))
        server_nz = sum(float(jnp.abs(e).sum()) for e in jax.tree.leaves(st["grad_err"]["server"]))
        assert local_nz > 0 and server_nz > 0

        # the residual pair round-trips through a checkpoint
        d = tempfile.mkdtemp()
        ckpt.save(d, st, 20)
        like = {"params": params, "opt_state": opt.init(params),
                "step": jnp.zeros((), jnp.int32),
                "grad_err": init_grad_err(params, 8, pspecs=pspecs, axis="data")}
        restored, step_no = ckpt.restore(d, like)
        assert step_no == 20
        for a, b in zip(jax.tree.leaves(restored["grad_err"]),
                        jax.tree.leaves(st["grad_err"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        # enabling compression mid-run: an uncompressed checkpoint restores
        # with allow_missing and the residuals restart from zeros
        d2 = tempfile.mkdtemp()
        no_gc = {k: v for k, v in st.items() if k != "grad_err"}
        ckpt.save(d2, no_gc, 5)
        restored2, _ = ckpt.restore(d2, like, allow_missing=True)
        assert sum(float(jnp.abs(e).sum()) for e in jax.tree.leaves(restored2["grad_err"])) == 0.0
        try:
            ckpt.restore(d2, like)
            raise SystemExit("expected KeyError")
        except KeyError:
            pass
        print("OK", diff)
        """
    )
    assert "OK" in out


@pytest.mark.slow
def test_compressed_grad_training_on_tp_mesh():
    """Same contract on a (data=2, model=4) mesh: the compressed reduction
    must coexist with tensor parallelism (per-column scales here)."""
    out = _run_subprocess(
        """
        import jax, jax.numpy as jnp
        from repro.configs import get_arch, reduced
        from repro.data.synthetic import TokenStream
        from repro.dist.collectives import GradCompressConfig
        from repro.dist.sharding import ShardingRules, param_specs
        from repro.models import Runtime, init_lm
        from repro.models.steps import build_train_step
        from repro.nn.module import unbox
        from repro.optim.optimizers import adamw
        from repro.train.state import init_grad_err

        arch = reduced(get_arch("smollm-135m"))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = ShardingRules.default(mesh, arch)
        params = unbox(init_lm(jax.random.PRNGKey(0), arch))
        boxed = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), arch))
        pspecs = param_specs(boxed, mesh, rules)
        opt = adamw()
        stream = TokenStream(vocab=arch.vocab, seq_len=32, global_batch=8)

        def run(rt, extra):
            state = {"params": params, "opt_state": opt.init(params),
                     "step": jnp.zeros((), jnp.int32), **extra}
            step = jax.jit(build_train_step(arch, opt, rt,
                                            lr_schedule=lambda s: jnp.float32(2e-3)))
            losses = []
            for i in range(12):
                batch = {k: jnp.asarray(v) for k, v in stream.batch(i).items()}
                state, m = step(state, batch)
                losses.append(float(m["loss"]))
            return losses

        base = run(Runtime(mesh=mesh, rules=rules), {})
        gc = GradCompressConfig(bits=8, scale_axis="column", axis="data")
        comp = run(Runtime(mesh=mesh, rules=rules, grad_compress=gc),
                   {"grad_err": init_grad_err(params, 2, pspecs=pspecs, axis="data")})
        diff = max(abs(a - b) for a, b in zip(base, comp))
        assert diff < 0.05, diff
        print("OK", diff)
        """
    )
    assert "OK" in out


@pytest.mark.slow
def test_decode_with_kv_sharded_cache_matches_unsharded():
    """Decode with the KV-cache head dim sharded over `model` (kv_heads=4 on
    a 4-way model axis) compiles and matches the single-device decode
    numerics — the cache_specs change must not alter the math."""
    out = _run_subprocess(
        """
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_arch, reduced
        from repro.dist.sharding import ShardingRules, cache_specs, param_specs
        from repro.models import Runtime, init_cache, init_lm
        from repro.models.steps import build_serve_step
        from repro.nn.module import unbox

        arch = reduced(get_arch("yi-6b"))
        s0 = arch.stacks[0]
        arch = dataclasses.replace(
            arch,
            stacks=(dataclasses.replace(s0, attn=dataclasses.replace(s0.attn, kv_heads=4)),)
            + arch.stacks[1:],
        )
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = ShardingRules.default(mesh, arch)
        params = unbox(init_lm(jax.random.PRNGKey(0), arch))
        cache = init_cache(arch, 8, 32)
        cspecs = cache_specs(cache, mesh, rules)
        assert cspecs["0"]["attn"]["k"][3] == "model", cspecs["0"]["attn"]["k"]

        tokens = jnp.asarray(np.random.default_rng(0).integers(0, arch.vocab, (8, 1)), jnp.int32)
        pos = jnp.zeros((), jnp.int32)

        # single device reference
        logits_ref, _ = build_serve_step(arch, Runtime())(params, tokens, cache, pos)

        pspecs = param_specs(jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), arch)), mesh, rules)
        sh = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                    is_leaf=lambda x: isinstance(x, P))
        rt = Runtime(mesh=mesh, rules=rules)
        with mesh:
            step = jax.jit(
                build_serve_step(arch, rt),
                in_shardings=(sh(pspecs), NamedSharding(mesh, P("data")),
                              sh(cspecs), NamedSharding(mesh, P())),
                out_shardings=(None, sh(cspecs)),
            )
            logits, new_cache = step(params, tokens, cache, pos)
        err = float(jnp.abs(logits.astype(jnp.float32) - logits_ref.astype(jnp.float32)).max())
        assert err < 1e-2, err
        # the cache was actually written at pos 0
        assert int(new_cache["0"]["attn"]["kpos"][0, 0, 0]) == 0
        print("OK", err)
        """
    )
    assert "OK" in out


@pytest.mark.slow
def test_elastic_reshard_restore():
    """Checkpoint saved unsharded restores onto a live mesh with resharding."""
    out = _run_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np, tempfile, os
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train import checkpoint as ckpt

        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        d = tempfile.mkdtemp()
        ckpt.save(d, tree, 7)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        sh = {"w": NamedSharding(mesh, P("data", "model"))}
        restored, step = ckpt.restore(d, tree, shardings=sh)
        assert step == 7
        assert restored["w"].sharding == sh["w"]
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
        print("OK")
        """
    )
    assert "OK" in out
