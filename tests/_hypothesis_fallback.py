"""Deterministic stand-in for the slice of the hypothesis API the property
tests use, so they run (as a seeded example sweep) when hypothesis is not
installed.

Test modules import it as::

    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings
        from _hypothesis_fallback import strategies as st

With real hypothesis installed nothing here runs.  The fallback draws
``max_examples`` (capped at ``_EXAMPLE_CAP`` to keep tier-1 fast) examples
from a ``numpy`` Generator seeded by the test name — fully deterministic
across runs, no shrinking, no database.
"""

from __future__ import annotations

import functools
import types
import zlib

import numpy as np

__all__ = ["given", "settings", "strategies"]

_EXAMPLE_CAP = 40


class _Strategy:
    """A draw function ``rng -> value``."""

    def __init__(self, fn):
        self._fn = fn

    def draw(self, rng):
        return self._fn(rng)


def _integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def _booleans():
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def _floats(min_value, max_value):
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def _lists(elements, min_size=0, max_size=10):
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.draw(rng) for _ in range(n)]

    return _Strategy(draw)


def _composite(f):
    def builder(*args, **kwargs):
        def draw_fn(rng):
            return f(lambda s: s.draw(rng), *args, **kwargs)

        return _Strategy(draw_fn)

    return builder


strategies = types.SimpleNamespace(
    integers=_integers,
    booleans=_booleans,
    floats=_floats,
    lists=_lists,
    composite=_composite,
)


def settings(max_examples=20, deadline=None, **_ignored):
    """Record ``max_examples`` on the test; composes with ``given`` in either
    decorator order (hypothesis allows both)."""

    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(*arg_strategies, **kw_strategies):
    """Run the test over a deterministic sweep of drawn examples."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(
                wrapper, "_fallback_max_examples",
                getattr(fn, "_fallback_max_examples", 20),
            )
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            for _ in range(min(n, _EXAMPLE_CAP)):
                drawn = [s.draw(rng) for s in arg_strategies]
                drawn_kw = {k: s.draw(rng) for k, s in kw_strategies.items()}
                fn(*args, *drawn, **kwargs, **drawn_kw)

        # pytest must not see the original signature (it would resolve the
        # drawn parameters as fixtures)
        del wrapper.__wrapped__
        return wrapper

    return deco
