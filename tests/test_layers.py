"""Layer-level behaviour: attention decode/parallel consistency, SSM chunked
vs sequential, MoE dispatch vs dense reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AttnConfig, MoEConfig, QuantConfig, SSMConfig
from repro.nn import attention as attn
from repro.nn import moe, ssm
from repro.nn.module import unbox

KEY = jax.random.PRNGKey(0)
QF = QuantConfig(mode="none")
QA = QuantConfig(mode="a2q", weight_bits=8, act_bits=8, acc_bits=20)


def _decode_replay(p, a, q, x, steps, max_seq=64, **kw):
    cache = attn.init_attn_cache(x.shape[0], a, max_seq=max_seq, dtype=jnp.float32)
    outs = []
    for t in range(steps):
        o, cache = attn.apply_attention(
            p, x[:, t : t + 1], a, q, jnp.full((x.shape[0], 1), t, jnp.int32), cache,
            compute_dtype=jnp.float32, **kw,
        )
        outs.append(o)
    return jnp.concatenate(outs, axis=1)


@pytest.mark.parametrize("qcfg", [QF, QA])
@pytest.mark.parametrize("window,chunk", [(None, None), (8, None), (None, 8)])
def test_gqa_decode_matches_parallel(qcfg, window, chunk):
    a = AttnConfig(heads=4, kv_heads=2, head_dim=16, window=window, chunk=chunk)
    p = unbox(attn.init_attention(KEY, 64, a, qcfg))
    x = jax.random.normal(KEY, (2, 20, 64), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(20)[None], (2, 20))
    full, _ = attn.apply_attention(p, x, a, qcfg, pos, q_chunk=8, compute_dtype=jnp.float32)
    dec = _decode_replay(p, a, qcfg, x, 20)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=1e-4)


def test_encoder_attention_is_bidirectional():
    a = AttnConfig(heads=2, kv_heads=2, head_dim=8, causal=False, rope_theta=None)
    p = unbox(attn.init_attention(KEY, 16, a, QF))
    x = jax.random.normal(KEY, (1, 10, 16), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(10)[None], (1, 10))
    out, _ = attn.apply_attention(p, x, a, QF, pos, compute_dtype=jnp.float32)
    # position 0 must see position 9: perturb the last token, check pos 0 moves
    x2 = x.at[:, -1].add(1.0)
    out2, _ = attn.apply_attention(p, x2, a, QF, pos, compute_dtype=jnp.float32)
    assert float(jnp.abs(out2[:, 0] - out[:, 0]).max()) > 1e-6


@pytest.mark.parametrize("absorb", [False, True])
def test_mla_decode_matches_parallel(absorb):
    a = AttnConfig(kind="mla", heads=4, head_dim=16, q_lora_rank=24, kv_lora_rank=16,
                   qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
    p = unbox(attn.init_attention(KEY, 32, a, QA))
    x = jax.random.normal(KEY, (2, 12, 32), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(12)[None], (2, 12))
    full, _ = attn.apply_attention(p, x, a, QA, pos, q_chunk=8, compute_dtype=jnp.float32)
    dec = _decode_replay(p, a, QA, x, 12, max_seq=16, mla_absorb=absorb)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=1e-3)


def test_ring_cache_evicts_beyond_window():
    """A 500k-context decode with window W holds exactly W slots."""
    a = AttnConfig(heads=2, kv_heads=2, head_dim=8, window=4)
    cache = attn.init_attn_cache(1, a, max_seq=1 << 19)
    assert cache["k"].shape[1] == 4  # ring, not 524288
    p = unbox(attn.init_attention(KEY, 16, a, QF))
    x = jax.random.normal(KEY, (1, 10, 16), jnp.float32)
    dec = _decode_replay(p, a, QF, x, 10)
    pos = jnp.broadcast_to(jnp.arange(10)[None], (1, 10))
    full, _ = attn.apply_attention(p, x, a, QF, pos, compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=1e-4)


# ---------------------------------------------------------------------------
# SSM mixers
# ---------------------------------------------------------------------------


def test_rwkv6_chunked_equals_sequential():
    rng = np.random.default_rng(0)
    B, H, T, Dk = 2, 3, 64, 8
    args = (
        jnp.asarray(rng.normal(size=(B, H, T, Dk)), jnp.float32),
        jnp.asarray(rng.normal(size=(B, H, T, Dk)), jnp.float32),
        jnp.asarray(rng.normal(size=(B, H, T, Dk)), jnp.float32),
        jnp.asarray(rng.uniform(0.2, 0.999, size=(B, H, T, Dk)), jnp.float32),
        jnp.asarray(rng.normal(size=(H, Dk)), jnp.float32),
        jnp.zeros((B, H, Dk, Dk), jnp.float32),
    )
    y1, s1 = ssm.rwkv6_sequential(*args)
    y2, s2 = ssm.rwkv6_chunked(*args, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-5)


def test_ssd_chunked_equals_sequential():
    rng = np.random.default_rng(1)
    B, H, T, Dh, N = 2, 2, 48, 8, 4
    args = (
        jnp.asarray(rng.normal(size=(B, H, T, Dh)), jnp.float32),
        jnp.asarray(rng.uniform(0.3, 0.999, size=(B, H, T)), jnp.float32),
        jnp.asarray(rng.normal(size=(B, H, T, N)), jnp.float32),
        jnp.asarray(rng.normal(size=(B, H, T, N)), jnp.float32),
        jnp.zeros((B, H, Dh, N), jnp.float32),
    )
    y1, s1 = ssm.ssd_sequential(*args)
    y2, s2 = ssm.ssd_chunked(*args, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-5)


@pytest.mark.parametrize("mixer", ["timemix", "mamba"])
def test_mixer_decode_matches_parallel(mixer):
    x = jax.random.normal(KEY, (2, 16, 32), jnp.float32)
    if mixer == "timemix":
        sc = SSMConfig(kind="rwkv6", head_dim=8, chunk=8, lora_rank=8)
        p = unbox(ssm.init_rwkv6_timemix(KEY, 32, sc, QA))
        full, _ = ssm.apply_rwkv6_timemix(p, x, sc, QA, compute_dtype=jnp.float32)
        st = {"S": jnp.zeros((2, 4, 8, 8), jnp.float32), "shift": jnp.zeros((2, 1, 32), jnp.float32)}
        step = lambda xt, st: ssm.apply_rwkv6_timemix(p, xt, sc, QA, st, compute_dtype=jnp.float32)
    else:
        sc = SSMConfig(kind="mamba", head_dim=8, state_dim=4, chunk=8)
        p = unbox(ssm.init_mamba_heads(KEY, 32, sc, QA))
        full, _ = ssm.apply_mamba_heads(p, x, sc, QA, compute_dtype=jnp.float32)
        st = {"S": jnp.zeros((2, 4, 8, 4), jnp.float32)}
        step = lambda xt, st: ssm.apply_mamba_heads(p, xt, sc, QA, st, compute_dtype=jnp.float32)
    outs = []
    for t in range(16):
        o, st = step(x[:, t : t + 1], st)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=1e-4)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def _dense_moe_reference(params, x, cfg):
    """Loop-over-experts oracle with unlimited capacity, top-k routing."""
    B, T, d = x.shape
    x2 = np.asarray(x.reshape(B * T, d), np.float64)
    probs = np.asarray(jax.nn.softmax(x.reshape(B * T, d) @ params["router"], -1), np.float64)
    k = cfg.top_k
    top = np.argsort(-probs, axis=-1)[:, :k]
    out = np.zeros_like(x2)
    for tok in range(x2.shape[0]):
        ps = probs[tok, top[tok]]
        ps = ps / ps.sum()
        for e, pw in zip(top[tok], ps):
            w_in = np.asarray(params["w_in"]["w"][e], np.float64)
            w_gate = np.asarray(params["w_gate"]["w"][e], np.float64)
            w_out = np.asarray(params["w_out"]["w"][e], np.float64)
            h = x2[tok] @ w_in
            g = x2[tok] @ w_gate
            silu = g / (1 + np.exp(-g))
            out[tok] += pw * ((silu * h) @ w_out)
    return out.reshape(B, T, d)


def test_moe_matches_dense_reference_with_ample_capacity():
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff=16, capacity_factor=8.0)
    p = unbox(moe.init_moe(KEY, 8, cfg, QF))
    x = jax.random.normal(KEY, (2, 6, 8), jnp.float32)
    got = moe.apply_moe(p, x, cfg, QF, compute_dtype=jnp.float32)
    want = _dense_moe_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-3)


def test_moe_capacity_drops_tokens_not_crashes():
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff=16, capacity_factor=0.25)
    p = unbox(moe.init_moe(KEY, 8, cfg, QF))
    x = jax.random.normal(KEY, (2, 16, 8), jnp.float32)
    got = moe.apply_moe(p, x, cfg, QF, compute_dtype=jnp.float32)
    assert not bool(jnp.isnan(got).any())


def test_moe_shared_expert_contributes():
    cfg = MoEConfig(n_experts=4, top_k=1, d_ff=16, n_shared=1, shared_d_ff=16)
    p = unbox(moe.init_moe(KEY, 8, cfg, QF))
    x = jax.random.normal(KEY, (1, 4, 8), jnp.float32)
    full = moe.apply_moe(p, x, cfg, QF, compute_dtype=jnp.float32)
    p2 = dict(p, shared_out={"w": jnp.zeros_like(p["shared_out"]["w"])})
    no_shared = moe.apply_moe(p2, x, cfg, QF, compute_dtype=jnp.float32)
    assert float(jnp.abs(full - no_shared).max()) > 1e-6
