"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# int_matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("M,K,N", [(8, 16, 8), (65, 200, 77), (128, 512, 128), (33, 129, 257)])
@pytest.mark.parametrize("mode", ["exact", "wrap", "saturate"])
def test_int_matmul_matches_ref(M, K, N, mode):
    x = jnp.asarray(RNG.integers(-128, 128, (M, K)), jnp.int8)
    w = jnp.asarray(RNG.integers(-128, 128, (K, N)), jnp.int8)
    got = ops.int_matmul(x, w, acc_bits=16, mode=mode, block_k=128)
    want = ref.ref_int_matmul(x, w, acc_bits=16, mode=mode, block_k=128)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("acc_bits", [12, 16, 20, 32])
def test_int_matmul_acc_bits(acc_bits):
    x = jnp.asarray(RNG.integers(-16, 16, (32, 96)), jnp.int8)
    w = jnp.asarray(RNG.integers(-16, 16, (96, 48)), jnp.int8)
    for mode in ("wrap", "saturate"):
        got = ops.int_matmul(x, w, acc_bits=acc_bits, mode=mode, block_k=32)
        want = ref.ref_int_matmul(x, w, acc_bits=acc_bits, mode=mode, block_k=32)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_int_matmul_int16_spill_lossless_under_a2q_bound():
    """The A2Q-enabled kernel optimization: P<=16 guarantees the int16 carry
    is exact."""
    # weights with per-column l1 * input max <= 2^15-1  (the Eq. 15 budget)
    w = jnp.asarray(RNG.integers(-2, 3, (256, 64)), jnp.int8)
    x = jnp.asarray(RNG.integers(0, 8, (64, 256)), jnp.int8)
    got = ops.int_matmul(x, w, acc_bits=16, mode="exact", spill_int16=True, block_k=64)
    want = ref.ref_int_matmul(x, w, acc_bits=32, mode="exact")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_int16_spill_rejected_for_wide_acc():
    x = jnp.zeros((8, 8), jnp.int8)
    w = jnp.zeros((8, 8), jnp.int8)
    with pytest.raises(ValueError):
        ops.int_matmul(x, w, acc_bits=24, spill_int16=True)


# -- fused epilogue (the W8A8 serve path) -----------------------------------


@pytest.mark.parametrize("M,K,N", [(5, 33, 7), (65, 200, 77), (8, 16, 8), (1, 129, 257)])
def test_int_matmul_fused_epilogue_matches_ref(M, K, N):
    """Non-block-multiple shapes through the fused epilogue: padded columns
    are sliced off before the caller ever sees them, and the scale-only form
    is bit-exact against the oracle (with bias: 1-ulp, FMA contraction)."""
    x = jnp.asarray(RNG.integers(-64, 64, (M, K)), jnp.int8)
    w = jnp.asarray(RNG.integers(-64, 64, (K, N)), jnp.int8)
    s = jnp.asarray(RNG.uniform(0.01, 2.0, (N,)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(N,)), jnp.float32)
    got_s = ops.int_matmul(x, w, scale=s, block_k=64)
    np.testing.assert_array_equal(
        np.asarray(got_s), np.asarray(ref.ref_int_matmul_fused(x, w, s))
    )
    got_b = ops.int_matmul(x, w, scale=s, bias=b, block_k=64)
    np.testing.assert_allclose(
        np.asarray(got_b), np.asarray(ref.ref_int_matmul_fused(x, w, s, b)), rtol=1e-6
    )


def test_int_matmul_epilogue_vs_matmul_then_scale():
    """Epilogue-vs-(matmul -> scale) parity: the fused op must equal the
    unfused int32 kernel output rescaled outside — same accumulator, the
    epilogue only moves the multiply into the flush."""
    x = jnp.asarray(RNG.integers(-32, 32, (47, 130)), jnp.int8)
    w = jnp.asarray(RNG.integers(-32, 32, (130, 19)), jnp.int8)
    s = jnp.asarray(RNG.uniform(0.01, 1.0, (19,)), jnp.float32)
    fused = ops.int_matmul(x, w, scale=s, block_k=64)
    unfused = ops.int_matmul(x, w, block_k=64).astype(jnp.float32) * s[None, :]
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(unfused))
    # scalar scale broadcasts like a full column vector
    sc = jnp.float32(0.125)
    fused_sc = ops.int_matmul(x, w, scale=sc, block_k=64)
    np.testing.assert_array_equal(
        np.asarray(fused_sc),
        np.asarray(ops.int_matmul(x, w, block_k=64), np.float32) * 0.125,
    )


def test_int_matmul_spill_int16_saturate_combo():
    """int16 spill composes with saturate-mode accumulator emulation: the
    saturated carry is always within acc_bits <= 16, so the narrow register
    stays lossless and the tile schedule must match the oracle's replay."""
    x = jnp.asarray(RNG.integers(-8, 8, (32, 96)), jnp.int8)
    w = jnp.asarray(RNG.integers(-8, 8, (96, 48)), jnp.int8)
    for acc_bits in (12, 16):
        got = ops.int_matmul(
            x, w, acc_bits=acc_bits, mode="saturate", spill_int16=True, block_k=32
        )
        want = ref.ref_int_matmul(x, w, acc_bits=acc_bits, mode="saturate", block_k=32)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # ...and with the fused epilogue on top (the deployed-layer configuration)
    s = jnp.asarray(RNG.uniform(0.01, 1.0, (48,)), jnp.float32)
    got = ops.int_matmul(
        x, w, acc_bits=16, mode="saturate", spill_int16=True, scale=s, block_k=32
    )
    want = ref.ref_int_matmul_fused(x, w, s, acc_bits=16, mode="saturate", block_k=32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_int_matmul_bias_requires_scale():
    x = jnp.zeros((8, 8), jnp.int8)
    with pytest.raises(ValueError):
        ops.int_matmul(x, x, bias=jnp.zeros((8,), jnp.float32))


# ---------------------------------------------------------------------------
# a2q_quantize
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("K,C", [(300, 130), (512, 256), (17, 5), (1024, 64)])
@pytest.mark.parametrize("acc_bits,input_signed", [(16, False), (20, True), (12, False)])
def test_a2q_quantize_kernel(K, C, acc_bits, input_signed):
    v = jnp.asarray(RNG.normal(size=(K, C)), jnp.float32)
    t = jnp.asarray(RNG.normal(size=(C,)) + 3, jnp.float32)
    d = jnp.asarray(RNG.normal(size=(C,)) - 6, jnp.float32)
    deq, q = ops.a2q_quantize(
        v, t, d, weight_bits=8, acc_bits=acc_bits, input_bits=8, input_signed=input_signed
    )
    deq_r, q_r = ref.ref_a2q_quantize(v, t, d, 8, acc_bits, 8, input_signed)
    np.testing.assert_array_equal(np.asarray(q, np.int32), np.asarray(q_r))
    np.testing.assert_allclose(np.asarray(deq), np.asarray(deq_r), atol=1e-6)


def test_a2q_quantize_kernel_budget_invariant():
    from repro.core.bounds import l1_budget

    v = jnp.asarray(RNG.normal(size=(640, 256)), jnp.float32)
    t = jnp.asarray(RNG.normal(size=(256,)) + 6, jnp.float32)  # over the cap
    d = jnp.asarray(RNG.normal(size=(256,)) - 5, jnp.float32)
    _, q = ops.a2q_quantize(v, t, d, weight_bits=8, acc_bits=14, input_bits=8, input_signed=False)
    l1 = np.abs(np.asarray(q, np.int64)).sum(0)
    assert (l1 <= l1_budget(14, 8, False)).all()


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("Tq,Tk,causal,window", [
    (100, 100, True, None),
    (100, 100, True, 17),
    (64, 64, False, None),
    (1, 100, True, None),     # decode
    (1, 100, True, 32),       # windowed decode
    (96, 128, True, None),    # Tq < Tk end-aligned
])
def test_flash_attention_vs_ref(Tq, Tk, causal, window):
    B, H, D = 2, 3, 64
    q = jnp.asarray(RNG.normal(size=(B, H, Tq, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, H, Tk, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, H, Tk, D)), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal, window=window, block_q=32, block_k=32)
    want = ref.ref_flash_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    B, H, T, D = 1, 2, 48, 32
    q = jnp.asarray(RNG.normal(size=(B, H, T, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, H, T, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, H, T, D)), dtype)
    got = ops.flash_attention(q, k, v, block_q=16, block_k=16)
    want = ref.ref_flash_attention(q, k, v)
    atol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=atol
    )


# ---------------------------------------------------------------------------
# paged attention (decode through block tables)
# ---------------------------------------------------------------------------


def _paged_setup(B, KV, Dh, NB, bs, MB, lens, seed=0):
    rng = np.random.default_rng(seed)
    kp = jnp.asarray(rng.normal(size=(NB, bs, KV, Dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(NB, bs, KV, Dh)), jnp.float32)
    bt = np.zeros((B, MB), np.int32)
    nxt = 1  # block 0 = trash
    for b, ln in enumerate(lens):
        for j in range(-(-ln // bs)):
            bt[b, j] = nxt
            nxt += 1
    assert nxt <= NB
    return kp, vp, jnp.asarray(bt), jnp.asarray(np.asarray(lens, np.int32))


@pytest.mark.parametrize("H,KV", [(4, 4), (8, 2), (6, 1)])  # MHA, GQA, MQA
def test_paged_attention_matches_ref(H, KV):
    B, Dh, NB, bs, MB = 3, 32, 16, 8, 4
    lens = [19, 1, 32]
    kp, vp, bt, ln = _paged_setup(B, KV, Dh, NB, bs, MB, lens)
    q = jnp.asarray(RNG.normal(size=(B, H, Dh)), jnp.float32)
    got = ops.paged_attention(q, kp, vp, bt, ln)
    want = ref.ref_paged_attention(q, kp, vp, bt, ln)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_paged_attention_matches_contiguous_flash_ref():
    """A fully-packed paged layout is plain causal decode: the kernel must
    agree with the dense attention oracle on the gathered view."""
    B, H, Dh, bs, MB = 2, 4, 16, 4, 3
    L = bs * MB
    kp, vp, bt, ln = _paged_setup(B, H, Dh, 1 + B * MB, bs, MB, [L, L], seed=3)
    q = jnp.asarray(RNG.normal(size=(B, H, Dh)), jnp.float32)
    got = ops.paged_attention(q, kp, vp, bt, ln)
    k = np.asarray(kp)[np.asarray(bt)].reshape(B, L, H, Dh).transpose(0, 2, 1, 3)
    v = np.asarray(vp)[np.asarray(bt)].reshape(B, L, H, Dh).transpose(0, 2, 1, 3)
    want = ref.ref_flash_attention(
        jnp.asarray(q)[:, :, None, :], jnp.asarray(k), jnp.asarray(v), causal=True
    )[:, :, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_paged_attention_ignores_trash_entries():
    """Table entries past a row's length may point at any block (dead slots
    point at trash): they must not leak into the output."""
    B, H, Dh, NB, bs, MB = 2, 2, 16, 8, 4, 4
    kp, vp, bt, ln = _paged_setup(B, H, Dh, NB, bs, MB, [6, 6], seed=4)
    q = jnp.asarray(RNG.normal(size=(B, H, Dh)), jnp.float32)
    base = np.asarray(ops.paged_attention(q, kp, vp, bt, ln))
    bt2 = np.asarray(bt).copy()
    bt2[:, 2:] = 7  # garbage beyond the 6-token prefix
    redirected = np.asarray(ops.paged_attention(q, kp, vp, jnp.asarray(bt2), ln))
    np.testing.assert_array_equal(base, redirected)
    # zero-length rows produce zeros, not NaNs
    z = np.asarray(ops.paged_attention(q, kp, vp, bt, jnp.asarray([0, 6], jnp.int32)))
    assert np.isfinite(z).all() and np.abs(z[0]).max() == 0.0


def _q8_pools(rng, NB, bs, KV, Dh):
    kq = jnp.asarray(rng.integers(-127, 128, (NB, bs, KV, Dh)), jnp.int8)
    vq = jnp.asarray(rng.integers(-127, 128, (NB, bs, KV, Dh)), jnp.int8)
    ks = jnp.asarray(rng.uniform(0.005, 0.05, (NB, bs, KV)), jnp.float32)
    vs = jnp.asarray(rng.uniform(0.005, 0.05, (NB, bs, KV)), jnp.float32)
    return kq, vq, ks, vs


@pytest.mark.parametrize("H,KV", [(4, 4), (8, 2), (6, 1)])  # MHA, GQA, MQA
def test_paged_attention_q8_matches_ref(H, KV):
    """int8 pools with in-kernel dequant against the jnp q8 oracle."""
    B, Dh, NB, bs, MB = 3, 32, 16, 8, 4
    lens = [19, 1, 32]
    rng = np.random.default_rng(7)
    kq, vq, ks, vs = _q8_pools(rng, NB, bs, KV, Dh)
    _, _, bt, ln = _paged_setup(B, KV, Dh, NB, bs, MB, lens)
    q = jnp.asarray(RNG.normal(size=(B, H, Dh)), jnp.float32)
    got = ops.paged_attention(q, kq, vq, bt, ln, kps=ks, vps=vs)
    want = ref.ref_paged_attention_q8(q, kq, vq, ks, vs, bt, ln)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_paged_attention_q8_equals_dequantized_fp32_path():
    """In-kernel dequant is the same arithmetic as dequantizing the pools
    up front and running the fp32 kernel — the scales commute with the
    block gather."""
    B, H, Dh, NB, bs, MB = 2, 4, 16, 8, 4, 3
    rng = np.random.default_rng(9)
    kq, vq, ks, vs = _q8_pools(rng, NB, bs, H, Dh)
    _, _, bt, ln = _paged_setup(B, H, Dh, NB, bs, MB, [9, 12])
    q = jnp.asarray(RNG.normal(size=(B, H, Dh)), jnp.float32)
    got = ops.paged_attention(q, kq, vq, bt, ln, kps=ks, vps=vs)
    kd = kq.astype(jnp.float32) * ks[..., None]
    vd = vq.astype(jnp.float32) * vs[..., None]
    want = ops.paged_attention(q, kd, vd, bt, ln)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_paged_attention_q8_ignores_trash_and_zero_rows():
    B, H, Dh, NB, bs, MB = 2, 2, 16, 8, 4, 4
    rng = np.random.default_rng(11)
    kq, vq, ks, vs = _q8_pools(rng, NB, bs, H, Dh)
    _, _, bt, ln = _paged_setup(B, H, Dh, NB, bs, MB, [6, 6])
    q = jnp.asarray(RNG.normal(size=(B, H, Dh)), jnp.float32)
    base = np.asarray(ops.paged_attention(q, kq, vq, bt, ln, kps=ks, vps=vs))
    bt2 = np.asarray(bt).copy()
    bt2[:, 2:] = 7
    redirected = np.asarray(
        ops.paged_attention(q, kq, vq, jnp.asarray(bt2), ln, kps=ks, vps=vs)
    )
    np.testing.assert_array_equal(base, redirected)
    z = np.asarray(
        ops.paged_attention(q, kq, vq, bt, jnp.asarray([0, 6], jnp.int32), kps=ks, vps=vs)
    )
    assert np.isfinite(z).all() and np.abs(z[0]).max() == 0.0


def test_paged_attention_scale_args_must_pair():
    B, H, Dh, NB, bs, MB = 1, 2, 16, 4, 4, 2
    rng = np.random.default_rng(13)
    kq, vq, ks, _ = _q8_pools(rng, NB, bs, H, Dh)
    _, _, bt, ln = _paged_setup(B, H, Dh, NB, bs, MB, [4])
    q = jnp.asarray(RNG.normal(size=(B, H, Dh)), jnp.float32)
    with pytest.raises(ValueError):
        ops.paged_attention(q, kq, vq, bt, ln, kps=ks)


# ---------------------------------------------------------------------------
# rwkv6 scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("T,chunk", [(50, 16), (64, 64), (33, 8)])
def test_rwkv6_kernel_vs_ref(T, chunk):
    B, H, Dk, Dv = 2, 2, 16, 16
    r = jnp.asarray(RNG.normal(size=(B, H, T, Dk)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, H, T, Dk)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, H, T, Dv)), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.5, 0.999, size=(B, H, T, Dk)), jnp.float32)
    u = jnp.asarray(RNG.normal(size=(H, Dk)), jnp.float32)
    y, sT = ops.rwkv6_scan(r, k, v, w, u, chunk=chunk)
    for h in range(H):
        y_r, s_r = ref.ref_rwkv6(r[:, h], k[:, h], v[:, h], w[:, h], u[h])
        np.testing.assert_allclose(np.asarray(y[:, h]), np.asarray(y_r), atol=1e-4)
        np.testing.assert_allclose(np.asarray(sT[:, h]), np.asarray(s_r), atol=1e-4)


def test_rwkv6_kernel_initial_state_carry():
    B, H, T, Dk = 1, 1, 32, 8
    r = jnp.asarray(RNG.normal(size=(B, H, T, Dk)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, H, T, Dk)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, H, T, Dk)), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.7, 0.99, size=(B, H, T, Dk)), jnp.float32)
    u = jnp.asarray(RNG.normal(size=(H, Dk)), jnp.float32)
    # run in two halves, carrying state, must equal the single pass
    y_full, s_full = ops.rwkv6_scan(r, k, v, w, u, chunk=8)
    y1, s1 = ops.rwkv6_scan(r[:, :, :16], k[:, :, :16], v[:, :, :16], w[:, :, :16], u, chunk=8)
    y2, s2 = ops.rwkv6_scan(
        r[:, :, 16:], k[:, :, 16:], v[:, :, 16:], w[:, :, 16:], u,
        initial_state=s1, chunk=8,
    )
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 2)), np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=1e-4)


# ---------------------------------------------------------------------------
# windowed paged-attention decode (sliding-window kernel coverage)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("H,KV,window", [(4, 4, 8), (8, 2, 5), (6, 1, 16)])
def test_paged_attention_window_matches_ref(H, KV, window):
    """Sliding-window masking in the paged decode kernel: each row attends
    only keys at kpos >= length - window.  MHA/GQA/MQA sweep, mixed lengths
    shorter and longer than the window."""
    B, Dh, NB, bs, MB = 3, 32, 16, 8, 4
    lens = [19, 3, 32]
    kp, vp, bt, ln = _paged_setup(B, KV, Dh, NB, bs, MB, lens, seed=11)
    q = jnp.asarray(RNG.normal(size=(B, H, Dh)), jnp.float32)
    got = ops.paged_attention(q, kp, vp, bt, ln, window=window)
    want = ref.ref_paged_attention(q, kp, vp, bt, ln, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_paged_attention_window_matches_flash_window_ref():
    """Cross-oracle: a fully-packed windowed paged decode equals the dense
    flash oracle's sliding-window decode on the gathered view."""
    B, H, Dh, bs, MB, W = 2, 4, 16, 4, 3, 5
    L = bs * MB
    kp, vp, bt, ln = _paged_setup(B, H, Dh, 1 + B * MB, bs, MB, [L, L], seed=12)
    q = jnp.asarray(RNG.normal(size=(B, H, Dh)), jnp.float32)
    got = ops.paged_attention(q, kp, vp, bt, ln, window=W)
    k = np.asarray(kp)[np.asarray(bt)].reshape(B, L, H, Dh).transpose(0, 2, 1, 3)
    v = np.asarray(vp)[np.asarray(bt)].reshape(B, L, H, Dh).transpose(0, 2, 1, 3)
    want = ref.ref_flash_attention(
        jnp.asarray(q)[:, :, None, :], jnp.asarray(k), jnp.asarray(v),
        causal=True, window=W,
    )[:, :, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_paged_attention_window_wider_than_length_is_causal():
    """A window covering the whole sequence must equal the unwindowed path
    (the mask reduces to plain causal validity)."""
    B, H, Dh, NB, bs, MB = 2, 2, 16, 8, 4, 4
    kp, vp, bt, ln = _paged_setup(B, H, Dh, NB, bs, MB, [7, 13], seed=13)
    q = jnp.asarray(RNG.normal(size=(B, H, Dh)), jnp.float32)
    wide = ops.paged_attention(q, kp, vp, bt, ln, window=1000)
    plain = ops.paged_attention(q, kp, vp, bt, ln)
    np.testing.assert_allclose(np.asarray(wide), np.asarray(plain), atol=1e-6)
    with pytest.raises(ValueError):
        ops.paged_attention(q, kp, vp, bt, ln, window=0)


def test_paged_attention_q8_window_matches_ref():
    """Window masking composes with the int8 in-register dequant path."""
    B, H, KV, Dh, NB, bs, MB, W = 2, 4, 2, 16, 10, 4, 4, 6
    rng = np.random.default_rng(14)
    kq, vq, ks, vs = _q8_pools(rng, NB, bs, KV, Dh)
    _, _, bt, ln = _paged_setup(B, KV, Dh, NB, bs, MB, [9, 14], seed=14)
    q = jnp.asarray(rng.normal(size=(B, H, Dh)), jnp.float32)
    got = ops.paged_attention(q, kq, vq, bt, ln, kps=ks, vps=vs, window=W)
    want = ref.ref_paged_attention_q8(q, kq, vq, ks, vs, bt, ln, window=W)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)

# ---------------------------------------------------------------------------
# packed int4 paged-attention decode (nibble pools, in-register unpack)
# ---------------------------------------------------------------------------


def _pack_nibbles_np(codes):
    u = codes.astype(np.uint8) & 0xF
    return (u[..., 0::2] | (u[..., 1::2] << 4)).astype(np.uint8)


def _q4_pools(rng, NB, bs, KV, Dh):
    kc = rng.integers(-7, 8, (NB, bs, KV, Dh)).astype(np.int8)
    vc = rng.integers(-7, 8, (NB, bs, KV, Dh)).astype(np.int8)
    ks = jnp.asarray(rng.uniform(0.02, 0.2, (NB, bs, KV)), jnp.float32)
    vs = jnp.asarray(rng.uniform(0.02, 0.2, (NB, bs, KV)), jnp.float32)
    return jnp.asarray(_pack_nibbles_np(kc)), jnp.asarray(_pack_nibbles_np(vc)), ks, vs


@pytest.mark.parametrize("H,KV", [(4, 4), (8, 2), (6, 1)])  # MHA, GQA, MQA
def test_paged_attention_q4_matches_ref(H, KV):
    """Packed-int4 pools (uint8, half feature width) with in-kernel unpack +
    dequant against the jnp q4 oracle."""
    B, Dh, NB, bs, MB = 3, 32, 16, 8, 4
    lens = [19, 1, 32]
    rng = np.random.default_rng(21)
    kq, vq, ks, vs = _q4_pools(rng, NB, bs, KV, Dh)
    _, _, bt, ln = _paged_setup(B, KV, Dh, NB, bs, MB, lens)
    q = jnp.asarray(RNG.normal(size=(B, H, Dh)), jnp.float32)
    got = ops.paged_attention(q, kq, vq, bt, ln, kps=ks, vps=vs)
    want = ref.ref_paged_attention_q4(q, kq, vq, ks, vs, bt, ln)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_paged_attention_q4_equals_unpacked_fp32_path():
    """Nibble unpack + rescale in register is the same arithmetic as
    unpacking the pools up front and running the fp32 kernel."""
    B, H, Dh, NB, bs, MB = 2, 4, 16, 8, 4, 3
    rng = np.random.default_rng(22)
    kc = rng.integers(-7, 8, (NB, bs, H, Dh)).astype(np.int8)
    vc = rng.integers(-7, 8, (NB, bs, H, Dh)).astype(np.int8)
    ks = jnp.asarray(rng.uniform(0.02, 0.2, (NB, bs, H)), jnp.float32)
    vs = jnp.asarray(rng.uniform(0.02, 0.2, (NB, bs, H)), jnp.float32)
    _, _, bt, ln = _paged_setup(B, H, Dh, NB, bs, MB, [9, 12])
    q = jnp.asarray(RNG.normal(size=(B, H, Dh)), jnp.float32)
    got = ops.paged_attention(
        q, jnp.asarray(_pack_nibbles_np(kc)), jnp.asarray(_pack_nibbles_np(vc)),
        bt, ln, kps=ks, vps=vs,
    )
    kd = jnp.asarray(kc, jnp.float32) * ks[..., None]
    vd = jnp.asarray(vc, jnp.float32) * vs[..., None]
    want = ops.paged_attention(q, kd, vd, bt, ln)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_paged_attention_q4_ignores_trash_and_zero_rows():
    B, H, Dh, NB, bs, MB = 2, 2, 16, 8, 4, 4
    rng = np.random.default_rng(23)
    kq, vq, ks, vs = _q4_pools(rng, NB, bs, H, Dh)
    _, _, bt, ln = _paged_setup(B, H, Dh, NB, bs, MB, [6, 6])
    q = jnp.asarray(RNG.normal(size=(B, H, Dh)), jnp.float32)
    base = np.asarray(ops.paged_attention(q, kq, vq, bt, ln, kps=ks, vps=vs))
    bt2 = np.asarray(bt).copy()
    bt2[:, 2:] = 7  # garbage beyond the 6-token prefix
    redirected = np.asarray(
        ops.paged_attention(q, kq, vq, jnp.asarray(bt2), ln, kps=ks, vps=vs)
    )
    np.testing.assert_array_equal(base, redirected)
    z = np.asarray(
        ops.paged_attention(q, kq, vq, bt, jnp.asarray([0, 6], jnp.int32), kps=ks, vps=vs)
    )
    assert np.isfinite(z).all() and np.abs(z[0]).max() == 0.0


def test_paged_attention_q4_window_matches_ref():
    """Window masking composes with the packed-int4 unpack path."""
    B, H, KV, Dh, NB, bs, MB, W = 2, 4, 2, 16, 10, 4, 4, 6
    rng = np.random.default_rng(24)
    kq, vq, ks, vs = _q4_pools(rng, NB, bs, KV, Dh)
    _, _, bt, ln = _paged_setup(B, KV, Dh, NB, bs, MB, [9, 14], seed=24)
    q = jnp.asarray(rng.normal(size=(B, H, Dh)), jnp.float32)
    got = ops.paged_attention(q, kq, vq, bt, ln, kps=ks, vps=vs, window=W)
    want = ref.ref_paged_attention_q4(q, kq, vq, ks, vs, bt, ln, window=W)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_paged_attention_q4_requires_scales():
    B, H, Dh, NB, bs, MB = 1, 2, 16, 4, 4, 2
    rng = np.random.default_rng(25)
    kq, vq, _, _ = _q4_pools(rng, NB, bs, H, Dh)
    _, _, bt, ln = _paged_setup(B, H, Dh, NB, bs, MB, [4])
    q = jnp.asarray(RNG.normal(size=(B, H, Dh)), jnp.float32)
    with pytest.raises(ValueError):
        ops.paged_attention(q, kq, vq, bt, ln)


# ---------------------------------------------------------------------------
# MLA latent paged attention (absorbed decode over compressed pools)
# ---------------------------------------------------------------------------

_MLA_SCALE = (48 + 16) ** -0.5  # (qk_nope_dim + qk_rope_dim) ** -0.5


def _mla_setup(rng, B, H, R, P, NB, bs, MB, lens):
    ql = jnp.asarray(rng.normal(size=(B, H, R)), jnp.float32)
    qp = jnp.asarray(rng.normal(size=(B, H, P)), jnp.float32)
    bt = np.zeros((B, MB), np.int32)
    nxt = 1
    for b, ln in enumerate(lens):
        for j in range(-(-ln // bs)):
            bt[b, j] = nxt
            nxt += 1
    assert nxt <= NB
    return ql, qp, jnp.asarray(bt), jnp.asarray(np.asarray(lens, np.int32))


def test_paged_mla_attention_matches_ref():
    """fp32 latent pools: kernel vs the gathered latent-softmax oracle,
    mixed lengths including a single-token row."""
    B, H, R, P, NB, bs, MB = 3, 8, 32, 8, 16, 8, 4
    rng = np.random.default_rng(31)
    ql, qp, bt, ln = _mla_setup(rng, B, H, R, P, NB, bs, MB, [19, 1, 32])
    ckvp = jnp.asarray(rng.normal(size=(NB, bs, R)), jnp.float32)
    kpep = jnp.asarray(rng.normal(size=(NB, bs, P)), jnp.float32)
    got = ops.paged_mla_attention(ql, qp, ckvp, kpep, bt, ln, scale=_MLA_SCALE)
    want = ref.ref_paged_mla_attention(ql, qp, ckvp, kpep, bt, ln, scale=_MLA_SCALE)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("bits", [8, 4])
def test_paged_mla_attention_quantized_matches_ref(bits):
    """int8 / packed-int4 latent pools with per-token scales: in-register
    dequant (and unpack) against the oracle."""
    B, H, R, P, NB, bs, MB = 3, 8, 32, 8, 16, 8, 4
    rng = np.random.default_rng(32 + bits)
    ql, qp, bt, ln = _mla_setup(rng, B, H, R, P, NB, bs, MB, [19, 1, 30])
    if bits == 8:
        ckvp = jnp.asarray(rng.integers(-127, 128, (NB, bs, R)), jnp.int8)
        kpep = jnp.asarray(rng.integers(-127, 128, (NB, bs, P)), jnp.int8)
    else:
        ckvp = jnp.asarray(_pack_nibbles_np(rng.integers(-7, 8, (NB, bs, R)).astype(np.int8)))
        kpep = jnp.asarray(_pack_nibbles_np(rng.integers(-7, 8, (NB, bs, P)).astype(np.int8)))
    ckvs = jnp.asarray(rng.uniform(0.005, 0.05, (NB, bs)), jnp.float32)
    kpes = jnp.asarray(rng.uniform(0.005, 0.05, (NB, bs)), jnp.float32)
    got = ops.paged_mla_attention(
        ql, qp, ckvp, kpep, bt, ln, ckvs=ckvs, kpes=kpes, scale=_MLA_SCALE
    )
    want = ref.ref_paged_mla_attention(
        ql, qp, ckvp, kpep, bt, ln, ckvs, kpes, scale=_MLA_SCALE
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_paged_mla_attention_act_quant_matches_ref():
    """The in-kernel activation fake-quant (clip(round(x/s)) * s on the
    dequantized latent, the absorb path's A2Q quantizer) matches the oracle
    on both the score and PV uses of the latent."""
    B, H, R, P, NB, bs, MB = 2, 4, 16, 8, 10, 4, 4
    rng = np.random.default_rng(35)
    ql, qp, bt, ln = _mla_setup(rng, B, H, R, P, NB, bs, MB, [9, 14])
    ckvp = jnp.asarray(rng.integers(-127, 128, (NB, bs, R)), jnp.int8)
    kpep = jnp.asarray(rng.integers(-127, 128, (NB, bs, P)), jnp.int8)
    ckvs = jnp.asarray(rng.uniform(0.005, 0.05, (NB, bs)), jnp.float32)
    kpes = jnp.asarray(rng.uniform(0.005, 0.05, (NB, bs)), jnp.float32)
    aq = jnp.asarray(0.017, jnp.float32)  # traced scalar, shipped as (1, 1)
    got = ops.paged_mla_attention(
        ql, qp, ckvp, kpep, bt, ln, ckvs=ckvs, kpes=kpes,
        scale=_MLA_SCALE, aq_scale=aq, act_bits=8,
    )
    want = ref.ref_paged_mla_attention(
        ql, qp, ckvp, kpep, bt, ln, ckvs, kpes,
        scale=_MLA_SCALE, aq_scale=aq, act_bits=8,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
    # act-quant must actually change the result (the flag is load-bearing)
    plain = ops.paged_mla_attention(
        ql, qp, ckvp, kpep, bt, ln, ckvs=ckvs, kpes=kpes, scale=_MLA_SCALE
    )
    assert np.abs(np.asarray(got) - np.asarray(plain)).max() > 1e-6


def test_paged_mla_attention_ignores_trash_and_zero_rows():
    B, H, R, P, NB, bs, MB = 2, 4, 16, 8, 10, 4, 4
    rng = np.random.default_rng(36)
    ql, qp, bt, ln = _mla_setup(rng, B, H, R, P, NB, bs, MB, [6, 6])
    ckvp = jnp.asarray(rng.normal(size=(NB, bs, R)), jnp.float32)
    kpep = jnp.asarray(rng.normal(size=(NB, bs, P)), jnp.float32)
    base = np.asarray(
        ops.paged_mla_attention(ql, qp, ckvp, kpep, bt, ln, scale=_MLA_SCALE)
    )
    bt2 = np.asarray(bt).copy()
    bt2[:, 2:] = 9  # garbage beyond the 6-token prefix
    redirected = np.asarray(
        ops.paged_mla_attention(ql, qp, ckvp, kpep, jnp.asarray(bt2), ln, scale=_MLA_SCALE)
    )
    np.testing.assert_array_equal(base, redirected)
    z = np.asarray(
        ops.paged_mla_attention(
            ql, qp, ckvp, kpep, bt, jnp.asarray([0, 6], jnp.int32), scale=_MLA_SCALE
        )
    )
    assert np.isfinite(z).all() and np.abs(z[0]).max() == 0.0


def test_paged_mla_attention_arg_validation():
    B, H, R, P, NB, bs, MB = 1, 2, 16, 8, 4, 4, 2
    rng = np.random.default_rng(37)
    ql, qp, bt, ln = _mla_setup(rng, B, H, R, P, NB, bs, MB, [4])
    ckvp = jnp.asarray(rng.normal(size=(NB, bs, R)), jnp.float32)
    kpep = jnp.asarray(rng.normal(size=(NB, bs, P)), jnp.float32)
    ckvs = jnp.asarray(rng.uniform(0.01, 0.05, (NB, bs)), jnp.float32)
    with pytest.raises(ValueError):  # scale pools must pair
        ops.paged_mla_attention(ql, qp, ckvp, kpep, bt, ln, ckvs=ckvs, scale=_MLA_SCALE)
    with pytest.raises(ValueError):  # aq_scale and act_bits must pair
        ops.paged_mla_attention(
            ql, qp, ckvp, kpep, bt, ln, scale=_MLA_SCALE, act_bits=8
        )
