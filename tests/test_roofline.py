"""Roofline machinery: HLO collective parsing, term math, and the XLA
while-body costing property the extrapolation methodology depends on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import (
    collective_bytes_from_hlo,
    model_flops,
    roofline_terms,
    wire_bytes,
)


def test_collective_parser_on_synthetic_hlo():
    hlo = """
  %ar = f32[8,1024]{1,0} all-reduce(f32[8,1024]{1,0} %x), replica_groups={}
  %ag = bf16[16,256]{1,0} all-gather(bf16[2,256]{1,0} %y), dimensions={0}
  %rs = f32[2,256]{1,0} reduce-scatter(f32[16,256]{1,0} %z), dimensions={0}
  %cp = s8[64]{0} collective-permute(s8[64]{0} %w), source_target_pairs={{0,1}}
  %other = f32[4]{0} add(f32[4]{0} %a, f32[4]{0} %b)
    """
    r = collective_bytes_from_hlo(hlo)
    assert r["counts"]["all-reduce"] == 1
    assert r["bytes_by_kind"]["all-reduce"] == 8 * 1024 * 4
    assert r["bytes_by_kind"]["all-gather"] == 16 * 256 * 2
    assert r["bytes_by_kind"]["reduce-scatter"] == 2 * 256 * 4
    assert r["bytes_by_kind"]["collective-permute"] == 64
    assert r["total_bytes"] == 8 * 1024 * 4 + 16 * 256 * 2 + 2 * 256 * 4 + 64


def test_collective_parser_skips_done_ops():
    hlo = """
  %s = f32[128]{0} all-reduce-start(f32[128]{0} %x)
  %d = f32[128]{0} all-reduce-done(f32[128]{0} %s)
    """
    r = collective_bytes_from_hlo(hlo)
    assert r["counts"]["all-reduce"] == 1
    assert r["total_bytes"] == 128 * 4


def test_collective_parser_classifies_gradient_wire():
    """s8/s16 all-gather / all-to-all results are compressed-gradient
    traffic (only dist.collectives narrows integers onto the wire); f32
    collectives and s8 collective-permutes are not."""
    hlo = """
  %ag = s8[1024,64]{1,0} all-gather(s8[64,64]{1,0} %q), dimensions={0}
  %a2a = s8[16,64]{1,0} all-to-all(s8[16,64]{1,0} %p), dimensions={0}
  %ag16 = s16[128]{0} all-gather(s16[8]{0} %r), dimensions={0}
  %arf = f32[1024]{0} all-reduce(f32[1024]{0} %x)
  %cp = s8[64]{0} collective-permute(s8[64]{0} %w), source_target_pairs={{0,1}}
    """
    r = collective_bytes_from_hlo(hlo)
    assert r["gradient_wire_bytes"] == 1024 * 64 + 16 * 64 + 128 * 2
    assert r["gradient_wire_counts"] == 3
    # existing accounting is untouched
    assert r["bytes_by_kind"]["all-reduce"] == 1024 * 4
    assert r["bytes_by_kind"]["collective-permute"] == 64


def test_wire_bytes_ring_convention():
    """all-reduce moves ~2x its result on a ring; everything else ~1x."""
    r = collective_bytes_from_hlo(
        """
  %ar = f32[100]{0} all-reduce(f32[100]{0} %x)
  %ag = s8[100]{0} all-gather(s8[10]{0} %y), dimensions={0}
    """
    )
    assert wire_bytes(r) == 2 * 400 + 100


def test_roofline_terms_math():
    t = roofline_terms(
        flops_per_device=197e12,  # exactly one second of compute
        bytes_per_device=819e9 / 2,  # half a second of HBM
        collective_bytes_per_device=0.0,
        n_chips=256,
    )
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(0.5)
    assert t["dominant"] == "compute_s"
    assert t["roofline_fraction"] == pytest.approx(1.0)


def test_model_flops():
    assert model_flops(1e9, 1e6, "train") == 6e15
    assert model_flops(1e9, 1e6, "fwd") == 2e15


def test_xla_counts_while_body_once():
    """The property the dry-run's marginal-layer extrapolation corrects for.
    If XLA ever starts multiplying loop bodies by trip count, this test fails
    and the costing methodology in launch/dryrun.py must be revisited."""
    M = 128

    def one(x, w):
        return jnp.tanh(x @ w)

    def scanned(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (one(c, w), None), x, ws)
        return y

    xs = jax.ShapeDtypeStruct((M, M), jnp.float32)
    w1 = jax.ShapeDtypeStruct((M, M), jnp.float32)
    wL = jax.ShapeDtypeStruct((10, M, M), jnp.float32)

    def flops(c):
        ca = c.cost_analysis()
        return (ca[0] if isinstance(ca, (list, tuple)) else ca)["flops"]

    f1 = flops(jax.jit(one).lower(xs, w1).compile())
    fL = flops(jax.jit(scanned).lower(xs, wL).compile())
    assert fL == pytest.approx(f1, rel=0.01), (f1, fL)


def test_unrolled_stack_flops_scale_with_depth():
    """Sanity for the extrapolation: unrolled 2-layer model costs ~2x the
    1-layer model's stack portion."""
    import dataclasses

    from repro.configs import get_arch, reduced
    from repro.models import init_lm, lm_loss
    from repro.nn.module import unbox

    arch1 = dataclasses.replace(
        reduced(get_arch("yi-6b")),
        stacks=tuple(dataclasses.replace(s, count=1) for s in reduced(get_arch("yi-6b")).stacks),
        unroll_stacks=True,
    )
    arch2 = dataclasses.replace(
        arch1, stacks=tuple(dataclasses.replace(s, count=2) for s in arch1.stacks)
    )

    def flops_for(arch):
        params = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), arch))
        from repro.nn.module import unbox as ub

        shapes = ub(params)
        batch = {
            "tokens": jax.ShapeDtypeStruct((2, 32), jnp.int32),
            "targets": jax.ShapeDtypeStruct((2, 32), jnp.int32),
        }
        c = jax.jit(lambda p, b: lm_loss(p, arch, b)[0]).lower(shapes, batch).compile()
        ca = c.cost_analysis()
        return (ca[0] if isinstance(ca, (list, tuple)) else ca)["flops"]

    f1, f2 = flops_for(arch1), flops_for(arch2)
    assert f2 > f1 * 1.3  # extra layer adds real counted flops
