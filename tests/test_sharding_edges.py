"""Edge cases for repro.dist beyond the seed contract tests: degenerate
meshes, fused-QKV unit counts, boxed-tree spec derivation, and the
compressed-collective quantization contracts on a single device (fast,
in-process) — including the per-column (A2Q+-style) scale mode, the static
overflow guard, and the grad-compress residual state layout."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.configs import get_arch, reduced
from repro.dist.collectives import (
    GradCompressConfig,
    compressed_allreduce_tree,
    compressed_psum,
    compressed_psum_tree,
    owner_dim,
    quantize_shared_scale,
    resolve_grad_compress,
    server_shape,
)
from repro.dist.sharding import ShardingRules, cache_specs, param_specs, resolve_pspec
from repro.nn.module import box


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_one_axis_mesh_data_only():
    """No 'model' axis: TP-ish dims replicate, FSDP/batch still shard."""
    mesh = _FakeMesh({"data": 8})
    arch = get_arch("smollm-135m")
    rules = ShardingRules.default(mesh, arch)
    assert rules.rules["batch"] == ("data",)
    assert resolve_pspec(("embed", "heads"), (576, 576), mesh, rules) == P("data", None)
    # mlp wants 'model' which doesn't exist -> replicated
    assert resolve_pspec(("embed", "mlp"), (576, 1536), mesh, rules) == P("data", None)


def test_mesh_size_one_everything_replicated():
    """Size-1 axes are skipped: single-device specs are fully replicated."""
    mesh = _FakeMesh({"data": 1, "model": 1})
    arch = get_arch("smollm-135m")
    rules = ShardingRules.default(mesh, arch)
    assert resolve_pspec(("embed", "mlp"), (576, 1536), mesh, rules) == P(None, None)
    assert resolve_pspec(("batch", None, None), (8, 64, 1), mesh, rules) == P(None, None, None)


def test_fused_qkv_heads_divide_kv_heads_do_not():
    """yi-6b on a (2, 16) mesh: 32 heads shard 16-way, 4 kv_heads cannot —
    even though the fused kv dim 4*128=512 itself divides 16."""
    mesh = _FakeMesh({"data": 2, "model": 16})
    arch = get_arch("yi-6b")
    rules = ShardingRules.default(mesh, arch)
    assert rules.unit_counts["heads"] == 32 and rules.unit_counts["kv_heads"] == 4
    assert resolve_pspec(("embed", "heads"), (4096, 4096), mesh, rules) == P("data", "model")
    assert (512 % 16) == 0  # raw-dim divisibility would wrongly shard...
    assert resolve_pspec(("embed", "kv_heads"), (4096, 512), mesh, rules) == P("data", None)


def test_multi_axis_rule_prefers_largest_valid_subset():
    """batch rule ('pod', 'data') with batch=8 on {pod: 2, data: 8}: the full
    16-way extent doesn't divide, and 'data' alone (8-way) beats 'pod' (2-way)."""
    mesh = _FakeMesh({"pod": 2, "data": 8})
    rules = ShardingRules.default(mesh, None)
    assert rules.rules["batch"] == ("pod", "data")
    assert resolve_pspec(("batch", None), (8, 4), mesh, rules) == P("data", None)
    # divisible by the full extent -> both axes, earlier-first
    assert resolve_pspec(("batch", None), (16, 4), mesh, rules) == P(("pod", "data"), None)


def test_param_specs_on_boxed_tree():
    mesh = _FakeMesh({"data": 2, "model": 4})
    arch = get_arch("yi-6b")
    rules = ShardingRules.default(mesh, arch)
    tree = {
        "wq": box(jnp.zeros((4096, 4096)), ("embed", "heads")),
        "norm": box(jnp.zeros((4096,)), (None,)),
        "plain": jnp.zeros((3, 3)),  # non-boxed leaves replicate
    }
    specs = param_specs(tree, mesh, rules)
    assert specs["wq"] == P("data", "model")
    assert specs["norm"] == P(None)
    assert specs["plain"] == P(None, None)


def test_compressed_psum_single_device_contract():
    """On a 1-device mesh the psum is an identity: the 'total' is the
    dequantized payload, the residual is exactly what quantization dropped,
    and total + err reconstructs the payload bit-for-bit."""
    mesh = jax.make_mesh((1,), ("data",))
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 32), jnp.float32)
    err0 = jnp.zeros_like(x)

    f = jax.shard_map(
        lambda xs, es: compressed_psum(xs, "data", es, bits=8),
        mesh=mesh,
        in_specs=(P("data"), P("data")),
        out_specs=(P("data"), P("data")),
        check_vma=False,
    )
    total, err = f(x, err0)
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    assert float(jnp.abs(total - x).max()) <= scale / 2 + 1e-7
    np.testing.assert_allclose(np.asarray(total + err), np.asarray(x), rtol=0, atol=1e-7)
    assert float(jnp.abs(err).max()) > 0  # normal data never quantizes exactly


def test_compressed_psum_tree_structure():
    mesh = jax.make_mesh((1,), ("data",))
    tree = {"a": jnp.ones((2, 4)), "b": {"c": jnp.full((3,), 0.3)}}
    errs = jax.tree.map(jnp.zeros_like, tree)

    f = jax.shard_map(
        lambda t, e: compressed_psum_tree(t, "data", e, bits=8),
        mesh=mesh,
        in_specs=(P(), P()),
        out_specs=(P(), P()),
        check_vma=False,
    )
    total, new_errs = f(tree, errs)
    assert jax.tree_util.tree_structure(total) == jax.tree_util.tree_structure(tree)
    assert jax.tree_util.tree_structure(new_errs) == jax.tree_util.tree_structure(tree)
    assert float(jnp.abs(total["a"] - 1.0).max()) < 1e-2


def test_compressed_psum_rejects_bad_bits():
    with pytest.raises(ValueError):
        compressed_psum(jnp.ones((2,)), "data", jnp.zeros((2,)), bits=1)


def test_compressed_psum_rejects_bad_scale_axis():
    with pytest.raises(ValueError):
        compressed_psum(jnp.ones((2,)), "data", jnp.zeros((2,)), scale_axis="row")


def test_compressed_psum_requires_bound_axis():
    """Outside shard_map the axis has no static size -> clear error, not a
    silently-skipped guard."""
    with pytest.raises(ValueError, match="static size"):
        compressed_psum(jnp.ones((2,)), "data", jnp.zeros((2,)))


def test_overflow_guard_raises_at_trace_time():
    """The Eq.-12-style static guard must actually fire: 2**17 shards at
    int16 overflows the int32 accumulator.  AbstractMesh traces the
    shard_map without devices, so the guard is exercised at trace time."""
    from jax._src.mesh import AbstractMesh

    n = 1 << 17
    am = AbstractMesh((("data", n),))
    x = jax.ShapeDtypeStruct((n, 4), jnp.float32)

    def f(xs, es):
        return compressed_psum(xs, "data", es, bits=16)

    g = jax.shard_map(f, mesh=am, in_specs=(P("data"), P("data")),
                      out_specs=(P("data"), P("data")), check_vma=False)
    with pytest.raises(ValueError, match="overflow"):
        jax.eval_shape(g, x, x)
    # int8 at the same width is fine: 2**17 * 127 << 2**31
    g8 = jax.shard_map(lambda xs, es: compressed_psum(xs, "data", es, bits=8),
                       mesh=am, in_specs=(P("data"), P("data")),
                       out_specs=(P("data"), P("data")), check_vma=False)
    jax.eval_shape(g8, x, x)


@settings(max_examples=12, deadline=None)
@given(
    bits=st.integers(2, 16),
    rows=st.integers(1, 5),
    cols=st.integers(1, 6),
)
def test_quantize_wire_format(bits, rows, cols):
    """Wire payload contract: int8 for bits<=8 / int16 above, one scale
    scalar for tensor mode, one fp32 scale per output column for column
    mode (rank>=2)."""
    mesh = jax.make_mesh((1,), ("data",))
    y = jax.random.normal(jax.random.PRNGKey(bits), (rows, cols), jnp.float32)

    def f(ys):
        qt, st_ = quantize_shared_scale(ys, "data", bits, "tensor")
        qc, sc = quantize_shared_scale(ys, "data", bits, "column")
        return qt, st_, qc, sc

    qt, st_, qc, sc = jax.shard_map(
        f, mesh=mesh, in_specs=P(), out_specs=P(), check_vma=False
    )(y)
    want = jnp.int8 if bits <= 8 else jnp.int16
    assert qt.dtype == want and qc.dtype == want
    assert st_.shape == () and st_.dtype == jnp.float32
    assert sc.shape == (1, cols) and sc.dtype == jnp.float32
    qmax = 2 ** (bits - 1) - 1
    assert int(jnp.abs(qt).max()) <= qmax and int(jnp.abs(qc).max()) <= qmax


@settings(max_examples=10, deadline=None)
@given(
    cols=st.integers(2, 8),
    spread=st.floats(1.5, 100.0),
)
def test_per_column_scale_exact_on_column_constant(cols, spread):
    """A payload whose every column is constant is represented exactly by
    per-column scales (each column quantizes to +-qmax), while a shared
    tensor scale loses the small columns — the A2Q+ granularity argument."""
    mesh = jax.make_mesh((1,), ("data",))
    vals = jnp.linspace(1.0, spread, cols)
    x = jnp.tile(vals[None, :], (4, 1)).astype(jnp.float32)
    err0 = jnp.zeros_like(x)

    def run(scale_axis):
        f = jax.shard_map(
            lambda xs, es: compressed_psum(xs, "data", es, bits=8, scale_axis=scale_axis),
            mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()), check_vma=False,
        )
        total, err = f(x, err0)
        return float(jnp.abs(total - x).max())

    err_col = run("column")
    err_tensor = run("tensor")
    assert err_col <= 1e-5 * spread, err_col
    # the shared scale cannot represent column 0 (magnitude 1) exactly when
    # the largest column sets the scale
    if spread > 3:
        assert err_tensor > err_col


def test_compressed_psum_column_tree_mixed_ranks():
    """Tree mode with per-column scales: rank>=2 leaves get column scales,
    rank-1 leaves fall back to the tensor scale — both still reconstruct
    payload = total + err on one device."""
    mesh = jax.make_mesh((1,), ("data",))
    tree = {
        "w": jnp.asarray([[0.5, 40.0], [0.5, 40.0]], jnp.float32),
        "b": jnp.asarray([0.1, -0.2, 0.3], jnp.float32),
    }
    errs = jax.tree.map(jnp.zeros_like, tree)
    f = jax.shard_map(
        lambda t, e: compressed_psum_tree(t, "data", e, bits=8, scale_axis="column"),
        mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()), check_vma=False,
    )
    total, err = f(tree, errs)
    for k in tree:
        np.testing.assert_allclose(
            np.asarray(total[k] + err[k]), np.asarray(tree[k]), rtol=0, atol=1e-6
        )
    # column-constant leaf "w" columns are exact under per-column scales
    assert float(jnp.abs(total["w"] - tree["w"]).max()) < 1e-4


def test_compressed_allreduce_tree_single_device_contract():
    """The global-view (GSPMD) transport on one device: total ~= payload,
    total + local residual reconstructs it, structure preserved."""
    mesh = jax.make_mesh((1,), ("data",))
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 6), jnp.float32),
            "s": jnp.float32(0.7)}
    stacked = jax.tree.map(lambda t: t[None], tree)
    err = {
        "local": jax.tree.map(jnp.zeros_like, stacked),
        "server": jax.tree.map(lambda t: jnp.zeros(server_shape(t.shape, 1), jnp.float32), tree),
    }

    def f(g, e):
        return compressed_allreduce_tree(g, e, mesh=mesh, axis="data", bits=8)

    total, new_err = jax.jit(f)(stacked, err)
    assert jax.tree_util.tree_structure(total) == jax.tree_util.tree_structure(tree)
    scale = float(jnp.abs(tree["w"]).max()) / 127.0
    assert float(jnp.abs(total["w"] - tree["w"]).max()) <= scale / 2 + 1e-7
    recon = total["w"] + new_err["local"]["w"][0]
    np.testing.assert_allclose(np.asarray(recon), np.asarray(tree["w"]), rtol=0, atol=1e-6)
    assert abs(float(total["s"]) - 0.7) <= float(jnp.abs(tree['s'])) / 127.0 + 1e-7


def test_owner_dim_prefers_axis_then_free_dim():
    assert owner_dim(P("model", "data"), 2, "data") == 1  # FSDP dim wins
    assert owner_dim(P(None, "data", "model"), 3, "data") == 1
    assert owner_dim(P("model", None), 2, "data") == 1  # free dim
    assert owner_dim(P("model", "model2"), 2, "data") == 0  # fallback
    assert owner_dim(None, 3, "data") == 0
    assert server_shape((30, 576), 16, 0) == (32, 576)
    assert server_shape((), 4) == (4,)


def test_owner_dim_sees_multi_axis_tuple_fsdp_dims():
    """Regression (ROADMAP nit): an FSDP dim spelled inside a multi-axis
    PartitionSpec tuple — P(("pod", "data"), ...) on a multi-pod mesh — must
    win ownership like the bare spelling does; missing it pushed ownership
    onto a free dim and cost an extra all-gather per leaf (wire only)."""
    assert owner_dim(P(("pod", "data"), "model"), 2, "data") == 0
    assert owner_dim(P("model", ("data", "model2")), 2, "data") == 1
    assert owner_dim(P(None, ("pod", "data")), 2, "data") == 1
    # the axis singleton-tuple spelling keeps working
    assert owner_dim(P(("data",), "model"), 2, "data") == 0
    # tuples NOT carrying the axis still lose to a later bare/free dim
    assert owner_dim(P(("pod", "model"), "data"), 2, "data") == 1
    assert owner_dim(P(("pod", "model"), None), 2, "data") == 1


def test_compressed_allreduce_tuple_pspec_numerics():
    """The multi-axis-tuple owner dim must not change the math: global-view
    compressed sum with P(("pod", "data"), ...) param layout equals the sum
    of shard contributions within wire tolerance."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh

    from repro.dist.collectives import compressed_allreduce, server_shape as ss

    devs = np.array(jax.devices()[:1]).reshape(1, 1, 1)
    mesh = Mesh(devs, ("pod", "data", "model"))
    n = int(mesh.shape["data"])
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(n, 6, 4)), jnp.float32)
    pspec = P(("pod", "data"), "model")
    od = owner_dim(pspec, 2, "data")
    assert od == 0
    with mesh:
        total, new_local, new_server = compressed_allreduce(
            g, jnp.zeros_like(g), jnp.zeros(ss((6, 4), n, od), jnp.float32),
            mesh=mesh, axis="data", pspec=pspec,
        )
    want = np.asarray(g).sum(0)
    scale = np.abs(np.asarray(g)).max() / 127.0
    assert np.abs(np.asarray(total) - want).max() <= n * scale + 1e-6
    assert new_server.shape == ss((6, 4), n, od)


def test_resolve_grad_compress_axis_selection():
    cfg = GradCompressConfig(bits=8)
    single = _FakeMesh({"data": 8, "model": 2})
    multi = _FakeMesh({"pod": 2, "data": 8, "model": 2})
    tiny = _FakeMesh({"data": 1})
    assert resolve_grad_compress(cfg, single).axis == "data"
    assert resolve_grad_compress(cfg, multi).axis == "pod"  # DCN wire first
    assert resolve_grad_compress(GradCompressConfig(axis="data"), multi).axis == "data"
    assert resolve_grad_compress(cfg, tiny) is None
    assert resolve_grad_compress(cfg, None) is None
    assert resolve_grad_compress(None, single) is None


def test_cache_specs_kv_heads_sharding():
    """K/V cache leaves shard their head dim over `model` when the kv_heads
    unit count divides it — and fall back to replicated when it does not
    (smollm's 3 kv-heads vs a 16-way axis)."""
    from repro.models.lm import init_cache

    # yi-6b: kv_heads=4 divides model=4
    mesh = _FakeMesh({"data": 2, "model": 4})
    arch = get_arch("yi-6b")
    rules = ShardingRules.default(mesh, arch)
    cache = jax.eval_shape(lambda: init_cache(arch, 8, 64))
    specs = cache_specs(cache, mesh, rules)
    k_spec = specs["0"]["attn"]["k"]
    assert k_spec == P(None, "data", None, "model", None)
    assert specs["0"]["attn"]["kpos"] == P(None, "data", None)

    # smollm: 3 kv heads never split over 16
    mesh16 = _FakeMesh({"data": 2, "model": 16})
    sm = get_arch("smollm-135m")
    rules16 = ShardingRules.default(mesh16, sm)
    cache_sm = jax.eval_shape(lambda: init_cache(sm, 8, 64))
    k_sm = cache_specs(cache_sm, mesh16, rules16)["0"]["attn"]["k"]
    assert k_sm == P(None, "data", None, None, None)

    # rwkv6: SSM state (layers, batch, heads, hd, hd) shards heads (64 % 16 == 0)
    rw = get_arch("rwkv6-7b")
    rules_rw = ShardingRules.default(mesh16, rw)
    cache_rw = jax.eval_shape(lambda: init_cache(rw, 16, 64))
    s_spec = cache_specs(cache_rw, mesh16, rules_rw)["0"]["tm"]["S"]
    assert s_spec[2] == "model"


def test_cache_specs_paged_layout():
    """Paged pools: block axis local (any row may own any block), head dim
    keeps the TP sharding of the projections that fill it; MLA latent pools
    replicate; the block table rides with the batch axes."""
    from repro.serve.paged_cache import init_paged_stack_cache

    mesh = _FakeMesh({"data": 2, "model": 4})
    arch = get_arch("yi-6b")
    rules = ShardingRules.default(mesh, arch)
    cache = jax.eval_shape(
        lambda: {
            "0": init_paged_stack_cache(arch, arch.stacks[0], 8, 32, 16, 64, jnp.bfloat16),
            "_paged": {"bt": jnp.zeros((8, 4), jnp.int32)},
        }
    )
    specs = cache_specs(cache, mesh, rules)
    # (layers, NB, bs, kv_heads, head_dim): only the head dim shards
    assert specs["0"]["attn"]["kp"] == P(None, None, None, "model", None)
    assert specs["0"]["attn"]["vp"] == P(None, None, None, "model", None)
    assert specs["_paged"]["bt"] == P("data", None)

    ds = get_arch("deepseek-v3-671b")
    rules_ds = ShardingRules.default(mesh, ds)
    mla = next(s for s in ds.stacks if s.attn is not None and s.attn.kind == "mla")
    cache_ds = jax.eval_shape(
        lambda: {"0": init_paged_stack_cache(ds, mla, 8, 32, 16, 64, jnp.bfloat16)}
    )
    specs_ds = cache_specs(cache_ds, mesh, rules_ds)
    assert specs_ds["0"]["attn"]["ckvp"] == P(None, None, None, None)
    assert specs_ds["0"]["attn"]["kpep"] == P(None, None, None, None)


def test_cache_specs_int8_pools_and_scale_leaves():
    """int8 code pools keep the paged layout specs (dtype is irrelevant to
    sharding); the per-slot scale pools shard their trailing kv_heads dim
    over `model` like the codes they scale (GQA) and replicate for MLA —
    with the usual unit-count fallback."""
    from repro.serve.paged_cache import init_paged_stack_cache

    mesh = _FakeMesh({"data": 2, "model": 4})
    arch = get_arch("yi-6b")
    rules = ShardingRules.default(mesh, arch)
    cache = jax.eval_shape(
        lambda: {"0": init_paged_stack_cache(
            arch, arch.stacks[0], 8, 32, 16, 64, jnp.bfloat16, kv_quant=True
        )}
    )
    specs = cache_specs(cache, mesh, rules)["0"]["attn"]
    assert cache["0"]["attn"]["kp"].dtype == jnp.int8
    assert specs["kp"] == P(None, None, None, "model", None)
    assert specs["kps"] == P(None, None, None, "model")
    assert specs["vps"] == P(None, None, None, "model")

    # smollm's 3 kv-heads: codes AND scales both fall back to replicated
    mesh16 = _FakeMesh({"data": 2, "model": 16})
    sm = get_arch("smollm-135m")
    rules16 = ShardingRules.default(mesh16, sm)
    cache_sm = jax.eval_shape(
        lambda: {"0": init_paged_stack_cache(
            sm, sm.stacks[0], 8, 32, 16, 64, jnp.bfloat16, kv_quant=True
        )}
    )
    specs_sm = cache_specs(cache_sm, mesh16, rules16)["0"]["attn"]
    assert specs_sm["kp"] == P(None, None, None, None, None)
    assert specs_sm["kps"] == P(None, None, None, None)

    # MLA latent scale pools carry nothing shardable
    ds = get_arch("deepseek-v3-671b")
    rules_ds = ShardingRules.default(mesh, ds)
    mla = next(s for s in ds.stacks if s.attn is not None and s.attn.kind == "mla")
    cache_ds = jax.eval_shape(
        lambda: {"0": init_paged_stack_cache(ds, mla, 8, 32, 16, 64, jnp.bfloat16, kv_quant=True)}
    )
    specs_ds = cache_specs(cache_ds, mesh, rules_ds)["0"]["attn"]
    assert cache_ds["0"]["attn"]["ckvp"].dtype == jnp.int8
    assert specs_ds["ckvs"] == P(None, None, None)
    assert specs_ds["kpes"] == P(None, None, None)


def test_make_state_specs_and_init_grad_err_layout():
    """grad_err residual pair: local = P(axis, param-spec minus axis);
    server = param layout with the ownership dim on the axis; shapes from
    init_grad_err line up leaf-for-leaf."""
    from repro.models import init_lm
    from repro.nn.module import unbox
    from repro.optim.optimizers import adamw
    from repro.train.state import init_grad_err, make_state_specs

    arch = reduced(get_arch("smollm-135m"))
    mesh = _FakeMesh({"data": 2, "model": 4})
    rules = ShardingRules.default(mesh, arch)
    boxed = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), arch))
    params = unbox(boxed)
    gc = GradCompressConfig(bits=8, axis="data")
    specs = make_state_specs(boxed, adamw(), mesh, rules, grad_compress=gc)
    assert set(specs) == {"params", "opt_state", "step", "grad_err"}
    pspecs = param_specs(boxed, mesh, rules)
    err = jax.eval_shape(lambda: init_grad_err(params, 2, pspecs=pspecs, axis="data"))

    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_local = dict(jax.tree_util.tree_flatten_with_path(err["local"])[0])
    flat_server = dict(jax.tree_util.tree_flatten_with_path(err["server"])[0])
    flat_ls = dict(
        jax.tree_util.tree_flatten_with_path(
            specs["grad_err"]["local"], is_leaf=lambda x: isinstance(x, P)
        )[0]
    )
    flat_ss = dict(
        jax.tree_util.tree_flatten_with_path(
            specs["grad_err"]["server"], is_leaf=lambda x: isinstance(x, P)
        )[0]
    )
    for path, p in flat_p:
        local, server = flat_local[path], flat_server[path]
        ls, ss = flat_ls[path], flat_ss[path]
        assert local.shape == (2,) + tuple(p.shape)
        assert len(ls) == local.ndim and ls[0] == "data"
        assert "data" not in tuple(ls)[1:]  # no axis reuse
        assert len(ss) <= max(server.ndim, 1)
        assert local.dtype == server.dtype == jnp.float32

    # grad_compress with an unresolved axis is a caller bug
    with pytest.raises(ValueError):
        make_state_specs(boxed, adamw(), mesh, rules, grad_compress=GradCompressConfig())
