"""Edge cases for repro.dist beyond the seed contract tests: degenerate
meshes, fused-QKV unit counts, boxed-tree spec derivation, and the
compressed-psum quantization contract on a single device (fast, in-process)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.dist.collectives import compressed_psum, compressed_psum_tree
from repro.dist.sharding import ShardingRules, param_specs, resolve_pspec
from repro.nn.module import box


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_one_axis_mesh_data_only():
    """No 'model' axis: TP-ish dims replicate, FSDP/batch still shard."""
    mesh = _FakeMesh({"data": 8})
    arch = get_arch("smollm-135m")
    rules = ShardingRules.default(mesh, arch)
    assert rules.rules["batch"] == ("data",)
    assert resolve_pspec(("embed", "heads"), (576, 576), mesh, rules) == P("data", None)
    # mlp wants 'model' which doesn't exist -> replicated
    assert resolve_pspec(("embed", "mlp"), (576, 1536), mesh, rules) == P("data", None)


def test_mesh_size_one_everything_replicated():
    """Size-1 axes are skipped: single-device specs are fully replicated."""
    mesh = _FakeMesh({"data": 1, "model": 1})
    arch = get_arch("smollm-135m")
    rules = ShardingRules.default(mesh, arch)
    assert resolve_pspec(("embed", "mlp"), (576, 1536), mesh, rules) == P(None, None)
    assert resolve_pspec(("batch", None, None), (8, 64, 1), mesh, rules) == P(None, None, None)


def test_fused_qkv_heads_divide_kv_heads_do_not():
    """yi-6b on a (2, 16) mesh: 32 heads shard 16-way, 4 kv_heads cannot —
    even though the fused kv dim 4*128=512 itself divides 16."""
    mesh = _FakeMesh({"data": 2, "model": 16})
    arch = get_arch("yi-6b")
    rules = ShardingRules.default(mesh, arch)
    assert rules.unit_counts["heads"] == 32 and rules.unit_counts["kv_heads"] == 4
    assert resolve_pspec(("embed", "heads"), (4096, 4096), mesh, rules) == P("data", "model")
    assert (512 % 16) == 0  # raw-dim divisibility would wrongly shard...
    assert resolve_pspec(("embed", "kv_heads"), (4096, 512), mesh, rules) == P("data", None)


def test_multi_axis_rule_prefers_largest_valid_subset():
    """batch rule ('pod', 'data') with batch=8 on {pod: 2, data: 8}: the full
    16-way extent doesn't divide, and 'data' alone (8-way) beats 'pod' (2-way)."""
    mesh = _FakeMesh({"pod": 2, "data": 8})
    rules = ShardingRules.default(mesh, None)
    assert rules.rules["batch"] == ("pod", "data")
    assert resolve_pspec(("batch", None), (8, 4), mesh, rules) == P("data", None)
    # divisible by the full extent -> both axes, earlier-first
    assert resolve_pspec(("batch", None), (16, 4), mesh, rules) == P(("pod", "data"), None)


def test_param_specs_on_boxed_tree():
    mesh = _FakeMesh({"data": 2, "model": 4})
    arch = get_arch("yi-6b")
    rules = ShardingRules.default(mesh, arch)
    tree = {
        "wq": box(jnp.zeros((4096, 4096)), ("embed", "heads")),
        "norm": box(jnp.zeros((4096,)), (None,)),
        "plain": jnp.zeros((3, 3)),  # non-boxed leaves replicate
    }
    specs = param_specs(tree, mesh, rules)
    assert specs["wq"] == P("data", "model")
    assert specs["norm"] == P(None)
    assert specs["plain"] == P(None, None)


def test_compressed_psum_single_device_contract():
    """On a 1-device mesh the psum is an identity: the 'total' is the
    dequantized payload, the residual is exactly what quantization dropped,
    and total + err reconstructs the payload bit-for-bit."""
    mesh = jax.make_mesh((1,), ("data",))
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 32), jnp.float32)
    err0 = jnp.zeros_like(x)

    f = jax.shard_map(
        lambda xs, es: compressed_psum(xs, "data", es, bits=8),
        mesh=mesh,
        in_specs=(P("data"), P("data")),
        out_specs=(P("data"), P("data")),
        check_vma=False,
    )
    total, err = f(x, err0)
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    assert float(jnp.abs(total - x).max()) <= scale / 2 + 1e-7
    np.testing.assert_allclose(np.asarray(total + err), np.asarray(x), rtol=0, atol=1e-7)
    assert float(jnp.abs(err).max()) > 0  # normal data never quantizes exactly


def test_compressed_psum_tree_structure():
    mesh = jax.make_mesh((1,), ("data",))
    tree = {"a": jnp.ones((2, 4)), "b": {"c": jnp.full((3,), 0.3)}}
    errs = jax.tree.map(jnp.zeros_like, tree)

    f = jax.shard_map(
        lambda t, e: compressed_psum_tree(t, "data", e, bits=8),
        mesh=mesh,
        in_specs=(P(), P()),
        out_specs=(P(), P()),
        check_vma=False,
    )
    total, new_errs = f(tree, errs)
    assert jax.tree_util.tree_structure(total) == jax.tree_util.tree_structure(tree)
    assert jax.tree_util.tree_structure(new_errs) == jax.tree_util.tree_structure(tree)
    assert float(jnp.abs(total["a"] - 1.0).max()) < 1e-2


def test_compressed_psum_rejects_bad_bits():
    with pytest.raises(ValueError):
        compressed_psum(jnp.ones((2,)), "data", jnp.zeros((2,)), bits=1)
