"""deploy_params / deploy_boxed: int8 deployment tree transforms.

Covers the satellite gaps: passthrough of ``aq``/``b`` leaves, vmapped
leading dims (scan-stacked layers and experts), shape-level twin agreement,
and int8-vs-float logits parity on a reduced arch.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models.lm import apply_lm, init_lm
from repro.nn.module import Boxed, unbox
from repro.serve.engine import deploy_boxed, deploy_params

KEY = jax.random.PRNGKey(0)


def _walk_deployed(tree):
    """Deployed {q8, s8} nodes keyed by tree path (order-independent)."""
    found = {}

    def walk(node, path=()):
        if isinstance(node, dict):
            if "q8" in node:
                found[path] = node
            else:
                for k, v in node.items():
                    walk(v, path + (k,))

    walk(tree)
    return found


def test_deploy_passes_through_aq_and_bias():
    """Activation-quantizer (aq) and bias (b) leaves survive deployment
    untouched — they are runtime state, not weight storage."""
    import dataclasses

    # force biases on so the b-passthrough is actually exercised
    arch = dataclasses.replace(reduced(get_arch("yi-6b")), use_bias=True)
    params = unbox(init_lm(KEY, arch))
    deployed = deploy_params(params, arch.quant)

    def collect(tree, key):
        out = []
        jax.tree_util.tree_map_with_path(
            lambda p, l: out.append((p, l)) if any(
                getattr(k, "key", None) == key for k in p
            ) else None,
            tree,
        )
        return out

    for key in ("aq", "b"):
        before = collect(params, key)
        after = collect(deployed, key)
        assert len(before) == len(after) and len(after) > 0, key
        for (_, x), (_, y) in zip(before, after):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_deploy_vmaps_stacked_layers_and_experts():
    """Scan-stacked linears (layers dim) and MoE expert stacks (experts dim)
    deploy via vmap over the leading dims: q8/s8 keep those dims."""
    arch = reduced(get_arch("deepseek-v3-671b"))  # scan layers + experts
    params = unbox(init_lm(KEY, arch))
    deployed = deploy_params(params, arch.quant)
    nodes = list(_walk_deployed(deployed).values())
    assert nodes
    ranks = {n["q8"].ndim for n in nodes}
    assert max(ranks) >= 3, "no stacked (vmapped) deployments found"
    for n in nodes:
        assert n["q8"].dtype == jnp.int8
        # s8 scales: one per output channel, aligned with q8's trailing dim
        assert n["s8"].shape[-1] == n["q8"].shape[-1]
        assert n["s8"].shape[:-1] == n["q8"].shape[:-2]


def test_deploy_boxed_mirrors_deploy_params_shapes():
    """The dry-run's shape-level twin must produce exactly the shapes/dtypes
    the materializing transform produces, with logical axes preserved."""
    arch = reduced(get_arch("yi-6b"))
    boxed = init_lm(KEY, arch)
    deployed = deploy_params(unbox(boxed), arch.quant)
    boxed_deployed = deploy_boxed(boxed, arch.quant)

    real = _walk_deployed(deployed)
    shaped = _walk_deployed(boxed_deployed)
    assert set(real) == set(shaped) and real
    for path, r in real.items():
        s = shaped[path]
        for k in ("q8", "s8"):
            leaf = s[k]
            assert isinstance(leaf, Boxed)
            assert tuple(leaf.value.shape) == tuple(r[k].shape), (path, k)
            assert leaf.value.dtype == r[k].dtype
            assert len(leaf.axes) == r[k].ndim


@pytest.mark.parametrize("name", ["smollm-135m", "h2o-danube-1.8b"])
def test_deployed_logits_close_to_float_reduced(name):
    """int8 deployment is the same math as training fake-quant: logits agree
    tightly under f32 compute on reduced archs (tie-embeddings + windowed)."""
    arch = reduced(get_arch(name))
    params = unbox(init_lm(KEY, arch))
    deployed = deploy_params(params, arch.quant)
    toks = jnp.asarray([[5, 1, 3, 2, 7, 6]], jnp.int32)
    l1, _, _ = apply_lm(params, arch, tokens=toks)
    l2, _, _ = apply_lm(deployed, arch, tokens=toks)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-3)
    assert np.argmax(np.asarray(l1)[0, -1]) == np.argmax(np.asarray(l2)[0, -1])
