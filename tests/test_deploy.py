"""deploy_params / deploy_boxed: int8 deployment tree transforms.

Covers the satellite gaps: passthrough of ``aq``/``b`` leaves, vmapped
leading dims (scan-stacked layers and experts), shape-level twin agreement,
and int8-vs-float logits parity on a reduced arch.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models.lm import apply_lm, init_lm
from repro.nn.module import Boxed, unbox
from repro.serve.engine import deploy_boxed, deploy_params

KEY = jax.random.PRNGKey(0)


def _walk_deployed(tree):
    """Deployed {q8, s8} nodes keyed by tree path (order-independent)."""
    found = {}

    def walk(node, path=()):
        if isinstance(node, dict):
            if "q8" in node:
                found[path] = node
            else:
                for k, v in node.items():
                    walk(v, path + (k,))

    walk(tree)
    return found


def test_deploy_passes_through_aq_and_bias():
    """Activation-quantizer (aq) and bias (b) leaves survive deployment
    untouched — they are runtime state, not weight storage."""
    import dataclasses

    # force biases on so the b-passthrough is actually exercised
    arch = dataclasses.replace(reduced(get_arch("yi-6b")), use_bias=True)
    params = unbox(init_lm(KEY, arch))
    deployed = deploy_params(params, arch.quant)

    def collect(tree, key):
        out = []
        jax.tree_util.tree_map_with_path(
            lambda p, l: out.append((p, l)) if any(
                getattr(k, "key", None) == key for k in p
            ) else None,
            tree,
        )
        return out

    for key in ("aq", "b"):
        before = collect(params, key)
        after = collect(deployed, key)
        assert len(before) == len(after) and len(after) > 0, key
        for (_, x), (_, y) in zip(before, after):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_deploy_vmaps_stacked_layers_and_experts():
    """Scan-stacked linears (layers dim) and MoE expert stacks (experts dim)
    deploy via vmap over the leading dims: q8/s8 keep those dims."""
    arch = reduced(get_arch("deepseek-v3-671b"))  # scan layers + experts
    params = unbox(init_lm(KEY, arch))
    deployed = deploy_params(params, arch.quant)
    nodes = list(_walk_deployed(deployed).values())
    assert nodes
    ranks = {n["q8"].ndim for n in nodes}
    assert max(ranks) >= 3, "no stacked (vmapped) deployments found"
    for n in nodes:
        assert n["q8"].dtype == jnp.int8
        # s8 scales: one per output channel, aligned with q8's trailing dim
        assert n["s8"].shape[-1] == n["q8"].shape[-1]
        assert n["s8"].shape[:-1] == n["q8"].shape[:-2]


def test_deploy_boxed_mirrors_deploy_params_shapes():
    """The dry-run's shape-level twin must produce exactly the shapes/dtypes
    the materializing transform produces, with logical axes preserved."""
    arch = reduced(get_arch("yi-6b"))
    boxed = init_lm(KEY, arch)
    deployed = deploy_params(unbox(boxed), arch.quant)
    boxed_deployed = deploy_boxed(boxed, arch.quant)

    real = _walk_deployed(deployed)
    shaped = _walk_deployed(boxed_deployed)
    assert set(real) == set(shaped) and real
    for path, r in real.items():
        s = shaped[path]
        for k in ("q8", "s8"):
            leaf = s[k]
            assert isinstance(leaf, Boxed)
            assert tuple(leaf.value.shape) == tuple(r[k].shape), (path, k)
            assert leaf.value.dtype == r[k].dtype
            assert len(leaf.axes) == r[k].ndim


@pytest.mark.parametrize("name", ["yi-6b", "deepseek-v3-671b"])
def test_int_forward_logits_parity_close(name):
    """The fused W8A8 path computes the same quantized algebra as dequant +
    fp32 dot exactly in integers, so logits agree to ~ulp on the reduced
    archs (GQA and MLA) and greedy argmax is preserved."""
    from repro.models.lm import Runtime

    arch = reduced(get_arch(name))
    deployed = deploy_params(unbox(init_lm(KEY, arch)), arch.quant)
    toks = jnp.asarray([[5, 1, 3, 2, 7, 6]], jnp.int32)
    l_deq, _, _ = apply_lm(deployed, arch, tokens=toks)
    l_int, _, _ = apply_lm(deployed, arch, tokens=toks, rt=Runtime(int_forward=True))
    np.testing.assert_allclose(np.asarray(l_deq), np.asarray(l_int), atol=1e-5)
    assert (np.argmax(np.asarray(l_deq), -1) == np.argmax(np.asarray(l_int), -1)).all()


def test_int_forward_exact_when_scales_pow2_and_acts_integral():
    """Int8-exactness witness: with pow2 activation AND weight scales and
    integer-valued inputs, every fp32 product/sum on the dequant path is
    exact, so the dequant dot and the W8A8 kernel are the same arithmetic —
    bitwise-equal outputs (the general case is ~ulp-close: non-pow2 weight
    scales round once per product on the dequant side)."""
    from repro.configs.base import QuantConfig
    from repro.nn.linear import apply_linear

    cfg = QuantConfig(mode="a2q", weight_bits=8, act_bits=8, acc_bits=16)
    rng = np.random.default_rng(0)
    dep = {
        "q8": jnp.asarray(rng.integers(-16, 16, (32, 48)), jnp.int8),
        "s8": jnp.exp2(jnp.asarray(rng.integers(-6, -2, (48,)), jnp.float32)),
        "aq": {"log2_scale": jnp.zeros(())},  # scale = 2**0: acts stay integral
    }
    x = jnp.asarray(rng.integers(-20, 20, (4, 32)), jnp.float32)
    y_deq = apply_linear(dep, x, cfg, compute_dtype=jnp.float32)
    y_int = apply_linear(dep, x, cfg, compute_dtype=jnp.float32, int_forward=True)
    np.testing.assert_array_equal(np.asarray(y_deq), np.asarray(y_int))


def test_int_forward_rwkv6_unsigned_channelmix_fused():
    """rwkv6's channel-mix ``wv`` consumes unsigned 8-bit acts (post-relu²,
    codes up to 255 — past the int8 operand).  It now rides the fused W8A8
    path via signed symmetrization (codes travel as ``q - 128``, the kernel
    adds ``128 * colsum(w)`` back at flush — exact in int32): logits stay
    ~ulp-close AND the chain report shows zero fallback call sites."""
    from repro.models.lm import Runtime

    arch = reduced(get_arch("rwkv6-7b"))
    deployed = deploy_params(unbox(init_lm(KEY, arch)), arch.quant)
    toks = jnp.asarray([[5, 1, 3, 2, 7, 6, 9, 8]], jnp.int32)  # T % ssm chunk == 0
    l_deq, _, _ = apply_lm(deployed, arch, tokens=toks)
    rt = Runtime(int_forward=True)
    l_int, _, _ = apply_lm(deployed, arch, tokens=toks, rt=rt)
    np.testing.assert_allclose(np.asarray(l_deq), np.asarray(l_int), atol=1e-5)
    assert rt.chain_report["fallback"] == [], rt.chain_report
    assert "cm.wv" in rt.chain_report["standalone"]  # fused, own act-quant dispatch


def test_int_forward_falls_back_off_the_int8_path():
    """Stacked (vmapped) q8 and non-deployed params must take the dequant
    path unchanged under int_forward — same output as int_forward=False."""
    from repro.configs.base import QuantConfig
    from repro.nn.linear import apply_linear, init_linear

    cfg = QuantConfig(mode="a2q", weight_bits=8, act_bits=8, acc_bits=16)
    p = unbox(init_linear(KEY, 16, 24, cfg))
    x = jnp.asarray(np.random.default_rng(1).normal(size=(3, 16)), jnp.float32)
    y0 = apply_linear(p, x, cfg, compute_dtype=jnp.float32)
    y1 = apply_linear(p, x, cfg, compute_dtype=jnp.float32, int_forward=True)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))


@pytest.mark.parametrize("name", ["smollm-135m", "h2o-danube-1.8b"])
def test_deployed_logits_close_to_float_reduced(name):
    """int8 deployment is the same math as training fake-quant: logits agree
    tightly under f32 compute on reduced archs (tie-embeddings + windowed)."""
    arch = reduced(get_arch(name))
    params = unbox(init_lm(KEY, arch))
    deployed = deploy_params(params, arch.quant)
    toks = jnp.asarray([[5, 1, 3, 2, 7, 6]], jnp.int32)
    l1, _, _ = apply_lm(params, arch, tokens=toks)
    l2, _, _ = apply_lm(deployed, arch, tokens=toks)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-3)
    assert np.argmax(np.asarray(l1)[0, -1]) == np.argmax(np.asarray(l2)[0, -1])
