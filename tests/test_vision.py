"""Paper benchmark models: shapes, trainability, A2Q budget after training."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import QuantConfig
from repro.core.bounds import l1_budget
from repro.data.synthetic import BinaryMnistStream, ImageClassStream, SuperResStream
from repro.models import vision
from repro.nn.module import unbox
from repro.optim.optimizers import adamw

KEY = jax.random.PRNGKey(0)
Q = QuantConfig(mode="a2q", weight_bits=6, act_bits=6, acc_bits=18)


@pytest.mark.parametrize("name,init,apply,inshape,outshape", [
    ("mobilenetv1", vision.init_mobilenet_v1, vision.apply_mobilenet_v1, (2, 32, 32, 3), (2, 10)),
    ("resnet18", vision.init_resnet18, vision.apply_resnet18, (2, 32, 32, 3), (2, 10)),
    ("espcn", vision.init_espcn, vision.apply_espcn, (2, 16, 16, 1), (2, 48, 48, 1)),
    ("unet", vision.init_unet, vision.apply_unet, (2, 16, 16, 1), (2, 48, 48, 1)),
])
def test_vision_shapes(name, init, apply, inshape, outshape):
    kwargs = {"width": 0.25} if name in ("mobilenetv1", "resnet18") else {}
    if name == "unet":
        kwargs = {"base": 8}
    p = unbox(init(KEY, Q, **kwargs))
    y = apply(p, jnp.ones(inshape), Q)
    assert y.shape == outshape
    assert bool(jnp.isfinite(y).all())


def test_linear_classifier_trains_on_binary_mnist():
    """The paper's App. A setup learns to >85% with a 32-bit accumulator."""
    q = QuantConfig(mode="qat", weight_bits=8, act_bits=1, acc_bits=32)
    p = unbox(vision.init_linear_classifier(KEY, q))
    stream = BinaryMnistStream(global_batch=128, seed=0)
    opt = adamw()
    state = opt.init(p)

    def loss_fn(p, x, y):
        logits = vision.apply_linear_classifier(p, x, q)
        onehot = jax.nn.one_hot(y, 2)
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))

    @jax.jit
    def step(p, state, x, y):
        g = jax.grad(loss_fn)(p, x, y)
        return opt.update(g, state, p, 5e-3)

    for i in range(60):
        b = stream.batch(i)
        p, state = step(p, state, jnp.asarray(b["x"]), jnp.asarray(b["y"]))
    test = stream.batch(10_000)
    logits = vision.apply_linear_classifier(p, jnp.asarray(test["x"]), q)
    acc = float(jnp.mean((jnp.argmax(logits, -1) == jnp.asarray(test["y"]))))
    assert acc > 0.85, acc


def test_a2q_vision_training_preserves_budget():
    """After real gradient steps, the integer conv weights still satisfy
    Eq. 15 (the guarantee is architectural, not init-only)."""
    q = QuantConfig(mode="a2q", weight_bits=6, act_bits=6, acc_bits=14)
    p = unbox(vision.init_espcn(KEY, q))
    stream = SuperResStream(global_batch=4, hr=24)
    opt = adamw()
    state = opt.init(p)

    def loss_fn(p, lr_img, hr_img):
        out = vision.apply_espcn(p, lr_img, q)
        mse = jnp.mean((out - hr_img) ** 2)
        return mse + q.reg_lambda * vision.vision_penalty(p, q)

    @jax.jit
    def step(p, state, lr_img, hr_img):
        g = jax.grad(loss_fn)(p, lr_img, hr_img)
        return opt.update(g, state, p, 1e-3)

    for i in range(10):
        b = stream.batch(i)
        p, state = step(p, state, jnp.asarray(b["lr"]), jnp.asarray(b["hr"]))

    from repro.core.a2q import a2q_int_weights

    def check(node, boundary_ok):
        if isinstance(node, dict):
            if "v" in node and "t" in node:
                M, N = q.weight_bits, q.act_bits
                qi, _ = a2q_int_weights(
                    {"v": node["v"], "t": node["t"], "d": node["d"]}, M, q.acc_bits, N, False
                )
                l1 = np.abs(np.asarray(qi)).sum(axis=tuple(range(qi.ndim - 1)))
                assert (l1 <= l1_budget(q.acc_bits, N, False) + 1e-5).all()
            else:
                for v in node.values():
                    check(v, boundary_ok)

    check(p, True)


def test_synthetic_streams_deterministic():
    s = ImageClassStream(global_batch=4)
    a, b = s.batch(3), s.batch(3)
    np.testing.assert_array_equal(a["x"], b["x"])
    assert not np.array_equal(s.batch(3)["x"], s.batch(4)["x"])
