"""Radix prompt cache: partial-prefix hits, LRU/cost eviction, system-prompt
pinning, batched CoW, dirty-row block-table uploads, and the adoption-path
compile-count witness (the prefix-share prefill cliff stays dead)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models.lm import init_lm
from repro.nn.module import unbox
from repro.serve.engine import PagedServeEngine
from repro.serve.paged_cache import PagedKVCache


def _cache(slots=3, num_blocks=32, block_size=4, max_seq=64, **kw):
    arch = reduced(get_arch("yi-6b"))
    return PagedKVCache(arch, slots=slots, block_size=block_size,
                        max_seq=max_seq, num_blocks=num_blocks,
                        dtype=jnp.float32, **kw)


def _params(arch, seed=0):
    return unbox(init_lm(jax.random.PRNGKey(seed), arch))


# ---------------------------------------------------------------------------
# radix lookup: partial-prefix hits without whole-prompt registration
# ---------------------------------------------------------------------------


def test_radix_partial_prefix_hit_mid_block():
    """A query sharing only part of a cached prompt must still hit: full
    blocks via exact descent, plus a partial match *into* the next cached
    block (the adopter CoWs it at its divergence point)."""
    c = _cache()
    toks = np.arange(12, dtype=np.int32)
    c.allocate(0, 12)
    c.lens[0] = 12
    c.register_prefix(0, toks)
    donor_blocks = tuple(c._owned[0][:3])
    # diverges inside the second block (position 6): one full block exact,
    # two tokens partial into the next
    q = np.concatenate([toks[:6], [99, 98, 97]]).astype(np.int32)
    shared, blocks = c.lookup_prefix(q)
    assert shared == 6
    assert blocks == donor_blocks[:2]
    # diverges inside the first block: partial hit on the root's child
    q0 = np.concatenate([toks[:2], [77, 76, 75]]).astype(np.int32)
    shared0, blocks0 = c.lookup_prefix(q0)
    assert shared0 == 2 and blocks0 == donor_blocks[:1]
    c.release(0)


def test_radix_dedup_same_prefix_pins_once():
    """A second donor of an already-cached prefix must not grow the tree or
    double-pin blocks — nodes deduplicate by token-chunk key."""
    c = _cache()
    toks = np.arange(8, dtype=np.int32)
    c.allocate(0, 8)
    c.lens[0] = 8
    c.register_prefix(0, toks)
    size0, rc0 = c.registry_size(), c._entry_rc.copy()
    shared, blocks = c.lookup_prefix(np.concatenate([toks, [5]]).astype(np.int32))
    c.adopt_prefix(1, shared, blocks)
    c.lens[1] = 8
    c.register_prefix(1, toks)  # same prompt, second donor
    assert c.registry_size() == size0
    np.testing.assert_array_equal(c._entry_rc, rc0)
    c.release(0)
    c.release(1)


def test_radix_lookup_caps_below_full_prompt():
    c = _cache()
    toks = np.arange(8, dtype=np.int32)
    c.allocate(0, 8)
    c.lens[0] = 8
    c.register_prefix(0, toks)
    shared, _ = c.lookup_prefix(toks)
    assert shared == 7  # len - 1: prefill must keep one token for logits
    c.release(0)


# ---------------------------------------------------------------------------
# LRU/cost eviction (FIFO regression: hot entry survives a cold burst)
# ---------------------------------------------------------------------------


def test_lru_hot_entry_survives_cold_registration_burst():
    """Under the node cap, a burst of never-hit registrations must evict the
    cold entries among themselves and leave the frequently-hit chain
    servable (the seed's FIFO evicted by insertion order)."""
    c = _cache(max_prefix_entries=4)
    hot = np.arange(8, dtype=np.int32)
    c.allocate(0, 8)
    c.lens[0] = 8
    c.register_prefix(0, hot)
    c.release(0)
    probe = np.concatenate([hot, [1]]).astype(np.int32)
    for _ in range(5):  # make it hot
        assert c.lookup_prefix(probe)[0] == 8
    for i in range(6):  # cold burst at the cap
        cold = (np.arange(8) + 100 * (i + 1)).astype(np.int32)
        c.allocate(1, 8)
        c.lens[1] = 8
        c.register_prefix(1, cold)
        c.release(1)
    assert c.lookup_prefix(probe)[0] == 8, "hot chain was evicted by cold burst"
    assert c._radix_unpinned <= c.max_prefix_entries
    c.reclaim(c.num_blocks)
    assert c.free_blocks == c.num_blocks - 1


def test_eviction_is_leaf_only_and_cost_aware():
    """Eviction must never orphan a chain (parents outlive children) and
    must prefer the lowest hits x covered-tokens leaf."""
    c = _cache(max_prefix_entries=3)
    long = np.arange(12, dtype=np.int32)  # 3 nodes, at the cap
    c.allocate(0, 12)
    c.lens[0] = 12
    c.register_prefix(0, long)
    c.release(0)
    c.lookup_prefix(np.concatenate([long, [1]]).astype(np.int32))
    # inserting one cold block must evict the *leaf* of the long chain,
    # never its root/middle (which the survivors still descend through)
    cold = (np.arange(4) + 500).astype(np.int32)
    c.allocate(1, 4)
    c.lens[1] = 4
    c.register_prefix(1, cold)
    c.release(1)
    shared, _ = c.lookup_prefix(np.concatenate([long, [1]]).astype(np.int32))
    assert shared == 8  # first two nodes intact, leaf (tokens 8..11) evicted
    c.reclaim(c.num_blocks)
    assert c.free_blocks == c.num_blocks - 1


# ---------------------------------------------------------------------------
# system-prompt pinning
# ---------------------------------------------------------------------------


def test_pinned_chain_never_evicted():
    """Pinned nodes survive full reclaim and cold bursts, ride outside the
    node cap, and report zero reclaimable blocks."""
    c = _cache(max_prefix_entries=2)
    pin = (np.arange(12) + 7).astype(np.int32)
    c.allocate(0, 12)
    c.lens[0] = 12
    c.register_prefix(0, pin, pinned=True)
    c.release(0)
    assert c.registry_size() == 3 and c._radix_unpinned == 0
    assert c.reclaimable_blocks() == 0  # the gate must not budget pinned blocks
    probe = np.concatenate([pin, [3]]).astype(np.int32)
    c.reclaim(c.num_blocks)  # block pressure: evicts everything evictable
    assert c.lookup_prefix(probe)[0] == 12
    for i in range(5):  # cap-pressure burst
        cold = (np.arange(8) + 1000 * (i + 1)).astype(np.int32)
        c.allocate(1, 8)
        c.lens[1] = 8
        c.register_prefix(1, cold)
        c.release(1)
    assert c.lookup_prefix(probe)[0] == 12
    assert c._radix_unpinned <= c.max_prefix_entries


def test_pinning_promotes_existing_chain():
    c = _cache(max_prefix_entries=8)
    toks = np.arange(8, dtype=np.int32)
    c.allocate(0, 8)
    c.lens[0] = 8
    c.register_prefix(0, toks)
    assert c._radix_unpinned == 2
    c.register_prefix(0, toks, pinned=True)
    assert c._radix_unpinned == 0 and c.registry_size() == 2
    c.release(0)
    c.reclaim(c.num_blocks)
    assert c.lookup_prefix(np.concatenate([toks, [9]]).astype(np.int32))[0] == 8


# ---------------------------------------------------------------------------
# batched CoW: one pool-pytree rebuild per ensure_writable call
# ---------------------------------------------------------------------------


def test_multi_block_cow_fault_is_one_pool_rebuild():
    """A span covering several shared blocks must copy them all in a single
    batched dispatch (the seed rebuilt the whole pool pytree once per
    block), and the copies must carry the contents."""
    c = _cache()
    c.allocate(0, 12)
    c.lens[0] = 12
    # stamp per-block content so copies are distinguishable
    src = list(c._owned[0])
    for j, b in enumerate(src):
        c.pools = jax.tree_util.tree_map_with_path(
            lambda p, l, b=b, j=j: l.at[:, b].set(float(j + 1))
            if p[-1].key in ("kp", "vp") else l, c.pools
        )
    c.adopt_prefix(1, 10, tuple(src))
    assert c.pool_rebuilds == 0
    c.ensure_writable(1, 0, 12)  # faults all three shared blocks at once
    assert c.cow_copies == 3
    assert c.pool_rebuilds == 1, "CoW batch must cost ONE pool rebuild"
    leaf = c.pools["0"]["attn"]["kp"]
    for j, (old, new) in enumerate(zip(src, c._owned[1])):
        assert new != old
        np.testing.assert_array_equal(np.asarray(leaf[:, new]), np.asarray(leaf[:, old]))
    # refcounts fully private now
    assert all(c.refcounts[b] == 1 for b in src)
    assert all(c.refcounts[b] == 1 for b in c._owned[1])


# ---------------------------------------------------------------------------
# dirty-row block-table uploads
# ---------------------------------------------------------------------------


def test_bt_uploads_once_then_patches_dirty_rows():
    """After the first full upload, adoptions/allocations/CoW must patch
    only their dirty rows — one scatter per round, zero further full
    uploads — and the device table must always match the host table."""
    c = _cache()
    _ = c.bt()
    assert (c.bt_full_uploads, c.bt_row_patches) == (1, 0)
    _ = c.bt()  # clean: no new dispatch
    assert (c.bt_full_uploads, c.bt_row_patches) == (1, 0)
    # an admission round touching two slots: one patch, not two, not a full
    c.allocate(0, 8)
    c.lens[0] = 8
    c.register_prefix(0, np.arange(8, dtype=np.int32))
    shared, blocks = c.lookup_prefix(np.arange(9, dtype=np.int32))
    c.adopt_prefix(1, shared, blocks)
    c.allocate(1, 12)
    bt = c.bt()
    assert (c.bt_full_uploads, c.bt_row_patches) == (1, 1)
    np.testing.assert_array_equal(np.asarray(bt), c.tables)
    # a CoW fault dirties its row; next bt() is one more patch
    c.ensure_writable(1, 4, 8)
    bt = c.bt()
    assert (c.bt_full_uploads, c.bt_row_patches) == (1, 2)
    np.testing.assert_array_equal(np.asarray(bt), c.tables)
    c.release(0)
    c.release(1)
    bt = c.bt()
    assert (c.bt_full_uploads, c.bt_row_patches) == (1, 3)
    np.testing.assert_array_equal(np.asarray(bt), c.tables)


# ---------------------------------------------------------------------------
# the adoption-path compile cliff (engine-level witnesses)
# ---------------------------------------------------------------------------


def test_adoption_mints_no_new_prefill_compiles_per_prefix_length():
    """The tentpole regression: serve two cohorts whose *shared-prefix*
    lengths differ but whose prompt lengths match.  Chunk-aligned resume
    keeps every resumed chunk shape inside the set plain prefill already
    compiled, so the prefill jit cache must not grow on the second cohort
    (the seed minted one compile per distinct shared length)."""
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    rng = np.random.default_rng(3)

    def cohort(prefix_len):
        common = rng.integers(0, arch.vocab, (prefix_len,)).astype(np.int32)
        return [np.concatenate([common,
                                rng.integers(0, arch.vocab, (16 - prefix_len,)).astype(np.int32)])
                for _ in range(3)]

    e = PagedServeEngine(arch, params, batch=2, max_seq=64, block_size=4,
                         prefill_chunk=4, prefix_share=True)
    e.generate(cohort(9), max_new=3)
    assert e.cache.prefix_hits > 0
    n0 = e._prefill._cache_size()
    hits0 = e.cache.prefix_hits
    for plen in (6, 11, 13):  # distinct shared-prefix lengths, same prompt len
        e.generate(cohort(plen), max_new=3)
    assert e.cache.prefix_hits > hits0  # adoption kept happening...
    assert e._prefill._cache_size() == n0, (
        "adoption minted a prefill recompile per shared-prefix length"
    )


def test_pinned_prompt_engine_parity_and_first_request_hit():
    """--pin-prompt semantics through the engine: greedy output identical
    to plain paged, the *first* request already hits (no donor needed),
    and the pinned chain survives a full drain + reclaim."""
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    rng = np.random.default_rng(4)
    preamble = rng.integers(0, arch.vocab, (9,)).astype(np.int32)
    prompts = [np.concatenate([preamble, rng.integers(0, arch.vocab, (n,)).astype(np.int32)])
               for n in (3, 5, 2)]
    kw = dict(batch=2, max_seq=64, block_size=4, prefill_chunk=4)
    want = PagedServeEngine(arch, params, **kw).generate(prompts, max_new=4)
    e = PagedServeEngine(arch, params, prefix_share=True, **kw)
    pinned_tokens = e.pin_prompt(preamble)
    assert pinned_tokens == 8  # full blocks only (9 tokens at block_size 4)
    assert e.cache.free_blocks == e.cache.num_blocks - 1 - 2  # only the pins stay
    assert e.generate(prompts, max_new=4) == want
    assert e.cache.prefix_hits == len(prompts)  # every request adopted
    e.cache.reclaim(e.cache.num_blocks)
    rng2 = np.random.default_rng(5)
    more = [np.concatenate([preamble, rng2.integers(0, arch.vocab, (4,)).astype(np.int32)])]
    hits0 = e.cache.prefix_hits
    assert e.generate(more, max_new=4) == PagedServeEngine(
        arch, params, **kw).generate(more, max_new=4)
    assert e.cache.prefix_hits == hits0 + 1  # pin survived the reclaim
    with pytest.raises(ValueError):
        PagedServeEngine(arch, params, **kw).pin_prompt(preamble)  # needs prefix_share
