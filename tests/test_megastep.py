"""Decode megastep: N decode ticks fused into one jitted lax.scan dispatch.

The megastep is a pure dispatch fusion of the per-tick paged decode loop —
position advance, EOS and max_new finish masking run on device, finished
rows coast writing into the trash block — so greedy token parity against the
per-tick path is the gate, including mid-window EOS, prefix-share adopters,
recurrent stacks, and the spec engine's fallback rounds.  The dispatch
counter is the scoreboard: ~1/N decode dispatches per generated token.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models.lm import apply_lm, init_cache, init_lm
from repro.nn.module import unbox
from repro.serve.engine import PagedServeEngine, Request
from repro.serve.spec import SpecServeEngine

KEY = jax.random.PRNGKey(0)
KW = dict(batch=2, max_seq=64, block_size=4, prefill_chunk=4)


def _params(arch):
    return unbox(init_lm(KEY, arch))


def _greedy_reference(arch, params, prompt, max_new, max_seq=64):
    """Step-by-step single-sequence decode as the oracle."""
    cache = init_cache(arch, 1, max_seq, dtype=jnp.dtype(arch.compute_dtype))
    logits = None
    for pos, t in enumerate(prompt):
        logits, cache, _ = apply_lm(
            params, arch, tokens=jnp.asarray([[t]], jnp.int32), cache=cache,
            start_pos=jnp.asarray(pos, jnp.int32),
        )
    out = []
    pos = len(prompt)
    for _ in range(max_new):
        nxt = int(jnp.argmax(logits[0, 0]))
        out.append(nxt)
        logits, cache, _ = apply_lm(
            params, arch, tokens=jnp.asarray([[nxt]], jnp.int32), cache=cache,
            start_pos=jnp.asarray(pos, jnp.int32),
        )
        pos += 1
    return out


def _prompts(arch, seed=0, lens=(5, 3, 9, 2)):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, arch.vocab, (n,)).astype(np.int32) for n in lens]


@pytest.mark.parametrize("steps", [2, 4, 8])
def test_megastep_matches_per_tick_paged(steps):
    """Mixed prompt lengths, more requests than slots (slot recycling mid
    window sequence), max_new=5 deliberately not a multiple of any window
    size so the drain tail exercises partially-active windows."""
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    prompts = _prompts(arch)
    tick = PagedServeEngine(arch, params, **KW)
    want = tick.generate(prompts, max_new=5)
    mega = PagedServeEngine(arch, params, decode_steps=steps, **KW)
    assert mega.generate(prompts, max_new=5) == want
    assert mega.cache.free_blocks == mega.cache.num_blocks - 1
    tp = mega.throughput()
    assert 0 < tp["dispatches_per_token"] < 1
    assert mega.stats["decode_tokens"] == tick.stats["decode_tokens"]


def test_megastep_eos_mid_window_parity_and_early_release():
    """A row whose EOS lands mid window must stop exactly where the per-tick
    path stops (its later in-window samples are masked, never recorded) and
    release its slot/blocks at window replay, not at max_new."""
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    prompts = _prompts(arch, seed=1, lens=(5, 7, 4))
    probe = PagedServeEngine(arch, params, **KW)
    full = probe.generate(prompts, max_new=6)
    eos = full[0][2]  # request 0 provably emits this mid-stream (greedy)
    tick = PagedServeEngine(arch, params, eos_id=eos, **KW)
    want = tick.generate(prompts, max_new=6)
    mega = PagedServeEngine(arch, params, eos_id=eos, decode_steps=8, **KW)
    got = mega.generate(prompts, max_new=6)
    assert got == want
    assert got[0] == full[0][: full[0].index(eos) + 1]
    assert any(len(o) < 6 for o in got)  # early termination really happened
    assert mega.cache.free_blocks == mega.cache.num_blocks - 1


def test_megastep_prefix_share_adopters_match():
    """Adopted (refcounted, possibly shared) blocks inside a megastep window:
    the entry preflight must CoW the whole window span, so adopters decode
    identically to both the per-tick sharing engine and plain paged."""
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    rng = np.random.default_rng(2)
    common = rng.integers(0, arch.vocab, (9,)).astype(np.int32)
    prompts = [np.concatenate([common, rng.integers(0, arch.vocab, (n,)).astype(np.int32)])
               for n in (4, 2, 6)]
    plain = PagedServeEngine(arch, params, **KW)
    want = plain.generate(prompts, max_new=5)
    tick_px = PagedServeEngine(arch, params, prefix_share=True, **KW)
    assert tick_px.generate(prompts, max_new=5) == want
    mega_px = PagedServeEngine(arch, params, prefix_share=True, decode_steps=4, **KW)
    assert mega_px.generate(prompts, max_new=5) == want
    assert mega_px.cache.prefix_hits > 0  # sharing actually engaged


def test_megastep_recurrent_arch_matches_reference():
    """Recurrent state is not block-paged, so coasting rows advance garbage
    state — harmless (finished rows are never read; reset_slot re-zeroes on
    admission).  Active rows must still match the stepwise oracle."""
    arch = reduced(get_arch("rwkv6-7b"))
    params = _params(arch)
    prompts = _prompts(arch, seed=3, lens=(5, 3, 7))
    mega = PagedServeEngine(arch, params, decode_steps=4, **KW)
    got = mega.generate(prompts, max_new=5)
    for p, o in zip(prompts, got):
        assert o == _greedy_reference(arch, params, list(p), 5)


def test_megastep_spec_engine_fallback_composes():
    """A spec engine whose acceptance gate never opens must fall back through
    the megastep (not raw per-tick decode) and stay token-identical."""
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    prompts = _prompts(arch, seed=4, lens=(5, 6))
    plain = PagedServeEngine(arch, params, **KW)
    want = plain.generate(prompts, max_new=6)
    spec = SpecServeEngine(
        arch, params, spec_k=3, min_accept=2.0, probe_interval=10**6,
        decode_steps=4, **KW,
    )
    assert spec.generate(prompts, max_new=6) == want
    assert spec.spec_stats["rounds"] == 0  # gate never opened
    assert spec.spec_stats["fallback_rounds"] > 0
    assert 0 < spec.throughput()["dispatches_per_token"] < 1  # megastep ran


def test_megastep_dispatch_accounting_exact():
    """One request, max_new=9, N=4: the first token is booked under prefill,
    the remaining 8 decode tokens fit exactly two fused windows."""
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    mega = PagedServeEngine(arch, params, decode_steps=4, **KW)
    out = mega.generate([np.arange(6, dtype=np.int32)], max_new=9)
    assert len(out[0]) == 9
    assert mega.stats["decode_tokens"] == 8
    assert mega.stats["decode_dispatches"] == 2
    assert mega.throughput()["dispatches_per_token"] == 0.25


def test_megastep_kv_int8_matches_per_tick_int8():
    """The fused window reads/writes the same int8 block pools the per-tick
    engine does — identical codes in, identical greedy tokens out."""
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    prompts = _prompts(arch, seed=5, lens=(6, 4))
    tick = PagedServeEngine(arch, params, kv_quant=True, **KW)
    want = tick.generate(prompts, max_new=5)
    mega = PagedServeEngine(arch, params, kv_quant=True, decode_steps=4, **KW)
    assert mega.generate(prompts, max_new=5) == want


def test_megastep_per_request_eos_override():
    """Per-request eos_id beats the engine default inside the device mask
    (the eos array is per-row, not a scalar)."""
    arch = reduced(get_arch("yi-6b"))
    params = _params(arch)
    prompts = _prompts(arch, seed=6, lens=(5, 5))
    probe = PagedServeEngine(arch, params, **KW)
    full = probe.generate(prompts, max_new=6)
    eos0 = full[0][1]
    mega = PagedServeEngine(arch, params, decode_steps=8, **KW)
    reqs = [
        Request(uid=0, prompt=prompts[0], max_new=6, eos_id=eos0),
        Request(uid=1, prompt=prompts[1], max_new=6, eos_id=-1),  # never fires
    ]
    for r in reqs:
        mega.submit(r)
    while not mega.sched.idle():
        mega.step()
    assert reqs[0].generated == full[0][: full[0].index(eos0) + 1]
    assert reqs[1].generated == full[1]
