"""Serve a small model with batched requests through the continuous-batching
engine, with A2Q int8 deployment.

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import init_lm
from repro.nn.module import unbox
from repro.serve.engine import ServeEngine, deploy_params


def main():
    arch = reduced(get_arch("h2o-danube-1.8b"))  # SWA arch: ring KV caches
    params = unbox(init_lm(jax.random.PRNGKey(0), arch))
    deployed = deploy_params(params, arch.quant)
    print(f"arch {arch.name} (reduced), SWA window={arch.stacks[0].attn.window}, "
          f"A2Q deployed to int8 @ P={arch.quant.acc_bits}")

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, arch.vocab, (n,)).astype(np.int32) for n in (6, 9, 4, 7, 5)]
    engine = ServeEngine(arch, deployed, batch=3, max_seq=64)
    t0 = time.perf_counter()
    outs = engine.generate(prompts, max_new=8)
    dt = time.perf_counter() - t0
    for i, (p, o) in enumerate(zip(prompts, outs)):
        print(f"req {i}: prompt[{len(p)}] -> {o}")
    total = sum(map(len, outs))
    print(f"{total} tokens, {total/dt:.1f} tok/s, 5 requests over 3 slots "
          f"(continuous batching)")


if __name__ == "__main__":
    main()
