"""End-to-end driver: train the full smollm-135m config (135M params, A2Q
hidden layers targeting 16-bit accumulators) for a few hundred steps on the
synthetic token stream, with checkpointing and resume.

    PYTHONPATH=src python examples/train_lm_a2q.py --steps 300
    PYTHONPATH=src python examples/train_lm_a2q.py --steps 300 --scale 0.25  # faster CPU run

The same entrypoint on a TPU fleet builds the production mesh (this is just
``launch/train.py`` pre-configured); on CPU one step of the full 135M model is
slow, so ``--scale`` optionally narrows the network (same depth/structure).
After training, verifies the A2Q invariant over every layer: integer-weight
l1 norms within the Eq. 15 budget for P=16.

Multi-device gradient compression (A2Q's accumulator argument applied to the
cross-device wire): on a mesh, put the data-parallel gradient all-reduce on
an int8 wire with error feedback by giving the Runtime a GradCompressConfig
and carrying the residual pair in the train state::

    from repro.dist.collectives import GradCompressConfig, resolve_grad_compress
    from repro.dist.sharding import ShardingRules, param_specs
    from repro.train.state import init_grad_err

    mesh  = jax.make_mesh((8,), ("data",))
    rules = ShardingRules.default(mesh, arch)
    gc    = GradCompressConfig(bits=8, scale_axis="column")   # A2Q+-style scales
    rt    = Runtime(mesh=mesh, rules=rules, grad_compress=gc)
    step_fn = build_train_step(arch, opt, rt, lr_schedule=sched)

    pspecs = param_specs(jax.eval_shape(lambda: init_lm(key, arch)), mesh, rules)
    axis   = resolve_grad_compress(gc, mesh).axis
    state["grad_err"] = init_grad_err(params, mesh.shape[axis], pspecs=pspecs, axis=axis)

(or just pass ``--grad-compress-bits 8`` to ``repro.launch.train``).  The
20-step parity test in tests/test_sharding.py shows the compressed run
tracking fp32 within ~0.05 loss; ``launch/dryrun.py`` records the measured
wire-byte savings per train cell.
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.configs.base import AttnConfig, StackConfig
from repro.core.a2q import a2q_int_weights
from repro.core.bounds import l1_budget
from repro.data.synthetic import TokenStream
from repro.models import Runtime, init_lm
from repro.models.steps import build_train_step
from repro.nn.module import unbox
from repro.optim.optimizers import adamw
from repro.optim.schedules import cosine_with_warmup
from repro.train.trainer import Trainer


def scaled_smollm(scale: float):
    arch = get_arch("smollm-135m")
    if scale >= 1.0:
        return arch
    s = arch.stacks[0]
    heads = max(int(s.attn.heads * scale) // 3 * 3, 3)  # keep kv ratio 3:1
    a = dataclasses.replace(s.attn, heads=heads, kv_heads=heads // 3)
    return dataclasses.replace(
        arch,
        d_model=heads * s.attn.head_dim,
        vocab=max(int(arch.vocab * scale), 1024),
        stacks=(dataclasses.replace(s, attn=a, d_ff=max(int(s.d_ff * scale) // 8 * 8, 64)),),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--ckpt-dir", default="/tmp/a2q_lm_ckpt")
    args = ap.parse_args()

    arch = scaled_smollm(args.scale)
    n_params_est = arch.n_layers * (4 * arch.d_model**2 + 3 * arch.d_model * arch.stacks[0].d_ff)
    print(f"arch: {arch.name} x{args.scale} d={arch.d_model} L={arch.n_layers} "
          f"(~{(n_params_est + arch.vocab*arch.d_model)/1e6:.0f}M params), "
          f"A2Q P={arch.quant.acc_bits}")

    params = unbox(init_lm(jax.random.PRNGKey(0), arch))
    opt = adamw(weight_decay=1e-5)
    state = {"params": params, "opt_state": opt.init(params), "step": jnp.zeros((), jnp.int32)}
    sched = cosine_with_warmup(3e-4, warmup=args.steps // 10, total=args.steps)
    step_fn = build_train_step(arch, opt, Runtime(), lr_schedule=sched)
    stream = TokenStream(vocab=arch.vocab, seq_len=args.seq, global_batch=args.batch)

    trainer = Trainer(step_fn, stream.batch, ckpt_dir=args.ckpt_dir, ckpt_every=100, log_every=20)
    state, start = trainer.maybe_restore(state)
    res = trainer.run(state, args.steps, start_step=start)
    print(f"loss: {res.history[0]['loss']:.3f} -> {res.history[-1]['loss']:.3f}")

    # verify the guarantee over the trained model
    q = arch.quant
    budget = l1_budget(q.acc_bits, q.act_bits, True)
    worst = 0.0
    n_layers = 0

    def walk(node):
        nonlocal worst, n_layers
        if isinstance(node, dict):
            if "v" in node and "t" in node and node["v"].ndim >= 2:
                v, t, d = node["v"], node["t"], node["d"]
                lead = v.ndim - 2
                fn = lambda vv, tt, dd: a2q_int_weights(
                    {"v": vv, "t": tt, "d": dd}, q.weight_bits, q.acc_bits, q.act_bits, True
                )[0]
                for _ in range(lead):
                    fn = jax.vmap(fn)
                qi = np.asarray(fn(v, t, d))
                l1 = np.abs(qi).sum(axis=-2)
                worst = max(worst, float(l1.max()))
                n_layers += 1
            else:
                for vv in node.values():
                    walk(vv)

    walk(res.state["params"])
    ok = worst <= budget + 1e-6
    print(f"A2Q invariant over {n_layers} trained layers: worst |w|_1 = {worst:.2f} "
          f"<= budget {budget:.2f}: {'OK' if ok else 'VIOLATED'}")
    assert ok


if __name__ == "__main__":
    main()
