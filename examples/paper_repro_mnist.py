"""Paper Appendix A reproduction, end-to-end: the 1-layer binary-MNIST
classifier (K=784, M=8, N=1) across accumulator widths.

    PYTHONPATH=src python examples/paper_repro_mnist.py

Trains the baseline QAT classifier, sweeps P downward showing wraparound and
saturation degrade while A2Q (retrained at each target P) holds — the Fig. 2
story on the synthetic binary-MNIST stand-in.
"""

from benchmarks.fig2_overflow import run

if __name__ == "__main__":
    out = run(steps=60, reorder=True)
    print()
    print(f"data-type bound: P = {out['bound_P']} bits")
    print(f"baseline (32b accumulator) accuracy: {out['baseline_acc']:.3f}")
    print(f"wraparound collapses below bound: {out['wrap_collapses']}")
    print(f"A2Q holds accuracy at every tested P: {out['a2q_holds']}")
    print(f"saturation order-dependence (App. A.1): "
          f"max spread {out['reorder_audit']['max_spread']} logits units")
