"""Quickstart: the A2Q guarantee in 40 lines.

    PYTHONPATH=src python examples/quickstart.py

Creates one A2Q-quantized layer for a 12-bit accumulator, trains nothing, and
demonstrates the paper's core property: the integer weights satisfy the Eq. 15
l1 budget, so a 12-bit accumulator provably never overflows — wraparound,
saturation, and ideal wide accumulation all agree, in every MAC order.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import QuantConfig
from repro.core.bounds import l1_budget, min_accumulator_bits_data_type
from repro.core.integer import accumulate_dot, mac_order_audit
from repro.nn.linear import deploy_linear, init_linear

K, C_OUT, P = 512, 16, 12
q = QuantConfig(mode="a2q", weight_bits=8, act_bits=8, acc_bits=P)

params = init_linear(jax.random.PRNGKey(0), K, C_OUT, q, input_signed=False)
from repro.nn.module import unbox

deployed = deploy_linear(unbox(params), q, input_signed=False)
w_int = np.asarray(deployed["q8"], np.int64)  # (K, C_OUT) integer weights

budget = l1_budget(P, q.act_bits, signed_input=False)
l1 = np.abs(w_int).sum(0)
print(f"target accumulator: {P} bits  (data-type bound would need "
      f"{min_accumulator_bits_data_type(K, 8, 8, False)} bits)")
print(f"per-channel |w|_1: max {l1.max()}  budget {budget:.2f}  ->  "
      f"{'WITHIN BUDGET' if (l1 <= budget).all() else 'VIOLATION'}")
print(f"weight sparsity from the l1 constraint: {(w_int == 0).mean():.1%}")

# worst-case 8-bit unsigned inputs, every accumulator semantics, random orders
x = np.random.default_rng(0).integers(0, 256, (64, K))
exact = accumulate_dot(x, w_int, 64, "exact")
wrap = accumulate_dot(x, w_int, P, "wrap")
audit = mac_order_audit(x, w_int, P, n_orders=8)
print(f"exact == {P}-bit wraparound: {bool((exact == wrap).all())}")
print(f"order-invariant under {P}-bit saturation: {audit['order_invariant']}, "
      f"matches exact: {audit['matches_exact']}")
