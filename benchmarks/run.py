"""Benchmark aggregator: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Prints each module's CSV, then a claims summary asserting the paper's
*relative* claims hold on the synthetic stand-in data (DESIGN.md Sec. 8):

  Fig 2: wraparound collapses below the bound; A2Q holds accuracy; overflow
         rate grows as P shrinks; A2Q overflow events == 0.
  Fig 3: the weight-norm bound is always at least as tight as the data-type
         bound.
  Fig 4: A2Q extends the accumulator Pareto frontier left of what baseline
         QAT can reach, and dominates it.
  Fig 5: sparsity rises monotonically as P falls.
  Fig 6: LUT ordering fixed32 >= dtype-bound >= PTM; A2Q dominates.

``--json [PATH]`` additionally writes a ``BENCH_<date>.json`` perf snapshot
(serve throughput/latency percentiles, kernel VMEM claims + oracle flags, KV
bytes-per-token fp32 vs int8, the claims table) so the perf trajectory of the
repo is recorded PR over PR; CI uploads it as a build artifact.
"""

from __future__ import annotations

import argparse
import datetime
import json
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="fewer training steps")
    ap.add_argument("--json-out", default=None)
    ap.add_argument("--json", nargs="?", const="auto", default=None,
                    help="write a BENCH_<date>.json perf snapshot (optionally to PATH)")
    args = ap.parse_args(argv)
    steps = 25 if args.fast else 40
    fig2_steps = 40 if args.fast else 60

    from benchmarks import (
        bounds_table,
        fig2_overflow,
        fig4_pareto,
        fig5_sparsity,
        fig6_resources,
        kernels_bench,
        serve_bench,
    )

    t0 = time.time()
    results = {}
    print("=" * 72)
    print("fig2_overflow (paper Fig. 2 / App. A)")
    print("=" * 72)
    results["fig2"] = fig2_overflow.run(steps=fig2_steps, reorder=True)

    print("=" * 72)
    print("bounds_table (paper Fig. 3)")
    print("=" * 72)
    results["fig3"] = bounds_table.run(samples=300 if args.fast else 1000)

    print("=" * 72)
    print("fig4_pareto (paper Fig. 4)")
    print("=" * 72)
    results["fig4"] = fig4_pareto.run(steps=steps)

    print("=" * 72)
    print("fig5_sparsity (paper Fig. 5)")
    print("=" * 72)
    results["fig5"] = fig5_sparsity.run(steps=steps)

    print("=" * 72)
    print("fig6_resources (paper Fig. 6/7)")
    print("=" * 72)
    results["fig6"] = fig6_resources.run(steps=steps)

    print("=" * 72)
    print("kernel microbenches")
    print("=" * 72)
    results["kernels"] = kernels_bench.run()

    print("=" * 72)
    print("serving bench (paged vs contiguous engines)")
    print("=" * 72)
    # max_new=8 keeps the decode phase long enough that the speculative
    # engine's dispatch-count win (2 per round vs k+1 ticks) is measured
    # above timing noise — at max_new=4 the identical-prefill phase
    # dominates and the end-to-end ratio sits at the claim threshold
    results["serve"] = serve_bench.run(requests=4 if args.fast else 8, max_new=8)

    print("=" * 72)
    print("serving cluster bench (routed replicas, failover drill)")
    print("=" * 72)
    results["cluster"] = serve_bench.run_cluster(requests=8 if args.fast else 10)

    claims = {
        "serve_int8_kv_bytes_3x_plus": results["serve"]["kv_bytes_ratio"] >= 3.0,
        # speculative decoding: measured acceptance > 0; decode tok/s at
        # least plain paged decode (the structural win — 2 dispatches per
        # round vs k+1 ticks — measured with ~1.3-2x margin on CPU); and
        # end-to-end tok/s not regressed (>= 0.9: prefill is identical and
        # dominates the mixed workload, so the end-to-end ratio carries
        # wall-clock noise a shared CI runner can push a few percent either
        # way — the committed BENCH_*.json baseline records the actual
        # measured >= 1.2x)
        "serve_spec_acceptance_positive": results["serve"].get("spec_acceptance_rate", 0) > 0,
        "serve_spec_decode_at_least_paged": results["serve"].get("spec_decode_speedup", 0) >= 1.0,
        "serve_spec_tok_s_not_regressed": results["serve"].get("spec_throughput_speedup", 0) >= 0.9,
        # prefix sharing: the shared cohort's prompt tokens really came from
        # shared blocks (radix prompt cache: adoption skipped recompute) AND
        # the sharing engine's prefill-dominated latency (TTFT p50) stays
        # within 1.2x of plain paged — the PR-6 cliff (a ~13x regression
        # from per-shared-length prefill recompiles + per-block CoW
        # dispatches) must never come back
        "serve_prefix_share_hit_tokens": results["serve"]["prefix_hit_tokens"] > 0,
        "serve_prefix_share_prefill_ratio": results["serve"]["prefix_share_prefill_ratio"] <= 1.2,
        "kernel_oracles_ok": results["kernels"]["all_ok"],
        "fig2_wrap_collapses": results["fig2"]["wrap_collapses"],
        "fig2_a2q_holds_accuracy": results["fig2"]["a2q_holds"],
        "fig2_a2q_beats_wrap_at_low_P": results["fig2"]["a2q_beats_wrap_at_low_P"],
        "fig2_reorder_nondeterministic_under_saturation": not results["fig2"]["reorder_audit"]["order_invariant"],
        "fig3_weight_bound_tighter": results["fig3"]["weight_bound_always_tighter"],
        "fig4_a2q_extends_pareto": results["fig4"]["a2q_extends_pareto_left"],
        "fig4_a2q_dominates": results["fig4"]["a2q_dominates"],
        "fig5_sparsity_monotone": results["fig5"]["sparsity_monotone_up"],
        "fig6_bound_ordering": results["fig6"]["bound_ordering_ok"],
        "fig6_a2q_dominates_fixed32": results["fig6"]["a2q_dominates_fixed32"],
        "serve_paged_prefill_faster": results["serve"]["prefill_speedup"] > 1.0,
        # the decode megastep (N fused ticks per jitted dispatch): each
        # generated token costs well under one dispatch (~1/N + admission
        # tail windows), and the paged engine's steady-state decode is no
        # longer behind the contiguous baseline it replaced (the per-tick
        # engine paid per-token host work — CoW preflight, lens upload,
        # device_get — the contiguous loop never did; 0.95 leaves wall-clock
        # noise room on shared runners, the BENCH_*.json records the margin)
        "serve_decode_dispatches_per_token": results["serve"]["megastep_dispatches_per_token"] <= 0.2,
        "serve_paged_decode_not_slower": results["serve"]["paged_decode_ratio"] >= 0.95,
        # int8 KV composed with the megastep: the fused dispatch count must
        # carry over to quantized pools, and fusing must not cost decode
        # throughput vs the per-tick int8 engine (0.95 = wall-clock noise
        # floor on shared runners; the BENCH_*.json records the margin)
        "serve_int8_megastep_dispatches_per_token":
            results["serve"]["int8_kv_megastep_dispatches_per_token"] <= 0.2,
        "serve_int8_megastep_decode_not_slower":
            results["serve"]["int8_kv_megastep_decode_ratio"] >= 0.95,
        # int8-out chaining: deployed layers pay ZERO standalone act-quant
        # dispatches (every activation quantizer folds into the W8A8 kernel:
        # epilogue requant on chained edges, prologue quant at chain breaks),
        # and the fold must not cost decode throughput vs the unchained
        # integer fast path (0.95 = wall-clock noise floor on shared runners)
        "serve_int_chain_requant_dispatches":
            results["serve"]["int_chain_requant_dispatches"] == 0,
        "serve_int_chain_decode_not_slower":
            results["serve"]["int_chain_decode_ratio"] >= 0.95,
        # observability: span tracing live on the megastep hot path costs at
        # most 5% decode throughput vs the untraced twin (the disabled path
        # is a null-span identity return; the enabled path is one clock read
        # + tuple append per span), and the accumulator-headroom telemetry
        # confirms the deployed integer engine serves strictly inside the
        # A2Q guarantee: max static L1 utilization < 1.0, zero violations
        "serve_obs_overhead": results["serve"]["obs_overhead"] <= 1.05,
        "serve_acc_headroom_max": results["serve"]["acc_headroom_util_max"] < 1.0,
        "serve_acc_headroom_violations":
            results["serve"]["acc_headroom_violations"] == 0,
        # disaggregated cluster: two routed replicas reach >= 1.6x one
        # replica's busy-time capacity (routing balance), and a mid-wave
        # replica kill completes every request token-exactly via requeue
        "serve_cluster_scaling": results["cluster"]["cluster_scaling"] >= 1.6,
        "serve_cluster_requeue_complete":
            results["cluster"]["cluster_requeue_complete"] == 1.0,
    }
    print("=" * 72)
    print("PAPER CLAIMS SUMMARY")
    print("=" * 72)
    failed = []
    for k, v in claims.items():
        print(f"{'PASS' if v else 'FAIL'}  {k}")
        if not v:
            failed.append(k)
    print(f"total {time.time()-t0:.0f}s")
    if args.json_out:
        slim = {k: {kk: vv for kk, vv in v.items() if kk != "rows"} for k, v in results.items()}
        with open(args.json_out, "w") as f:
            json.dump({"claims": claims, "results": slim}, f, indent=1, default=str)
    if args.json:
        date = datetime.date.today().isoformat()
        path = f"BENCH_{date}.json" if args.json == "auto" else args.json
        snapshot = {
            "date": date,
            "fast": args.fast,
            "wall_s": round(time.time() - t0, 1),
            # the perf trajectory: serve throughput/latency + KV bytes/token
            # (fp32 vs int8 blocks) and the kernel VMEM/oracle rows
            "serve": results["serve"],
            "cluster": results["cluster"],
            "kernels": results["kernels"]["rows"],
            # the observability block: tracing overhead on the megastep hot
            # path and the accumulator-headroom guarantee as measured gauges
            "obs": {
                "overhead": results["serve"]["obs_overhead"],
                "trace_events": results["serve"]["obs_trace_events"],
                "acc_headroom_util_max": results["serve"]["acc_headroom_util_max"],
                "acc_headroom_observed_frac_max":
                    results["serve"]["acc_headroom_observed_frac_max"],
                "acc_headroom_violations": results["serve"]["acc_headroom_violations"],
                "acc_headroom_layers": results["serve"]["acc_headroom_layers"],
            },
            "claims": claims,
        }
        with open(path, "w") as f:
            json.dump(snapshot, f, indent=1, default=str)
        print(f"wrote perf snapshot {path}")
    if failed:
        print(f"FAILED claims: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
