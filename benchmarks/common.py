"""Shared helpers for the paper-table benchmarks.

Each fig*.py module reproduces one paper artifact on synthetic data (DESIGN.md
Sec. 8) at a reduced-but-faithful scale, prints a CSV, and returns a dict of
headline numbers that ``run.py`` aggregates and asserts the paper's *relative*
claims on (orderings/monotonicity, not absolute accuracies).
"""

from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import QuantConfig
from repro.optim.optimizers import adamw


def train_classifier(
    init_fn, apply_fn, q: QuantConfig, stream, steps: int = 60, lr: float = 5e-3,
    seed: int = 0, penalty_fn=None, reg_lambda: float = 1e-3, init_params=None,
    optimizer: str = "adamw",
):
    """Generic CE training loop for the vision/classifier benchmarks.

    ``init_params``: start from these (e.g. requantized from a pre-trained
    float model, the paper's App. B protocol) instead of a fresh init.
    """
    key = jax.random.PRNGKey(seed)
    from repro.nn.module import unbox
    from repro.optim.optimizers import sgdm

    p = init_params if init_params is not None else unbox(init_fn(key, q))
    opt = adamw() if optimizer == "adamw" else sgdm(momentum=0.9)
    state = opt.init(p)

    def loss_fn(p, x, y):
        logits = apply_fn(p, x, q)
        onehot = jax.nn.one_hot(y, logits.shape[-1])
        ce = -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))
        if penalty_fn is not None:
            ce = ce + reg_lambda * penalty_fn(p, q)
        return ce

    @jax.jit
    def step(p, state, x, y):
        g = jax.grad(loss_fn)(p, x, y)
        return opt.update(g, state, p, lr)

    for i in range(steps):
        b = stream.batch(i)
        p, state = step(p, state, jnp.asarray(b["x"]), jnp.asarray(b["y"]))
    return p


def accuracy(apply_fn, p, q, stream, batch_idx: int = 10_000) -> float:
    b = stream.batch(batch_idx)
    logits = apply_fn(p, jnp.asarray(b["x"]), q)
    return float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(b["y"])))


def time_call(fn, *args, repeats: int = 3) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats * 1e6  # us


def requantized_init(init_fn, float_params, q: QuantConfig, seed: int = 0):
    """Fresh quantized tree initialized from trained float weights (paper
    App. B protocol: all QNNs start from converged float counterparts)."""
    from repro.models.vision import requantize_from_float
    from repro.nn.module import unbox

    template = unbox(init_fn(jax.random.PRNGKey(seed), q))
    return requantize_from_float(template, float_params, q)
