"""Paper Fig. 6/7: LUT-utilization vs accuracy under four HW-SW co-design
settings (Sec. 5.3), using the analytical FINN cost model:

  1. baseline QAT, fixed 32-bit accumulators,
  2. baseline QAT, per-layer P from the data-type bound (Eq. 8),
  3. baseline QAT, post-training minimization of P from weights (Eq. 13),
  4. A2Q trained at target P.

Plus the Fig. 7 compute/memory breakdown for the A2Q frontier.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import accuracy, requantized_init, train_classifier
from repro.configs.base import QuantConfig
from repro.core.a2q import a2q_int_weights
from repro.core.bounds import (
    min_accumulator_bits_data_type,
    min_accumulator_bits_weights,
)
from repro.core.lut import LayerGeometry, model_luts
from repro.core.quantizers import weight_qat_int
from repro.data.synthetic import ImageClassStream
from repro.models.vision import (
    apply_mobilenet_v1,
    init_mobilenet_v1,
    layer_geometries,
    vision_penalty,
)


def _geoms_with_P(params, q, policy: str):
    """Per-layer geometries with the accumulator width set by the policy."""
    geoms = layer_geometries(params, q)
    out = []
    for g in geoms:
        if policy == "fixed32":
            P = 32
        elif policy == "dtype":
            P = min_accumulator_bits_data_type(g.k, q.act_bits, q.weight_bits, False)
        elif policy in ("ptm", "a2q"):
            P = g.acc_bits  # filled by caller per layer below
        out.append(LayerGeometry(**{**g.__dict__, "acc_bits": P}))
    return out


def _ptm_geoms(params, q):
    """Post-training minimization: per-layer P from the trained weights' l1."""
    geoms = []

    def walk(node):
        if isinstance(node, dict):
            if "w" in node and "wq" in node:
                qi, _ = weight_qat_int({"log2_scale": node["wq"]["log2_scale"]}, node["w"], q.weight_bits)
                a = np.asarray(qi)
                a2 = a.reshape(-1, a.shape[-1])
                l1max = float(np.abs(a2).sum(0).max())
                P = min_accumulator_bits_weights(l1max, q.act_bits, False)
                geoms.append((a2.shape[0], a2.shape[1], P, float((a2 == 0).mean())))
            else:
                for v in node.values():
                    walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)

    walk(params)
    return geoms


def run(steps: int = 40) -> dict:
    stream = ImageClassStream(global_batch=64, seed=0)
    init = lambda k, q: init_mobilenet_v1(k, q, width=0.25)

    # App. B: every QNN starts from a converged float model
    p_float = train_classifier(init, apply_mobilenet_v1, QuantConfig(mode="none"),
                               stream, steps=steps)

    rows = []
    print("setting,bits,P_policy,luts,acc")
    for bits in (5, 6, 8):  # the paper's 5-8 bit design space (Sec. 5.1)
        qb = QuantConfig(mode="qat", weight_bits=bits, act_bits=bits, acc_bits=32)
        pb = train_classifier(init, apply_mobilenet_v1, qb, stream, steps=steps,
                              init_params=requantized_init(init, p_float, qb))
        acc_b = accuracy(apply_mobilenet_v1, pb, qb, stream)

        # setting 1: fixed 32b
        luts = model_luts(_geoms_with_P(pb, qb, "fixed32"))["total"]
        rows.append(dict(setting="fixed32", bits=bits, luts=luts, acc=acc_b))
        print(f"fixed32,{bits},32,{luts:.0f},{acc_b:.4f}")

        # setting 2: per-layer data-type bound
        luts = model_luts(_geoms_with_P(pb, qb, "dtype"))["total"]
        rows.append(dict(setting="dtype", bits=bits, luts=luts, acc=acc_b))
        print(f"dtype,{bits},bound,{luts:.0f},{acc_b:.4f}")

        # setting 3: post-training minimization from trained weights (Eq. 13)
        ptm = _ptm_geoms(pb, qb)
        geoms = [
            LayerGeometry(k=k, c_out=c, macs=k * c, weight_bits=bits, input_bits=bits,
                          output_bits=bits, acc_bits=P, sparsity=sp)
            for k, c, P, sp in ptm
        ]
        luts = model_luts(geoms)["total"]
        rows.append(dict(setting="ptm", bits=bits, luts=luts, acc=acc_b))
        print(f"ptm,{bits},weights,{luts:.0f},{acc_b:.4f}")

        # setting 4: A2Q at reduced target P
        bound = min_accumulator_bits_data_type(256, bits, bits, False)
        for P in (bound - 2, bound - 4):
            qa = QuantConfig(mode="a2q", weight_bits=bits, act_bits=bits, acc_bits=P)
            pa = train_classifier(init, apply_mobilenet_v1, qa, stream, steps=steps,
                                  penalty_fn=vision_penalty, optimizer="sgdm", lr=1e-2,
                                  init_params=requantized_init(init, p_float, qa))
            acc_a = accuracy(apply_mobilenet_v1, pa, qa, stream)
            ga = layer_geometries(pa, qa)
            luts = model_luts(ga)["total"]
            breakdown = model_luts(ga)
            rows.append(dict(setting="a2q", bits=bits, luts=luts, acc=acc_a,
                             compute=breakdown["compute"],
                             mem=breakdown["weight_mem"] + breakdown["threshold_mem"]))
            print(f"a2q,{bits},{P},{luts:.0f},{acc_a:.4f}")

    def frontier(setting):
        pts = sorted(((r["luts"], r["acc"]) for r in rows if r["setting"] == setting))
        return pts

    # A2Q dominance: for the best baseline point, some A2Q point has <= LUTs
    # and accuracy within noise
    best = {}
    for s in ("fixed32", "dtype", "ptm", "a2q"):
        pts = frontier(s)
        best[s] = pts
    a2q_pts = best["a2q"]
    dominated = all(
        any(la <= lb * 1.02 and aa >= ab - 0.05 for la, aa in a2q_pts)
        for lb, ab in best["fixed32"]
    )
    order_ok = (
        min(l for l, _ in best["dtype"]) <= min(l for l, _ in best["fixed32"])
        and min(l for l, _ in best["ptm"]) <= min(l for l, _ in best["dtype"]) * 1.05
    )
    return {
        "rows": rows,
        "a2q_dominates_fixed32": dominated,
        "bound_ordering_ok": order_ok,
        "min_luts": {s: min(l for l, _ in pts) for s, pts in best.items()},
    }


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    a = ap.parse_args()
    out = run(a.steps)
    print({k: v for k, v in out.items() if k != "rows"})
