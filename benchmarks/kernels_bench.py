"""Kernel microbenchmarks (interpret-mode wall time is NOT TPU-meaningful; the
derived column is the oracle-vs-kernel agreement + the VMEM working-set bytes
each BlockSpec claims, which is the structural number that matters off-TPU)."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import time_call
from repro.kernels import ops, ref
from repro.roofline import hw


def _vmem_claim(*block_shapes_dtypes) -> int:
    total = 0
    for shape, dtype in block_shapes_dtypes:
        n = 1
        for d in shape:
            n *= d
        total += n * jnp.dtype(dtype).itemsize
    return total


def run() -> dict:
    rng = np.random.default_rng(0)
    rows = []
    print("name,us_per_call,derived")

    # int_matmul: VMEM claim for the (128, 128, 512) tiling
    x = jnp.asarray(rng.integers(-128, 128, (256, 1024)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (1024, 256)), jnp.int8)
    us = time_call(lambda: ops.int_matmul(x, w, block_m=128, block_n=128, block_k=512))
    vm = _vmem_claim(((128, 512), jnp.int8), ((512, 128), jnp.int8), ((128, 128), jnp.int32))
    ok = bool((ops.int_matmul(x, w) == ref.ref_int_matmul(x, w)).all())
    print(f"int_matmul_256x1024x256,{us:.1f},vmem={vm}B fits={vm < hw.VMEM_BYTES} exact={ok}")
    rows.append(dict(name="int_matmul", vmem=vm, ok=ok))

    # int16 spill halves the accumulator scratch
    vm16 = _vmem_claim(((128, 512), jnp.int8), ((512, 128), jnp.int8), ((128, 128), jnp.int16))
    print(f"int_matmul_int16_spill,0.0,scratch {vm - vm16} bytes saved per tile")
    rows.append(dict(name="int16_spill", saved=vm - vm16))

    # a2q_quantize fused kernel
    v = jnp.asarray(rng.normal(size=(2048, 512)), jnp.float32)
    t = jnp.asarray(rng.normal(size=(512,)) + 3, jnp.float32)
    d = jnp.asarray(rng.normal(size=(512,)) - 6, jnp.float32)
    us = time_call(lambda: ops.a2q_quantize(v, t, d, weight_bits=8, acc_bits=16,
                                            input_bits=8, input_signed=False))
    vm = _vmem_claim(((512, 256), jnp.float32), ((1, 256), jnp.float32), ((512, 256), jnp.float32),
                     ((512, 256), jnp.int8))
    print(f"a2q_quantize_2048x512,{us:.1f},vmem={vm}B fits={vm < hw.VMEM_BYTES}")
    rows.append(dict(name="a2q_quantize", vmem=vm))

    # flash attention working set
    q = jnp.asarray(rng.normal(size=(2, 4, 256, 64)), jnp.float32)
    us = time_call(lambda: ops.flash_attention(q, q, q, block_q=64, block_k=64))
    vm = _vmem_claim(((64, 64), jnp.float32), ((64, 64), jnp.float32), ((64, 64), jnp.float32),
                     ((64, 1), jnp.float32), ((64, 1), jnp.float32), ((64, 64), jnp.float32))
    print(f"flash_attention_256,{us:.1f},vmem={vm}B (vs dense scores {256*256*4}B/row-block)")
    rows.append(dict(name="flash", vmem=vm))

    # rwkv6 scan state residency
    r = jnp.asarray(rng.normal(size=(4, 64, 64)), jnp.float32)
    wdecay = jnp.asarray(rng.uniform(0.9, 0.999, size=(4, 64, 64)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    us = time_call(
        lambda: ops.rwkv6_scan(r[:, None].reshape(1, 4, 64, 64), r.reshape(1, 4, 64, 64),
                               r.reshape(1, 4, 64, 64), wdecay.reshape(1, 4, 64, 64), u, chunk=16)
    )
    vm = _vmem_claim(((64, 64), jnp.float32))
    print(f"rwkv6_scan_T64,{us:.1f},state_vmem={vm}B O(1)-in-T")
    rows.append(dict(name="rwkv6", vmem=vm))
    return {"rows": rows}


if __name__ == "__main__":
    run()
