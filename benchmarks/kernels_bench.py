"""Kernel microbenchmarks (interpret-mode wall time is NOT TPU-meaningful; the
derived column is the oracle-vs-kernel agreement + the VMEM working-set bytes
each BlockSpec claims, which is the structural number that matters off-TPU).

Every row carries an ``ok`` flag — kernel output checked against its jnp
oracle — and ``main`` exits nonzero when any is False, so the CI
``kernels-smoke`` job fails on any oracle mismatch."""

from __future__ import annotations

import sys

import numpy as np
import jax.numpy as jnp

from benchmarks.common import time_call
from repro.kernels import ops, ref
from repro.roofline import hw


def _vmem_claim(*block_shapes_dtypes) -> int:
    total = 0
    for shape, dtype in block_shapes_dtypes:
        n = 1
        for d in shape:
            n *= d
        total += n * jnp.dtype(dtype).itemsize
    return total


def run() -> dict:
    rng = np.random.default_rng(0)
    rows = []
    print("name,us_per_call,derived")

    # int_matmul: VMEM claim for the (128, 128, 512) tiling
    x = jnp.asarray(rng.integers(-128, 128, (256, 1024)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (1024, 256)), jnp.int8)
    us = time_call(lambda: ops.int_matmul(x, w, block_m=128, block_n=128, block_k=512))
    vm = _vmem_claim(((128, 512), jnp.int8), ((512, 128), jnp.int8), ((128, 128), jnp.int32))
    ok = bool((ops.int_matmul(x, w) == ref.ref_int_matmul(x, w)).all())
    print(f"int_matmul_256x1024x256,{us:.1f},vmem={vm}B fits={vm < hw.VMEM_BYTES} exact={ok}")
    rows.append(dict(name="int_matmul", vmem=vm, ok=ok))

    # fused W8A8 epilogue: per-channel scale + bias folded into the flush —
    # the serve-path layer runs in ONE pallas_call instead of matmul + dequant
    scale = jnp.asarray(rng.uniform(0.001, 0.1, (256,)), jnp.float32)
    bias = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    us = time_call(lambda: ops.int_matmul(x, w, scale=scale, bias=bias))
    got = ops.int_matmul(x, w, scale=scale, bias=bias)
    want = ref.ref_int_matmul_fused(x, w, scale, bias)
    ok = bool(np.allclose(np.asarray(got), np.asarray(want), rtol=1e-6))
    # scale-only epilogue is bit-exact (one fp32 multiply either way)
    ok &= bool(
        (np.asarray(ops.int_matmul(x, w, scale=scale))
         == np.asarray(ref.ref_int_matmul_fused(x, w, scale))).all()
    )
    print(f"int_matmul_fused_epilogue,{us:.1f},epilogue adds (1,128) f32 scale+bias blocks ok={ok}")
    rows.append(dict(name="int_matmul_fused", ok=ok))

    # int16 spill halves the accumulator scratch — and composes with the
    # fused epilogue (the serve path when A2Q guarantees acc_bits <= 16)
    xs = jnp.asarray(rng.integers(0, 8, (64, 256)), jnp.int8)
    ws = jnp.asarray(rng.integers(-2, 3, (256, 64)), jnp.int8)
    s16 = jnp.asarray(rng.uniform(0.001, 0.1, (64,)), jnp.float32)
    got = ops.int_matmul(xs, ws, acc_bits=16, spill_int16=True, scale=s16, block_k=64)
    want = ref.ref_int_matmul_fused(xs, ws, s16)
    ok = bool((np.asarray(got) == np.asarray(want)).all())
    vm16 = _vmem_claim(((128, 512), jnp.int8), ((512, 128), jnp.int8), ((128, 128), jnp.int16))
    print(f"int_matmul_int16_spill,0.0,scratch {vm - vm16} bytes saved per tile ok={ok}")
    rows.append(dict(name="int16_spill", saved=vm - vm16, ok=ok))

    # requantizing epilogue (int8-out chaining): acc -> rescale -> act replay
    # -> round/clamp -> int8 codes, bit-exact vs the jnp oracle for both pow2
    # and arbitrary out scales (f32 divide either way)
    out_pow2 = jnp.exp2(jnp.asarray(rng.integers(-4, 0, (256,)), jnp.float32))
    out_rand = jnp.asarray(rng.uniform(0.01, 0.3, (256,)), jnp.float32)
    us = time_call(lambda: ops.int_matmul(x, w, scale=scale, bias=bias, out_scale=out_pow2))
    ok = True
    for out_scale, act_fn in ((out_pow2, None), (out_rand, None), (out_pow2, "relu2"),
                              (out_rand, "gelu")):
        got = ops.int_matmul(x, w, scale=scale, bias=bias, out_scale=out_scale,
                             act_fn=act_fn)
        want = ref.ref_int_matmul_requant(x, w, scale, out_scale, bias=bias,
                                          act_fn=act_fn)
        ok &= bool((np.asarray(got) == np.asarray(want)).all())
    print(f"int_matmul_requant_epilogue,{us:.1f},int8-out chaining: pow2+random "
          f"out_scale, relu2/gelu replay, bit-exact={ok}")
    rows.append(dict(name="int_matmul_requant", ok=ok))

    # unsigned-8 symmetrization: u8 codes travel as q-128 and the kernel adds
    # 128*colsum(w) back at flush — exact in int32, so the old N<=7 unsigned
    # restriction on the fused path is gone
    xu = jnp.asarray(rng.integers(0, 256, (64, 256)) - 128, jnp.int8)
    su = jnp.asarray(rng.uniform(0.001, 0.1, (64,)), jnp.float32)
    got = ops.int_matmul(xu, ws, scale=su, in_signed=False, block_k=64)
    offs = 128 * np.asarray(ws, np.int64).sum(axis=0)
    acc = (np.asarray(xu, np.int64) @ np.asarray(ws, np.int64)) + offs
    want = acc.astype(np.float32) * np.asarray(su)[None, :]
    ok = bool((np.asarray(got) == want.astype(np.float32)).all())
    print(f"int_matmul_u8_symmetrize,0.0,offset=128*colsum(w) restores unsigned "
          f"codes exactly ok={ok}")
    rows.append(dict(name="int_matmul_u8_sym", ok=ok))

    # a2q_quantize fused kernel
    v = jnp.asarray(rng.normal(size=(2048, 512)), jnp.float32)
    t = jnp.asarray(rng.normal(size=(512,)) + 3, jnp.float32)
    d = jnp.asarray(rng.normal(size=(512,)) - 6, jnp.float32)
    us = time_call(lambda: ops.a2q_quantize(v, t, d, weight_bits=8, acc_bits=16,
                                            input_bits=8, input_signed=False))
    _, q_got = ops.a2q_quantize(v, t, d, weight_bits=8, acc_bits=16, input_bits=8,
                                input_signed=False)
    _, q_ref = ref.ref_a2q_quantize(v, t, d, 8, 16, 8, False)
    ok = bool((np.asarray(q_got, np.int32) == np.asarray(q_ref)).all())
    vm = _vmem_claim(((512, 256), jnp.float32), ((1, 256), jnp.float32), ((512, 256), jnp.float32),
                     ((512, 256), jnp.int8))
    print(f"a2q_quantize_2048x512,{us:.1f},vmem={vm}B fits={vm < hw.VMEM_BYTES} exact={ok}")
    rows.append(dict(name="a2q_quantize", vmem=vm, ok=ok))

    # flash attention working set
    q = jnp.asarray(rng.normal(size=(2, 4, 256, 64)), jnp.float32)
    us = time_call(lambda: ops.flash_attention(q, q, q, block_q=64, block_k=64))
    ok = bool(np.allclose(
        np.asarray(ops.flash_attention(q, q, q, block_q=64, block_k=64)),
        np.asarray(ref.ref_flash_attention(q, q, q)), atol=2e-5,
    ))
    vm = _vmem_claim(((64, 64), jnp.float32), ((64, 64), jnp.float32), ((64, 64), jnp.float32),
                     ((64, 1), jnp.float32), ((64, 1), jnp.float32), ((64, 64), jnp.float32))
    print(f"flash_attention_256,{us:.1f},vmem={vm}B (vs dense scores {256*256*4}B/row-block) ok={ok}")
    rows.append(dict(name="flash", vmem=vm, ok=ok))

    # paged attention: fp32 blocks and int8 blocks with in-kernel dequant
    B, KV, G, Dh, NB, bs, MB = 4, 2, 4, 64, 32, 8, 6
    H = KV * G
    kp = jnp.asarray(rng.normal(size=(NB, bs, KV, Dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(NB, bs, KV, Dh)), jnp.float32)
    bt_np = np.zeros((B, MB), np.int32)
    lens = [37, 5, 48, 16]
    nxt = 1
    for b, ln in enumerate(lens):
        for j in range(-(-ln // bs)):
            bt_np[b, j] = nxt
            nxt += 1
    bt = jnp.asarray(bt_np)
    ln = jnp.asarray(lens, jnp.int32)
    qd = jnp.asarray(rng.normal(size=(B, H, Dh)), jnp.float32)
    us = time_call(lambda: ops.paged_attention(qd, kp, vp, bt, ln))
    ok = bool(np.allclose(np.asarray(ops.paged_attention(qd, kp, vp, bt, ln)),
                          np.asarray(ref.ref_paged_attention(qd, kp, vp, bt, ln)), atol=2e-5))
    vm = _vmem_claim(((1, bs, 1, Dh), jnp.float32), ((1, bs, 1, Dh), jnp.float32))
    print(f"paged_attention_fp32,{us:.1f},kv_block_vmem={vm}B ok={ok}")
    rows.append(dict(name="paged_attention", vmem=vm, ok=ok))

    kq = jnp.asarray(rng.integers(-127, 128, (NB, bs, KV, Dh)), jnp.int8)
    vq = jnp.asarray(rng.integers(-127, 128, (NB, bs, KV, Dh)), jnp.int8)
    ks = jnp.asarray(rng.uniform(0.005, 0.02, (NB, bs, KV)), jnp.float32)
    vs = jnp.asarray(rng.uniform(0.005, 0.02, (NB, bs, KV)), jnp.float32)
    us = time_call(lambda: ops.paged_attention(qd, kq, vq, bt, ln, kps=ks, vps=vs))
    ok = bool(np.allclose(
        np.asarray(ops.paged_attention(qd, kq, vq, bt, ln, kps=ks, vps=vs)),
        np.asarray(ref.ref_paged_attention_q8(qd, kq, vq, ks, vs, bt, ln)), atol=2e-5,
    ))
    vm8 = _vmem_claim(((1, bs, 1, Dh), jnp.int8), ((1, bs, 1, Dh), jnp.int8),
                      ((1, bs, 1), jnp.float32), ((1, bs, 1), jnp.float32))
    print(f"paged_attention_int8,{us:.1f},kv_block_vmem={vm8}B ({vm}B fp32, "
          f"{vm / vm8:.2f}x less DMA) ok={ok}")
    rows.append(dict(name="paged_attention_q8", vmem=vm8, fp32_vmem=vm, ok=ok))

    # rwkv6 scan state residency
    r = jnp.asarray(rng.normal(size=(4, 64, 64)), jnp.float32)
    wdecay = jnp.asarray(rng.uniform(0.9, 0.999, size=(4, 64, 64)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    args = (r[:, None].reshape(1, 4, 64, 64), r.reshape(1, 4, 64, 64),
            r.reshape(1, 4, 64, 64), wdecay.reshape(1, 4, 64, 64), u)
    us = time_call(lambda: ops.rwkv6_scan(*args, chunk=16))
    y_got, _ = ops.rwkv6_scan(*args, chunk=16)
    y_ref, _ = ref.ref_rwkv6(  # head 0, oracle in its (B, T, D) folded layout
        args[0][:, 0], args[1][:, 0], args[2][:, 0], args[3][:, 0], u[0]
    )
    ok = bool(np.allclose(np.asarray(y_got[:, 0]), np.asarray(y_ref), atol=1e-4))
    vm = _vmem_claim(((64, 64), jnp.float32))
    print(f"rwkv6_scan_T64,{us:.1f},state_vmem={vm}B O(1)-in-T ok={ok}")
    rows.append(dict(name="rwkv6", vmem=vm, ok=ok))
    return {"rows": rows, "all_ok": all(r.get("ok", True) for r in rows)}


def main() -> int:
    out = run()
    bad = [r["name"] for r in out["rows"] if not r.get("ok", True)]
    if bad:
        print(f"ORACLE MISMATCH: {bad}", file=sys.stderr)
        return 1
    print("all kernel oracles OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
