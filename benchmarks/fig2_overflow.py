"""Paper Fig. 2 + Appendix A: overflow impact on the 1-layer binary-MNIST
classifier (K=784, M=8, N=1).

For each accumulator width P below the 19-bit data-type bound:
  * wraparound accuracy (black stars),
  * saturation accuracy (blue triangles),
  * A2Q retrained at target P (green dots),
  * overflow rate per dot product, and logits MAE vs the 32-bit result.

Also ``--reorder``: Appendix A.1's MAC-order audit under saturation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import accuracy, train_classifier
from repro.configs.base import QuantConfig
from repro.core.bounds import min_accumulator_bits_data_type
from repro.core.integer import accumulate_dot, mac_order_audit, overflow_stats
from repro.core.quantizers import act_quant_int, weight_qat_int
from repro.core.a2q import a2q_int_weights
from repro.data.synthetic import BinaryMnistStream
from repro.models.vision import apply_linear_classifier, init_linear_classifier


def _int_artifacts(params, q: QuantConfig):
    """(integer weights (784, 2), per-channel scale) from a trained model."""
    fc = params["fc"]
    if "v" in fc:
        return a2q_int_weights(
            {"v": fc["v"], "t": fc["t"], "d": fc["d"]},
            q.weight_bits, q.acc_bits, q.act_bits, False,
        )
    return weight_qat_int({"log2_scale": fc["wq"]["log2_scale"]}, fc["w"], q.weight_bits)


def _eval_int(w_int, x_bits, acc_bits, mode):
    """Integer-exact inference of the classifier at P bits."""
    y = accumulate_dot(x_bits, np.asarray(w_int, np.int64), acc_bits, mode)
    return y


def run(steps: int = 60, reorder: bool = False) -> dict:
    stream = BinaryMnistStream(global_batch=128, seed=0)
    test = stream.batch(10_000)
    x_bits = test["x"].astype(np.int64)  # 1-bit unsigned inputs
    labels = test["y"]

    bound = min_accumulator_bits_data_type(784, 1, 8, signed_input=False)

    # float pre-training (App. B: QNNs init from converged float models)
    q_float = QuantConfig(mode="none")
    p_float = train_classifier(
        lambda k, q: init_linear_classifier(k, q),
        apply_linear_classifier, q_float, stream, steps=steps,
    )

    # baseline QAT model (the paper's 91.5%-style reference)
    q_base = QuantConfig(mode="qat", weight_bits=8, act_bits=1, acc_bits=32)
    p_base = train_classifier(
        lambda k, q: init_linear_classifier(k, q),
        apply_linear_classifier, q_base, stream, steps=steps,
    )
    w_int, s = _int_artifacts(p_base, q_base)
    ref32 = _eval_int(w_int, x_bits, 64, "exact")
    base_acc = float((np.argmax(ref32, -1) == labels).mean())

    rows = []
    print("P,overflow_per_dot,wrap_acc,sat_acc,a2q_acc,wrap_mae,sat_mae")
    for P in range(bound, 7, -1):
        wrap = _eval_int(w_int, x_bits, P, "wrap")
        sat = _eval_int(w_int, x_bits, P, "saturate")
        ov = overflow_stats(x_bits, np.asarray(w_int, np.int64), P)["overflows_per_dot"]
        wrap_acc = float((np.argmax(wrap, -1) == labels).mean())
        sat_acc = float((np.argmax(sat, -1) == labels).mean())
        wrap_mae = float(np.abs(wrap - ref32).mean())
        sat_mae = float(np.abs(sat - ref32).mean())

        # A2Q retrained at target P: init from the pre-trained float weights
        # (App. B protocol; fine-tune with SGD-M -- Adam's per-coordinate
        # normalization fights the l1 concentration at tight budgets)
        q_a2q = QuantConfig(mode="a2q", weight_bits=8, act_bits=1, acc_bits=P)
        from repro.models.vision import requantize_from_float
        from repro.nn.module import unbox
        import jax as _jax

        p_init = requantize_from_float(
            unbox(init_linear_classifier(_jax.random.PRNGKey(0), q_a2q)),
            p_float, q_a2q,
        )
        p_a2q = train_classifier(
            lambda k, q: init_linear_classifier(k, q),
            apply_linear_classifier, q_a2q, stream, steps=steps,
            init_params=p_init, optimizer="sgdm", lr=1e-2,
        )
        wa, _ = _int_artifacts(p_a2q, q_a2q)
        ya = _eval_int(wa, x_bits, P, "wrap")  # wrap == exact under the guarantee
        ov_a2q = overflow_stats(x_bits, np.asarray(wa, np.int64), P)["events"]
        assert ov_a2q == 0, f"A2Q guarantee violated at P={P}"
        a2q_acc = float((np.argmax(ya, -1) == labels).mean())
        rows.append(dict(P=P, overflow=ov, wrap=wrap_acc, sat=sat_acc, a2q=a2q_acc))
        print(f"{P},{ov:.4f},{wrap_acc:.4f},{sat_acc:.4f},{a2q_acc:.4f},{wrap_mae:.1f},{sat_mae:.1f}")

    result = {
        "bound_P": bound,
        "baseline_acc": base_acc,
        "rows": rows,
        "wrap_collapses": rows[-1]["wrap"] < base_acc - 0.15,
        "a2q_holds": min(r["a2q"] for r in rows) > base_acc - 0.12,
        "a2q_beats_wrap_at_low_P": rows[-1]["a2q"] > rows[-1]["wrap"],
    }

    if reorder:
        audit = mac_order_audit(x_bits[:32], np.asarray(w_int, np.int64), acc_bits=12, n_orders=8)
        result["reorder_audit"] = audit
        print("reorder audit (P=12, saturate):", audit)
    return result


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--reorder", action="store_true")
    a = ap.parse_args()
    out = run(a.steps, a.reorder)
    print({k: v for k, v in out.items() if k != "rows"})
