"""Paper Fig. 4: accumulator-width vs task-performance Pareto frontier.

Grid over (M=N in weight/act bits) x (target P), A2Q vs the baseline
"heuristic" approach (baseline QAT can only reach a given P by shrinking data
bit widths until the data-type bound admits it).  Reduced scale: MobileNetV1
x0.25 on the synthetic CIFAR10-shaped stream; the deliverable is the Pareto
*dominance ordering*, matching the paper's relative claim.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import accuracy, requantized_init, train_classifier
from repro.configs.base import QuantConfig
from repro.core.bounds import min_accumulator_bits_data_type
from repro.data.synthetic import ImageClassStream
from repro.models.vision import apply_mobilenet_v1, init_mobilenet_v1, vision_penalty

# largest dot product in MobileNetV1 x0.25: pw conv K = 256 (1x1 conv, C_in=256)
_KSTAR = 256


def run(steps: int = 40, bit_widths=(5, 6, 8), p_drops=(0, 2, 4, 6)) -> dict:
    # 5-8 bits: the paper's own design space (Sec. 5.1: below 5 bits needs
    # unique hyperparameters; we constrain identically)
    stream = ImageClassStream(global_batch=64, seed=0)
    init = lambda k, q: init_mobilenet_v1(k, q, width=0.25)

    # App. B: every QNN starts from a converged float model
    p_float = train_classifier(init, apply_mobilenet_v1, QuantConfig(mode="none"),
                               stream, steps=steps)

    rows = []
    print("algo,M,N,P,acc")
    for bits in bit_widths:
        bound = min_accumulator_bits_data_type(_KSTAR, bits, bits, signed_input=False)
        # baseline QAT: P is whatever the data-type bound says for (M=N=bits)
        q = QuantConfig(mode="qat", weight_bits=bits, act_bits=bits, acc_bits=bound)
        p = train_classifier(init, apply_mobilenet_v1, q, stream, steps=steps,
                             init_params=requantized_init(init, p_float, q))
        acc = accuracy(apply_mobilenet_v1, p, q, stream)
        rows.append(dict(algo="baseline", M=bits, N=bits, P=bound, acc=acc))
        print(f"baseline,{bits},{bits},{bound},{acc:.4f}")
        # A2Q: P is an independent variable pushed below the bound
        for drop in p_drops:
            P = bound - drop
            qa = QuantConfig(mode="a2q", weight_bits=bits, act_bits=bits, acc_bits=P)
            pa = train_classifier(
                init, apply_mobilenet_v1, qa, stream, steps=steps,
                penalty_fn=vision_penalty, optimizer="sgdm", lr=1e-2,
                init_params=requantized_init(init, p_float, qa),
            )
            acc = accuracy(apply_mobilenet_v1, pa, qa, stream)
            rows.append(dict(algo="a2q", M=bits, N=bits, P=P, acc=acc))
            print(f"a2q,{bits},{bits},{P},{acc:.4f}")

    # Pareto frontiers: best accuracy at each attainable P
    def frontier(algo):
        f = {}
        for r in rows:
            if r["algo"] == algo:
                f[r["P"]] = max(f.get(r["P"], 0.0), r["acc"])
        return f

    fb, fa = frontier("baseline"), frontier("a2q")
    min_p_baseline = min(fb)
    min_p_a2q = min(fa)
    # dominance: at every baseline-attainable P, some A2Q point at <= that P
    # achieves accuracy within noise or better
    dominated = all(
        max((acc for p_, acc in fa.items() if p_ <= p), default=0.0) >= acc_b - 0.05
        for p, acc_b in fb.items()
    )
    return {
        "rows": rows,
        "min_P_baseline": min_p_baseline,
        "min_P_a2q": min_p_a2q,
        "a2q_extends_pareto_left": min_p_a2q < min_p_baseline,
        "a2q_dominates": dominated,
    }


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    a = ap.parse_args()
    out = run(a.steps)
    print({k: v for k, v in out.items() if k != "rows"})
