"""Serving benchmark: contiguous per-token-prefill baseline vs the paged
engine on a mixed-length workload.

Reports continuous-batching throughput (tok/s, split prefill vs decode) and
per-request end-to-end latency p50/p99 for both engines, plus the paged
engine's peak KV block usage vs the contiguous engine's fixed
``batch x max_seq`` footprint.  Prints a CSV like the other ``benchmarks/``
modules and returns a headline dict (``run.py``-aggregatable); ``--json``
writes the same dict to disk.

Wall-clock on CPU/interpret is not TPU-meaningful in absolute terms, but the
*relative* contiguous-vs-paged comparison is structural: the baseline spends
one jit call per prompt token while the paged engine batches whole chunks,
and that ratio survives any backend.
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.models.lm import init_lm
from repro.nn.module import unbox
from repro.serve.engine import PagedServeEngine, Request, ServeEngine


def _percentiles(reqs) -> dict:
    lat = np.asarray([r.latency for r in reqs])
    ttft = np.asarray([r.ttft for r in reqs])
    return {
        "latency_p50_s": float(np.percentile(lat, 50)),
        "latency_p99_s": float(np.percentile(lat, 99)),
        "ttft_p50_s": float(np.percentile(ttft, 50)),
        "ttft_p99_s": float(np.percentile(ttft, 99)),
    }


def _stats_row(engine, reqs) -> dict:
    row = engine.throughput()
    row.update(_percentiles(reqs))
    return row


def _drive_contiguous(engine, reqs):
    import time

    for r in reqs:
        r.submitted_at = time.perf_counter()
    if engine.recurrent:
        # the contiguous baseline cannot continuously batch recurrent stacks
        # (slot-at-a-time prefill pollutes every row's non-positional state)
        # and mixed-length prompts rule out multi-request lockstep groups:
        # its honest capability on this workload is one request per group
        for r in reqs:
            engine._generate_lockstep([r])
        return
    pending = list(reqs)
    while pending or any(s is not None for s in engine.slots):
        while pending and engine.admit(pending[0]):
            pending.pop(0)
        if engine.tick() == 0 and not pending:
            break


def _drive_paged(engine, reqs):
    for r in reqs:
        engine.submit(r)
    while not engine.sched.idle():
        engine.step()


def _workload(rng, arch, n, max_new):
    """Mixed-length prompts: the regime where per-token prefill hurts most and
    paged memory reuse matters (short and long requests share slots).  Prompt
    lengths dominate generation lengths, as in real serving traffic."""
    lens = rng.integers(8, 49, size=n)
    return [
        Request(uid=i, prompt=rng.integers(0, arch.vocab, (int(L),)).astype(np.int32),
                max_new=max_new)
        for i, L in enumerate(lens)
    ]


def run(
    arch_name: str = "yi-6b",
    requests: int = 8,
    max_new: int = 4,
    batch: int = 2,
    max_seq: int = 64,
    block_size: int = 8,
    prefill_chunk: int = 16,
    num_blocks=None,
    seed: int = 0,
) -> dict:
    arch = reduced(get_arch(arch_name))
    params = unbox(init_lm(jax.random.PRNGKey(seed), arch))

    def workload():  # identical draw for every engine / pass
        return _workload(np.random.default_rng(seed), arch, requests, max_new)

    contig = ServeEngine(arch, params, batch=batch, max_seq=max_seq)
    paged = PagedServeEngine(
        arch, params, batch=batch, max_seq=max_seq,
        block_size=block_size, prefill_chunk=prefill_chunk, num_blocks=num_blocks,
    )
    # Warmup pass covers every jit shape (the paged engine compiles one
    # prefill per distinct chunk length), so the timed pass measures
    # steady-state serving throughput rather than XLA compile time.
    _drive_contiguous(contig, workload())
    _drive_paged(paged, workload())
    contig.reset_stats()
    paged.reset_stats()
    paged.cache.peak_blocks = 0

    reqs_c, reqs_p = workload(), workload()
    _drive_contiguous(contig, reqs_c)
    _drive_paged(paged, reqs_p)

    assert [r.generated for r in reqs_c] == [r.generated for r in reqs_p], \
        "engines diverged on the benchmark workload"

    out = {
        "arch": arch_name,
        "requests": requests,
        "contiguous": _stats_row(contig, reqs_c),
        "paged": _stats_row(paged, reqs_p),
        # fixed lanes vs token-proportional blocks (same dtype, so the slot
        # count ratio is the memory ratio for the seq-indexed leaves)
        "contiguous_cache_slots": batch * max_seq,
        "paged_peak_block_tokens": paged.cache.peak_blocks * paged.cache.block_size,
    }
    out["prefill_speedup"] = (
        out["paged"]["prefill_tok_s"] / out["contiguous"]["prefill_tok_s"]
        if out["contiguous"]["prefill_tok_s"] > 0 else float("inf")
    )
    out["throughput_speedup"] = (
        out["paged"]["tok_s"] / out["contiguous"]["tok_s"]
        if out["contiguous"]["tok_s"] > 0 else float("inf")
    )

    print("engine,tok_s,prefill_tok_s,decode_tok_s,latency_p50_s,latency_p99_s")
    for name in ("contiguous", "paged"):
        r = out[name]
        print(f"{name},{r['tok_s']:.1f},{r['prefill_tok_s']:.1f},{r['decode_tok_s']:.1f},"
              f"{r['latency_p50_s']:.3f},{r['latency_p99_s']:.3f}")
    print(f"prefill_speedup,{out['prefill_speedup']:.2f},throughput_speedup,"
          f"{out['throughput_speedup']:.2f}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument("--json", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    out = run(
        arch_name=args.arch, requests=args.requests, max_new=args.max_new,
        batch=args.batch, max_seq=args.max_seq, block_size=args.block_size,
        prefill_chunk=args.prefill_chunk, seed=args.seed,
    )
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json}")
    return out


if __name__ == "__main__":
    main()
