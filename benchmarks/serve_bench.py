"""Serving benchmark: contiguous per-token-prefill baseline vs the paged
engine family (fp32 / int8 KV blocks / int8 KV composed with the fused
decode megastep / prefix sharing / speculative decoding) on a mixed-length
workload with a shared-prefix cohort.  ``run_cluster()`` adds the routed
two-replica cluster cohort: capacity scaling vs a single replica and the
mid-wave replica-kill requeue drill (``--cluster`` on the CLI).

Reports continuous-batching throughput (tok/s, split prefill vs decode) and
per-request end-to-end latency p50/p99 for every engine, the paged engine's
peak KV block usage vs the contiguous engine's fixed ``batch x max_seq``
footprint, the KV bytes-per-token the int8 block pools save (~4x), the
prompt tokens the prefix-sharing engine served from shared blocks (plus its
CoW copy count), the speculative engine's acceptance rate, and — per engine —
``dispatches_per_token``: the jitted decode launches each generated token
paid for (1.0 per-tick; ~1/N for the fused megastep engine, which must also
close the paged-vs-contiguous decode gap the per-tick engine regressed).  The int8
engine's greedy tokens are held to the parity bound (token-identical up to
sub-margin quantization ties — see ``launch/serve.py``); the prefix-sharing
and speculative engines must match the plain paged engine token-for-token.
Prints a CSV like the other ``benchmarks/`` modules and returns a headline
dict (``run.py``-aggregatable); ``--json`` writes the same dict to disk.

Wall-clock on CPU/interpret is not TPU-meaningful in absolute terms, but the
*relative* comparisons are structural: the baseline spends one jit call per
prompt token while the paged engine batches whole chunks; the speculative
engine replaces k + 1 decode dispatches with two (a k-step draft scan + one
batched verify); prefix sharing skips recomputing the shared cohort's
common prompt altogether.  Those ratios survive any backend.
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.models.lm import Runtime, init_lm
from repro.nn.module import unbox
from repro.obs import Obs, percentile
from repro.obs.headroom import engine_headroom
from repro.serve.engine import (
    PagedServeEngine, Request, ServeEngine, deploy_params, parity_up_to_ties,
)
from repro.serve.spec import SpecServeEngine


def _percentiles(reqs) -> dict:
    # nearest-rank percentiles through the shared obs helper — the same math
    # the engines' metrics histograms and the cluster heartbeat report
    lat = [r.latency for r in reqs]
    ttft = [r.ttft for r in reqs]
    return {
        "latency_p50_s": percentile(lat, 50),
        "latency_p99_s": percentile(lat, 99),
        "ttft_p50_s": percentile(ttft, 50),
        "ttft_p99_s": percentile(ttft, 99),
    }


def _stats_row(engine, reqs) -> dict:
    row = engine.throughput()
    row.update(_percentiles(reqs))
    return row


def _drive_contiguous(engine, reqs):
    import time

    for r in reqs:
        r.submitted_at = time.perf_counter()
    if engine.recurrent:
        # the contiguous baseline cannot continuously batch recurrent stacks
        # (slot-at-a-time prefill pollutes every row's non-positional state)
        # and mixed-length prompts rule out multi-request lockstep groups:
        # its honest capability on this workload is one request per group
        for r in reqs:
            engine._generate_lockstep([r])
        return
    pending = list(reqs)
    while pending or any(s is not None for s in engine.slots):
        while pending and engine.admit(pending[0]):
            pending.pop(0)
        if engine.tick() == 0 and not pending:
            break


def _drive_paged(engine, reqs):
    for r in reqs:
        engine.submit(r)
    while not engine.sched.idle():
        engine.step()


def _workload(rng, arch, n, max_new):
    """Mixed-length prompts with a shared-prefix cohort: the regime where
    per-token prefill hurts most and paged memory reuse matters (short and
    long requests share slots).  Half the requests open with one common
    21-token prompt prefix — the common-system-prompt pattern; 21 is
    deliberately NOT a block or chunk multiple, so the chunk-aligned resume
    logic (adopt to the aligned offset, recompute the ragged tail) is
    exercised on every hit rather than only on aligned lengths.  Prompt
    lengths dominate generation lengths, as in real serving traffic."""
    common = rng.integers(0, arch.vocab, (21,)).astype(np.int32)
    lens = rng.integers(8, 49, size=n)
    out = []
    for i, L in enumerate(lens):
        tail = rng.integers(0, arch.vocab, (int(L),)).astype(np.int32)
        prompt = np.concatenate([common, tail[: max(int(L) - 21, 4)]]) if i % 2 else tail
        out.append(Request(uid=i, prompt=prompt, max_new=max_new))
    return out


def run(
    arch_name: str = "yi-6b",
    requests: int = 8,
    max_new: int = 4,
    batch: int = 2,
    max_seq: int = 64,
    block_size: int = 8,
    prefill_chunk: int = 16,
    num_blocks=None,
    decode_steps: int = 8,
    seed: int = 0,
) -> dict:
    arch = reduced(get_arch(arch_name))
    params = unbox(init_lm(jax.random.PRNGKey(seed), arch))
    spec_k = 3
    spec_ok = not any(s.kind in ("rwkv6", "hymba") for s in arch.stacks)

    def workload():  # identical draw for every engine / pass
        return _workload(np.random.default_rng(seed), arch, requests, max_new)

    contig = ServeEngine(arch, params, batch=batch, max_seq=max_seq)
    pkw = dict(batch=batch, max_seq=max_seq, block_size=block_size,
               prefill_chunk=prefill_chunk, num_blocks=num_blocks)
    paged = PagedServeEngine(arch, params, **pkw)
    # the dispatch-count engine: N decode ticks fused per jitted dispatch.
    # Kept separate from `paged` so the per-tick engine remains the reference
    # the int8-KV / prefix-share / spec comparisons were defined against.
    paged_mega = PagedServeEngine(arch, params, decode_steps=decode_steps, **pkw)
    paged_q8 = PagedServeEngine(arch, params, kv_quant=True, **pkw)
    # int8 KV blocks *composed with* the fused decode megastep: the two
    # optimizations must stack (quantized pools ride the same N-tick fused
    # dispatch), not merely coexist in separate engines
    paged_q8m = PagedServeEngine(arch, params, kv_quant=True,
                                 decode_steps=decode_steps, **pkw)
    # the integer fast path and its int8-out chained variant run the deployed
    # artifact (int8 weights + scales).  The chained engine folds activation
    # quantization into the W8A8 kernel (epilogue requant / prologue quant);
    # both share the exact same quantized numerics, so greedy tokens must be
    # identical between them — chaining is a pure dispatch fusion.
    dep = deploy_params(params, arch.quant)
    paged_int = PagedServeEngine(arch, dep, rt=Runtime(int_forward=True), **pkw)
    paged_intc = PagedServeEngine(arch, dep, rt=Runtime(int_chain=True), **pkw)
    paged_px = PagedServeEngine(arch, params, prefix_share=True, **pkw)
    # the tracing-overhead engine: identical config to the megastep engine
    # but with span tracing live on every admit/preflight/megastep.  The
    # obs_overhead headline (untraced / traced decode tok/s) gates that
    # permanent hot-path instrumentation stays within noise (run.py <= 1.05)
    paged_megat = PagedServeEngine(arch, params, decode_steps=decode_steps,
                                   obs=Obs(trace=True), **pkw)
    # pin the workload's common system prefix (same rng draw as _workload):
    # prefilled once here, never evicted, so even the *first* shared-cohort
    # request adopts it — the --pin-prompt serving pattern, benchmarked
    common = np.random.default_rng(seed).integers(0, arch.vocab, (21,)).astype(np.int32)
    pinned_tokens = paged_px.pin_prompt(common)
    spec = (SpecServeEngine(arch, params, spec_k=spec_k, **pkw)
            if spec_ok else None)
    engines = [e for e in (contig, paged, paged_mega, paged_q8, paged_q8m,
                           paged_int, paged_intc, paged_px, paged_megat, spec)
               if e is not None]
    # Warmup pass covers every jit shape (the paged engine compiles one
    # prefill per distinct chunk length), so the timed pass measures
    # steady-state serving throughput rather than XLA compile time.
    _drive_contiguous(contig, workload())
    for e in engines[1:]:
        _drive_paged(e, workload())
    for e in engines:
        # one reset path: engine stats, obs (trace + metrics), and — on the
        # paged engines — every cache counter, peak_blocks included
        e.reset_stats()

    reqs_c, reqs_p, reqs_m, reqs_q, reqs_qm, reqs_i, reqs_ic, reqs_x, reqs_t = (
        workload() for _ in range(9))
    _drive_contiguous(contig, reqs_c)
    _drive_paged(paged, reqs_p)
    _drive_paged(paged_mega, reqs_m)
    _drive_paged(paged_q8, reqs_q)
    _drive_paged(paged_q8m, reqs_qm)
    _drive_paged(paged_int, reqs_i)
    _drive_paged(paged_intc, reqs_ic)
    _drive_paged(paged_px, reqs_x)
    _drive_paged(paged_megat, reqs_t)
    reqs_s = None
    if spec is not None:
        reqs_s = workload()
        _drive_paged(spec, reqs_s)

    assert [r.generated for r in reqs_c] == [r.generated for r in reqs_p], \
        "engines diverged on the benchmark workload"
    # the megastep is a pure dispatch fusion: greedy tokens must be identical
    assert [r.generated for r in reqs_m] == [r.generated for r in reqs_p], \
        "megastep engine diverged from per-tick paged decode"
    # ...and it stays a pure fusion over int8 pools: the fused int8 engine
    # must match the per-tick int8 engine token-for-token (both share the
    # same quantized numerics; only the dispatch count differs)
    assert [r.generated for r in reqs_qm] == [r.generated for r in reqs_q], \
        "int8-KV megastep engine diverged from per-tick int8-KV decode"
    # prefix sharing and speculative decoding are lossless: exact parity
    assert [r.generated for r in reqs_x] == [r.generated for r in reqs_p], \
        "prefix-sharing engine diverged"
    if reqs_s is not None:
        assert [r.generated for r in reqs_s] == [r.generated for r in reqs_p], \
            "speculative engine diverged from plain greedy decode"
    # int8-out chaining is a pure dispatch fusion over the integer fast path:
    # the chained engine must match the unchained int engine token-for-token
    assert [r.generated for r in reqs_ic] == [r.generated for r in reqs_i], \
        "int8-chained engine diverged from unchained int-forward decode"
    # tracing is observation only: the traced engine's greedy tokens must be
    # identical to the untraced megastep engine it mirrors
    assert [r.generated for r in reqs_t] == [r.generated for r in reqs_m], \
        "span tracing changed the traced engine's output"
    # int8 KV is lossy: hold it to the parity bound instead of bit equality
    ok, ties, detail = parity_up_to_ties(
        reqs_p, [r.generated for r in reqs_q], eps=0.05
    )
    assert ok, f"int8-KV engine broke the parity bound: {detail}"

    out = {
        "arch": arch_name,
        "requests": requests,
        "contiguous": _stats_row(contig, reqs_c),
        "paged": _stats_row(paged, reqs_p),
        "paged_megastep": _stats_row(paged_mega, reqs_m),
        "decode_steps": decode_steps,
        "paged_int8_kv": _stats_row(paged_q8, reqs_q),
        "paged_megastep_int8_kv": _stats_row(paged_q8m, reqs_qm),
        "paged_int_forward": _stats_row(paged_int, reqs_i),
        "paged_int_forward_chained": _stats_row(paged_intc, reqs_ic),
        "paged_prefix_share": _stats_row(paged_px, reqs_x),
        # fixed lanes vs token-proportional blocks (same dtype, so the slot
        # count ratio is the memory ratio for the seq-indexed leaves)
        "contiguous_cache_slots": batch * max_seq,
        "paged_peak_block_tokens": paged.cache.peak_blocks * paged.cache.block_size,
        # the int8-KV headline: HBM bytes one cached token costs, summed over
        # every seq-indexed pool (codes + scales), fp32 blocks vs int8 blocks
        "kv_bytes_per_token_fp32": paged.cache.kv_bytes_per_token(),
        "kv_bytes_per_token_int8": paged_q8.cache.kv_bytes_per_token(),
        "int8_kv_sub_margin_ties": ties,
        # prefix sharing: prompt tokens served straight from shared blocks
        # (never recomputed) and the CoW copies that kept writers honest
        "prefix_hits": paged_px.cache.prefix_hits,
        "prefix_hit_tokens": paged_px.cache.prefix_hit_tokens,
        "prefix_cow_copies": paged_px.cache.cow_copies,
        "prefix_pinned_tokens": pinned_tokens,
        "prefix_radix_nodes": paged_px.cache.registry_size(),
        "prefix_pool_rebuilds": paged_px.cache.pool_rebuilds,
        "prefix_bt_row_patches": paged_px.cache.bt_row_patches,
        "prefix_bt_full_uploads": paged_px.cache.bt_full_uploads,
    }
    if spec is not None:
        out["spec"] = _stats_row(spec, reqs_s)
        out["spec_k"] = spec_k
        out["spec_acceptance_rate"] = spec.acceptance_rate()
        out["spec_rounds"] = spec.spec_stats["rounds"]
        out["spec_decode_speedup"] = (
            out["spec"]["decode_tok_s"] / out["paged"]["decode_tok_s"]
            if out["paged"]["decode_tok_s"] > 0 else float("inf")
        )
        out["spec_throughput_speedup"] = (
            out["spec"]["tok_s"] / out["paged"]["tok_s"]
            if out["paged"]["tok_s"] > 0 else float("inf")
        )
    # recurrent archs (rwkv6) have no seq-indexed pools at all — nothing to
    # quantize, both byte counts are 0, ratio is the identity
    out["kv_bytes_ratio"] = (
        out["kv_bytes_per_token_fp32"] / out["kv_bytes_per_token_int8"]
        if out["kv_bytes_per_token_int8"] > 0 else 1.0
    )
    out["prefill_speedup"] = (
        out["paged"]["prefill_tok_s"] / out["contiguous"]["prefill_tok_s"]
        if out["contiguous"]["prefill_tok_s"] > 0 else float("inf")
    )
    out["throughput_speedup"] = (
        out["paged"]["tok_s"] / out["contiguous"]["tok_s"]
        if out["contiguous"]["tok_s"] > 0 else float("inf")
    )
    # the megastep headlines (run.py claims): the jitted-dispatch cost each
    # decode token pays, and paged steady-state decode vs the contiguous
    # baseline — the regression this engine exists to close (per-tick paged
    # decode paid per-token host work the contiguous loop never did)
    out["megastep_dispatches_per_token"] = out["paged_megastep"]["dispatches_per_token"]
    out["paged_decode_ratio"] = (
        out["paged_megastep"]["decode_tok_s"] / out["contiguous"]["decode_tok_s"]
        if out["contiguous"]["decode_tok_s"] > 0 else float("inf")
    )
    out["megastep_decode_speedup"] = (
        out["paged_megastep"]["decode_tok_s"] / out["paged"]["decode_tok_s"]
        if out["paged"]["decode_tok_s"] > 0 else float("inf")
    )
    # steady-state decode throughput of int8 blocks vs fp32 blocks: on TPU
    # this is the ~4x-bandwidth win; on CPU/interpret it only proves the
    # quantize/dequant work does not sink the decode path
    out["int8_kv_decode_ratio"] = (
        out["paged_int8_kv"]["decode_tok_s"] / out["paged"]["decode_tok_s"]
        if out["paged"]["decode_tok_s"] > 0 else float("inf")
    )
    # the composed engine (int8 pools + fused megastep): dispatch cost per
    # token must match the fp32 megastep (~1/N), and its steady-state decode
    # must not fall behind the per-tick int8 engine it fuses
    out["int8_kv_megastep_dispatches_per_token"] = (
        out["paged_megastep_int8_kv"]["dispatches_per_token"]
    )
    out["int8_kv_megastep_decode_ratio"] = (
        out["paged_megastep_int8_kv"]["decode_tok_s"]
        / out["paged_int8_kv"]["decode_tok_s"]
        if out["paged_int8_kv"]["decode_tok_s"] > 0 else float("inf")
    )
    # int8-out chaining headlines (run.py claims): the chained engine must
    # launch ZERO standalone act-quant dispatches for deployed layers (the
    # stats-contract field, trace-time count of apply_linear call sites), and
    # folding the quantizer into the kernel must not slow steady-state decode
    # vs the unchained integer fast path
    out["int_chain_requant_dispatches"] = (
        out["paged_int_forward_chained"]["int_chain_requant_dispatches"]
    )
    out["int_chain_decode_ratio"] = (
        out["paged_int_forward_chained"]["decode_tok_s"]
        / out["paged_int_forward"]["decode_tok_s"]
        if out["paged_int_forward"]["decode_tok_s"] > 0 else float("inf")
    )
    # observability headlines (run.py claims): the traced engine's decode
    # throughput vs its untraced twin (obs_overhead <= 1.05: span tracing on
    # the dispatch loop costs a clock read + tuple append per span), and the
    # accumulator-headroom telemetry from the deployed integer engine — max
    # static L1 utilization must stay < 1.0 (the A2Q guarantee, Eq. 11) with
    # zero violations across static and observed samples
    out["paged_megastep_traced"] = _stats_row(paged_megat, reqs_t)
    out["obs_overhead"] = (
        out["paged_megastep"]["decode_tok_s"]
        / out["paged_megastep_traced"]["decode_tok_s"]
        if out["paged_megastep_traced"]["decode_tok_s"] > 0 else float("inf")
    )
    out["obs_trace_events"] = len(paged_megat.obs.trace.events)
    hr = engine_headroom(paged_int)
    out["acc_headroom_util_max"] = hr["util_max"]
    out["acc_headroom_observed_frac_max"] = hr["observed_frac_max"]
    out["acc_headroom_violations"] = hr["violations"]
    out["acc_headroom_layers"] = hr["layers"]
    # the prefix-share cliff gate: prefill-dominated latency (TTFT p50) of
    # the sharing engine vs plain paged on the identical workload.  The seed
    # regression was ~13x (a recompile per distinct shared-prefix length);
    # chunk-aligned resume keeps this ~1x (run.py claims <= 1.2)
    out["prefix_share_prefill_ratio"] = (
        out["paged_prefix_share"]["ttft_p50_s"] / out["paged"]["ttft_p50_s"]
        if out["paged"]["ttft_p50_s"] > 0 else float("inf")
    )

    print("engine,tok_s,prefill_tok_s,decode_tok_s,dispatches_per_token,"
          "latency_p50_s,latency_p99_s")
    rows = ["contiguous", "paged", "paged_megastep", "paged_int8_kv",
            "paged_megastep_int8_kv", "paged_int_forward",
            "paged_int_forward_chained", "paged_prefix_share"]
    if "spec" in out:
        rows.append("spec")
    for name in rows:
        r = out[name]
        print(f"{name},{r['tok_s']:.1f},{r['prefill_tok_s']:.1f},{r['decode_tok_s']:.1f},"
              f"{r['dispatches_per_token']:.3f},"
              f"{r['latency_p50_s']:.3f},{r['latency_p99_s']:.3f}")
    print(f"prefill_speedup,{out['prefill_speedup']:.2f},throughput_speedup,"
          f"{out['throughput_speedup']:.2f}")
    print(f"megastep,decode_steps {out['decode_steps']},"
          f"dispatches_per_token {out['megastep_dispatches_per_token']:.3f},"
          f"decode_speedup_vs_tick {out['megastep_decode_speedup']:.2f},"
          f"decode_ratio_vs_contiguous {out['paged_decode_ratio']:.2f}")
    print(f"kv_bytes_per_token,{out['kv_bytes_per_token_fp32']}B fp32,"
          f"{out['kv_bytes_per_token_int8']}B int8,ratio {out['kv_bytes_ratio']:.2f}x,"
          f"decode_ratio {out['int8_kv_decode_ratio']:.2f}")
    print(f"int8_kv_megastep,dispatches_per_token "
          f"{out['int8_kv_megastep_dispatches_per_token']:.3f},"
          f"decode_ratio_vs_tick_int8 {out['int8_kv_megastep_decode_ratio']:.2f}")
    print(f"int_chain,standalone_act_quant {out['int_chain_requant_dispatches']},"
          f"folded {out['paged_int_forward_chained']['int_chain_folded']},"
          f"chained {out['paged_int_forward_chained']['int_chain_chained']},"
          f"decode_ratio_vs_unchained {out['int_chain_decode_ratio']:.2f}")
    print(f"obs,overhead {out['obs_overhead']:.3f},trace_events "
          f"{out['obs_trace_events']},headroom_util_max "
          f"{out['acc_headroom_util_max']:.4f},observed_frac_max "
          f"{out['acc_headroom_observed_frac_max']:.4f},violations "
          f"{out['acc_headroom_violations']}")
    print(f"prefix_share,hits {out['prefix_hits']},shared_tokens "
          f"{out['prefix_hit_tokens']},cow_copies {out['prefix_cow_copies']},"
          f"pinned_tokens {out['prefix_pinned_tokens']},"
          f"prefill_ratio {out['prefix_share_prefill_ratio']:.2f}")
    if "spec" in out:
        print(f"spec,k {out['spec_k']},acceptance {out['spec_acceptance_rate']:.2f},"
              f"decode_speedup {out['spec_decode_speedup']:.2f},"
              f"throughput_speedup {out['spec_throughput_speedup']:.2f}")
    return out


def run_cluster(
    arch_name: str = "yi-6b",
    requests: int = 10,
    max_new: int = 6,
    batch: int = 2,
    max_seq: int = 64,
    block_size: int = 8,
    prefill_chunk: int = 16,
    seed: int = 0,
) -> dict:
    """Two-replica routed cluster vs a single replica on the skewed bursty
    wave, plus a mid-wave replica-kill pass.

    Three passes over the identical workload, all through the Router so the
    single-replica baseline pays the same routing overhead: (1) one replica,
    (2) two replicas, (3) two replicas with the busiest one killed mid-wave.
    Throughput is fleet **capacity** — total tokens over the busiest
    replica's engine-measured busy seconds (the multi-host makespan; see
    ``launch/serve_cluster.py``) — because a single-host CI runner
    interleaves replicas on one core and cannot show wall-clock speedup.
    The 2-replica pass must reach >= 1.6x the 1-replica capacity (a routing
    *balance* claim: a router that piles work on one replica fails it), the
    kill pass must complete every request with token-exact output (the
    at-most-once requeue claim), and all passes must match pass 1
    token-for-token.
    """
    from repro.launch.serve_cluster import aggregate_capacity, build_workload
    from repro.serve.cluster import (
        InProcessReplica, ReplicaConfig, Router, make_cluster_configs,
    )
    from repro.serve.cluster.replica import build_engine

    arch = reduced(get_arch(arch_name))
    params = unbox(init_lm(jax.random.PRNGKey(seed), arch))
    base = ReplicaConfig(
        arch=arch_name, reduced=True, seed=seed, batch=batch, max_seq=max_seq,
        block_size=block_size, prefill_chunk=prefill_chunk,
    )
    cfgs = make_cluster_configs(base, replicas=2)
    # one warmed engine per replica, shared across the timed passes (a fresh
    # InProcessReplica handle per pass wraps the same engine, so XLA compiles
    # are paid once here and the timed passes measure steady-state serving)
    engines = {c.name: build_engine(c, params=params) for c in cfgs}
    rng = np.random.default_rng(seed)
    prompts = build_workload(rng, requests, 12, 4, min(arch.vocab, 50))
    for eng in engines.values():
        warm = [Request(uid=i, prompt=p, max_new=max_new)
                for i, p in enumerate(prompts)]
        _drive_paged(eng, warm)

    def routed_pass(names, kill_after=None):
        for eng in engines.values():
            eng.reset_stats()
        handles = [InProcessReplica(c, engine=engines[c.name])
                   for c in cfgs if c.name in names]
        router = Router(handles)
        rids = [router.submit(p, max_new=max_new) for p in prompts]
        state = {"killed": None}

        def hook(r, step):
            if state["killed"] is not None:
                return
            done = sum(1 for q in r.reqs.values() if q.done)
            if done < kill_after:
                return
            alive = [st for st in r.states.values() if st.alive]
            if len(alive) < 2:
                return
            victim = max(alive, key=lambda st: (len(st.inflight), st.name))
            if victim.inflight:
                r.kill(victim.name)
                state["killed"] = victim.name

        res = router.drain(on_step=hook if kill_after is not None else None)
        outs = [res[r] for r in rids]
        complete = all(q.done and q.emitted for q in router.reqs.values())
        agg = aggregate_capacity(router.collect_stats())
        requeues, deaths = router.requeues, router.deaths
        router.close()
        return outs, agg, requeues, deaths, complete

    outs1, agg1, _, _, _ = routed_pass({cfgs[0].name})
    outs2, agg2, _, _, _ = routed_pass({c.name for c in cfgs})
    assert outs2 == outs1, "2-replica routed output diverged from 1-replica"
    # the kill pass runs last: the victim engine is left with stranded slots
    outs3, _, requeues, deaths, complete = routed_pass(
        {c.name for c in cfgs}, kill_after=max(1, requests // 4))
    assert outs3 == outs1, \
        "requeued requests after the replica kill diverged (duplicate or lost tokens)"

    out = {
        "arch": arch_name,
        "requests": requests,
        "cluster_1rep_tok_s": agg1["agg_tok_s"],
        "cluster_2rep_tok_s": agg2["agg_tok_s"],
        "cluster_busy_s": agg2["busy_s"],
        "cluster_scaling": (agg2["agg_tok_s"] / agg1["agg_tok_s"]
                            if agg1["agg_tok_s"] > 0 else float("inf")),
        "cluster_deaths": deaths,
        "cluster_requeues": requeues,
        # 1.0 iff every request in the kill pass finished with its full,
        # token-exact stream (outs3 equality above guarantees no duplicates)
        "cluster_requeue_complete": float(complete and deaths == 1),
    }
    print("cluster,replicas,agg_tok_s")
    print(f"cluster,1,{out['cluster_1rep_tok_s']:.1f}")
    print(f"cluster,2,{out['cluster_2rep_tok_s']:.1f}")
    print(f"cluster_scaling,{out['cluster_scaling']:.2f},"
          f"requeue_complete,{out['cluster_requeue_complete']:.1f},"
          f"deaths {out['cluster_deaths']},requeues {out['cluster_requeues']}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument("--decode-steps", type=int, default=8,
                    help="fused decode ticks per dispatch for the megastep engine")
    ap.add_argument("--cluster", action="store_true",
                    help="also run the 2-replica routed cluster cohort")
    ap.add_argument("--json", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    out = run(
        arch_name=args.arch, requests=args.requests, max_new=args.max_new,
        batch=args.batch, max_seq=args.max_seq, block_size=args.block_size,
        prefill_chunk=args.prefill_chunk, decode_steps=args.decode_steps,
        seed=args.seed,
    )
    if args.cluster:
        out["cluster"] = run_cluster(
            arch_name=args.arch, requests=args.requests, max_new=args.max_new,
            batch=args.batch, max_seq=args.max_seq, block_size=args.block_size,
            prefill_chunk=args.prefill_chunk, seed=args.seed,
        )
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json}")
    return out


if __name__ == "__main__":
    main()
