"""Serving benchmark: contiguous per-token-prefill baseline vs the paged
engine (fp32 and int8 KV blocks) on a mixed-length workload.

Reports continuous-batching throughput (tok/s, split prefill vs decode) and
per-request end-to-end latency p50/p99 for all three engines, the paged
engine's peak KV block usage vs the contiguous engine's fixed
``batch x max_seq`` footprint, and the KV bytes-per-token the int8 block
pools save (~4x: int8 codes + one fp32 scale per head-slot vs fp32 values).
The int8 engine's greedy tokens are held to the parity bound (token-identical
up to sub-margin quantization ties — see ``launch/serve.py``).  Prints a CSV
like the other ``benchmarks/`` modules and returns a headline dict
(``run.py``-aggregatable); ``--json`` writes the same dict to disk.

Wall-clock on CPU/interpret is not TPU-meaningful in absolute terms, but the
*relative* contiguous-vs-paged comparison is structural: the baseline spends
one jit call per prompt token while the paged engine batches whole chunks,
and that ratio survives any backend.
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.models.lm import init_lm
from repro.nn.module import unbox
from repro.serve.engine import PagedServeEngine, Request, ServeEngine, parity_up_to_ties


def _percentiles(reqs) -> dict:
    lat = np.asarray([r.latency for r in reqs])
    ttft = np.asarray([r.ttft for r in reqs])
    return {
        "latency_p50_s": float(np.percentile(lat, 50)),
        "latency_p99_s": float(np.percentile(lat, 99)),
        "ttft_p50_s": float(np.percentile(ttft, 50)),
        "ttft_p99_s": float(np.percentile(ttft, 99)),
    }


def _stats_row(engine, reqs) -> dict:
    row = engine.throughput()
    row.update(_percentiles(reqs))
    return row


def _drive_contiguous(engine, reqs):
    import time

    for r in reqs:
        r.submitted_at = time.perf_counter()
    if engine.recurrent:
        # the contiguous baseline cannot continuously batch recurrent stacks
        # (slot-at-a-time prefill pollutes every row's non-positional state)
        # and mixed-length prompts rule out multi-request lockstep groups:
        # its honest capability on this workload is one request per group
        for r in reqs:
            engine._generate_lockstep([r])
        return
    pending = list(reqs)
    while pending or any(s is not None for s in engine.slots):
        while pending and engine.admit(pending[0]):
            pending.pop(0)
        if engine.tick() == 0 and not pending:
            break


def _drive_paged(engine, reqs):
    for r in reqs:
        engine.submit(r)
    while not engine.sched.idle():
        engine.step()


def _workload(rng, arch, n, max_new):
    """Mixed-length prompts: the regime where per-token prefill hurts most and
    paged memory reuse matters (short and long requests share slots).  Prompt
    lengths dominate generation lengths, as in real serving traffic."""
    lens = rng.integers(8, 49, size=n)
    return [
        Request(uid=i, prompt=rng.integers(0, arch.vocab, (int(L),)).astype(np.int32),
                max_new=max_new)
        for i, L in enumerate(lens)
    ]


def run(
    arch_name: str = "yi-6b",
    requests: int = 8,
    max_new: int = 4,
    batch: int = 2,
    max_seq: int = 64,
    block_size: int = 8,
    prefill_chunk: int = 16,
    num_blocks=None,
    seed: int = 0,
) -> dict:
    arch = reduced(get_arch(arch_name))
    params = unbox(init_lm(jax.random.PRNGKey(seed), arch))

    def workload():  # identical draw for every engine / pass
        return _workload(np.random.default_rng(seed), arch, requests, max_new)

    contig = ServeEngine(arch, params, batch=batch, max_seq=max_seq)
    paged = PagedServeEngine(
        arch, params, batch=batch, max_seq=max_seq,
        block_size=block_size, prefill_chunk=prefill_chunk, num_blocks=num_blocks,
    )
    paged_q8 = PagedServeEngine(
        arch, params, batch=batch, max_seq=max_seq,
        block_size=block_size, prefill_chunk=prefill_chunk, num_blocks=num_blocks,
        kv_quant=True,
    )
    # Warmup pass covers every jit shape (the paged engine compiles one
    # prefill per distinct chunk length), so the timed pass measures
    # steady-state serving throughput rather than XLA compile time.
    _drive_contiguous(contig, workload())
    _drive_paged(paged, workload())
    _drive_paged(paged_q8, workload())
    for e in (contig, paged, paged_q8):
        e.reset_stats()
    paged.cache.peak_blocks = 0
    paged_q8.cache.peak_blocks = 0

    reqs_c, reqs_p, reqs_q = workload(), workload(), workload()
    _drive_contiguous(contig, reqs_c)
    _drive_paged(paged, reqs_p)
    _drive_paged(paged_q8, reqs_q)

    assert [r.generated for r in reqs_c] == [r.generated for r in reqs_p], \
        "engines diverged on the benchmark workload"
    # int8 KV is lossy: hold it to the parity bound instead of bit equality
    ok, ties, detail = parity_up_to_ties(
        reqs_p, [r.generated for r in reqs_q], eps=0.05
    )
    assert ok, f"int8-KV engine broke the parity bound: {detail}"

    out = {
        "arch": arch_name,
        "requests": requests,
        "contiguous": _stats_row(contig, reqs_c),
        "paged": _stats_row(paged, reqs_p),
        "paged_int8_kv": _stats_row(paged_q8, reqs_q),
        # fixed lanes vs token-proportional blocks (same dtype, so the slot
        # count ratio is the memory ratio for the seq-indexed leaves)
        "contiguous_cache_slots": batch * max_seq,
        "paged_peak_block_tokens": paged.cache.peak_blocks * paged.cache.block_size,
        # the int8-KV headline: HBM bytes one cached token costs, summed over
        # every seq-indexed pool (codes + scales), fp32 blocks vs int8 blocks
        "kv_bytes_per_token_fp32": paged.cache.kv_bytes_per_token(),
        "kv_bytes_per_token_int8": paged_q8.cache.kv_bytes_per_token(),
        "int8_kv_sub_margin_ties": ties,
    }
    # recurrent archs (rwkv6) have no seq-indexed pools at all — nothing to
    # quantize, both byte counts are 0, ratio is the identity
    out["kv_bytes_ratio"] = (
        out["kv_bytes_per_token_fp32"] / out["kv_bytes_per_token_int8"]
        if out["kv_bytes_per_token_int8"] > 0 else 1.0
    )
    out["prefill_speedup"] = (
        out["paged"]["prefill_tok_s"] / out["contiguous"]["prefill_tok_s"]
        if out["contiguous"]["prefill_tok_s"] > 0 else float("inf")
    )
    out["throughput_speedup"] = (
        out["paged"]["tok_s"] / out["contiguous"]["tok_s"]
        if out["contiguous"]["tok_s"] > 0 else float("inf")
    )
    # steady-state decode throughput of int8 blocks vs fp32 blocks: on TPU
    # this is the ~4x-bandwidth win; on CPU/interpret it only proves the
    # quantize/dequant work does not sink the decode path
    out["int8_kv_decode_ratio"] = (
        out["paged_int8_kv"]["decode_tok_s"] / out["paged"]["decode_tok_s"]
        if out["paged"]["decode_tok_s"] > 0 else float("inf")
    )

    print("engine,tok_s,prefill_tok_s,decode_tok_s,latency_p50_s,latency_p99_s")
    for name in ("contiguous", "paged", "paged_int8_kv"):
        r = out[name]
        print(f"{name},{r['tok_s']:.1f},{r['prefill_tok_s']:.1f},{r['decode_tok_s']:.1f},"
              f"{r['latency_p50_s']:.3f},{r['latency_p99_s']:.3f}")
    print(f"prefill_speedup,{out['prefill_speedup']:.2f},throughput_speedup,"
          f"{out['throughput_speedup']:.2f}")
    print(f"kv_bytes_per_token,{out['kv_bytes_per_token_fp32']}B fp32,"
          f"{out['kv_bytes_per_token_int8']}B int8,ratio {out['kv_bytes_ratio']:.2f}x,"
          f"decode_ratio {out['int8_kv_decode_ratio']:.2f}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument("--json", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    out = run(
        arch_name=args.arch, requests=args.requests, max_new=args.max_new,
        batch=args.batch, max_seq=args.max_seq, block_size=args.block_size,
        prefill_chunk=args.prefill_chunk, seed=args.seed,
    )
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json}")
    return out


if __name__ == "__main__":
    main()
