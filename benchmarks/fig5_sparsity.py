"""Paper Fig. 5: sparsity and relative accuracy vs accumulator width.

Trains A2Q models at decreasing P (M=N fixed) and reports unstructured
integer-weight sparsity + accuracy relative to the float baseline.  Claims
validated: sparsity rises monotonically as P falls; relative accuracy stays
near 1.0 until extreme P.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import accuracy, requantized_init, train_classifier
from repro.configs.base import QuantConfig
from repro.core.a2q import a2q_int_weights
from repro.core.bounds import min_accumulator_bits_data_type
from repro.data.synthetic import ImageClassStream
from repro.models.vision import apply_mobilenet_v1, init_mobilenet_v1, vision_penalty


def _model_sparsity(params, q: QuantConfig) -> float:
    zeros = total = 0

    def walk(node):
        nonlocal zeros, total
        if isinstance(node, dict):
            if "v" in node and "t" in node:
                qi, _ = a2q_int_weights(
                    {"v": node["v"], "t": node["t"], "d": node["d"]},
                    q.weight_bits, q.acc_bits, q.act_bits, False,
                )
                a = np.asarray(qi)
                zeros += int((a == 0).sum())
                total += a.size
            else:
                for v in node.values():
                    walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)

    walk(params)
    return zeros / max(total, 1)


def run(steps: int = 40, bits: int = 6) -> dict:
    stream = ImageClassStream(global_batch=64, seed=0)
    init = lambda k, q: init_mobilenet_v1(k, q, width=0.25)

    # float reference
    qf = QuantConfig(mode="none")
    pf = train_classifier(init, apply_mobilenet_v1, qf, stream, steps=steps)
    ref = accuracy(apply_mobilenet_v1, pf, qf, stream)

    bound = min_accumulator_bits_data_type(256, bits, bits, False)
    rows = []
    print(f"float_acc={ref:.4f}  (data-type bound P={bound})")
    print("P,sparsity,acc,relative")
    for P in range(bound, bound - 8, -2):
        q = QuantConfig(mode="a2q", weight_bits=bits, act_bits=bits, acc_bits=P)
        p = train_classifier(init, apply_mobilenet_v1, q, stream, steps=steps,
                             penalty_fn=vision_penalty, optimizer="sgdm", lr=1e-2,
                             init_params=requantized_init(init, pf, q))
        s = _model_sparsity(p, q)
        acc = accuracy(apply_mobilenet_v1, p, q, stream)
        rows.append(dict(P=P, sparsity=s, acc=acc, rel=acc / max(ref, 1e-9)))
        print(f"{P},{s:.4f},{acc:.4f},{acc/max(ref,1e-9):.4f}")

    sp = [r["sparsity"] for r in rows]
    return {
        "rows": rows,
        "float_acc": ref,
        "sparsity_monotone_up": all(b >= a - 0.02 for a, b in zip(sp, sp[1:])),
        "max_sparsity": max(sp),
        "rel_acc_at_P16_band": rows[0]["rel"],
    }


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    a = ap.parse_args()
    out = run(a.steps)
    print({k: v for k, v in out.items() if k != "rows"})
