"""Paper Fig. 3: data-type bound vs weight-norm bound across (K, M, N).

For each (K, data-bits) cell: the Eq. 8 bound, and the median/min/max Eq. 12
bound over 1000 discrete-Gaussian weight samples — showing the weight bound is
consistently tighter, exactly as Fig. 3 visualizes.
"""

from __future__ import annotations

import numpy as np

from repro.core.bounds import min_accumulator_bits_data_type, min_accumulator_bits_weights


def run(samples: int = 1000) -> dict:
    rng = np.random.default_rng(0)
    rows = []
    print("K,bits,dtype_bound,weight_bound_med,weight_bound_min,weight_bound_max")
    for K in (64, 256, 1024, 4096):
        for bits in (4, 6, 8):
            dt = min_accumulator_bits_data_type(K, bits, bits, signed_input=False)
            ws = []
            hi = 2 ** (bits - 1) - 1
            for _ in range(samples):
                w = np.clip(np.round(rng.normal(0, hi / 3, K)), -hi - 1, hi)
                l1 = float(np.abs(w).sum())
                ws.append(min_accumulator_bits_weights(l1, bits, False))
            med, lo, hi_ = int(np.median(ws)), min(ws), max(ws)
            rows.append(dict(K=K, bits=bits, dtype=dt, med=med, min=lo, max=hi_))
            print(f"{K},{bits},{dt},{med},{lo},{hi_}")
    tighter = all(r["max"] <= r["dtype"] for r in rows)
    return {"rows": rows, "weight_bound_always_tighter": tighter}


if __name__ == "__main__":
    out = run()
    print({k: v for k, v in out.items() if k != "rows"})
