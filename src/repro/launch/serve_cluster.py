"""Disaggregated serving-cluster launcher: router + N engine replicas.

    PYTHONPATH=src python -m repro.launch.serve_cluster --arch yi-6b --reduced \
        [--replicas 2 | --disagg P:D] [--policy least-loaded|weighted-latency] \
        [--transport inproc|subproc] [--fault-rate 0.25] \
        [--requests 8 --prompt-len 12 --long-every 4 --max-new 8] \
        [--kv-int8 [--kv-bits 4]] [--int-forward] [--prefix-share] \
        [--decode-steps 8] [--spec-k 4] [--parity-check] [--json PATH]

Builds a fleet of :class:`PagedServeEngine` replicas behind the cluster
:class:`Router` (``serve/cluster/``) and drives a skewed, bursty arrival
wave through it: every request submitted up front, most prompts short and
every ``--long-every``-th one 3x long — the heavy-traffic shape the ROADMAP
names.  ``--disagg P:D`` splits the fleet into prefill-role and decode-role
replicas; prompts run on a prefill replica, whose finished KV blocks migrate
to a decode replica over the paged-pool wire format (no prompt recompute).

``--fault-rate R`` kills ``floor(R * replicas)`` replicas (at least one if
R > 0; never the last one) once a quarter of the wave has completed, then
asserts every request still finishes through the router's requeue path.

``--parity-check`` runs a single engine with the identical flags on the same
workload and fails unless the routed cluster's greedy output is
token-identical (up to quantization ties with ``--kv-int8``) — routing,
failover, and KV migration must be invisible in the token stream.

Aggregate throughput is reported as **capacity**: total tokens produced by
the fleet divided by the *busiest replica's* engine-measured busy time
(prefill_s + decode_s).  On a multi-host deployment each replica owns its
hardware, so the makespan is the slowest replica's busy time; measuring this
way keeps the scaling claim meaningful on a single-host CI runner (which
interleaves the replicas on one core and cannot show wall-clock speedup) —
it is a test of routing *balance*: an unbalanced router piles work on one
replica and fails the >= 1.6x two-replica claim.
"""

from __future__ import annotations

import argparse
import json
import math

import numpy as np


def build_workload(rng, requests: int, prompt_len: int, long_every: int, vocab: int):
    """Skewed burst: short prompts with every ``long_every``-th 3x long."""
    prompts = []
    for i in range(requests):
        n = prompt_len * 3 if long_every and (i % long_every == long_every - 1) else prompt_len
        # jitter short lengths so the wave isn't one lockstep shape
        n = max(2, n + int(rng.integers(-2, 3)))
        prompts.append(rng.integers(1, vocab, size=n).astype(np.int32))
    return prompts


def make_fault_hook(router, n_kill: int, total: int):
    """Kill ``n_kill`` busiest replicas once a quarter of the wave is done."""
    state = {"killed": []}

    def hook(r, step):
        if len(state["killed"]) >= n_kill:
            return
        done = sum(1 for q in r.reqs.values() if q.done)
        if done < max(1, total // 4):
            return
        alive = [st for st in r.states.values() if st.alive]
        victims = sorted(alive, key=lambda st: (-len(st.inflight), st.name))
        for st in victims[: n_kill - len(state["killed"])]:
            if sum(1 for s in r.states.values() if s.alive) <= 1:
                break  # never kill the last replica
            r.kill(st.name)
            state["killed"].append(st.name)

    return hook, state


def aggregate_capacity(stats: dict) -> dict:
    """Fleet capacity from per-replica engine stats: total tokens over the
    busiest replica's busy seconds (the multi-host makespan; see module
    docstring)."""
    toks = sum(s["throughput"]["prefill_tokens"] + s["throughput"]["decode_tokens"]
               for s in stats.values())
    busy = {n: s["throughput"]["prefill_s"] + s["throughput"]["decode_s"]
            for n, s in stats.items()}
    makespan = max(busy.values()) if busy else 0.0
    return {
        "total_tokens": toks,
        "busy_s": busy,
        "makespan_s": makespan,
        "agg_tok_s": toks / makespan if makespan > 0 else 0.0,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--disagg", default=None, help="P:D prefill/decode replica split")
    ap.add_argument("--policy", choices=("least-loaded", "weighted-latency"),
                    default="least-loaded")
    ap.add_argument("--transport", choices=("inproc", "subproc"), default="inproc")
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="fraction of replicas to kill mid-wave (requeue drill)")
    ap.add_argument("--heartbeat-timeout", type=float, default=None,
                    help="seconds of replica silence before failover "
                         "(default: 5 inproc, 300 subproc — a cold subprocess "
                         "replica pays XLA compiles before its first event)")
    ap.add_argument("--no-sticky", action="store_true",
                    help="disable sticky shared-prefix routing")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--long-every", type=int, default=4,
                    help="every Nth request gets a 3x prompt (0 = uniform)")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--num-blocks", type=int, default=None)
    ap.add_argument("--deploy-int8", action="store_true")
    ap.add_argument("--int-forward", action="store_true")
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--kv-bits", type=int, choices=(8, 4), default=8)
    ap.add_argument("--prefix-share", action="store_true")
    ap.add_argument("--decode-steps", type=int, default=1)
    ap.add_argument("--spec-k", type=int, default=0)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--parity-check", action="store_true",
                    help="routed output must be token-identical to one engine")
    ap.add_argument("--parity-eps", type=float, default=0.05)
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the merged fleet metrics view (per-replica "
                         "snapshots + cluster aggregate) to this path")
    ap.add_argument("--json", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.kv_bits != 8 and not args.kv_int8:
        ap.error("--kv-bits only affects integer KV blocks; add --kv-int8")
    if not 0.0 <= args.fault_rate < 1.0:
        ap.error("--fault-rate must be in [0, 1)")

    from repro.configs import get_arch, reduced
    from repro.serve.cluster import (
        InProcessReplica, ReplicaConfig, Router, SubprocessReplica,
        make_cluster_configs, parse_disagg,
    )

    arch = get_arch(args.arch)
    if args.reduced:
        arch = reduced(arch)
    base = ReplicaConfig(
        arch=args.arch, reduced=args.reduced, seed=args.seed,
        batch=args.batch, max_seq=args.max_seq, block_size=args.block_size,
        prefill_chunk=args.prefill_chunk, num_blocks=args.num_blocks,
        kv_quant=args.kv_int8, kv_bits=args.kv_bits,
        prefix_share=args.prefix_share, decode_steps=args.decode_steps,
        eos_id=args.eos_id, deploy_int8=args.deploy_int8,
        int_forward=args.int_forward, spec_k=args.spec_k,
    )
    disagg = parse_disagg(args.disagg) if args.disagg else None
    cfgs = make_cluster_configs(base, replicas=args.replicas, disagg=disagg)
    n_replicas = len(cfgs)
    n_kill = min(math.floor(args.fault_rate * n_replicas) or (1 if args.fault_rate > 0 else 0),
                 n_replicas - 1)

    rng = np.random.default_rng(args.seed)
    prompts = build_workload(rng, args.requests, args.prompt_len,
                             args.long_every, min(arch.vocab, 50))

    params = None
    if args.transport == "inproc":
        # share one host params copy across replicas (and the parity engine)
        from repro.serve.cluster.replica import build_engine  # noqa: F401
        import jax
        from repro.models.lm import init_lm
        from repro.nn.module import unbox

        params = unbox(init_lm(jax.random.PRNGKey(args.seed), arch))
        handles = [InProcessReplica(c, params=params) for c in cfgs]
    else:
        handles = [SubprocessReplica(c) for c in cfgs]
    hb = args.heartbeat_timeout
    if hb is None:
        hb = 5.0 if args.transport == "inproc" else 300.0
    router = Router(handles, policy=args.policy, sticky=not args.no_sticky,
                    heartbeat_timeout=hb)

    roles = {c.name: c.role for c in cfgs}
    print(f"cluster: {n_replicas} replicas {roles} policy={args.policy} "
          f"transport={args.transport} fault_kills={n_kill}")
    rids = [router.submit(p, max_new=args.max_new, eos_id=args.eos_id)
            for p in prompts]
    hook, chaos = (None, {"killed": []})
    if n_kill:
        hook, chaos = make_fault_hook(router, n_kill, len(rids))
    res = router.drain(on_step=hook)
    outs = [res[r] for r in rids]
    incomplete = [r for r in rids
                  if not router.reqs[r].done or not router.reqs[r].emitted]
    assert not incomplete, f"requests never completed: {incomplete}"

    stats = router.collect_stats()
    agg = aggregate_capacity(stats)
    fleet = router.fleet_metrics(stats)
    dispatched = {n: st.dispatched for n, st in router.states.items()}
    migrated = sum(s["migrated_blocks_in"] for s in stats.values())
    report = {
        "replicas": n_replicas, "roles": roles, "policy": args.policy,
        "transport": args.transport, "requests": args.requests,
        "dispatched": dispatched,
        "completed": sum(1 for q in router.reqs.values() if q.done),
        "requeues": router.requeues, "deaths": router.deaths,
        "killed": chaos["killed"],
        "migrated_blocks": migrated,
        "per_replica": {n: s["throughput"] for n, s in stats.items()},
        "served": {n: s["served"] for n, s in stats.items()},
        **agg,
    }
    report["latency"] = {k: fleet[k] for k in
                         ("p50_latency_s", "p99_latency_s", "p50_ttft_s", "p99_ttft_s")}
    report["fleet_requests_completed"] = fleet["requests_completed"]
    print(f"fleet: {agg['total_tokens']} tokens, makespan {agg['makespan_s']:.2f}s "
          f"busiest-replica busy time -> {agg['agg_tok_s']:.1f} tok/s capacity")
    print(f"dispatched per replica: {dispatched} | requeues={router.requeues} "
          f"deaths={router.deaths} migrated_blocks={migrated}")
    print(f"fleet latency: p50 {fleet['p50_latency_s']:.3f}s "
          f"p99 {fleet['p99_latency_s']:.3f}s | ttft p50 {fleet['p50_ttft_s']:.3f}s "
          f"p99 {fleet['p99_ttft_s']:.3f}s "
          f"({fleet['requests_completed']} completions merged from "
          f"{len(fleet['per_replica'])} replicas)")
    if args.metrics_json:
        with open(args.metrics_json, "w") as f:
            json.dump(fleet, f, indent=2, sort_keys=True)
        print(f"wrote fleet metrics to {args.metrics_json}")

    if args.parity_check:
        from repro.models.lm import Runtime
        from repro.serve.cluster.replica import build_engine
        from repro.serve.engine import parity_up_to_ties

        single = build_engine(base, params=params)
        ref_out = single.generate([p.tolist() for p in prompts], max_new=args.max_new)
        if args.kv_int8:
            ok, ties, detail = parity_up_to_ties(single.last_requests, outs,
                                                 args.parity_eps)
            report["parity_sub_margin_ties"] = ties
            if not ok:
                raise SystemExit(f"cluster parity FAILED (int8 KV): {detail}")
            print(f"parity OK (int8 KV): {len(outs)} routed requests "
                  f"token-identical up to {ties} sub-margin ties")
        else:
            if outs != ref_out:
                bad = [i for i, (a, b) in enumerate(zip(outs, ref_out)) if a != b]
                raise SystemExit(f"cluster parity FAILED on requests {bad}: "
                                 f"{outs[bad[0]]} != {ref_out[bad[0]]}")
            print(f"parity OK: {len(outs)} routed requests token-identical "
                  f"to the single engine")
        report["parity"] = True
    router.close()

    for r in rids[: min(4, len(rids))]:
        print(f"req {r}: {res[r]}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {args.json}")
    return report


if __name__ == "__main__":
    main()
