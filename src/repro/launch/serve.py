"""Serving launcher CLI: batched decode with the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
        --requests 6 --prompt-len 12 --max-new 8 [--deploy-int8]

``--deploy-int8`` swaps trained A2Q params for int8 weights + scales before
serving (the paper-guaranteed deployment artifact).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.models.lm import init_lm
from repro.nn.module import unbox
from repro.serve.engine import ServeEngine, deploy_params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--deploy-int8", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    if args.reduced:
        arch = reduced(arch)
    key = jax.random.PRNGKey(args.seed)
    params = unbox(init_lm(key, arch))
    if args.deploy_int8:
        params = deploy_params(params, arch.quant)
        print("serving deployed int8 weights (A2Q-guaranteed accumulator safety)")

    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(0, arch.vocab, (args.prompt_len,)).astype(np.int32)
               for _ in range(args.requests)]
    engine = ServeEngine(arch, params, batch=args.batch, max_seq=args.max_seq)
    t0 = time.perf_counter()
    outs = engine.generate(prompts, max_new=args.max_new)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(o) for o in outs)
    for i, o in enumerate(outs):
        print(f"req {i}: {o}")
    print(f"{total_tokens} tokens in {dt:.2f}s ({total_tokens/dt:.1f} tok/s, "
          f"batch={args.batch}, continuous batching={'off' if engine.recurrent else 'on'})")
    return outs


if __name__ == "__main__":
    main()
