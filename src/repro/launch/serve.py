"""Serving launcher CLI: batched decode with the continuous-batching engines.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
        --requests 6 --prompt-len 12 --max-new 8 \
        [--paged --block-size 16 --prefill-chunk 32] [--deploy-int8] \
        [--int-forward] [--kv-int8 [--kv-bits 4]] \
        [--prefix-share [--shared-prefix 24] [--pin-prompt 32]] \
        [--spec-k 4 [--spec-draft self-int8|<config>]] \
        [--decode-steps 8] [--eos-id N | --eos-auto] \
        [--sample topk --temperature 0.8 --top-k 40] [--parity-check]

``--paged`` serves through :class:`PagedServeEngine` (block-table KV cache,
chunked prefill, on-device sampling); the default is the contiguous baseline.
``--deploy-int8`` swaps trained A2Q params for int8 weights + scales before
serving (the paper-guaranteed deployment artifact).  ``--int-forward``
(implies ``--deploy-int8``) runs those deployed linears through the fused
W8A8 integer kernel instead of dequant + float dot; ``--kv-int8`` stores the
paged KV pools as integer blocks with per-slot scales (~4x KV bytes/token at
the default ``--kv-bits 8``; ``--kv-bits 4`` packs two codes per byte).
``--prefix-share`` dedups common prompt prefixes through the radix prompt
cache (refcounted copy-on-write blocks, LRU/cost eviction).
``--shared-prefix N`` prepends an N-token common prefix to every request so
the cache has something to hit; ``--pin-prompt N`` additionally prefills an
N-token system preamble once pre-traffic and pins it permanently (never
evicted), so even the first request adopts it.

``--spec-k K`` serves through :class:`SpecServeEngine`: K tokens drafted per
round (default drafter ``self-int8`` — the same weights on the integer fast
path — or a named config, e.g. ``--spec-draft smollm-135m``, as a separate
small draft model), verified in one batched call, greedy output token-
identical to plain decode.  Archs with ring/recurrent state (no rollback)
refuse spec mode cleanly and fall back to plain paged decode.

``--decode-steps N`` fuses N paged decode ticks into one jitted megastep
dispatch (on-device position/EOS bookkeeping; dead rows coast into the trash
block), and ``--eos-id``/``--eos-auto`` stop requests at end-of-sequence
instead of always burning the full ``--max-new`` budget.

``--parity-check`` runs the configured engine AND the float dequant
contiguous baseline greedily on the same workload and fails unless their
outputs are token-identical — the CI serve-smoke/spec-smoke gate, covering
the full integer path (int8 weights, W8A8 matmuls, int8 KV) and the
speculative path against float truth.

Throughput is reported split into prefill and decode (one aggregate tok/s
hides that prefill dominates mixed-length workloads).
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.models.lm import Runtime, init_lm
from repro.nn.module import unbox
from repro.obs import Obs
from repro.obs.headroom import engine_headroom
from repro.serve.engine import PagedServeEngine, ServeEngine, deploy_params, parity_up_to_ties
from repro.serve.sampling import SampleConfig


def _spec_report(engine) -> dict:
    """Speculative-decoding stats block (active=False => clean fallback)."""
    out = {
        "active": engine.spec_active() or engine.spec_stats["rounds"] > 0,
        "supported": engine.spec_supported,
        "k": engine.spec_k,
        "acceptance_rate": engine.acceptance_rate(),
        **engine.spec_stats,
    }
    tag = "speculative" if out["supported"] else "speculative UNSUPPORTED (plain fallback)"
    print(f"[{tag}] k={out['k']} rounds={out['rounds']} "
          f"acceptance={out['acceptance_rate']:.2f} bonus={out['bonus']} "
          f"fallback_rounds={out['fallback_rounds']}")
    return out


def _report(tag: str, engine) -> dict:
    tp = engine.throughput()
    print(
        f"[{tag}] prefill: {tp['prefill_tokens']} tok in {tp['prefill_s']:.2f}s "
        f"({tp['prefill_tok_s']:.1f} tok/s) | decode: {tp['decode_tokens']} tok in "
        f"{tp['decode_s']:.2f}s ({tp['decode_tok_s']:.1f} tok/s, "
        f"{tp['decode_dispatches']} dispatches = "
        f"{tp['dispatches_per_token']:.3f}/tok) | overall {tp['tok_s']:.1f} tok/s"
    )
    if "int_chain_requant_dispatches" in tp:
        print(f"[{tag}] chain report: {tp['int_chain_folded']} folded, "
              f"{tp['int_chain_chained']} chained, "
              f"{tp['int_chain_requant_dispatches']} standalone act-quant, "
              f"{tp['int_chain_fallback']} fallback call sites")
    return tp


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--deploy-int8", action="store_true")
    ap.add_argument("--int-forward", action="store_true",
                    help="fused W8A8 integer matmuls for deployed layers (implies --deploy-int8)")
    ap.add_argument("--int-chain", action="store_true",
                    help="int8-out chaining: fold activation quantization into "
                         "the W8A8 kernel (epilogue requant on chained edges, "
                         "prologue quant at chain breaks) so deployed layers "
                         "pay zero standalone act-quant dispatches "
                         "(implies --int-forward)")
    ap.add_argument("--kv-int8", action="store_true",
                    help="integer paged KV blocks with per-slot scales")
    ap.add_argument("--kv-bits", type=int, choices=(8, 4), default=8,
                    help="KV code width with --kv-int8 (4 packs two codes per byte)")
    ap.add_argument("--prefix-share", action="store_true",
                    help="dedup common prompt prefixes via the radix prompt cache")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend an N-token common prefix to every request")
    ap.add_argument("--pin-prompt", type=int, default=0,
                    help="prefill an N-token system preamble once and pin it "
                         "in the prompt cache (prepended to every request; "
                         "requires --prefix-share)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: draft tokens per round (0 = off)")
    ap.add_argument("--spec-draft", default="self-int8",
                    help="drafter: 'self-int8' (same weights, integer fast path) "
                         "or a config name for a small draft model")
    ap.add_argument("--paged", action="store_true", help="serve via PagedServeEngine")
    ap.add_argument("--block-size", type=int, default=16, help="paged KV tokens per block")
    ap.add_argument("--prefill-chunk", type=int, default=32, help="prompt tokens per prefill jit call")
    ap.add_argument("--num-blocks", type=int, default=None, help="paged KV pool size (blocks)")
    ap.add_argument("--decode-kernel", action="store_true",
                    help="route paged decode through the Pallas paged-attention kernel")
    ap.add_argument("--decode-steps", type=int, default=1,
                    help="paged decode ticks fused per jitted dispatch (the "
                         "megastep; 1 = per-tick decode)")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="end-of-sequence token id: requests finish the step "
                         "they emit it instead of decoding to --max-new")
    ap.add_argument("--eos-auto", action="store_true",
                    help="probe a greedy contiguous run and use the token "
                         "request 0 emits mid-stream as the EOS id — "
                         "guarantees the workload exercises early EOS "
                         "termination (the CI serve-smoke cohort)")
    ap.add_argument("--sample", choices=("greedy", "temperature", "topk"), default="greedy")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--parity-check", action="store_true",
                    help="run paged AND contiguous engines; fail on any token mismatch")
    ap.add_argument("--parity-eps", type=float, default=None,
                    help="greedy-margin tie tolerance for --parity-check with --kv-int8 "
                         "(default 0.05; lossless configs always compare exactly)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record request-span traces and write Chrome trace-event "
                         "JSON here (load in Perfetto / chrome://tracing)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the unified metrics snapshot (engine + cache + "
                         "chain + headroom) to this path")
    ap.add_argument("--json", default=None, help="write the stats report to this path")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if not args.paged and not args.parity_check:
        wanted = [
            flag for flag, on in (
                ("--sample", args.sample != "greedy"),
                ("--top-k", args.top_k != 0),
                ("--decode-kernel", args.decode_kernel),
                ("--kv-int8", args.kv_int8),
                ("--num-blocks", args.num_blocks is not None),
                ("--spec-k", args.spec_k > 0),
                ("--prefix-share", args.prefix_share),
                ("--shared-prefix", args.shared_prefix > 0),
                ("--pin-prompt", args.pin_prompt > 0),
                ("--decode-steps", args.decode_steps != 1),
            ) if on
        ]
        if wanted:
            ap.error(f"{', '.join(wanted)} only affect the paged engine; add --paged")
    if args.eos_auto and args.eos_id is not None:
        ap.error("--eos-auto derives the EOS id; drop --eos-id")
    if args.pin_prompt > 0 and not args.prefix_share:
        ap.error("--pin-prompt pins into the prompt cache; add --prefix-share")
    if args.kv_bits != 8 and not args.kv_int8:
        ap.error("--kv-bits only affects integer KV blocks; add --kv-int8")
    if args.spec_draft != "self-int8" and args.spec_k == 0:
        ap.error("--spec-draft only affects speculative decoding; add --spec-k")
    if args.spec_k > 0 and args.sample != "greedy":
        ap.error("--spec-k is lossless for greedy decoding only")

    arch = get_arch(args.arch)
    if args.reduced:
        arch = reduced(arch)
    key = jax.random.PRNGKey(args.seed)
    params = unbox(init_lm(key, arch))
    if args.int_chain:
        args.int_forward = True  # chaining is a mode of the integer fast path
    if args.int_forward:
        args.deploy_int8 = True  # the W8A8 path consumes the deployed artifact
    if args.deploy_int8:
        params = deploy_params(params, arch.quant)
        print("serving deployed int8 weights (A2Q-guaranteed accumulator safety)")
    if args.int_chain:
        print("int-chain: activation quantization folded into the W8A8 kernel "
              "(int8 codes chained between deployed layers)")
    elif args.int_forward:
        print("int-forward: deployed linears run the fused W8A8 integer kernel")

    rng = np.random.default_rng(args.seed)
    # common material is *prepended* to the per-request prompt_len tail:
    # a pinned preamble first (prefilled once, never evicted), then an
    # optional shared prefix (cached from the first request that donates it)
    preamble = (rng.integers(0, arch.vocab, (args.pin_prompt,)).astype(np.int32)
                if args.pin_prompt > 0 else None)
    common = (rng.integers(0, arch.vocab, (args.shared_prefix,)).astype(np.int32)
              if args.shared_prefix > 0 else None)
    head = [p for p in (preamble, common) if p is not None]
    prompts = [np.concatenate(head + [rng.integers(0, arch.vocab, (args.prompt_len,)).astype(np.int32)])
               if head else rng.integers(0, arch.vocab, (args.prompt_len,)).astype(np.int32)
               for _ in range(args.requests)]
    sample = SampleConfig(method=args.sample, temperature=args.temperature, top_k=args.top_k)
    decode_kernel = args.decode_kernel
    if args.parity_check and (args.sample != "greedy" or decode_kernel):
        # the contiguous baseline is always greedy via the gathered-view
        # arithmetic; comparing anything else would fail by construction
        print("parity-check forces greedy sampling on the jnp decode path")
        sample = SampleConfig()
        decode_kernel = False
    if args.eos_auto:
        # greedy contiguous probe: the token request 0 emits halfway through
        # its budget becomes the EOS id — greedy determinism then guarantees
        # at least that request terminates early in every engine under test
        probe = ServeEngine(arch, params, batch=args.batch, max_seq=args.max_seq)
        ptoks = probe.generate(prompts[:1], max_new=args.max_new)[0]
        args.eos_id = int(ptoks[len(ptoks) // 2])
        print(f"eos-auto: eos_id={args.eos_id} (request 0's token at step {len(ptoks) // 2})")

    obs = Obs(trace=bool(args.trace))

    def paged_engine():
        kw = dict(
            batch=args.batch, max_seq=args.max_seq,
            block_size=args.block_size, prefill_chunk=args.prefill_chunk,
            num_blocks=args.num_blocks, sample=sample, seed=args.seed,
            kv_quant=args.kv_int8, kv_bits=args.kv_bits,
            prefix_share=args.prefix_share,
            eos_id=args.eos_id, decode_steps=args.decode_steps, obs=obs,
            rt=Runtime(decode_kernel=decode_kernel, int_forward=args.int_forward,
                       int_chain=args.int_chain),
        )
        if args.spec_k > 0:
            from repro.serve.spec import ModelDrafter, SpecServeEngine

            drafter = None
            if args.spec_draft != "self-int8":
                darch = get_arch(args.spec_draft)
                if args.reduced:
                    darch = reduced(darch)
                if darch.vocab != arch.vocab:
                    raise SystemExit(
                        f"draft config {args.spec_draft} vocab {darch.vocab} != "
                        f"target vocab {arch.vocab}"
                    )
                dparams = unbox(init_lm(jax.random.PRNGKey(args.seed + 1), darch))
                drafter = ModelDrafter(
                    darch, dparams, slots=args.batch, max_seq=args.max_seq,
                    spec_k=args.spec_k, block_size=args.block_size,
                    prefill_chunk=args.prefill_chunk,
                )
            e = SpecServeEngine(arch, params, spec_k=args.spec_k, drafter=drafter, **kw)
        else:
            e = PagedServeEngine(arch, params, **kw)
        if preamble is not None:
            pinned = e.pin_prompt(preamble)
            print(f"pinned system preamble: {pinned} of {len(preamble)} tokens "
                  f"({pinned // e.cache.block_size} blocks, never evicted)")
        return e

    report: dict = {
        "arch": args.arch, "paged": bool(args.paged or args.parity_check),
        "int_forward": args.int_forward, "int_chain": args.int_chain,
        "kv_int8": args.kv_int8,
        "kv_bits": args.kv_bits if args.kv_int8 else None,
        "spec_k": args.spec_k, "prefix_share": args.prefix_share,
        "shared_prefix": args.shared_prefix, "pin_prompt": args.pin_prompt,
        "decode_steps": args.decode_steps, "eos_id": args.eos_id,
    }
    if args.parity_check:
        # the baseline stays on the float truth path: dequant matmuls
        # (default Runtime) over the fp32 contiguous cache — so parity with
        # --int-forward/--kv-int8 gates the whole integer path against it
        contig = ServeEngine(arch, params, batch=args.batch, max_seq=args.max_seq,
                             eos_id=args.eos_id)
        reqs_c: list = []
        if contig.recurrent:
            # the contiguous baseline serves recurrent archs one lockstep
            # group (<= batch equal-length prompts) at a time
            outs_c = []
            for lo in range(0, len(prompts), args.batch):
                outs_c += contig.generate(prompts[lo:lo + args.batch], max_new=args.max_new)
                reqs_c += contig.last_requests
        else:
            outs_c = contig.generate(prompts, max_new=args.max_new)
            reqs_c = contig.last_requests
        pagede = paged_engine()
        outs_p = pagede.generate(prompts, max_new=args.max_new)
        report["contiguous"] = _report("contiguous", contig)
        report["paged_engine"] = _report("paged", pagede)
        report["kv_bytes_per_token"] = pagede.cache.kv_bytes_per_token()
        if args.prefix_share:
            print(f"prefix sharing: {pagede.cache.prefix_hits} hits, "
                  f"{pagede.cache.prefix_hit_tokens} prompt tokens served from "
                  f"shared blocks, {pagede.cache.cow_copies} CoW copies")
            report["prefix_hits"] = pagede.cache.prefix_hits
            report["prefix_hit_tokens"] = pagede.cache.prefix_hit_tokens
            report["cow_copies"] = pagede.cache.cow_copies
        if args.spec_k > 0:
            report["spec"] = _spec_report(pagede)
        if args.kv_int8:
            # int8 KV is lossy: token parity holds up to quantization ties
            # (see serve.engine.parity_up_to_ties and serve/README.md "parity bound")
            eps = 0.05 if args.parity_eps is None else args.parity_eps
            ok, ties, detail = parity_up_to_ties(reqs_c, outs_p, eps)
            report["parity_eps"] = eps
            report["parity_sub_margin_ties"] = ties
            if not ok:
                raise SystemExit(f"parity FAILED (int8 KV, eps={eps}): {detail}")
            print(f"parity OK (int8 KV): {len(outs_p)} requests token-identical "
                  f"up to {ties} sub-margin ties (eps={eps})")
        else:
            if outs_c != outs_p:
                raise SystemExit(f"parity FAILED: contiguous {outs_c} != paged {outs_p}")
            print(f"parity OK: {len(outs_p)} requests token-identical across engines")
        assert report["paged_engine"]["decode_tok_s"] > 0, "no decode throughput measured"
        outs = outs_p
        engine = pagede
    elif args.paged:
        engine = paged_engine()
        outs = engine.generate(prompts, max_new=args.max_new)
        report["paged_engine"] = _report("paged", engine)
        cache = engine.cache
        print(f"paged KV: peak {cache.peak_blocks} blocks "
              f"({cache.peak_blocks * cache.block_size} tokens) of "
              f"{cache.num_blocks - 1} (block_size={cache.block_size}); "
              f"contiguous equivalent {args.batch * args.max_seq} tokens; "
              f"{cache.kv_bytes_per_token()} KV bytes/token"
              f"{' (int8 blocks)' if args.kv_int8 else ''}")
        report["paged_peak_blocks"] = cache.peak_blocks
        report["kv_bytes_per_token"] = cache.kv_bytes_per_token()
        if args.prefix_share:
            print(f"prefix sharing: {cache.prefix_hits} hits, "
                  f"{cache.prefix_hit_tokens} prompt tokens served from shared "
                  f"blocks, {cache.cow_copies} CoW copies")
            report["prefix_hits"] = cache.prefix_hits
            report["prefix_hit_tokens"] = cache.prefix_hit_tokens
            report["cow_copies"] = cache.cow_copies
        if args.spec_k > 0:
            report["spec"] = _spec_report(engine)
    else:
        # the contiguous engine honors --int-forward too (apply_lm threads it
        # through the contiguous cache path) — without this the flag would be
        # a silent no-op here while the banner claims the W8A8 kernel is on
        engine = ServeEngine(arch, params, batch=args.batch, max_seq=args.max_seq,
                             rt=Runtime(int_forward=args.int_forward,
                                        int_chain=args.int_chain),
                             eos_id=args.eos_id, obs=obs)
        outs = engine.generate(prompts, max_new=args.max_new)
        report["contiguous"] = _report("contiguous", engine)

    if args.eos_id is not None:
        report["eos_terminated"] = sum(1 for o in outs if o and o[-1] == args.eos_id)
        print(f"eos: {report['eos_terminated']} of {len(outs)} requests "
              f"terminated on eos_id={args.eos_id}")
    if args.int_forward:
        # accumulator-headroom telemetry: static L1 utilization per deployed
        # layer (the paper's Eq. 11 ratio) plus observed int accumulator
        # magnitudes sampled through an eager probed forward
        hr = engine_headroom(engine)
        report["headroom"] = hr
        print(f"acc headroom: {hr['layers']} deployed layers, "
              f"max static utilization {hr['util_max']:.4f}, "
              f"max observed |acc|/bound {hr['observed_frac_max']:.4f}, "
              f"{hr['violations']} violations")
    for i, o in enumerate(outs):
        print(f"req {i}: {o}")
    if args.trace:
        engine.obs.trace.export(args.trace)
        print(f"wrote trace ({len(engine.obs.trace.events)} events) to {args.trace}")
    if args.metrics_json:
        snap = engine.metrics_snapshot()
        with open(args.metrics_json, "w") as f:
            json.dump(snap, f, indent=2, sort_keys=True)
        print(f"wrote {len(snap)} metrics to {args.metrics_json}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {args.json}")
    return outs


if __name__ == "__main__":
    main()
