import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402  (the two lines above MUST precede any jax-touching import)
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell against
the production mesh and record memory / cost / collective analyses.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder host devices stand in for 2 v5e pods;
``jax.jit(step).lower(...).compile()`` must succeed for every cell, and the
compiled artifact supplies the roofline terms (EXPERIMENTS.md SDry-run /
SRoofline).

Costing methodology: XLA's cost_analysis counts a while-loop (lax.scan) body
ONCE, not x trip-count (verified in tests/test_roofline.py), so the scanned
full graph underreports per-step cost.  The roofline numbers are therefore
reconstructed by *marginal-layer extrapolation*: for every distinct stack
signature we compile unrolled 1-layer and 2-layer variants and take

    total = cost(base: one layer per signature)
          + sum_entries (count_e - 1) * [cost(sig 2-layer) - cost(base)]

which is exact for homogeneous scanned stacks (every layer in a stack has
identical cost by construction).  The full scanned graph is still compiled for
every cell — that compile succeeding IS the dry-run pass, and supplies
memory_analysis + the collective schedule.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-v3-671b \
        --shape decode_32k --opt mla_absorb --tag hc_mla

Results land in experiments/dryrun/<tag>/<arch>__<shape>__<mesh>.json.
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, applicable_shapes, get_arch, input_specs
from repro.dist.collectives import GradCompressConfig, resolve_grad_compress
from repro.dist.sharding import ShardingRules, cache_specs, param_specs
from repro.launch.mesh import make_production_mesh
from repro.models.lm import Runtime, init_cache, init_lm
from repro.models.steps import build_prefill_step, build_serve_step, build_train_step
from repro.nn.module import unbox
from repro.optim.optimizers import adafactor
from repro.roofline.analysis import (
    collective_bytes_from_hlo,
    model_flops,
    roofline_terms,
    wire_bytes,
)

_COST_KEYS = ("flops", "bytes accessed", "transcendentals")


def _sharding(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree, is_leaf=lambda x: isinstance(x, P)
    )


def _param_counts(boxed_shapes, arch) -> dict:
    total = 0
    routed = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(unbox(boxed_shapes))[0]:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        ks = jax.tree_util.keystr(path)
        if "moe" in ks and any(k in ks for k in ("'w_in'", "'w_gate'", "'w_out'")):
            routed += n
    active = total
    for s in arch.stacks:
        if s.moe is not None and routed:
            active = total - routed + routed * s.moe.top_k / s.moe.n_experts
            break
    return {"total": total, "active": active, "routed": routed}


def _make_runtime(arch, mesh, opts):
    rules = ShardingRules.default(
        mesh, arch, fsdp="no_fsdp" not in opts,
        seq_shard_extra="seq_shard_extra" in opts, tp_extra="tp_extra" in opts,
    )
    ep_axis = None
    if any(s.moe is not None for s in arch.stacks):
        # 'ep_both': experts over (model, data) — 1 expert/chip serving layout
        ep_axis = ("model", "data") if "ep_both" in opts else "model"
    grad_compress = None
    if "grad_compress" in opts:
        grad_compress = GradCompressConfig(
            bits=8,
            scale_axis="column" if "grad_compress_column" in opts else "tensor",
        )
    rt = Runtime(
        mesh=mesh, ep_axis=ep_axis, rules=rules,
        mla_absorb="mla_absorb" in opts, grad_compress=grad_compress,
    )
    return rules, rt


def _lower_compile(arch, shape, mesh, rules, rt, opts=frozenset()) -> dict:
    """Lower + compile one step function; return cost/collective/memory info."""
    key = jax.random.PRNGKey(0)
    boxed_shapes = jax.eval_shape(lambda: init_lm(key, arch))
    if "int8_weights" in opts and shape.kind != "train":
        # A2Q-guaranteed int8 weight deployment (beyond-paper memory lever)
        from repro.serve.engine import deploy_boxed

        boxed_shapes = deploy_boxed(boxed_shapes, arch.quant)
    pspecs = param_specs(boxed_shapes, mesh, rules)
    param_shapes = unbox(boxed_shapes)
    counts = _param_counts(boxed_shapes, arch)
    batch_specs = input_specs(arch, shape)

    def bspec(shape_tuple):
        # divisibility-aware: long_500k's global_batch=1 falls back to
        # replicated instead of an invalid P('data') spec
        from repro.dist.sharding import resolve_pspec

        axes = ("batch",) + (None,) * (len(shape_tuple) - 1)
        return resolve_pspec(axes, shape_tuple, mesh, rules)

    batch_sharding = {k: NamedSharding(mesh, bspec(v.shape)) for k, v in batch_specs.items()}

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            optimizer = adafactor()
            opt_shapes = jax.eval_shape(optimizer.init, param_shapes)
            from repro.train.state import init_grad_err, make_state_specs

            gc = resolve_grad_compress(rt.grad_compress, mesh)
            state_spec = make_state_specs(boxed_shapes, optimizer, mesh, rules, grad_compress=gc)
            state_shapes = {
                "params": param_shapes,
                "opt_state": opt_shapes,
                "step": jax.ShapeDtypeStruct((), jnp.int32),
            }
            if gc is not None:
                state_shapes["grad_err"] = jax.eval_shape(
                    lambda: init_grad_err(
                        param_shapes, mesh.shape[gc.axis], pspecs=pspecs, axis=gc.axis
                    )
                )
            jitted = jax.jit(
                build_train_step(arch, optimizer, rt),
                in_shardings=(_sharding(mesh, state_spec), batch_sharding),
                out_shardings=(_sharding(mesh, state_spec), None),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state_shapes, batch_specs)
        elif shape.kind == "prefill":
            jitted = jax.jit(
                build_prefill_step(arch, rt),
                in_shardings=(_sharding(mesh, pspecs), batch_sharding),
            )
            lowered = jitted.lower(param_shapes, batch_specs)
        else:  # decode
            cache_shapes = jax.eval_shape(
                lambda: init_cache(arch, shape.global_batch, shape.seq_len, jnp.bfloat16)
            )
            cspecs = cache_specs(cache_shapes, mesh, rules)
            jitted = jax.jit(
                build_serve_step(arch, rt),
                in_shardings=(
                    _sharding(mesh, pspecs),
                    NamedSharding(mesh, bspec(batch_specs["tokens"].shape)),
                    _sharding(mesh, cspecs),
                    NamedSharding(mesh, P()),
                ),
                out_shardings=(None, _sharding(mesh, cspecs)),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(
                param_shapes,
                batch_specs["tokens"],
                cache_shapes,
                jax.ShapeDtypeStruct((), jnp.int32),
            )
        lower_s = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t1

    info = {"lower_s": round(lower_s, 2), "compile_s": round(compile_s, 2), "counts": counts}
    try:
        mem = compiled.memory_analysis()
        info["memory_analysis"] = {
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        }
    except Exception as e:
        info["memory_analysis"] = {"error": str(e)}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        info["cost"] = {k: float(ca.get(k, 0.0)) for k in _COST_KEYS}
    except Exception as e:
        info["cost"] = {k: 0.0 for k in _COST_KEYS}
        info["cost_error"] = str(e)
    hlo = compiled.as_text()
    info["hlo_bytes"] = len(hlo)
    info["collectives"] = collective_bytes_from_hlo(hlo)
    del hlo, compiled, lowered
    return info


def _stack_signature(s):
    return dataclasses.replace(s, count=1)


def _costing_variants(arch):
    """(base arch, {sig: variant arch}, entry signatures) for extrapolation."""
    sigs = []
    seen = {}
    for s in arch.stacks:
        sig = _stack_signature(s)
        sigs.append(sig)
        seen.setdefault(sig, None)
    distinct = list(seen.keys())
    base = dataclasses.replace(arch, stacks=tuple(distinct), unroll_stacks=True)
    variants = {}
    for sig in distinct:
        stacks = tuple(
            dataclasses.replace(d, count=2) if d == sig else d for d in distinct
        )
        variants[sig] = dataclasses.replace(arch, stacks=stacks, unroll_stacks=True)
    return base, variants, sigs


def _combine(base_info, variant_infos, sigs, counts_per_entry) -> dict:
    """total = base + sum_entries (count-1) * (variant[sig] - base)."""
    out_cost = dict(base_info["cost"])
    out_coll = {
        "total_bytes": base_info["collectives"]["total_bytes"],
        "bytes_by_kind": dict(base_info["collectives"]["bytes_by_kind"]),
    }
    for sig, count in zip(sigs, counts_per_entry):
        v = variant_infos[sig]
        extra = count - 1
        if extra <= 0:
            continue
        for k in _COST_KEYS:
            out_cost[k] += extra * (v["cost"][k] - base_info["cost"][k])
        out_coll["total_bytes"] += extra * (
            v["collectives"]["total_bytes"] - base_info["collectives"]["total_bytes"]
        )
        for kind in out_coll["bytes_by_kind"]:
            out_coll["bytes_by_kind"][kind] += extra * (
                v["collectives"]["bytes_by_kind"][kind]
                - base_info["collectives"]["bytes_by_kind"][kind]
            )
    return {"cost": out_cost, "collectives": out_coll}


def run_cell(
    arch_name: str,
    shape_name: str,
    multi_pod: bool,
    opts: Optional[set] = None,
    out_dir: str = "experiments/dryrun",
    tag: str = "baseline",
    costing: bool = True,
) -> dict:
    opts = opts or set()
    arch = get_arch(arch_name)
    if "remat_none" in opts:
        arch = dataclasses.replace(arch, remat="none")
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules, rt = _make_runtime(arch, mesh, opts)

    record = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": mesh.size,
        "opts": sorted(opts),
        "tag": tag,
    }

    # 1) the required dry-run pass: full scanned graph must lower + compile
    full = _lower_compile(arch, shape, mesh, rules, rt, opts)

    # 1b) train cells with grad_compress ON price the compressed-gradient
    # wire: compile the cell with the opt off and diff the collective
    # schedules.  The int8 all-gather/all-to-all traffic is classified as
    # gradient bytes by roofline.analysis; `wire_bytes_saved` is the
    # measured s8 gradient payload against the fp32 wire the same payload
    # costs uncompressed (32/bits ratio) — the per-cell proof that the
    # gradient traffic crosses the wire `bits`-wide.  `program_wire_delta`
    # is the whole-program ring-convention diff vs the other variant: an
    # honest, noisier number (the grouped-vmap bwd can shift GSPMD's
    # strategies elsewhere in the graph — see dist/README.md).  Cells that
    # never enable the opt skip the twin compile outright — pricing a wire
    # nobody asked for doubled every baseline sweep's train-cell time.
    grad_compress_cmp = None
    if shape.kind == "train" and "grad_compress" in opts:
        bits = 8
        alt_opts = set(opts) - {"grad_compress"}
        alt_rules, alt_rt = _make_runtime(arch, mesh, alt_opts)
        alt = _lower_compile(arch, shape, mesh, alt_rules, alt_rt, alt_opts)
        comp_info, base_info = full, alt
        grad_wire = comp_info["collectives"]["gradient_wire_bytes"]
        fp32_equiv = grad_wire * (32 // bits)
        grad_compress_cmp = {
            "enabled": True,
            "bits": bits,
            "scale_axis": "column" if "grad_compress_column" in opts else "tensor",
            "gradient_wire_bytes": grad_wire,
            "fp32_equivalent_bytes": fp32_equiv,
            "wire_bytes_saved": fp32_equiv - grad_wire,
            "baseline_program_wire": wire_bytes(base_info["collectives"]),
            "compressed_program_wire": wire_bytes(comp_info["collectives"]),
            "program_wire_delta": wire_bytes(base_info["collectives"])
            - wire_bytes(comp_info["collectives"]),
            "baseline_f32_allreduce_bytes": base_info["collectives"]["bytes_by_kind"]["all-reduce"],
            "compressed_f32_allreduce_bytes": comp_info["collectives"]["bytes_by_kind"]["all-reduce"],
        }
        record["grad_compress"] = grad_compress_cmp

    record.update(
        lower_s=full["lower_s"],
        compile_s=full["compile_s"],
        memory_analysis=full["memory_analysis"],
        raw_cost=full["cost"],
        raw_collectives=full["collectives"],
        hlo_bytes=full["hlo_bytes"],
        params_total=full["counts"]["total"],
        params_active=full["counts"]["active"],
    )

    # 2) roofline costing via marginal-layer extrapolation (single-pod table)
    if costing:
        base_arch, variants, sigs = _costing_variants(arch)
        base_info = _lower_compile(base_arch, shape, mesh, rules, rt, opts)
        variant_infos = {
            sig: _lower_compile(va, shape, mesh, rules, rt, opts) for sig, va in variants.items()
        }
        corrected = _combine(base_info, variant_infos, sigs, [s.count for s in arch.stacks])
        record["cost"] = corrected["cost"]
        record["collectives"] = corrected["collectives"]
        record["costing"] = {
            "method": "marginal-layer extrapolation (unrolled 1 vs 2 layer variants)",
            "base_cost": base_info["cost"],
            "n_variants": len(variant_infos),
        }
    else:
        record["cost"] = full["cost"]
        record["collectives"] = {
            "total_bytes": full["collectives"]["total_bytes"],
            "bytes_by_kind": full["collectives"]["bytes_by_kind"],
        }
    if grad_compress_cmp is not None:
        record["collectives"]["wire_bytes_saved"] = grad_compress_cmp["wire_bytes_saved"]
        record["collectives"]["gradient_wire_bytes"] = full["collectives"]["gradient_wire_bytes"]

    if shape.kind == "train":
        mf = model_flops(record["params_active"], shape.global_batch * shape.seq_len, "train")
    elif shape.kind == "prefill":
        mf = model_flops(record["params_active"], shape.global_batch * shape.seq_len, "fwd")
    else:
        mf = model_flops(record["params_active"], shape.global_batch, "fwd")

    terms = roofline_terms(
        flops_per_device=record["cost"]["flops"],
        bytes_per_device=record["cost"]["bytes accessed"],
        collective_bytes_per_device=record["collectives"]["total_bytes"],
        n_chips=mesh.size,
    )
    record["roofline"] = terms
    record["model_flops"] = mf
    flops_dev = record["cost"]["flops"]
    record["useful_flops_ratio"] = (mf / mesh.size) / flops_dev if flops_dev else None

    os.makedirs(os.path.join(out_dir, tag), exist_ok=True)
    fn = os.path.join(out_dir, tag, f"{arch_name}__{shape_name}__{record['mesh']}.json")
    with open(fn, "w") as f:
        json.dump(record, f, indent=1)
    # NB: no bare ternary around the whole f-string here — `f"..." if x else
    # "[ok]"` binds the conditional to the entire print argument and drops the
    # arch/shape/compile info whenever useful_flops_ratio is None.
    useful = record["useful_flops_ratio"]
    line = (
        f"[ok] {arch_name:24s} {shape_name:12s} {record['mesh']:8s} "
        f"compile={record['compile_s']}s dominant={terms['dominant']} "
        f"bound={terms['bound_s']:.4f}s"
    )
    if useful is not None:
        line += f" useful={useful:.3f}"
    if grad_compress_cmp is not None:
        line += f" wire_saved={grad_compress_cmp['wire_bytes_saved']:.3g}B"
    print(line)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--opt", action="append", default=[], help="hillclimb toggles")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-costing", action="store_true", help="compile-only (skip roofline variants)")
    args = ap.parse_args()

    from repro.configs import ARCH_NAMES

    cells = []
    archs = ARCH_NAMES if (args.all or args.arch is None) else [args.arch]
    for a in archs:
        arch = get_arch(a)
        shapes = applicable_shapes(arch) if (args.all or args.shape is None) else [args.shape]
        for s in shapes:
            meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
            for m in meshes:
                cells.append((a, s, m))

    failures = []
    for a, s, m in cells:
        try:
            # roofline costing on the single-pod mesh only (SRoofline is
            # single-pod); the multi-pod pass is the compile proof.
            run_cell(a, s, m, set(args.opt), args.out, args.tag, costing=(not m) and not args.no_costing)
        except Exception:
            failures.append((a, s, "multi" if m else "single"))
            print(f"[FAIL] {a} {s} {'multi' if m else 'single'}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: {failures}")
    print(f"all {len(cells)} cells passed")


if __name__ == "__main__":
    main()
