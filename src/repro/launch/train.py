"""Training launcher CLI.

Runs a real training loop on whatever devices exist: on this CPU container it
drives reduced configs end-to-end (examples + integration tests); on a TPU
fleet the same entrypoint builds the production mesh and shards state/batches
with the exact same code paths the dry-run compiles.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
        --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/run1

Elastic restart: rerun the same command after changing the device fleet; the
mesh planner re-plans and the checkpoint re-shards onto the new mesh.
"""

from __future__ import annotations

import argparse
import json
import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.data.synthetic import TokenStream
from repro.dist.collectives import GradCompressConfig, resolve_grad_compress
from repro.dist.sharding import ShardingRules
from repro.launch.mesh import make_production_mesh
from repro.models.lm import Runtime, init_lm
from repro.models.steps import build_train_step
from repro.nn.module import unbox
from repro.optim.optimizers import adamw, adafactor, sgdm
from repro.optim.schedules import cosine_with_warmup
from repro.train.elastic import StragglerWatchdog, plan_mesh
from repro.train.state import init_grad_err
from repro.train.trainer import Trainer

_OPTS = {"adamw": adamw, "adafactor": adafactor, "sgdm": sgdm}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", help="CPU-runnable reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", choices=sorted(_OPTS), default="adamw")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", choices=["auto", "none"], default="auto")
    ap.add_argument(
        "--grad-compress-bits", type=int, default=0,
        help="int wire width for the data-parallel gradient all-reduce "
             "(0 = off, fp32; 8 = int8 wire with error feedback)",
    )
    ap.add_argument(
        "--grad-compress-scale", choices=["tensor", "column"], default="tensor",
        help="compressed-gradient scale granularity: one scale per leaf, or "
             "one per output column (A2Q+-style)",
    )
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    if args.reduced:
        arch = reduced(arch)

    mesh = None
    rules = None
    if args.mesh == "auto" and jax.device_count() > 1:
        plan = plan_mesh(jax.device_count(), model_divisors=[s.attn.heads for s in arch.stacks if s.attn])
        mesh = jax.make_mesh(plan["shape"], plan["axes"])
        rules = ShardingRules.default(mesh, arch)
        print(f"mesh: {dict(zip(plan['axes'], plan['shape']))}")
    ep_axis = "model" if (mesh is not None and any(s.moe for s in arch.stacks)) else None
    grad_compress = None
    if args.grad_compress_bits:
        grad_compress = GradCompressConfig(
            bits=args.grad_compress_bits, scale_axis=args.grad_compress_scale
        )
    rt = Runtime(mesh=mesh, ep_axis=ep_axis, rules=rules, grad_compress=grad_compress)

    key = jax.random.PRNGKey(args.seed)
    boxed = init_lm(key, arch)
    params = unbox(boxed)
    optimizer = _OPTS[args.optimizer]()
    state = {"params": params, "opt_state": optimizer.init(params), "step": jnp.zeros((), jnp.int32)}
    gc = resolve_grad_compress(grad_compress, mesh)
    if grad_compress is not None and gc is None:
        print("grad-compress requested but no multi-device data axis: running uncompressed")
    if gc is not None:
        from repro.dist.sharding import param_specs

        pspecs = param_specs(boxed, mesh, rules) if rules is not None else None
        state["grad_err"] = init_grad_err(params, mesh.shape[gc.axis], pspecs=pspecs, axis=gc.axis)
        print(f"grad-compress: int{gc.bits} wire over '{gc.axis}' ({gc.scale_axis} scale)")

    sched = cosine_with_warmup(args.lr, warmup=max(args.steps // 20, 1), total=args.steps)
    step_fn = build_train_step(arch, optimizer, rt, lr_schedule=sched)

    stream = TokenStream(vocab=arch.vocab, seq_len=args.seq, global_batch=args.batch, seed=args.seed)
    trainer = Trainer(
        step_fn,
        stream.batch,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        watchdog=StragglerWatchdog(),
    )
    # older checkpoints have no grad_err leaves; residuals restart from zeros
    state, start = trainer.maybe_restore(state, allow_missing=gc is not None)
    if start:
        print(f"resumed from step {start}")
    from repro.train.checkpoint import install_signal_handler

    if args.ckpt_dir:
        install_signal_handler(trainer.emergency_save)

    result = trainer.run(state, args.steps, start_step=start)
    for rec in result.history[:3] + result.history[-3:]:
        print({k: round(v, 4) if isinstance(v, float) else v for k, v in rec.items()})
    if result.straggler_events:
        print(f"straggler events: {len(result.straggler_events)}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(result.history, f, indent=1)
    first, last = result.history[0]["loss"], result.history[-1]["loss"]
    print(f"loss {first:.4f} -> {last:.4f}")
    return result


if __name__ == "__main__":
    main()
