"""Production mesh factory.  A FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — only the dry-run (and a
real launcher) ever calls it."""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (256-chip v5e pod) single-pod mesh, or 2x16x16 across 2 pods.

    Axes: ``data`` = FSDP+DP, ``model`` = TP/EP/split-KV, ``pod`` = outer DP
    (one DCN-crossing gradient reduction per step).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
