"""jax version compatibility shims.

Call-sites across the repo (and the subprocess bodies in the test suite) use
the modern spellings ``jax.shard_map`` and its ``check_vma=`` keyword.  Older
jax releases only provide ``jax.experimental.shard_map.shard_map``, and a
middle window exports ``jax.shard_map`` whose keyword is still named
``check_rep``.  Importing :mod:`repro` installs a thin adapter so one
spelling works everywhere.

The adapter is additive only: on a jax whose ``jax.shard_map`` already
accepts ``check_vma`` nothing is touched.
"""

from __future__ import annotations

import inspect

import jax


def _accepts_check_vma(fn) -> bool:
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return True  # can't introspect: assume modern, don't wrap
    return "check_vma" in params or any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()
    )


if not hasattr(jax, "shard_map") or not _accepts_check_vma(jax.shard_map):
    if hasattr(jax, "shard_map"):
        _shard_map = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, mesh=None, in_specs=None, out_specs=None,
                  check_vma=None, check_rep=None, **kwargs):
        check = True
        if check_vma is not None:
            check = check_vma
        elif check_rep is not None:
            check = check_rep
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=check, **kwargs,
        )

    jax.shard_map = shard_map
