"""Render the EXPERIMENTS.md roofline table from experiments/dryrun JSONs.

    PYTHONPATH=src python -m repro.roofline.report [--tag baseline] [--mesh 16x16]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

_SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def load(tag: str = "baseline", out_dir: str = "experiments/dryrun"):
    recs = []
    for fn in glob.glob(os.path.join(out_dir, tag, "*.json")):
        with open(fn) as f:
            recs.append(json.load(f))
    recs.sort(key=lambda r: (r["arch"], _SHAPE_ORDER.get(r["shape"], 9), r["mesh"]))
    return recs


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def table(recs, mesh: str = "16x16") -> str:
    rows = [
        "| arch | shape | compute | memory | collective | dominant | useful FLOPs | compile |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        t = r["roofline"]
        u = r.get("useful_flops_ratio")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(t['compute_s'])} | "
            f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
            f"{t['dominant'].replace('_s','')} | {u:.3f} | {r['compile_s']:.0f}s |"
            if u is not None
            else f"| {r['arch']} | {r['shape']} | - | - | - | - | - | {r['compile_s']:.0f}s |"
        )
    return "\n".join(rows)


def multi_pod_table(recs) -> str:
    rows = [
        "| arch | shape | compile | collectives (AR/AG/RS/A2A/CP) | coll bytes/dev |",
        "|---|---|---|---|---|",
    ]
    for r in recs:
        if r["mesh"] != "2x16x16":
            continue
        c = r["raw_collectives"]["counts"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['compile_s']:.0f}s | "
            f"{c['all-reduce']}/{c['all-gather']}/{c['reduce-scatter']}/"
            f"{c['all-to-all']}/{c['collective-permute']} | "
            f"{r['raw_collectives']['total_bytes']/1e6:.1f}MB |"
        )
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    recs = load(args.tag, args.out)
    print(f"### Roofline ({args.mesh}, tag={args.tag}, {len(recs)} records)\n")
    print(table(recs, args.mesh))
    if any(r["mesh"] == "2x16x16" for r in recs):
        print("\n### Multi-pod (2x16x16) compile proof\n")
        print(multi_pod_table(recs))


if __name__ == "__main__":
    main()
