"""Three-term roofline from the compiled dry-run artifact.

    compute    = FLOPs / (chips * peak)
    memory     = HBM bytes / (chips * HBM bw)
    collective = collective bytes / (chips * link bw)

Sources: ``compiled.cost_analysis()`` provides per-device FLOPs and bytes
(XLA's post-partitioning module is the per-device program, so these are
already divided by the mesh).  Collective bytes are NOT in cost_analysis:
``collective_bytes_from_hlo`` parses the optimized HLO and sums the result
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (a consistent per-chip wire-bytes proxy: ring all-reduce
moves ~2x the buffer, all-gather ~1x the result — we record raw result bytes
per op kind so any convention can be recomputed; the *deltas* the perf loop
optimizes are convention-independent).

``MODEL_FLOPS = 6*N*D`` (dense) / ``6*N_active*D`` (MoE) gives the useful-work
ratio that catches remat/redundancy waste.

Compressed-gradient classification: ``dist.collectives.compressed_psum`` puts
the data-parallel gradient on the wire as s8/s16 integers (an all-to-all plus
an all-gather per leaf).  No other path in the repo moves low-bit *integers*
through a collective, so an s8/s16/u8/u16 all-gather / all-to-all IS gradient
traffic — ``collective_bytes_from_hlo`` reports it separately as
``gradient_wire_bytes`` so the dry-run can price the gradient path on its own.
``wire_bytes`` converts raw result bytes into the ring-algorithm wire
convention (all-reduce moves ~2x its buffer, everything else ~1x), which is
the basis for the ``wire_bytes_saved`` number the dry-run records.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

from repro.roofline import hw

__all__ = ["collective_bytes_from_hlo", "wire_bytes", "roofline_terms", "model_flops"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")

# result of an HLO op:  %name = bf16[8,128,4096]{2,1,0} all-reduce(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\b(" + "|".join(_COLLECTIVES) + r")\b"
)
_TUPLE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_GRADIENT_WIRE_DTYPES = ("s8", "u8", "s16", "u16")


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum collective result bytes by op kind over an optimized HLO module.

    Low-bit integer (s8/s16) all-gather / all-to-all results are additionally
    classified as compressed-gradient traffic (``gradient_wire_bytes``): only
    ``dist.collectives`` puts integer payloads that narrow on the wire.
    """
    per_kind = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    gradient_wire = 0
    gradient_count = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        kind = None
        for k in _COLLECTIVES:
            # match the op name with word-ish boundaries: "all-reduce(", "all-reduce-start("
            if f" {k}(" in stripped or f" {k}-start(" in stripped or f"{k}-done(" in stripped:
                kind = k
                break
        if kind is None:
            continue
        if f"{kind}-done(" in stripped:
            continue  # avoid double counting start/done pairs
        # take the result type(s) on the lhs of '='
        lhs = stripped.split("=", 1)
        if len(lhs) != 2:
            continue
        header = lhs[1].split(kind)[0]
        total = 0
        int_bytes = 0
        for dtype, dims in _TUPLE_RE.findall(header):
            nbytes = _shape_bytes(dtype, dims)
            total += nbytes
            if dtype in _GRADIENT_WIRE_DTYPES:
                int_bytes += nbytes
        per_kind[kind] += total
        counts[kind] += 1
        if int_bytes and kind in ("all-gather", "all-to-all"):
            gradient_wire += int_bytes
            gradient_count += 1
    return {
        "bytes_by_kind": per_kind,
        "counts": counts,
        "total_bytes": sum(per_kind.values()),
        "gradient_wire_bytes": gradient_wire,
        "gradient_wire_counts": gradient_count,
    }


# Ring-algorithm wire weight per result byte: a ring all-reduce moves
# ~2x its buffer (reduce-scatter pass + all-gather pass); gather/scatter/
# permute collectives move ~1x their result.
_WIRE_WEIGHT = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def wire_bytes(collectives: dict) -> float:
    """Result-byte record -> estimated per-chip wire bytes (ring convention)."""
    return sum(
        _WIRE_WEIGHT.get(kind, 1.0) * b
        for kind, b in collectives["bytes_by_kind"].items()
    )


def model_flops(n_params: float, tokens: float, kind: str = "train") -> float:
    """6*N*D for training; 2*N*D for a forward/decode pass."""
    factor = 6.0 if kind == "train" else 2.0
    return factor * n_params * tokens


def roofline_terms(
    *,
    flops_per_device: float,
    bytes_per_device: float,
    collective_bytes_per_device: float,
    n_chips: int,
    links_per_chip: int = 4,
) -> dict:
    """Seconds per step for each roofline term, per chip."""
    compute = flops_per_device / hw.PEAK_FLOPS_BF16
    memory = bytes_per_device / hw.HBM_BW
    collective = collective_bytes_per_device / (hw.ICI_LINK_BW * links_per_chip)
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    terms.update(
        dominant=dominant,
        bound_s=bound,
        # fraction of roofline: how close the *dominant* term is to being the
        # only cost — bound/(sum) == 1 means perfectly balanced on one wall.
        roofline_fraction=(compute / bound) if bound > 0 else 0.0,
        n_chips=n_chips,
    )
    return terms
