"""TPU v5e hardware constants (the dry-run's roofline denominators)."""

PEAK_FLOPS_BF16 = 197e12  # per chip, bf16
HBM_BW = 819e9  # bytes/s per chip
ICI_LINK_BW = 50e9  # bytes/s per link (~45-50 GB/s each direction)
VMEM_BYTES = 128 * 1024 * 1024 // 8  # 16 MiB
CHIPS_PER_POD = 256  # 16x16 v5e pod
