from repro.roofline import hw  # noqa: F401
from repro.roofline.analysis import collective_bytes_from_hlo, model_flops, roofline_terms  # noqa: F401
