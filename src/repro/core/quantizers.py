"""Baseline quantization-aware-training (QAT) operators — paper Section 2.1.

Implements the standard uniform affine quantize/dequantize pipeline used by the
paper's *baseline* QAT algorithm (the thing A2Q is compared against), plus the
shared primitives A2Q builds on:

* straight-through-estimator rounding (half-way and round-toward-zero),
* per-channel / per-tensor scales, exponentially parameterized ``s = 2**d``
  with ``d`` learned by SGD (paper Sec. 4.1, following Jain et al.),
* weight quantizers with ``z = 0`` (paper convention), activation quantizers
  signed or unsigned depending on the preceding nonlinearity.

Everything is a pure function over explicit parameter pytrees so it composes
with pjit/shard_map and ``jax.lax.scan`` over layers.
"""

from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.bounds import int_range

RoundMode = Literal["nearest", "to_zero"]

__all__ = [
    "ste_round",
    "ste_round_to_zero",
    "fake_quant",
    "init_weight_qat",
    "apply_weight_qat",
    "weight_qat_int",
    "init_act_quant",
    "apply_act_quant",
    "act_quant_int",
]


def ste_round(x: jnp.ndarray) -> jnp.ndarray:
    """Half-way rounding with a straight-through gradient (grad == 1)."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def ste_round_to_zero(x: jnp.ndarray) -> jnp.ndarray:
    """Round toward zero (truncate) with a straight-through gradient.

    A2Q's rounding mode: truncation can only *shrink* magnitudes, so the
    integer l1 norm can never round upward past the accumulator budget
    (paper Sec. 4.1, footnote 2).
    """
    return x + jax.lax.stop_gradient(jnp.trunc(x) - x)


_ROUND = {"nearest": ste_round, "to_zero": ste_round_to_zero}


def fake_quant(
    x: jnp.ndarray,
    scale: jnp.ndarray,
    bits: int,
    signed: bool,
    round_mode: RoundMode = "nearest",
) -> jnp.ndarray:
    """quantize (Eq. 1, z=0) then dequantize (Eq. 2): clip(round(x/s)) * s.

    Gradients: STE through the rounding, clipped-STE through the clip (zero
    outside the representable range), and LSQ-style gradients w.r.t. ``scale``
    through both the division and the final multiply.
    """
    n, p = int_range(bits, signed)
    q = jnp.clip(_ROUND[round_mode](x / scale), n, p)
    return q * scale


# ---------------------------------------------------------------------------
# Weight quantizer (per-channel, z = 0, learned log2 scale)
# ---------------------------------------------------------------------------


def _channel_reduce(w: jnp.ndarray, op) -> jnp.ndarray:
    """Reduce every axis except the last (output-channel) axis."""
    axes = tuple(range(w.ndim - 1))
    return op(w, axis=axes)


def init_weight_qat(w: jnp.ndarray, bits: int, per_channel: bool = True) -> dict:
    """Calibrate the learned log2-scale from the float weights (max-abs init)."""
    _, p = int_range(bits, signed=True)
    if per_channel:
        absmax = _channel_reduce(jnp.abs(w), jnp.max)
    else:
        absmax = jnp.max(jnp.abs(w))
    absmax = jnp.maximum(absmax, 1e-8)
    return {"log2_scale": jnp.log2(absmax / p).astype(jnp.float32)}


def apply_weight_qat(params: dict, w: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Fake-quantized weights (float domain). Weights are always signed, z=0."""
    scale = jnp.exp2(params["log2_scale"].astype(w.dtype))
    return fake_quant(w, scale, bits, signed=True, round_mode="nearest")


def weight_qat_int(params: dict, w: jnp.ndarray, bits: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(integer weights, per-channel scale) — the inference-time artifacts."""
    scale = jnp.exp2(params["log2_scale"].astype(w.dtype))
    n, p = int_range(bits, signed=True)
    q = jnp.clip(jnp.round(w / scale), n, p)
    return q, scale


# ---------------------------------------------------------------------------
# Activation quantizer (per-tensor, learned log2 scale)
# ---------------------------------------------------------------------------


def init_act_quant(bits: int, signed: bool, init_absmax: float = 6.0) -> dict:
    """Per-tensor learned log2 scale. ``init_absmax`` approximates the dynamic
    range after the preceding nonlinearity (6.0 suits ReLU-family nets)."""
    _, p = int_range(bits, signed)
    return {"log2_scale": jnp.asarray(jnp.log2(init_absmax / p), dtype=jnp.float32)}


def apply_act_quant(params: dict, x: jnp.ndarray, bits: int, signed: bool) -> jnp.ndarray:
    scale = jnp.exp2(params["log2_scale"].astype(x.dtype))
    return fake_quant(x, scale, bits, signed=signed, round_mode="nearest")


def act_quant_int(params: dict, x: jnp.ndarray, bits: int, signed: bool) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(integer activations, scale) for integer-exact inference simulation."""
    scale = jnp.exp2(params["log2_scale"].astype(x.dtype))
    n, p = int_range(bits, signed)
    q = jnp.clip(jnp.round(x / scale), n, p)
    return q, scale
