"""Analytical FINN-style LUT cost model (paper Sec. 5.3, Fig. 6/7).

The paper evaluates HW-SW co-design by generating FINN streaming accelerators
and reading LUT utilization estimates.  No FPGA toolchain exists offline, so
this module reimplements the published FINN-R matrix-vector-activation-unit
(MVAU) cost relations as an analytical model.  It reproduces the *structure* of
the paper's resource accounting:

* **compute LUTs** — MAC cost grows with weight width M, input width N, and the
  accumulator width P (the adder chain and register are P bits wide),
* **weight-memory LUTs** — distributed LUTRAM storing M-bit weights,
* **threshold-memory LUTs** — FINN lowers quantized activations to threshold
  comparisons; storage grows with the number of thresholds ``2**N_out - 1``
  *and* their width, which is the accumulator width P (Sec. 5.3.1: "their
  resource utilization exponentially grows with the precision of the
  accumulator and output activations").

Constants are calibrated to FINN-R's published LUT-per-op figures; absolute
numbers are estimates, but the model preserves the orderings the paper's
Pareto analysis depends on (P ↓ ⇒ LUT ↓, monotone in M and N).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Sequence

__all__ = ["LayerGeometry", "mvau_luts", "model_luts"]

# Calibration constants (LUT6 counts), from FINN-R-style cost relations.
_LUT_PER_MAC_BITPRODUCT = 1.1  # multiplier LUTs ~ M*N bit-partial-products
_LUT_PER_ADDER_BIT = 0.65  # carry-chain adder + accumulator register
_LUTRAM_BITS = 64.0  # one LUT6 provides 64 bits of distributed RAM
_THRESHOLD_OVERHEAD = 1.0  # comparator tree per threshold bit


@dataclass(frozen=True)
class LayerGeometry:
    """One matmul/conv layer as FINN sees it: C_out accumulators of length K."""

    k: int  # dot-product length (C_in * kernel_h * kernel_w)
    c_out: int
    macs: int  # total MACs per inference (k * c_out * spatial positions)
    weight_bits: int  # M
    input_bits: int  # N (of this layer's input activations)
    output_bits: int  # N of the activation it feeds (threshold count driver)
    acc_bits: int  # P
    sparsity: float = 0.0  # fraction of zero integer weights (A2Q payoff)
    pe: int = 1  # processing elements (output parallelism)
    simd: int = 1  # SIMD lanes (input parallelism)


def mvau_luts(g: LayerGeometry, exploit_sparsity: bool = False) -> dict:
    """LUT estimate for one MVAU instantiation, split compute vs memory."""
    units = g.pe * g.simd
    mult = _LUT_PER_MAC_BITPRODUCT * g.weight_bits * g.input_bits
    adder = _LUT_PER_ADDER_BIT * g.acc_bits
    compute = units * (mult + adder)

    weight_bits_total = g.k * g.c_out * g.weight_bits
    if exploit_sparsity:
        # CSR-ish packing: values + small index overhead on surviving weights.
        density = max(1.0 - g.sparsity, 0.0)
        weight_bits_total = g.k * g.c_out * density * (g.weight_bits + 4)
    weight_mem = weight_bits_total / _LUTRAM_BITS

    n_thresholds = (2**g.output_bits - 1) if g.output_bits > 0 else 0
    thresh_bits = g.c_out * n_thresholds * g.acc_bits
    thresh_mem = thresh_bits / _LUTRAM_BITS + _THRESHOLD_OVERHEAD * n_thresholds * g.acc_bits / 8.0

    return {
        "compute": compute,
        "weight_mem": weight_mem,
        "threshold_mem": thresh_mem,
        "total": compute + weight_mem + thresh_mem,
    }


def model_luts(
    layers: Sequence[LayerGeometry],
    exploit_sparsity: bool = False,
) -> dict:
    """Aggregate the per-layer MVAU estimates for a whole QNN."""
    agg = {"compute": 0.0, "weight_mem": 0.0, "threshold_mem": 0.0, "total": 0.0}
    per_layer = []
    for g in layers:
        r = mvau_luts(g, exploit_sparsity=exploit_sparsity)
        per_layer.append(r)
        for k in agg:
            agg[k] += r[k]
    agg["per_layer"] = per_layer
    return agg
