"""Core A2Q library: accumulator bounds, quantizers, the A2Q operator, the
bit-exact integer simulator, sparsity accounting, and the FINN LUT cost model."""

from repro.core import a2q, bounds, integer, lut, quantizers, sparsity  # noqa: F401
from repro.core.a2q import (  # noqa: F401
    a2q_channel_l1,
    a2q_int_weights,
    a2q_norm_cap,
    a2q_penalty,
    apply_a2q,
    init_a2q,
)
from repro.core.bounds import (  # noqa: F401
    data_type_bound,
    int_range,
    l1_budget,
    min_accumulator_bits_data_type,
    min_accumulator_bits_weights,
    weight_norm_bound,
)
from repro.core.quantizers import (  # noqa: F401
    apply_act_quant,
    apply_weight_qat,
    fake_quant,
    init_act_quant,
    init_weight_qat,
    ste_round,
    ste_round_to_zero,
)
