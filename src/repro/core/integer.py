"""Bit-exact integer accumulation simulator (numpy, deliberately outside jit).

This is the framework's *audit* path: it replays any quantized dot product /
linear layer with true fixed-point accumulator semantics —

* ``exact``     : ideal wide accumulator (int64), the ground truth,
* ``wrap``      : two's-complement wraparound at ``P`` bits (what cheap hardware
                  does on overflow; paper Fig. 2 "black stars"),
* ``saturate``  : clip to the P-bit range *after every MAC* (industry-standard
                  saturation logic; paper Fig. 2 "blue triangles").  Saturation
                  is order-dependent — it breaks associativity (Appendix A.1) —
                  so an explicit MAC ``order`` permutation is supported.

Wraparound is modular arithmetic, hence associative: wrapping once at the end
equals wrapping after every MAC.  We still expose sequential wrapping for the
tests that prove that equivalence.

The simulator is what *proves* A2Q's guarantee in this repo: for A2Q-trained
layers, ``exact == wrap == saturate`` for every input and every MAC order,
because no intermediate partial sum can leave the P-bit range.
"""

from __future__ import annotations

from typing import Literal, Optional

import numpy as np

AccMode = Literal["exact", "wrap", "saturate"]

__all__ = [
    "wrap_to_bits",
    "saturate_to_bits",
    "accumulate_dot",
    "overflow_stats",
    "mac_order_audit",
]


def wrap_to_bits(v: np.ndarray, bits: int) -> np.ndarray:
    """Two's-complement wraparound of int64 values to a ``bits``-wide register."""
    m = np.int64(1) << np.int64(bits)
    half = np.int64(1) << np.int64(bits - 1)
    return ((v.astype(np.int64) + half) % m) - half


def saturate_to_bits(v: np.ndarray, bits: int) -> np.ndarray:
    lo = -(np.int64(1) << np.int64(bits - 1))
    hi = (np.int64(1) << np.int64(bits - 1)) - 1
    return np.clip(v.astype(np.int64), lo, hi)


def _check_int(a: np.ndarray, name: str) -> np.ndarray:
    a = np.asarray(a)
    if not np.issubdtype(a.dtype, np.integer):
        if not np.all(a == np.round(a)):
            raise ValueError(f"{name} must hold integers; got non-integral values")
        a = a.astype(np.int64)
    return a.astype(np.int64)


def accumulate_dot(
    x: np.ndarray,
    w: np.ndarray,
    acc_bits: int,
    mode: AccMode = "exact",
    order: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Simulate ``y[b, c] = sum_k x[b, k] * w[k, c]`` in a P-bit accumulator.

    Args:
      x: (B, K) or (K,) integer inputs.
      w: (K, C) or (K,) integer weights.
      acc_bits: accumulator width P (signed).
      mode: accumulator overflow semantics.
      order: optional permutation of ``range(K)`` giving MAC execution order
        (models out-of-order hardware; only observable under ``saturate``).

    Returns (B, C) int64 results under the requested semantics.
    """
    x = _check_int(x, "x")
    w = _check_int(w, "w")
    if x.ndim == 1:
        x = x[None, :]
    if w.ndim == 1:
        w = w[:, None]
    B, K = x.shape
    K2, C = w.shape
    if K != K2:
        raise ValueError(f"K mismatch: x has {K}, w has {K2}")
    if order is None:
        order = np.arange(K)
    order = np.asarray(order)
    if sorted(order.tolist()) != list(range(K)):
        raise ValueError("order must be a permutation of range(K)")

    if mode == "exact":
        return x @ w

    if mode == "wrap":
        # Modular arithmetic is associative: wrapping the exact sum once equals
        # wrapping after every MAC (tested in tests/test_integer.py). int64
        # holds the exact sum for every (K, M, N) this repo uses.
        return wrap_to_bits(x @ w, acc_bits)

    if mode == "saturate":
        acc = np.zeros((B, C), dtype=np.int64)
        xt = x[:, order]  # (B, K)
        wt = w[order, :]  # (K, C)
        for k in range(K):
            acc = saturate_to_bits(acc + xt[:, k : k + 1] * wt[k : k + 1, :], acc_bits)
        return acc

    raise ValueError(f"unknown accumulator mode {mode!r}")


def overflow_stats(
    x: np.ndarray,
    w: np.ndarray,
    acc_bits: int,
    order: Optional[np.ndarray] = None,
) -> dict:
    """Count intermediate partial sums that leave the P-bit range.

    Uses exact prefix sums (the value a wide register would hold) and counts
    prefixes outside ``[-2**(P-1), 2**(P-1)-1]``.  Returns per-dot-product
    overflow *events* plus the rate (events / (K * B * C)) the paper's Fig. 2
    plots as "overflows per dot product".
    """
    x = _check_int(x, "x")
    w = _check_int(w, "w")
    if x.ndim == 1:
        x = x[None, :]
    if w.ndim == 1:
        w = w[:, None]
    B, K = x.shape
    _, C = w.shape
    if order is None:
        order = np.arange(K)
    lo = -(np.int64(1) << np.int64(acc_bits - 1))
    hi = (np.int64(1) << np.int64(acc_bits - 1)) - 1
    # prefix[b, k, c] = sum of first k+1 MACs — built without materializing
    # (B, K, C) at once for huge K by chunking over C.
    events = 0
    total = 0
    chunk = max(1, int(2**22 // max(K * B, 1)))
    for c0 in range(0, C, chunk):
        wc = w[order][:, c0 : c0 + chunk]  # (K, c)
        prods = x[:, order, None].astype(np.int64) * wc[None, :, :]
        prefix = np.cumsum(prods, axis=1)
        bad = (prefix < lo) | (prefix > hi)
        events += int(bad.sum())
        total += int(np.prod(bad.shape))
    return {
        "events": events,
        "macs": total,
        "dot_products": B * C,
        "overflows_per_dot": events / max(B * C, 1),
        "overflow_rate": events / max(total, 1),
    }


def mac_order_audit(
    x: np.ndarray,
    w: np.ndarray,
    acc_bits: int,
    n_orders: int = 8,
    seed: int = 0,
) -> dict:
    """Replay the dot product under ``n_orders`` random MAC orders with
    saturating accumulators and report the spread of results (Appendix A.1:
    saturation breaks associativity; A2Q-trained layers must show zero spread).
    """
    rng = np.random.default_rng(seed)
    x = _check_int(x, "x")
    w = _check_int(w, "w")
    if x.ndim == 1:
        x = x[None, :]
    if w.ndim == 1:
        w = w[:, None]
    K = x.shape[1]
    exact = accumulate_dot(x, w, 64, mode="exact")
    results = []
    for i in range(n_orders):
        order = np.arange(K) if i == 0 else rng.permutation(K)
        results.append(accumulate_dot(x, w, acc_bits, mode="saturate", order=order))
    stack = np.stack(results)  # (n_orders, B, C)
    spread = stack.max(axis=0) - stack.min(axis=0)
    err = np.abs(stack - exact[None]).astype(np.float64)
    return {
        "max_spread": int(spread.max()),
        "mean_abs_error": float(err.mean()),
        "max_abs_error": float(err.max()),
        "order_invariant": bool(spread.max() == 0),
        "matches_exact": bool(err.max() == 0),
    }
