"""A2Q: accumulator-aware quantization (paper Section 4, Eq. 16-23).

The weight quantizer is reparameterized with l1 weight normalization::

    w_i = g_i * v_i / ||v_i||_1        (per output channel i, Eq. 17)

with exponential parameterizations ``s = 2**d`` (scale) and ``g = 2**min(T, t)``
(norm), where ``d`` and ``t`` are learned log-scale parameters and

    T = 1_signed(x) + log2(2**(P-1) - 1) + d - N                      (Eq. 23)

caps the learned norm so the *integer* weights provably satisfy the per-channel
l1 budget (Eq. 15)::

    ||w_int||_1 <= (2**(P-1) - 1) * 2**(1_signed(x) - N)

Rounding is toward zero (truncation) so rounding can never push the integer l1
norm past the budget; clipping can only shrink magnitudes further.  Hence every
dot product against N-bit inputs — including every intermediate partial sum, in
any order — fits a P-bit signed accumulator.  ``tests/test_a2q.py`` proves this
property with hypothesis + the bit-exact integer simulator.

The regularizer ``L_reg = sum_l sum_i max(t_i - T_i, 0)`` keeps ``t`` from
getting stuck above the cap (paper Sec. 4.1); weight it by lambda=1e-3 as in
Appendix B.
"""

from __future__ import annotations

import jax
import numpy as np
import jax.numpy as jnp

from repro.core.bounds import int_range
from repro.core.quantizers import ste_round_to_zero

__all__ = [
    "a2q_norm_cap",
    "init_a2q",
    "apply_a2q",
    "a2q_int_weights",
    "a2q_penalty",
    "a2q_channel_l1",
]

_EPS = 1e-12


def a2q_norm_cap(d: jnp.ndarray, acc_bits: int, input_bits: int, input_signed: bool) -> jnp.ndarray:
    """Eq. 23: ``T = 1_signed(x) + log2(2**(P-1) - 1) + d - N`` (per channel)."""
    log2_amax = jnp.log2(jnp.asarray(2.0 ** (acc_bits - 1) - 1.0, dtype=d.dtype))
    return int(input_signed) + log2_amax + d - input_bits


def _channel_reduce(w: jnp.ndarray, op) -> jnp.ndarray:
    axes = tuple(range(w.ndim - 1))
    return op(w, axis=axes)


def init_a2q(
    w: jnp.ndarray,
    bits: int,
    acc_bits: int,
    input_bits: int,
    input_signed: bool,
) -> dict:
    """Initialize (v, t, d) from a float weight tensor.

    Convention: the *last* axis of ``w`` is the output-channel axis (matmul
    weights are stored ``(K, C_out)``; convs ``(kh, kw, C_in, C_out)``), so each
    output channel — each accumulator — is a column.

    * ``v`` starts at the float weights (direction) — *concentrated* when the
      integer budget is tighter than the fan-in (see below),
    * ``d`` = log2(max-abs / (2**(M-1)-1)) as in baseline QAT max-abs calibration,
    * ``t`` = log2(||w||_1) per channel, pre-clamped to the cap ``T`` so the
      budget holds from step zero.

    Concentration init (ours, beyond the paper): the Eq. 15 budget allows at
    most ``B = (2**(P-1)-1) * 2**(1_signed-N)`` integer units of l1 per
    channel, so when ``B < K`` at most ``floor(B)`` weights can be nonzero at
    all.  A diffuse init spreads ``g`` so thin that *every* weight truncates
    to zero and the layer is born dead (round-to-zero never recovers fast —
    the paper's Sec. 6 rounding caveat).  Keeping only the top-``floor(B)``
    magnitudes per channel at init matches the representable set exactly and
    keeps the layer alive at aggressive (P, N, K) combinations.
    """
    pmax = float(2 ** (bits - 1) - 1)
    K = int(np.prod(w.shape[:-1]))
    budget = (2.0 ** (acc_bits - 1) - 1.0) * 2.0 ** (int(input_signed) - input_bits)
    m = int(budget)
    if 0 < m < K:
        flat = jnp.abs(w.reshape(K, w.shape[-1]))
        kth = -jnp.sort(-flat, axis=0)[m - 1]  # m-th largest |w| per channel
        keep = flat >= jnp.maximum(kth, 1e-12)[None, :]
        w = (w.reshape(K, -1) * keep).reshape(w.shape)
    absmax = jnp.maximum(_channel_reduce(jnp.abs(w), jnp.max), 1e-8)
    l1 = jnp.maximum(_channel_reduce(jnp.abs(w), jnp.sum), 1e-8)
    d = jnp.log2(absmax / pmax).astype(jnp.float32)
    T = a2q_norm_cap(d, acc_bits, input_bits, input_signed)
    t = jnp.minimum(jnp.log2(l1).astype(jnp.float32), T)
    return {"v": w.astype(jnp.float32), "t": t, "d": d}


def _effective_gs(params: dict, acc_bits: int, input_bits: int, input_signed: bool):
    """(g/s ratio, s) with the norm cap applied — shared by train + int paths."""
    d = params["d"]
    t = params["t"]
    T = a2q_norm_cap(d, acc_bits, input_bits, input_signed)
    t_eff = jnp.minimum(t, T)  # g = 2**min(t, T)   (Eq. 22)
    s = jnp.exp2(d)
    g_over_s = jnp.exp2(t_eff - d)  # computed in log space: exact powers of 2
    return g_over_s, s


def apply_a2q(
    params: dict,
    bits: int,
    acc_bits: int,
    input_bits: int,
    input_signed: bool,
    dtype=jnp.float32,
) -> jnp.ndarray:
    """Eq. 20: ``q(w; s) = clip(rtz(g/s * v/||v||_1); n, p) * s`` (fake-quant).

    Returns the dequantized (float) weights used by the training graph.  STE
    through rtz, clipped-STE through clip, gradients reach v, t, d.
    """
    v = params["v"]
    n, p = int_range(bits, signed=True)
    g_over_s, s = _effective_gs(params, acc_bits, input_bits, input_signed)
    l1_v = jnp.maximum(_channel_reduce(jnp.abs(v), jnp.sum), _EPS)
    w_scaled = g_over_s * v / l1_v
    q = jnp.clip(ste_round_to_zero(w_scaled), n, p)
    return (q * s).astype(dtype)


def a2q_int_weights(
    params: dict,
    bits: int,
    acc_bits: int,
    input_bits: int,
    input_signed: bool,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(integer weights, per-channel scale) — the deployable artifacts.

    ``||w_int||_1 <= g/s <= (2**(P-1)-1) * 2**(1_signed - N)`` by construction.
    """
    v = params["v"]
    n, p = int_range(bits, signed=True)
    g_over_s, s = _effective_gs(params, acc_bits, input_bits, input_signed)
    l1_v = jnp.maximum(_channel_reduce(jnp.abs(v), jnp.sum), _EPS)
    q = jnp.clip(jnp.trunc(g_over_s * v / l1_v), n, p)
    return q, s


def a2q_penalty(params: dict, acc_bits: int, input_bits: int, input_signed: bool) -> jnp.ndarray:
    """Per-layer regularizer ``R_l = sum_i max(t_i - T_i, 0)`` (Sec. 4.1)."""
    T = a2q_norm_cap(params["d"], acc_bits, input_bits, input_signed)
    return jnp.sum(jnp.maximum(params["t"] - T, 0.0))


def a2q_channel_l1(
    params: dict,
    bits: int,
    acc_bits: int,
    input_bits: int,
    input_signed: bool,
) -> jnp.ndarray:
    """Per-channel l1 norm of the *integer* weights (for audits / fig5)."""
    q, _ = a2q_int_weights(params, bits, acc_bits, input_bits, input_signed)
    return _channel_reduce(jnp.abs(q), jnp.sum)
