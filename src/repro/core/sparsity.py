"""Weight-sparsity accounting (paper Sec. 5.2.1 / Fig. 5).

A2Q's l1 budget tightens exponentially as the accumulator width P shrinks
(Eq. 15/18/23), which drives unstructured sparsity in the *integer* weights —
the quantity that matters for deployment (zero integer weights are skippable
MACs and compressible memory).  These helpers measure it.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["tensor_sparsity", "tree_sparsity", "pack_sparse_count"]


def tensor_sparsity(w_int: jnp.ndarray) -> float:
    """Fraction of exactly-zero entries in an integer weight tensor."""
    w = np.asarray(w_int)
    if w.size == 0:
        return 0.0
    return float(np.mean(w == 0))


def tree_sparsity(int_weight_tree) -> dict:
    """Aggregate sparsity over a pytree of integer weight tensors.

    Returns overall sparsity plus per-leaf breakdown keyed by tree path.
    """
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(int_weight_tree)[0]
    per_leaf = {}
    zeros = 0
    total = 0
    for path, leaf in leaves_with_paths:
        leaf = np.asarray(leaf)
        name = jax.tree_util.keystr(path)
        z = int(np.sum(leaf == 0))
        per_leaf[name] = z / max(leaf.size, 1)
        zeros += z
        total += leaf.size
    return {"overall": zeros / max(total, 1), "per_leaf": per_leaf, "params": total}


def pack_sparse_count(w_int: np.ndarray) -> dict:
    """Size accounting for a CSR-style packing of an integer weight matrix —
    the memory-roofline payoff of A2Q sparsity (Sec. 6 'Discussion')."""
    w = np.asarray(w_int)
    nnz = int(np.count_nonzero(w))
    dense_bits = w.size * 8  # int8 storage
    # values (8b) + column indices (16b suffices for K <= 65536) + row pointers
    packed_bits = nnz * (8 + 16) + (w.shape[0] + 1 if w.ndim > 1 else 2) * 32
    return {
        "nnz": nnz,
        "dense_bytes": dense_bits // 8,
        "packed_bytes": packed_bits // 8,
        "compression": dense_bits / max(packed_bits, 1),
    }
