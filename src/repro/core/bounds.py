"""Accumulator bit-width bounds from the A2Q paper (Section 3).

Two lower bounds on the signed accumulator bit width ``P`` required to
guarantee that the dot product ``y = sum_i x_i * w_i`` — *including every
intermediate partial sum, in any accumulation order* — fits without overflow:

* **Data-type bound** (Eq. 8-10): uses only the bit widths ``(K, N, M)``.
* **Weight-norm bound** (Eq. 12-14): uses the frozen weights' l1 norm —
  strictly tighter, and the bound A2Q inverts into a training constraint.

Both are exact transcriptions of the paper's equations.  All functions work on
python scalars, numpy arrays, and jnp arrays (they only use ``log2``/``ceil``
style primitives), so they are usable inside jitted training code *and* in
offline design-space exploration (benchmarks/fig3-style tables).

Conventions (paper Section 2.1):
  signed integers of bit width b:  n = -2**(b-1),  p = 2**(b-1) - 1
  unsigned integers of bit width b: n = 0,          p = 2**b - 1
"""

from __future__ import annotations

import math
from typing import Union

import jax.numpy as jnp
import numpy as np

Arrayish = Union[float, int, np.ndarray, jnp.ndarray]

__all__ = [
    "int_range",
    "phi",
    "alpha_term",
    "beta_term",
    "data_type_bound",
    "weight_norm_bound",
    "l1_budget",
    "min_accumulator_bits_data_type",
    "min_accumulator_bits_weights",
    "headroom_utilization",
]


def int_range(bits: int, signed: bool) -> tuple[int, int]:
    """(n, p) clipping range for a ``bits``-wide integer (paper Sec. 2.1)."""
    if bits <= 0:
        raise ValueError(f"bit width must be positive, got {bits}")
    if signed:
        return -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    return 0, 2**bits - 1


def phi(x: Arrayish):
    """``phi(a) = log2(1 + 2**-a)`` — Eq. 10 / Eq. 14 correction term.

    Uses log1p for numerical stability at large ``a`` (2**-a underflows to 0,
    log1p(0) = 0 which is the correct limit).
    """
    xn = jnp.asarray(x, dtype=jnp.float64) if _wants_jnp(x) else np.asarray(x, dtype=np.float64)
    mod = jnp if _wants_jnp(x) else np
    return mod.log1p(mod.exp2(-xn)) / math.log(2.0)


def _wants_jnp(x) -> bool:
    return isinstance(x, jnp.ndarray) and not isinstance(x, np.ndarray)


def alpha_term(K: Arrayish, N: int, M: int, signed_input: bool):
    """Eq. 9: ``alpha = log2(K) + N + M - 1 - 1_signed(x)``."""
    mod = jnp if _wants_jnp(K) else np
    return mod.log2(mod.asarray(K, dtype=mod.float64)) + N + M - 1 - int(signed_input)


def beta_term(l1_norm: Arrayish, N: int, signed_input: bool):
    """Eq. 13: ``beta = log2(||w||_1) + N - 1_signed(x)``."""
    mod = jnp if _wants_jnp(l1_norm) else np
    l1 = mod.asarray(l1_norm, dtype=mod.float64)
    return mod.log2(l1) + N - int(signed_input)


def data_type_bound(K: Arrayish, N: int, M: int, signed_input: bool):
    """Eq. 8: real-valued lower bound ``P >= alpha + phi(alpha) + 1``.

    Args:
      K: dot-product length (may be an array for vectorized tables).
      N: input (activation) bit width.
      M: weight bit width.
      signed_input: whether the inputs are signed integers.
    """
    a = alpha_term(K, N, M, signed_input)
    return a + phi(a) + 1.0


def weight_norm_bound(l1_norm: Arrayish, N: int, signed_input: bool):
    """Eq. 12: real-valued lower bound ``P >= beta + phi(beta) + 1``.

    ``l1_norm`` is the l1 norm of the *integer* weights of one output channel
    (i.e. ``||w_int||_1``; if weights are stored dequantized, divide by the
    channel scale first).
    """
    b = beta_term(l1_norm, N, signed_input)
    return b + phi(b) + 1.0


# At an exact power-of-two boundary (e.g. ||w||_1 == the Eq. 15 budget) the
# real-valued bound equals the integer P exactly; float64 rounding can land
# epsilon above it and ceil one bit too high.
_CEIL_EPS = 1e-9


def min_accumulator_bits_data_type(K: int, N: int, M: int, signed_input: bool) -> int:
    """Smallest integer P satisfying the data-type bound (Eq. 8)."""
    return int(math.ceil(float(data_type_bound(K, N, M, signed_input)) - _CEIL_EPS))


def min_accumulator_bits_weights(l1_norm: float, N: int, signed_input: bool) -> int:
    """Smallest integer P satisfying the weight-norm bound (Eq. 12).

    A zero-l1 channel (fully sparse) still needs the minimum signed register.
    """
    if l1_norm <= 0:
        return 2  # a signed accumulator cannot be narrower than 2 bits
    return max(2, int(math.ceil(float(weight_norm_bound(l1_norm, N, signed_input)) - _CEIL_EPS)))


def l1_budget(P: int, N: int, signed_input: bool):
    """Eq. 15: per-channel budget ``||w||_1 <= (2**(P-1) - 1) * 2**(1_signed - N)``.

    This is the *inverse* of the weight-norm bound: the largest integer-weight
    l1 norm (scaled by the weight scale ``s`` if weights are dequantized) that
    a ``P``-bit signed accumulator can absorb for ``N``-bit inputs.

    Returned as a float (it can be fractional for unsigned inputs with N > 1).
    """
    if P < 2:
        raise ValueError(f"accumulator width must be >= 2 bits, got P={P}")
    return float(2 ** (P - 1) - 1) * 2.0 ** (int(signed_input) - N)


def headroom_utilization(l1_norm: Arrayish, N: int, signed_input: bool, P: int):
    """Fraction of a P-bit signed accumulator's bound consumed in the worst
    case by a channel with integer-weight l1 norm ``l1_norm`` and ``N``-bit
    inputs: ``||w||_1 * 2**(N - 1_signed) / (2**(P-1) - 1)``.

    This is the ratio form of Eq. 11 (the quantity ``verify_no_overflow``
    compares against 1): utilization <= 1.0 iff overflow is provably
    impossible in any accumulation order.  The obs layer exports it as the
    per-layer ``acc_headroom_utilization`` gauge.
    """
    if P < 2:
        raise ValueError(f"accumulator width must be >= 2 bits, got P={P}")
    mod = jnp if _wants_jnp(l1_norm) else np
    l1 = mod.asarray(l1_norm, dtype=mod.float64)
    return l1 * 2.0 ** (N - int(signed_input)) / float(2 ** (P - 1) - 1)


def verify_no_overflow(weights_int: np.ndarray, N: int, signed_input: bool, P: int) -> bool:
    """Check Eq. 11 for a (C_out, K) integer weight matrix: True iff a P-bit
    signed accumulator provably cannot overflow for *any* N-bit input."""
    w = np.asarray(weights_int, dtype=np.float64)
    if w.ndim == 1:
        w = w[None, :]
    l1 = np.abs(w).sum(axis=-1)
    worst = l1 * 2.0 ** (N - int(signed_input))
    return bool(np.all(worst <= 2 ** (P - 1) - 1))
