from repro.configs.base import (  # noqa: F401
    SHAPES,
    ArchConfig,
    AttnConfig,
    FrontendConfig,
    MoEConfig,
    QuantConfig,
    SSMConfig,
    ShapeSpec,
    StackConfig,
    applicable_shapes,
    input_specs,
)
from repro.configs.registry import ARCH_NAMES, get_arch, reduced  # noqa: F401
