"""hubert-xlarge [audio]: 48L d=1280 16H d_ff=5120 vocab(classes)=504.

Encoder-only (bidirectional attention), same backbone as wav2vec2.  The conv
feature frontend is a STUB: input_specs provides precomputed frame embeddings
(B, S, d).  No decode step -> decode_32k / long_500k skipped.
[arXiv:2106.07447; unverified]
"""

from repro.configs.base import ArchConfig, AttnConfig, FrontendConfig, QuantConfig, StackConfig

ARCH = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    d_model=1280,
    vocab=504,
    n_classes=504,
    norm="layernorm",
    use_bias=True,
    frontend=FrontendConfig(kind="frames", seq_len=0),
    stacks=(
        StackConfig(
            kind="attn_mlp",
            count=48,
            attn=AttnConfig(heads=16, kv_heads=16, head_dim=80, rope_theta=None, causal=False),
            d_ff=5120,
            mlp_gated=False,  # GELU MLP, wav2vec2-style
        ),
    ),
    quant=QuantConfig(mode="a2q", weight_bits=8, act_bits=8, acc_bits=16),
    sub_quadratic=False,
)
