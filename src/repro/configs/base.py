"""Config system: architecture, quantization, and input-shape descriptors.

Everything the launcher, dry-run, trainer, and tests consume is described by
these frozen dataclasses.  One ``<arch>.py`` per assigned architecture under
``repro/configs/`` builds an :class:`ArchConfig`; ``SHAPES`` lists the four
assigned input-shape cells; ``input_specs`` produces allocation-free
``ShapeDtypeStruct`` stand-ins for the dry-run.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal, Optional, Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "QuantConfig",
    "AttnConfig",
    "MoEConfig",
    "SSMConfig",
    "StackConfig",
    "FrontendConfig",
    "ArchConfig",
    "ShapeSpec",
    "SHAPES",
    "input_specs",
]


@dataclass(frozen=True)
class QuantConfig:
    """A2Q / QAT settings (paper Sec. 5.1 conventions).

    ``mode``: 'none' (float), 'qat' (baseline Sec. 2.1), 'a2q' (Sec. 4).
    ``weight_bits`` M / ``act_bits`` N / ``acc_bits`` P are the uniform hidden
    layer widths; first/last layers stay at ``boundary_bits`` (8, per App. B).
    """

    mode: Literal["none", "qat", "a2q"] = "none"
    weight_bits: int = 8
    act_bits: int = 8
    acc_bits: int = 32
    boundary_bits: int = 8
    reg_lambda: float = 1e-3
    # Beyond-paper lever: store deployable weights as int8 + per-channel scale
    # (sound because A2Q guarantees the accumulator), halving weight HBM bytes.
    int8_weight_storage: bool = False


@dataclass(frozen=True)
class AttnConfig:
    kind: Literal["gqa", "mla"] = "gqa"
    heads: int = 8
    kv_heads: int = 8
    head_dim: int = 128
    causal: bool = True
    rope_theta: Optional[float] = 10000.0  # None => NoPE
    window: Optional[int] = None  # sliding-window width
    chunk: Optional[int] = None  # chunked-local (llama4) block width
    qk_norm: bool = False
    # MLA (deepseek-v3) dims
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    d_ff: int = 2048  # per-expert FFN width
    n_shared: int = 0  # shared (always-on) experts
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    router_noise: float = 0.0


@dataclass(frozen=True)
class SSMConfig:
    kind: Literal["rwkv6", "mamba"] = "rwkv6"
    head_dim: int = 64
    state_dim: int = 16  # mamba N
    chunk: int = 64
    lora_rank: int = 64  # rwkv6 data-dependent decay LoRA
    expand: int = 2  # mamba inner expansion


@dataclass(frozen=True)
class StackConfig:
    """A run of ``count`` identical blocks, compiled as one lax.scan."""

    kind: Literal["attn_mlp", "moe", "rwkv6", "hymba", "conv"] = "attn_mlp"
    count: int = 1
    attn: Optional[AttnConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    d_ff: int = 0  # dense MLP width (attn_mlp blocks)
    parallel_block: bool = False  # command-r style parallel attn+FFN
    mlp_gated: bool = True  # SwiGLU vs plain GELU MLP


@dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend STUB: input_specs provides precomputed embeddings."""

    kind: Literal["patches", "frames"] = "patches"
    seq_len: int = 576  # embeddings prepended (vlm) or consumed directly (audio)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["lm", "encoder", "vlm", "audio"] = "lm"
    d_model: int = 512
    vocab: int = 32000
    stacks: Sequence[StackConfig] = ()
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    use_bias: bool = False
    frontend: Optional[FrontendConfig] = None
    mtp_depth: int = 0  # deepseek multi-token prediction heads
    n_classes: int = 0  # encoder classification head (hubert)
    quant: QuantConfig = field(default_factory=QuantConfig)
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: Literal["none", "block", "full"] = "block"
    # Unroll stacks as python loops instead of lax.scan.  Used by the roofline
    # costing variants: XLA cost_analysis counts a while body ONCE (verified in
    # tests/test_roofline.py), so per-layer costs are measured on unrolled
    # 1-layer vs 2-layer models and extrapolated (launch/dryrun.py).
    unroll_stacks: bool = False
    attn_q_chunk: int = 256  # query-chunked attention block (jnp path)
    max_seq_len: int = 532480  # RoPE table bound (covers long_500k + frontend)
    # True => this arch can run the long_500k decode cell (sub-quadratic attn)
    sub_quadratic: bool = False

    @property
    def n_layers(self) -> int:
        return sum(s.count for s in self.stacks)

    def layer_dims(self) -> list[tuple[int, int]]:
        """(K, C_out) of every distinct matmul family — for bound tables."""
        dims = []
        for s in self.stacks:
            if s.attn is not None:
                dims.append((self.d_model, s.attn.heads * s.attn.head_dim))
            if s.d_ff:
                dims.append((self.d_model, s.d_ff))
                dims.append((s.d_ff, self.d_model))
            if s.moe is not None:
                dims.append((self.d_model, s.moe.d_ff))
                dims.append((s.moe.d_ff, self.d_model))
        return dims


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: Literal["train", "prefill", "decode"]
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def applicable_shapes(arch: ArchConfig) -> list[str]:
    """Which of the four assigned cells this arch runs (DESIGN.md Sec. 5)."""
    out = ["train_4k", "prefill_32k"]
    if arch.family in ("lm", "vlm"):  # decoder LMs decode
        out.append("decode_32k")
        if arch.sub_quadratic:
            out.append("long_500k")
    return out


def input_specs(arch: ArchConfig, shape: ShapeSpec, *, per_pod_batch: Optional[int] = None):
    """ShapeDtypeStruct stand-ins for every model input — no allocation.

    train: {tokens, targets [, frontend_embeds]} — ``tokens (B, S)`` int32.
    prefill: {tokens [, frontend_embeds]}.
    decode: {tokens (B, 1), cache} — cache specs come from the model builder,
    so decode specs are produced there; this returns the token part.
    """
    B = per_pod_batch if per_pod_batch is not None else shape.global_batch
    S = shape.seq_len
    specs = {}
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    if arch.family == "audio":
        # stub frame frontend: model consumes precomputed frame embeddings
        specs["frontend_embeds"] = jax.ShapeDtypeStruct((B, S, arch.d_model), bf16)
        if shape.kind == "train":
            specs["targets"] = jax.ShapeDtypeStruct((B, S), i32)
        return specs
    s_text = S
    if arch.family == "vlm" and arch.frontend is not None:
        s_img = min(arch.frontend.seq_len, max(S // 8, 1)) if shape.kind != "decode" else arch.frontend.seq_len
        if shape.kind != "decode":
            s_text = S - s_img
            specs["frontend_embeds"] = jax.ShapeDtypeStruct((B, s_img, arch.d_model), bf16)
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((B, s_text), i32)
        specs["targets"] = jax.ShapeDtypeStruct((B, S), i32)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, s_text), i32)
    else:  # decode: one new token against a seq_len-deep cache
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
    return specs
