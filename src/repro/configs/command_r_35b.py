"""command-r-35b [dense]: 40L d=8192 64H (GQA kv=8) d_ff=22528 vocab=256000.

GQA, no-bias, parallel attention+FFN residual block, LayerNorm (Cohere arch).
[hf:CohereForAI/c4ai-command-r-v01; unverified]
"""

from repro.configs.base import ArchConfig, AttnConfig, QuantConfig, StackConfig

ARCH = ArchConfig(
    name="command-r-35b",
    family="lm",
    d_model=8192,
    vocab=256000,
    norm="layernorm",
    use_bias=False,
    stacks=(
        StackConfig(
            kind="attn_mlp",
            count=40,
            attn=AttnConfig(heads=64, kv_heads=8, head_dim=128, rope_theta=8e6),
            d_ff=22528,
            parallel_block=True,
            mlp_gated=True,
        ),
    ),
    quant=QuantConfig(mode="a2q", weight_bits=8, act_bits=8, acc_bits=16),
    sub_quadratic=False,  # pure full attention -> long_500k skipped (DESIGN Sec.5)
)
