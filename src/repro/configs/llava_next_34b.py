"""llava-next-34b [vlm]: 60L d=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.

Anyres tiling frontend is a STUB: input_specs provides precomputed patch
embeddings prepended to the token embeddings.  Backbone = Yi-34B-style
decoder. [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""

from repro.configs.base import ArchConfig, AttnConfig, FrontendConfig, QuantConfig, StackConfig

ARCH = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    d_model=7168,
    vocab=64000,
    frontend=FrontendConfig(kind="patches", seq_len=576),
    stacks=(
        StackConfig(
            kind="attn_mlp",
            count=60,
            attn=AttnConfig(heads=56, kv_heads=8, head_dim=128, rope_theta=5e6),
            d_ff=20480,
        ),
    ),
    quant=QuantConfig(mode="a2q", weight_bits=8, act_bits=8, acc_bits=16),
    sub_quadratic=False,
)
