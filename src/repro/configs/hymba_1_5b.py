"""hymba-1.5b [hybrid]: 32L d=1600 25H (GQA kv=5) d_ff=5504 ssm_state=16.

Parallel attention + mamba heads in every block (outputs averaged), SWA
attention (window 1024).  Mamba heads use the Mamba-2 SSD form (scalar
per-head decay) — DESIGN Sec. 5 notes this + the meta-token simplification.
Runs long_500k (ring cache + O(1) SSM state). [arXiv:2411.13676; hf]
"""

from repro.configs.base import ArchConfig, AttnConfig, QuantConfig, SSMConfig, StackConfig

ARCH = ArchConfig(
    name="hymba-1.5b",
    family="lm",
    d_model=1600,
    vocab=32001,
    stacks=(
        StackConfig(
            kind="hymba",
            count=32,
            attn=AttnConfig(heads=25, kv_heads=5, head_dim=64, rope_theta=10000.0, window=1024),
            ssm=SSMConfig(kind="mamba", head_dim=64, state_dim=16, chunk=64),
            d_ff=5504,
        ),
    ),
    quant=QuantConfig(mode="a2q", weight_bits=8, act_bits=8, acc_bits=16),
    sub_quadratic=True,
)
