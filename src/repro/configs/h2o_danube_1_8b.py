"""h2o-danube-1.8b [dense]: 24L d=2560 32H (GQA kv=8) d_ff=6912 vocab=32000.

Llama+Mistral mix with sliding-window attention (window 4096) -> ring KV cache,
runs the long_500k cell. [arXiv:2401.16818; hf]
"""

from repro.configs.base import ArchConfig, AttnConfig, QuantConfig, StackConfig

ARCH = ArchConfig(
    name="h2o-danube-1.8b",
    family="lm",
    d_model=2560,
    vocab=32000,
    stacks=(
        StackConfig(
            kind="attn_mlp",
            count=24,
            attn=AttnConfig(heads=32, kv_heads=8, head_dim=80, rope_theta=10000.0, window=4096),
            d_ff=6912,
        ),
    ),
    quant=QuantConfig(mode="a2q", weight_bits=8, act_bits=8, acc_bits=16),
    sub_quadratic=True,
)
