"""smollm-135m [dense]: 30L d=576 9H (GQA kv=3) d_ff=1536 vocab=49152.

Llama-architecture small model, tied embeddings.  9 heads do not divide the
16-way model axis -> heads replicate, d_ff/vocab still shard (DESIGN Sec. 4).
[hf:HuggingFaceTB/SmolLM-135M; hf]
"""

from repro.configs.base import ArchConfig, AttnConfig, QuantConfig, StackConfig

ARCH = ArchConfig(
    name="smollm-135m",
    family="lm",
    d_model=576,
    vocab=49152,
    tie_embeddings=True,
    stacks=(
        StackConfig(
            kind="attn_mlp",
            count=30,
            attn=AttnConfig(heads=9, kv_heads=3, head_dim=64, rope_theta=10000.0),
            d_ff=1536,
        ),
    ),
    quant=QuantConfig(mode="a2q", weight_bits=8, act_bits=8, acc_bits=16),
    sub_quadratic=False,
)
