"""deepseek-v3-671b [moe]: 61L d=7168 128H MLA d_ff(expert)=2048 vocab=129280.

MLA (q_lora 1536 / kv_lora 512 / nope 128 / rope 64 / v 128), first 3 layers
dense (d_ff 18432), 58 MoE layers with 1 shared + 256 routed experts top-8,
MTP head.  Group-limited routing is simplified to plain top-k (DESIGN Sec. 8).
[arXiv:2412.19437; hf]
"""

from repro.configs.base import ArchConfig, AttnConfig, MoEConfig, QuantConfig, StackConfig

_MLA = AttnConfig(
    kind="mla",
    heads=128,
    kv_heads=128,
    head_dim=128,
    rope_theta=10000.0,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
)

ARCH = ArchConfig(
    name="deepseek-v3-671b",
    family="lm",
    d_model=7168,
    vocab=129280,
    mtp_depth=1,
    stacks=(
        StackConfig(kind="attn_mlp", count=3, attn=_MLA, d_ff=18432),
        StackConfig(
            kind="moe",
            count=58,
            attn=_MLA,
            moe=MoEConfig(
                n_experts=256, top_k=8, d_ff=2048, n_shared=1, shared_d_ff=2048,
                capacity_factor=1.25,
            ),
        ),
    ),
    quant=QuantConfig(mode="a2q", weight_bits=8, act_bits=8, acc_bits=16),
    sub_quadratic=False,
)
