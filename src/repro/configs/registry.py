"""Architecture registry + reduced-config factory for smoke tests.

``get_arch(name)`` returns the full assigned config; ``reduced(arch)`` shrinks
it to a CPU-runnable config of the *same family* (same stack kinds, same
attention/MoE/SSM structure, tiny dims) for the per-arch smoke tests.  The
full configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation) as the assignment requires.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

from repro.configs.base import ArchConfig, AttnConfig, FrontendConfig, MoEConfig, SSMConfig, StackConfig

_MODULES = {
    "command-r-35b": "repro.configs.command_r_35b",
    "yi-6b": "repro.configs.yi_6b",
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1_8b",
    "smollm-135m": "repro.configs.smollm_135m",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "llava-next-34b": "repro.configs.llava_next_34b",
    "hymba-1.5b": "repro.configs.hymba_1_5b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
}

ARCH_NAMES = tuple(_MODULES)


def get_arch(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).ARCH


def _reduce_attn(a: Optional[AttnConfig], head_dim: int) -> Optional[AttnConfig]:
    if a is None:
        return None
    if a.kind == "mla":
        return dataclasses.replace(
            a, heads=4, kv_heads=4, head_dim=head_dim,
            q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=head_dim, qk_rope_dim=8,
            v_head_dim=head_dim,
        )
    heads = 4 if a.heads != a.kv_heads else 2
    kv = max(1, heads // max(a.heads // a.kv_heads, 1))
    window = min(a.window, 16) if a.window else None
    chunk = min(a.chunk, 16) if a.chunk else None
    return dataclasses.replace(
        a, heads=heads, kv_heads=kv, head_dim=head_dim, window=window, chunk=chunk
    )


def reduced(arch: ArchConfig, *, head_dim: int = 16, count: int = 2, vocab: int = 256) -> ArchConfig:
    """Same-family tiny config: ~64-wide, 2 blocks per stack, <=2 stacks."""
    stacks = []
    for s in arch.stacks[:2]:
        a = _reduce_attn(s.attn, head_dim)
        d_model = (a.heads * head_dim) if a is not None and a.kind != "mla" else 64
        moe = None
        if s.moe is not None:
            moe = dataclasses.replace(
                s.moe, n_experts=8, top_k=min(s.moe.top_k, 2), d_ff=32,
                n_shared=min(s.moe.n_shared, 1), shared_d_ff=32, capacity_factor=2.0,
            )
        ssm = None
        if s.ssm is not None:
            ssm = dataclasses.replace(s.ssm, head_dim=16, state_dim=4, chunk=8, lora_rank=8)
        stacks.append(
            dataclasses.replace(
                s, count=min(s.count, count), attn=a, moe=moe, ssm=ssm,
                d_ff=(64 if s.d_ff else 0),
            )
        )
    # All stacks must agree on d_model; derive from the first.
    s0 = stacks[0]
    if s0.attn is not None and s0.attn.kind != "mla":
        d_model = s0.attn.heads * head_dim
    elif s0.ssm is not None:
        d_model = 4 * (s0.ssm.head_dim if s0.ssm else 16)
    else:
        d_model = 64
    frontend = None
    if arch.frontend is not None:
        frontend = dataclasses.replace(arch.frontend, seq_len=min(arch.frontend.seq_len or 8, 8))
    return dataclasses.replace(
        arch,
        d_model=d_model,
        vocab=vocab,
        n_classes=min(arch.n_classes, 32) if arch.n_classes else 0,
        stacks=tuple(stacks),
        frontend=frontend,
        attn_q_chunk=8,
        compute_dtype="float32",
        param_dtype="float32",
        max_seq_len=4096,
    )
