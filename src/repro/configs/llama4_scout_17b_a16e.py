"""llama4-scout-17b-a16e [moe]: 48L d=5120 40H (GQA kv=8) d_ff=8192 MoE 16e top-1.

iRoPE interleave: 3 chunked-local (RoPE, chunk 8192) : 1 global (NoPE) layers,
every layer MoE (16 routed top-1 + 1 shared expert).  Early-fusion multimodal
frontend out of scope for the LM cells (text-only input specs).  Chunked-local
layers use ring caches; global layers decode O(S) per step -> runs long_500k.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""

from repro.configs.base import ArchConfig, AttnConfig, MoEConfig, QuantConfig, StackConfig

_MOE = MoEConfig(n_experts=16, top_k=1, d_ff=8192, n_shared=1, shared_d_ff=8192,
                 capacity_factor=1.25)


def _local(count: int) -> StackConfig:
    return StackConfig(
        kind="moe",
        count=count,
        attn=AttnConfig(heads=40, kv_heads=8, head_dim=128, rope_theta=5e5, chunk=8192),
        moe=_MOE,
    )


def _global() -> StackConfig:
    return StackConfig(
        kind="moe",
        count=1,
        attn=AttnConfig(heads=40, kv_heads=8, head_dim=128, rope_theta=None),
        moe=_MOE,
    )


ARCH = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="lm",
    d_model=5120,
    vocab=202048,
    stacks=tuple(s for _ in range(12) for s in (_local(3), _global())),
    quant=QuantConfig(mode="a2q", weight_bits=8, act_bits=8, acc_bits=16),
    sub_quadratic=True,
)
