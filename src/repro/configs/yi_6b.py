"""yi-6b [dense]: 32L d=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.

Llama-architecture GQA (RMSNorm, SwiGLU, RoPE theta=5e6). [arXiv:2403.04652; hf]
"""

from repro.configs.base import ArchConfig, AttnConfig, QuantConfig, StackConfig

ARCH = ArchConfig(
    name="yi-6b",
    family="lm",
    d_model=4096,
    vocab=64000,
    stacks=(
        StackConfig(
            kind="attn_mlp",
            count=32,
            attn=AttnConfig(heads=32, kv_heads=4, head_dim=128, rope_theta=5e6),
            d_ff=11008,
        ),
    ),
    quant=QuantConfig(mode="a2q", weight_bits=8, act_bits=8, acc_bits=16),
    sub_quadratic=False,
)
