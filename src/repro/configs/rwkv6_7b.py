"""rwkv6-7b [ssm]: 32L d=4096 (attention-free) d_ff=14336 vocab=65536.

Finch: data-dependent per-channel decay, 64 heads of 64.  O(1) recurrent state
-> runs long_500k.  A2Q attaches to r/k/v/g/o + channel-mix projections; the
recurrence itself has no frozen weight vector to bound (DESIGN Sec. 5).
[arXiv:2404.05892; hf]
"""

from repro.configs.base import ArchConfig, QuantConfig, SSMConfig, StackConfig

ARCH = ArchConfig(
    name="rwkv6-7b",
    family="lm",
    d_model=4096,
    vocab=65536,
    stacks=(
        StackConfig(
            kind="rwkv6",
            count=32,
            ssm=SSMConfig(kind="rwkv6", head_dim=64, chunk=64, lora_rank=64),
            d_ff=14336,
        ),
    ),
    quant=QuantConfig(mode="a2q", weight_bits=8, act_bits=8, acc_bits=16),
    sub_quadratic=True,
)
