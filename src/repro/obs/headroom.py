"""Accumulator-headroom telemetry: the paper's overflow guarantee as a
runtime observable.

A2Q proves overflow avoidance *statically* — the deployed integer weights'
per-channel l1 norms fit the Eq. 15 budget for the target accumulator width
``P``.  This module turns that proof into gauges the serve stack exports:

* :func:`static_headroom_report` — walks a deployed param tree (``q8``/``s8``
  leaves from ``serve.engine.deploy_params``) and computes each layer's
  worst-case bound utilization ``||q8||_1 * 2**(N - 1_signed) / (2**(P-1)-1)``
  (``core.bounds.headroom_utilization``, the ratio form of Eq. 11).
  Utilization < 1.0 on every layer *is* the guarantee.
* :func:`observed_headroom` — drives one eager forward through the fused
  W8A8 path inside ``nn.linear.acc_probe_scope`` and samples the actual
  integer operands' worst partial-sum magnitude ``max(|x_codes| @ |q8|)``
  per call site — always <= the static bound when the guarantee holds, so
  ``observed > bound`` is a hard violation.
* :func:`engine_headroom` — populates an engine's metrics registry
  (``acc_headroom_utilization{site=...}``, ``acc_observed_max{site=...}``,
  ``acc_bound{site=...}``, ``acc_headroom_util_max``,
  ``acc_headroom_violations``) and returns a summary dict.  CI's obs-smoke
  job and ``benchmarks/run.py`` gate ``acc_headroom_violations == 0``.

Sites inside vmapped/scanned layer stacks trace with abstract operands, so
the eager probe skips them; the static report still covers every deployed
layer (stacked leaves reduce per-channel l1 over all stack members).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bounds import headroom_utilization, l1_budget

__all__ = ["static_headroom_report", "observed_headroom", "engine_headroom"]


def _deployed_signed(path: tuple) -> bool:
    # mirror of deploy_params: rwkv6's channel-mix wv consumes unsigned
    # (post-relu^2) activations; everything else is signed
    return not (len(path) >= 2 and path[-2] == "cm" and path[-1] == "wv")


def static_headroom_report(params: dict, quant) -> list:
    """Per-layer worst-case accumulator utilization for a deployed tree.

    One record per ``q8`` leaf (stacked leaves collapse to their worst
    channel across all stack members)::

        {"site", "utilization", "l1_max", "l1_budget", "acc_bits",
         "in_bits", "in_signed"}
    """
    P = quant.acc_bits if quant.mode == "a2q" else 32
    N = quant.act_bits
    out: list = []

    def walk(node, path=()):
        if not isinstance(node, dict):
            return
        if "q8" in node and "s8" in node:
            signed = _deployed_signed(path)
            q8 = np.asarray(node["q8"], dtype=np.int64)
            # weights are (..., K, C): channels (accumulators) on the last
            # axis, so per-channel l1 reduces the K axis
            l1 = np.abs(q8).sum(axis=-2)
            l1_max = float(l1.max()) if l1.size else 0.0
            out.append({
                "site": ".".join(path),
                "utilization": float(headroom_utilization(l1_max, N, signed, P)),
                "l1_max": l1_max,
                "l1_budget": l1_budget(P, N, signed),
                "acc_bits": P,
                "in_bits": N,
                "in_signed": signed,
            })
            return
        for k, v in node.items():
            walk(v, path + (k,))

    walk(params)
    return out


def observed_headroom(
    arch,
    params: dict,
    *,
    rt=None,
    tokens: Optional[np.ndarray] = None,
    batch: int = 1,
    seq: int = 8,
    seed: int = 0,
) -> list:
    """Sample observed accumulator magnitudes from one eager forward.

    Returns the probe records from :func:`nn.linear.acc_probe_scope` —
    empty when ``rt.int_forward`` is off (the fused path never runs) or
    every deployed site sits inside a vmapped stack.
    """
    from repro.models.lm import apply_lm
    from repro.nn.linear import acc_probe_scope

    if tokens is None:
        tokens = jax.random.randint(
            jax.random.PRNGKey(seed), (batch, seq), 0, arch.vocab, dtype=jnp.int32
        )
    samples: list = []
    with acc_probe_scope(samples):
        apply_lm(params, arch, tokens=jnp.asarray(tokens), rt=rt)
    return samples


def engine_headroom(engine, *, seq: int = 8, seed: int = 0) -> dict:
    """Populate an engine's metrics registry with headroom gauges.

    Static gauges cover every deployed layer; observed gauges cover the
    eager-probeable fused sites.  ``acc_headroom_violations`` counts static
    utilizations > 1.0 plus observed samples exceeding their bound — zero
    whenever the A2Q constraint actually held at deployment.
    """
    m = engine.obs.metrics
    quant = engine.arch.quant
    static = static_headroom_report(engine.params, quant)
    observed = observed_headroom(
        engine.arch, engine.params, rt=engine.rt, seq=seq, seed=seed
    )
    violations = 0
    util_max = 0.0
    for rec in static:
        m.gauge("acc_headroom_utilization", {"site": rec["site"]}).set(rec["utilization"])
        util_max = max(util_max, rec["utilization"])
        if rec["utilization"] > 1.0:
            violations += 1
    obs_max = 0.0
    for rec in observed:
        site = rec["site"] or "<unlabeled>"
        m.gauge("acc_observed_max", {"site": site}).set(rec["acc_max"])
        m.gauge("acc_bound", {"site": site}).set(rec["bound"])
        if rec["bound"] > 0:
            obs_max = max(obs_max, rec["acc_max"] / rec["bound"])
        if rec["acc_max"] > rec["bound"]:
            violations += 1
    m.gauge("acc_headroom_util_max").set(util_max)
    m.gauge("acc_observed_frac_max").set(obs_max)
    m.counter("acc_headroom_violations").set(violations)
    return {
        "layers": len(static),
        "observed_sites": len(observed),
        "util_max": util_max,
        "observed_frac_max": obs_max,
        "violations": violations,
    }
