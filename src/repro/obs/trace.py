"""Request-span tracing: context-manager spans with monotonic timestamps,
exported as Chrome trace-event JSON (Perfetto-loadable).

Design constraints, in priority order:

1. **~Zero cost disabled.**  ``Tracer(enabled=False).span(...)`` returns a
   module-level null-span singleton — no object allocation, no clock read,
   no event append — so instrumentation can live permanently on the serve
   hot paths (``tick``/``megastep`` dispatch loops) without a flag check at
   every call site.  ``instant()`` likewise returns immediately.
2. **Single-threaded nesting by containment.**  The serve engines are
   single-threaded hosts driving jitted device work, so spans need no
   explicit parent ids: every span records ``(name, t0, dur)`` against one
   ``(pid, tid)`` and Perfetto reconstructs the nesting from timestamp
   containment — exactly how Chrome's own trace events nest.  Events are
   appended at span *exit*, so a child always precedes its parent in the
   buffer (the ordering tests key off this).
3. **Clock = ``time.perf_counter``.**  Monotonic, the same clock the engine
   stats and request latency timestamps already use, so span durations and
   ``stats["decode_s"]`` agree to the microsecond and a trace can be lined
   up against a metrics snapshot from the same run.

The export format is the Chrome trace-event JSON object form::

    {"traceEvents": [
        {"name": "admit", "ph": "X", "ts": 12.3, "dur": 4500.0,
         "pid": 0, "tid": 0, "args": {"rid": 7}},
        {"name": "emit", "ph": "i", "ts": 99.0, "s": "t",
         "pid": 0, "tid": 0, "args": {"rid": 7}},
    ]}

``ph: "X"`` are complete (duration) events, ``ph: "i"`` are instants;
timestamps are microseconds relative to the tracer's construction.  Load
with https://ui.perfetto.dev ("Open trace file") or chrome://tracing.
"""

from __future__ import annotations

import json
import time
from typing import Optional

__all__ = ["Tracer", "Span", "NULL_SPAN"]


class _NullSpan:
    """Shared no-op span: the disabled-tracer fast path.  One module-level
    instance is returned for every ``span()`` call on a disabled tracer, so
    the disabled cost is one attribute check + one identity return."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    # duration reads on a disabled span are explicit zeros, never clock reads
    dur_s = 0.0


NULL_SPAN = _NullSpan()


class Span:
    """One live span; append-on-exit keeps ``__enter__`` to a clock read."""

    __slots__ = ("_tracer", "name", "args", "t0", "dur_s")

    def __init__(self, tracer: "Tracer", name: str, args: Optional[dict]):
        self._tracer = tracer
        self.name = name
        self.args = args
        self.t0 = 0.0
        self.dur_s = 0.0

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.dur_s = time.perf_counter() - self.t0
        self._tracer.events.append(("X", self.name, self.t0, self.dur_s, self.args))
        return False


class Tracer:
    """Span/instant collector with Chrome trace-event export.

    ``events`` holds ``(ph, name, t_s, dur_s, args)`` tuples where ``ph`` is
    ``"X"`` (complete span, appended at exit) or ``"i"`` (instant,
    ``dur_s`` is None).  Timestamps are raw ``perf_counter`` seconds; the
    export rebases them onto the tracer's origin in microseconds.
    """

    def __init__(self, enabled: bool = True, pid: int = 0, tid: int = 0):
        self.enabled = enabled
        self.pid = pid
        self.tid = tid
        self.events: list = []
        self._origin = time.perf_counter()

    # -- recording ----------------------------------------------------------

    def span(self, name: str, args: Optional[dict] = None):
        """Context manager timing one region.  Disabled tracers return the
        shared null span (identity-stable; zero allocation)."""
        if not self.enabled:
            return NULL_SPAN
        return Span(self, name, args)

    def instant(self, name: str, args: Optional[dict] = None) -> None:
        """Point event (``ph: "i"``): submissions, token emits."""
        if not self.enabled:
            return
        self.events.append(("i", name, time.perf_counter(), None, args))

    def clear(self) -> None:
        self.events.clear()
        self._origin = time.perf_counter()

    # -- inspection ---------------------------------------------------------

    def span_names(self) -> set:
        return {name for _, name, _, _, _ in self.events}

    def spans(self, name: Optional[str] = None) -> list:
        """Completed spans (ph == "X"), optionally filtered by name, as
        ``(name, t0_s, dur_s, args)`` in append (child-before-parent) order."""
        return [
            (n, t0, dur, args) for ph, n, t0, dur, args in self.events
            if ph == "X" and (name is None or n == name)
        ]

    def instants(self, name: Optional[str] = None) -> list:
        return [
            (n, t0, args) for ph, n, t0, _, args in self.events
            if ph == "i" and (name is None or n == name)
        ]

    # -- export -------------------------------------------------------------

    def to_chrome(self) -> dict:
        """The Chrome trace-event JSON object (``{"traceEvents": [...]}``)."""
        out = []
        for ph, name, t0, dur, args in self.events:
            ev = {
                "name": name, "ph": ph,
                "ts": (t0 - self._origin) * 1e6,
                "pid": self.pid, "tid": self.tid,
            }
            if ph == "X":
                ev["dur"] = dur * 1e6
            else:
                ev["s"] = "t"  # instant scope: thread
            if args:
                ev["args"] = args
            out.append(ev)
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
