"""Metrics registry: counters / gauges / histograms with labels, one
``snapshot()`` contract, and associative snapshot merging for the cluster
fleet view.

The registry absorbs the stack's previously scattered runtime signals —
engine ``stats``/``throughput()``, paged-cache counters, the int-chain
report, jit compile counts — behind a single schema:

* **Counter** — monotone accumulator (tokens, dispatches, cache events).
* **Gauge** — last-written value (utilization, acceptance rate, compile
  counts, peak block usage).
* **Histogram** — raw observed values (request latency, TTFT) with
  nearest-rank percentiles.

Snapshot keys are Prometheus-flavoured: ``name`` or ``name{k=v,...}`` with
label pairs sorted, so equal metric identities collide by construction.
Snapshots are plain JSON dicts::

    {"serve_decode_tokens": {"type": "counter", "value": 512.0},
     "request_latency_s":   {"type": "histogram", "values": [...]},
     "acc_headroom_utilization{site=blocks.0.attn.wq}":
                            {"type": "gauge", "value": 0.41}}

``merge_snapshots`` defines the fleet semantics: counters **add**, gauges
take the **max** (the conservative choice for utilizations, peaks, and
compile counts), histograms **concatenate** raw values.  All three are
associative and commutative, so ``replica ⊕ replica == fleet`` regardless
of arrival order — the property the cluster tests pin.

``percentile`` is the one shared quantile implementation (nearest-rank:
``rank = ceil(q/100 · n)``), replacing the duplicated ``np.percentile``
calls in ``serve/cluster/replica.py`` and ``benchmarks/serve_bench.py``.
Nearest-rank returns an *observed* sample even for tiny n — p99 of 5
samples is the max, not an interpolated value that no request experienced.
"""

from __future__ import annotations

import json
import math
from typing import Dict, Iterable, Optional, Sequence

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "percentile", "merge_snapshots",
]


def percentile(values: Sequence[float], q: float) -> float:
    """Nearest-rank percentile: the smallest observed value with at least
    ``q`` percent of samples at or below it.  Returns 0.0 on empty input
    (callers report "no samples yet" as zero latency, matching the engine
    stats convention)."""
    if not values:
        return 0.0
    xs = sorted(values)
    rank = math.ceil(q / 100.0 * len(xs))
    return float(xs[min(max(rank, 1), len(xs)) - 1])


def _key(name: str, labels: Optional[dict]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotone accumulator.  ``set`` exists for absorbing externally
    maintained totals (engine stats dicts) at snapshot time."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def set(self, value: float) -> None:
        self.value = float(value)


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)


class Histogram:
    """Raw-sample histogram.  The serve workloads observe at most a few
    thousand requests per run, so storing raw values keeps percentiles
    exact and merge trivial (concat); a bucketed representation can replace
    the storage later without changing the snapshot contract."""

    __slots__ = ("values",)

    def __init__(self):
        self.values: list = []

    def observe(self, value: float) -> None:
        self.values.append(float(value))

    def percentile(self, q: float) -> float:
        return percentile(self.values, q)

    @property
    def count(self) -> int:
        return len(self.values)


class MetricsRegistry:
    """Get-or-create registry keyed by ``(name, sorted labels)``."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get(self, cls, name: str, labels: Optional[dict]):
        key = _key(name, labels)
        m = self._metrics.get(key)
        if m is None:
            m = cls()
            self._metrics[key] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {key!r} already registered as {type(m).__name__}")
        return m

    def counter(self, name: str, labels: Optional[dict] = None) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, labels: Optional[dict] = None) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, labels: Optional[dict] = None) -> Histogram:
        return self._get(Histogram, name, labels)

    def reset(self) -> None:
        self._metrics.clear()

    # -- snapshot contract --------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-serializable view of every registered metric."""
        out = {}
        for key, m in sorted(self._metrics.items()):
            if isinstance(m, Counter):
                out[key] = {"type": "counter", "value": m.value}
            elif isinstance(m, Gauge):
                out[key] = {"type": "gauge", "value": m.value}
            else:
                out[key] = {"type": "histogram", "values": list(m.values)}
        return out

    def load(self, snap: dict) -> None:
        """Restore metrics from a snapshot (used by the router to park a
        merged fleet view in a registry for percentile queries)."""
        for key, entry in snap.items():
            name, labels = _parse_key(key)
            if entry["type"] == "counter":
                self.counter(name, labels).set(entry["value"])
            elif entry["type"] == "gauge":
                self.gauge(name, labels).set(entry["value"])
            else:
                self.histogram(name, labels).values.extend(entry["values"])

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2, sort_keys=True)


def _parse_key(key: str):
    if "{" not in key:
        return key, None
    name, rest = key.split("{", 1)
    labels = {}
    for pair in rest.rstrip("}").split(","):
        k, v = pair.split("=", 1)
        labels[k] = v
    return name, labels


def merge_snapshots(*snaps: dict) -> dict:
    """Fleet merge: counters add, gauges max, histograms concat.

    Each rule is associative and commutative over its value domain, so any
    grouping/order of replica snapshots yields the same fleet view."""
    out: dict = {}
    for snap in snaps:
        for key, entry in snap.items():
            cur = out.get(key)
            if cur is None:
                out[key] = {
                    "type": entry["type"],
                    **({"values": list(entry["values"])} if entry["type"] == "histogram"
                       else {"value": entry["value"]}),
                }
                continue
            if cur["type"] != entry["type"]:
                raise TypeError(f"metric {key!r} merged across types "
                                f"{cur['type']!r} vs {entry['type']!r}")
            if entry["type"] == "counter":
                cur["value"] += entry["value"]
            elif entry["type"] == "gauge":
                cur["value"] = max(cur["value"], entry["value"])
            else:
                cur["values"].extend(entry["values"])
    return out
