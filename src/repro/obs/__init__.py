"""Unified observability layer for the serve stack.

``Obs`` bundles the two collectors every engine carries:

* ``obs.trace`` — request-span tracer (Chrome trace-event export).
* ``obs.metrics`` — counter/gauge/histogram registry with one
  ``snapshot()`` contract.

Engines default to ``Obs(trace=False)``: metrics are always live (they
back ``--metrics-json`` and the cluster fleet view), tracing is opt-in
because only the span path touches the per-dispatch hot loop.
"""

from __future__ import annotations

from repro.obs.metrics import (
    Counter, Gauge, Histogram, MetricsRegistry, merge_snapshots, percentile,
)
from repro.obs.trace import NULL_SPAN, Span, Tracer

__all__ = [
    "Obs", "Tracer", "Span", "NULL_SPAN",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "merge_snapshots", "percentile",
]


class Obs:
    """Tracer + metrics bundle threaded through the serve stack."""

    def __init__(self, trace: bool = False):
        self.trace = Tracer(enabled=trace)
        self.metrics = MetricsRegistry()

    def reset(self) -> None:
        """Clear collected state (spans + metrics); the single reset path
        behind every ``reset_stats``."""
        self.trace.clear()
        self.metrics.reset()
