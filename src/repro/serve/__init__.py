from repro.serve.engine import (  # noqa: F401
    PagedServeEngine,
    Request,
    ServeEngine,
    deploy_boxed,
    deploy_params,
)
from repro.serve.paged_cache import PagedKVCache  # noqa: F401
from repro.serve.sampling import SampleConfig, sample_tokens  # noqa: F401
from repro.serve.scheduler import Scheduler, ServeRequest  # noqa: F401
from repro.serve.spec import ModelDrafter, SelfDrafter, SpecServeEngine  # noqa: F401
