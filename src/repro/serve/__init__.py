from repro.serve.engine import ServeEngine, deploy_params  # noqa: F401
