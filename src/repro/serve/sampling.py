"""On-device token sampling for the serve engines.

The seed engine round-tripped full ``(B, V)`` logits to the host and ran
``np.argmax`` every tick.  Here sampling runs *inside* the jitted decode /
prefill step: the step returns ``(B,)`` int32 token ids, the host fetches a
few bytes of ids for bookkeeping, and the sampled tokens feed straight back
into the next step without ever materializing logits off-device.

``SampleConfig`` is a frozen (hashable) dataclass so a jitted step closing
over it re-traces only when the sampling mode actually changes.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["SampleConfig", "sample_tokens"]


@dataclasses.dataclass(frozen=True)
class SampleConfig:
    """``greedy`` (argmax), ``temperature`` (softmax sampling), or ``topk``
    (mask to the ``top_k`` highest logits, then temperature-sample)."""

    method: str = "greedy"
    temperature: float = 1.0
    top_k: int = 0

    def __post_init__(self):
        if self.method not in ("greedy", "temperature", "topk"):
            raise ValueError(f"unknown sampling method {self.method!r}")
        if self.method == "topk" and self.top_k <= 0:
            raise ValueError("topk sampling needs top_k > 0")


def sample_tokens(logits: jnp.ndarray, cfg: SampleConfig, key) -> jnp.ndarray:
    """``(..., V)`` logits -> ``(...,)`` int32 token ids, fully on device.

    Greedy ignores ``key`` (deterministic argmax, first-index tie-break —
    identical to ``np.argmax`` on the same logits, which is what the
    paged-vs-contiguous parity gates rely on).
    """
    lf = logits.astype(jnp.float32)
    if cfg.method == "greedy":
        return jnp.argmax(lf, axis=-1).astype(jnp.int32)
    if cfg.method == "topk":
        vals = jax.lax.top_k(lf, cfg.top_k)[0]
        lf = jnp.where(lf < vals[..., -1:], -jnp.inf, lf)
    t = max(cfg.temperature, 1e-6)
    return jax.random.categorical(key, lf / t, axis=-1).astype(jnp.int32)
