"""On-device token sampling for the serve engines.

The seed engine round-tripped full ``(B, V)`` logits to the host and ran
``np.argmax`` every tick.  Here sampling runs *inside* the jitted decode /
prefill step: the step returns ``(B,)`` int32 token ids, the host fetches a
few bytes of ids for bookkeeping, and the sampled tokens feed straight back
into the next step without ever materializing logits off-device.

``SampleConfig`` is a frozen (hashable) dataclass so a jitted step closing
over it re-traces only when the sampling mode actually changes.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["SampleConfig", "sample_tokens", "TEMPERATURE_EPS"]

# Below this, temperature sampling *is* greedy: dividing logits by a vanishing
# temperature inflates them toward +/-inf, and exp() of that feeds NaN
# probabilities into ``jax.random.categorical`` (--temperature 0 used to
# decode pure garbage).  Routing to argmax is the correct limit.
TEMPERATURE_EPS = 1e-6


@dataclasses.dataclass(frozen=True)
class SampleConfig:
    """``greedy`` (argmax), ``temperature`` (softmax sampling), or ``topk``
    (mask to the ``top_k`` highest logits, then temperature-sample)."""

    method: str = "greedy"
    temperature: float = 1.0
    top_k: int = 0

    def __post_init__(self):
        if self.method not in ("greedy", "temperature", "topk"):
            raise ValueError(f"unknown sampling method {self.method!r}")
        if self.method == "topk" and self.top_k <= 0:
            raise ValueError("topk sampling needs top_k > 0")
        if self.method in ("temperature", "topk") and self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")


def sample_tokens(logits: jnp.ndarray, cfg: SampleConfig, key) -> jnp.ndarray:
    """``(..., V)`` logits -> ``(...,)`` int32 token ids, fully on device.

    Greedy ignores ``key`` (deterministic argmax, first-index tie-break —
    identical to ``np.argmax`` on the same logits, which is what the
    paged-vs-contiguous parity gates rely on).  ``temperature <=
    TEMPERATURE_EPS`` takes the greedy path too (the zero-temperature limit;
    dividing by it would blow logits up to inf and sample NaN), and ``top_k``
    is clamped to the vocab size (``lax.top_k`` hard-crashes past it, and
    top-V-of-V is plain temperature sampling anyway).
    """
    lf = logits.astype(jnp.float32)
    if cfg.method == "greedy" or cfg.temperature <= TEMPERATURE_EPS:
        return jnp.argmax(lf, axis=-1).astype(jnp.int32)
    if cfg.method == "topk":
        k = min(cfg.top_k, lf.shape[-1])
        vals = jax.lax.top_k(lf, k)[0]
        lf = jnp.where(lf < vals[..., -1:], -jnp.inf, lf)
    return jax.random.categorical(key, lf / cfg.temperature, axis=-1).astype(jnp.int32)
