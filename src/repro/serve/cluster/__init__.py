from repro.serve.cluster.replica import (  # noqa: F401
    InProcessReplica,
    Replica,
    ReplicaConfig,
    SubprocessReplica,
    build_engine,
)
from repro.serve.cluster.router import ClusterRequest, Router  # noqa: F401
from repro.serve.cluster.disagg import (  # noqa: F401
    handoff_local,
    make_cluster_configs,
    parse_disagg,
)
