"""Prefill/decode disaggregation helpers.

The mechanism lives in two layers below this module — the cache migrates
blocks (:meth:`PagedKVCache.export_blocks` / :meth:`import_blocks`, wire
width = storage width: int8 codes as int8, packed int4 as uint8 nibble
pairs, scales as fp32) and the engine runs the two halves
(:meth:`PagedServeEngine.prefill_handoff` / :meth:`submit_handoff`).  This
module supplies the topology plumbing: the ``P:D`` split of a replica
fleet, and a direct engine→engine handoff used by tests and parity gates
without standing up mailboxes."""

from __future__ import annotations

import dataclasses

from repro.serve.cluster.replica import ReplicaConfig

__all__ = ["parse_disagg", "make_cluster_configs", "handoff_local"]


def parse_disagg(spec: str) -> tuple[int, int]:
    """``"P:D"`` -> (prefill replicas, decode replicas), both >= 1."""
    try:
        p, d = (int(x) for x in spec.split(":"))
    except ValueError:
        raise ValueError(f"--disagg wants P:D (e.g. 1:2), got {spec!r}") from None
    if p < 1 or d < 1:
        raise ValueError(f"--disagg needs at least one replica per role, got {spec!r}")
    return p, d


def make_cluster_configs(base: ReplicaConfig, replicas: int = 0,
                         disagg: tuple[int, int] | None = None) -> list[ReplicaConfig]:
    """Fan a base config out into a named fleet: ``replicas`` homogeneous
    ``both``-role engines, or a ``(P, D)`` disaggregated split (``p0..``
    prefill-only, ``d0..`` decode-only)."""
    if disagg is not None:
        p, d = disagg
        return (
            [dataclasses.replace(base, name=f"p{i}", role="prefill") for i in range(p)]
            + [dataclasses.replace(base, name=f"d{i}", role="decode") for i in range(d)]
        )
    if replicas < 1:
        raise ValueError("need --replicas >= 1 or a --disagg split")
    return [dataclasses.replace(base, name=f"r{i}", role="both") for i in range(replicas)]


def handoff_local(prefill_engine, decode_engine, req) -> dict:
    """Engine→engine migration without a cluster: run the prompt on
    ``prefill_engine``, hand the exported blocks to ``decode_engine``'s
    queue.  Returns the wire payload (for size/dtype assertions).  The
    caller steps ``decode_engine`` to completion."""
    import copy

    from repro.serve.engine import Request

    probe = Request(uid=req.uid, prompt=copy.deepcopy(req.prompt),
                    max_new=req.max_new, eos_id=req.eos_id)
    payload = prefill_engine.prefill_handoff(probe)
    decode_engine.submit_handoff(req, payload)
    return payload
