"""Cluster replica: one serving engine behind a mailbox.

A :class:`Replica` wraps one :class:`~repro.serve.engine.PagedServeEngine`
(any flag combination — ``--int-forward``, ``--kv-int8``, ``--decode-steps``,
``--prefix-share``, speculative) and speaks a small message protocol with the
router.  The same replica code runs two transports:

* **in-process** (:class:`InProcessReplica`): commands/events move through a
  pair of deques and the router drives ``pump()`` directly — fully
  deterministic, the substrate for tests and the serve_bench cluster cohort
  (every replica's engine keeps its own wall-clock ``stats``, so aggregate
  capacity is measured per replica even though one host interleaves them);
* **subprocess** (:class:`SubprocessReplica`): the replica owns a real
  process (``spawn`` context — forking after jax initializes is unsafe) and
  the same messages cross a ``multiprocessing.Pipe``.  The child rebuilds its
  engine from the picklable :class:`ReplicaConfig` (params re-initialized
  deterministically from the seed, so every replica — and the router-side
  parity reference — serves identical weights).

Protocol (plain dicts, picklable; numpy arrays allowed in handoff payloads):

    router -> replica
      {"op": "submit",  "rid", "prompt", "max_new", "eos_id"}   full lifecycle
      {"op": "prefill", "rid", "prompt", "max_new", "eos_id"}   prefill role:
                        run the prompt, export KV, reply with a handoff event
      {"op": "adopt",   "rid", "prompt", "max_new", "eos_id", "payload"}
                        decode role: import migrated KV, decode from it
      {"op": "reset_stats"} | {"op": "stats"} | {"op": "shutdown"}

    replica -> router
      {"type": "hello", "name", "role", "num_blocks", "block_size", "batch"}
      {"type": "heartbeat", ...}      queue depth, free blocks, tok/s EWMAs, p99
      {"type": "progress", "rid", "tokens", "done"}   full generated-so-far list
                        (the router appends only the unseen suffix — the
                        at-most-once emission guarantee lives router-side)
      {"type": "handoff", "rid", "payload"}           exported KV + first token
      {"type": "reject", "rid", "reason"}             request can never fit here
      {"type": "stats", ...}                          throughput + migration counters
"""

from __future__ import annotations

import dataclasses
import multiprocessing
import time
from collections import deque
from typing import Optional

import numpy as np

__all__ = [
    "ReplicaConfig", "Replica", "InProcessReplica", "SubprocessReplica",
    "build_engine",
]

# EWMA smoothing for the per-replica tok/s health signals: ~3-step memory,
# fast enough to follow a load shift, slow enough to ride out one odd step
_EWMA_ALPHA = 0.3


@dataclasses.dataclass
class ReplicaConfig:
    """Everything needed to rebuild a replica's engine in another process.
    Only names/scalars — params are re-initialized from ``seed`` (and
    optionally deployed to int8), never shipped."""

    name: str = "r0"
    arch: str = "yi-6b"
    reduced: bool = True
    role: str = "both"  # both | prefill | decode
    seed: int = 0
    batch: int = 2
    max_seq: int = 128
    block_size: int = 16
    prefill_chunk: int = 32
    num_blocks: Optional[int] = None
    kv_quant: bool = False
    kv_bits: int = 8
    prefix_share: bool = False
    decode_steps: int = 1
    eos_id: Optional[int] = None
    deploy_int8: bool = False
    int_forward: bool = False
    spec_k: int = 0

    def __post_init__(self):
        if self.role not in ("both", "prefill", "decode"):
            raise ValueError(f"unknown replica role {self.role!r}")


def build_engine(cfg: ReplicaConfig, params=None):
    """Construct the engine a :class:`ReplicaConfig` describes.  ``params``
    (raw, un-deployed) may be passed to share one host copy across
    in-process replicas; subprocesses re-derive them from the seed."""
    import jax

    from repro.configs import get_arch, reduced
    from repro.models.lm import Runtime, init_lm
    from repro.nn.module import unbox
    from repro.serve.engine import PagedServeEngine, deploy_params

    arch = get_arch(cfg.arch)
    if cfg.reduced:
        arch = reduced(arch)
    if params is None:
        params = unbox(init_lm(jax.random.PRNGKey(cfg.seed), arch))
    if cfg.deploy_int8 or cfg.int_forward:
        params = deploy_params(params, arch.quant)
    kw = dict(
        batch=cfg.batch, max_seq=cfg.max_seq, block_size=cfg.block_size,
        prefill_chunk=cfg.prefill_chunk, num_blocks=cfg.num_blocks,
        kv_quant=cfg.kv_quant, kv_bits=cfg.kv_bits,
        prefix_share=cfg.prefix_share, eos_id=cfg.eos_id,
        decode_steps=cfg.decode_steps, seed=cfg.seed,
        rt=Runtime(int_forward=cfg.int_forward),
    )
    if cfg.spec_k > 0:
        from repro.serve.spec import SpecServeEngine

        return SpecServeEngine(arch, params, spec_k=cfg.spec_k, **kw)
    return PagedServeEngine(arch, params, **kw)


class LocalMailbox:
    """In-process transport: two deques, zero copies, deterministic order."""

    def __init__(self):
        self._to_replica: deque = deque()
        self._to_router: deque = deque()

    # replica side
    def recv_commands(self) -> list:
        out = list(self._to_replica)
        self._to_replica.clear()
        return out

    def send_event(self, ev: dict) -> None:
        self._to_router.append(ev)

    # router side
    def send_command(self, cmd: dict) -> None:
        self._to_replica.append(cmd)

    def recv_events(self) -> list:
        out = list(self._to_router)
        self._to_router.clear()
        return out


class PipeMailbox:
    """Replica side of a ``multiprocessing.Pipe`` connection."""

    def __init__(self, conn):
        self.conn = conn

    def recv_commands(self) -> list:
        out = []
        try:
            while self.conn.poll():
                out.append(self.conn.recv())
        except (EOFError, OSError):
            out.append({"op": "shutdown"})  # router went away
        return out

    def send_event(self, ev: dict) -> None:
        try:
            self.conn.send(ev)
        except (BrokenPipeError, OSError):
            pass


class Replica:
    """One engine + protocol state.  ``pump()`` is the whole replica loop:
    drain commands, run pending prefill handoffs, advance the engine one
    step, report progress, heartbeat."""

    def __init__(self, cfg: ReplicaConfig, box, engine=None):
        self.cfg = cfg
        self.box = box
        self.engine = engine if engine is not None else build_engine(cfg)
        self._track: dict = {}  # rid -> (Request, tokens already reported)
        self._pending_prefills: deque = deque()
        self._prev = dict(self.engine.stats)
        self._ewma = {"prefill_tok_s": 0.0, "decode_tok_s": 0.0}
        self.served = 0
        self.shutdown = False
        self.dead = False  # fault injection: a dead replica goes silent
        cache = self.engine.cache
        self.box.send_event({
            "type": "hello", "name": cfg.name, "role": cfg.role,
            "num_blocks": cache.num_blocks, "block_size": cache.block_size,
            "batch": self.engine.batch,
        })

    # -- command handling ---------------------------------------------------

    def _mk_request(self, cmd):
        from repro.serve.engine import Request

        return Request(
            uid=int(cmd["rid"]),
            prompt=np.asarray(cmd["prompt"], np.int32),
            max_new=int(cmd["max_new"]),
            eos_id=cmd.get("eos_id"),
        )

    def _handle(self, cmd: dict) -> None:
        op = cmd["op"]
        if op == "submit":
            if self.cfg.role == "prefill":
                raise RuntimeError(f"{self.cfg.name}: prefill-role replica got a full submit")
            req = self._mk_request(cmd)
            try:
                self.engine.submit(req)
            except ValueError as e:
                self.box.send_event({"type": "reject", "rid": req.uid, "reason": str(e)})
                return
            self._track[req.uid] = (req, 0)
        elif op == "prefill":
            self._pending_prefills.append(self._mk_request(cmd))
        elif op == "adopt":
            if self.cfg.role == "prefill":
                raise RuntimeError(f"{self.cfg.name}: prefill-role replica got an adopt")
            req = self._mk_request(cmd)
            try:
                self.engine.submit_handoff(req, cmd["payload"])
            except ValueError as e:
                self.box.send_event({"type": "reject", "rid": req.uid, "reason": str(e)})
                return
            self._track[req.uid] = (req, 0)
        elif op == "reset_stats":
            # one reset path: engine stats + obs (spans, metrics, latency
            # histograms) + paged-cache counters all clear through
            # engine.reset_stats — the old per-field clearing here leaked
            # cache counters across benchmark phases
            self.engine.reset_stats()
            self._prev = dict(self.engine.stats)
            self._ewma = {"prefill_tok_s": 0.0, "decode_tok_s": 0.0}
            self.served = 0
        elif op == "stats":
            cache = self.engine.cache
            self.box.send_event({
                "type": "stats", "name": self.cfg.name, "served": self.served,
                "throughput": self.engine.throughput(),
                "migrated_blocks_in": cache.migrated_blocks_in,
                "migrated_blocks_out": cache.migrated_blocks_out,
                "migration_bytes_in": cache.migration_bytes_in,
                "migration_bytes_out": cache.migration_bytes_out,
                "prefix_hits": cache.prefix_hits,
                # the full unified snapshot rides along: the router merges
                # these into the fleet view (merge_snapshots)
                "metrics": self.engine.metrics_snapshot(),
            })
        elif op == "shutdown":
            self.shutdown = True
        else:
            raise ValueError(f"unknown op {op!r}")

    # -- loop body ----------------------------------------------------------

    def pump(self) -> bool:
        """One replica turn; returns True if engine work happened (the
        subprocess loop sleeps briefly on False)."""
        if self.dead or self.shutdown:
            return False
        for cmd in self.box.recv_commands():
            self._handle(cmd)
            if self.dead or self.shutdown:
                return False
        worked = False
        # prefill-handoff service: one prompt per pump keeps the replica
        # responsive to kills/heartbeats between prompts
        if self._pending_prefills:
            req = self._pending_prefills[0]
            if self.engine.can_prefill_handoff(req):
                self._pending_prefills.popleft()
                payload = self.engine.prefill_handoff(req)
                self.box.send_event(
                    {"type": "handoff", "rid": req.uid, "payload": payload}
                )
                self.served += 1
                worked = True
        if not self.engine.sched.idle():
            self.engine.step()
            worked = True
        self._report_progress()
        self._update_ewma()
        self.box.send_event(self._heartbeat())
        return worked

    def _report_progress(self) -> None:
        done = []
        for rid, (req, sent) in self._track.items():
            if len(req.generated) > sent or (req.done and sent == 0):
                self.box.send_event({
                    "type": "progress", "rid": rid,
                    "tokens": list(req.generated), "done": req.done,
                })
                self._track[rid] = (req, len(req.generated))
            if req.done:
                done.append(rid)
                self.served += 1
        for rid in done:
            del self._track[rid]

    def _update_ewma(self) -> None:
        cur = self.engine.stats
        for phase in ("prefill", "decode"):
            dt = cur[f"{phase}_s"] - self._prev[f"{phase}_s"]
            dtok = cur[f"{phase}_tokens"] - self._prev[f"{phase}_tokens"]
            if dt > 0 and dtok > 0:
                inst = dtok / dt
                old = self._ewma[f"{phase}_tok_s"]
                self._ewma[f"{phase}_tok_s"] = (
                    inst if old == 0.0 else (1 - _EWMA_ALPHA) * old + _EWMA_ALPHA * inst
                )
        self._prev = dict(cur)

    def _heartbeat(self) -> dict:
        cache = self.engine.cache
        # completed-request latencies live in the engine's obs histogram
        # (recorded at Scheduler.record_token); nearest-rank percentiles so
        # p99 of a handful of requests is an observed sample, not an
        # interpolated value no request experienced
        lat = self.engine.obs.metrics.histogram("request_latency_s")
        return {
            "type": "heartbeat", "name": self.cfg.name,
            "queued": len(self.engine.sched.queue) + len(self._pending_prefills),
            "live": len(self.engine.sched.live),
            "free_blocks": cache.free_blocks,
            "reclaimable_blocks": cache.reclaimable_blocks(),
            "ewma_prefill_tok_s": self._ewma["prefill_tok_s"],
            "ewma_decode_tok_s": self._ewma["decode_tok_s"],
            "p99_s": lat.percentile(99),
            "p50_s": lat.percentile(50),
            "served": self.served,
        }


def _replica_main(cfg: ReplicaConfig, conn) -> None:
    box = PipeMailbox(conn)
    rep = Replica(cfg, box)
    while not rep.shutdown:
        if not rep.pump() and not rep.dead:
            # idle: block briefly on the pipe instead of spinning
            conn.poll(0.002)


class InProcessReplica:
    """Deterministic handle: the router's ``step()`` drives ``pump()``."""

    transport = "inproc"

    def __init__(self, cfg: ReplicaConfig, engine=None, params=None):
        self.cfg = cfg
        self.name = cfg.name
        self.box = LocalMailbox()
        if engine is None and params is not None:
            engine = build_engine(cfg, params=params)
        self.replica = Replica(cfg, self.box, engine=engine)

    def send(self, cmd: dict) -> None:
        self.box.send_command(cmd)

    def poll(self) -> list:
        return self.box.recv_events()

    def pump(self) -> bool:
        if self.replica.dead:
            return False
        return self.replica.pump()

    def alive(self) -> bool:
        return not self.replica.dead

    def kill(self) -> None:
        """Fault injection: the replica goes silent mid-flight (in-flight
        requests stranded until the router requeues them)."""
        self.replica.dead = True

    def close(self) -> None:
        self.replica.shutdown = True


class SubprocessReplica:
    """Real-process handle over a spawn-context pipe."""

    transport = "subproc"

    def __init__(self, cfg: ReplicaConfig):
        self.cfg = cfg
        self.name = cfg.name
        ctx = multiprocessing.get_context("spawn")
        self.conn, child = ctx.Pipe()
        self.proc = ctx.Process(target=_replica_main, args=(cfg, child), daemon=True)
        self.proc.start()
        child.close()

    def send(self, cmd: dict) -> None:
        try:
            self.conn.send(cmd)
        except (BrokenPipeError, OSError):
            pass

    def poll(self) -> list:
        out = []
        try:
            while self.conn.poll():
                out.append(self.conn.recv())
        except (EOFError, OSError):
            pass
        return out

    def pump(self) -> bool:
        return False  # the child process pumps itself

    def alive(self) -> bool:
        return self.proc.is_alive()

    def kill(self) -> None:
        self.proc.terminate()

    def close(self) -> None:
        if self.proc.is_alive():
            self.send({"op": "shutdown"})
            self.proc.join(timeout=30)
        if self.proc.is_alive():
            self.proc.terminate()
            self.proc.join(timeout=10)

    def __del__(self):
        try:
            if self.proc.is_alive():
                self.proc.terminate()
        except Exception:
            pass
