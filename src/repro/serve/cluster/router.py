"""Cluster router: admission routing, backpressure, stickiness, failover.

The router owns the client-facing request queue and drives N replica handles
(:mod:`repro.serve.cluster.replica`).  Design rules:

* **Backpressure by block budget.**  The router keeps its own commitment
  ledger per replica — the worst-case blocks of every dispatched-but-
  unfinished request — and never dispatches past a replica's pool capacity.
  Excess traffic waits *here* (where it can still be re-routed or requeued),
  not in a replica's queue.  Admission order is strict FIFO, matching the
  engine scheduler's no-starvation rule: if the head request fits nowhere,
  nothing behind it jumps ahead.
* **Policies.**  ``least-loaded`` picks the replica with the fewest committed
  blocks; ``weighted-latency`` scores replicas by expected drain time
  (committed tokens / heartbeat decode-tok/s EWMA) so a faster engine —
  e.g. a megastep replica next to a per-tick one — absorbs more of the wave.
* **Sticky prefixes.**  Requests whose first prompt block matches an earlier
  request are routed to the replica that served it (when it has room), so
  radix-prompt-cache hits stay warm on one replica instead of spraying cold
  misses across the fleet.
* **Failover.**  A replica is dead when its process/flag says so or when no
  event has arrived for ``heartbeat_timeout`` seconds (injectable clock).
  Its in-flight requests are requeued at the *front* of the queue in
  original order.  Request ids make the retry idempotent; the router emits
  each client token **at most once** by appending only the unseen suffix of
  every progress report — a restarted (greedy, deterministic) request
  regenerates the same prefix and the client stream just continues.
* **Disaggregation.**  With prefill-role replicas present, prompts are
  dispatched to a prefill replica first; its handoff event (exported KV
  blocks + first token, :meth:`PagedKVCache.export_blocks`) is then
  dispatched to a decode-role replica that imports the blocks and decodes
  without recomputing the prompt.  The handoff payload lives at the router
  until completion, so a decode-replica death re-dispatches the *same* KV
  — prefill work is never repeated on failover.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import numpy as np

__all__ = ["Router", "ClusterRequest"]

POLICIES = ("least-loaded", "weighted-latency")


@dataclasses.dataclass
class ClusterRequest:
    rid: int
    prompt: np.ndarray
    max_new: int
    eos_id: Optional[int]
    emitted: list = dataclasses.field(default_factory=list)
    done: bool = False
    stage: str = "queued"  # queued | prefill | await_decode | decode | done
    replica: Optional[str] = None
    attempts: int = 0
    handoff: Optional[dict] = None  # exported-KV payload (disagg path)
    submitted_at: Optional[float] = None
    finished_at: Optional[float] = None


class _ReplicaState:
    def __init__(self, handle):
        self.handle = handle
        self.name = handle.name
        self.role = handle.cfg.role
        self.alive = True
        self.hello: Optional[dict] = None
        self.hb: dict = {}
        self.last_seen: Optional[float] = None
        self.inflight: dict = {}  # rid -> committed blocks
        self.committed = 0
        self.dispatched = 0
        self.stats: Optional[dict] = None

    @property
    def capacity(self) -> int:
        return self.hello["num_blocks"] - 1  # block 0 is the trash block

    @property
    def block_size(self) -> int:
        return self.hello["block_size"]


class Router:
    def __init__(
        self,
        handles,
        *,
        policy: str = "least-loaded",
        sticky: bool = True,
        heartbeat_timeout: float = 5.0,
        clock=time.monotonic,
    ):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; pick from {POLICIES}")
        if not handles:
            raise ValueError("router needs at least one replica handle")
        self.policy = policy
        self.sticky = sticky
        self.heartbeat_timeout = heartbeat_timeout
        self.clock = clock
        self.states = {h.name: _ReplicaState(h) for h in handles}
        if len(self.states) != len(handles):
            raise ValueError("replica names must be unique")
        self.reqs: dict = {}
        self.queue: deque = deque()  # ClusterRequests awaiting (pre)fill dispatch
        self.pending_adopts: deque = deque()  # handoffs awaiting decode capacity
        self._sticky: dict = {}  # first-block token key -> replica name
        self._next_rid = 0
        self.requeues = 0
        self.deaths = 0

    # -- client API ---------------------------------------------------------

    def submit(self, prompt, max_new: int = 16, eos_id: Optional[int] = None) -> int:
        rid = self._next_rid
        self._next_rid += 1
        creq = ClusterRequest(
            rid=rid, prompt=np.asarray(prompt, np.int32).reshape(-1),
            max_new=int(max_new), eos_id=eos_id,
            submitted_at=self.clock(),
        )
        self.reqs[rid] = creq
        self.queue.append(creq)
        return rid

    def outstanding(self) -> int:
        return sum(1 for r in self.reqs.values() if not r.done)

    def results(self) -> dict:
        return {rid: list(r.emitted) for rid, r in self.reqs.items()}

    def step(self, now: Optional[float] = None) -> int:
        """One router turn: pump in-process replicas, ingest their events,
        fail over dead replicas, dispatch what fits.  Returns the number of
        events ingested (0 = externally idle; drivers of subprocess
        clusters sleep briefly on it)."""
        now = self.clock() if now is None else now
        for st in self.states.values():
            if st.alive:
                st.handle.pump()
        n_events = self._drain_events(now)
        self._check_health(now)
        self._dispatch()
        return n_events

    def drain(self, *, max_steps: int = 200_000, idle_timeout_s: float = 300.0,
              on_step=None) -> dict:
        """Step until every submitted request completes.  ``on_step(router,
        step_idx)`` is the fault-injection hook.  ``idle_timeout_s`` bounds
        wall time with no observable progress (covers a hung subprocess) —
        generous by default because a cold replica's first prompt pays its
        XLA compiles."""
        steps = 0
        last_progress = time.monotonic()
        progress_mark = (0, 0)
        while self.outstanding():
            n = self.step()
            if on_step is not None:
                on_step(self, steps)
            steps += 1
            mark = (sum(len(r.emitted) for r in self.reqs.values()), self.requeues)
            if n or mark != progress_mark:
                progress_mark = mark
                last_progress = time.monotonic()
            elif time.monotonic() - last_progress > idle_timeout_s:
                raise RuntimeError(
                    f"cluster made no progress for {idle_timeout_s:.0f}s "
                    f"({self.outstanding()} requests outstanding)"
                )
            if steps > max_steps:
                raise RuntimeError(f"cluster drain exceeded {max_steps} steps")
            if n == 0 and all(
                st.handle.transport != "inproc" for st in self.states.values()
            ):
                time.sleep(0.002)
        return self.results()

    def close(self) -> None:
        for st in self.states.values():
            st.handle.close()

    # -- fleet management ---------------------------------------------------

    def reset_stats(self) -> None:
        for st in self.states.values():
            if st.alive:
                st.handle.send({"op": "reset_stats"})

    def collect_stats(self, timeout_s: float = 60.0) -> dict:
        """Synchronous stats sweep of the live fleet."""
        want = [st for st in self.states.values() if st.alive]
        for st in want:
            st.stats = None
            st.handle.send({"op": "stats"})
        deadline = time.monotonic() + timeout_s
        while any(st.stats is None for st in want):
            self.step()
            if time.monotonic() > deadline:
                missing = [st.name for st in want if st.stats is None]
                raise RuntimeError(f"stats timeout: no reply from {missing}")
        return {st.name: st.stats for st in want}

    def fleet_metrics(self, stats: Optional[dict] = None) -> dict:
        """Fleet-wide observability view over the replicas' unified metric
        snapshots (the ``metrics`` field each stats event now carries).

        Snapshots merge with :func:`repro.obs.merge_snapshots` — counters
        add, gauges max, histograms concat — which is associative and
        commutative, so ``replica ⊕ replica == fleet`` no matter how the
        sweep ordered the replies.  Aggregate latency percentiles are then
        *exact* over the fleet's completed requests (raw-sample histograms),
        not an average of per-replica percentiles.  Router-side counters
        (requeues, deaths) ride along since no replica can see them.
        """
        from repro.obs import merge_snapshots, percentile

        if stats is None:
            stats = self.collect_stats()
        per_replica = {name: ev.get("metrics", {}) for name, ev in stats.items()}
        fleet = merge_snapshots(*per_replica.values())
        lat = fleet.get("request_latency_s", {}).get("values", [])
        ttft = fleet.get("request_ttft_s", {}).get("values", [])
        return {
            "replicas": sorted(stats),
            "fleet": fleet,
            "per_replica": per_replica,
            "requests_completed": len(lat),
            "p50_latency_s": percentile(lat, 50),
            "p99_latency_s": percentile(lat, 99),
            "p50_ttft_s": percentile(ttft, 50),
            "p99_ttft_s": percentile(ttft, 99),
            "busy_s": {
                name: ev["throughput"]["prefill_s"] + ev["throughput"]["decode_s"]
                for name, ev in stats.items()
            },
            "requeues": self.requeues,
            "deaths": self.deaths,
        }

    def kill(self, name: str) -> None:
        """Fault injection: silence a replica (the router discovers the
        death through its liveness/heartbeat machinery, not through this
        call)."""
        self.states[name].handle.kill()

    # -- event ingestion ----------------------------------------------------

    def _drain_events(self, now: float) -> int:
        n = 0
        for st in self.states.values():
            for ev in st.handle.poll():
                n += 1
                st.last_seen = now
                kind = ev["type"]
                if kind == "hello":
                    st.hello = ev
                elif kind == "heartbeat":
                    st.hb = ev
                elif kind == "stats":
                    st.stats = ev
                elif kind == "progress":
                    self._on_progress(st, ev, now)
                elif kind == "handoff":
                    self._on_handoff(st, ev, now)
                elif kind == "reject":
                    # the router pre-validates block budgets, so a reject
                    # means a config skew worth failing loudly on
                    raise RuntimeError(
                        f"replica {st.name} rejected rid {ev['rid']}: {ev['reason']}"
                    )
                else:
                    raise RuntimeError(f"unknown event {kind!r} from {st.name}")
        return n

    def _on_progress(self, st: _ReplicaState, ev: dict, now: float) -> None:
        creq = self.reqs[ev["rid"]]
        if creq.done or creq.replica != st.name:
            return  # stale report from a replica this rid was requeued off
        new = ev["tokens"][len(creq.emitted):]
        creq.emitted.extend(int(t) for t in new)
        if ev["done"]:
            self._complete(st, creq, now)

    def _on_handoff(self, st: _ReplicaState, ev: dict, now: float) -> None:
        creq = self.reqs[ev["rid"]]
        if creq.done or creq.replica != st.name:
            return
        self._uncommit(st, creq.rid)
        payload = ev["payload"]
        creq.handoff = payload
        creq.replica = None
        if not creq.emitted:
            # the prefill dispatch sampled the first token; emit it now so a
            # decode replica's later report dedups against it
            creq.emitted.append(int(payload["first_token"]))
        if len(creq.emitted) >= creq.max_new or (
            creq.eos_id is not None and creq.emitted[-1] == creq.eos_id
        ):
            self._complete(None, creq, now)  # finished at the first token
        else:
            creq.stage = "await_decode"
            self.pending_adopts.append(creq)

    def _complete(self, st: Optional[_ReplicaState], creq: ClusterRequest,
                  now: float) -> None:
        creq.done = True
        creq.stage = "done"
        creq.finished_at = now
        if st is not None:
            self._uncommit(st, creq.rid)
        creq.replica = None
        creq.handoff = None

    def _uncommit(self, st: _ReplicaState, rid: int) -> None:
        st.committed -= st.inflight.pop(rid, 0)

    # -- health -------------------------------------------------------------

    def _check_health(self, now: float) -> None:
        for st in self.states.values():
            if not st.alive:
                continue
            stale = (
                st.last_seen is not None
                and now - st.last_seen > self.heartbeat_timeout
            )
            if not st.handle.alive() or stale:
                self._mark_dead(st)

    def _mark_dead(self, st: _ReplicaState) -> None:
        st.alive = False
        self.deaths += 1
        # requeue the dead replica's in-flight work at the queue front, in
        # original submission order; the emitted-suffix dedup makes the
        # retry at-most-once for the client stream
        for rid in sorted(st.inflight, reverse=True):
            creq = self.reqs[rid]
            if creq.done:
                continue
            creq.attempts += 1
            creq.replica = None
            self.requeues += 1
            if creq.handoff is not None:
                creq.stage = "await_decode"
                self.pending_adopts.appendleft(creq)
            else:
                creq.stage = "queued"
                self.queue.appendleft(creq)
        st.inflight.clear()
        st.committed = 0
        self._sticky = {k: v for k, v in self._sticky.items() if v != st.name}

    # -- dispatch -----------------------------------------------------------

    def _blocks(self, st: _ReplicaState, creq: ClusterRequest, full: bool) -> int:
        toks = len(creq.prompt) + (creq.max_new if full else 0)
        return -(-toks // st.block_size)

    def _eligible(self, roles) -> list:
        return [
            st for st in self.states.values()
            if st.alive and st.hello is not None and st.role in roles
        ]

    def _score(self, st: _ReplicaState) -> tuple:
        if self.policy == "weighted-latency":
            ew = st.hb.get("ewma_decode_tok_s", 0.0)
            if ew > 0:
                # expected drain: committed tokens at the replica's measured
                # decode rate (cold replicas fall through to least-loaded)
                return (st.committed * st.block_size / ew, len(st.inflight), st.name)
        return (float(st.committed), len(st.inflight), st.name)

    def _pick(self, candidates: list, creq: ClusterRequest, full: bool):
        if not candidates:
            return None
        fits_anywhere = False
        with_room = []
        for st in candidates:
            need = self._blocks(st, creq, full)
            if need <= st.capacity:
                fits_anywhere = True
            if st.committed + need <= st.capacity:
                with_room.append(st)
        if not fits_anywhere:
            raise RuntimeError(
                f"rid {creq.rid} needs more blocks than any eligible replica's "
                f"whole pool — it can never be served"
            )
        if not with_room:
            return None  # backpressure: wait for commitments to drain
        if self.sticky:
            key = self._sticky_key(creq)
            name = self._sticky.get(key)
            for st in with_room:
                if st.name == name:
                    return st
        return min(with_room, key=self._score)

    def _sticky_key(self, creq: ClusterRequest):
        bs = next(st.block_size for st in self.states.values() if st.hello)
        return tuple(int(t) for t in creq.prompt[:bs])

    def _commit(self, st: _ReplicaState, creq: ClusterRequest, full: bool) -> None:
        need = self._blocks(st, creq, full)
        st.inflight[creq.rid] = need
        st.committed += need
        st.dispatched += 1
        creq.replica = st.name

    def _dispatch(self) -> None:
        # handoffs first: their prefill work is sunk cost holding router
        # memory, and adopting frees the pipeline for the next prompt
        while self.pending_adopts:
            creq = self.pending_adopts[0]
            st = self._pick(self._eligible(("both", "decode")), creq, full=True)
            if st is None:
                break
            self.pending_adopts.popleft()
            self._commit(st, creq, full=True)
            creq.stage = "decode"
            st.handle.send({
                "op": "adopt", "rid": creq.rid,
                "prompt": [int(t) for t in creq.prompt],
                "max_new": creq.max_new, "eos_id": creq.eos_id,
                "payload": creq.handoff,
            })
        while self.queue:
            creq = self.queue[0]
            prefillers = self._eligible(("prefill",))
            if prefillers:
                st = self._pick(prefillers, creq, full=False)
                if st is None:
                    break
                self.queue.popleft()
                self._commit(st, creq, full=False)
                creq.stage = "prefill"
                op = "prefill"
            else:
                st = self._pick(self._eligible(("both", "decode")), creq, full=True)
                if st is None:
                    break
                self.queue.popleft()
                self._commit(st, creq, full=True)
                creq.stage = "decode"
                op = "submit"
            if self.sticky:
                self._sticky.setdefault(self._sticky_key(creq), st.name)
            st.handle.send({
                "op": op, "rid": creq.rid,
                "prompt": [int(t) for t in creq.prompt],
                "max_new": creq.max_new, "eos_id": creq.eos_id,
            })
