"""Batched speculative verification: score all k draft tokens in one call.

The verifier is the *truth path*: one ``apply_lm`` call per round feeds
``[x0, d1, ..., dk]`` (``T = k + 1`` — the cached-call interface already
supports multi-token steps) at each row's current length, so position ``j``'s
logits are the model's next-token distribution after consuming the prefix
through ``d_j``.  Greedy accept-prefix semantics make the output
token-identical to non-speculative greedy decode:

* ``argmax(logits[:, 0])`` is exactly the token plain decode would emit after
  ``x0``; if it equals ``d1`` the draft guessed right and position 1's logits
  are the post-``d1`` distribution plain decode would compute next — by
  induction every accepted draft token *is* the plain-decode token;
* the first mismatch position emits the verifier's own argmax (the correct
  token) and everything after it is rolled back;
* full acceptance emits a free bonus token from the last position.

The verify call also *writes* K/V for every scored position (the same
write-then-gather path chunked prefill uses), so the accepted prefix's cache
entries are verify-precision regardless of what the drafter wrote — draft
writes are entirely overwritten.  Rejected positions are unwound by the
engine via ``PagedKVCache.rollback`` — a lens-only rewind that keeps the
request's admission reservation owned (``truncate``, which also frees
blocks, must NOT be used per-round: a freed block could be claimed by a
concurrent admission and the plain-decode fallback would write into trash).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.lm import apply_lm

__all__ = ["make_verify_step", "accept_prefix"]


def make_verify_step(arch, rt, params_struct=lambda p: p):
    """Build the jitted verify step ``(params, tokens (B, T), pools, bt,
    start (B,)) -> (argmax (B, T) int32, top-2 margins (B, T) fp32, pools)``.

    ``rt`` is the *verify* runtime — the engine's configured precision (the
    dequant fp32 path by default), never the drafter's accelerated one; the
    returned argmaxes define what "correct" means for acceptance.  Margins
    feed the per-token bookkeeping the int8-KV parity bound reads.  The pool
    buffers are donated (argnum 2), mirroring the engine's decode step.

    MoE caveat: expert-capacity competition is *chunk-local* (``nn/moe.py``
    sizes the drop buffer from the call's token count), so a ``T = k + 1``
    call can drop different tokens than k + 1 single-token steps — a real
    semantic difference, not float noise.  For archs with MoE stacks the
    verify therefore scans single-token steps *inside* the one dispatch:
    bitwise the same arithmetic as plain decode, same dispatch count, only
    the within-call matmul batching is lost (and only for MoE archs).
    """
    moe_arch = any(s.kind == "moe" for s in arch.stacks)

    def score(logits):
        lf = logits.astype(jnp.float32)
        top2 = jax.lax.top_k(lf, 2)[0]
        return jnp.argmax(lf, axis=-1).astype(jnp.int32), top2[..., 0] - top2[..., 1]

    def verify_fn(params, tokens, pools, bt, start):
        p = params_struct(params)
        cache_of = lambda pools: {**pools, "_paged": {"bt": bt}}
        if moe_arch:
            def step(carry, tok):
                pos, pools = carry
                logits, new_cache, _ = apply_lm(
                    p, arch, tokens=tok[:, None], cache=cache_of(pools),
                    start_pos=pos, rt=rt,
                )
                am, mg = score(logits[:, 0])
                return (pos + 1, new_cache), (am, mg)

            (_, new_cache), (am, mg) = jax.lax.scan(
                step, (start, pools), jnp.swapaxes(tokens, 0, 1)
            )
            return jnp.swapaxes(am, 0, 1), jnp.swapaxes(mg, 0, 1), new_cache
        logits, new_cache, _ = apply_lm(
            p, arch, tokens=tokens, cache=cache_of(pools), start_pos=start, rt=rt,
        )
        am, mg = score(logits)
        return am, mg, new_cache

    return jax.jit(verify_fn, donate_argnums=(2,))


def accept_prefix(draft_tokens, verify_argmax) -> tuple[int, list[int]]:
    """Greedy accept-prefix for one row: ``draft_tokens (k,)`` proposals vs
    ``verify_argmax (k + 1,)`` scored positions.  Returns ``(a, emitted)``
    where ``a`` is the number of accepted draft tokens and ``emitted`` is
    ``draft[:a] + [verify_argmax[a]]`` — the correction token on the first
    mismatch, the bonus token on full acceptance.  ``emitted`` is exactly
    the next ``a + 1`` tokens of non-speculative greedy decode."""
    a = 0
    k = len(draft_tokens)
    while a < k and int(draft_tokens[a]) == int(verify_argmax[a]):
        a += 1
    return a, [int(t) for t in draft_tokens[:a]] + [int(verify_argmax[a])]
