"""SpecServeEngine: speculative decoding on the paged-KV serving stack.

A speculative round replaces k + 1 plain decode ticks with two dispatches:

1. **draft** — the drafter proposes k greedy tokens per live row (one jitted
   ``lax.scan`` for the built-in drafters);
2. **verify** — one ``apply_lm`` call scores ``[x0, d1..dk]`` (T = k + 1)
   per row under the engine's *verify* runtime, accepts the longest matching
   draft prefix, and emits the verifier's own argmax as correction (first
   mismatch) or bonus (full acceptance).

Output is token-identical to non-speculative greedy decode on the same
engine configuration (see ``serve/spec/verify.py`` for the induction);
speculation only changes *when* cache writes happen, and the rejected tail
is unwound with ``PagedKVCache.rollback`` — the copy-on-write rollback: the
engine pre-declares the round's write span with ``ensure_writable`` (CoW on
any prefix-shared block, watermark recorded) and the rollback rewinds the
write position so the rejected tokens are as if never drafted.  The round's
writes never leave the request's admission reservation (``_slot_tokens``
includes the ``spec_k`` headroom), so block ownership is untouched
round-to-round — no allocator churn, no free-list interaction with
concurrent admissions — and ``truncate`` remains the allocator-exact
primitive for genuinely retiring capacity.

Supported archs are the *fully paged* ones (every seq-indexed leaf lives in
block pools — GQA full attention, MLA): ring and recurrent state advance
destructively and cannot roll back, so those archs either raise
(``strict=True``) or serve through the inherited plain decode path with
``spec_active() == False``.

Acceptance-rate bookkeeping rides on each request (``spec_proposed`` /
``spec_accepted``) and aggregates in ``spec_stats``; when the acceptance EMA
collapses below ``min_accept`` the engine falls back to plain ticks and
re-probes speculation every ``probe_interval`` rounds — a drafter that has
stopped guessing right costs k wasted forwards per round, so the fallback is
what keeps worst-case throughput at plain-decode levels.
"""

from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import Runtime
from repro.serve.engine import PagedServeEngine
from repro.serve.spec.drafter import ModelDrafter, SelfDrafter
from repro.serve.spec.verify import accept_prefix, make_verify_step

__all__ = ["SpecServeEngine"]


class SpecServeEngine(PagedServeEngine):
    """Paged serving engine with precision-staged speculative decoding."""

    def __init__(
        self,
        arch,
        params,
        *,
        spec_k: int = 4,
        drafter=None,
        draft_rt: Optional[Runtime] = None,
        min_accept: float = 0.1,
        probe_interval: int = 8,
        strict: bool = False,
        **kw,
    ):
        super().__init__(arch, params, **kw)
        if self.sample_cfg.method != "greedy":
            raise ValueError(
                "speculative decoding is lossless for greedy sampling only; "
                f"got sample method {self.sample_cfg.method!r}"
            )
        if spec_k < 1:
            raise ValueError("spec_k must be >= 1 (use PagedServeEngine for plain decode)")
        self.spec_k = spec_k
        self.min_accept = min_accept
        self.probe_interval = probe_interval
        # ring caches (windowed/chunked-local) and recurrent state advance
        # destructively — there is no watermark to roll them back to
        self.spec_supported = (
            self.cache.fully_paged and not self.recurrent and not self.sched.lockstep
        )
        if not self.spec_supported:
            if strict:
                raise ValueError(
                    f"{arch.name}: speculative decoding needs a fully paged, "
                    "non-recurrent, non-lockstep configuration (ring/recurrent "
                    "state cannot unwind rejected drafts); serving falls back "
                    "to plain decode unless strict"
                )
            self.drafter = None
        else:
            # precision-staged default: draft through the fused W8A8 integer
            # path (and the Pallas decode kernel if the engine uses it); the
            # verify pass keeps the engine's own (dequant fp32) runtime
            self.drafter = drafter or SelfDrafter(
                arch, draft_rt or Runtime(
                    int_forward=True, decode_kernel=self.rt.decode_kernel,
                ),
            )
            if isinstance(self.drafter, ModelDrafter) and self.drafter.arch.vocab != arch.vocab:
                raise ValueError(
                    f"draft vocab {self.drafter.arch.vocab} != target vocab {arch.vocab}"
                )
        self._verify = make_verify_step(arch, self.rt, self.params_struct)
        self._accept_ema = 1.0
        self._plain_rounds = 0
        self.spec_stats = {
            "rounds": 0, "fallback_rounds": 0, "proposed": 0, "accepted": 0,
            "emitted": 0, "bonus": 0,
        }

    # -- bookkeeping --------------------------------------------------------

    def reset_stats(self) -> None:
        """Benchmarks zero counters after their warmup pass: the spec
        round/acceptance tallies must reset with the throughput stats or
        the reported acceptance rate double-counts the warmup drive."""
        super().reset_stats()
        self.spec_stats = {k: 0 for k in self.spec_stats}

    def acceptance_rate(self) -> float:
        """Accepted draft tokens / proposed draft tokens, engine lifetime."""
        return self.spec_stats["accepted"] / max(self.spec_stats["proposed"], 1)

    def _sync_metrics(self) -> None:
        super()._sync_metrics()
        m = self.obs.metrics
        for k, v in self.spec_stats.items():
            m.counter(f"spec_{k}").set(v)
        m.gauge("spec_acceptance_rate").set(self.acceptance_rate())

    def spec_active(self) -> bool:
        return self.spec_supported and self._accept_ema >= self.min_accept

    def _slot_tokens(self, req) -> int:
        # speculative rounds write up to spec_k positions past the emitted
        # stream before rollback; reserve that headroom at admission
        return super()._slot_tokens(req) + (self.spec_k if self.spec_supported else 0)

    def _release_slot(self, slot: int) -> None:
        if self.drafter is not None:
            self.drafter.release(slot)
        super()._release_slot(slot)

    def _on_admitted(self, slot: int, req) -> None:
        if self.drafter is not None and self.sched.slots[slot] is req:
            self.drafter.admit(slot, req.prompt, req.max_new)

    # -- the speculative round ---------------------------------------------

    def _advance(self) -> int:
        if not self.sched.live:
            return 0
        if self.spec_active():
            return self.spec_round()
        if self.spec_supported:
            # acceptance collapsed: plain ticks, re-probing periodically (the
            # probe round's own rate replaces the stale EMA, so a drafter
            # that recovers — e.g. past an unpredictable span — resumes)
            self._plain_rounds += 1
            if self._plain_rounds >= self.probe_interval:
                self._plain_rounds = 0
                return self.spec_round(probe=True)
        self.spec_stats["fallback_rounds"] += 1
        # fall back through the parent's round, not raw tick(): with
        # decode_steps > 1 that is the fused megastep, so even a drafter
        # whose acceptance collapsed keeps the dispatch-per-token win
        return super()._advance()

    def spec_round(self, probe: bool = False) -> int:
        """Draft k, verify in one batched call, accept-prefix, roll back."""
        live = self.sched.live
        if not live:
            return 0
        k = self.spec_k
        tr = self.obs.trace
        t0 = time.perf_counter()
        with tr.span("spec_round", {"live": len(live), "k": k, "probe": probe}):
            lens0 = self.cache.lens.copy()
            with tr.span("cow_preflight", {"live": len(live)}):
                for i in live:
                    # the round writes [lens, lens + k + 1): draft inputs then
                    # the verify span; declare it once so shared blocks CoW up
                    # front and the watermark records how far garbage may
                    # extend on rejection
                    self.cache.allocate(i, int(lens0[i]) + k + 1)
                    self.cache.ensure_writable(i, int(lens0[i]), int(lens0[i]) + k + 1)
            tok_in = np.zeros((self.batch,), np.int32)
            for i in live:
                tok_in[i] = self.sched.slots[i].last_token
            with tr.span("spec_draft", {"live": len(live), "k": k}):
                proposals = self.drafter.propose(self, live, tok_in, k)  # (B, k)
            tokens = np.concatenate([tok_in[:, None], proposals], axis=1)
            with tr.span("spec_verify", {"live": len(live)}):
                am_d, mg_d, pools = self._verify(
                    self.params, jnp.asarray(tokens), self.cache.pools, self.cache.bt(),
                    jnp.asarray(lens0),
                )
                self.cache.pools = pools
                am, mg = (np.asarray(a) for a in jax.device_get((am_d, mg_d)))
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["decode_dispatches"] += 2  # draft scan + batched verify

        emitted_total = 0
        round_accepted = 0
        for i in live:
            req = self.sched.slots[i]
            a, emitted = accept_prefix(proposals[i], am[i])
            req.spec_proposed += k
            req.spec_accepted += a
            self.spec_stats["proposed"] += k
            self.spec_stats["accepted"] += a
            if a == k:
                self.spec_stats["bonus"] += 1
            round_accepted += a
            done = False
            for j, t in enumerate(emitted):
                req.margins.append(float(mg[i, j]))
                emitted_total += 1
                if self.sched.record_token(i, int(t)):
                    done = True
                    break
            if done:
                self._release_slot(i)
            else:
                # rollback: keep the consumed prefix [x0, d1..da], rewind
                # the write position past the rejected tail.  Lens-only —
                # the admission reservation (which includes the spec_k
                # headroom) stays owned for the request's lifetime, so the
                # plain-tick fallback and later rounds always have their
                # blocks and the allocator sees no per-round churn
                new_len = int(lens0[i]) + 1 + a
                self.cache.rollback(i, new_len)
                if self.drafter is not None:
                    pending = [int(proposals[i, -1])] if a == k else []
                    self.drafter.sync(i, new_len, pending)
        self.stats["decode_tokens"] += emitted_total
        self.spec_stats["rounds"] += 1
        self.spec_stats["emitted"] += emitted_total
        rate = round_accepted / max(k * len(live), 1)
        if probe:
            self._accept_ema = rate
        else:
            self._accept_ema = 0.8 * self._accept_ema + 0.2 * rate
        return len(live)
