"""Drafters: propose k tokens per sequence per speculative round.

Two built-ins, one contract (the engine is agnostic to how drafts are made —
losslessness comes from the verifier, so a drafter only affects *speed* via
its acceptance rate and its own cost):

* :class:`SelfDrafter` — **precision-staged self-drafting**: the same
  weights run under a cheaper runtime (``int_forward=True`` fused W8A8
  matmuls; with ``--kv-int8`` the int8 code pools are the draft's KV read
  view, optionally through the in-register-dequant Pallas decode kernel)
  against the *engine's own* paged cache.  Draft writes land in the shared
  pools at positions the verify pass overwrites wholesale, so the drafter
  needs no cache bookkeeping at all.  All k draft steps run inside ONE
  jitted ``lax.scan`` — one dispatch per round instead of k, which is where
  the wall-clock win comes from even before the precision gap.

* :class:`ModelDrafter` — a small draft model (e.g. a reduced ``smollm``
  drafting for ``yi``) with its own params and its own paged cache.  The
  draft cache tracks the accepted token stream: after each round the engine
  calls :meth:`sync` with the accepted length (truncating rejected draft
  state — the same rollback primitive the main cache uses) and any accepted
  tokens the drafter has not consumed yet (the full-acceptance bonus case);
  the next round's first step feeds that pending delta before proposing.
  Vocabularies must match; the draft arch must be fully paged.

Both drafters draft greedily — proposals are argmaxes, never samples — so a
given (weights, cache) state drafts deterministically.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import Runtime, apply_lm

__all__ = ["SelfDrafter", "ModelDrafter"]


class SelfDrafter:
    """Draft with the engine's own params/cache under a draft runtime."""

    name = "self"

    def __init__(self, arch, rt: Runtime):
        self.arch = arch
        self.rt = rt
        self._scan = {}  # k -> jitted k-step draft scan

    # -- lifecycle hooks (no private state to manage) -----------------------

    def admit(self, slot: int, prompt, max_new: int) -> None:
        pass

    def release(self, slot: int) -> None:
        pass

    def sync(self, slot: int, accepted_len: int, pending) -> None:
        pass

    # -- drafting -----------------------------------------------------------

    def _draft_fn(self, k: int):
        def fn(params, tok0, pools, bt, lens):
            def step(carry, _):
                tok, pos, pools = carry
                cache = {**pools, "_paged": {"bt": bt}}
                logits, new_cache, _ = apply_lm(
                    params, self.arch, tokens=tok[:, None], cache=cache,
                    start_pos=pos, rt=self.rt,
                )
                nxt = jnp.argmax(logits[:, 0].astype(jnp.float32), axis=-1)
                return (nxt.astype(jnp.int32), pos + 1, new_cache), nxt.astype(jnp.int32)

            (_, _, pools), toks = jax.lax.scan(step, (tok0, lens, pools), None, length=k)
            return jnp.swapaxes(toks, 0, 1), pools  # (B, k)

        return fn

    def propose(self, engine, live, tok_in: np.ndarray, k: int) -> np.ndarray:
        """k greedy draft tokens per row, one jit dispatch.  Writes draft-
        precision K/V into the engine's pools at [lens, lens + k) — the
        verify pass overwrites every one of them."""
        fn = self._scan.get(k)
        if fn is None:
            fn = self._scan[k] = jax.jit(self._draft_fn(k), donate_argnums=(2,))
        cache = engine.cache
        toks, pools = fn(
            engine.params, jnp.asarray(tok_in), cache.pools, cache.bt(),
            jnp.asarray(cache.lens.copy()),
        )
        cache.pools = pools
        return np.asarray(jax.device_get(toks))


class ModelDrafter:
    """Separate small-model drafter with its own params and paged cache."""

    name = "model"

    def __init__(
        self,
        arch,
        params,
        *,
        slots: int,
        max_seq: int,
        spec_k: int,
        block_size: int = 16,
        prefill_chunk: int = 32,
        rt: Optional[Runtime] = None,
        dtype=None,
    ):
        from repro.serve.paged_cache import PagedKVCache

        self.arch = arch
        self.params = params
        self.rt = rt or Runtime()
        self.spec_k = spec_k
        self.prefill_chunk = prefill_chunk
        if dtype is None:
            dtype = jnp.dtype(arch.compute_dtype)
        self.cache = PagedKVCache(
            arch, slots, block_size=block_size, max_seq=max_seq, dtype=dtype,
        )
        if not self.cache.fully_paged:
            raise ValueError(
                "ModelDrafter needs a fully paged draft arch (no ring/recurrent "
                f"state to roll back), got {arch.name}"
            )
        self.pending: list[list[int]] = [[] for _ in range(slots)]
        self._prefill = jax.jit(self._prefill_fn, donate_argnums=(2,))
        self._sync_draft = {}  # (delta_max, k) -> jitted sync + draft scan

    # -- jitted pieces ------------------------------------------------------

    def _prefill_fn(self, params, tokens, pools, bt, start):
        cache = {**pools, "_paged": {"bt": bt}}
        _, new_cache, _ = apply_lm(
            params, self.arch, tokens=tokens, cache=cache, start_pos=start,
            rt=self.rt,
        )
        return new_cache

    def _sync_draft_fn(self, delta_max: int, k: int):
        """One dispatch per round: consume each row's pending delta (padded to
        ``delta_max`` by repeating its last token — pad writes land beyond the
        row's tracked length, masked until overwritten), read the first
        proposal from each row's true last position, then scan k - 1 more
        greedy steps."""

        def fn(params, toks, idx, pools, bt, pos0):
            cache = {**pools, "_paged": {"bt": bt}}
            logits, new_cache, _ = apply_lm(
                params, self.arch, tokens=toks, cache=cache, start_pos=pos0,
                rt=self.rt,
            )
            lf = logits.astype(jnp.float32)  # (B, delta_max, V)
            sel = jnp.take_along_axis(
                lf, idx[:, None, None].astype(jnp.int32), axis=1
            )[:, 0]
            d1 = jnp.argmax(sel, axis=-1).astype(jnp.int32)
            pos = pos0 + idx + 1  # per-row next write position

            def step(carry, _):
                tok, pos, pools = carry
                cache = {**pools, "_paged": {"bt": bt}}
                logits, new_cache, _ = apply_lm(
                    params, self.arch, tokens=tok[:, None], cache=cache,
                    start_pos=pos, rt=self.rt,
                )
                nxt = jnp.argmax(logits[:, 0].astype(jnp.float32), axis=-1).astype(jnp.int32)
                return (nxt, pos + 1, new_cache), nxt

            (_, _, pools2), rest = jax.lax.scan(step, (d1, pos, new_cache), None, length=k - 1)
            proposals = jnp.concatenate([d1[:, None], jnp.swapaxes(rest, 0, 1)], axis=1)
            return proposals, pools2

        return fn

    # -- lifecycle ----------------------------------------------------------

    def admit(self, slot: int, prompt, max_new: int) -> None:
        """Prefill the prompt into the drafter's own cache (isolated B=1
        view, chunked like the engine's prefill)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        self.cache.reset_slot(slot)
        self.cache.allocate(slot, len(prompt) + max_new + self.spec_k)
        for lo in range(0, len(prompt), self.prefill_chunk):
            hi = min(lo + self.prefill_chunk, len(prompt))
            sub = self.cache.slice_slot(slot)
            new_pools = self._prefill(
                self.params, jnp.asarray(prompt[None, lo:hi]), sub,
                self.cache.bt_row(slot), jnp.int32(lo),
            )
            self.cache.merge_slot(slot, new_pools)
        self.cache.lens[slot] = len(prompt)
        self.pending[slot] = []

    def release(self, slot: int) -> None:
        self.cache.release(slot)
        self.pending[slot] = []

    def sync(self, slot: int, accepted_len: int, pending) -> None:
        """Roll the draft cache back to the accepted stream: rejected draft
        state rewinds away (lens-only — the drafter's admit-time block
        reservation must survive the request, like the main cache's);
        accepted tokens the drafter has not consumed yet queue as the next
        round's delta."""
        self.cache.rollback(slot, min(int(self.cache.lens[slot]), accepted_len))
        self.pending[slot] = [int(t) for t in pending]

    # -- drafting -----------------------------------------------------------

    def propose(self, engine, live, tok_in: np.ndarray, k: int) -> np.ndarray:
        B = self.cache.slots
        deltas = [[] for _ in range(B)]
        for i in live:
            deltas[i] = self.pending[i] + [int(tok_in[i])]
        delta_max = max((len(deltas[i]) for i in live), default=1)
        toks = np.zeros((B, delta_max), np.int32)
        idx = np.zeros((B,), np.int32)
        for i in range(B):
            d = deltas[i] or [0]
            toks[i, : len(d)] = d
            toks[i, len(d) :] = d[-1]  # pad by repetition; masked + overwritten
            idx[i] = len(d) - 1
        key = (delta_max, k)
        fn = self._sync_draft.get(key)
        if fn is None:
            fn = self._sync_draft[key] = jax.jit(
                self._sync_draft_fn(delta_max, k), donate_argnums=(3,)
            )
        proposals, pools = fn(
            self.params, jnp.asarray(toks), jnp.asarray(idx), self.cache.pools,
            self.cache.bt(), jnp.asarray(self.cache.lens.copy()),
        )
        self.cache.pools = pools
        for i in live:
            self.cache.lens[i] += len(deltas[i]) + (k - 1)
            self.pending[i] = []
        return np.asarray(jax.device_get(proposals))
