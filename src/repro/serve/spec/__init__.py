"""Speculative decoding subsystem on the paged-KV serving stack.

``drafter.py`` proposes, ``verify.py`` scores and accepts, ``engine.py``
orchestrates rounds and the copy-on-write rollback.  See
``serve/README.md`` ("Speculative decoding") for the losslessness argument
and the block lifecycle.
"""

from repro.serve.spec.drafter import ModelDrafter, SelfDrafter  # noqa: F401
from repro.serve.spec.engine import SpecServeEngine  # noqa: F401
from repro.serve.spec.verify import accept_prefix, make_verify_step  # noqa: F401
