"""Batched decode engine: continuous batching over KV caches.

The engine owns a fixed slot layout of ``batch`` concurrent sequences, a
jitted prefill and a jitted decode step.  Requests are admitted into free
slots (their prompt prefilled into the cache at slot granularity), every
engine tick advances all live slots one token, and finished sequences release
their slot.  Deployment option ``deploy=True`` swaps trained A2Q params for
int8 weights + per-channel scales — the artifact whose l1 norms provably fit
the target accumulator (the serving payoff of the paper's guarantee; also the
memory-roofline lever recorded in EXPERIMENTS.md SPerf).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, QuantConfig
from repro.models.lm import Runtime, apply_lm, init_cache
from repro.nn.linear import deploy_linear

__all__ = ["ServeEngine", "deploy_params"]


def deploy_params(params: dict, q: QuantConfig) -> dict:
    """Convert every quantized linear's (v,t,d)/(w,wq) into {q8, s8}.

    Halves weight bytes (int8 vs bf16/fp32) on the serve path; sound because
    A2Q guarantees the P-bit accumulator for the resulting integer weights.
    """

    def one(node, signed):
        # leading dims (scan layers, experts) are vmapped onto the 2D core
        lead = node["v" if "v" in node else "w"].ndim - 2
        fn = lambda sub: deploy_linear(sub, q, input_signed=signed)
        for _ in range(lead):
            fn = jax.vmap(fn)
        keys = ("v", "t", "d") if "v" in node else ("w", "wq")
        sub = {k: node[k] for k in keys if k in node}
        out = fn(sub)
        for passthrough in ("aq", "b"):
            if passthrough in node:
                out[passthrough] = node[passthrough]
        return out

    def walk(node, path=()):
        if isinstance(node, dict):
            keys = set(node.keys())
            if ("v" in keys and "t" in keys and "d" in keys) or ("w" in keys and "wq" in keys):
                signed = not (len(path) >= 2 and path[-2] == "cm" and path[-1] == "wv")
                return one(node, signed)
            return {k: walk(v, path + (k,)) for k, v in node.items()}
        return node

    return walk(params)


def deploy_boxed(boxed_tree, q: QuantConfig):
    """Shape-level twin of :func:`deploy_params` for the dry-run: transforms a
    *boxed ShapeDtypeStruct* tree so the serve graph can be lowered against
    int8 weight storage without materializing anything.  q8 inherits the
    weight's logical axes, s8 the per-channel axes."""
    import jax

    from repro.nn.module import Boxed

    def walk(node):
        if isinstance(node, dict):
            keys = set(node.keys())
            if "v" in keys and "t" in keys and "d" in keys:
                v, t = node["v"], node["t"]
                out = {
                    "q8": Boxed(jax.ShapeDtypeStruct(v.value.shape, jnp.int8), v.axes),
                    "s8": Boxed(jax.ShapeDtypeStruct(t.value.shape, jnp.float32), t.axes),
                }
                for passthrough in ("aq", "b"):
                    if passthrough in node:
                        out[passthrough] = node[passthrough]
                return out
            if "w" in keys and "wq" in keys:
                w = node["w"]
                out = {
                    "q8": Boxed(jax.ShapeDtypeStruct(w.value.shape, jnp.int8), w.axes),
                    "s8": Boxed(
                        jax.ShapeDtypeStruct(w.value.shape[-1:], jnp.float32),
                        (w.axes[-1],),
                    ),
                }
                for passthrough in ("aq", "b"):
                    if passthrough in node:
                        out[passthrough] = node[passthrough]
                return out
            return {k: walk(v) for k, v in node.items()}
        return node

    return walk(boxed_tree)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (T,) int32
    max_new: int = 16
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(
        self,
        arch: ArchConfig,
        params: dict,
        *,
        batch: int = 4,
        max_seq: int = 512,
        rt: Optional[Runtime] = None,
        greedy: bool = True,
    ):
        self.arch = arch
        self.params = params
        self.batch = batch
        self.max_seq = max_seq
        self.rt = rt or Runtime()
        self.greedy = greedy
        self.cache = init_cache(arch, batch, max_seq, dtype=jnp.dtype(arch.compute_dtype))
        self.pos = np.zeros((batch,), np.int32)  # per-slot next position
        self.slots: list[Optional[Request]] = [None] * batch
        # Recurrent mixers (rwkv6/hymba) advance a non-positional state for
        # every row on every call, so slot-at-a-time prefill would pollute
        # other live rows irreversibly.  Those archs run in synchronized-batch
        # mode: equal-length prompt groups prefilled in lockstep.
        self.recurrent = any(s.kind in ("rwkv6", "hymba") for s in arch.stacks)
        self._decode = jax.jit(self._decode_fn)

    # Prefill is implemented as sequential cached steps over the prompt so the
    # slot-granular cache stays consistent under continuous batching (a
    # batch-wide one-shot prefill would clobber other live slots).  The
    # one-shot prefill path exists for benchmarking (models/steps.py).
    def _decode_fn(self, params, tokens, cache, pos):
        logits, new_cache, _ = apply_lm(
            self.params_struct(params), self.arch, tokens=tokens, cache=cache,
            start_pos=pos, rt=self.rt,
        )
        return logits, new_cache

    def params_struct(self, params):
        return params

    def admit(self, req: Request) -> bool:
        for i, s in enumerate(self.slots):
            if s is None:
                self.slots[i] = req
                self._prefill_slot(i, req)
                return True
        return False

    def _prefill_slot(self, slot: int, req: Request):
        # Feed prompt tokens one at a time into this slot's cache lane.  Other
        # rows receive transient garbage at their *current* position, which
        # their own next real token overwrites before it is ever attended.
        self.pos[slot] = 0
        for t in req.prompt:
            tok = np.zeros((self.batch, 1), np.int32)
            tok[slot, 0] = t
            logits, self.cache = self._decode(
                self.params, jnp.asarray(tok), self.cache, jnp.asarray(self.pos.copy())
            )
            self.pos[slot] += 1
        req._last_logits = np.asarray(jax.device_get(logits[slot, 0]))

    def tick(self) -> int:
        """Advance every live slot one token; returns number of live slots.

        Slots advance at *their own* positions (per-row cache writes), so
        sequences admitted at different times interleave correctly.
        """
        live = [i for i, s in enumerate(self.slots) if s is not None]
        if not live:
            return 0
        tok = np.zeros((self.batch, 1), np.int32)
        for i in live:
            req = self.slots[i]
            last = getattr(req, "_last_logits")
            nxt = int(np.argmax(last))
            req.generated.append(nxt)
            tok[i, 0] = nxt
        logits, self.cache = self._decode(self.params, jnp.asarray(tok), self.cache, jnp.asarray(self.pos.copy()))
        ln = np.asarray(jax.device_get(logits[:, 0]))
        for i in live:
            req = self.slots[i]
            req._last_logits = ln[i]
            self.pos[i] += 1
            if len(req.generated) >= req.max_new:
                req.done = True
                self.slots[i] = None
        return len(live)

    def generate(self, prompts: list[np.ndarray], max_new: int = 16) -> list[list[int]]:
        """Convenience batch API: admit all, tick until drained."""
        reqs = [Request(uid=i, prompt=p, max_new=max_new) for i, p in enumerate(prompts)]
        if self.recurrent:
            return self._generate_lockstep(reqs)
        pending = list(reqs)
        while pending or any(s is not None for s in self.slots):
            while pending and self.admit(pending[0]):
                pending.pop(0)
            if self.tick() == 0 and not pending:
                break
        return [r.generated for r in reqs]

    def _generate_lockstep(self, reqs: list) -> list[list[int]]:
        assert len(reqs) <= self.batch, "lockstep mode serves one group at a time"
        lens = {len(r.prompt) for r in reqs}
        assert len(lens) == 1, "recurrent archs require equal-length prompt groups"
        T = lens.pop()
        self.pos[:] = 0
        for i, r in enumerate(reqs):
            self.slots[i] = r
        for t in range(T):
            tok = np.zeros((self.batch, 1), np.int32)
            for i, r in enumerate(reqs):
                tok[i, 0] = r.prompt[t]
            logits, self.cache = self._decode(
                self.params, jnp.asarray(tok), self.cache, jnp.asarray(self.pos.copy())
            )
            self.pos[: len(reqs)] += 1
        ln = np.asarray(jax.device_get(logits[:, 0]))
        for i, r in enumerate(reqs):
            r._last_logits = ln[i]
        while any(s is not None for s in self.slots):
            self.tick()
        return [r.generated for r in reqs]
