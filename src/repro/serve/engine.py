"""Serving engines: continuous batching over contiguous or paged KV caches.

Two engines share the scheduler/request machinery (``serve/scheduler.py``):

* :class:`ServeEngine` — the seed engine, kept as the measured baseline: one
  contiguous ``max_seq`` cache lane per slot, prompts prefilled one token per
  jit call, logits round-tripped to the host for argmax every tick.
* :class:`PagedServeEngine` — the serving subsystem: block-table paged KV
  memory (``serve/paged_cache.py``), chunked one-shot prefill (whole prompt
  chunks per jit call on an isolated B=1 cache view), on-device sampling
  (``serve/sampling.py``; the host only ever fetches token ids), and an
  optional Pallas paged-attention decode kernel (``Runtime(decode_kernel=
  True)``).  Continuous batching works for recurrent stacks too — per-slot
  prefill never touches other rows' states — with the scheduler's lockstep
  mode kept as the conservative equal-length-group fallback.

Deployment option ``deploy_params`` swaps trained A2Q params for int8 weights
+ per-channel scales — the artifact whose l1 norms provably fit the target
accumulator (the serving payoff of the paper's guarantee; also the
memory-roofline lever recorded in EXPERIMENTS.md SPerf).

Both engines keep ``stats`` = {prefill_tokens, decode_tokens, prefill_s,
decode_s, decode_dispatches} so launchers and benchmarks report prefill and
decode throughput separately instead of one aggregate tok/s, plus the
dispatch-count scoreboard ``dispatches_per_token`` (how many jitted decode
launches each generated token paid for — 1.0 for per-tick engines, ~1/N for
the paged megastep at ``decode_steps=N``).

Accounting convention (shared by both engines): the first generated token is
produced by the *prefill* dispatch's logits and is booked under prefill time
with zero decode tokens; ``decode_tokens`` counts only tokens whose forward
ran in a decode dispatch (``max_new - 1`` per request, absent early EOS).
The seed contiguous engine booked that first token under decode instead —
64 vs 56 decode tokens for the identical 8x8 workload — skewing every
cross-engine ``decode_tok_s`` comparison ~14%.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, QuantConfig
from repro.models.lm import Runtime, apply_lm, init_cache
from repro.nn.linear import deploy_linear
from repro.obs import Obs
from repro.serve.paged_cache import PagedKVCache
from repro.serve.sampling import SampleConfig, sample_tokens
from repro.serve.scheduler import Scheduler, ServeRequest

__all__ = [
    "ServeEngine", "PagedServeEngine", "Request", "deploy_params", "deploy_boxed",
    "parity_up_to_ties",
]


def deploy_params(params: dict, q: QuantConfig) -> dict:
    """Convert every quantized linear's (v,t,d)/(w,wq) into {q8, s8}.

    Halves weight bytes (int8 vs bf16/fp32) on the serve path; sound because
    A2Q guarantees the P-bit accumulator for the resulting integer weights.
    """

    def one(node, signed):
        # leading dims (scan layers, experts) are vmapped onto the 2D core
        lead = node["v" if "v" in node else "w"].ndim - 2
        fn = lambda sub: deploy_linear(sub, q, input_signed=signed)
        for _ in range(lead):
            fn = jax.vmap(fn)
        keys = ("v", "t", "d") if "v" in node else ("w", "wq")
        sub = {k: node[k] for k in keys if k in node}
        out = fn(sub)
        for passthrough in ("aq", "b"):
            if passthrough in node:
                out[passthrough] = node[passthrough]
        return out

    def walk(node, path=()):
        if isinstance(node, dict):
            keys = set(node.keys())
            if ("v" in keys and "t" in keys and "d" in keys) or ("w" in keys and "wq" in keys):
                signed = not (len(path) >= 2 and path[-2] == "cm" and path[-1] == "wv")
                return one(node, signed)
            return {k: walk(v, path + (k,)) for k, v in node.items()}
        return node

    return walk(params)


def deploy_boxed(boxed_tree, q: QuantConfig):
    """Shape-level twin of :func:`deploy_params` for the dry-run: transforms a
    *boxed ShapeDtypeStruct* tree so the serve graph can be lowered against
    int8 weight storage without materializing anything.  q8 inherits the
    weight's logical axes, s8 the per-channel axes."""
    import jax

    from repro.nn.module import Boxed

    def walk(node):
        if isinstance(node, dict):
            keys = set(node.keys())
            if "v" in keys and "t" in keys and "d" in keys:
                v, t = node["v"], node["t"]
                out = {
                    "q8": Boxed(jax.ShapeDtypeStruct(v.value.shape, jnp.int8), v.axes),
                    "s8": Boxed(jax.ShapeDtypeStruct(t.value.shape, jnp.float32), t.axes),
                }
                for passthrough in ("aq", "b"):
                    if passthrough in node:
                        out[passthrough] = node[passthrough]
                return out
            if "w" in keys and "wq" in keys:
                w = node["w"]
                out = {
                    "q8": Boxed(jax.ShapeDtypeStruct(w.value.shape, jnp.int8), w.axes),
                    "s8": Boxed(
                        jax.ShapeDtypeStruct(w.value.shape[-1:], jnp.float32),
                        (w.axes[-1],),
                    ),
                }
                for passthrough in ("aq", "b"):
                    if passthrough in node:
                        out[passthrough] = node[passthrough]
                return out
            return {k: walk(v) for k, v in node.items()}
        return node

    return walk(boxed_tree)



def parity_up_to_ties(ref_reqs, outs_test, eps: float):
    """Token-parity bound for lossy (int8-KV) serving: compare each request's
    generated prefix against the float reference and fail on any mismatch at
    a step where the reference's greedy top-2 logit margin exceeds ``eps``.
    A mismatch *below* the margin is a quantization-noise tie — the int8 path
    was within its error budget of the float decision — and the prefixes
    legitimately diverge from there, so comparison for that request stops.
    With ``eps == 0`` this is exact token parity.

    ``ref_reqs`` are the reference engine's driven :class:`ServeRequest`
    objects (``engine.last_requests``) — tokens and margins index-aligned.
    Returns ``(ok, n_ties, detail)``.  Documented in serve/README.md
    ("parity bound"); gated by launch/serve --parity-check --kv-int8,
    benchmarks/serve_bench.py, and tests/test_paged.py.
    """
    ties = 0
    for r, req in enumerate(ref_reqs):
        for t, (x, y) in enumerate(zip(req.generated, outs_test[r])):
            if x != y:
                if req.margins[t] > eps:
                    return False, ties, (
                        f"req {r} step {t}: {x} != {y} with reference margin "
                        f"{req.margins[t]:.4f} > eps {eps}"
                    )
                ties += 1
                break
    return True, ties, None


# Back-compat alias: the seed engine's request type is the scheduler's.
Request = ServeRequest


def _normalize_prompt(prompt, bos_id: int) -> np.ndarray:
    """Empty prompts synthesize a BOS token: the model needs at least one
    position of context before it can emit logits (the seed engine raised a
    ``NameError`` here — ``logits`` unbound when the prefill loop never ran)."""
    arr = np.asarray(prompt, np.int32).reshape(-1)
    if arr.size == 0:
        arr = np.asarray([bos_id], np.int32)
    return arr


def _fresh_stats() -> dict:
    return {
        "prefill_tokens": 0, "decode_tokens": 0, "prefill_s": 0.0, "decode_s": 0.0,
        "decode_dispatches": 0,
    }


class _StatsMixin:
    def reset_stats(self) -> None:
        """Zero the throughput counters (benchmarks call this after a warmup
        pass so compile time stays out of steady-state numbers).  This is the
        *one* reset path: engine stats, collected spans, live metrics, and —
        via the paged subclass — cache counters all clear together, so a
        benchmark phase can never leak counters into the next one."""
        self.stats = _fresh_stats()
        self.obs.reset()

    def throughput(self) -> dict:
        """Derived tok/s split — the one place the stats contract turns into
        reportable numbers (launcher and benchmark both consume this)."""
        st = self.stats
        total_s = st["prefill_s"] + st["decode_s"]
        total_tok = st["prefill_tokens"] + st["decode_tokens"]
        out = {
            **st,
            "prefill_tok_s": st["prefill_tokens"] / st["prefill_s"] if st["prefill_s"] > 0 else 0.0,
            "decode_tok_s": st["decode_tokens"] / st["decode_s"] if st["decode_s"] > 0 else 0.0,
            "tok_s": total_tok / total_s if total_s > 0 else 0.0,
            "dispatches_per_token": (
                st["decode_dispatches"] / st["decode_tokens"] if st["decode_tokens"] > 0 else 0.0
            ),
        }
        rt = getattr(self, "rt", None)
        if rt is not None and getattr(rt, "int_forward", False):
            # Trace-time chain report from the last compiled forward: counts of
            # apply_linear call sites by disposition.  Under --int-chain the
            # stats contract requires zero standalone act-quant dispatches.
            rep = getattr(rt, "chain_report", {}) or {}
            out["int_chain_requant_dispatches"] = len(rep.get("standalone", ()))
            out["int_chain_folded"] = len(rep.get("folded", ()))
            out["int_chain_chained"] = len(rep.get("chained", ()))
            out["int_chain_fallback"] = len(rep.get("fallback", ()))
        return out

    # -- unified metrics contract -------------------------------------------

    def _jit_sites(self) -> dict:
        """Named jitted entry points whose compile counts the registry tracks
        (the PR 6 TTFT cliff was an unobserved per-shape recompile — the
        ``jit_cache_size{fn=...}`` gauges make that class of bug a metric)."""
        return {}

    def _sync_metrics(self) -> None:
        """Fold the engine's scattered runtime state — stats dict, derived
        throughput, chain report, jit compile counts — into the registry.
        Called lazily at snapshot time: nothing on the dispatch hot path ever
        touches a metric object (per-request histograms are recorded at
        completion, everything else is state the engine already keeps)."""
        m = self.obs.metrics
        tp = self.throughput()
        for k in ("prefill_tokens", "decode_tokens", "decode_dispatches",
                  "prefill_s", "decode_s"):
            m.counter(f"serve_{k}").set(tp[k])
        for k in ("prefill_tok_s", "decode_tok_s", "tok_s", "dispatches_per_token"):
            m.gauge(f"serve_{k}").set(tp[k])
        for k in ("int_chain_requant_dispatches", "int_chain_folded",
                  "int_chain_chained", "int_chain_fallback"):
            if k in tp:
                m.gauge(k).set(tp[k])
        for name, fn in self._jit_sites().items():
            try:
                m.gauge("jit_cache_size", {"fn": name}).set(fn._cache_size())
            except Exception:
                pass  # private jax API: degrade to "no compile-count gauge"

    def metrics_snapshot(self) -> dict:
        """The one ``snapshot()`` contract: sync engine state into the
        registry, return the JSON-able view.  Consumed by ``--metrics-json``,
        serve_bench, run.py, and the cluster stats event."""
        self._sync_metrics()
        return self.obs.metrics.snapshot()


class ServeEngine(_StatsMixin):
    """Contiguous-cache baseline: per-token prefill + host-side argmax."""

    def __init__(
        self,
        arch: ArchConfig,
        params: dict,
        *,
        batch: int = 4,
        max_seq: int = 512,
        rt: Optional[Runtime] = None,
        greedy: bool = True,
        bos_id: int = 0,
        eos_id: Optional[int] = None,
        obs: Optional[Obs] = None,
    ):
        self.arch = arch
        self.params = params
        self.batch = batch
        self.max_seq = max_seq
        self.rt = rt or Runtime()
        self.obs = obs or Obs()
        self.greedy = greedy
        self.bos_id = bos_id
        self.eos_id = eos_id  # default for requests that don't set their own
        self.cache = init_cache(arch, batch, max_seq, dtype=jnp.dtype(arch.compute_dtype))
        self.pos = np.zeros((batch,), np.int32)  # per-slot next position
        self.slots: list[Optional[Request]] = [None] * batch
        # Recurrent mixers (rwkv6/hymba) advance a non-positional state for
        # every row on every call, so slot-at-a-time prefill would pollute
        # other live rows irreversibly.  Those archs run in synchronized-batch
        # mode: equal-length prompt groups prefilled in lockstep.  (The paged
        # engine lifts this: its prefill runs on an isolated B=1 cache view.)
        self.recurrent = any(s.kind in ("rwkv6", "hymba") for s in arch.stacks)
        self.stats = _fresh_stats()
        self._decode = jax.jit(self._decode_fn)

    def _jit_sites(self) -> dict:
        return {"decode": self._decode}

    # Prefill is implemented as sequential cached steps over the prompt so the
    # slot-granular cache stays consistent under continuous batching (a
    # batch-wide one-shot prefill would clobber other live slots).  The paged
    # engine's chunked prefill replaces this with whole-chunk jit calls.
    def _decode_fn(self, params, tokens, cache, pos):
        logits, new_cache, _ = apply_lm(
            self.params_struct(params), self.arch, tokens=tokens, cache=cache,
            start_pos=pos, rt=self.rt,
        )
        return logits, new_cache

    def params_struct(self, params):
        return params

    def admit(self, req: Request) -> bool:
        req.prompt = _normalize_prompt(req.prompt, self.bos_id)
        if req.eos_id is None:
            req.eos_id = self.eos_id
        self.obs.trace.instant("submit", {"uid": req.uid, "prompt": len(req.prompt)})
        for i, s in enumerate(self.slots):
            if s is None:
                self.slots[i] = req
                with self.obs.trace.span("admit", {"uid": req.uid, "slot": i}):
                    self._prefill_slot(i, req)
                return True
        return False

    def _emit_token(self, slot: int, req: Request, logits_row: np.ndarray) -> bool:
        """Host-side argmax + bookkeeping for one fresh token; returns True
        (and frees the slot) when the request just completed — ``max_new``
        reached or the token *is* the request's ``eos_id`` (the seed engine
        never checked EOS and decoded garbage to the length cap)."""
        nxt = int(np.argmax(logits_row))
        top2 = np.partition(logits_row.astype(np.float32), -2)[-2:]
        req.margins.append(float(top2[1] - top2[0]))
        if not req.generated:
            req.first_token_at = time.perf_counter()
        req.generated.append(nxt)
        req.last_token = nxt
        if len(req.generated) >= req.max_new or (req.eos_id is not None and nxt == req.eos_id):
            req.done = True
            req.finished_at = time.perf_counter()
            self.slots[slot] = None
            m = self.obs.metrics
            m.counter("requests_completed").inc()
            if req.submitted_at is not None:
                m.histogram("request_latency_s").observe(req.latency)
                if req.first_token_at is not None:
                    m.histogram("request_ttft_s").observe(req.ttft)
            self.obs.trace.instant("emit", {"uid": req.uid, "tokens": len(req.generated)})
            return True
        return False

    def _prefill_slot(self, slot: int, req: Request):
        # Feed prompt tokens one at a time into this slot's cache lane.  Other
        # rows receive transient garbage at their *current* position, which
        # their own next real token overwrites before it is ever attended.
        # The final prompt step's logits yield the first generated token here,
        # booked under prefill — same convention as the paged engine (the seed
        # engine deferred it to the first tick and booked it under decode,
        # skewing decode_tok_s comparisons ~14%).
        t0 = time.perf_counter()
        with self.obs.trace.span("prefill_slot", {"uid": req.uid, "tokens": len(req.prompt)}):
            self.pos[slot] = 0
            for t in req.prompt:
                tok = np.zeros((self.batch, 1), np.int32)
                tok[slot, 0] = t
                logits, self.cache = self._decode(
                    self.params, jnp.asarray(tok), self.cache, jnp.asarray(self.pos.copy())
                )
                self.pos[slot] += 1
            last = np.asarray(jax.device_get(logits[slot, 0]))
        self.stats["prefill_s"] += time.perf_counter() - t0
        self.stats["prefill_tokens"] += len(req.prompt)
        self._emit_token(slot, req, last)

    def tick(self) -> int:
        """Advance every live slot one token; returns number of live slots.

        Slots advance at *their own* positions (per-row cache writes), so
        sequences admitted at different times interleave correctly.  Each tick
        feeds the previous token (``req.last_token``) and samples from the
        fresh logits it produces — one forward per emitted token, none wasted
        (the seed engine ran a final forward whose logits were never used).
        """
        live = [i for i, s in enumerate(self.slots) if s is not None]
        if not live:
            return 0
        t0 = time.perf_counter()
        with self.obs.trace.span("decode_tick", {"live": len(live)}):
            tok = np.zeros((self.batch, 1), np.int32)
            for i in live:
                tok[i, 0] = self.slots[i].last_token
            logits, self.cache = self._decode(self.params, jnp.asarray(tok), self.cache, jnp.asarray(self.pos.copy()))
            ln = np.asarray(jax.device_get(logits[:, 0]))
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["decode_tokens"] += len(live)
        self.stats["decode_dispatches"] += 1
        for i in live:
            req = self.slots[i]
            self.pos[i] += 1
            self._emit_token(i, req, ln[i])
        return len(live)

    def generate(self, prompts: list, max_new: int = 16) -> list[list[int]]:
        """Convenience batch API: admit all, tick until drained."""
        reqs = [
            Request(uid=i, prompt=_normalize_prompt(p, self.bos_id), max_new=max_new,
                    submitted_at=time.perf_counter())
            for i, p in enumerate(prompts)
        ]
        self.last_requests = reqs  # parity gates read tokens + margins here
        if self.recurrent:
            return self._generate_lockstep(reqs)
        pending = list(reqs)
        while pending or any(s is not None for s in self.slots):
            while pending and self.admit(pending[0]):
                pending.pop(0)
            if self.tick() == 0 and not pending:
                break
        return [r.generated for r in reqs]

    def _generate_lockstep(self, reqs: list) -> list[list[int]]:
        assert len(reqs) <= self.batch, "lockstep mode serves one group at a time"
        self.last_requests = reqs
        for r in reqs:  # admit() is bypassed here — apply the engine default
            if r.eos_id is None:
                r.eos_id = self.eos_id
        lens = {len(r.prompt) for r in reqs}
        assert len(lens) == 1, "recurrent archs require equal-length prompt groups"
        T = lens.pop()
        t0 = time.perf_counter()
        # groups start from an empty engine: drop whatever recurrent S/shift
        # (and ring kpos) the previous group's drain left in the cache
        self.cache = init_cache(self.arch, self.batch, self.max_seq,
                                dtype=jnp.dtype(self.arch.compute_dtype))
        self.pos[:] = 0
        for i, r in enumerate(reqs):
            self.slots[i] = r
        for t in range(T):
            tok = np.zeros((self.batch, 1), np.int32)
            for i, r in enumerate(reqs):
                tok[i, 0] = r.prompt[t]
            logits, self.cache = self._decode(
                self.params, jnp.asarray(tok), self.cache, jnp.asarray(self.pos.copy())
            )
            self.pos[: len(reqs)] += 1
        ln = np.asarray(jax.device_get(logits[:, 0]))
        self.stats["prefill_s"] += time.perf_counter() - t0
        self.stats["prefill_tokens"] += T * len(reqs)
        for i, r in enumerate(reqs):
            self._emit_token(i, r, ln[i])
        while any(s is not None for s in self.slots):
            self.tick()
        return [r.generated for r in reqs]


class PagedServeEngine(_StatsMixin):
    """Paged-KV serving engine: scheduler-driven continuous batching, chunked
    prefill on isolated cache views, on-device sampling.

    ``num_blocks`` bounds KV memory (default: worst case, every slot at
    ``max_seq``); admission stalls — never crashes — when blocks run out,
    resuming as finished sequences release theirs.

    ``kv_quant=True`` stores seq-indexed K/V as integer blocks with per-slot
    fp32 scales (``serve/paged_cache.py``): ~4x less KV HBM per live token
    and ~4x less decode read bandwidth at ``kv_bits=8`` (int8 codes; ~6-7x
    at ``kv_bits=4``, two packed codes per byte), at a bounded quantization
    error the parity gates bound to greedy-token agreement on reduced archs.

    ``prefix_share=True`` dedups common prompt prefixes across requests via
    the cache's prefix registry: admission adopts the longest registered
    matching block run (refcounted, copy-on-write on any later write into a
    shared block) and prefill skips the adopted tokens entirely.  Only
    fully paged archs participate (the registry refuses otherwise).
    """

    def __init__(
        self,
        arch: ArchConfig,
        params: dict,
        *,
        batch: int = 4,
        max_seq: int = 512,
        block_size: int = 16,
        prefill_chunk: int = 32,
        num_blocks: Optional[int] = None,
        rt: Optional[Runtime] = None,
        sample: Optional[SampleConfig] = None,
        lockstep: Optional[bool] = None,
        kv_quant: bool = False,
        kv_bits: int = 8,
        prefix_share: bool = False,
        bos_id: int = 0,
        eos_id: Optional[int] = None,
        decode_steps: int = 1,
        seed: int = 0,
        obs: Optional[Obs] = None,
    ):
        if decode_steps < 1:
            raise ValueError(f"decode_steps must be >= 1, got {decode_steps}")
        self.arch = arch
        self.params = params
        self.batch = batch
        self.max_seq = max_seq
        self.rt = rt or Runtime()
        self.obs = obs or Obs()
        self.sample_cfg = sample or SampleConfig()
        self.bos_id = bos_id
        self.eos_id = eos_id  # default for requests that don't set their own
        self.decode_steps = int(decode_steps)
        self.recurrent = any(s.kind in ("rwkv6", "hymba") for s in arch.stacks)
        self.cache = PagedKVCache(
            arch, batch, block_size=block_size, num_blocks=num_blocks,
            max_seq=max_seq, dtype=jnp.dtype(arch.compute_dtype), kv_quant=kv_quant,
            kv_bits=kv_bits,
        )
        self.prefix_share = prefix_share and self.cache.fully_paged
        self.sched = Scheduler(
            batch, prefill_chunk=prefill_chunk,
            lockstep=bool(lockstep) if lockstep is not None else False,
            obs=self.obs,
        )
        self._key = jax.random.PRNGKey(seed)
        self.stats = _fresh_stats()
        # disaggregation: uid -> exported-KV payload awaiting adoption
        # (submit_handoff queues the request; _admit consumes the payload)
        self._handoffs: dict = {}
        self._prefill = jax.jit(self._prefill_fn, donate_argnums=(2,))
        self._decode = jax.jit(self._decode_fn, donate_argnums=(2,))
        self._megadecode = jax.jit(self._megastep_fn, donate_argnums=(2,))

    def params_struct(self, params):
        return params

    def reset_stats(self) -> None:
        super().reset_stats()
        self.cache.reset_counters()

    def _jit_sites(self) -> dict:
        return {
            "prefill": self._prefill,
            "decode": self._decode,
            "megadecode": self._megadecode,
        }

    def _sync_metrics(self) -> None:
        super()._sync_metrics()
        m = self.obs.metrics
        cc = self.cache.counters()
        # peak_blocks is a watermark (fleet merge takes the max); the rest
        # are monotone event counts
        m.gauge("kv_peak_blocks").set(cc.pop("peak_blocks"))
        for k, v in cc.items():
            m.counter(f"kv_{k}").set(v)
        m.gauge("kv_free_blocks").set(self.cache.free_blocks)

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    # -- jitted steps (sampling fused: only token ids — plus one fp32 greedy
    # margin per row, read by the int8-KV parity bound — leave the device) --

    @staticmethod
    def _greedy_margin(logits):
        top2 = jax.lax.top_k(logits.astype(jnp.float32), 2)[0]
        return top2[:, 0] - top2[:, 1]

    def _prefill_fn(self, params, tokens, pools, bt, start, key):
        cache = {**pools, "_paged": {"bt": bt}}
        logits, new_cache, _ = apply_lm(
            self.params_struct(params), self.arch, tokens=tokens, cache=cache,
            start_pos=start, rt=self.rt,
        )
        tok = sample_tokens(logits[:, -1], self.sample_cfg, key)
        return tok, self._greedy_margin(logits[:, -1]), new_cache

    def _decode_fn(self, params, tokens, pools, bt, pos, key):
        cache = {**pools, "_paged": {"bt": bt}}
        logits, new_cache, _ = apply_lm(
            self.params_struct(params), self.arch, tokens=tokens, cache=cache,
            start_pos=pos, rt=self.rt,
        )
        tok = sample_tokens(logits[:, 0], self.sample_cfg, key)
        return tok, self._greedy_margin(logits[:, 0]), new_cache

    def _megastep_fn(self, params, tok0, pools, bt, lens, active, rem, eos, key):
        """``decode_steps`` decode ticks fused into one jitted ``lax.scan``
        dispatch (the spec drafter's k-steps-in-one-scan shape, promoted to
        the main decode loop).  All bookkeeping the per-tick path does on the
        host runs on device instead:

        * position advance — the carry holds per-row ``pos``; each row's
          sampled token feeds the next tick's forward without a host
          round-trip;
        * finish masking — a row goes inactive the tick it emits its
          ``eos`` id (``-1`` = no EOS for that row) or exhausts ``rem``
          (remaining ``max_new`` budget), exactly mirroring
          ``Scheduler.record_token``.  Inactive rows coast: their block
          table is swapped for the all-trash-block-0 table, so their
          (garbage) KV writes land in the trash block and their real cache
          is never touched.  Per-slot non-pool leaves (ring kpos, recurrent
          S/shift) do keep advancing for coasting rows — harmless, because
          ``reset_slot`` zeroes them on the slot's next admission.

        Returns ``(B, N)`` token ids / greedy margins / emitted flags plus
        the advanced pools — one ``device_get`` per window instead of per
        token.  ``emitted[i, j]`` is True iff row i was active entering tick
        j; the host replays exactly those flags through ``record_token``, so
        greedy output is token-identical to the per-tick path.
        """
        trash_bt = jnp.zeros_like(bt)
        keys = jax.random.split(key, self.decode_steps)

        def step(carry, k):
            tok, pos, act, remaining, pools = carry
            bte = jnp.where(act[:, None], bt, trash_bt)
            cache = {**pools, "_paged": {"bt": bte}}
            logits, new_cache, _ = apply_lm(
                self.params_struct(params), self.arch, tokens=tok[:, None],
                cache=cache, start_pos=pos, rt=self.rt,
            )
            nxt = sample_tokens(logits[:, 0], self.sample_cfg, k)
            marg = self._greedy_margin(logits[:, 0])
            emitted = act
            adv = act.astype(jnp.int32)
            pos2 = pos + adv
            rem2 = remaining - adv
            act2 = act & (nxt != eos) & (rem2 > 0)
            return (nxt, pos2, act2, rem2, new_cache), (nxt, marg, emitted)

        (_, _, _, _, pools), (toks, margs, emitted) = jax.lax.scan(
            step, (tok0, lens, active, rem, pools), keys
        )
        # scan stacks along the leading (tick) axis; report (B, N)
        return (
            jnp.swapaxes(toks, 0, 1), jnp.swapaxes(margs, 0, 1),
            jnp.swapaxes(emitted, 0, 1), pools,
        )

    # -- request lifecycle --------------------------------------------------

    def _slot_tokens(self, req: Request) -> int:
        """Worst-case cache positions a request may write (subclasses add
        headroom — e.g. the speculative engine's rejected-draft span)."""
        return len(req.prompt) + req.max_new

    def _release_slot(self, slot: int) -> None:
        """Finished-request teardown (subclasses add drafter state)."""
        self.cache.release(slot)

    def _on_admitted(self, slot: int, req: Request) -> None:
        """Post-prefill hook for subclasses (drafter admission)."""

    def submit(self, req: Request) -> None:
        req.prompt = _normalize_prompt(req.prompt, self.bos_id)
        if req.eos_id is None:
            req.eos_id = self.eos_id
        total = self._slot_tokens(req)
        if total > self.max_seq:
            raise ValueError(f"request needs {total} positions > max_seq={self.max_seq}")
        if self.cache.blocks_needed(total) > self.cache.num_blocks - 1:
            raise ValueError("request exceeds the paged cache's total block budget")
        self.sched.submit(req)

    # -- prefill/decode disaggregation --------------------------------------

    def can_prefill_handoff(self, req: Request) -> bool:
        """Capacity probe for a prefill-role replica: a borrowed slot and
        enough blocks for the *prompt only* (decode headroom is the decode
        replica's budget)."""
        return (
            any(r is None for r in self.sched.slots)
            and self.cache.blocks_needed(len(req.prompt))
            <= self.cache.free_blocks + self.cache.reclaimable_blocks()
        )

    def prefill_handoff(self, req: Request) -> dict:
        """Prefill-role entry point of the disaggregated cluster: run the
        prompt through the isolated chunked prefill on a borrowed free slot,
        export the written KV blocks at wire width, release the slot, and
        return the migration payload — the request never enters this
        engine's decode loop.  The payload carries the prefill's sampled
        first token (and its greedy margin), so the decode replica adopts
        at exactly the state a local admission would have produced:

            {"kv": <export_blocks payload>, "first_token": int, "margin": float}
        """
        req.prompt = _normalize_prompt(req.prompt, self.bos_id)
        if req.eos_id is None:
            req.eos_id = self.eos_id
        if len(req.prompt) > self.max_seq:
            raise ValueError(f"prompt of {len(req.prompt)} tokens > max_seq={self.max_seq}")
        free = [i for i, r in enumerate(self.sched.slots) if r is None]
        if not free:
            raise RuntimeError("prefill_handoff needs a free slot")
        slot = free[0]
        self.cache.reset_slot(slot)
        self.cache.allocate(slot, len(req.prompt))
        self.sched.slots[slot] = req  # prefill_plan reads the slot binding
        try:
            t0 = time.perf_counter()
            tok = marg = None
            with self.obs.trace.span("prefill_handoff", {"uid": req.uid}):
                for chunk, start in self.sched.prefill_plan(slot):
                    with self.obs.trace.span("prefill_chunk", {"uid": req.uid, "start": start}):
                        self.cache.ensure_writable(slot, start, start + len(chunk))
                        sub = self.cache.slice_slot(slot)
                        tok, marg, new_pools = self._prefill(
                            self.params, jnp.asarray(chunk[None, :]), sub,
                            self.cache.bt_row(slot), jnp.int32(start), self._next_key(),
                        )
                        self.cache.merge_slot(slot, new_pools)
                self.cache.lens[slot] = len(req.prompt)
                tok_h, marg_h = jax.device_get((tok, marg))
                self.stats["prefill_s"] += time.perf_counter() - t0
                self.stats["prefill_tokens"] += len(req.prompt)
                with self.obs.trace.span("kv_export", {"uid": req.uid}):
                    payload = {
                        "kv": self.cache.export_blocks(slot),
                        "first_token": int(tok_h[0]),
                        "margin": float(marg_h[0]),
                    }
        finally:
            self.sched.slots[slot] = None
            req.prefilled = 0  # a requeued copy must be able to re-prefill
            self.cache.release(slot)
        return payload

    def submit_handoff(self, req: Request, payload: dict) -> None:
        """Decode-role entry point: queue a request whose prompt KV arrives
        as a migrated block payload from a prefill replica.  Admission goes
        through the normal scheduler/block gate (the full prompt + max_new
        reservation), but ``_admit`` imports the payload's blocks instead of
        recomputing the prompt — zero prefill dispatches, decode resumes at
        ``len(prompt)`` with the handed-off first token already recorded."""
        req.prompt = _normalize_prompt(req.prompt, self.bos_id)
        if req.eos_id is None:
            req.eos_id = self.eos_id
        kv = payload["kv"]
        if kv["tokens"] != len(req.prompt):
            raise ValueError(
                f"handoff payload covers {kv['tokens']} tokens, "
                f"prompt has {len(req.prompt)}"
            )
        # fail at the queue boundary, not inside a later _admit: geometry
        # skew means the fleets were launched with mismatched cache configs
        if kv["block_size"] != self.cache.block_size:
            raise ValueError(
                f"handoff block_size {kv['block_size']} != {self.cache.block_size}"
            )
        if kv["kv_quant"] != self.cache.kv_quant or (
            kv["kv_quant"] and kv["kv_bits"] != self.cache.kv_bits
        ):
            raise ValueError(
                f"handoff kv_quant/kv_bits ({kv['kv_quant']}, {kv['kv_bits']}) do "
                f"not match this cache ({self.cache.kv_quant}, {self.cache.kv_bits})"
            )
        total = self._slot_tokens(req)
        if total > self.max_seq:
            raise ValueError(f"request needs {total} positions > max_seq={self.max_seq}")
        if self.cache.blocks_needed(total) > self.cache.num_blocks - 1:
            raise ValueError("request exceeds the paged cache's total block budget")
        self._handoffs[req.uid] = payload
        self.sched.submit(req)

    def _admit_handoff(self, slot: int, req: Request, payload: dict) -> None:
        """Adopt migrated prompt KV into a fresh slot: import the wire
        blocks, grow the allocation to the full decode reservation, and
        record the prefill replica's first token.  No prompt forward runs
        here — ``prefill_tokens`` counts zero recomputed tokens, mirroring
        the prefix-adoption accounting."""
        self.cache.reset_slot(slot)
        t0 = time.perf_counter()
        with self.obs.trace.span("kv_import", {"uid": req.uid, "slot": slot}):
            self.cache.import_blocks(slot, payload["kv"])
            self.cache.allocate(slot, self._slot_tokens(req))
        req.prefilled = len(req.prompt)
        req.margins.append(float(payload["margin"]))
        if self.prefix_share:
            self.cache.register_prefix(slot, req.prompt)
        self.stats["prefill_s"] += time.perf_counter() - t0
        self._on_admitted(slot, req)
        if self.sched.record_token(slot, int(payload["first_token"])):
            self._release_slot(slot)

    def _admission_gate(self):
        """Round-local block budget: each admitted request reserves its
        worst-case blocks against the same free pool, so a round can never
        jointly over-commit what ``allocate`` will actually hand out (two
        requests that fit individually but not together must stall the
        second, not crash it).  Prefix adoption only ever *reduces* a
        request's fresh-block draw (a copy-on-write fault consumes a block
        the sequence would otherwise have allocated outright), so the
        worst-case reservation stays sound with sharing on.  Cache-pinned
        prefix blocks count as capacity: ``allocate`` reclaims them
        (LRU/cost eviction; permanently pinned chains excluded) before it
        ever fails."""
        budget = self.cache.free_blocks + self.cache.reclaimable_blocks()

        def can_admit(req: Request) -> bool:
            nonlocal budget
            need = self.cache.blocks_needed(self._slot_tokens(req))
            if need > budget:
                return False
            budget -= need
            return True

        return can_admit

    def _admit(self, slot: int, req: Request) -> None:
        """Isolated chunked prefill: whole prompt chunks through a B=1 cache
        view of this slot — other live rows' caches and recurrent states are
        never touched, so admission composes with continuous batching on
        every arch (incl. recurrent stacks).  With ``prefix_share`` the
        longest cached prompt prefix is adopted from the radix prompt cache
        first and prefill resumes after it — at the *chunk-aligned* offset
        below the shared length, not at the shared length itself.  Resuming
        at an arbitrary offset mints a fresh XLA compile per distinct
        shared-prefix length (the chunk token array takes a new shape); the
        aligned resume keeps every chunk shape inside the fixed
        ``{prefill_chunk, len % prefill_chunk}`` set plain prefill already
        compiles.  Adoption is trimmed to the blocks covering ``[0,
        resume)``: the span ``[resume, shared)`` gets recomputed regardless
        (re-deriving bit-identical K/V — deterministic B=1 chunked prefill,
        same path the donor ran), so adopting its partial block would only
        buy a copy-on-write fault; when ``block_size`` divides
        ``prefill_chunk`` the trimmed run is all-full blocks the adopter
        never writes, and admission costs zero CoW dispatches."""
        payload = self._handoffs.pop(req.uid, None)
        if payload is not None:
            return self._admit_handoff(slot, req, payload)
        tr = self.obs.trace
        with tr.span("admit", {"uid": req.uid, "slot": slot, "prompt": len(req.prompt)}):
            self.cache.reset_slot(slot)
            adopted = 0
            if self.prefix_share:
                with tr.span("radix_lookup", {"uid": req.uid}):
                    shared, blocks = self.cache.lookup_prefix(req.prompt)
                resume = (shared // self.sched.prefill_chunk) * self.sched.prefill_chunk
                if resume > 0:
                    blocks = blocks[: self.cache.blocks_needed(resume)]
                    self.cache.adopt_prefix(slot, resume, blocks)
                    req.prefilled = adopted = resume
            with tr.span("block_alloc", {"uid": req.uid}):
                self.cache.allocate(slot, self._slot_tokens(req))
            t0 = time.perf_counter()
            tok = marg = None
            for chunk, start in self.sched.prefill_plan(slot):
                with tr.span("prefill_chunk", {"uid": req.uid, "start": start}):
                    with tr.span("cow_preflight", {"uid": req.uid}):
                        self.cache.ensure_writable(slot, start, start + len(chunk))
                    sub = self.cache.slice_slot(slot)
                    tok, marg, new_pools = self._prefill(
                        self.params, jnp.asarray(chunk[None, :]), sub,
                        self.cache.bt_row(slot), jnp.int32(start), self._next_key(),
                    )
                    self.cache.merge_slot(slot, new_pools)
            self.cache.lens[slot] = len(req.prompt)
            if self.prefix_share:
                self.cache.register_prefix(slot, req.prompt)
            tok_h, marg_h = jax.device_get((tok, marg))
            first = int(tok_h[0])
            req.margins.append(float(marg_h[0]))
            self.stats["prefill_s"] += time.perf_counter() - t0
            # adopted tokens were never recomputed — throughput counts real work
            self.stats["prefill_tokens"] += len(req.prompt) - adopted
            self._on_admitted(slot, req)
        if self.sched.record_token(slot, first):
            self._release_slot(slot)

    def _admit_group(self, group: list) -> None:
        """Lockstep fallback: equal-length group prefilled together in one
        batched chunked pass (all rows share every position)."""
        L = len(group[0][1].prompt)
        assert all(len(r.prompt) == L for _, r in group), "lockstep needs equal lengths"
        toks = np.zeros((self.batch, L), np.int32)
        for slot, req in group:
            self.cache.reset_slot(slot)
            self.cache.allocate(slot, L + req.max_new)
            toks[slot] = req.prompt
            req.prefilled = L
        t0 = time.perf_counter()
        tok = marg = None
        with self.obs.trace.span("admit_group", {"requests": len(group), "prompt": L}):
            for lo in range(0, L, self.sched.prefill_chunk):
                hi = min(lo + self.sched.prefill_chunk, L)
                with self.obs.trace.span("prefill_chunk", {"start": lo}):
                    tok, marg, pools = self._prefill(
                        self.params, jnp.asarray(toks[:, lo:hi]), self.cache.pools,
                        self.cache.bt(), jnp.int32(lo), self._next_key(),
                    )
                    self.cache.pools = pools
            firsts, margs = (np.asarray(a) for a in jax.device_get((tok, marg)))
        self.stats["prefill_s"] += time.perf_counter() - t0
        self.stats["prefill_tokens"] += L * len(group)
        for slot, req in group:
            self.cache.lens[slot] = L
            req.margins.append(float(margs[slot]))
            if self.sched.record_token(slot, int(firsts[slot])):
                self._release_slot(slot)

    def pin_prompt(self, tokens) -> int:
        """Prefill a system preamble once and pin its full blocks in the
        radix prompt cache permanently (``--pin-prompt``): the chain is
        never evicted — not by block pressure, not by a burst of cold
        registrations — and does not count against the node cap.  Call
        before traffic (needs an idle engine: it borrows slot 0 for the
        prefill and releases it, leaving only the cache pins).  Returns the
        number of pinned tokens (full blocks only — the partial tail block,
        if any, is recomputed by adopters like any other resumed span)."""
        if not self.prefix_share:
            raise ValueError("pin_prompt requires prefix_share=True")
        tokens = _normalize_prompt(tokens, self.bos_id)
        if not self.sched.idle():
            raise RuntimeError("pin_prompt needs an idle engine (call pre-traffic)")
        if len(tokens) + 1 > self.max_seq:
            raise ValueError("pinned prompt exceeds max_seq")
        slot = 0
        self.cache.reset_slot(slot)
        self.cache.allocate(slot, len(tokens))
        for lo in range(0, len(tokens), self.sched.prefill_chunk):
            hi = min(lo + self.sched.prefill_chunk, len(tokens))
            self.cache.ensure_writable(slot, lo, hi)
            sub = self.cache.slice_slot(slot)
            _, _, new_pools = self._prefill(
                self.params, jnp.asarray(tokens[None, lo:hi]), sub,
                self.cache.bt_row(slot), jnp.int32(lo), self._next_key(),
            )
            self.cache.merge_slot(slot, new_pools)
        self.cache.lens[slot] = len(tokens)
        self.cache.register_prefix(slot, tokens, pinned=True)
        self.cache.release(slot)
        return (len(tokens) // self.cache.block_size) * self.cache.block_size

    def tick(self) -> int:
        """One decode step for every live slot (dead rows ride along writing
        into the trash block); returns the number of live slots advanced."""
        live = self.sched.live
        if not live:
            return 0
        tr = self.obs.trace
        tok_in = np.zeros((self.batch,), np.int32)
        with tr.span("cow_preflight", {"live": len(live)}):
            for i in live:
                tok_in[i] = self.sched.slots[i].last_token
                # a donor's decode write can land in a block a prefix-sharer
                # adopted — copy-on-write it out of the shared run first
                self.cache.ensure_writable(i, int(self.cache.lens[i]), int(self.cache.lens[i]) + 1)
        t0 = time.perf_counter()
        with tr.span("decode_tick", {"live": len(live)}):
            toks, margs, pools = self._decode(
                self.params, jnp.asarray(tok_in[:, None]), self.cache.pools,
                self.cache.bt(), jnp.asarray(self.cache.lens.copy()), self._next_key(),
            )
            self.cache.pools = pools
            # one host round-trip for ids + margins (decode stays two tiny arrays)
            out, marg = (np.asarray(a) for a in jax.device_get((toks, margs)))
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["decode_tokens"] += len(live)
        self.stats["decode_dispatches"] += 1
        for i in live:
            self.cache.lens[i] += 1
            self.sched.slots[i].margins.append(float(marg[i]))
            if self.sched.record_token(i, int(out[i])):
                self._release_slot(i)
        return len(live)

    def megastep(self) -> int:
        """Up to ``decode_steps`` decode ticks for every live slot in ONE
        jitted dispatch (``_megastep_fn``); returns the number of live slots
        advanced.  The per-tick host work is hoisted to window entry:

        * **CoW preflight**: each slot's write span for the whole window —
          ``[lens, lens + min(N, remaining))`` — is made writable once via
          the batched ``ensure_writable`` (one pool rebuild), instead of one
          call per slot per tick.  The span never exceeds the slot's
          admission-time allocation because ``remaining`` caps it at
          ``max_new`` and the final emitted token is never consumed.
        * **one upload** of block tables / lens / masks, **one download** of
          ``(B, N)`` token ids + margins + emitted flags per window.

        The host then replays the emitted flags through
        ``Scheduler.record_token`` in tick order; because the device finish
        mask mirrors ``record_token`` exactly (EOS emit or ``max_new``
        reached), a finished row's later flags are False and the replay
        releases each slot at the same tick the per-tick path would have.
        """
        live = self.sched.live
        if not live:
            return 0
        tr = self.obs.trace
        N = self.decode_steps
        tok_in = np.zeros((self.batch,), np.int32)
        active = np.zeros((self.batch,), bool)
        rem = np.zeros((self.batch,), np.int32)
        eos = np.full((self.batch,), -1, np.int32)  # -1: token ids are >= 0
        with tr.span("cow_preflight", {"live": len(live)}):
            for i in live:
                req = self.sched.slots[i]
                tok_in[i] = req.last_token
                active[i] = True
                rem[i] = req.max_new - len(req.generated)
                if req.eos_id is not None:
                    eos[i] = req.eos_id
                lo = int(self.cache.lens[i])
                self.cache.ensure_writable(i, lo, lo + min(N, int(rem[i])))
        t0 = time.perf_counter()
        with tr.span("decode_megastep", {"live": len(live), "steps": N}):
            toks, margs, emitted, pools = self._megadecode(
                self.params, jnp.asarray(tok_in), self.cache.pools, self.cache.bt(),
                jnp.asarray(self.cache.lens.copy()), jnp.asarray(active),
                jnp.asarray(rem), jnp.asarray(eos), self._next_key(),
            )
            self.cache.pools = pools
            out, marg, em = (np.asarray(a) for a in jax.device_get((toks, margs, emitted)))
        dt = time.perf_counter() - t0
        total = 0
        for j in range(N):
            for i in live:
                if not em[i, j]:
                    continue
                total += 1
                self.cache.lens[i] += 1
                self.sched.slots[i].margins.append(float(marg[i, j]))
                if self.sched.record_token(i, int(out[i, j])):
                    self._release_slot(i)
        self.stats["decode_s"] += dt
        self.stats["decode_tokens"] += total
        self.stats["decode_dispatches"] += 1
        return len(live)

    def _advance(self) -> int:
        """One decode round (subclass hook: the spec engine swaps in its
        draft-verify round here).  ``decode_steps > 1`` routes to the fused
        megastep; 1 keeps the per-tick path (and its per-token parity role)."""
        if self.decode_steps > 1:
            return self.megastep()
        return self.tick()

    def step(self) -> int:
        """Admit what fits, then advance one decode round."""
        admitted = self.sched.admissions(self._admission_gate())
        if self.sched.lockstep:
            if admitted:
                self._admit_group(admitted)
        else:
            for slot, req in admitted:
                self._admit(slot, req)
        n = self._advance()
        if n == 0 and not admitted and self.sched.queue:
            raise RuntimeError("scheduler stalled: queued work but nothing admittable")
        return n

    def generate(self, prompts: list, max_new: int = 16) -> list[list[int]]:
        """Convenience batch API: submit all, step until drained."""
        reqs = [
            Request(uid=i, prompt=_normalize_prompt(p, self.bos_id), max_new=max_new)
            for i, p in enumerate(prompts)
        ]
        for r in reqs:
            self.submit(r)
        self.last_requests = reqs  # parity gates read tokens + margins here
        while not self.sched.idle():
            self.step()
        return [r.generated for r in reqs]
