"""Serving scheduler: admission queue, chunked prefill plans, slot recycling.

Pure host-side policy — no jax in here.  The engine owns execution (jitted
prefill / decode steps, the paged cache); the scheduler owns *which* request
occupies *which* slot *when*:

* **continuous mode** (default): any freed slot is immediately refilled from
  the FIFO queue, so long requests never stall short ones behind them.
  Prefill is per-slot and isolated (the engine runs it on a B=1 cache view),
  which is also what makes continuous batching sound for recurrent stacks —
  admitting into a live batch never touches other rows' states.
* **lockstep mode** (the conservative fallback for recurrent stacks, and the
  batched-prefill fast path): requests are admitted in equal-prompt-length
  groups into an *empty* engine, prefilled together in one batched chunked
  pass, and decoded until the whole group drains.

Requests also carry their latency bookkeeping (submit / first-token / finish
timestamps) so the benchmark derives p50/p99 without instrumenting engines.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Iterator, List, Optional, Tuple

import numpy as np

__all__ = ["ServeRequest", "Scheduler"]


@dataclasses.dataclass
class ServeRequest:
    uid: int
    prompt: np.ndarray  # (T,) int32, non-empty (engine normalizes)
    max_new: int = 16
    # end-of-sequence token: the request finishes as soon as it *emits* this
    # id (the EOS token is appended to ``generated``, then the slot and its
    # cache blocks release immediately — no decoding past end-of-sequence,
    # no blocks burned on garbage).  ``None`` defers to the engine's default
    # (``eos_id=`` engine kwarg), which may itself be None (length-only stop).
    eos_id: Optional[int] = None
    generated: list = dataclasses.field(default_factory=list)
    # greedy decision margins: top-2 logit gap at the step that produced
    # generated[t] — what the int8-KV parity bound reads (a mismatch only
    # counts where the float baseline's margin exceeds the quantization-noise
    # bound; below it the decision is a tie).  Engines append one entry per
    # generated token; empty when the engine does not track margins.
    margins: list = dataclasses.field(default_factory=list)
    done: bool = False
    prefilled: int = 0  # prompt tokens already in the cache
    last_token: int = -1  # most recent sampled token (next decode input)
    # speculative-decoding bookkeeping (SpecServeEngine): draft tokens
    # proposed for / accepted by this request — per-request acceptance rate
    spec_proposed: int = 0
    spec_accepted: int = 0
    # latency timestamps: ``None`` until the event happens.  They used to
    # default to 0.0, so reading ``ttft``/``latency`` on an in-flight request
    # returned epoch-scale *negative* values (now - 0.0 negated) that a
    # percentile aggregation would silently swallow; the properties now
    # refuse instead of lying.
    submitted_at: Optional[float] = None
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None

    @property
    def latency(self) -> float:
        if self.submitted_at is None or self.finished_at is None:
            raise RuntimeError(
                f"request {self.uid}: latency read before completion "
                f"(submitted={self.submitted_at}, finished={self.finished_at})"
            )
        return self.finished_at - self.submitted_at

    @property
    def ttft(self) -> float:
        if self.submitted_at is None or self.first_token_at is None:
            raise RuntimeError(
                f"request {self.uid}: ttft read before the first token "
                f"(submitted={self.submitted_at}, first_token={self.first_token_at})"
            )
        return self.first_token_at - self.submitted_at


class Scheduler:
    def __init__(self, n_slots: int, *, prefill_chunk: int = 32, lockstep: bool = False,
                 obs=None):
        if prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        self.n_slots = n_slots
        self.prefill_chunk = prefill_chunk
        self.lockstep = lockstep
        # observability bundle (repro.obs.Obs) shared with the owning engine:
        # the scheduler is where requests enter and complete, so the
        # per-request latency histograms and submit/emit trace instants are
        # recorded here rather than in any engine.
        self.obs = obs
        self.queue: deque[ServeRequest] = deque()
        self.slots: List[Optional[ServeRequest]] = [None] * n_slots

    # -- state --------------------------------------------------------------

    @property
    def live(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is not None]

    def idle(self) -> bool:
        return not self.queue and not self.live

    # -- admission ----------------------------------------------------------

    def submit(self, req: ServeRequest) -> None:
        req.submitted_at = time.perf_counter()
        self.queue.append(req)
        if self.obs is not None:
            self.obs.trace.instant("submit", {"uid": req.uid, "prompt": len(req.prompt)})

    def admissions(self, can_admit: Callable[[ServeRequest], bool]) -> List[Tuple[int, "ServeRequest"]]:
        """Assign queued requests to slots; returns the new (slot, request)
        pairs.  ``can_admit`` gates on engine capacity (free KV blocks).

        FIFO is strict: if the head of the queue does not fit, nothing behind
        it is admitted either (no starvation of large requests).
        """
        if self.lockstep:
            return self._admit_lockstep(can_admit)
        out = []
        free = (i for i, r in enumerate(self.slots) if r is None)
        for slot in free:
            if not self.queue or not can_admit(self.queue[0]):
                break
            req = self.queue.popleft()
            self.slots[slot] = req
            out.append((slot, req))
        return out

    def _admit_lockstep(self, can_admit) -> List[Tuple[int, "ServeRequest"]]:
        """Equal-length group into an empty engine (recurrent-stack fallback:
        every row advances through identical positions, so a batched prefill
        never desynchronizes the non-positional states)."""
        if self.live or not self.queue:
            return []
        group_len = len(self.queue[0].prompt)
        out = []
        for slot in range(self.n_slots):
            if not self.queue or len(self.queue[0].prompt) != group_len:
                break
            if not can_admit(self.queue[0]):
                break
            req = self.queue.popleft()
            self.slots[slot] = req
            out.append((slot, req))
        return out

    # -- prefill ------------------------------------------------------------

    def prefill_plan(self, slot: int) -> Iterator[Tuple[np.ndarray, int]]:
        """Yield ``(tokens, start)`` chunks remaining for this slot's prompt;
        consuming a chunk marks it prefilled."""
        req = self.slots[slot]
        while req.prefilled < len(req.prompt):
            lo = req.prefilled
            hi = min(lo + self.prefill_chunk, len(req.prompt))
            req.prefilled = hi
            yield req.prompt[lo:hi], lo

    # -- decode bookkeeping -------------------------------------------------

    def record_token(self, slot: int, token: int) -> bool:
        """Append a sampled token; returns True (and frees the slot) when the
        request just completed — either ``max_new`` tokens emitted or the
        token *is* the request's ``eos_id`` (the EOS token itself is recorded,
        then the request stops; nothing decodes past end-of-sequence).  The
        engine releases cache blocks on True."""
        req = self.slots[slot]
        if not req.generated:
            req.first_token_at = time.perf_counter()
        req.generated.append(token)
        req.last_token = token
        if len(req.generated) >= req.max_new or (
            req.eos_id is not None and token == req.eos_id
        ):
            req.done = True
            req.finished_at = time.perf_counter()
            self.slots[slot] = None
            if self.obs is not None:
                m = self.obs.metrics
                m.counter("requests_completed").inc()
                if req.submitted_at is not None:
                    m.histogram("request_latency_s").observe(req.latency)
                    if req.first_token_at is not None:
                        m.histogram("request_ttft_s").observe(req.ttft)
                self.obs.trace.instant(
                    "emit", {"uid": req.uid, "tokens": len(req.generated)}
                )
            return True
        return False
