"""Paged KV cache: fixed-size token blocks + per-sequence block tables.

Instead of pinning every serve slot to a contiguous ``max_seq`` cache lane
(``models/lm.init_cache``: memory = ``batch x max_seq`` regardless of load),
seq-indexed K/V lives in *pools* of ``block_size``-token blocks shared by all
slots.  A host-side free-list allocator hands each sequence just the blocks
its tokens need, recorded in a per-slot *block table*; releasing a finished
sequence returns its blocks immediately.  Cache memory therefore scales with
live tokens, which is what lets a fixed memory budget admit many more mixed-
length requests (the vLLM insight, composed here with the A2Q int8 artifact).

Layout per stack (leading dim = layer count, exactly like
``init_stack_cache``):

* full-attention GQA   — ``kp``/``vp``: ``(count, NB, bs, KV, Dh)`` pools;
* MLA                  — ``ckvp``/``kpep``: ``(count, NB, bs, rank)`` pools
  (the compressed latent is seq-indexed and pages the same way);
* sliding-window / chunked-local — the existing *ring* cache (already bounded
  by the window, nothing to page) stays per-slot contiguous;
* recurrent state (rwkv6 / mamba shift + S) — O(1) per slot, per-slot rows.

Block 0 of every pool is the reserved **trash block**: the block tables of
dead slots point at it, so a full-batch decode step can include dead rows
(they scatter into trash and attend garbage that is never read).

``kv_quant=True`` stores the seq-indexed pools as **integer codes** next to
per-slot fp32 *scale pools* (``kps``/``vps`` for GQA — one scalar per
token-slot per KV head; ``ckvs``/``kpes`` for MLA — one per token-slot),
laid out in the same block geometry and gathered through the same table.
``kv_bits=8`` (default) stores int8 codes; ``kv_bits=4`` packs two 4-bit
codes per byte (uint8 pools of half the feature width — the scale-pool
machinery is unchanged, ``SCALE_KEYS`` still ⊂ ``POOL_KEYS``).  K/V are
quantized on write (``nn/attention._paged_write_q8``) and dequantized on
read — in-register inside the Pallas decode kernel for int8 — so the
seq-indexed KV HBM footprint drops ~4x at 8 bits and ~6-7x at 4 bits.
Ring and recurrent leaves are already O(window)/O(1) and stay float.

All layers share one block table — block ``b`` holds the same token span in
every layer's pool — so the allocator runs once per sequence, not per layer.
The device-facing view is attached to the cache tree under the reserved key
``"_paged"`` (consumed by ``models/lm.apply_lm``).

Sharing and rollback (the speculative-decoding / prefix-sharing substrate):

* every block carries a **refcount**; fresh allocations start at 1, prefix
  adoption (``adopt_prefix``) increments, release/truncate decrement, and a
  block returns to the free list only at refcount zero;
* **copy-on-write**: before any jitted write into a token span the engine
  calls ``ensure_writable(slot, start, end)`` — any covered block with
  refcount > 1 is replaced by a private device-side copy, so a shared
  block's other readers never observe the write;
* **watermarks + truncate**: ``watermarks[slot]`` records the high-water
  write position (set by ``ensure_writable``); ``truncate(slot, n)`` rolls
  a slot back to ``n`` tokens — surplus blocks are dropped in reverse
  ownership order (refcounted, freed at zero) so undoing a speculative
  round restores the allocator state *exactly* (LIFO-symmetric with
  ``allocate``), and stale pool entries past ``lens`` are masked by the
  position arithmetic until overwritten;
* a host-side **radix-tree prompt cache** maps block-granular token chunks
  to pinned blocks: each node owns one full block of ``block_size`` prompt
  tokens, keyed under its parent by the chunk's token tuple (hash-exact —
  descent is one dict probe per block, O(prompt / block_size) total,
  independent of how many prompts are cached).  ``register_prefix`` inserts
  a prompt's fully-covered blocks as a node chain, deduplicating against
  existing nodes (a second donor of the same prefix pins nothing new), so
  *partial-prefix* hits fall out structurally: a lookup descends as far as
  its tokens match ever-registered block content, never needing a whole
  registered prompt to agree.  ``lookup_prefix`` returns the longest match
  (capped at ``len(prompt) - 1`` so prefill always has at least one token
  to produce logits from), including a partial match *into* the next
  block, and ``adopt_prefix`` maps those blocks into a new slot for free.
  Each node pins its block with its own refcount, so a cached prefix
  outlives its donor; eviction is **LRU/cost-aware** — leaf nodes only
  (children always outlive parents), lowest ``hits * covered_tokens``
  first, ties broken least-recently-used — under block pressure
  (``reclaim``) or at the node cap, so one burst of cold registrations can
  no longer flush a hot system prompt (the FIFO failure mode).  Nodes
  registered ``pinned=True`` (``register_prefix(..., pinned=True)``, the
  ``--pin-prompt`` system-preamble path) are never evicted, and a pinned
  node shields its ancestors.  The pin guarantees a cached block can never
  be freed-and-recycled out from under its node (asserted in
  ``_free_and_purge``) — stale-KV matches are structurally impossible.

Invariants the allocator maintains:
* a sequence's blocks appear in its table row in logical order, so the
  gathered view equals the contiguous layout bit-for-bit;
* live slots share a block only while every sharer treats it read-only
  (refcount > 1 ⇒ copy-on-write before any write); unowned table entries
  stay 0 (trash); the trash block is never refcounted and never freed;
* ``lens[slot]`` counts tokens written for the slot (its next write
  position); ``watermarks[slot] >= lens[slot]`` bounds where garbage from
  rolled-back writes may sit.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, AttnConfig, StackConfig
from repro.nn.attention import init_attn_cache

__all__ = [
    "PagedKVCache", "init_paged_stack_cache", "POOL_KEYS", "SCALE_KEYS", "TRASH_BLOCK",
]

# Leaves indexed (count, NB, bs, ...) — everything else is (count, B, ...).
# SCALE_KEYS are the per-slot fp32 scale pools that ride along with integer
# code pools (kv_quant=True); they are block-indexed like any other pool.
SCALE_KEYS = frozenset({"kps", "vps", "ckvs", "kpes"})
POOL_KEYS = frozenset({"kp", "vp", "ckvp", "kpep"}) | SCALE_KEYS
TRASH_BLOCK = 0


def _leaf_name(path) -> Optional[str]:
    keys = [k.key for k in path if hasattr(k, "key")]
    return keys[-1] if keys else None


class _RadixNode:
    """One cached block of the radix prompt cache: ``key`` is the block's
    token chunk (the child key under ``parent``), ``block`` the pinned pool
    block holding those tokens' K/V.  ``hits``/``last_used`` feed the
    LRU/cost eviction policy; ``pinned`` nodes are never evicted."""

    __slots__ = ("key", "block", "parent", "children", "hits", "last_used", "depth_tokens", "pinned")

    def __init__(self, key, block, parent, depth_tokens):
        self.key = key  # tuple of block_size token ids
        self.block = block
        self.parent = parent
        self.children: dict[tuple, "_RadixNode"] = {}
        self.hits = 0
        self.last_used = 0
        self.depth_tokens = depth_tokens  # prompt tokens a hit on this node serves
        self.pinned = False


def _code_shape(dim: int, kv_bits: int) -> tuple[int, ...]:
    """Feature width of a quantized code pool: int8 keeps the width, int4
    packs two codes per byte (requires an even feature dim)."""
    if kv_bits == 8:
        return (dim,)
    if kv_bits == 4:
        if dim % 2:
            raise ValueError(f"int4 KV packing needs an even feature dim, got {dim}")
        return (dim // 2,)
    raise ValueError(f"kv_bits must be 8 or 4, got {kv_bits}")


def init_paged_attn_cache(
    a: AttnConfig, slots: int, num_blocks: int, block_size: int, max_seq: int, dtype,
    kv_quant: bool = False, kv_bits: int = 8,
) -> dict:
    """Paged cache for one attention layer; ring layers keep their bounded
    per-slot layout (a window-sized ring is already token-proportional).
    ``kv_quant``: integer code pools + per-slot fp32 scale pools —
    ``kv_bits=8`` int8 codes, ``kv_bits=4`` two-per-byte packed uint8."""
    code_dtype = jnp.int8 if kv_bits == 8 else jnp.uint8
    if a.kind == "mla":
        if kv_quant:
            return {
                "ckvp": jnp.zeros((num_blocks, block_size, *_code_shape(a.kv_lora_rank, kv_bits)), code_dtype),
                "ckvs": jnp.zeros((num_blocks, block_size), jnp.float32),
                "kpep": jnp.zeros((num_blocks, block_size, *_code_shape(a.qk_rope_dim, kv_bits)), code_dtype),
                "kpes": jnp.zeros((num_blocks, block_size), jnp.float32),
            }
        return {
            "ckvp": jnp.zeros((num_blocks, block_size, a.kv_lora_rank), dtype),
            "kpep": jnp.zeros((num_blocks, block_size, a.qk_rope_dim), dtype),
        }
    if (a.window or a.chunk) is not None:
        return init_attn_cache(slots, a, max_seq, dtype)
    if kv_quant:
        return {
            "kp": jnp.zeros((num_blocks, block_size, a.kv_heads, *_code_shape(a.head_dim, kv_bits)), code_dtype),
            "kps": jnp.zeros((num_blocks, block_size, a.kv_heads), jnp.float32),
            "vp": jnp.zeros((num_blocks, block_size, a.kv_heads, *_code_shape(a.head_dim, kv_bits)), code_dtype),
            "vps": jnp.zeros((num_blocks, block_size, a.kv_heads), jnp.float32),
        }
    return {
        "kp": jnp.zeros((num_blocks, block_size, a.kv_heads, a.head_dim), dtype),
        "vp": jnp.zeros((num_blocks, block_size, a.kv_heads, a.head_dim), dtype),
    }


def init_paged_stack_cache(
    arch: ArchConfig, s: StackConfig, slots: int, num_blocks: int, block_size: int,
    max_seq: int, dtype, kv_quant: bool = False, kv_bits: int = 8,
):
    """Paged twin of ``nn.transformer.init_stack_cache`` (leading ``count``)."""
    d = arch.d_model

    def one():
        if s.kind in ("attn_mlp", "moe"):
            return {"attn": init_paged_attn_cache(s.attn, slots, num_blocks, block_size, max_seq, dtype, kv_quant, kv_bits)}
        if s.kind == "rwkv6":
            H = d // s.ssm.head_dim
            return {
                "tm": {
                    "S": jnp.zeros((slots, H, s.ssm.head_dim, s.ssm.head_dim), jnp.float32),
                    "shift": jnp.zeros((slots, 1, d), dtype),
                },
                "cm": {"shift": jnp.zeros((slots, 1, d), dtype)},
            }
        if s.kind == "hymba":
            H = d // s.ssm.head_dim
            return {
                "attn": init_paged_attn_cache(s.attn, slots, num_blocks, block_size, max_seq, dtype, kv_quant, kv_bits),
                "mamba": {"S": jnp.zeros((slots, H, s.ssm.head_dim, s.ssm.state_dim), jnp.float32)},
            }
        raise ValueError(s.kind)

    cache = one()
    return jax.tree.map(lambda a_: jnp.broadcast_to(a_[None], (s.count, *a_.shape)), cache)


class PagedKVCache:
    """Device pools + host-side block-table allocator for ``slots`` sequences."""

    def __init__(
        self,
        arch: ArchConfig,
        slots: int,
        *,
        block_size: int = 16,
        num_blocks: Optional[int] = None,
        max_seq: int = 512,
        dtype=jnp.bfloat16,
        kv_quant: bool = False,
        kv_bits: int = 8,
        max_prefix_entries: int = 32,
    ):
        if kv_bits not in (8, 4):
            raise ValueError(f"kv_bits must be 8 or 4, got {kv_bits}")
        self.arch = arch
        self.slots = slots
        self.block_size = block_size
        self.kv_quant = kv_quant
        self.kv_bits = kv_bits if kv_quant else 8
        self.max_seq = max_seq
        self.max_blocks_per_seq = -(-max_seq // block_size)
        if num_blocks is None:
            # worst case every slot runs to max_seq, plus the trash block
            num_blocks = slots * self.max_blocks_per_seq + 1
        if num_blocks < 2:
            raise ValueError("need at least one non-trash block")
        self.num_blocks = num_blocks
        self.pools = {
            str(i): init_paged_stack_cache(
                arch, s, slots, num_blocks, block_size, max_seq, dtype, kv_quant, kv_bits
            )
            for i, s in enumerate(arch.stacks)
        }
        # LIFO free list; low ids handed out first so fresh tables are ordered
        self.free = list(range(num_blocks - 1, TRASH_BLOCK, -1))
        self.tables = np.zeros((slots, self.max_blocks_per_seq), np.int32)
        self.lens = np.zeros((slots,), np.int32)
        # high-water write position per slot: truncate() rolls lens back but
        # leaves the watermark — the span [lens, watermark) may hold garbage
        # from rejected speculative writes, masked until overwritten
        self.watermarks = np.zeros((slots,), np.int32)
        self._owned: list[list[int]] = [[] for _ in range(slots)]
        # block refcounts: fresh allocation = 1, prefix adoption increments,
        # release/truncate decrement, free list entry iff 0.  The trash block
        # is never refcounted (rc[TRASH_BLOCK] stays 0 and it is never freed).
        self.refcounts = np.zeros((num_blocks,), np.int32)
        self.peak_blocks = 0  # high-water mark of simultaneously owned blocks
        self.cow_copies = 0  # copy-on-write block copies performed
        self.pool_rebuilds = 0  # pool-pytree rebuild dispatches (CoW batches)
        self.prefix_hits = 0  # admissions that adopted a shared prefix
        self.prefix_hit_tokens = 0  # prompt tokens served from shared blocks
        # radix prompt cache: one node per cached block, children keyed by
        # the next block's token tuple.  Each node pins its block with its
        # own refcount (tracked per block in _entry_rc) so cached prefixes
        # outlive their donor sequence; _block_pins counts nodes per block
        # (normally 1, but nothing stops a caller registering one block
        # under two key chains) for the freed-block purge assert.
        # max_prefix_entries caps the number of *unpinned* nodes (pinned
        # system prompts ride outside the cap).
        self.max_prefix_entries = max_prefix_entries
        self._radix_root = _RadixNode((), TRASH_BLOCK, None, 0)
        self._block_pins: dict[int, int] = {}
        self._entry_rc = np.zeros((num_blocks,), np.int32)
        self._radix_clock = 0  # logical LRU clock
        self._radix_nodes = 0  # total node count
        self._radix_unpinned = 0  # unpinned node count, checked against the cap
        # Device copy of the block tables.  Mutations mark their row dirty;
        # bt() patches dirty rows in place on the existing device array (one
        # dispatch per admission round) instead of re-uploading the whole
        # table per adoption/CoW — the counters witness that behavior.
        self._bt_dev = None
        self._bt_dirty: set[int] = set()
        self.bt_full_uploads = 0
        self.bt_row_patches = 0
        # KV-block migration counters (prefill/decode disaggregation):
        # blocks and wire bytes exported to / imported from a peer cache.
        # Wire width is the *storage* width — int8 codes ship as int8,
        # packed int4 ships as uint8 nibble pairs, scales as fp32.
        self.migrated_blocks_out = 0
        self.migrated_blocks_in = 0
        self.migration_bytes_out = 0
        self.migration_bytes_in = 0
        # all seq-indexed state lives in pools (no ring / recurrent per-slot
        # leaves) — the precondition for prefix sharing and spec rollback
        names = {
            _leaf_name(p)
            for p, _ in jax.tree_util.tree_flatten_with_path(self.pools)[0]
        }
        self.fully_paged = names <= POOL_KEYS

    # -- counters ------------------------------------------------------------

    _COUNTER_FIELDS = (
        "peak_blocks", "cow_copies", "pool_rebuilds", "prefix_hits",
        "prefix_hit_tokens", "bt_full_uploads", "bt_row_patches",
        "migrated_blocks_out", "migrated_blocks_in",
        "migration_bytes_out", "migration_bytes_in",
    )

    def counters(self) -> dict:
        """All cache event counters as one dict — the engine's metrics sync
        and the cluster stats event both read this instead of cherry-picking
        attributes (which is how counters leaked out of reset paths)."""
        return {k: getattr(self, k) for k in self._COUNTER_FIELDS}

    def reset_counters(self) -> None:
        """Zero every event counter (part of the engine's unified
        ``reset_stats`` path; benchmarks used to zero a hand-picked subset
        and leak the rest across phases)."""
        for k in self._COUNTER_FIELDS:
            setattr(self, k, 0)

    # -- allocator ----------------------------------------------------------

    def blocks_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    @property
    def free_blocks(self) -> int:
        return len(self.free)

    def can_allocate(self, n_tokens: int) -> bool:
        return self.blocks_needed(n_tokens) <= len(self.free)

    def allocate(self, slot: int, n_tokens: int) -> None:
        """Grow ``slot``'s table to cover ``n_tokens`` total tokens."""
        need = self.blocks_needed(n_tokens)
        if need > self.max_blocks_per_seq:
            raise ValueError(
                f"sequence of {n_tokens} tokens exceeds max_seq={self.max_seq}"
            )
        owned = self._owned[slot]
        while len(owned) < need:
            if not self.free:
                self.reclaim(1)
            if not self.free:
                raise RuntimeError("paged KV cache out of blocks")
            b = self.free.pop()
            self.tables[slot, len(owned)] = b
            owned.append(b)
            self.refcounts[b] = 1
            self._bt_dirty.add(slot)
        self.peak_blocks = max(self.peak_blocks, self.allocated_blocks())

    def _drop_block(self, slot: int, idx: int) -> Optional[int]:
        """Decrement the refcount of ``slot``'s ``idx``-th block and clear its
        table entry; returns the block id if it just became free."""
        b = self._owned[slot][idx]
        self.tables[slot, idx] = TRASH_BLOCK
        self.refcounts[b] -= 1
        assert self.refcounts[b] >= 0, "refcount underflow"
        return b if self.refcounts[b] == 0 else None

    def _free_and_purge(self, freed: list) -> None:
        if not freed:
            return
        self.free.extend(freed)
        for b in freed:
            # a cached block is pinned by its node's own refcount, so it can
            # only hit zero after eviction already unmapped its node — a
            # freed block must never still be matchable in the radix cache
            assert b not in self._block_pins, "freed a registry-pinned block"

    def release(self, slot: int) -> None:
        freed = []
        for idx in reversed(range(len(self._owned[slot]))):
            b = self._drop_block(slot, idx)
            if b is not None:
                freed.append(b)
        self._free_and_purge(freed)
        self._owned[slot] = []
        self.tables[slot] = TRASH_BLOCK
        self.lens[slot] = 0
        self.watermarks[slot] = 0
        self._bt_dirty.add(slot)

    def rollback(self, slot: int, n_tokens: int) -> None:
        """Lens-only rollback: rewind ``slot``'s write position to
        ``n_tokens``, leaving its block ownership untouched.  This is the
        per-round speculative rollback — the admission reservation
        (prompt + max_new + spec headroom) holds for the request's whole
        lifetime, so rejected-draft blocks must NOT return to the shared
        free pool mid-flight (a later admission could claim them and the
        plain-decode fallback would write into trash).  Pool entries in
        ``[n_tokens, watermark)`` keep their garbage; the position masks
        hide them until a later write overwrites them."""
        assert n_tokens <= self.lens[slot] or n_tokens <= self.watermarks[slot]
        self.lens[slot] = n_tokens

    def truncate(self, slot: int, n_tokens: int) -> None:
        """Retire ``slot``'s capacity beyond ``n_tokens``: surplus blocks
        are dropped in reverse ownership order — LIFO-symmetric with
        ``allocate``, so undoing a just-made allocation restores the free
        list *exactly* (order included) — and ``lens`` resets.  Use
        :meth:`rollback` for the per-round speculative unwind (which must
        keep the admission reservation intact); ``truncate`` is for
        genuinely returning capacity."""
        need = self.blocks_needed(n_tokens)
        owned = self._owned[slot]
        freed = []
        while len(owned) > need:
            b = self._drop_block(slot, len(owned) - 1)
            owned.pop()
            if b is not None:
                freed.append(b)
        self._free_and_purge(freed)
        self.lens[slot] = n_tokens
        self._bt_dirty.add(slot)

    def live_tokens(self) -> int:
        return int(self.lens.sum())

    def allocated_blocks(self) -> int:
        return self.num_blocks - 1 - len(self.free)

    def kv_bytes_per_token(self) -> int:
        """HBM bytes one cached token costs across every seq-indexed pool
        (all layers; codes + scale pools).  Ring/recurrent leaves are
        excluded — they do not scale with live tokens.  This is the number
        the int8 pools cut ~4x (int8 codes + one fp32 scale per head-slot
        vs fp32 values) and int4 packing cuts further (two codes per
        byte)."""
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(self.pools)[0]:
            if _leaf_name(path) in POOL_KEYS:
                nb, bs = leaf.shape[1], leaf.shape[2]
                total += leaf.size * leaf.dtype.itemsize // (nb * bs)
        return total

    # -- copy-on-write ------------------------------------------------------

    def ensure_writable(self, slot: int, start: int, end: int) -> None:
        """Make the token span ``[start, end)`` of ``slot`` safe to write:
        any covered block with refcount > 1 (shared via ``adopt_prefix``) is
        replaced by a private copy before the jitted write ever sees the
        table.  All faulting blocks of one call are copied in a **single**
        batched gather/scatter per pool leaf (one pool-pytree rebuild, one
        dispatch — not one per block).  Also advances the slot's write
        watermark.  No-op for unshared spans."""
        if end <= start:
            return
        self.watermarks[slot] = max(int(self.watermarks[slot]), end)
        bs = self.block_size
        pairs: list[tuple[int, int]] = []
        # a megastep window preflight may name a span past the slot's table
        # (lens + N at the drain tail); positions beyond are never written
        # (the on-device mask parks finished rows in trash), so clamp rather
        # than index out of the table
        j_hi = min((end - 1) // bs, self.tables.shape[1] - 1)
        for j in range(start // bs, j_hi + 1):
            b = int(self.tables[slot, j])
            if b == TRASH_BLOCK or self.refcounts[b] <= 1:
                continue
            if not self.free:
                self.reclaim(1)
            if not self.free:
                raise RuntimeError("paged KV cache out of blocks for CoW copy")
            nb = self.free.pop()
            pairs.append((b, nb))
            self.refcounts[b] -= 1
            self.refcounts[nb] = 1
            self.tables[slot, j] = nb
            self._owned[slot][j] = nb
            self._bt_dirty.add(slot)
        if pairs:
            self._copy_blocks(pairs)
            self.cow_copies += len(pairs)
        self.peak_blocks = max(self.peak_blocks, self.allocated_blocks())

    def _copy_blocks(self, pairs: list) -> None:
        """Copy every (src, dst) block pair in one batched ``set`` per pool
        leaf.  Gathers read the pre-copy pool state (dst blocks are fresh
        off the free list, so no pair can observe another's write), and the
        whole batch costs ONE pool-pytree rebuild regardless of how many
        blocks faulted — ``pool_rebuilds`` witnesses that."""
        src = jnp.asarray([p[0] for p in pairs], jnp.int32)
        dst = jnp.asarray([p[1] for p in pairs], jnp.int32)

        def one(path, leaf):
            if _leaf_name(path) in POOL_KEYS:
                return leaf.at[:, dst].set(leaf[:, src])
            return leaf

        self.pools = jax.tree_util.tree_map_with_path(one, self.pools)
        self.pool_rebuilds += 1

    # -- prefix sharing -----------------------------------------------------

    def _touch(self, node: _RadixNode, hit: bool) -> None:
        self._radix_clock += 1
        node.last_used = self._radix_clock
        if hit:
            node.hits += 1

    def register_prefix(self, slot: int, tokens: np.ndarray, pinned: bool = False) -> None:
        """Publish ``slot``'s prompt blocks into the radix prompt cache.
        Each block wholly covered by the prompt becomes (or joins) a radix
        node keyed by its token chunk; new nodes pin the slot's own block
        with the node's refcount, existing nodes deduplicate (a second donor
        of an already-cached prefix pins nothing).  The chain stays servable
        after the donor releases — until LRU/cost eviction under block
        pressure or at the node cap.  ``pinned=True`` marks the whole chain
        permanent (the ``--pin-prompt`` system-preamble path): never evicted,
        not counted against the cap.

        Only blocks *wholly covered* by the prompt are listed: the donor
        writes at positions >= len(prompt) only, so it can never write into
        a fully-covered block — pinning a partial tail block would force
        the donor itself into a copy-on-write fault whose block demand no
        admission budget reserved (a mid-decode out-of-blocks crash under
        pressure).  CoW therefore only ever happens on the *adopter* side,
        whose worst case the admission gate already budgets."""
        if not self.fully_paged:
            return
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        n_full = tokens.size // self.block_size
        if n_full == 0 or tokens.size < 2:
            return  # nothing shareable below a full block / the len-1 cap
        cur = self._radix_root
        path = {id(cur)}
        for j in range(n_full):
            key = tuple(int(t) for t in tokens[j * self.block_size : (j + 1) * self.block_size])
            child = cur.children.get(key)
            if child is None:
                # cap applies to unpinned nodes; evict around the insertion
                # path so we never orphan the chain we are extending
                while not pinned and self._radix_unpinned >= self.max_prefix_entries:
                    if not self._evict_one(protect=path):
                        return  # everything else is pinned: stop inserting
                b = self._owned[slot][j]
                child = _RadixNode(key, b, cur, (j + 1) * self.block_size)
                child.pinned = pinned
                cur.children[key] = child
                self._block_pins[b] = self._block_pins.get(b, 0) + 1
                self.refcounts[b] += 1
                self._entry_rc[b] += 1
                self._radix_nodes += 1
                if not pinned:
                    self._radix_unpinned += 1
            elif pinned and not child.pinned:
                # pinning promotes the whole chain; a previously-unpinned
                # node leaves the cap accounting
                child.pinned = True
                self._radix_unpinned -= 1
            self._touch(child, hit=False)
            cur = child
            path.add(id(cur))

    def _evict_one(self, protect: Optional[set] = None) -> bool:
        """Evict the lowest-value evictable leaf: priority ``hits *
        covered_tokens`` (cost-aware — a hot long prefix beats a cold short
        one), ties broken least-recently-used.  Only leaves are evictable
        (children's chains extend their parents), pinned nodes never are,
        and ``protect`` shields an in-progress insertion path.  Returns
        whether a node was evicted; its block returns to the free list iff
        no live slot still owns it."""
        protect = protect or set()
        best = None
        stack = list(self._radix_root.children.values())
        while stack:
            node = stack.pop()
            if node.children:
                stack.extend(node.children.values())
                continue
            if node.pinned or id(node) in protect:
                continue
            score = (node.hits * node.depth_tokens, node.last_used)
            if best is None or score < best[0]:
                best = (score, node)
        if best is None:
            return False
        node = best[1]
        node.parent.children.pop(node.key)
        pins = self._block_pins[node.block] - 1
        if pins:
            self._block_pins[node.block] = pins
        else:
            del self._block_pins[node.block]
        self._radix_nodes -= 1
        self._radix_unpinned -= 1
        self._entry_rc[node.block] -= 1
        self.refcounts[node.block] -= 1
        assert self.refcounts[node.block] >= 0, "refcount underflow on eviction"
        if self.refcounts[node.block] == 0:
            self.free.append(node.block)
        return True

    def reclaim(self, need: int) -> None:
        """Evict prompt-cache nodes (lowest value first) until at least
        ``need`` blocks are free or only pinned chains remain — live
        sequences always win over cached prefixes."""
        while self.free_blocks < need and self._evict_one():
            pass

    def registry_size(self) -> int:
        """Number of cached radix nodes, pinned included."""
        return self._radix_nodes

    def registered_blocks(self) -> frozenset:
        """The block ids currently pinned by the prompt cache."""
        return frozenset(self._block_pins)

    def reclaimable_blocks(self) -> int:
        """Blocks a full ``reclaim`` would hand back: nodes in fully
        evictable subtrees (no pinned node at or below them — eviction is
        leaf-first, so a pinned descendant shields its ancestors) whose
        refcount is entirely the node's own pin.  The admission gate counts
        these as available capacity, so this must never overpromise."""

        def walk(node: _RadixNode) -> tuple[bool, int]:
            evictable, freed = True, 0
            for ch in node.children.values():
                ev, f = walk(ch)
                evictable &= ev
                freed += f
            evictable &= not node.pinned
            if evictable and self.refcounts[node.block] == self._entry_rc[node.block]:
                freed += 1
            return evictable, freed

        return sum(walk(ch)[1] for ch in self._radix_root.children.values())

    def lookup_prefix(self, tokens: np.ndarray) -> tuple[int, tuple[int, ...]]:
        """Longest cached common prefix of ``tokens``, capped at
        ``len(tokens) - 1`` (prefill must keep at least one token to produce
        logits from).  Descends the radix tree one full-block dict probe at
        a time — O(prompt / block_size), independent of how many prompts
        ever registered — then tries a *partial* match into the children of
        the deepest full-block node.  Returns ``(shared_tokens, block_run)``
        where the run covers the shared span — its last block may be partial
        (the adopter copy-on-writes it when its own tokens land there)."""
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        cap = tokens.size - 1
        cur = self._radix_root
        blocks: list[int] = []
        d = 0
        while (d + 1) * self.block_size <= cap:
            key = tuple(int(t) for t in tokens[d * self.block_size : (d + 1) * self.block_size])
            child = cur.children.get(key)
            if child is None:
                break
            self._touch(child, hit=True)
            blocks.append(child.block)
            cur = child
            d += 1
        shared = d * self.block_size
        # partial match into the next block: the cached chunk whose tokens
        # agree longest with the remaining span (ties: any maximal one)
        rest = tokens[shared:cap]
        if rest.size:
            best_m, best_child = 0, None
            for child in cur.children.values():
                key = np.asarray(child.key, np.int32)[: rest.size]
                neq = np.nonzero(rest[: key.size] != key)[0]
                m = int(neq[0]) if neq.size else key.size
                if m > best_m:
                    best_m, best_child = m, child
            if best_child is not None:
                self._touch(best_child, hit=True)
                blocks.append(best_child.block)
                shared += best_m
        return shared, tuple(blocks)

    def adopt_prefix(self, slot: int, shared_tokens: int, blocks) -> None:
        """Map a looked-up shared block run into an empty ``slot``: table
        entries point at the shared blocks (refcounts bumped), ``lens`` jumps
        to ``shared_tokens`` — the prompt prefix is served without recompute
        and without copies until a write forces CoW.  (The engine trims the
        lookup result to its chunk-aligned resume offset before adopting, so
        on block-aligned configs its prefill never writes into an adopted
        block at all — zero CoW on the admission path.)"""
        assert not self._owned[slot], "adopt_prefix needs an empty slot"
        for j, b in enumerate(blocks):
            self.tables[slot, j] = b
            self._owned[slot].append(b)
            self.refcounts[b] += 1
        self.lens[slot] = shared_tokens
        self.watermarks[slot] = shared_tokens
        self.prefix_hits += 1
        self.prefix_hit_tokens += shared_tokens
        self._bt_dirty.add(slot)
        self.peak_blocks = max(self.peak_blocks, self.allocated_blocks())

    # -- per-slot state (recurrent / ring leaves) ---------------------------

    def reset_slot(self, slot: int) -> None:
        """Zero ``slot``'s rows of every per-slot (non-pool) leaf, so a fresh
        sequence starts from empty ring (``kpos = -1``) and zero recurrent
        state regardless of what the slot's previous occupant left behind."""

        def one(path, leaf):
            name = _leaf_name(path)
            if name in POOL_KEYS:
                return leaf
            return leaf.at[:, slot].set(-1 if name == "kpos" else 0)

        self.pools = jax.tree_util.tree_map_with_path(one, self.pools)

    def slice_slot(self, slot: int) -> dict:
        """B=1 cache view for an isolated per-slot prefill: pools whole (the
        slot's blocks live there), per-slot leaves sliced to the single row.
        Pair with ``bt_row(slot)`` for the matching block-table view."""

        def one(path, leaf):
            if _leaf_name(path) in POOL_KEYS:
                return leaf
            return leaf[:, slot : slot + 1]

        return jax.tree_util.tree_map_with_path(one, self.pools)

    def merge_slot(self, slot: int, new_pools: dict) -> None:
        """Fold a B=1 prefill result back: pool leaves replace wholesale,
        per-slot leaves write their single row into ``slot``."""

        def one(path, old, new):
            if _leaf_name(path) in POOL_KEYS:
                return new
            if old.shape[1] == new.shape[1]:
                # single-slot engine: the B=1 "slice" was the whole leaf (jax
                # returns the original buffer for full slices, which the jit
                # call then donated) — the result replaces it wholesale
                return new
            return old.at[:, slot].set(new[:, 0])

        self.pools = jax.tree_util.tree_map_with_path(one, self.pools, new_pools)

    # -- device view --------------------------------------------------------

    def bt(self) -> jnp.ndarray:
        """Full block table ``(slots, MB)`` as a device array.  Tables only
        change at allocate/release/adopt/CoW, and each of those marks just
        its own row dirty — so the per-tick call patches the touched rows
        in place (one scatter per round, ``bt_row_patches``) instead of
        re-uploading the whole table (``bt_full_uploads``, first call
        only)."""
        if self._bt_dev is None:
            self._bt_dev = jnp.asarray(self.tables)
            self._bt_dirty.clear()
            self.bt_full_uploads += 1
        elif self._bt_dirty:
            rows = np.array(sorted(self._bt_dirty), np.int32)
            self._bt_dev = self._bt_dev.at[jnp.asarray(rows)].set(
                jnp.asarray(self.tables[rows])
            )
            self.bt_row_patches += 1
            self._bt_dirty.clear()
        return self._bt_dev

    def bt_row(self, slot: int) -> jnp.ndarray:
        """Single-row block-table view ``(1, MB)`` matching ``slice_slot``."""
        return jnp.asarray(self.tables[slot : slot + 1])

    def attach(self) -> dict:
        """Full-batch cache tree for ``apply_lm``: pools + block-table view."""
        return {**self.pools, "_paged": {"bt": self.bt()}}

    def device_state(self) -> dict:
        """Host bookkeeping as device arrays for multi-host serving: the
        block table plus refcounts (``rc``, block axis — local like the
        pools) and write watermarks (``wm``, slot axis — rides with the
        batch).  ``dist.sharding.cache_specs`` knows these leaves."""
        return {
            "bt": self.bt(),
            "rc": jnp.asarray(self.refcounts),
            "wm": jnp.asarray(self.watermarks),
        }

    # -- KV-block migration (prefill/decode disaggregation) ------------------

    def _migration_guard(self) -> None:
        if not self.fully_paged:
            raise ValueError(
                "KV-block migration needs a fully paged cache (no ring / "
                "recurrent per-slot leaves); this arch keeps per-slot state "
                "outside the block pools"
            )

    def export_blocks(self, slot: int) -> dict:
        """Serialize ``slot``'s written KV into a host-side wire payload: one
        gathered array per pool leaf (codes at storage width — int8 codes as
        int8, packed int4 as uint8 nibble pairs, scale pools as fp32) for
        the blocks covering ``lens[slot]`` tokens, plus the geometry needed
        to validate adoption.  The slot keeps its blocks — export is a read.
        This is the prefill→decode transfer unit of the disaggregated
        cluster: a decode replica feeds the payload to
        :meth:`import_blocks` and resumes at position ``tokens`` without
        recomputing the prompt."""
        self._migration_guard()
        n_tok = int(self.lens[slot])
        if n_tok <= 0:
            raise ValueError(f"slot {slot} has no written tokens to export")
        need = self.blocks_needed(n_tok)
        ids = jnp.asarray(self._owned[slot][:need], jnp.int32)
        leaves = {}
        for path, leaf in jax.tree_util.tree_flatten_with_path(self.pools)[0]:
            if _leaf_name(path) in POOL_KEYS:
                leaves[jax.tree_util.keystr(path)] = np.asarray(leaf[:, ids])
        nbytes = sum(a.nbytes for a in leaves.values())
        self.migrated_blocks_out += need
        self.migration_bytes_out += nbytes
        return {
            "tokens": n_tok,
            "n_blocks": need,
            "block_size": self.block_size,
            "kv_quant": self.kv_quant,
            "kv_bits": self.kv_bits,
            "leaves": leaves,
        }

    def import_blocks(self, slot: int, payload: dict) -> None:
        """Adopt an exported payload into an empty ``slot``: allocate fresh
        blocks for its token span, scatter every wire leaf into the local
        pools (one batched set per leaf, one pool-pytree rebuild total),
        and set ``lens``/``watermark`` so decode resumes at position
        ``tokens``.  Geometry (block size, KV quantization, per-leaf dtype
        and shape) must match the exporting cache — migration never
        re-quantizes, so int8/int4 codes land bit-identical."""
        self._migration_guard()
        for field in ("block_size", "kv_quant", "kv_bits"):
            if payload[field] != getattr(self, field):
                raise ValueError(
                    f"migration geometry mismatch: {field}="
                    f"{payload[field]!r} vs local {getattr(self, field)!r}"
                )
        assert not self._owned[slot], "import_blocks needs an empty slot"
        n_tok = int(payload["tokens"])
        self.allocate(slot, n_tok)
        ids = self._owned[slot]
        assert len(ids) == payload["n_blocks"], "block count / geometry skew"
        idx = jnp.asarray(ids, jnp.int32)
        leaves = dict(payload["leaves"])

        def one(path, leaf):
            if _leaf_name(path) not in POOL_KEYS:
                return leaf
            arr = leaves.pop(jax.tree_util.keystr(path))
            want = (leaf.shape[0], len(ids)) + leaf.shape[2:]
            if arr.dtype != leaf.dtype or arr.shape != want:
                raise ValueError(
                    f"migration leaf mismatch at {jax.tree_util.keystr(path)}: "
                    f"got {arr.dtype}{arr.shape}, want {leaf.dtype}{want}"
                )
            return leaf.at[:, idx].set(jnp.asarray(arr))

        self.pools = jax.tree_util.tree_map_with_path(one, self.pools)
        if leaves:
            raise ValueError(f"payload has leaves unknown here: {sorted(leaves)}")
        self.pool_rebuilds += 1
        self.lens[slot] = n_tok
        self.watermarks[slot] = n_tok
        self.migrated_blocks_in += len(ids)
        self.migration_bytes_in += sum(a.nbytes for a in payload["leaves"].values())
