"""Paged KV cache: fixed-size token blocks + per-sequence block tables.

Instead of pinning every serve slot to a contiguous ``max_seq`` cache lane
(``models/lm.init_cache``: memory = ``batch x max_seq`` regardless of load),
seq-indexed K/V lives in *pools* of ``block_size``-token blocks shared by all
slots.  A host-side free-list allocator hands each sequence just the blocks
its tokens need, recorded in a per-slot *block table*; releasing a finished
sequence returns its blocks immediately.  Cache memory therefore scales with
live tokens, which is what lets a fixed memory budget admit many more mixed-
length requests (the vLLM insight, composed here with the A2Q int8 artifact).

Layout per stack (leading dim = layer count, exactly like
``init_stack_cache``):

* full-attention GQA   — ``kp``/``vp``: ``(count, NB, bs, KV, Dh)`` pools;
* MLA                  — ``ckvp``/``kpep``: ``(count, NB, bs, rank)`` pools
  (the compressed latent is seq-indexed and pages the same way);
* sliding-window / chunked-local — the existing *ring* cache (already bounded
  by the window, nothing to page) stays per-slot contiguous;
* recurrent state (rwkv6 / mamba shift + S) — O(1) per slot, per-slot rows.

Block 0 of every pool is the reserved **trash block**: the block tables of
dead slots point at it, so a full-batch decode step can include dead rows
(they scatter into trash and attend garbage that is never read).

``kv_quant=True`` stores the seq-indexed pools as **int8 codes** next to
per-slot fp32 *scale pools* (``kps``/``vps`` for GQA — one scalar per
token-slot per KV head; ``ckvs``/``kpes`` for MLA — one per token-slot),
laid out in the same block geometry and gathered through the same table.
K/V are quantized on write (``nn/attention._paged_write_q8``) and
dequantized on read — in-register inside the Pallas decode kernel — so the
seq-indexed KV HBM footprint drops ~4x (int8 + one fp32 scale per head-slot
vs fp32 values): ~4x more live tokens per pool, ~4x less decode bandwidth.
Ring and recurrent leaves are already O(window)/O(1) and stay float.

All layers share one block table — block ``b`` holds the same token span in
every layer's pool — so the allocator runs once per sequence, not per layer.
The device-facing view is attached to the cache tree under the reserved key
``"_paged"`` (consumed by ``models/lm.apply_lm``).

Invariants the allocator maintains:
* a sequence's blocks appear in its table row in logical order, so the
  gathered view equals the contiguous layout bit-for-bit;
* live slots never share a block; unowned table entries stay 0 (trash);
* ``lens[slot]`` counts tokens written for the slot (its next write position).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, AttnConfig, StackConfig
from repro.nn.attention import init_attn_cache

__all__ = [
    "PagedKVCache", "init_paged_stack_cache", "POOL_KEYS", "SCALE_KEYS", "TRASH_BLOCK",
]

# Leaves indexed (count, NB, bs, ...) — everything else is (count, B, ...).
# SCALE_KEYS are the per-slot fp32 scale pools that ride along with int8
# code pools (kv_quant=True); they are block-indexed like any other pool.
SCALE_KEYS = frozenset({"kps", "vps", "ckvs", "kpes"})
POOL_KEYS = frozenset({"kp", "vp", "ckvp", "kpep"}) | SCALE_KEYS
TRASH_BLOCK = 0


def _leaf_name(path) -> Optional[str]:
    keys = [k.key for k in path if hasattr(k, "key")]
    return keys[-1] if keys else None


def init_paged_attn_cache(
    a: AttnConfig, slots: int, num_blocks: int, block_size: int, max_seq: int, dtype,
    kv_quant: bool = False,
) -> dict:
    """Paged cache for one attention layer; ring layers keep their bounded
    per-slot layout (a window-sized ring is already token-proportional).
    ``kv_quant``: int8 code pools + per-slot fp32 scale pools."""
    if a.kind == "mla":
        if kv_quant:
            return {
                "ckvp": jnp.zeros((num_blocks, block_size, a.kv_lora_rank), jnp.int8),
                "ckvs": jnp.zeros((num_blocks, block_size), jnp.float32),
                "kpep": jnp.zeros((num_blocks, block_size, a.qk_rope_dim), jnp.int8),
                "kpes": jnp.zeros((num_blocks, block_size), jnp.float32),
            }
        return {
            "ckvp": jnp.zeros((num_blocks, block_size, a.kv_lora_rank), dtype),
            "kpep": jnp.zeros((num_blocks, block_size, a.qk_rope_dim), dtype),
        }
    if (a.window or a.chunk) is not None:
        return init_attn_cache(slots, a, max_seq, dtype)
    if kv_quant:
        return {
            "kp": jnp.zeros((num_blocks, block_size, a.kv_heads, a.head_dim), jnp.int8),
            "kps": jnp.zeros((num_blocks, block_size, a.kv_heads), jnp.float32),
            "vp": jnp.zeros((num_blocks, block_size, a.kv_heads, a.head_dim), jnp.int8),
            "vps": jnp.zeros((num_blocks, block_size, a.kv_heads), jnp.float32),
        }
    return {
        "kp": jnp.zeros((num_blocks, block_size, a.kv_heads, a.head_dim), dtype),
        "vp": jnp.zeros((num_blocks, block_size, a.kv_heads, a.head_dim), dtype),
    }


def init_paged_stack_cache(
    arch: ArchConfig, s: StackConfig, slots: int, num_blocks: int, block_size: int,
    max_seq: int, dtype, kv_quant: bool = False,
):
    """Paged twin of ``nn.transformer.init_stack_cache`` (leading ``count``)."""
    d = arch.d_model

    def one():
        if s.kind in ("attn_mlp", "moe"):
            return {"attn": init_paged_attn_cache(s.attn, slots, num_blocks, block_size, max_seq, dtype, kv_quant)}
        if s.kind == "rwkv6":
            H = d // s.ssm.head_dim
            return {
                "tm": {
                    "S": jnp.zeros((slots, H, s.ssm.head_dim, s.ssm.head_dim), jnp.float32),
                    "shift": jnp.zeros((slots, 1, d), dtype),
                },
                "cm": {"shift": jnp.zeros((slots, 1, d), dtype)},
            }
        if s.kind == "hymba":
            H = d // s.ssm.head_dim
            return {
                "attn": init_paged_attn_cache(s.attn, slots, num_blocks, block_size, max_seq, dtype, kv_quant),
                "mamba": {"S": jnp.zeros((slots, H, s.ssm.head_dim, s.ssm.state_dim), jnp.float32)},
            }
        raise ValueError(s.kind)

    cache = one()
    return jax.tree.map(lambda a_: jnp.broadcast_to(a_[None], (s.count, *a_.shape)), cache)


class PagedKVCache:
    """Device pools + host-side block-table allocator for ``slots`` sequences."""

    def __init__(
        self,
        arch: ArchConfig,
        slots: int,
        *,
        block_size: int = 16,
        num_blocks: Optional[int] = None,
        max_seq: int = 512,
        dtype=jnp.bfloat16,
        kv_quant: bool = False,
    ):
        self.arch = arch
        self.slots = slots
        self.block_size = block_size
        self.kv_quant = kv_quant
        self.max_seq = max_seq
        self.max_blocks_per_seq = -(-max_seq // block_size)
        if num_blocks is None:
            # worst case every slot runs to max_seq, plus the trash block
            num_blocks = slots * self.max_blocks_per_seq + 1
        if num_blocks < 2:
            raise ValueError("need at least one non-trash block")
        self.num_blocks = num_blocks
        self.pools = {
            str(i): init_paged_stack_cache(
                arch, s, slots, num_blocks, block_size, max_seq, dtype, kv_quant
            )
            for i, s in enumerate(arch.stacks)
        }
        # LIFO free list; low ids handed out first so fresh tables are ordered
        self.free = list(range(num_blocks - 1, TRASH_BLOCK, -1))
        self.tables = np.zeros((slots, self.max_blocks_per_seq), np.int32)
        self.lens = np.zeros((slots,), np.int32)
        self._owned: list[list[int]] = [[] for _ in range(slots)]
        self.peak_blocks = 0  # high-water mark of simultaneously owned blocks
        self._bt_dev = None  # device copy of tables; invalidated on mutation

    # -- allocator ----------------------------------------------------------

    def blocks_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    @property
    def free_blocks(self) -> int:
        return len(self.free)

    def can_allocate(self, n_tokens: int) -> bool:
        return self.blocks_needed(n_tokens) <= len(self.free)

    def allocate(self, slot: int, n_tokens: int) -> None:
        """Grow ``slot``'s table to cover ``n_tokens`` total tokens."""
        need = self.blocks_needed(n_tokens)
        if need > self.max_blocks_per_seq:
            raise ValueError(
                f"sequence of {n_tokens} tokens exceeds max_seq={self.max_seq}"
            )
        owned = self._owned[slot]
        while len(owned) < need:
            if not self.free:
                raise RuntimeError("paged KV cache out of blocks")
            b = self.free.pop()
            self.tables[slot, len(owned)] = b
            owned.append(b)
            self._bt_dev = None
        self.peak_blocks = max(self.peak_blocks, self.allocated_blocks())

    def release(self, slot: int) -> None:
        self.free.extend(reversed(self._owned[slot]))
        self._owned[slot] = []
        self.tables[slot] = TRASH_BLOCK
        self.lens[slot] = 0
        self._bt_dev = None

    def live_tokens(self) -> int:
        return int(self.lens.sum())

    def allocated_blocks(self) -> int:
        return self.num_blocks - 1 - len(self.free)

    def kv_bytes_per_token(self) -> int:
        """HBM bytes one cached token costs across every seq-indexed pool
        (all layers; codes + scale pools).  Ring/recurrent leaves are
        excluded — they do not scale with live tokens.  This is the number
        the int8 pools cut ~4x (int8 codes + one fp32 scale per head-slot
        vs fp32 values)."""
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(self.pools)[0]:
            if _leaf_name(path) in POOL_KEYS:
                nb, bs = leaf.shape[1], leaf.shape[2]
                total += leaf.size * leaf.dtype.itemsize // (nb * bs)
        return total

    # -- per-slot state (recurrent / ring leaves) ---------------------------

    def reset_slot(self, slot: int) -> None:
        """Zero ``slot``'s rows of every per-slot (non-pool) leaf, so a fresh
        sequence starts from empty ring (``kpos = -1``) and zero recurrent
        state regardless of what the slot's previous occupant left behind."""

        def one(path, leaf):
            name = _leaf_name(path)
            if name in POOL_KEYS:
                return leaf
            return leaf.at[:, slot].set(-1 if name == "kpos" else 0)

        self.pools = jax.tree_util.tree_map_with_path(one, self.pools)

    def slice_slot(self, slot: int) -> dict:
        """B=1 cache view for an isolated per-slot prefill: pools whole (the
        slot's blocks live there), per-slot leaves sliced to the single row.
        Pair with ``bt_row(slot)`` for the matching block-table view."""

        def one(path, leaf):
            if _leaf_name(path) in POOL_KEYS:
                return leaf
            return leaf[:, slot : slot + 1]

        return jax.tree_util.tree_map_with_path(one, self.pools)

    def merge_slot(self, slot: int, new_pools: dict) -> None:
        """Fold a B=1 prefill result back: pool leaves replace wholesale,
        per-slot leaves write their single row into ``slot``."""

        def one(path, old, new):
            if _leaf_name(path) in POOL_KEYS:
                return new
            if old.shape[1] == new.shape[1]:
                # single-slot engine: the B=1 "slice" was the whole leaf (jax
                # returns the original buffer for full slices, which the jit
                # call then donated) — the result replaces it wholesale
                return new
            return old.at[:, slot].set(new[:, 0])

        self.pools = jax.tree_util.tree_map_with_path(one, self.pools, new_pools)

    # -- device view --------------------------------------------------------

    def bt(self) -> jnp.ndarray:
        """Full block table ``(slots, MB)`` as a device array.  Tables only
        change at allocate/release, so the decode loop's per-tick call reuses
        one upload between admissions."""
        if self._bt_dev is None:
            self._bt_dev = jnp.asarray(self.tables)
        return self._bt_dev

    def bt_row(self, slot: int) -> jnp.ndarray:
        """Single-row block-table view ``(1, MB)`` matching ``slice_slot``."""
        return jnp.asarray(self.tables[slot : slot + 1])

    def attach(self) -> dict:
        """Full-batch cache tree for ``apply_lm``: pools + block-table view."""
        return {**self.pools, "_paged": {"bt": self.bt()}}
