"""Paged KV cache: fixed-size token blocks + per-sequence block tables.

Instead of pinning every serve slot to a contiguous ``max_seq`` cache lane
(``models/lm.init_cache``: memory = ``batch x max_seq`` regardless of load),
seq-indexed K/V lives in *pools* of ``block_size``-token blocks shared by all
slots.  A host-side free-list allocator hands each sequence just the blocks
its tokens need, recorded in a per-slot *block table*; releasing a finished
sequence returns its blocks immediately.  Cache memory therefore scales with
live tokens, which is what lets a fixed memory budget admit many more mixed-
length requests (the vLLM insight, composed here with the A2Q int8 artifact).

Layout per stack (leading dim = layer count, exactly like
``init_stack_cache``):

* full-attention GQA   — ``kp``/``vp``: ``(count, NB, bs, KV, Dh)`` pools;
* MLA                  — ``ckvp``/``kpep``: ``(count, NB, bs, rank)`` pools
  (the compressed latent is seq-indexed and pages the same way);
* sliding-window / chunked-local — the existing *ring* cache (already bounded
  by the window, nothing to page) stays per-slot contiguous;
* recurrent state (rwkv6 / mamba shift + S) — O(1) per slot, per-slot rows.

Block 0 of every pool is the reserved **trash block**: the block tables of
dead slots point at it, so a full-batch decode step can include dead rows
(they scatter into trash and attend garbage that is never read).

``kv_quant=True`` stores the seq-indexed pools as **integer codes** next to
per-slot fp32 *scale pools* (``kps``/``vps`` for GQA — one scalar per
token-slot per KV head; ``ckvs``/``kpes`` for MLA — one per token-slot),
laid out in the same block geometry and gathered through the same table.
``kv_bits=8`` (default) stores int8 codes; ``kv_bits=4`` packs two 4-bit
codes per byte (uint8 pools of half the feature width — the scale-pool
machinery is unchanged, ``SCALE_KEYS`` still ⊂ ``POOL_KEYS``).  K/V are
quantized on write (``nn/attention._paged_write_q8``) and dequantized on
read — in-register inside the Pallas decode kernel for int8 — so the
seq-indexed KV HBM footprint drops ~4x at 8 bits and ~6-7x at 4 bits.
Ring and recurrent leaves are already O(window)/O(1) and stay float.

All layers share one block table — block ``b`` holds the same token span in
every layer's pool — so the allocator runs once per sequence, not per layer.
The device-facing view is attached to the cache tree under the reserved key
``"_paged"`` (consumed by ``models/lm.apply_lm``).

Sharing and rollback (the speculative-decoding / prefix-sharing substrate):

* every block carries a **refcount**; fresh allocations start at 1, prefix
  adoption (``adopt_prefix``) increments, release/truncate decrement, and a
  block returns to the free list only at refcount zero;
* **copy-on-write**: before any jitted write into a token span the engine
  calls ``ensure_writable(slot, start, end)`` — any covered block with
  refcount > 1 is replaced by a private device-side copy, so a shared
  block's other readers never observe the write;
* **watermarks + truncate**: ``watermarks[slot]`` records the high-water
  write position (set by ``ensure_writable``); ``truncate(slot, n)`` rolls
  a slot back to ``n`` tokens — surplus blocks are dropped in reverse
  ownership order (refcounted, freed at zero) so undoing a speculative
  round restores the allocator state *exactly* (LIFO-symmetric with
  ``allocate``), and stale pool entries past ``lens`` are masked by the
  position arithmetic until overwritten;
* a host-side **prefix registry** maps registered prompts to their block
  runs: ``lookup_prefix`` finds the longest common prefix (capped at
  ``len(prompt) - 1`` so prefill always has at least one token to produce
  logits from) and ``adopt_prefix`` maps those blocks — including a partial
  tail block — into a new slot for free.  Registration takes its own
  refcount on every listed block, so a registered prefix outlives the
  sequence that produced it (the common-prompt payoff: later requests hit
  even after the donor finished); entries are evicted FIFO under block
  pressure (``reclaim``) or at the entry cap, and the pin guarantees a
  registered block can never be freed-and-recycled out from under its
  entry (asserted in ``_free_and_purge``) — stale-KV matches are
  structurally impossible.

Invariants the allocator maintains:
* a sequence's blocks appear in its table row in logical order, so the
  gathered view equals the contiguous layout bit-for-bit;
* live slots share a block only while every sharer treats it read-only
  (refcount > 1 ⇒ copy-on-write before any write); unowned table entries
  stay 0 (trash); the trash block is never refcounted and never freed;
* ``lens[slot]`` counts tokens written for the slot (its next write
  position); ``watermarks[slot] >= lens[slot]`` bounds where garbage from
  rolled-back writes may sit.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, AttnConfig, StackConfig
from repro.nn.attention import init_attn_cache

__all__ = [
    "PagedKVCache", "init_paged_stack_cache", "POOL_KEYS", "SCALE_KEYS", "TRASH_BLOCK",
]

# Leaves indexed (count, NB, bs, ...) — everything else is (count, B, ...).
# SCALE_KEYS are the per-slot fp32 scale pools that ride along with integer
# code pools (kv_quant=True); they are block-indexed like any other pool.
SCALE_KEYS = frozenset({"kps", "vps", "ckvs", "kpes"})
POOL_KEYS = frozenset({"kp", "vp", "ckvp", "kpep"}) | SCALE_KEYS
TRASH_BLOCK = 0


def _leaf_name(path) -> Optional[str]:
    keys = [k.key for k in path if hasattr(k, "key")]
    return keys[-1] if keys else None


def _code_shape(dim: int, kv_bits: int) -> tuple[int, ...]:
    """Feature width of a quantized code pool: int8 keeps the width, int4
    packs two codes per byte (requires an even feature dim)."""
    if kv_bits == 8:
        return (dim,)
    if kv_bits == 4:
        if dim % 2:
            raise ValueError(f"int4 KV packing needs an even feature dim, got {dim}")
        return (dim // 2,)
    raise ValueError(f"kv_bits must be 8 or 4, got {kv_bits}")


def init_paged_attn_cache(
    a: AttnConfig, slots: int, num_blocks: int, block_size: int, max_seq: int, dtype,
    kv_quant: bool = False, kv_bits: int = 8,
) -> dict:
    """Paged cache for one attention layer; ring layers keep their bounded
    per-slot layout (a window-sized ring is already token-proportional).
    ``kv_quant``: integer code pools + per-slot fp32 scale pools —
    ``kv_bits=8`` int8 codes, ``kv_bits=4`` two-per-byte packed uint8."""
    code_dtype = jnp.int8 if kv_bits == 8 else jnp.uint8
    if a.kind == "mla":
        if kv_quant:
            return {
                "ckvp": jnp.zeros((num_blocks, block_size, *_code_shape(a.kv_lora_rank, kv_bits)), code_dtype),
                "ckvs": jnp.zeros((num_blocks, block_size), jnp.float32),
                "kpep": jnp.zeros((num_blocks, block_size, *_code_shape(a.qk_rope_dim, kv_bits)), code_dtype),
                "kpes": jnp.zeros((num_blocks, block_size), jnp.float32),
            }
        return {
            "ckvp": jnp.zeros((num_blocks, block_size, a.kv_lora_rank), dtype),
            "kpep": jnp.zeros((num_blocks, block_size, a.qk_rope_dim), dtype),
        }
    if (a.window or a.chunk) is not None:
        return init_attn_cache(slots, a, max_seq, dtype)
    if kv_quant:
        return {
            "kp": jnp.zeros((num_blocks, block_size, a.kv_heads, *_code_shape(a.head_dim, kv_bits)), code_dtype),
            "kps": jnp.zeros((num_blocks, block_size, a.kv_heads), jnp.float32),
            "vp": jnp.zeros((num_blocks, block_size, a.kv_heads, *_code_shape(a.head_dim, kv_bits)), code_dtype),
            "vps": jnp.zeros((num_blocks, block_size, a.kv_heads), jnp.float32),
        }
    return {
        "kp": jnp.zeros((num_blocks, block_size, a.kv_heads, a.head_dim), dtype),
        "vp": jnp.zeros((num_blocks, block_size, a.kv_heads, a.head_dim), dtype),
    }


def init_paged_stack_cache(
    arch: ArchConfig, s: StackConfig, slots: int, num_blocks: int, block_size: int,
    max_seq: int, dtype, kv_quant: bool = False, kv_bits: int = 8,
):
    """Paged twin of ``nn.transformer.init_stack_cache`` (leading ``count``)."""
    d = arch.d_model

    def one():
        if s.kind in ("attn_mlp", "moe"):
            return {"attn": init_paged_attn_cache(s.attn, slots, num_blocks, block_size, max_seq, dtype, kv_quant, kv_bits)}
        if s.kind == "rwkv6":
            H = d // s.ssm.head_dim
            return {
                "tm": {
                    "S": jnp.zeros((slots, H, s.ssm.head_dim, s.ssm.head_dim), jnp.float32),
                    "shift": jnp.zeros((slots, 1, d), dtype),
                },
                "cm": {"shift": jnp.zeros((slots, 1, d), dtype)},
            }
        if s.kind == "hymba":
            H = d // s.ssm.head_dim
            return {
                "attn": init_paged_attn_cache(s.attn, slots, num_blocks, block_size, max_seq, dtype, kv_quant, kv_bits),
                "mamba": {"S": jnp.zeros((slots, H, s.ssm.head_dim, s.ssm.state_dim), jnp.float32)},
            }
        raise ValueError(s.kind)

    cache = one()
    return jax.tree.map(lambda a_: jnp.broadcast_to(a_[None], (s.count, *a_.shape)), cache)


class PagedKVCache:
    """Device pools + host-side block-table allocator for ``slots`` sequences."""

    def __init__(
        self,
        arch: ArchConfig,
        slots: int,
        *,
        block_size: int = 16,
        num_blocks: Optional[int] = None,
        max_seq: int = 512,
        dtype=jnp.bfloat16,
        kv_quant: bool = False,
        kv_bits: int = 8,
        max_prefix_entries: int = 32,
    ):
        if kv_bits not in (8, 4):
            raise ValueError(f"kv_bits must be 8 or 4, got {kv_bits}")
        self.arch = arch
        self.slots = slots
        self.block_size = block_size
        self.kv_quant = kv_quant
        self.kv_bits = kv_bits if kv_quant else 8
        self.max_seq = max_seq
        self.max_blocks_per_seq = -(-max_seq // block_size)
        if num_blocks is None:
            # worst case every slot runs to max_seq, plus the trash block
            num_blocks = slots * self.max_blocks_per_seq + 1
        if num_blocks < 2:
            raise ValueError("need at least one non-trash block")
        self.num_blocks = num_blocks
        self.pools = {
            str(i): init_paged_stack_cache(
                arch, s, slots, num_blocks, block_size, max_seq, dtype, kv_quant, kv_bits
            )
            for i, s in enumerate(arch.stacks)
        }
        # LIFO free list; low ids handed out first so fresh tables are ordered
        self.free = list(range(num_blocks - 1, TRASH_BLOCK, -1))
        self.tables = np.zeros((slots, self.max_blocks_per_seq), np.int32)
        self.lens = np.zeros((slots,), np.int32)
        # high-water write position per slot: truncate() rolls lens back but
        # leaves the watermark — the span [lens, watermark) may hold garbage
        # from rejected speculative writes, masked until overwritten
        self.watermarks = np.zeros((slots,), np.int32)
        self._owned: list[list[int]] = [[] for _ in range(slots)]
        # block refcounts: fresh allocation = 1, prefix adoption increments,
        # release/truncate decrement, free list entry iff 0.  The trash block
        # is never refcounted (rc[TRASH_BLOCK] stays 0 and it is never freed).
        self.refcounts = np.zeros((num_blocks,), np.int32)
        self.peak_blocks = 0  # high-water mark of simultaneously owned blocks
        self.cow_copies = 0  # copy-on-write block copies performed
        self.prefix_hits = 0  # admissions that adopted a shared prefix
        self.prefix_hit_tokens = 0  # prompt tokens served from shared blocks
        # prefix registry: eid -> (prompt token array, block run covering it),
        # insertion-ordered for FIFO eviction; registration pins each listed
        # block with its own refcount (tracked in _entry_rc) so prefixes
        # outlive their donor sequence; reverse map block -> eids for eager
        # purge if a block is ever freed out from under an entry
        self.max_prefix_entries = max_prefix_entries
        self._prefix_entries: dict[int, tuple[np.ndarray, tuple[int, ...]]] = {}
        self._block_eids: dict[int, set] = {}
        self._entry_rc = np.zeros((num_blocks,), np.int32)
        self._next_eid = 0
        self._bt_dev = None  # device copy of tables; invalidated on mutation
        # all seq-indexed state lives in pools (no ring / recurrent per-slot
        # leaves) — the precondition for prefix sharing and spec rollback
        names = {
            _leaf_name(p)
            for p, _ in jax.tree_util.tree_flatten_with_path(self.pools)[0]
        }
        self.fully_paged = names <= POOL_KEYS

    # -- allocator ----------------------------------------------------------

    def blocks_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    @property
    def free_blocks(self) -> int:
        return len(self.free)

    def can_allocate(self, n_tokens: int) -> bool:
        return self.blocks_needed(n_tokens) <= len(self.free)

    def allocate(self, slot: int, n_tokens: int) -> None:
        """Grow ``slot``'s table to cover ``n_tokens`` total tokens."""
        need = self.blocks_needed(n_tokens)
        if need > self.max_blocks_per_seq:
            raise ValueError(
                f"sequence of {n_tokens} tokens exceeds max_seq={self.max_seq}"
            )
        owned = self._owned[slot]
        while len(owned) < need:
            if not self.free:
                self.reclaim(1)
            if not self.free:
                raise RuntimeError("paged KV cache out of blocks")
            b = self.free.pop()
            self.tables[slot, len(owned)] = b
            owned.append(b)
            self.refcounts[b] = 1
            self._bt_dev = None
        self.peak_blocks = max(self.peak_blocks, self.allocated_blocks())

    def _drop_block(self, slot: int, idx: int) -> Optional[int]:
        """Decrement the refcount of ``slot``'s ``idx``-th block and clear its
        table entry; returns the block id if it just became free."""
        b = self._owned[slot][idx]
        self.tables[slot, idx] = TRASH_BLOCK
        self.refcounts[b] -= 1
        assert self.refcounts[b] >= 0, "refcount underflow"
        return b if self.refcounts[b] == 0 else None

    def _free_and_purge(self, freed: list) -> None:
        if not freed:
            return
        self.free.extend(freed)
        for b in freed:
            # a registered block is pinned by its entry's own refcount, so
            # it can only hit zero after _evict_entry already unmapped it —
            # a freed block must never still be matchable in the registry
            assert b not in self._block_eids, "freed a registry-pinned block"

    def release(self, slot: int) -> None:
        freed = []
        for idx in reversed(range(len(self._owned[slot]))):
            b = self._drop_block(slot, idx)
            if b is not None:
                freed.append(b)
        self._free_and_purge(freed)
        self._owned[slot] = []
        self.tables[slot] = TRASH_BLOCK
        self.lens[slot] = 0
        self.watermarks[slot] = 0
        self._bt_dev = None

    def rollback(self, slot: int, n_tokens: int) -> None:
        """Lens-only rollback: rewind ``slot``'s write position to
        ``n_tokens``, leaving its block ownership untouched.  This is the
        per-round speculative rollback — the admission reservation
        (prompt + max_new + spec headroom) holds for the request's whole
        lifetime, so rejected-draft blocks must NOT return to the shared
        free pool mid-flight (a later admission could claim them and the
        plain-decode fallback would write into trash).  Pool entries in
        ``[n_tokens, watermark)`` keep their garbage; the position masks
        hide them until a later write overwrites them."""
        assert n_tokens <= self.lens[slot] or n_tokens <= self.watermarks[slot]
        self.lens[slot] = n_tokens

    def truncate(self, slot: int, n_tokens: int) -> None:
        """Retire ``slot``'s capacity beyond ``n_tokens``: surplus blocks
        are dropped in reverse ownership order — LIFO-symmetric with
        ``allocate``, so undoing a just-made allocation restores the free
        list *exactly* (order included) — and ``lens`` resets.  Use
        :meth:`rollback` for the per-round speculative unwind (which must
        keep the admission reservation intact); ``truncate`` is for
        genuinely returning capacity."""
        need = self.blocks_needed(n_tokens)
        owned = self._owned[slot]
        freed = []
        while len(owned) > need:
            b = self._drop_block(slot, len(owned) - 1)
            owned.pop()
            if b is not None:
                freed.append(b)
        self._free_and_purge(freed)
        self.lens[slot] = n_tokens
        self._bt_dev = None

    def live_tokens(self) -> int:
        return int(self.lens.sum())

    def allocated_blocks(self) -> int:
        return self.num_blocks - 1 - len(self.free)

    def kv_bytes_per_token(self) -> int:
        """HBM bytes one cached token costs across every seq-indexed pool
        (all layers; codes + scale pools).  Ring/recurrent leaves are
        excluded — they do not scale with live tokens.  This is the number
        the int8 pools cut ~4x (int8 codes + one fp32 scale per head-slot
        vs fp32 values) and int4 packing cuts further (two codes per
        byte)."""
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(self.pools)[0]:
            if _leaf_name(path) in POOL_KEYS:
                nb, bs = leaf.shape[1], leaf.shape[2]
                total += leaf.size * leaf.dtype.itemsize // (nb * bs)
        return total

    # -- copy-on-write ------------------------------------------------------

    def ensure_writable(self, slot: int, start: int, end: int) -> None:
        """Make the token span ``[start, end)`` of ``slot`` safe to write:
        any covered block with refcount > 1 (shared via ``adopt_prefix``) is
        replaced by a private copy — one fused device-side ``set`` per pool
        leaf — before the jitted write ever sees the table.  Also advances
        the slot's write watermark.  No-op for unshared spans."""
        if end <= start:
            return
        self.watermarks[slot] = max(int(self.watermarks[slot]), end)
        bs = self.block_size
        for j in range(start // bs, (end - 1) // bs + 1):
            b = int(self.tables[slot, j])
            if b == TRASH_BLOCK or self.refcounts[b] <= 1:
                continue
            if not self.free:
                self.reclaim(1)
            if not self.free:
                raise RuntimeError("paged KV cache out of blocks for CoW copy")
            nb = self.free.pop()
            self._copy_block(b, nb)
            self.refcounts[b] -= 1
            self.refcounts[nb] = 1
            self.tables[slot, j] = nb
            self._owned[slot][j] = nb
            self.cow_copies += 1
            self._bt_dev = None
        self.peak_blocks = max(self.peak_blocks, self.allocated_blocks())

    def _copy_block(self, src: int, dst: int) -> None:
        def one(path, leaf):
            if _leaf_name(path) in POOL_KEYS:
                return leaf.at[:, dst].set(leaf[:, src])
            return leaf

        self.pools = jax.tree_util.tree_map_with_path(one, self.pools)

    # -- prefix sharing -----------------------------------------------------

    def register_prefix(self, slot: int, tokens: np.ndarray) -> None:
        """Publish ``slot``'s prompt block run for future sharing.  The entry
        takes its own refcount on every listed block, so the prefix stays
        servable after the donor sequence releases — until the registry
        evicts it (FIFO, under block pressure or at the entry cap).

        Only blocks *wholly covered* by the prompt are listed: the donor
        writes at positions >= len(prompt) only, so it can never write into
        a fully-covered block — pinning a partial tail block would force
        the donor itself into a copy-on-write fault whose block demand no
        admission budget reserved (a mid-decode out-of-blocks crash under
        pressure).  CoW therefore only ever happens on the *adopter* side,
        whose worst case the admission gate already budgets."""
        if not self.fully_paged:
            return
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        n_full = tokens.size // self.block_size
        if n_full == 0 or tokens.size < 2:
            return  # nothing shareable below a full block / the len-1 cap
        shared, _ = self.lookup_prefix(tokens)
        if shared >= min(tokens.size - 1, n_full * self.block_size):
            return  # an existing entry already covers this prompt
        while len(self._prefix_entries) >= self.max_prefix_entries:
            self._evict_entry(next(iter(self._prefix_entries)))
        blocks = tuple(self._owned[slot][:n_full])
        eid = self._next_eid
        self._next_eid += 1
        self._prefix_entries[eid] = (tokens.copy(), blocks)
        for b in blocks:
            self._block_eids.setdefault(b, set()).add(eid)
            self.refcounts[b] += 1
            self._entry_rc[b] += 1

    def _evict_entry(self, eid: int) -> None:
        """Drop a registry entry, releasing its pinned refcounts (blocks no
        live slot still owns return to the free list)."""
        _, blocks = self._prefix_entries.pop(eid)
        freed = []
        for b in blocks:
            eids = self._block_eids.get(b)
            if eids is not None:
                eids.discard(eid)
                if not eids:
                    del self._block_eids[b]
            self._entry_rc[b] -= 1
            self.refcounts[b] -= 1
            assert self.refcounts[b] >= 0, "refcount underflow on eviction"
            if self.refcounts[b] == 0:
                freed.append(b)
        self.free.extend(freed)

    def reclaim(self, need: int) -> None:
        """Evict registry entries (oldest first) until at least ``need``
        blocks are free or the registry is empty — live sequences always win
        over cached prefixes."""
        while self.free_blocks < need and self._prefix_entries:
            self._evict_entry(next(iter(self._prefix_entries)))

    def reclaimable_blocks(self) -> int:
        """Blocks the registry alone is keeping alive (refcount fully
        accounted for by entry pins): what ``reclaim`` could hand back.  The
        admission gate counts these as available capacity."""
        return int(np.sum((self._entry_rc > 0) & (self.refcounts == self._entry_rc)))

    def lookup_prefix(self, tokens: np.ndarray) -> tuple[int, tuple[int, ...]]:
        """Longest registered common prefix of ``tokens``, capped at
        ``len(tokens) - 1`` (prefill must keep at least one token to produce
        logits from).  Returns ``(shared_tokens, block_run)`` where the run
        covers the shared span — its last block may be partial (the adopter
        copy-on-writes it when its own tokens land there)."""
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        cap = tokens.size - 1
        best, best_blocks = 0, ()
        for ptoks, blocks in self._prefix_entries.values():
            # an entry only pins the blocks wholly inside its prompt, so a
            # match can never extend past the entry's block coverage
            n = min(cap, ptoks.size, len(blocks) * self.block_size)
            if n <= best:
                continue
            neq = np.nonzero(tokens[:n] != ptoks[:n])[0]
            m = int(neq[0]) if neq.size else n
            if m > best:
                best, best_blocks = m, blocks[: self.blocks_needed(m)]
        return best, best_blocks

    def adopt_prefix(self, slot: int, shared_tokens: int, blocks) -> None:
        """Map a looked-up shared block run into an empty ``slot``: table
        entries point at the shared blocks (refcounts bumped), ``lens`` jumps
        to ``shared_tokens`` — the prompt prefix is served without recompute
        and without copies until a write forces CoW."""
        assert not self._owned[slot], "adopt_prefix needs an empty slot"
        for j, b in enumerate(blocks):
            self.tables[slot, j] = b
            self._owned[slot].append(b)
            self.refcounts[b] += 1
        self.lens[slot] = shared_tokens
        self.watermarks[slot] = shared_tokens
        self.prefix_hits += 1
        self.prefix_hit_tokens += shared_tokens
        self._bt_dev = None
        self.peak_blocks = max(self.peak_blocks, self.allocated_blocks())

    # -- per-slot state (recurrent / ring leaves) ---------------------------

    def reset_slot(self, slot: int) -> None:
        """Zero ``slot``'s rows of every per-slot (non-pool) leaf, so a fresh
        sequence starts from empty ring (``kpos = -1``) and zero recurrent
        state regardless of what the slot's previous occupant left behind."""

        def one(path, leaf):
            name = _leaf_name(path)
            if name in POOL_KEYS:
                return leaf
            return leaf.at[:, slot].set(-1 if name == "kpos" else 0)

        self.pools = jax.tree_util.tree_map_with_path(one, self.pools)

    def slice_slot(self, slot: int) -> dict:
        """B=1 cache view for an isolated per-slot prefill: pools whole (the
        slot's blocks live there), per-slot leaves sliced to the single row.
        Pair with ``bt_row(slot)`` for the matching block-table view."""

        def one(path, leaf):
            if _leaf_name(path) in POOL_KEYS:
                return leaf
            return leaf[:, slot : slot + 1]

        return jax.tree_util.tree_map_with_path(one, self.pools)

    def merge_slot(self, slot: int, new_pools: dict) -> None:
        """Fold a B=1 prefill result back: pool leaves replace wholesale,
        per-slot leaves write their single row into ``slot``."""

        def one(path, old, new):
            if _leaf_name(path) in POOL_KEYS:
                return new
            if old.shape[1] == new.shape[1]:
                # single-slot engine: the B=1 "slice" was the whole leaf (jax
                # returns the original buffer for full slices, which the jit
                # call then donated) — the result replaces it wholesale
                return new
            return old.at[:, slot].set(new[:, 0])

        self.pools = jax.tree_util.tree_map_with_path(one, self.pools, new_pools)

    # -- device view --------------------------------------------------------

    def bt(self) -> jnp.ndarray:
        """Full block table ``(slots, MB)`` as a device array.  Tables only
        change at allocate/release/CoW, so the decode loop's per-tick call
        reuses one upload between admissions."""
        if self._bt_dev is None:
            self._bt_dev = jnp.asarray(self.tables)
        return self._bt_dev

    def bt_row(self, slot: int) -> jnp.ndarray:
        """Single-row block-table view ``(1, MB)`` matching ``slice_slot``."""
        return jnp.asarray(self.tables[slot : slot + 1])

    def attach(self) -> dict:
        """Full-batch cache tree for ``apply_lm``: pools + block-table view."""
        return {**self.pools, "_paged": {"bt": self.bt()}}

    def device_state(self) -> dict:
        """Host bookkeeping as device arrays for multi-host serving: the
        block table plus refcounts (``rc``, block axis — local like the
        pools) and write watermarks (``wm``, slot axis — rides with the
        batch).  ``dist.sharding.cache_specs`` knows these leaves."""
        return {
            "bt": self.bt(),
            "rc": jnp.asarray(self.refcounts),
            "wm": jnp.asarray(self.watermarks),
        }
