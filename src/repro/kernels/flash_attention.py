"""Pallas TPU kernel: blocked online-softmax attention (FlashAttention-2 style).

Grid ``(B*H, Tq/bq, Tk/bk)`` with the KV axis innermost (sequential).  Running
row-max / row-sum / output accumulator live in VMEM scratch; the ``(Tq, Tk)``
score matrix is never materialized, so 32k-token prefill fits VMEM with
``O(bq * bk)`` working set.  Supports:

* causal masking (block-level position arithmetic),
* sliding-window masking (h2o-danube / hymba SWA, llama4 chunked-local is
  lowered to windows by the layer above),
* decode alignment (Tq < Tk with query positions aligned to the sequence end).

Numerics: fp32 softmax state regardless of input dtype, matching the oracle
``ref.ref_flash_attention`` to ~1e-5.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel", "flash_attention_pallas"]

_NEG_INF = -1e30


def flash_attention_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    kv_steps: int,
    block_q: int,
    block_k: int,
    seq_q: int,
    seq_k: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale  # (bq, d)
    k = k_ref[0].astype(jnp.float32)  # (bk, d)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bq, bk)

    # absolute positions; queries are end-aligned for decode (Tq < Tk)
    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    qpos = qpos + (seq_k - seq_q)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos < seq_k  # KV padding
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]  # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)  # (bq, bk)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = m_new
    pv = jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_ref[...] = alpha * acc_ref[...] + pv

    @pl.when(ki == kv_steps - 1)
    def _flush():
        l = l_ref[...]
        norm = jnp.where(l > 0.0, 1.0 / jnp.maximum(l, 1e-30), 0.0)
        o_ref[0] = (acc_ref[...] * norm).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    true_q: Optional[int] = None,
    true_k: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """q ``(BH, Tq, D)``, k/v ``(BH, Tk, D)`` — heads pre-folded, Tq/Tk padded
    to block multiples (``ops.py`` handles folding/padding).  ``true_q`` /
    ``true_k`` are the unpadded lengths: padded KV columns are masked out and
    query positions are end-aligned against ``true_k`` (padded query rows
    produce garbage that the wrapper slices off)."""
    BH, Tq, D = q.shape
    _, Tk, _ = k.shape
    assert Tq % block_q == 0 and Tk % block_k == 0, (Tq, Tk, block_q, block_k)
    if scale is None:
        scale = D**-0.5
    true_q = Tq if true_q is None else true_q
    true_k = Tk if true_k is None else true_k

    kv_steps = Tk // block_k
    grid = (BH, Tq // block_q, kv_steps)
    kernel = functools.partial(
        flash_attention_kernel,
        scale=scale,
        causal=causal,
        window=window,
        kv_steps=kv_steps,
        block_q=block_q,
        block_k=block_k,
        seq_q=true_q,
        seq_k=true_k,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Tq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
