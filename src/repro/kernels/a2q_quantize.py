"""Pallas TPU kernel: fused A2Q weight quantizer (Eq. 20-23 in one pass).

Fuses the whole A2Q inference-side pipeline for a ``(K, C)`` weight matrix —
per-channel l1 norm -> norm cap ``g = 2**min(t, T)`` -> scale -> round-to-zero
-> clip -> dequantize — without materializing any intermediate in HBM.

Two-phase sequential grid ``(C/bc, 2, K/bk)``:

* phase 0 streams the column block over K accumulating ``sum |v|`` into a VMEM
  scratch row (the l1 norm needs all of K before any output element is final);
* phase 1 re-streams the same blocks and emits both the integer weights (int8)
  and the dequantized float weights.

v is read twice from HBM (unavoidable for an exact norm), but the quantize
arithmetic, both outputs, and the norm never round-trip through HBM — versus
four materializations for the unfused jnp path.  Channel blocks are VMEM-sized
so K can be arbitrarily large (command-r's d_ff=22528 columns stream fine).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["a2q_quantize_kernel", "a2q_quantize_pallas"]


def a2q_quantize_kernel(
    v_ref,
    t_ref,
    d_ref,
    deq_ref,
    q_ref,
    l1_ref,
    *,
    weight_bits: int,
    acc_bits: int,
    input_bits: int,
    input_signed: bool,
):
    phase = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((phase == 0) & (k == 0))
    def _init():
        l1_ref[...] = jnp.zeros_like(l1_ref)

    @pl.when(phase == 0)
    def _accumulate():
        l1_ref[...] += jnp.sum(
            jnp.abs(v_ref[...].astype(jnp.float32)), axis=0, keepdims=True
        )

    @pl.when(phase == 1)
    def _quantize():
        n = float(-(2 ** (weight_bits - 1)))
        p = float(2 ** (weight_bits - 1) - 1)
        t = t_ref[...]  # (1, bc)
        d = d_ref[...]
        log2_amax = jnp.log2(jnp.float32(2.0 ** (acc_bits - 1) - 1.0))
        T = int(input_signed) + log2_amax + d - input_bits  # Eq. 23
        g_over_s = jnp.exp2(jnp.minimum(t, T) - d)  # g/s, exact in log space
        l1 = jnp.maximum(l1_ref[...], 1e-12)
        v = v_ref[...].astype(jnp.float32)
        q = jnp.clip(jnp.trunc(g_over_s * v / l1), n, p)
        q_ref[...] = q.astype(jnp.int8)
        deq_ref[...] = q * jnp.exp2(d)


def a2q_quantize_pallas(
    v: jnp.ndarray,
    t: jnp.ndarray,
    d: jnp.ndarray,
    *,
    weight_bits: int,
    acc_bits: int,
    input_bits: int,
    input_signed: bool,
    block_k: int = 512,
    block_c: int = 256,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused quantize of a padded ``(K, C)`` matrix with per-channel ``t``/``d``
    given as ``(1, C)``.  Returns (dequantized float32, integer int8)."""
    K, C = v.shape
    assert t.shape == (1, C) and d.shape == (1, C), (t.shape, d.shape, C)
    assert K % block_k == 0 and C % block_c == 0, (K, C, block_k, block_c)

    grid = (C // block_c, 2, K // block_k)
    kernel = functools.partial(
        a2q_quantize_kernel,
        weight_bits=weight_bits,
        acc_bits=acc_bits,
        input_bits=input_bits,
        input_signed=input_signed,
    )
    deq, q = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_k, block_c), lambda c, phase, k: (k, c)),
            pl.BlockSpec((1, block_c), lambda c, phase, k: (0, c)),
            pl.BlockSpec((1, block_c), lambda c, phase, k: (0, c)),
        ],
        out_specs=[
            pl.BlockSpec((block_k, block_c), lambda c, phase, k: (k, c)),
            pl.BlockSpec((block_k, block_c), lambda c, phase, k: (k, c)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((K, C), jnp.float32),
            jax.ShapeDtypeStruct((K, C), jnp.int8),
        ],
        scratch_shapes=[pltpu.VMEM((1, block_c), jnp.float32)],
        interpret=interpret,
    )(v, t, d)
    return deq, q
