"""Pallas TPU kernels for the perf-critical compute hot spots, with jit'd
wrappers (ops.py) and pure-jnp oracles (ref.py).  Layers import from ops."""

from repro.kernels.ops import a2q_quantize, flash_attention, int_matmul, rwkv6_scan  # noqa: F401
