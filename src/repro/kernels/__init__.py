"""Pallas TPU kernels for the perf-critical compute hot spots, with jit'd
wrappers (ops.py) and pure-jnp oracles (ref.py).  Layers import from ops."""

from repro.kernels.ops import (  # noqa: F401
    a2q_quantize,
    flash_attention,
    int_matmul,
    paged_attention,
    rwkv6_scan,
)
