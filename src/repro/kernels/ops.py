"""Public jit'd wrappers around the Pallas kernels.

Each op handles padding to block multiples, head folding, dtype plumbing, and
an ``interpret`` default (True off-TPU so the kernels execute via the Pallas
interpreter on CPU; on TPU they compile to Mosaic).  Layers call these — never
``pallas_call`` directly — and every op has a pure-jnp oracle in ``ref.py``
that the test suite sweeps against.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.a2q_quantize import a2q_quantize_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.int_matmul import int_matmul_pallas
from repro.kernels.paged_attention import (
    paged_attention_pallas,
    paged_mla_attention_pallas,
)
from repro.kernels.rwkv6_scan import rwkv6_scan_pallas

__all__ = [
    "int_matmul",
    "a2q_quantize",
    "flash_attention",
    "paged_attention",
    "paged_mla_attention",
    "rwkv6_scan",
]


def _default_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _pad_axis(x: jnp.ndarray, axis: int, to: int, value=0):
    pad = to - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def int_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    acc_bits: int = 32,
    mode: str = "exact",
    scale: Optional[jnp.ndarray] = None,
    bias: Optional[jnp.ndarray] = None,
    offset: Optional[jnp.ndarray] = None,
    out_scale: Optional[jnp.ndarray] = None,
    out_bits: int = 8,
    out_signed: bool = True,
    act_fn: Optional[str] = None,
    cast_dtype=jnp.float32,
    aq_scale: Optional[jnp.ndarray] = None,
    in_bits: int = 8,
    in_signed: bool = True,
    out_dtype=jnp.float32,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    spill_int16: bool = False,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """int8 x int8 -> int32 matmul ``(M, K) @ (K, N)`` with P-bit accumulator
    emulation.  Zero padding is sound for all modes (adding zero then wrapping
    or saturating an in-range value is the identity).

    ``scale`` (scalar or per-column ``(N,)`` fp32 — e.g. the deployed layer's
    ``s8`` with the activation scale folded in) engages the fused epilogue:
    the int32 accumulator is rescaled (+ ``bias``) in VMEM and the op returns
    ``out_dtype`` instead of raw int32.  Oracle: ``ref.ref_int_matmul_fused``.

    Int8-out chaining (oracle: ``ref.ref_int_matmul_requant``):

    * ``in_signed=False, in_bits=8`` declares that ``x`` carries *symmetrized*
      unsigned codes (``true_code - 128`` as int8, or the fp32 prologue input
      of an unsigned consumer); the wrapper adds the exact correction
      ``128 * colsum(w)`` to the accumulator at flush, so unsigned-activation
      layers ride the fused path at full ``N=8``.
    * ``out_scale`` (scalar or per-column ``(N,)`` fp32 — the *next* layer's
      activation scale) engages the requantizing epilogue: the rescaled
      accumulator is passed through ``act_fn`` (``None``/``'relu2'``/
      ``'gelu'``, replayed in ``cast_dtype`` exactly as the layer code
      computes it) and re-quantized to int8 codes for ``out_bits``/
      ``out_signed`` in the same flush — the op returns int8, and unsigned
      targets come out symmetrized.
    * ``aq_scale`` (scalar fp32) engages the quantizing prologue: ``x``
      arrives fp32 and each tile is quantized in-register before the dot —
      the chain-break entry point needs no standalone act-quant dispatch.
    """
    M, K = x.shape
    _, N = w.shape
    bm = min(block_m, _round_up(M, 8))
    bn = min(block_n, _round_up(N, 128))
    bk = min(block_k, _round_up(K, 128))
    Np = _round_up(N, bn)
    Kp = _round_up(K, bk)
    xp = _pad_axis(_pad_axis(x, 0, _round_up(M, bm)), 1, Kp)
    wp = _pad_axis(_pad_axis(w, 0, Kp), 1, Np)
    if scale is not None:
        scale = _pad_axis(
            jnp.broadcast_to(jnp.asarray(scale, jnp.float32), (N,)).reshape(1, N), 1, Np
        )
    if bias is not None:
        if scale is None:
            raise ValueError("int_matmul: bias requires an epilogue scale")
        bias = _pad_axis(jnp.asarray(bias, jnp.float32).reshape(1, N), 1, Np)
    if not in_signed and in_bits == 8:
        # symmetrized unsigned operand: q = qs + 128, so
        # acc_true = acc_sym + 128 * colsum(w).  Exact in int32; w's K padding
        # is zeros, so the unpadded colsum is already correct.
        sym = 128 * jnp.sum(w.astype(jnp.int32), axis=0)
        offset = sym if offset is None else jnp.asarray(offset, jnp.int32) + sym
    if offset is not None:
        if scale is None:
            raise ValueError("int_matmul: offset requires an epilogue scale")
        offset = _pad_axis(jnp.asarray(offset, jnp.int32).reshape(1, N), 1, Np)
    if out_scale is not None:
        if scale is None:
            raise ValueError("int_matmul: out_scale requires an epilogue scale")
        # pad columns divide by 1 (never 0) and are sliced off below
        out_scale = _pad_axis(
            jnp.broadcast_to(jnp.asarray(out_scale, jnp.float32), (N,)).reshape(1, N),
            1, Np, value=1,
        )
    if aq_scale is not None:
        if scale is None:
            raise ValueError("int_matmul: aq_scale requires an epilogue scale")
        aq_scale = _pad_axis(
            jnp.broadcast_to(jnp.asarray(aq_scale, jnp.float32), (K,)).reshape(1, K),
            1, Kp, value=1,
        )
    out = int_matmul_pallas(
        xp,
        wp,
        scale,
        bias,
        offset,
        out_scale,
        aq_scale,
        acc_bits=acc_bits,
        mode=mode,
        block_m=bm,
        block_n=bn,
        block_k=bk,
        spill_dtype=jnp.int16 if spill_int16 else jnp.int32,
        out_dtype=out_dtype,
        out_bits=out_bits,
        out_signed=out_signed,
        act_fn=act_fn,
        cast_dtype=cast_dtype,
        in_bits=in_bits,
        in_signed=in_signed,
        interpret=_default_interpret(interpret),
    )
    return out[:M, :N]


def a2q_quantize(
    v: jnp.ndarray,
    t: jnp.ndarray,
    d: jnp.ndarray,
    *,
    weight_bits: int,
    acc_bits: int,
    input_bits: int,
    input_signed: bool,
    block_k: int = 512,
    block_c: int = 256,
    interpret: Optional[bool] = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused A2Q quantizer for a ``(K, C)`` weight matrix with per-channel
    ``t``/``d`` of shape ``(C,)``.  Returns (dequantized fp32, int8 weights).

    K padding uses v=0 (adds nothing to the l1 norm); C padding uses t=d=0
    (garbage channels sliced off).
    """
    K, C = v.shape
    bk = min(block_k, _round_up(K, 8))
    bc = min(block_c, _round_up(C, 128))
    Kp, Cp = _round_up(K, bk), _round_up(C, bc)
    vp = _pad_axis(_pad_axis(v, 0, Kp), 1, Cp)
    tp = _pad_axis(t.reshape(1, C), 1, Cp)
    dp = _pad_axis(d.reshape(1, C), 1, Cp)
    deq, q = a2q_quantize_pallas(
        vp,
        tp,
        dp,
        weight_bits=weight_bits,
        acc_bits=acc_bits,
        input_bits=input_bits,
        input_signed=input_signed,
        block_k=bk,
        block_c=bc,
        interpret=_default_interpret(interpret),
    )
    return deq[:K, :C], q[:K, :C]


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Blocked attention over ``(B, H, T, D)`` tensors (KV heads already
    repeated to H by the GQA layer).  Pads T axes to block multiples."""
    B, H, Tq, D = q.shape
    Tk = k.shape[2]
    bq = min(block_q, _round_up(Tq, 8))
    bk = min(block_k, _round_up(Tk, 8))
    qf = q.reshape(B * H, Tq, D)
    kf = k.reshape(B * H, Tk, D)
    vf = v.reshape(B * H, Tk, D)
    qf = _pad_axis(qf, 1, _round_up(Tq, bq))
    kf = _pad_axis(kf, 1, _round_up(Tk, bk))
    vf = _pad_axis(vf, 1, _round_up(Tk, bk))
    out = flash_attention_pallas(
        qf,
        kf,
        vf,
        causal=causal,
        window=window,
        scale=scale,
        true_q=Tq,
        true_k=Tk,
        block_q=bq,
        block_k=bk,
        interpret=_default_interpret(interpret),
    )
    return out[:, :Tq].reshape(B, H, Tq, D)


def paged_attention(
    q: jnp.ndarray,
    kp: jnp.ndarray,
    vp: jnp.ndarray,
    bt: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    kps: Optional[jnp.ndarray] = None,
    vps: Optional[jnp.ndarray] = None,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Paged-attention decode: one query token per row against block-table
    K/V pools.  ``q (B, H, Dh)``, pools ``(NB, bs, KV, Dh)``, table
    ``bt (B, MB)``, ``lengths (B,)`` counting valid tokens (including this
    step's write).  Returns ``(B, H, Dh)``.  Oracle:
    ``ref.ref_paged_attention``.

    ``kps``/``vps`` (``(NB, bs, KV)`` fp32): the pools are integer and the
    kernel dequantizes in-register — int8 codes directly (oracle:
    ``ref.ref_paged_attention_q8``) or, when the pools are uint8, the packed
    int4 layout at feature width ``Dh // 2``, unpacked + sign-extended in
    register (oracle: ``ref.ref_paged_attention_q4``).

    ``window``: sliding-window masking — each row attends keys at
    ``kpos >= length - window`` only (windowed-decode kernel coverage).
    """
    B, H, Dh = q.shape
    KV = kp.shape[2]
    G = H // KV
    if (kps is None) != (vps is None):
        raise ValueError("paged_attention: kps and vps must be given together")
    if kp.dtype == jnp.uint8 and kps is None:
        raise ValueError("paged_attention: packed int4 pools need kps/vps")
    if window is not None and window < 1:
        raise ValueError("paged_attention: window must be >= 1")
    out = paged_attention_pallas(
        q.reshape(B, KV, G, Dh),
        kp,
        vp,
        bt,
        lengths,
        kps,
        vps,
        scale=scale,
        window=window,
        interpret=_default_interpret(interpret),
    )
    return out.reshape(B, H, Dh)


def paged_mla_attention(
    q_lat: jnp.ndarray,
    q_pe: jnp.ndarray,
    ckvp: jnp.ndarray,
    kpep: jnp.ndarray,
    bt: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    ckvs: Optional[jnp.ndarray] = None,
    kpes: Optional[jnp.ndarray] = None,
    scale: float,
    aq_scale: Optional[jnp.ndarray] = None,
    act_bits: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """MLA absorbed-decode latent attention over paged compressed pools.

    ``q_lat (B, H, R)`` is the query already absorbed through the up-proj's
    key half, ``q_pe (B, H, P)`` the rope half; pools ``ckvp (NB, bs, R)`` /
    ``kpep (NB, bs, P)`` hold the shared latent + rope key per token (no head
    axis — that is the MLA bandwidth win), table ``bt (B, MB)``, ``lengths``
    counting valid tokens including this step's write.  Returns the latent
    output ``o_lat (B, H, R)`` (fp32); the caller up-projects through
    ``w_v``.  Oracle: ``ref.ref_paged_mla_attention``.

    ``ckvs``/``kpes`` (``(NB, bs)`` fp32): the pools are integer — int8
    codes, or packed int4 at half feature width when uint8 — and the kernel
    dequantizes in-register.  ``aq_scale``/``act_bits`` replay the absorb
    path's activation fake-quant on the dequantized latent.  ``scale`` is the
    absorbed score scale ``(qk_nope_dim + qk_rope_dim) ** -0.5`` (required —
    not derivable from latent shapes)."""
    if (ckvs is None) != (kpes is None):
        raise ValueError("paged_mla_attention: ckvs and kpes must be given together")
    return paged_mla_attention_pallas(
        q_lat,
        q_pe,
        ckvp,
        kpep,
        bt,
        lengths,
        ckvs,
        kpes,
        scale=scale,
        aq_scale=aq_scale,
        act_bits=act_bits,
        interpret=_default_interpret(interpret),
    )


def rwkv6_scan(
    r: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,
    initial_state: Optional[jnp.ndarray] = None,
    *,
    chunk: int = 64,
    interpret: Optional[bool] = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """RWKV-6 scan over ``(B, H, T, Dk/Dv)`` tensors with per-head bonus
    ``u (H, Dk)``.  Pads T with no-op steps (k=0 so kv=0, w=1 so S unchanged)."""
    B, H, T, Dk = r.shape
    Dv = v.shape[-1]
    ct = min(chunk, _round_up(T, 8))
    Tp = _round_up(T, ct)
    fold = lambda x: x.reshape(B * H, *x.shape[2:])
    rp = _pad_axis(fold(r), 1, Tp)
    kp = _pad_axis(fold(k), 1, Tp)
    vp = _pad_axis(fold(v), 1, Tp)
    wp = _pad_axis(fold(w), 1, Tp, value=1)
    uf = jnp.broadcast_to(u[None], (B, H, Dk)).reshape(B * H, Dk)
    if initial_state is not None:
        s0 = initial_state.reshape(B * H, Dk, Dv)
    else:
        s0 = None
    y, sT = rwkv6_scan_pallas(
        rp, kp, vp, wp, uf, s0, chunk=ct, interpret=_default_interpret(interpret)
    )
    return y[:, :T].reshape(B, H, T, Dv), sT.reshape(B, H, Dk, Dv)
