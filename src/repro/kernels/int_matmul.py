"""Pallas TPU kernel: tiled int8 x int8 -> int32 matmul with accumulator
bit-width emulation and an A2Q-enabled int16 partial-sum spill path.

TPU adaptation of the paper's FPGA payoff (DESIGN.md Sec. 2): on FINN
accelerators a small accumulator shrinks the adder/register; on TPU the MXU
datapath is fixed (int8 x int8 -> int32), but the A2Q guarantee that *every*
partial sum fits ``P`` bits unlocks:

* ``spill_dtype=int16`` — when P <= 16, the carried inter-K-tile partial sums
  are provably representable in int16, so the VMEM accumulator scratch (and any
  HBM spill of partial sums in very-large-K matmuls) is half-width.  The cast
  is lossless *because of* the A2Q bound — this is the kernel-level beyond-FPGA
  payoff of the paper's method.
* ``mode='wrap' | 'saturate'`` — exact emulation of a P-bit accumulator, used
  by the overflow benchmarks (Fig. 2) and the bit-exactness tests against the
  numpy simulator.

Grid: ``(M/bm, N/bn, K/bk)`` with K innermost (sequential on TPU); the
accumulator lives in VMEM scratch across K steps.  Per-tile dots use the MXU
via ``jax.lax.dot_general(..., preferred_element_type=int32)``.

Fused epilogue (the W8A8 serve path): with ``scale`` (one fp32 scalar per
output column — the per-channel weight scale ``s8`` with the activation scale
already folded in) and optionally ``bias``, the final K step rescales the
int32 accumulator in VMEM and writes the floating-point output directly:
``out = acc * scale + bias``.  The deployed layer then runs
``act_quant(x) -> int8 @ int8 -> int32 -> scaled fp`` in ONE ``pallas_call``
instead of dequantizing ``q8`` to fp32 and paying a bf16 matmul — the int32
accumulator never round-trips through HBM.

Int8-out chaining extends the epilogue and adds a prologue:

* ``offset`` (``(1, N)`` int32, added to the accumulator at flush) corrects
  signed symmetrization of unsigned activations: unsigned 8-bit codes
  ``q ∈ [0, 255]`` don't fit the int8 MXU operand, so the wrapper (or the
  in-kernel prologue) feeds ``q - 128`` and the flush adds
  ``128 * colsum(w)`` back — exact in int32, and the carried partial sums
  ``|Σ (q-128)·w| <= 128·Σ|w|`` stay inside the A2Q ``P``-bit bound, so the
  int16 spill remains lossless.
* ``requant`` — after the fp rescale (+ bias), the flush replays the *next*
  layer's activation quantizer in-register (optional activation function,
  then ``clip(round(y / out_scale))``) and writes int8 codes directly:
  ``int32 acc -> rescale -> act -> round/clamp -> int8 out``.  The chained
  layer then consumes codes without a standalone act-quant dispatch and
  without materializing the fp32 activation.  Unsigned requant targets emit
  symmetrized codes (``q - 128``).
* ``prologue_quant`` — ``x`` arrives fp32 and the kernel quantizes each tile
  before the dot (``clip(round(x / aq_scale))``, symmetrizing when the
  target is unsigned 8-bit).  Used at chain-break points so even the first
  deployed linear after a norm/residual runs without a standalone act-quant
  dispatch.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["int_matmul_kernel", "int_matmul_pallas"]


def _wrap_bits_i32(v: jnp.ndarray, bits: int) -> jnp.ndarray:
    if bits >= 32:
        return v
    shift = 32 - bits
    return (v << shift) >> shift


def _saturate_bits_i32(v: jnp.ndarray, bits: int) -> jnp.ndarray:
    if bits >= 32:
        return v
    lo = -(1 << (bits - 1))
    hi = (1 << (bits - 1)) - 1
    return jnp.clip(v, lo, hi)


def _int_range(bits: int, signed: bool) -> tuple[int, int]:
    if signed:
        return -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    return 0, 2**bits - 1


def _apply_act(y: jnp.ndarray, act_fn: Optional[str], cast_dtype) -> jnp.ndarray:
    """Replay the layer's inter-linear activation bit-exactly.

    ``y`` arrives fp32 (the rescaled accumulator).  The layer code first sees
    the linear's output in ``compute_dtype``, so cast first; each activation
    then reproduces the exact cast sequence of its call site: rwkv6
    channel-mix squares relu in compute dtype (no fp32 round-trip), the
    non-gated MLP runs gelu in fp32 then casts back.
    """
    y = y.astype(cast_dtype)
    if act_fn is None:
        pass
    elif act_fn == "relu2":
        y = jnp.square(jax.nn.relu(y))
    elif act_fn == "gelu":
        y = jax.nn.gelu(y.astype(jnp.float32)).astype(cast_dtype)
    else:
        raise ValueError(f"unknown chained activation {act_fn!r}")
    return y.astype(jnp.float32)


def int_matmul_kernel(
    x_ref,
    w_ref,
    *rest,
    k_steps: int,
    acc_bits: int,
    mode: str,
    fused: bool,
    has_bias: bool,
    has_offset: bool = False,
    requant: bool = False,
    out_bits: int = 8,
    out_signed: bool = True,
    act_fn: Optional[str] = None,
    cast_dtype=jnp.float32,
    prologue_quant: bool = False,
    in_bits: int = 8,
    in_signed: bool = True,
):
    """Kernel body. acc_ref dtype is int32 or int16 (the spill path).

    ``rest`` is ``(scale_ref[, bias_ref][, offset_ref][, out_scale_ref]
    [, aq_scale_ref], o_ref, acc_ref)`` when ``fused`` else
    ``(o_ref, acc_ref)`` — operands precede outputs precede scratch.
    """
    if fused:
        it = iter(rest)
        scale_ref = next(it)
        bias_ref = next(it) if has_bias else None
        offset_ref = next(it) if has_offset else None
        out_scale_ref = next(it) if requant else None
        aq_scale_ref = next(it) if prologue_quant else None
    o_ref, acc_ref = rest[-2:]
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if prologue_quant:
        # Chain-break entry: x arrives fp32; replay act_quant_int in-register
        # (identical divide/round/clip, so bit-exact vs the standalone
        # dispatch), symmetrizing unsigned 8-bit codes into the int8 operand.
        n, p = _int_range(in_bits, in_signed)
        xq = jnp.clip(jnp.round(x_ref[...] / aq_scale_ref[...]), n, p)
        if not in_signed and in_bits == 8:
            xq = xq - 128.0
        x_tile = xq.astype(jnp.int8)
    else:
        x_tile = x_ref[...]
    tile = jax.lax.dot_general(
        x_tile,
        w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    carried = acc_ref[...].astype(jnp.int32)
    total = carried + tile
    if mode == "wrap":
        total = _wrap_bits_i32(total, acc_bits)
    elif mode == "saturate":
        total = _saturate_bits_i32(total, acc_bits)
    elif mode != "exact":
        raise ValueError(f"unknown mode {mode!r}")
    # Lossless by the A2Q bound when acc_ref is int16 (P <= 16): every carried
    # partial sum is guaranteed to fit the narrow register (symmetrized
    # unsigned codes are bounded by 128 < 2^N - 1, so they only tighten it).
    acc_ref[...] = total.astype(acc_ref.dtype)

    @pl.when(k == k_steps - 1)
    def _flush():
        acc = acc_ref[...].astype(jnp.int32)
        if fused:
            if has_offset:
                acc = acc + offset_ref[...]
            out = acc.astype(jnp.float32) * scale_ref[...]
            if has_bias:
                out = out + bias_ref[...]
            if requant:
                y = _apply_act(out, act_fn, cast_dtype)
                qn, qp = _int_range(out_bits, out_signed)
                q = jnp.clip(jnp.round(y / out_scale_ref[...]), qn, qp)
                if not out_signed and out_bits == 8:
                    q = q - 128.0
                o_ref[...] = q.astype(jnp.int8)
            else:
                o_ref[...] = out.astype(o_ref.dtype)
        else:
            o_ref[...] = acc


def int_matmul_pallas(
    x: jnp.ndarray,
    w: jnp.ndarray,
    scale: Optional[jnp.ndarray] = None,
    bias: Optional[jnp.ndarray] = None,
    offset: Optional[jnp.ndarray] = None,
    out_scale: Optional[jnp.ndarray] = None,
    aq_scale: Optional[jnp.ndarray] = None,
    *,
    acc_bits: int = 32,
    mode: str = "exact",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    spill_dtype: Optional[jnp.dtype] = None,
    out_dtype=jnp.float32,
    out_bits: int = 8,
    out_signed: bool = True,
    act_fn: Optional[str] = None,
    cast_dtype=jnp.float32,
    in_bits: int = 8,
    in_signed: bool = True,
    interpret: bool = False,
) -> jnp.ndarray:
    """Tiled integer matmul.  Inputs must already be padded to block multiples
    (the public wrapper in ``ops.py`` handles padding/slicing and defaults).

    ``spill_dtype=jnp.int16`` requires ``acc_bits <= 16`` — the A2Q guarantee
    is what makes the narrow carry lossless.

    ``scale``/``bias`` (``(1, N)`` fp32) enable the fused epilogue: the output
    is ``acc * scale (+ bias)`` in ``out_dtype`` instead of raw int32.
    ``bias`` requires ``scale``.

    ``offset`` (``(1, N)`` int32) is added to the accumulator at flush (the
    unsigned-symmetrization correction ``128 * colsum(w)``).  ``out_scale``
    (``(1, N)`` fp32) engages the requantizing epilogue — int8 codes out,
    after the optional ``act_fn`` replay in ``cast_dtype``.  ``aq_scale``
    (``(1, K)`` fp32) engages the quantizing prologue — ``x`` arrives fp32
    and each tile is quantized in-register before the dot.  Requant and
    prologue quant need ``mode='exact'`` (P-bit emulation of the *chained*
    datapath is not modeled).
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (K, K2)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, (
        f"unpadded shapes M={M} N={N} K={K} for blocks {(block_m, block_n, block_k)}"
    )
    if spill_dtype is None:
        spill_dtype = jnp.int32
    if jnp.dtype(spill_dtype) == jnp.dtype(jnp.int16) and acc_bits > 16:
        raise ValueError("int16 partial-sum spill is only sound when acc_bits <= 16 (A2Q bound)")
    fused = scale is not None
    if bias is not None and not fused:
        raise ValueError("fused bias requires an epilogue scale")
    if (offset is not None or out_scale is not None or aq_scale is not None) and not fused:
        raise ValueError("offset/out_scale/aq_scale require an epilogue scale")
    requant = out_scale is not None
    prologue = aq_scale is not None
    if (requant or prologue) and mode != "exact":
        raise ValueError("requant/prologue quant need mode='exact'")

    k_steps = K // block_k
    grid = (M // block_m, N // block_n, k_steps)
    kernel = functools.partial(
        int_matmul_kernel, k_steps=k_steps, acc_bits=acc_bits, mode=mode,
        fused=fused, has_bias=bias is not None, has_offset=offset is not None,
        requant=requant, out_bits=out_bits, out_signed=out_signed,
        act_fn=act_fn, cast_dtype=cast_dtype,
        prologue_quant=prologue, in_bits=in_bits, in_signed=in_signed,
    )
    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
        pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
    ]
    operands = [x, w]
    if fused:
        epilogue_spec = pl.BlockSpec((1, block_n), lambda i, j, k: (0, j))
        epilogue = [(scale, jnp.float32)]
        if bias is not None:
            epilogue.append((bias, jnp.float32))
        if offset is not None:
            epilogue.append((offset, jnp.int32))
        if out_scale is not None:
            epilogue.append((out_scale, jnp.float32))
        for arr, dt in epilogue:
            assert arr.shape == (1, N), (arr.shape, N)
            in_specs.append(epilogue_spec)
            operands.append(arr.astype(dt))
        if aq_scale is not None:
            assert aq_scale.shape == (1, K), (aq_scale.shape, K)
            in_specs.append(pl.BlockSpec((1, block_k), lambda i, j, k: (0, k)))
            operands.append(aq_scale.astype(jnp.float32))
    if requant:
        final_dtype = jnp.int8
    elif fused:
        final_dtype = out_dtype
    else:
        final_dtype = jnp.int32
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), final_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), spill_dtype)],
        interpret=interpret,
    )(*operands)
