"""Pallas TPU kernel: tiled int8 x int8 -> int32 matmul with accumulator
bit-width emulation and an A2Q-enabled int16 partial-sum spill path.

TPU adaptation of the paper's FPGA payoff (DESIGN.md Sec. 2): on FINN
accelerators a small accumulator shrinks the adder/register; on TPU the MXU
datapath is fixed (int8 x int8 -> int32), but the A2Q guarantee that *every*
partial sum fits ``P`` bits unlocks:

* ``spill_dtype=int16`` — when P <= 16, the carried inter-K-tile partial sums
  are provably representable in int16, so the VMEM accumulator scratch (and any
  HBM spill of partial sums in very-large-K matmuls) is half-width.  The cast
  is lossless *because of* the A2Q bound — this is the kernel-level beyond-FPGA
  payoff of the paper's method.
* ``mode='wrap' | 'saturate'`` — exact emulation of a P-bit accumulator, used
  by the overflow benchmarks (Fig. 2) and the bit-exactness tests against the
  numpy simulator.

Grid: ``(M/bm, N/bn, K/bk)`` with K innermost (sequential on TPU); the
accumulator lives in VMEM scratch across K steps.  Per-tile dots use the MXU
via ``jax.lax.dot_general(..., preferred_element_type=int32)``.

Fused epilogue (the W8A8 serve path): with ``scale`` (one fp32 scalar per
output column — the per-channel weight scale ``s8`` with the activation scale
already folded in) and optionally ``bias``, the final K step rescales the
int32 accumulator in VMEM and writes the floating-point output directly:
``out = acc * scale + bias``.  The deployed layer then runs
``act_quant(x) -> int8 @ int8 -> int32 -> scaled fp`` in ONE ``pallas_call``
instead of dequantizing ``q8`` to fp32 and paying a bf16 matmul — the int32
accumulator never round-trips through HBM.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["int_matmul_kernel", "int_matmul_pallas"]


def _wrap_bits_i32(v: jnp.ndarray, bits: int) -> jnp.ndarray:
    if bits >= 32:
        return v
    shift = 32 - bits
    return (v << shift) >> shift


def _saturate_bits_i32(v: jnp.ndarray, bits: int) -> jnp.ndarray:
    if bits >= 32:
        return v
    lo = -(1 << (bits - 1))
    hi = (1 << (bits - 1)) - 1
    return jnp.clip(v, lo, hi)


def int_matmul_kernel(
    x_ref,
    w_ref,
    *rest,
    k_steps: int,
    acc_bits: int,
    mode: str,
    fused: bool,
    has_bias: bool,
):
    """Kernel body. acc_ref dtype is int32 or int16 (the spill path).

    ``rest`` is ``(scale_ref[, bias_ref], o_ref, acc_ref)`` when ``fused``
    else ``(o_ref, acc_ref)`` — operands precede outputs precede scratch.
    """
    if fused:
        scale_ref = rest[0]
        bias_ref = rest[1] if has_bias else None
    o_ref, acc_ref = rest[-2:]
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    tile = jax.lax.dot_general(
        x_ref[...],
        w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    carried = acc_ref[...].astype(jnp.int32)
    total = carried + tile
    if mode == "wrap":
        total = _wrap_bits_i32(total, acc_bits)
    elif mode == "saturate":
        total = _saturate_bits_i32(total, acc_bits)
    elif mode != "exact":
        raise ValueError(f"unknown mode {mode!r}")
    # Lossless by the A2Q bound when acc_ref is int16 (P <= 16): every carried
    # partial sum is guaranteed to fit the narrow register.
    acc_ref[...] = total.astype(acc_ref.dtype)

    @pl.when(k == k_steps - 1)
    def _flush():
        acc = acc_ref[...].astype(jnp.int32)
        if fused:
            out = acc.astype(jnp.float32) * scale_ref[...]
            if has_bias:
                out = out + bias_ref[...]
            o_ref[...] = out.astype(o_ref.dtype)
        else:
            o_ref[...] = acc


def int_matmul_pallas(
    x: jnp.ndarray,
    w: jnp.ndarray,
    scale: Optional[jnp.ndarray] = None,
    bias: Optional[jnp.ndarray] = None,
    *,
    acc_bits: int = 32,
    mode: str = "exact",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    spill_dtype: Optional[jnp.dtype] = None,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jnp.ndarray:
    """Tiled integer matmul.  Inputs must already be padded to block multiples
    (the public wrapper in ``ops.py`` handles padding/slicing and defaults).

    ``spill_dtype=jnp.int16`` requires ``acc_bits <= 16`` — the A2Q guarantee
    is what makes the narrow carry lossless.

    ``scale``/``bias`` (``(1, N)`` fp32) enable the fused epilogue: the output
    is ``acc * scale (+ bias)`` in ``out_dtype`` instead of raw int32.
    ``bias`` requires ``scale``.
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (K, K2)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, (
        f"unpadded shapes M={M} N={N} K={K} for blocks {(block_m, block_n, block_k)}"
    )
    if spill_dtype is None:
        spill_dtype = jnp.int32
    if jnp.dtype(spill_dtype) == jnp.dtype(jnp.int16) and acc_bits > 16:
        raise ValueError("int16 partial-sum spill is only sound when acc_bits <= 16 (A2Q bound)")
    fused = scale is not None
    if bias is not None and not fused:
        raise ValueError("fused bias requires an epilogue scale")

    k_steps = K // block_k
    grid = (M // block_m, N // block_n, k_steps)
    kernel = functools.partial(
        int_matmul_kernel, k_steps=k_steps, acc_bits=acc_bits, mode=mode,
        fused=fused, has_bias=bias is not None,
    )
    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
        pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
    ]
    operands = [x, w]
    if fused:
        epilogue_spec = pl.BlockSpec((1, block_n), lambda i, j, k: (0, j))
        for arr in (scale, bias) if bias is not None else (scale,):
            assert arr.shape == (1, N), (arr.shape, N)
            in_specs.append(epilogue_spec)
            operands.append(arr.astype(jnp.float32))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype if fused else jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), spill_dtype)],
        interpret=interpret,
    )(*operands)
