"""Pallas TPU kernel: chunked RWKV-6 (Finch) linear-attention scan.

The recurrence (per head, data-dependent per-channel decay ``w_t``)::

    y_t = r_t @ (S + (u * k_t) v_t^T)
    S   = diag(w_t) S + k_t v_t^T

is O(1)-state, which is what makes rwkv6-7b / hymba runnable at 500k context.
The kernel processes the sequence in chunks: grid ``(B*H, T/chunk)`` with the
chunk axis innermost/sequential, the ``(Dk, Dv)`` state carried in fp32 VMEM
scratch across chunks, and an in-chunk ``fori_loop`` over timesteps.  Inputs
stream HBM->VMEM one chunk at a time, so the working set is
``O(chunk * (2 Dk + 2 Dv) + Dk * Dv)`` regardless of T.

The in-chunk loop is step-sequential (the paper-faithful recurrence); the
intra-chunk matmul re-formulation (cumulative decay products + two GEMMs per
chunk, Finch Appendix D) is the MXU-friendly upgrade path and is noted in
EXPERIMENTS.md SPerf.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rwkv6_scan_kernel", "rwkv6_scan_pallas"]


def rwkv6_scan_kernel(
    r_ref,
    k_ref,
    v_ref,
    w_ref,
    u_ref,
    s0_ref,
    y_ref,
    sT_ref,
    state_ref,
    *,
    chunk: int,
    t_steps: int,
):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _load_state():
        state_ref[...] = s0_ref[0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)  # (Dk,)

    def step(i, _):
        r_t = r_ref[0, i, :].astype(jnp.float32)  # (Dk,)
        k_t = k_ref[0, i, :].astype(jnp.float32)
        v_t = v_ref[0, i, :].astype(jnp.float32)  # (Dv,)
        w_t = w_ref[0, i, :].astype(jnp.float32)
        kv = k_t[:, None] * v_t[None, :]  # (Dk, Dv)
        S = state_ref[...]
        y = r_t @ (S + u[:, None] * kv)  # (Dv,)
        y_ref[0, i, :] = y.astype(y_ref.dtype)
        state_ref[...] = w_t[:, None] * S + kv
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)

    @pl.when(ti == t_steps - 1)
    def _flush_state():
        sT_ref[0] = state_ref[...]


def rwkv6_scan_pallas(
    r: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,
    initial_state: Optional[jnp.ndarray] = None,
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """r/k/w ``(BH, T, Dk)``, v ``(BH, T, Dv)``, u ``(BH, Dk)`` (head bonus
    broadcast per batch in the wrapper), state ``(BH, Dk, Dv)``.  T must be a
    chunk multiple (wrapper pads with w=1, k=0 no-op steps).

    Returns (y ``(BH, T, Dv)`` in r.dtype, final state fp32)."""
    BH, T, Dk = r.shape
    Dv = v.shape[-1]
    assert T % chunk == 0, (T, chunk)
    if initial_state is None:
        initial_state = jnp.zeros((BH, Dk, Dv), jnp.float32)

    t_steps = T // chunk
    grid = (BH, t_steps)
    kernel = functools.partial(rwkv6_scan_kernel, chunk=chunk, t_steps=t_steps)
    y, sT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, Dk), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, chunk, Dk), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, chunk, Dv), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, chunk, Dk), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, Dk), lambda b, t: (b, 0)),
            pl.BlockSpec((1, Dk, Dv), lambda b, t: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, Dv), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, Dk, Dv), lambda b, t: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, Dv), r.dtype),
            jax.ShapeDtypeStruct((BH, Dk, Dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((Dk, Dv), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, initial_state)
    return y, sT
