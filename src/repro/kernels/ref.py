"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``ref_*`` function defines the *semantics* a kernel must match bit-for-bit
(integer kernels) or to float tolerance (attention / scan kernels).  The
oracles are also the CPU/dry-run execution path for the layers that use them —
kernels are the TPU fast path, refs are the portable truth.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "wrap_bits",
    "saturate_bits",
    "ref_int_matmul",
    "ref_int_matmul_fused",
    "ref_int_matmul_requant",
    "ref_a2q_quantize",
    "ref_flash_attention",
    "ref_paged_attention",
    "ref_paged_attention_q8",
    "ref_paged_attention_q4",
    "ref_paged_mla_attention",
    "ref_rwkv6",
]


def wrap_bits(v: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Two's-complement wrap of int32 values into a ``bits``-wide register."""
    if bits >= 32:
        return v
    shift = 32 - bits
    return (v << shift) >> shift  # arithmetic shift sign-extends


def saturate_bits(v: jnp.ndarray, bits: int) -> jnp.ndarray:
    if bits >= 32:
        return v
    lo = -(1 << (bits - 1))
    hi = (1 << (bits - 1)) - 1
    return jnp.clip(v, lo, hi)


def ref_int_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    acc_bits: int = 32,
    mode: str = "exact",
    block_k: Optional[int] = None,
) -> jnp.ndarray:
    """Integer matmul ``(M, K) @ (K, N) -> int32`` with accumulator emulation.

    Semantics the Pallas kernel implements:
      * ``exact``    — wide (int32) accumulation.
      * ``wrap``     — P-bit two's-complement wraparound.  Wraparound is
        associative, so tiling order is irrelevant and the reference applies a
        single wrap to the exact result.
      * ``saturate`` — P-bit saturation applied *after each K-tile of size
        ``block_k``*, sequentially in tile order.  Saturation is order
        dependent; the reference replays the kernel's exact tile schedule.
    """
    x32 = x.astype(jnp.int32)
    w32 = w.astype(jnp.int32)
    if mode == "exact":
        return x32 @ w32
    if mode == "wrap":
        return wrap_bits(x32 @ w32, acc_bits)
    if mode == "saturate":
        K = x.shape[-1]
        bk = block_k or K
        n_blocks = -(-K // bk)
        acc = jnp.zeros((x.shape[0], w.shape[1]), jnp.int32)
        for b in range(n_blocks):
            lo = b * bk
            hi = min(lo + bk, K)
            acc = saturate_bits(acc + x32[:, lo:hi] @ w32[lo:hi, :], acc_bits)
        return acc
    raise ValueError(f"unknown mode {mode!r}")


def ref_int_matmul_fused(
    x: jnp.ndarray,
    w: jnp.ndarray,
    scale: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
    acc_bits: int = 32,
    mode: str = "exact",
    block_k: Optional[int] = None,
    offset: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Fused-epilogue oracle: the integer matmul followed by the per-column
    rescale (+ bias) in fp32 — exactly ``matmul -> scale``.  The kernel's
    in-VMEM epilogue matches the scale-only form bit-for-bit (one fp32
    multiply either way); with ``bias`` the kernel's rescale+add may contract
    into an FMA (one rounding vs the oracle's two), so agreement is to 1-ulp
    float tolerance.  ``offset`` (``(N,)`` int32 — the unsigned-symmetrization
    correction ``128 * colsum(w)``) is added to the int32 accumulator before
    the rescale, exactly as the kernel does at flush."""
    acc = ref_int_matmul(x, w, acc_bits=acc_bits, mode=mode, block_k=block_k)
    if offset is not None:
        acc = acc + jnp.asarray(offset, jnp.int32).reshape(1, -1)
    out = acc.astype(jnp.float32) * jnp.asarray(scale, jnp.float32).reshape(1, -1)
    if bias is not None:
        out = out + jnp.asarray(bias, jnp.float32).reshape(1, -1)
    return out


def ref_int_matmul_requant(
    x: jnp.ndarray,
    w: jnp.ndarray,
    scale: jnp.ndarray,
    out_scale: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
    offset: Optional[jnp.ndarray] = None,
    out_bits: int = 8,
    out_signed: bool = True,
    act_fn: Optional[str] = None,
    cast_dtype=jnp.float32,
    acc_bits: int = 32,
) -> jnp.ndarray:
    """Requantizing-epilogue oracle (the int8-out chaining flush): integer
    matmul, per-column rescale (+ bias), the producer/consumer activation
    replay, then the *next* layer's ``act_quant_int`` — ``clip(round(y /
    out_scale))`` — emitted as int8 codes.  Unsigned targets come out
    *symmetrized* (``true_code - 128``), matching the kernel's convention for
    feeding the next int8 MXU operand.

    ``act_fn`` replays the call-site cast sequence bit-exactly: ``'relu2'``
    squares relu in ``cast_dtype`` (rwkv6 channel-mix), ``'gelu'`` runs in
    fp32 then casts back (the non-gated MLP), ``None`` is the bare
    cast round-trip.
    """
    y = ref_int_matmul_fused(
        x, w, scale, bias=bias, acc_bits=acc_bits, offset=offset
    ).astype(cast_dtype)
    if act_fn == "relu2":
        y = jnp.square(jax.nn.relu(y))
    elif act_fn == "gelu":
        y = jax.nn.gelu(y.astype(jnp.float32)).astype(cast_dtype)
    elif act_fn is not None:
        raise ValueError(f"unknown chained activation {act_fn!r}")
    if out_signed:
        n, p = -(2 ** (out_bits - 1)), 2 ** (out_bits - 1) - 1
    else:
        n, p = 0, 2**out_bits - 1
    q = jnp.clip(
        jnp.round(y.astype(jnp.float32) / jnp.asarray(out_scale, jnp.float32).reshape(1, -1)),
        n, p,
    )
    if not out_signed and out_bits == 8:
        q = q - 128.0
    return q.astype(jnp.int8)


def ref_a2q_quantize(
    v: jnp.ndarray,
    t: jnp.ndarray,
    d: jnp.ndarray,
    weight_bits: int,
    acc_bits: int,
    input_bits: int,
    input_signed: bool,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused A2Q weight quantizer on a ``(K, C)`` matrix.

    Returns (dequantized float32 weights, integer weights as int32).  Matches
    ``core.a2q.apply_a2q`` / ``a2q_int_weights`` exactly (no STE — this is the
    inference-side op; the training graph wraps it with STE at a higher level).
    """
    n = -(2 ** (weight_bits - 1))
    p = 2 ** (weight_bits - 1) - 1
    log2_amax = jnp.log2(jnp.asarray(2.0 ** (acc_bits - 1) - 1.0, v.dtype))
    T = int(input_signed) + log2_amax + d - input_bits
    t_eff = jnp.minimum(t, T)
    g_over_s = jnp.exp2(t_eff - d)
    s = jnp.exp2(d)
    l1 = jnp.maximum(jnp.sum(jnp.abs(v), axis=0), 1e-12)
    q = jnp.clip(jnp.trunc(g_over_s[None, :] * v / l1[None, :]), n, p)
    return (q * s[None, :]).astype(jnp.float32), q.astype(jnp.int32)


def ref_flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Dense softmax attention oracle.

    Shapes: q ``(B, H, Tq, Dh)``, k/v ``(B, H, Tk, Dh)`` (GQA repeat happens in
    the layer above).  ``window``: sliding-window width — position i attends to
    ``[i - window + 1, i]`` (None = full causal).  fp32 softmax arithmetic.
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5
    qf = q.astype(jnp.float32) * scale
    scores = jnp.einsum("bhqd,bhkd->bhqk", qf, k.astype(jnp.float32))
    Tq, Tk = q.shape[-2], k.shape[-2]
    qpos = jnp.arange(Tq)[:, None] + (Tk - Tq)  # align ends (decode: Tq < Tk)
    kpos = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ref_paged_attention(
    q: jnp.ndarray,  # (B, H, Dh)
    kp: jnp.ndarray,  # (NB, bs, KV, Dh) paged key pool
    vp: jnp.ndarray,  # (NB, bs, KV, Dh) paged value pool
    bt: jnp.ndarray,  # (B, MB) int32 block table
    lengths: jnp.ndarray,  # (B,) int32 valid tokens per row
    scale: Optional[float] = None,
    window: Optional[int] = None,
) -> jnp.ndarray:
    """Paged-attention decode oracle: gather the per-row contiguous K/V view
    through the block table, then dense fp32 softmax over the valid prefix.

    One query token per row (decode); ``lengths`` includes the current step's
    token.  GQA: ``H = KV * G`` query heads share each KV head.  Rows with
    ``lengths == 0`` return zeros (masked denominator guard), matching the
    kernel's flush semantics.  ``window`` restricts each row to the sliding
    window ending at its query position: keys at ``kpos >= length - window``
    (the query sits at ``length - 1``).
    """
    B, H, Dh = q.shape
    NB, bs, KV, _ = kp.shape
    MB = bt.shape[1]
    G = H // KV
    if scale is None:
        scale = Dh**-0.5
    k = kp[bt].reshape(B, MB * bs, KV, Dh).astype(jnp.float32)  # (B, S, KV, Dh)
    v = vp[bt].reshape(B, MB * bs, KV, Dh).astype(jnp.float32)
    qg = q.reshape(B, KV, G, Dh).astype(jnp.float32) * scale
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k)
    kpos = jnp.arange(MB * bs)[None, :]
    valid = kpos < lengths[:, None]  # (B, S)
    if window is not None:
        valid &= kpos >= lengths[:, None] - window
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(valid[:, None, None, :], jnp.exp(s - m), 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = jnp.where(denom > 0.0, p / jnp.maximum(denom, 1e-30), 0.0)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v)
    return out.reshape(B, H, Dh).astype(q.dtype)


def ref_paged_attention_q8(
    q: jnp.ndarray,  # (B, H, Dh)
    kp: jnp.ndarray,  # (NB, bs, KV, Dh) int8 key pool
    vp: jnp.ndarray,  # (NB, bs, KV, Dh) int8 value pool
    kps: jnp.ndarray,  # (NB, bs, KV) fp32 per-slot key scales
    vps: jnp.ndarray,  # (NB, bs, KV) fp32 per-slot value scales
    bt: jnp.ndarray,  # (B, MB) int32 block table
    lengths: jnp.ndarray,  # (B,) int32
    scale: Optional[float] = None,
    window: Optional[int] = None,
) -> jnp.ndarray:
    """int8-pool paged-attention oracle: dequantize the pools against their
    per-slot scales (one fp32 scalar per token-slot per KV head, stored in the
    same block layout as the codes), then the fp32 gathered-view softmax.  The
    Pallas kernel dequantizes the same values in-register; both paths compute
    ``k = k8 * s_k`` in fp32 before the dot, so agreement is to float
    tolerance, not bit-exact."""
    kd = kp.astype(jnp.float32) * kps.astype(jnp.float32)[..., None]
    vd = vp.astype(jnp.float32) * vps.astype(jnp.float32)[..., None]
    return ref_paged_attention(q, kd, vd, bt, lengths, scale=scale, window=window)


def _unpack_nibbles(packed: jnp.ndarray) -> jnp.ndarray:
    """Packed uint8 ``(..., D // 2)`` -> sign-extended int32 ``(..., D)``:
    element 2i from the low nibble, 2i+1 from the high, ``(x ^ 8) - 8``
    two's-complement sign extension (the layer-side pack/unpack convention)."""
    lo = (packed & 0xF).astype(jnp.int32)
    hi = (packed >> 4).astype(jnp.int32)
    se = lambda x: (x ^ 8) - 8
    out = jnp.stack([se(lo), se(hi)], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


def ref_paged_attention_q4(
    q: jnp.ndarray,  # (B, H, Dh)
    kp: jnp.ndarray,  # (NB, bs, KV, Dh // 2) packed uint8 key pool
    vp: jnp.ndarray,  # (NB, bs, KV, Dh // 2) packed uint8 value pool
    kps: jnp.ndarray,  # (NB, bs, KV) fp32 per-slot key scales
    vps: jnp.ndarray,  # (NB, bs, KV) fp32 per-slot value scales
    bt: jnp.ndarray,  # (B, MB) int32 block table
    lengths: jnp.ndarray,  # (B,) int32
    scale: Optional[float] = None,
    window: Optional[int] = None,
) -> jnp.ndarray:
    """Packed-int4-pool paged-attention oracle: unpack the nibble pairs,
    sign-extend, rescale against the per-slot fp32 scales, then the fp32
    gathered-view softmax.  Same dequant the kernel performs in register."""
    kd = _unpack_nibbles(kp).astype(jnp.float32) * kps.astype(jnp.float32)[..., None]
    vd = _unpack_nibbles(vp).astype(jnp.float32) * vps.astype(jnp.float32)[..., None]
    return ref_paged_attention(q, kd, vd, bt, lengths, scale=scale, window=window)


def ref_paged_mla_attention(
    q_lat: jnp.ndarray,  # (B, H, R) absorbed latent query
    q_pe: jnp.ndarray,  # (B, H, P) rope query half
    ckvp: jnp.ndarray,  # (NB, bs, R) latent pool (fp / int8 / packed uint8)
    kpep: jnp.ndarray,  # (NB, bs, P) rope-key pool
    bt: jnp.ndarray,  # (B, MB) int32 block table
    lengths: jnp.ndarray,  # (B,) int32
    ckvs: Optional[jnp.ndarray] = None,  # (NB, bs) fp32 latent scales
    kpes: Optional[jnp.ndarray] = None,
    *,
    scale: float,
    aq_scale: Optional[jnp.ndarray] = None,
    act_bits: Optional[int] = None,
) -> jnp.ndarray:
    """MLA absorbed-decode oracle: gather the compressed latent / rope-key
    pools through the block table (dequantizing int8 or packed-int4 codes
    against their per-token scales), optionally replay the A2Q activation
    fake-quant on the latent (``clip(round(x / aq_scale)) * aq_scale``, the
    absorb path's quantizer), then latent-space scores and PV:

        s = (q_lat @ ckv^T + q_pe @ kpe^T) * scale
        o_lat = softmax(s) @ ckv                         (B, H, R)

    The caller up-projects ``o_lat`` through ``w_v`` exactly as the absorbed
    layer path does."""
    B, H, R = q_lat.shape
    NB, bs = ckvp.shape[:2]
    MB = bt.shape[1]
    ckv = ckvp[bt].reshape(B, MB * bs, ckvp.shape[-1])
    kpe = kpep[bt].reshape(B, MB * bs, kpep.shape[-1])
    if ckvp.dtype == jnp.uint8:
        ckv = _unpack_nibbles(ckv)
        kpe = _unpack_nibbles(kpe)
    ckv = ckv.astype(jnp.float32)
    kpe = kpe.astype(jnp.float32)
    if ckvs is not None:
        ckv = ckv * ckvs[bt].reshape(B, MB * bs).astype(jnp.float32)[..., None]
        kpe = kpe * kpes[bt].reshape(B, MB * bs).astype(jnp.float32)[..., None]
    if act_bits is not None:
        n, p_max = -(1 << (act_bits - 1)), (1 << (act_bits - 1)) - 1
        s_aq = jnp.asarray(aq_scale, jnp.float32)
        ckv = jnp.clip(jnp.round(ckv / s_aq), n, p_max) * s_aq
    s = jnp.einsum("bhr,bsr->bhs", q_lat.astype(jnp.float32), ckv)
    s += jnp.einsum("bhp,bsp->bhs", q_pe.astype(jnp.float32), kpe)
    s *= scale
    kpos = jnp.arange(MB * bs)[None, :]
    valid = kpos < lengths[:, None]  # (B, S)
    s = jnp.where(valid[:, None, :], s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(valid[:, None, :], jnp.exp(s - m), 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = jnp.where(denom > 0.0, p / jnp.maximum(denom, 1e-30), 0.0)
    return jnp.einsum("bhs,bsr->bhr", p, ckv)


def ref_rwkv6(
    r: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,
    initial_state: Optional[jnp.ndarray] = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """RWKV-6 (Finch) linear-attention recurrence, naive scan oracle.

    Shapes (single head folded into batch): r/k/w ``(B, T, Dk)``, v
    ``(B, T, Dv)``, u ``(Dk,)`` bonus, state ``(B, Dk, Dv)``.

    Per step (data-dependent per-channel decay ``w_t`` in (0, 1)):
        y_t = r_t @ (S + (u * k_t) v_t^T)
        S   = diag(w_t) S + k_t v_t^T
    Returns (y ``(B, T, Dv)``, final state).
    """
    B, T, Dk = r.shape
    Dv = v.shape[-1]
    if initial_state is None:
        initial_state = jnp.zeros((B, Dk, Dv), jnp.float32)

    def step(S, inputs):
        r_t, k_t, v_t, w_t = inputs  # (B, Dk), (B, Dk), (B, Dv), (B, Dk)
        kv = k_t[:, :, None] * v_t[:, None, :]  # (B, Dk, Dv)
        y = jnp.einsum("bk,bkv->bv", r_t, S + u[None, :, None] * kv)
        S = w_t[:, :, None] * S + kv
        return S, y

    xs = (
        r.swapaxes(0, 1).astype(jnp.float32),
        k.swapaxes(0, 1).astype(jnp.float32),
        v.swapaxes(0, 1).astype(jnp.float32),
        w.swapaxes(0, 1).astype(jnp.float32),
    )
    S, ys = jax.lax.scan(step, initial_state, xs)
    return ys.swapaxes(0, 1).astype(r.dtype), S
