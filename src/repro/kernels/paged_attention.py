"""Pallas TPU kernel: paged-attention decode over block-table KV pools.

One query token per sequence attends to K/V scattered across fixed-size token
blocks (``serve/paged_cache.py`` owns the layout): pool ``(NB, bs, KV, Dh)``,
per-sequence block table ``bt (B, MB)``, per-sequence length.  The kernel
walks each row's table with the KV-block axis innermost and *gathers through
the table at the BlockSpec level*: the block table is a scalar-prefetch
operand (``pltpu.PrefetchScalarGridSpec``), so the index map of the K/V
operands reads ``bt[b, j]`` to pick which pool block the next grid step DMAs
into VMEM — the ``(B, MB * bs, ...)`` contiguous view is never materialized
(the jnp twin ``ref.ref_paged_attention`` materializes it; `ops.py` picks).

Softmax is the same fp32 online (running max / sum / accumulator) scheme as
``flash_attention.py``; GQA is handled by gridding over KV heads with the
``G = H // KV`` query group as the row dim of each score panel.  Key validity
comes from the per-row length: position ``j * bs + o`` participates iff it is
``< length`` — dead rows (length 0) produce a zero output via the flush-time
denominator guard, never a NaN.

Quantized pools (the int8 KV-cache serve path): with ``kps``/``vps`` — one
fp32 scale per block-slot per KV head, stored in the same ``(NB, bs, KV)``
block layout and gathered through the same table entry — the K/V operands are
int8 and the kernel dequantizes *in register* inside the online-softmax loop:
the int8 block is what DMAs from HBM (~4x less decode bandwidth than fp32),
the fp32 view never exists outside VMEM.  Oracle:
``ref.ref_paged_attention_q8``.

Packed int4 pools (uint8, two codes per byte, half the feature width) ride
the same scale machinery: the kernel detects the byte-width from the pool
dtype, DMAs the nibble-packed block, and unpacks + sign-extends in register
before the per-slot rescale — ~8x less decode bandwidth than fp32.  Oracle:
``ref.ref_paged_attention_q4``.

``paged_mla_attention_*`` is the latent-attention sibling for MLA absorbed
decode: scores are taken directly against the compressed ``(ckv, kpe)``
latent pools (rank R + rope P per token instead of H heads x Dh), the PV
accumulation reuses the *same* ckv block, and the per-head up-projections
stay outside the kernel.  Supports fp32 / int8 / packed-int4 latent pools
and an optional in-kernel activation fake-quant of the dequantized latent
(`clip(round(x/s)) * s`) so the absorbed-decode numerics — including the
A2Q activation quantizer the absorb path folds in — match the gathered
oracle ``ref.ref_paged_mla_attention``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "paged_attention_kernel",
    "paged_attention_pallas",
    "paged_mla_attention_kernel",
    "paged_mla_attention_pallas",
]

_NEG_INF = -1e30


def _unpack_nibbles_f32(u: jnp.ndarray) -> jnp.ndarray:
    """Packed uint8 ``(bs, D // 2)`` -> fp32 codes ``(bs, D)`` (element 2i in
    the low nibble, 2i+1 in the high; ``(x ^ 8) - 8`` sign extension) —
    in-register twin of the layer-side ``_unpack_nibbles``."""
    lo = (u & 0xF).astype(jnp.int32)
    hi = (u >> 4).astype(jnp.int32)
    se = lambda x: (x ^ 8) - 8
    codes = jnp.stack([se(lo), se(hi)], axis=-1)
    return codes.reshape(u.shape[0], u.shape[1] * 2).astype(jnp.float32)


def paged_attention_kernel(
    bt_ref,  # (B, MB) scalar-prefetch block table
    len_ref,  # (B,)   scalar-prefetch per-row lengths
    q_ref,  # (1, 1, G, Dh)
    k_ref,  # (1, bs, 1, Dh) — the pool block bt[b, j]; int8 when quantized
    v_ref,  # (1, bs, 1, Dh)
    *rest,  # quantized: (ks_ref, vs_ref, o_ref, scratch...) else (o_ref, ...)
    scale: float,
    block_size: int,
    mb_steps: int,
    quantized: bool,
    packed: bool = False,
    window: Optional[int] = None,
):
    if quantized:
        ks_ref, vs_ref = rest[0], rest[1]  # (1, bs, 1) fp32 per-slot scales
    o_ref, m_ref, l_ref, acc_ref = rest[-4:]
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (G, Dh)
    if packed:
        k = _unpack_nibbles_f32(k_ref[0, :, 0])  # (bs, Dh) from (bs, Dh // 2)
    else:
        k = k_ref[0, :, 0].astype(jnp.float32)  # (bs, Dh)
    if quantized:
        # in-register dequant: the fp32 K block exists only in VMEM
        k = k * ks_ref[0, :, 0][:, None]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (G, bs)

    length = len_ref[b]
    kpos = j * block_size + jax.lax.broadcasted_iota(jnp.int32, (1, block_size), 1)
    valid = kpos < length
    if window is not None:
        # the single decode query sits at position length - 1; a sliding
        # window admits keys in (length - 1 - window, length - 1], i.e.
        # kpos >= length - window
        valid &= kpos >= length - window
    s = jnp.where(valid, s, _NEG_INF)  # (G, bs) via broadcast

    m_prev = m_ref[...]  # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(valid, p, 0.0)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = m_new
    if packed:
        v = _unpack_nibbles_f32(v_ref[0, :, 0])
    else:
        v = v_ref[0, :, 0].astype(jnp.float32)
    if quantized:
        v = v * vs_ref[0, :, 0][:, None]
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_ref[...] = alpha * acc_ref[...] + pv

    @pl.when(j == mb_steps - 1)
    def _flush():
        l = l_ref[...]
        norm = jnp.where(l > 0.0, 1.0 / jnp.maximum(l, 1e-30), 0.0)
        o_ref[0, 0] = (acc_ref[...] * norm).astype(o_ref.dtype)


def paged_attention_pallas(
    q: jnp.ndarray,  # (B, KV, G, Dh)
    kp: jnp.ndarray,  # (NB, bs, KV, Dh)
    vp: jnp.ndarray,  # (NB, bs, KV, Dh)
    bt: jnp.ndarray,  # (B, MB) int32
    lengths: jnp.ndarray,  # (B,) int32
    kps: Optional[jnp.ndarray] = None,  # (NB, bs, KV) fp32 — int8 pool scales
    vps: Optional[jnp.ndarray] = None,
    *,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns ``(B, KV, G, Dh)`` attention outputs for one decode token per
    row.  ``lengths`` counts valid tokens (including this step's freshly
    written one); table entries past a row's length may point anywhere — they
    are loaded and fully masked.  ``kps``/``vps`` given => ``kp``/``vp`` are
    int8 pools dequantized in-kernel against the per-slot scales.
    ``window`` masks to the sliding window ending at the query position
    (keys at ``kpos >= length - window``) — the windowed-decode coverage for
    ring/sliding-window archs.  uint8 pools are the nibble-packed int4 layout
    (feature width ``Dh // 2``) and are unpacked in register."""
    B, KV, G, Dh = q.shape
    NB, bs, _, Dhp = kp.shape
    MB = bt.shape[1]
    quantized = kps is not None
    packed = kp.dtype == jnp.uint8
    if packed and not quantized:
        raise ValueError("packed int4 pools need kps/vps scale pools")
    if scale is None:
        scale = Dh**-0.5

    kernel = functools.partial(
        paged_attention_kernel, scale=scale, block_size=bs, mb_steps=MB,
        quantized=quantized, packed=packed, window=window,
    )
    pool_spec = pl.BlockSpec(
        (1, bs, 1, Dhp), lambda b, h, j, bt_ref, len_ref: (bt_ref[b, j], 0, h, 0)
    )
    in_specs = [
        pl.BlockSpec((1, 1, G, Dh), lambda b, h, j, bt_ref, len_ref: (b, h, 0, 0)),
        pool_spec,
        pool_spec,
    ]
    operands = [q, kp, vp]
    if quantized:
        scale_spec = pl.BlockSpec(
            (1, bs, 1), lambda b, h, j, bt_ref, len_ref: (bt_ref[b, j], 0, h)
        )
        in_specs += [scale_spec, scale_spec]
        operands += [kps.astype(jnp.float32), vps.astype(jnp.float32)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, MB),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, Dh), lambda b, h, j, bt_ref, len_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, Dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, Dh), q.dtype),
        interpret=interpret,
    )(bt.astype(jnp.int32), lengths.astype(jnp.int32), *operands)


# ---------------------------------------------------------------------------
# MLA latent attention: absorbed decode directly over the compressed pools.
# ---------------------------------------------------------------------------


def paged_mla_attention_kernel(
    bt_ref,  # (B, MB) scalar-prefetch block table
    len_ref,  # (B,)   scalar-prefetch per-row lengths
    ql_ref,  # (1, H, R)  absorbed query in latent space
    qp_ref,  # (1, H, P)  rope query half
    ckv_ref,  # (1, bs, R) latent block bt[b, j]; int8 / packed uint8 when quantized
    kpe_ref,  # (1, bs, P) rope-key block
    *rest,  # [ckvs_ref, kpes_ref][, aq_ref], o_ref, m, l, acc
    scale: float,
    block_size: int,
    mb_steps: int,
    quantized: bool,
    packed: bool,
    act_bits: Optional[int],
):
    idx = 0
    if quantized:
        ckvs_ref, kpes_ref = rest[idx], rest[idx + 1]  # (1, bs) fp32 per-token scales
        idx += 2
    if act_bits is not None:
        aq_ref = rest[idx]  # (1, 1) fp32 activation-quantizer scale
    o_ref, m_ref, l_ref, acc_ref = rest[-4:]
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ql = ql_ref[0].astype(jnp.float32)  # (H, R)
    qp = qp_ref[0].astype(jnp.float32)  # (H, P)
    if packed:
        ckv = _unpack_nibbles_f32(ckv_ref[0])  # (bs, R)
        kpe = _unpack_nibbles_f32(kpe_ref[0])  # (bs, P)
    else:
        ckv = ckv_ref[0].astype(jnp.float32)
        kpe = kpe_ref[0].astype(jnp.float32)
    if quantized:
        ckv = ckv * ckvs_ref[0][:, None]
        kpe = kpe * kpes_ref[0][:, None]
    if act_bits is not None:
        # The absorb path runs the latent through the up-projection's A2Q
        # activation quantizer; replay the fake-quant on the dequantized
        # block so score *and* PV see exactly the quantized latent.
        n = -(1 << (act_bits - 1))
        p_max = (1 << (act_bits - 1)) - 1
        s_aq = aq_ref[0, 0]
        ckv = jnp.clip(jnp.round(ckv / s_aq), n, p_max) * s_aq

    s = jax.lax.dot_general(
        ql, ckv, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (H, bs)
    s += jax.lax.dot_general(
        qp, kpe, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    s *= scale

    length = len_ref[b]
    kpos = j * block_size + jax.lax.broadcasted_iota(jnp.int32, (1, block_size), 1)
    valid = kpos < length
    s = jnp.where(valid, s, _NEG_INF)

    m_prev = m_ref[...]  # (H, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(valid, p, 0.0)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = m_new
    pv = jax.lax.dot_general(
        p, ckv, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (H, R) — PV reuses the same (dequantized, act-quantized) latent block
    acc_ref[...] = alpha * acc_ref[...] + pv

    @pl.when(j == mb_steps - 1)
    def _flush():
        l = l_ref[...]
        norm = jnp.where(l > 0.0, 1.0 / jnp.maximum(l, 1e-30), 0.0)
        o_ref[0] = (acc_ref[...] * norm).astype(o_ref.dtype)


def paged_mla_attention_pallas(
    q_lat: jnp.ndarray,  # (B, H, R) — q_nope absorbed through w_k
    q_pe: jnp.ndarray,  # (B, H, P)
    ckvp: jnp.ndarray,  # (NB, bs, R) latent pool (fp / int8 / packed uint8)
    kpep: jnp.ndarray,  # (NB, bs, P) rope-key pool
    bt: jnp.ndarray,  # (B, MB) int32
    lengths: jnp.ndarray,  # (B,) int32
    ckvs: Optional[jnp.ndarray] = None,  # (NB, bs) fp32 latent scales
    kpes: Optional[jnp.ndarray] = None,
    *,
    scale: float,
    aq_scale: Optional[jnp.ndarray] = None,  # scalar activation-quant scale
    act_bits: Optional[int] = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns ``(B, H, R)`` latent attention outputs (``o_lat``; the caller
    up-projects through ``w_v``).  ``scale`` is the absorbed score scale
    ``(qk_nope_dim + qk_rope_dim) ** -0.5`` — not derivable from the latent
    shapes, so it is required.  ``aq_scale``/``act_bits`` replay the A2Q
    activation fake-quant on the dequantized latent in register (``aq_scale``
    is a traced scalar, shipped as a ``(1, 1)`` operand)."""
    B, H, R = q_lat.shape
    P = q_pe.shape[-1]
    NB, bs = ckvp.shape[:2]
    MB = bt.shape[1]
    quantized = ckvs is not None
    packed = ckvp.dtype == jnp.uint8
    if packed and not quantized:
        raise ValueError("packed int4 latent pools need ckvs/kpes scale pools")
    if (act_bits is None) != (aq_scale is None):
        raise ValueError("aq_scale and act_bits must be given together")

    kernel = functools.partial(
        paged_mla_attention_kernel, scale=scale, block_size=bs, mb_steps=MB,
        quantized=quantized, packed=packed, act_bits=act_bits,
    )
    in_specs = [
        pl.BlockSpec((1, H, R), lambda b, j, bt_ref, len_ref: (b, 0, 0)),
        pl.BlockSpec((1, H, P), lambda b, j, bt_ref, len_ref: (b, 0, 0)),
        pl.BlockSpec((1, bs, ckvp.shape[-1]),
                     lambda b, j, bt_ref, len_ref: (bt_ref[b, j], 0, 0)),
        pl.BlockSpec((1, bs, kpep.shape[-1]),
                     lambda b, j, bt_ref, len_ref: (bt_ref[b, j], 0, 0)),
    ]
    operands = [q_lat, q_pe, ckvp, kpep]
    if quantized:
        scale_spec = pl.BlockSpec(
            (1, bs), lambda b, j, bt_ref, len_ref: (bt_ref[b, j], 0)
        )
        in_specs += [scale_spec, scale_spec]
        operands += [ckvs.astype(jnp.float32), kpes.astype(jnp.float32)]
    if act_bits is not None:
        in_specs.append(pl.BlockSpec((1, 1), lambda b, j, bt_ref, len_ref: (0, 0)))
        operands.append(jnp.asarray(aq_scale, jnp.float32).reshape(1, 1))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, MB),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, H, R), lambda b, j, bt_ref, len_ref: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, R), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, R), jnp.float32),
        interpret=interpret,
    )(bt.astype(jnp.int32), lengths.astype(jnp.int32), *operands)
