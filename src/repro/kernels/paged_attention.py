"""Pallas TPU kernel: paged-attention decode over block-table KV pools.

One query token per sequence attends to K/V scattered across fixed-size token
blocks (``serve/paged_cache.py`` owns the layout): pool ``(NB, bs, KV, Dh)``,
per-sequence block table ``bt (B, MB)``, per-sequence length.  The kernel
walks each row's table with the KV-block axis innermost and *gathers through
the table at the BlockSpec level*: the block table is a scalar-prefetch
operand (``pltpu.PrefetchScalarGridSpec``), so the index map of the K/V
operands reads ``bt[b, j]`` to pick which pool block the next grid step DMAs
into VMEM — the ``(B, MB * bs, ...)`` contiguous view is never materialized
(the jnp twin ``ref.ref_paged_attention`` materializes it; `ops.py` picks).

Softmax is the same fp32 online (running max / sum / accumulator) scheme as
``flash_attention.py``; GQA is handled by gridding over KV heads with the
``G = H // KV`` query group as the row dim of each score panel.  Key validity
comes from the per-row length: position ``j * bs + o`` participates iff it is
``< length`` — dead rows (length 0) produce a zero output via the flush-time
denominator guard, never a NaN.

Quantized pools (the int8 KV-cache serve path): with ``kps``/``vps`` — one
fp32 scale per block-slot per KV head, stored in the same ``(NB, bs, KV)``
block layout and gathered through the same table entry — the K/V operands are
int8 and the kernel dequantizes *in register* inside the online-softmax loop:
the int8 block is what DMAs from HBM (~4x less decode bandwidth than fp32),
the fp32 view never exists outside VMEM.  Oracle:
``ref.ref_paged_attention_q8``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["paged_attention_kernel", "paged_attention_pallas"]

_NEG_INF = -1e30


def paged_attention_kernel(
    bt_ref,  # (B, MB) scalar-prefetch block table
    len_ref,  # (B,)   scalar-prefetch per-row lengths
    q_ref,  # (1, 1, G, Dh)
    k_ref,  # (1, bs, 1, Dh) — the pool block bt[b, j]; int8 when quantized
    v_ref,  # (1, bs, 1, Dh)
    *rest,  # quantized: (ks_ref, vs_ref, o_ref, scratch...) else (o_ref, ...)
    scale: float,
    block_size: int,
    mb_steps: int,
    quantized: bool,
    window: Optional[int] = None,
):
    if quantized:
        ks_ref, vs_ref = rest[0], rest[1]  # (1, bs, 1) fp32 per-slot scales
    o_ref, m_ref, l_ref, acc_ref = rest[-4:]
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (G, Dh)
    k = k_ref[0, :, 0].astype(jnp.float32)  # (bs, Dh)
    if quantized:
        # in-register dequant: the fp32 K block exists only in VMEM
        k = k * ks_ref[0, :, 0][:, None]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (G, bs)

    length = len_ref[b]
    kpos = j * block_size + jax.lax.broadcasted_iota(jnp.int32, (1, block_size), 1)
    valid = kpos < length
    if window is not None:
        # the single decode query sits at position length - 1; a sliding
        # window admits keys in (length - 1 - window, length - 1], i.e.
        # kpos >= length - window
        valid &= kpos >= length - window
    s = jnp.where(valid, s, _NEG_INF)  # (G, bs) via broadcast

    m_prev = m_ref[...]  # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(valid, p, 0.0)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = m_new
    v = v_ref[0, :, 0].astype(jnp.float32)
    if quantized:
        v = v * vs_ref[0, :, 0][:, None]
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_ref[...] = alpha * acc_ref[...] + pv

    @pl.when(j == mb_steps - 1)
    def _flush():
        l = l_ref[...]
        norm = jnp.where(l > 0.0, 1.0 / jnp.maximum(l, 1e-30), 0.0)
        o_ref[0, 0] = (acc_ref[...] * norm).astype(o_ref.dtype)


def paged_attention_pallas(
    q: jnp.ndarray,  # (B, KV, G, Dh)
    kp: jnp.ndarray,  # (NB, bs, KV, Dh)
    vp: jnp.ndarray,  # (NB, bs, KV, Dh)
    bt: jnp.ndarray,  # (B, MB) int32
    lengths: jnp.ndarray,  # (B,) int32
    kps: Optional[jnp.ndarray] = None,  # (NB, bs, KV) fp32 — int8 pool scales
    vps: Optional[jnp.ndarray] = None,
    *,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns ``(B, KV, G, Dh)`` attention outputs for one decode token per
    row.  ``lengths`` counts valid tokens (including this step's freshly
    written one); table entries past a row's length may point anywhere — they
    are loaded and fully masked.  ``kps``/``vps`` given => ``kp``/``vp`` are
    int8 pools dequantized in-kernel against the per-slot scales.
    ``window`` masks to the sliding window ending at the query position
    (keys at ``kpos >= length - window``) — the windowed-decode coverage for
    ring/sliding-window archs."""
    B, KV, G, Dh = q.shape
    NB, bs, _, _ = kp.shape
    MB = bt.shape[1]
    quantized = kps is not None
    if scale is None:
        scale = Dh**-0.5

    kernel = functools.partial(
        paged_attention_kernel, scale=scale, block_size=bs, mb_steps=MB,
        quantized=quantized, window=window,
    )
    pool_spec = pl.BlockSpec(
        (1, bs, 1, Dh), lambda b, h, j, bt_ref, len_ref: (bt_ref[b, j], 0, h, 0)
    )
    in_specs = [
        pl.BlockSpec((1, 1, G, Dh), lambda b, h, j, bt_ref, len_ref: (b, h, 0, 0)),
        pool_spec,
        pool_spec,
    ]
    operands = [q, kp, vp]
    if quantized:
        scale_spec = pl.BlockSpec(
            (1, bs, 1), lambda b, h, j, bt_ref, len_ref: (bt_ref[b, j], 0, h)
        )
        in_specs += [scale_spec, scale_spec]
        operands += [kps.astype(jnp.float32), vps.astype(jnp.float32)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, MB),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, Dh), lambda b, h, j, bt_ref, len_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, Dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, Dh), q.dtype),
        interpret=interpret,
    )(bt.astype(jnp.int32), lengths.astype(jnp.int32), *operands)
