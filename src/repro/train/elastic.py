"""Elastic scaling + straggler mitigation.

Elastic restart: after losing nodes, the job restarts with a different device
count.  ``plan_mesh`` picks the largest valid (data, model) (or pod-extended)
mesh for the live devices while respecting the arch's TP divisibility; the
checkpoint's *global* arrays then re-shard onto the new mesh
(``checkpoint.restore(shardings=...)``).  Nothing about the checkpoint format
depends on the mesh that wrote it.

Straggler mitigation: on real fleets the symptom is step-time outliers on a
subset of hosts.  ``StragglerWatchdog`` keeps a rolling step-time window and
flags p95-relative outliers; the trainer's hook can then rebalance (drop the
pod from the mesh at the next elastic restart) or just alert.  The detection
logic is host-side and fully testable offline.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Optional, Sequence

import numpy as np

__all__ = ["plan_mesh", "StragglerWatchdog"]


def plan_mesh(
    n_devices: int,
    *,
    prefer_model: int = 16,
    model_divisors: Sequence[int] = (),
    max_pods: int = 64,
) -> dict:
    """Choose (pod, data, model) for a live device count.

    ``model_divisors``: unit counts the TP axis should divide (e.g. heads,
    d_ff); the planner degrades model-parallel width before data width.
    Returns {"shape": tuple, "axes": tuple} for ``jax.make_mesh``.
    """
    if n_devices <= 0:
        raise ValueError("no devices")
    model = min(prefer_model, n_devices)
    while model > 1:
        ok = n_devices % model == 0 and all(u % model == 0 for u in model_divisors if u)
        if ok:
            break
        model //= 2
    model = max(model, 1)
    rest = n_devices // model
    # prefer a pod axis of 2..max_pods when rest is even and large (cross-DCN
    # gradient reduction stays a single outer axis)
    pod = 1
    for cand in (2, 4, 8):
        if cand <= max_pods and rest % cand == 0 and rest // cand >= 2:
            pod = cand
            break
    data = rest // pod
    if pod > 1:
        return {"shape": (pod, data, model), "axes": ("pod", "data", "model")}
    return {"shape": (data, model), "axes": ("data", "model")}


@dataclasses.dataclass
class StragglerWatchdog:
    """Rolling p95 step-time outlier detector with a replace/alert hook."""

    window: int = 64
    threshold: float = 1.5  # step flagged if > threshold * rolling p95
    min_samples: int = 16
    on_straggler: Optional[Callable[[int, float, float], None]] = None
    _times: collections.deque = dataclasses.field(default_factory=lambda: collections.deque(maxlen=256))
    _flags: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, step_time: float) -> bool:
        """Record one step; True if this step is a straggler event."""
        history = list(self._times)[-self.window :]
        self._times.append(step_time)
        if len(history) < self.min_samples:
            return False
        p95 = float(np.percentile(history, 95))
        if step_time > self.threshold * p95:
            self._flags.append((step, step_time, p95))
            if self.on_straggler is not None:
                self.on_straggler(step, step_time, p95)
            return True
        return False

    @property
    def events(self):
        return tuple(self._flags)
