from repro.train import checkpoint, elastic, state, trainer  # noqa: F401
