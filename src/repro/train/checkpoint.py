"""Fault-tolerant checkpointing: atomic, keep-k, mesh-reshape on load.

Layout (one directory per step)::

    <dir>/step_000123/
        manifest.json       # leaf paths, shapes, dtypes, step, mesh shape
        arrays.npz          # one entry per leaf (globally-assembled values)
        _COMPLETE           # written last -> a checkpoint is valid iff present

Properties the 1000-node design needs:

* **atomic**: writes go to ``step_X.tmp`` then a single rename; a crash
  mid-save never corrupts the latest valid checkpoint;
* **keep-k** garbage collection;
* **mesh-reshape on load**: arrays are stored as *global* logical arrays and
  re-sharded onto whatever mesh/sharding the restarted job supplies — the
  elastic-restart path after losing a pod (``train/elastic.py``);
* **emergency save**: ``install_signal_handler`` flushes a checkpoint on
  SIGTERM (preemption) before exit.

On a multi-host cluster the npz write would become per-host shard files keyed
by device slice (the manifest already records per-leaf sharding); in this
single-process container every array is fully addressable so one file holds
the assembled global values.
"""

from __future__ import annotations

import json
import os
import shutil
import signal
import threading
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save", "restore", "latest_step", "install_signal_handler"]

_SENTINEL = "_COMPLETE"


def _leafkey(path) -> str:
    return jax.tree_util.keystr(path)


def save(directory: str, tree: Any, step: int, keep: int = 3) -> str:
    """Atomically write ``tree`` (any pytree of arrays/scalars) for ``step``."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {}
    manifest = {"step": int(step), "leaves": []}
    for i, (path, leaf) in enumerate(flat):
        key = f"leaf_{i:05d}"
        val = np.asarray(jax.device_get(leaf))
        arrays[key] = val
        manifest["leaves"].append(
            {"key": key, "path": _leafkey(path), "shape": list(val.shape), "dtype": str(val.dtype)}
        )
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    with open(os.path.join(tmp, _SENTINEL), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    steps = sorted(_valid_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)


def _valid_steps(directory: str):
    out = []
    if not os.path.isdir(directory):
        return out
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, _SENTINEL)):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
    return out


def latest_step(directory: str) -> Optional[int]:
    steps = _valid_steps(directory)
    return max(steps) if steps else None


def restore(
    directory: str,
    like: Any,
    step: Optional[int] = None,
    shardings: Any = None,
    allow_missing: bool = False,
) -> tuple[Any, int]:
    """Load a checkpoint into the structure of ``like``.

    ``shardings`` (optional pytree of NamedSharding matching ``like``) re-lays
    the global arrays onto the *current* mesh — which may have a different
    shape than the mesh that saved them (elastic restart).

    ``allow_missing`` keeps the ``like`` value for leaves the checkpoint does
    not record instead of raising — the path that turns on gradient
    compression mid-run: the ``grad_err`` residual tree is absent from older
    checkpoints and simply restarts from zeros.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint under {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    if not os.path.exists(os.path.join(d, _SENTINEL)):
        raise FileNotFoundError(f"checkpoint {d} is incomplete")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(d, "arrays.npz"))

    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    by_path = {m["path"]: m for m in manifest["leaves"]}
    shard_flat = None
    if shardings is not None:
        shard_flat = treedef.flatten_up_to(shardings)
    leaves = []
    for i, (path, leaf) in enumerate(flat_like):
        key = _leafkey(path)
        if key not in by_path:
            if allow_missing:
                val = np.asarray(jax.device_get(leaf))
            else:
                raise KeyError(f"checkpoint missing leaf {key}")
        else:
            val = arrays[by_path[key]["key"]]
        if tuple(val.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {key}: ckpt {val.shape} vs expected {np.shape(leaf)}")
        if shard_flat is not None:
            leaves.append(jax.device_put(val, shard_flat[i]))
        else:
            leaves.append(jnp.asarray(val))
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def install_signal_handler(save_fn: Callable[[], None], signals=(signal.SIGTERM, signal.SIGINT)):
    """Emergency checkpoint on preemption.  ``save_fn`` must be reentrant-safe
    (the trainer passes a closure over its latest completed state)."""
    done = threading.Event()

    def handler(signum, frame):
        if not done.is_set():
            done.set()
            save_fn()
        raise SystemExit(128 + signum)

    for s in signals:
        signal.signal(s, handler)
    return done
