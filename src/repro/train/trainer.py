"""Training loop: jitted step, metrics, checkpointing, watchdog, emergency
save.  Works identically on 1 CPU device (examples/tests) and on a production
mesh (launch/train.py passes shardings + Runtime)."""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.train import checkpoint as ckpt
from repro.train.elastic import StragglerWatchdog

__all__ = ["Trainer", "TrainLoopResult"]


@dataclasses.dataclass
class TrainLoopResult:
    state: Any
    history: list
    straggler_events: tuple


class Trainer:
    """Drives ``step_fn(state, batch) -> (state, metrics)`` over a stateless
    batch source (``batch_fn(step) -> dict``)."""

    def __init__(
        self,
        step_fn: Callable,
        batch_fn: Callable[[int], dict],
        *,
        ckpt_dir: Optional[str] = None,
        ckpt_every: int = 100,
        keep: int = 3,
        log_every: int = 10,
        donate: bool = True,
        watchdog: Optional[StragglerWatchdog] = None,
        shard_batch: Optional[Callable[[dict], Any]] = None,
    ):
        self.batch_fn = batch_fn
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.keep = keep
        self.log_every = log_every
        self.watchdog = watchdog or StragglerWatchdog()
        self.shard_batch = shard_batch or (lambda b: {k: jnp.asarray(v) for k, v in b.items()})
        self.step_fn = jax.jit(step_fn, donate_argnums=(0,) if donate else ())
        self._last_state = None

    def maybe_restore(self, state, allow_missing: bool = False):
        """Resume from the latest valid checkpoint if one exists (the data
        stream is stateless, so the step index fully restores the run).

        ``allow_missing`` tolerates state leaves absent from the checkpoint
        (e.g. resuming with gradient compression newly enabled: the
        ``grad_err`` residuals restart from zeros)."""
        if self.ckpt_dir is None:
            return state, 0
        latest = ckpt.latest_step(self.ckpt_dir)
        if latest is None:
            return state, 0
        tree, step = ckpt.restore(self.ckpt_dir, state, allow_missing=allow_missing)
        return tree, int(step)

    def emergency_save(self):
        if self.ckpt_dir is not None and self._last_state is not None:
            step = int(jax.device_get(self._last_state["step"]))
            ckpt.save(self.ckpt_dir, self._last_state, step, keep=self.keep)

    def run(self, state, n_steps: int, start_step: Optional[int] = None) -> TrainLoopResult:
        history = []
        start = start_step if start_step is not None else int(jax.device_get(state["step"]))
        for i in range(start, start + n_steps):
            batch = self.shard_batch(self.batch_fn(i))
            t0 = time.perf_counter()
            state, metrics = self.step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self._last_state = state
            self.watchdog.observe(i, dt)
            if i % self.log_every == 0 or i == start + n_steps - 1:
                rec = {k: float(jax.device_get(v)) for k, v in metrics.items()}
                rec.update(step=i, step_time=dt)
                history.append(rec)
            if self.ckpt_dir is not None and (i + 1) % self.ckpt_every == 0:
                ckpt.save(self.ckpt_dir, state, i + 1, keep=self.keep)
        if self.ckpt_dir is not None:
            ckpt.save(self.ckpt_dir, state, start + n_steps, keep=self.keep)
        return TrainLoopResult(state, history, self.watchdog.events)
