"""Train state: params + optimizer state + step, with sharding derivation."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import ShardingRules, param_specs
from repro.nn.module import axes_tree, unbox
from repro.optim.optimizers import Optimizer

__all__ = ["TrainState", "make_state_specs"]


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jnp.ndarray

    def tree(self):
        return {"params": self.params, "opt_state": self.opt_state, "step": self.step}

    @staticmethod
    def from_tree(t):
        return TrainState(t["params"], t["opt_state"], t["step"])


def init_state(boxed_params, optimizer: Optimizer) -> TrainState:
    params = unbox(boxed_params)
    return TrainState(params, optimizer.init(params), jnp.zeros((), jnp.int32))


def make_state_specs(boxed_params, optimizer: Optimizer, mesh: Mesh, rules: ShardingRules):
    """PartitionSpec tree for a TrainState.tree().

    Optimizer states mirror param structure leaf-for-leaf (momentum/variance)
    or reduce a trailing axis (adafactor vr/vc); both inherit the param's spec
    (trimmed for reduced axes) — ZeRO-1 + ZeRO-3 by construction.
    """
    pspecs = param_specs(boxed_params, mesh, rules)
    params = unbox(boxed_params)
    opt_shapes = jax.eval_shape(optimizer.init, params)

    def spec_for(path, leaf):
        # paths look like ('m', <param path...>) / ('v', ...) / ('count',)
        if leaf.ndim == 0:
            return P()
        # try to locate the matching param leaf by stripping the head key
        sub = path[1:] if len(path) > 1 else path
        try:
            node = pspecs
            for k in sub:
                key = k.key if hasattr(k, "key") else k.idx if hasattr(k, "idx") else k
                node = node[key]
            spec = node
        except (KeyError, TypeError, IndexError):
            return P()
        if isinstance(spec, P):
            if len(spec) == leaf.ndim:
                return spec
            if len(spec) == leaf.ndim + 1:  # adafactor vr: trailing axis reduced
                return P(*tuple(spec)[:-1])
            if len(spec) == leaf.ndim - 1:
                return P(*tuple(spec), None)
            return P()
        return P()

    opt_spec = _map_with_path(spec_for, opt_shapes)
    state_spec = {
        "params": pspecs,
        "opt_state": opt_spec,
        "step": P(),
    }
    return state_spec


def _map_with_path(f, tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return jax.tree_util.tree_unflatten(treedef, [f(p, l) for p, l in flat])


def specs_to_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
