"""Train state: params + optimizer state + step, with sharding derivation."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.dist.collectives import GradCompressConfig, owner_dim, server_shape, strip_axis
from repro.dist.sharding import ShardingRules, param_specs
from repro.nn.module import axes_tree, unbox
from repro.optim.optimizers import Optimizer

__all__ = ["TrainState", "make_state_specs", "init_grad_err"]


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jnp.ndarray

    def tree(self):
        return {"params": self.params, "opt_state": self.opt_state, "step": self.step}

    @staticmethod
    def from_tree(t):
        return TrainState(t["params"], t["opt_state"], t["step"])


def init_state(boxed_params, optimizer: Optimizer) -> TrainState:
    params = unbox(boxed_params)
    return TrainState(params, optimizer.init(params), jnp.zeros((), jnp.int32))


def init_grad_err(params, n_shards: int, pspecs=None, axis: Optional[str] = None):
    """Zero error-feedback residuals for the compressed gradient reduction.

    The residual *pair* of ``dist.collectives.compressed_allreduce``:

    * ``local``  — phase-1 (quantization) residual, one fp32 row per
      compression shard per param leaf: leaf ``(d0, ...)`` ->
      ``(n_shards, d0, ...)``; row ``i`` is shard ``i``'s private residual.
    * ``server`` — phase-2 (requantization) residual kept by each owner:
      param-shaped with the ownership dim padded to a multiple of
      ``n_shards`` (``server_shape``), owner-sharded over the compression
      axis.  ``pspecs``/``axis`` (the param PartitionSpec tree and the
      compression axis) pick the same per-leaf ownership dim the reduction
      uses; omitted = dim 0 everywhere (unsharded layouts).

    Works on real arrays and ``jax.eval_shape`` trees alike.
    """
    local = jax.tree.map(
        lambda p: jnp.zeros((n_shards,) + tuple(p.shape), jnp.float32), params
    )
    if pspecs is None:
        server = jax.tree.map(
            lambda p: jnp.zeros(server_shape(p.shape, n_shards), jnp.float32), params
        )
    else:
        server = jax.tree.map(
            lambda p, s: jnp.zeros(
                server_shape(p.shape, n_shards, owner_dim(s, len(p.shape), axis)),
                jnp.float32,
            ),
            params,
            pspecs,
        )
    return {"local": local, "server": server}


def _grad_err_specs(pspecs, axis: str):
    """Residual specs: both trees lead with the compression axis (``local``
    on its per-shard stack dim, ``server`` on the post-all-to-all owner dim);
    trailing dims inherit the param's spec — minus any reuse of the
    compression axis (a PartitionSpec may not mention one mesh axis twice)."""

    def local_one(spec: P) -> P:
        return P(axis, *strip_axis(spec, axis))

    def server_one(spec: P) -> P:
        # server leaves are param-shaped (ownership dim padded): that dim
        # takes `axis`, every other dim keeps the param layout
        entries = strip_axis(spec, axis)
        if not entries:  # scalar param: server is (n_shards,)
            return P(axis)
        od = owner_dim(spec, len(entries), axis)
        entries[od] = axis
        return P(*entries)

    is_spec = lambda x: isinstance(x, P)
    return {
        "local": jax.tree.map(local_one, pspecs, is_leaf=is_spec),
        "server": jax.tree.map(server_one, pspecs, is_leaf=is_spec),
    }


def make_state_specs(
    boxed_params,
    optimizer: Optimizer,
    mesh: Mesh,
    rules: ShardingRules,
    grad_compress: Optional[GradCompressConfig] = None,
):
    """PartitionSpec tree for a TrainState.tree().

    Optimizer states mirror param structure leaf-for-leaf (momentum/variance)
    or reduce a trailing axis (adafactor vr/vc); both inherit the param's spec
    (trimmed for reduced axes) — ZeRO-1 + ZeRO-3 by construction.

    ``grad_compress`` (with a resolved ``axis``) adds the ``grad_err``
    residual tree: per-shard rows over the compression axis, trailing dims
    sharded like the params they mirror.
    """
    pspecs = param_specs(boxed_params, mesh, rules)
    params = unbox(boxed_params)
    opt_shapes = jax.eval_shape(optimizer.init, params)

    def spec_for(path, leaf):
        # paths look like ('m', <param path...>) / ('v', ...) / ('count',)
        if leaf.ndim == 0:
            return P()
        # try to locate the matching param leaf by stripping the head key
        sub = path[1:] if len(path) > 1 else path
        try:
            node = pspecs
            for k in sub:
                key = k.key if hasattr(k, "key") else k.idx if hasattr(k, "idx") else k
                node = node[key]
            spec = node
        except (KeyError, TypeError, IndexError):
            return P()
        if isinstance(spec, P):
            if len(spec) == leaf.ndim:
                return spec
            if len(spec) == leaf.ndim + 1:  # adafactor vr: trailing axis reduced
                return P(*tuple(spec)[:-1])
            if len(spec) == leaf.ndim - 1:
                return P(*tuple(spec), None)
            return P()
        return P()

    opt_spec = _map_with_path(spec_for, opt_shapes)
    state_spec = {
        "params": pspecs,
        "opt_state": opt_spec,
        "step": P(),
    }
    if grad_compress is not None:
        if grad_compress.axis is None:
            raise ValueError("grad_compress.axis must be resolved (resolve_grad_compress)")
        state_spec["grad_err"] = _grad_err_specs(pspecs, grad_compress.axis)
    return state_spec


def _map_with_path(f, tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return jax.tree_util.tree_unflatten(treedef, [f(p, l) for p, l in flat])


def specs_to_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
