"""The paper's four benchmark networks (Sec. 5.1, App. B), in JAX with
QuantConv/QuantLinear so baseline-QAT and A2Q train exactly as in the paper:

* MobileNetV1 (CIFAR10 variant: stride-2 first conv, stride-2 final pool)
* ResNet18    (CIFAR10 variant: 3x3 s1 stem, no maxpool, conv shortcuts)
* ESPCN       (3x SISR, sub-pixel conv replaced by NNRC as in App. B.2)
* UNet        (3 enc/3 dec, NNRC upsampling, adds instead of concats)

All hidden activations are ReLU -> unsigned activation quantizers; first/last
layers stay 8-bit (App. B).  These models feed benchmarks/fig4-fig6 and the
LUT co-design study; layer geometries for the cost model come from
``layer_geometries``.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import QuantConfig
from repro.core.lut import LayerGeometry
from repro.nn.linear import apply_conv, apply_linear, init_conv, init_linear
from repro.nn.module import box, unbox
from repro.nn.transformer import tree_a2q_penalty

__all__ = [
    "init_mobilenet_v1",
    "apply_mobilenet_v1",
    "init_resnet18",
    "apply_resnet18",
    "init_espcn",
    "apply_espcn",
    "init_unet",
    "apply_unet",
    "init_linear_classifier",
    "apply_linear_classifier",
    "vision_penalty",
    "VISION_MODELS",
]

relu = jax.nn.relu


def _bn_init(c):
    return {"scale": box(jnp.ones((c,), jnp.float32), (None,)),
            "bias": box(jnp.zeros((c,), jnp.float32), (None,))}


def _bn(p, x):
    """Batch-stat normalization + affine.  Batch statistics keep the quantized
    activation distributions in range through depth (QAT needs this — fixed
    affine drifts below the act-quant step and the net dies at init).  FINN
    absorbs the affine into threshold logic at deploy time (App. C)."""
    mu = x.mean(axis=(0, 1, 2))
    var = x.var(axis=(0, 1, 2))
    xn = (x - mu) * jax.lax.rsqrt(var + 1e-5)
    return xn * p["scale"] + p["bias"]


# ---------------------------------------------------------------------------
# 1-layer binary-MNIST classifier (Fig. 2 / App. A motivating example)
# ---------------------------------------------------------------------------


def init_linear_classifier(key, q: QuantConfig, d_in: int = 784, n_out: int = 2) -> dict:
    # K=784, 1-bit unsigned inputs, 8-bit weights: the paper's exact setup.
    # act_absmax=1: the inputs are already {0,1}, the 1-bit quantizer is identity.
    return {"fc": init_linear(key, d_in, n_out, q, axes=(None, None), input_signed=False,
                              use_bias=False, act_absmax=1.0)}


def apply_linear_classifier(params, x, q: QuantConfig):
    return apply_linear(params["fc"], x, q, input_signed=False, compute_dtype=jnp.float32)


# ---------------------------------------------------------------------------
# MobileNetV1 (App. B.1)
# ---------------------------------------------------------------------------

# (depthwise stride) for each of the 13 separable blocks, CIFAR variant
_MBN_CFG = [(64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2),
            (512, 1), (512, 1), (512, 1), (512, 1), (512, 1), (1024, 2), (1024, 1)]


def init_mobilenet_v1(key, q: QuantConfig, n_classes: int = 10, width: float = 1.0) -> dict:
    ks = iter(jax.random.split(key, 64))
    w = lambda c: max(int(c * width), 8)
    p: dict = {"stem": init_conv(next(ks), 3, w(32), (3, 3), q, boundary=True),
               "stem_bn": _bn_init(w(32)), "blocks": []}
    c_in = w(32)
    for c_out, stride in _MBN_CFG:
        c_out = w(c_out)
        p["blocks"].append({
            "dw": init_conv(next(ks), c_in, c_in, (3, 3), q, groups=c_in),
            "dw_bn": _bn_init(c_in),
            "pw": init_conv(next(ks), c_in, c_out, (1, 1), q),
            "pw_bn": _bn_init(c_out),
        })
        c_in = c_out
    p["head"] = init_linear(next(ks), c_in, n_classes, q, axes=(None, None),
                            boundary=True, input_signed=False, use_bias=True)
    return p


def apply_mobilenet_v1(params, x, q: QuantConfig):
    x = relu(_bn(params["stem_bn"], apply_conv(params["stem"], x, q, stride=(2, 2), boundary=True)))
    for b, (_, stride) in zip(params["blocks"], _MBN_CFG):
        x = relu(_bn(b["dw_bn"], apply_conv(b["dw"], x, q, stride=(stride, stride), groups=x.shape[-1])))
        x = relu(_bn(b["pw_bn"], apply_conv(b["pw"], x, q)))
    x = jnp.mean(x, axis=(1, 2))  # stride-2 global pool on 32x32 ends at 1x1
    return apply_linear(params["head"], x, q, boundary=True, input_signed=False,
                        compute_dtype=jnp.float32)


# ---------------------------------------------------------------------------
# ResNet18 (App. B.1: 3x3 s1 stem, conv shortcuts)
# ---------------------------------------------------------------------------


_RESNET_STRIDES = (1, 1, 2, 1, 2, 1, 2, 1)  # first block of groups 2-4 downsamples


def _init_basic(ks, c_in, c_out, q):
    return {
        "c1": init_conv(next(ks), c_in, c_out, (3, 3), q), "bn1": _bn_init(c_out),
        "c2": init_conv(next(ks), c_out, c_out, (3, 3), q), "bn2": _bn_init(c_out),
        "sc": init_conv(next(ks), c_in, c_out, (1, 1), q), "bn_sc": _bn_init(c_out),
    }


def init_resnet18(key, q: QuantConfig, n_classes: int = 10, width: float = 1.0) -> dict:
    ks = iter(jax.random.split(key, 64))
    w = lambda c: max(int(c * width), 8)
    p = {"stem": init_conv(next(ks), 3, w(64), (3, 3), q, boundary=True),
         "stem_bn": _bn_init(w(64)), "blocks": []}
    c_in = w(64)
    for c_out, blocks in [(w(64), 2), (w(128), 2), (w(256), 2), (w(512), 2)]:
        for i in range(blocks):
            p["blocks"].append(_init_basic(ks, c_in, c_out, q))
            c_in = c_out
    p["head"] = init_linear(next(ks), c_in, n_classes, q, axes=(None, None),
                            boundary=True, input_signed=False, use_bias=True)
    return p


def apply_resnet18(params, x, q: QuantConfig):
    x = relu(_bn(params["stem_bn"], apply_conv(params["stem"], x, q, boundary=True)))
    for b, stride in zip(params["blocks"], _RESNET_STRIDES):
        s = (stride, stride)
        h = relu(_bn(b["bn1"], apply_conv(b["c1"], x, q, stride=s)))
        h = _bn(b["bn2"], apply_conv(b["c2"], h, q))
        sc = _bn(b["bn_sc"], apply_conv(b["sc"], x, q, stride=s))
        x = relu(h + sc)
    x = jnp.mean(x, axis=(1, 2))
    return apply_linear(params["head"], x, q, boundary=True, input_signed=False,
                        compute_dtype=jnp.float32)


# ---------------------------------------------------------------------------
# ESPCN / UNet (App. B.2) — NNRC = nearest-neighbor resize + conv
# ---------------------------------------------------------------------------


def _nn_resize(x, factor: int):
    B, H, W, C = x.shape
    return jax.image.resize(x, (B, H * factor, W * factor, C), method="nearest")


def init_espcn(key, q: QuantConfig, upscale: int = 3) -> dict:
    ks = iter(jax.random.split(key, 8))
    return {
        "c1": init_conv(next(ks), 1, 64, (5, 5), q, boundary=True),
        "c2": init_conv(next(ks), 64, 64, (3, 3), q),
        "c3": init_conv(next(ks), 64, 32, (3, 3), q),
        "out": init_conv(next(ks), 32, 1, (3, 3), q, boundary=True),
    }


def apply_espcn(params, x, q: QuantConfig, upscale: int = 3):
    x = relu(apply_conv(params["c1"], x, q, boundary=True))
    x = relu(apply_conv(params["c2"], x, q))
    x = relu(apply_conv(params["c3"], x, q))
    x = _nn_resize(x, upscale)
    return apply_conv(params["out"], x, q, boundary=True)


def init_unet(key, q: QuantConfig, base: int = 32, upscale: int = 3) -> dict:
    ks = iter(jax.random.split(key, 32))
    c = [base, base * 2, base * 4]
    p = {"stem": init_conv(next(ks), 1, c[0], (3, 3), q, boundary=True), "enc": [], "dec": []}
    for cin, cout in [(c[0], c[1]), (c[1], c[2]), (c[2], c[2])]:
        p["enc"].append({"c1": init_conv(next(ks), cin, cout, (3, 3), q),
                         "c2": init_conv(next(ks), cout, cout, (3, 3), q)})
    # decoder outputs must match the skip channels: skips are (c0, c1, c2)
    for cin, cout in [(c[2], c[2]), (c[2], c[1]), (c[1], c[0])]:
        p["dec"].append({"c1": init_conv(next(ks), cin, cout, (3, 3), q),
                         "c2": init_conv(next(ks), cout, cout, (3, 3), q)})
    p["up"] = init_conv(next(ks), c[0], c[0], (3, 3), q)
    p["out"] = init_conv(next(ks), c[0], 1, (3, 3), q, boundary=True)
    return p


def apply_unet(params, x, q: QuantConfig, upscale: int = 3):
    x = relu(apply_conv(params["stem"], x, q, boundary=True))
    skips = []
    for e in params["enc"]:
        skips.append(x)
        x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "SAME")
        x = relu(apply_conv(e["c1"], x, q))
        x = relu(apply_conv(e["c2"], x, q))
    for d, skip in zip(params["dec"], reversed(skips)):
        x = _nn_resize(x, 2)
        x = relu(apply_conv(d["c1"], x, q))
        x = relu(apply_conv(d["c2"], x, q))
        x = x + skip  # adds instead of concats (App. B.2)
    x = _nn_resize(x, upscale)
    x = relu(apply_conv(params["up"], x, q))
    return apply_conv(params["out"], x, q, boundary=True)


def vision_penalty(params, q: QuantConfig) -> jnp.ndarray:
    return tree_a2q_penalty(params, q)


def requantize_from_float(quant_tree, float_tree, q: QuantConfig):
    """Initialize a quantized model from trained float weights (paper App. B:
    'We initialize all models from floating-point counterparts pre-trained to
    convergence').  Walks the freshly-initialized quantized tree (which has
    the right aq/structure) and replaces every weight-derived leaf group with
    one calibrated from the float model's trained ``w``."""
    from repro.core.a2q import init_a2q
    from repro.core.quantizers import init_weight_qat

    def walk(qt, ft):
        if isinstance(qt, dict):
            if "v" in qt and "t" in qt and "d" in qt:
                a = init_a2q(ft["w"], q.weight_bits, q.acc_bits, q.act_bits, False)
                out = {**qt, **a}
                if "b" in ft:
                    out["b"] = ft["b"]
                return out
            if "w" in qt and "wq" in qt:
                wq = init_weight_qat(ft["w"], q.weight_bits)
                out = {**qt, "w": ft["w"], "wq": {"log2_scale": wq["log2_scale"]}}
                if "b" in ft:
                    out["b"] = ft["b"]
                return out
            return {k: walk(v, ft[k]) for k, v in qt.items()}
        if isinstance(qt, list):
            return [walk(a, b) for a, b in zip(qt, ft)]
        # plain leaves (bn scales, biases) copy the trained float values
        return ft if ft is not None else qt

    return walk(quant_tree, float_tree)


VISION_MODELS = {
    "mobilenetv1": (init_mobilenet_v1, apply_mobilenet_v1),
    "resnet18": (init_resnet18, apply_resnet18),
    "espcn": (init_espcn, apply_espcn),
    "unet": (init_unet, apply_unet),
}


def layer_geometries(params, q: QuantConfig, input_hw: tuple[int, int] = (32, 32)) -> list[LayerGeometry]:
    """Rough per-layer geometry extraction for the LUT cost model: walks conv/
    linear param subtrees, derives (K, C_out, MACs) from weight shapes.  MAC
    spatial factors assume the CIFAR/BSD pipeline resolution."""
    from repro.core.a2q import a2q_int_weights
    import numpy as np

    geoms = []

    def walk(node):
        if isinstance(node, dict):
            keyset = set(node.keys())
            if ("v" in keyset and "t" in keyset) or "w" in keyset:
                wshape = (node["v"] if "v" in node else node["w"]).shape
                if len(wshape) == 4:
                    kh, kw, ci, co = wshape
                    k = kh * kw * ci
                    spatial = input_hw[0] * input_hw[1]
                else:
                    k, co = wshape
                    spatial = 1
                sparsity = 0.0
                if "v" in node:
                    qi, _ = a2q_int_weights(
                        {"v": node["v"], "t": node["t"], "d": node["d"]},
                        q.weight_bits, q.acc_bits, q.act_bits, False,
                    )
                    sparsity = float(np.mean(np.asarray(qi) == 0))
                geoms.append(LayerGeometry(
                    k=int(k), c_out=int(co), macs=int(k * co * spatial),
                    weight_bits=q.weight_bits, input_bits=q.act_bits,
                    output_bits=q.act_bits, acc_bits=q.acc_bits, sparsity=sparsity,
                ))
            else:
                for v in node.values():
                    walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)

    walk(params)
    return geoms
