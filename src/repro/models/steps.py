"""Step builders shared by the trainer, the serve engine, and the dry-run.

Each builder returns a pure function suitable for ``jax.jit`` with explicit
in/out shardings:

* ``build_train_step``  — fwd + bwd + grad-clip + optimizer update (+donation)
* ``build_prefill_step``— forward over a full prompt, returns last-position
  logits + the populated KV cache
* ``build_serve_step``  — one decode token against a KV cache

The dry-run lowers these exact functions for every (arch x shape x mesh) cell;
nothing is special-cased for compilation.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.dist.collectives import compressed_allreduce_tree, resolve_grad_compress
from repro.dist.sharding import ShardingRules, constrain, param_specs
from repro.models.lm import Runtime, apply_lm, init_cache, init_lm, lm_loss
from repro.optim.optimizers import Optimizer, clip_by_global_norm

__all__ = ["build_train_step", "build_prefill_step", "build_serve_step"]


def _strip_axis_rules(rules: Optional[ShardingRules], axis: str) -> Optional[ShardingRules]:
    """Rules for the per-shard (vmapped) model pass of the compressed step:
    the compression axis carries the *group* dim, so activation constraints
    inside the model may only mention the remaining mesh axes."""
    if rules is None:
        return None
    return ShardingRules(
        rules={k: tuple(a for a in v if a != axis) for k, v in rules.rules.items()},
        unit_counts=dict(rules.unit_counts),
    )


def build_train_step(
    arch: ArchConfig,
    optimizer: Optimizer,
    rt: Optional[Runtime] = None,
    lr_schedule: Optional[Callable] = None,
    grad_clip: float = 1.0,
):
    rt = rt or Runtime()
    lr_schedule = lr_schedule or (lambda step: jnp.asarray(3e-4, jnp.float32))
    gc = resolve_grad_compress(rt.grad_compress, rt.mesh)
    if gc is not None:
        return _build_compressed_train_step(arch, optimizer, rt, lr_schedule, grad_clip, gc)

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params, opt_state, step = state["params"], state["opt_state"], state["step"]

        def loss_fn(p):
            return lm_loss(p, arch, batch, rt=rt)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        lr = lr_schedule(step)
        new_params, new_opt = optimizer.update(grads, opt_state, params, lr)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return {"params": new_params, "opt_state": new_opt, "step": step + 1}, metrics

    return train_step


def _build_compressed_train_step(arch, optimizer, rt, lr_schedule, grad_clip, gc):
    """Train step whose data-parallel gradient reduction is the int-quantized
    two-phase ``compressed_allreduce_tree`` instead of the fp32 all-reduce
    GSPMD would emit.

    The global batch is split into ``n_shards`` groups along the compression
    axis (``pod`` on a multi-pod mesh: the DCN-crossing reduction) and the
    fwd+bwd is ``vmap``-ed over groups, so the per-group gradients — the
    quantities the baseline would immediately all-reduce in fp32 — stay
    visible as a stacked ``(n_shards, *shape)`` tree sharded over the axis.
    They then meet on the wire as ``bits``-wide integers via the GSPMD
    reshards inside ``compressed_allreduce_tree``.  (A shard_map over the
    axis would be the more direct spelling, but the pinned jaxlib's SPMD
    partitioner fatally rejects gather-family collectives and scanned
    attention blocks inside a partially-manual shard_map — see
    ``dist/collectives.py``.)

    The error-feedback residual pair is carried in ``state["grad_err"]``
    (see ``train.state.init_grad_err``); the global batch must be a
    multiple of the axis extent.  Grad-clip and the optimizer update run on
    the reduced gradient, exactly as in the uncompressed path.
    """
    mesh, axis = rt.mesh, gc.axis
    n_shards = int(mesh.shape[axis])
    inner_rt = Runtime(
        mesh=mesh,
        ep_axis=rt.ep_axis,
        rules=_strip_axis_rules(rt.rules, axis),
        mla_absorb=rt.mla_absorb,
    )
    # param layout tree: lets the reduction keep TP shardings on the wire
    pspec_tree = None
    if rt.rules is not None:
        boxed_shapes = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), arch))
        pspec_tree = param_specs(boxed_shapes, mesh, rt.rules)

    def group(t):
        if t.shape[0] % n_shards:
            raise ValueError(
                f"grad_compress: global batch {t.shape[0]} must be a "
                f"multiple of the {axis!r} axis extent {n_shards}"
            )
        t = t.reshape(n_shards, t.shape[0] // n_shards, *t.shape[1:])
        return constrain(t, mesh, P(axis, *([None] * (t.ndim - 1))))

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params, opt_state, step = state["params"], state["opt_state"], state["step"]
        grouped = jax.tree.map(group, batch)

        def loss_fn(p, b):
            return lm_loss(p, arch, b, rt=inner_rt)

        # spmd_axis_name pins the group dim to the compression axis through
        # every op of the vmapped fwd+bwd, so activations keep their
        # group-sharding instead of being gathered at each internal
        # sharding constraint
        (_, metrics), grads = jax.vmap(
            jax.value_and_grad(loss_fn, has_aux=True),
            in_axes=(None, 0),
            spmd_axis_name=axis,
        )(params, grouped)
        # each group saw 1/n of the global batch: the global-mean-loss
        # gradient is the mean of the per-group gradients
        grads = jax.tree.map(lambda g: g / n_shards, grads)
        grads, new_err = compressed_allreduce_tree(
            grads, state["grad_err"], mesh=mesh, axis=axis,
            bits=gc.bits, scale_axis=gc.scale_axis, pspec_tree=pspec_tree,
        )
        metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), metrics)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        lr = lr_schedule(step)
        new_params, new_opt = optimizer.update(grads, opt_state, params, lr)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return {
            "params": new_params,
            "opt_state": new_opt,
            "step": step + 1,
            "grad_err": new_err,
        }, metrics

    return train_step


def build_prefill_step(arch: ArchConfig, rt: Optional[Runtime] = None, max_seq: Optional[int] = None):
    """Prompt -> (last-position logits, cache filled up to the prompt length).

    The cache is produced by replaying the prompt's K/V into the cache layout
    in one shot (a scatter of the computed K/V), so prefill is a single
    forward pass — not T decode steps.
    """
    rt = rt or Runtime()

    def prefill_step(params: dict, batch: dict) -> tuple[jnp.ndarray, jnp.ndarray]:
        logits, _, _ = apply_lm(
            params, arch,
            tokens=batch.get("tokens"),
            frontend_embeds=batch.get("frontend_embeds"),
            rt=rt,
        )
        return logits[:, -1:, :]

    return prefill_step


def build_serve_step(arch: ArchConfig, rt: Optional[Runtime] = None):
    """(params, tokens (B,1), cache, pos) -> (logits (B,1,V), new cache)."""
    rt = rt or Runtime()

    def serve_step(params: dict, tokens: jnp.ndarray, cache: dict, pos: jnp.ndarray):
        logits, new_cache, _ = apply_lm(
            params, arch, tokens=tokens, cache=cache, start_pos=pos, rt=rt
        )
        return logits, new_cache

    return serve_step
