"""Step builders shared by the trainer, the serve engine, and the dry-run.

Each builder returns a pure function suitable for ``jax.jit`` with explicit
in/out shardings:

* ``build_train_step``  — fwd + bwd + grad-clip + optimizer update (+donation)
* ``build_prefill_step``— forward over a full prompt, returns last-position
  logits + the populated KV cache
* ``build_serve_step``  — one decode token against a KV cache

The dry-run lowers these exact functions for every (arch x shape x mesh) cell;
nothing is special-cased for compilation.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.lm import Runtime, apply_lm, init_cache, lm_loss
from repro.optim.optimizers import Optimizer, clip_by_global_norm

__all__ = ["build_train_step", "build_prefill_step", "build_serve_step"]


def build_train_step(
    arch: ArchConfig,
    optimizer: Optimizer,
    rt: Optional[Runtime] = None,
    lr_schedule: Optional[Callable] = None,
    grad_clip: float = 1.0,
):
    rt = rt or Runtime()
    lr_schedule = lr_schedule or (lambda step: jnp.asarray(3e-4, jnp.float32))

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params, opt_state, step = state["params"], state["opt_state"], state["step"]

        def loss_fn(p):
            return lm_loss(p, arch, batch, rt=rt)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        lr = lr_schedule(step)
        new_params, new_opt = optimizer.update(grads, opt_state, params, lr)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return {"params": new_params, "opt_state": new_opt, "step": step + 1}, metrics

    return train_step


def build_prefill_step(arch: ArchConfig, rt: Optional[Runtime] = None, max_seq: Optional[int] = None):
    """Prompt -> (last-position logits, cache filled up to the prompt length).

    The cache is produced by replaying the prompt's K/V into the cache layout
    in one shot (a scatter of the computed K/V), so prefill is a single
    forward pass — not T decode steps.
    """
    rt = rt or Runtime()

    def prefill_step(params: dict, batch: dict) -> tuple[jnp.ndarray, jnp.ndarray]:
        logits, _, _ = apply_lm(
            params, arch,
            tokens=batch.get("tokens"),
            frontend_embeds=batch.get("frontend_embeds"),
            rt=rt,
        )
        return logits[:, -1:, :]

    return prefill_step


def build_serve_step(arch: ArchConfig, rt: Optional[Runtime] = None):
    """(params, tokens (B,1), cache, pos) -> (logits (B,1,V), new cache)."""
    rt = rt or Runtime()

    def serve_step(params: dict, tokens: jnp.ndarray, cache: dict, pos: jnp.ndarray):
        logits, new_cache, _ = apply_lm(
            params, arch, tokens=tokens, cache=cache, start_pos=pos, rt=rt
        )
        return logits, new_cache

    return serve_step
