"""Decoder / encoder LM assembly: embeddings -> stacks -> head, plus the
train/prefill/decode forward passes used by the trainer, the serve engine, and
the multi-pod dry-run.

Families (configs/base.ArchConfig.family):
  * ``lm``     — token decoder (command-r, yi, danube, smollm, rwkv6, hymba,
                 deepseek-v3, llama4-scout)
  * ``vlm``    — llava-next: stub patch embeddings prepended to token embeds
  * ``audio``  — hubert: stub frame embeddings, bidirectional encoder,
                 504-way framewise classification head (no decode step)

The A2Q regularizer ``L_reg`` accumulates through every stack and is returned
next to the logits, so ``loss = task + lambda * penalty`` needs no second tree
walk (paper Sec. 4.1 / App. B, lambda = 1e-3).
"""

from __future__ import annotations

import contextlib
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.dist.sharding import ShardingRules, constrain
from repro.nn.embedding import apply_embedding, init_embedding
from repro.nn.linear import (
    apply_linear,
    chain_report_scope,
    init_linear,
    linear_penalty,
)
from repro.nn.module import box, unbox
from repro.nn.norms import apply_norm, init_norm
from repro.nn.transformer import apply_stack, init_stack, init_stack_cache

__all__ = ["init_lm", "apply_lm", "lm_loss", "init_cache", "Runtime"]


class Runtime:
    """Static (hashable) execution context threaded through the model: mesh,
    EP axis, activation-sharding rules, beyond-paper toggles.

    ``grad_compress`` (an optional ``dist.collectives.GradCompressConfig``)
    routes the data-parallel gradient reduction of ``build_train_step``
    through the int-quantized ``compressed_psum_tree`` instead of the fp32
    all-reduce GSPMD would emit.

    ``decode_kernel`` routes paged-attention decode reads through the Pallas
    kernel (``kernels/paged_attention.py``) instead of the gathered-view jnp
    path — the TPU serving fast path.

    ``int_forward`` routes deployed (``q8``/``s8``) linears through the fused
    W8A8 integer kernel (``kernels/int_matmul.py``) instead of dequant + a
    ``compute_dtype`` dot — the integer-fast serve path the A2Q accumulator
    guarantee makes safe.

    ``int_chain`` (implies ``int_forward``) keeps activations integer
    *between* deployed linears: producers requantize in their epilogue and
    pass ``(codes, scale)`` (``nn.linear.IntAct``) to chained consumers;
    chain-break consumers fold their act-quant into the kernel prologue —
    zero standalone act-quant dispatches on the serve path.
    ``chain_report`` holds the per-call-site disposition lists from the most
    recent forward trace (see ``nn.linear.chain_report_scope``).
    """

    def __init__(self, mesh=None, ep_axis=None, rules=None, mla_absorb=False,
                 grad_compress=None, decode_kernel=False, int_forward=False,
                 int_chain=False):
        self.mesh = mesh
        self.ep_axis = ep_axis
        self.rules = rules
        self.mla_absorb = mla_absorb
        self.grad_compress = grad_compress
        self.decode_kernel = decode_kernel
        self.int_forward = int_forward or int_chain
        self.int_chain = int_chain
        self.chain_report: dict = {}

    def batch_spec(self, ndim: int) -> P:
        if self.rules is None:
            return P()
        return P(self.rules.rules.get("batch") or None, *([None] * (ndim - 1)))


def init_lm(key, arch: ArchConfig):
    ks = jax.random.split(key, 8)
    params: dict = {}
    if arch.family != "audio":
        params["embed"] = init_embedding(ks[0], arch.vocab, arch.d_model)
    params["stacks"] = {
        str(i): init_stack(ks[1 + (i % 6)], arch, s) for i, s in enumerate(arch.stacks)
    }
    params["final_norm"] = init_norm(arch.d_model, arch.norm)
    if arch.family == "audio":
        params["head"] = init_linear(
            ks[7], arch.d_model, arch.n_classes, arch.quant,
            axes=("embed", None), boundary=True,
        )
    elif not arch.tie_embeddings:
        params["head"] = init_linear(
            ks[7], arch.d_model, arch.vocab, arch.quant,
            axes=("embed", "vocab"), boundary=True,
        )
    if arch.mtp_depth > 0:
        from repro.configs.base import StackConfig

        mtp_stack = arch.stacks[-1]
        params["mtp"] = {
            "proj": init_linear(ks[6], 2 * arch.d_model, arch.d_model, arch.quant,
                                axes=(None, "embed")),
            "block": init_stack(
                jax.random.fold_in(ks[6], 1), arch,
                StackConfig(kind="attn_mlp", count=1, attn=mtp_stack.attn,
                            d_ff=mtp_stack.d_ff or arch.d_model * 4,
                            mlp_gated=True),
            ),
            "norm_h": init_norm(arch.d_model, arch.norm),
            "norm_e": init_norm(arch.d_model, arch.norm),
        }
    return params


def _head_logits(params, arch: ArchConfig, h: jnp.ndarray, rt: Runtime) -> jnp.ndarray:
    cd = jnp.dtype(arch.compute_dtype)
    if arch.tie_embeddings and arch.family != "audio":
        logits = h.astype(cd) @ params["embed"]["table"].astype(cd).T
    else:
        logits = apply_linear(
            params["head"], h, arch.quant, boundary=True, compute_dtype=cd,
            int_forward=rt.int_forward, int_chain=rt.int_chain, site="head",
        )
    if rt.mesh is not None:
        batch = rt.rules.rules.get("batch") or ()
        # vocab axes minus any axis already carrying the batch dim (tp_extra
        # widens vocab onto 'data', which may also be the batch axis)
        vocab = tuple(a for a in (rt.rules.rules.get("vocab") or ()) if a not in batch)
        vspec = vocab[0] if len(vocab) == 1 else (tuple(vocab) if vocab else None)
        bspec = batch if batch else None
        if arch.family != "audio" and vocab and arch.vocab % _axis_prod(rt.mesh, vocab) == 0:
            logits = constrain(logits, rt.mesh, P(bspec, None, vspec))
        else:
            logits = constrain(logits, rt.mesh, P(bspec, None, None))
    return logits


def _axis_prod(mesh, axes) -> int:
    out = 1
    for a in axes or ():
        out *= mesh.shape[a]
    return out


def apply_lm(
    params: dict,
    arch: ArchConfig,
    *,
    tokens: Optional[jnp.ndarray] = None,
    frontend_embeds: Optional[jnp.ndarray] = None,
    cache: Optional[dict] = None,
    start_pos: Optional[jnp.ndarray] = None,
    rt: Optional[Runtime] = None,
    return_hidden: bool = False,
):
    """Forward pass.  ``cache`` given => cached step: ``tokens (B, T)`` with
    ``T == 1`` (decode) or ``T > 1`` (chunked prefill), written at each row's
    ``start_pos``.  A paged cache carries its block-table view under the
    reserved key ``"_paged"`` (see ``serve/paged_cache.py``); the returned
    cache holds only the per-stack state — the caller re-attaches the view.

    Returns (logits, new_cache, penalty[, hidden]).
    """
    rt = rt or Runtime()
    cd = jnp.dtype(arch.compute_dtype)

    parts = []
    if frontend_embeds is not None:
        parts.append(frontend_embeds.astype(cd))
    if tokens is not None:
        parts.append(apply_embedding(params["embed"], tokens, dtype=cd))
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    B, S, _ = x.shape
    x = constrain(x, rt.mesh, rt.batch_spec(3))

    view = cache.get("_paged") if cache is not None else None
    if cache is not None:
        assert start_pos is not None
        sp = jnp.asarray(start_pos, jnp.int32).reshape(-1)  # scalar or per-row (B,)
        base = sp[:, None] if sp.shape[0] == B else sp.reshape(1, 1)
        positions = jnp.broadcast_to(
            base + jnp.arange(S, dtype=jnp.int32)[None, :], (B, S)
        )
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    penalty = jnp.zeros((), jnp.float32)
    new_cache: dict = {}
    # the chain report is (re)populated at trace time: each jitted forward
    # traces every apply_linear call site once, so after compilation the
    # report lists exactly what the compiled program dispatches per step
    with contextlib.ExitStack() as _scope:
        if rt.int_forward:
            _scope.enter_context(chain_report_scope(rt.chain_report))
        for i, s in enumerate(arch.stacks):
            sp = params["stacks"][str(i)]
            sc = cache.get(str(i)) if cache is not None else None
            x, nc, pen = apply_stack(
                sp, x, arch, s, positions, sc,
                mesh=rt.mesh, ep_axis=rt.ep_axis, mla_absorb=rt.mla_absorb,
                view=view, decode_kernel=rt.decode_kernel,
                int_forward=rt.int_forward, int_chain=rt.int_chain,
            )
            x = constrain(x, rt.mesh, rt.batch_spec(3))
            if nc is not None:
                new_cache[str(i)] = nc
            penalty = penalty + pen

        h = apply_norm(params["final_norm"], x, kind=arch.norm, eps=arch.norm_eps)
        if "head" in params:
            penalty = penalty + linear_penalty(params["head"], arch.quant, True, True)
        logits = _head_logits(params, arch, h, rt)
    out_cache = new_cache if cache is not None else None
    if return_hidden:
        return logits, out_cache, penalty, h
    return logits, out_cache, penalty


def _cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray, z_loss: float = 1e-4):
    """Mean CE over all positions, fp32, with MaxText-style z-loss."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    ce = (lse - gold).mean()
    zl = z_loss * jnp.square(lse).mean()
    return ce + zl, ce


def lm_loss(params, arch: ArchConfig, batch: dict, rt: Optional[Runtime] = None):
    """Training loss: task CE + lambda * L_reg (+ MTP auxiliary).

    ``batch`` = {tokens [, frontend_embeds], targets} with targets aligned to
    the *full* (frontend + text) sequence.
    """
    rt = rt or Runtime()
    logits, _, penalty, h = apply_lm(
        params, arch,
        tokens=batch.get("tokens"),
        frontend_embeds=batch.get("frontend_embeds"),
        rt=rt,
        return_hidden=True,
    )
    targets = batch["targets"]
    loss, ce = _cross_entropy(logits, targets)

    metrics = {"ce": ce, "penalty": penalty}
    if arch.mtp_depth > 0 and "mtp" in params:
        # DeepSeek-style MTP: predict target[t+1] from h[t] fused with the
        # embedding of target[t] (the token one step ahead of position t).
        cd = jnp.dtype(arch.compute_dtype)
        mtp = params["mtp"]
        emb_next = apply_embedding(params["embed"], targets[:, :-1], dtype=cd)
        fused = jnp.concatenate(
            [
                apply_norm(mtp["norm_h"], h[:, :-1], kind=arch.norm),
                apply_norm(mtp["norm_e"], emb_next, kind=arch.norm),
            ],
            axis=-1,
        )
        hm = apply_linear(mtp["proj"], fused, arch.quant, compute_dtype=cd)
        Bm, Sm, _ = hm.shape
        pos = jnp.broadcast_to(jnp.arange(Sm, dtype=jnp.int32)[None], (Bm, Sm))
        hm, _, mtp_pen = apply_stack(
            mtp["block"], hm, arch, _mtp_stackcfg(arch), pos, None, mesh=rt.mesh,
        )
        mtp_logits = _head_logits(params, arch, hm, rt)
        mtp_loss, _ = _cross_entropy(mtp_logits, targets[:, 1:])
        loss = loss + 0.3 * mtp_loss
        penalty = penalty + mtp_pen
        metrics["mtp_ce"] = mtp_loss

    loss = loss + arch.quant.reg_lambda * penalty
    metrics["loss"] = loss
    return loss, metrics


def _mtp_stackcfg(arch: ArchConfig):
    from repro.configs.base import StackConfig

    last = arch.stacks[-1]
    return StackConfig(kind="attn_mlp", count=1, attn=last.attn,
                       d_ff=last.d_ff or arch.d_model * 4, mlp_gated=True)


def init_cache(arch: ArchConfig, batch: int, max_seq: int, dtype=jnp.bfloat16) -> dict:
    """Decode caches for every stack, keyed like params['stacks']."""
    return {
        str(i): init_stack_cache(arch, s, batch, max_seq, dtype)
        for i, s in enumerate(arch.stacks)
    }
