from repro.models.lm import apply_lm, init_cache, init_lm, lm_loss, Runtime  # noqa: F401
