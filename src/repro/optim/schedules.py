"""LR schedules as pure ``step -> lr`` functions (jit-safe)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["constant", "cosine_with_warmup", "step_decay", "exponential_decay"]


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_with_warmup(peak: float, warmup: int, total: int, floor: float = 0.0):
    def f(step):
        s = jnp.asarray(step, jnp.float32)
        warm = peak * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)

    return f


def step_decay(base: float, gamma: float, every: int):
    """Paper App. B: e.g. ResNet18 uses 1e-3 decayed x0.1 every 30 epochs."""

    def f(step):
        k = jnp.floor(jnp.asarray(step, jnp.float32) / every)
        return base * gamma**k

    return f


def exponential_decay(base: float, gamma: float, every: int = 1):
    """Paper App. B: MobileNetV1 / ESPCN style per-epoch x0.9 / x0.98 decay."""

    def f(step):
        k = jnp.asarray(step, jnp.float32) / every
        return base * gamma**k

    return f
