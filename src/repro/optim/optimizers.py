"""Optimizers, from scratch (no optax): SGD-M, Adam(W), Adafactor.

Each optimizer is an ``(init, update)`` pair over plain param pytrees.
Optimizer state mirrors the param tree leaf-for-leaf, so ZeRO-style sharding
falls out for free: states inherit each param's PartitionSpec
(``dist/sharding.py``), which is exactly ZeRO-1/3 when params are
FSDP-sharded.  Adafactor keeps factored second moments for rank>=2 leaves —
the memory-roofline-friendly choice for the billion-parameter archs.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "sgdm", "adamw", "adafactor", "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params, lr) -> (new_params, new_state)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def sgdm(momentum: float = 0.9, weight_decay: float = 0.0, nesterov: bool = False) -> Optimizer:
    def init(params):
        return {"m": jax.tree.map(jnp.zeros_like, params)}

    def update(grads, state, params, lr):
        def upd(g, m, p):
            g = g + weight_decay * p
            m_new = momentum * m + g
            step = (g + momentum * m_new) if nesterov else m_new
            return p - lr * step, m_new

        flat = jax.tree.map(upd, grads, state["m"], params)
        new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"m": new_m}

    return Optimizer(init, update)


def adamw(
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    def init(params):
        return {
            "m": jax.tree.map(jnp.zeros_like, params),
            "v": jax.tree.map(jnp.zeros_like, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        c = state["count"] + 1
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g32
            v_new = b2 * v + (1 - b2) * jnp.square(g32)
            step = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps) + weight_decay * p
            return (p - lr * step).astype(p.dtype), m_new, v_new

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        istuple = lambda t: isinstance(t, tuple)
        return (
            jax.tree.map(lambda t: t[0], out, is_leaf=istuple),
            {
                "m": jax.tree.map(lambda t: t[1], out, is_leaf=istuple),
                "v": jax.tree.map(lambda t: t[2], out, is_leaf=istuple),
                "count": c,
            },
        )

    return Optimizer(init, update)


def adafactor(
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
    min_dim_size_to_factor: int = 128,
) -> Optimizer:
    """Factored second moments: O(n+m) state for an (n, m) matrix instead of
    O(nm) — the optimizer-memory lever for the 35B/671B configs."""

    def _factored(shape) -> bool:
        return len(shape) >= 2 and shape[-1] >= min_dim_size_to_factor and shape[-2] >= min_dim_size_to_factor

    def init(params):
        def one(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

        return {
            "v": jax.tree.map(one, params),
            "count": jnp.zeros((), jnp.int32),
        }

    # Manual tree walk so the factored/unfactored state dicts stay aligned.
    def update2(grads, state, params, lr):
        c = state["count"] + 1
        rho = jnp.minimum(1.0, c.astype(jnp.float32) ** -decay)
        g_leaves, treedef = jax.tree.flatten(grads)
        p_leaves = treedef.flatten_up_to(params)
        v_leaves = treedef.flatten_up_to(state["v"])
        new_p, new_v = [], []
        for g, v, p in zip(g_leaves, v_leaves, p_leaves):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if "vr" in v:
                vr = (1 - rho) * v["vr"] + rho * g2.mean(axis=-1)
                vc = (1 - rho) * v["vc"] + rho * g2.mean(axis=-2)
                denom_r = vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
                u = g32 * jax.lax.rsqrt(denom_r + eps)[..., None] * jax.lax.rsqrt(vc + eps)[..., None, :]
                nv = {"vr": vr, "vc": vc}
            else:
                vv = (1 - rho) * v["v"] + rho * g2
                u = g32 * jax.lax.rsqrt(vv + eps)
                nv = {"v": vv}
            rms = jnp.sqrt(jnp.mean(jnp.square(u)))
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            u = u + weight_decay * p.astype(jnp.float32)
            new_p.append((p.astype(jnp.float32) - lr * u).astype(p.dtype))
            new_v.append(nv)
        return treedef.unflatten(new_p), {"v": treedef.unflatten(new_v), "count": c}

    return Optimizer(init, update2)
