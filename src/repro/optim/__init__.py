from repro.optim.optimizers import Optimizer, adafactor, adamw, clip_by_global_norm, global_norm, sgdm  # noqa: F401
from repro.optim import schedules  # noqa: F401
