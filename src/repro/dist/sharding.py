"""Logical-axis sharding rules with divisibility-aware fallback.

Every parameter in the repo is ``Boxed`` with *logical* axis names
(``nn/module.py``): ``embed``, ``heads``, ``kv_heads``, ``mlp``, ``experts``,
``vocab``, ``layers``, plus the activation-only ``batch``.  This module maps
those names onto mesh axes:

* ``ShardingRules.rules[name]``   — ordered tuple of mesh axes the logical
  axis *wants* to shard over (Megatron-style TP on ``model``, FSDP on
  ``data``, outer DP on ``pod``);
* ``ShardingRules.unit_counts[name]`` — how many *semantic units* the axis
  carries (heads, experts, ffn channels...).  A dim only shards when its unit
  count divides the mesh extent: smollm's 9 heads never split over a 16-way
  ``model`` axis even though the fused ``9 * 64 = 576`` dim would divide —
  splitting mid-head would break per-head attention.  Such dims *replicate*
  instead (the divisibility fallback), which is always correct, just wider.

``resolve_pspec`` additionally never reuses one mesh axis for two dims of the
same array (an invalid ``PartitionSpec``): earlier dims win, later dims fall
back to replication.

``param_specs`` / ``cache_specs`` walk boxed-param / decode-cache pytrees and
return ``PartitionSpec`` trees; ``constrain`` is the mesh-optional
``with_sharding_constraint`` used inside the model forward pass.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.nn.module import Boxed

__all__ = [
    "ShardingRules",
    "resolve_pspec",
    "param_specs",
    "cache_specs",
    "constrain",
]


def _prod(vals) -> int:
    out = 1
    for v in vals:
        out *= v
    return out


def _gcd_all(vals: Sequence[int]) -> Optional[int]:
    """gcd of all values (a sharding must divide *every* stack's count)."""
    out = 0
    for v in vals:
        out = math.gcd(out, int(v))
    return out or None


@dataclasses.dataclass
class ShardingRules:
    """Logical-axis -> mesh-axis mapping plus per-axis semantic unit counts."""

    rules: dict
    unit_counts: dict

    @staticmethod
    def default(
        mesh,
        arch,
        *,
        fsdp: bool = True,
        seq_shard_extra: bool = False,
        tp_extra: bool = False,
    ) -> "ShardingRules":
        """Derive the production layout from the mesh axes + arch dims.

        ``data`` carries FSDP (and batch), ``model`` carries TP/EP, ``pod`` is
        the outer data-parallel axis (batch spans ``("pod", "data")`` on a
        multi-pod mesh).  ``arch=None`` yields activation-only rules with no
        unit counts (everything parameter-ish replicates).

        Toggles (dry-run hillclimb levers): ``fsdp=False`` keeps params
        unsharded over ``data``; ``tp_extra`` widens ``vocab`` onto ``data``
        as well; ``seq_shard_extra`` marks the activation ``seq`` axis for
        sharding over ``model``.
        """
        names = tuple(mesh.axis_names)
        model = ("model",) if "model" in names else ()
        data = ("data",) if "data" in names else ()
        batch = tuple(a for a in ("pod", "data") if a in names)
        rules = {
            "batch": batch,
            "embed": data if fsdp else (),
            "heads": model,
            "kv_heads": model,
            "mlp": model,
            "experts": model,
            "vocab": model + (data if tp_extra else ()),
            "layers": (),  # scan-over-layers stacked dim: never sharded
            "seq": model if seq_shard_extra else (),
        }

        unit_counts: dict = {}
        if arch is not None:
            heads: list = []
            kv_heads: list = []
            mlp: list = []
            experts: list = []
            for s in arch.stacks:
                if s.attn is not None:
                    heads.append(s.attn.heads)
                    kv_heads.append(s.attn.kv_heads)
                if s.ssm is not None and arch.d_model % s.ssm.head_dim == 0:
                    heads.append(arch.d_model // s.ssm.head_dim)
                if s.d_ff:
                    mlp.append(s.d_ff)
                if s.moe is not None:
                    mlp.append(s.moe.d_ff)
                    experts.append(s.moe.n_experts)
                    if s.moe.n_shared:
                        mlp.append(s.moe.shared_d_ff or s.moe.d_ff * s.moe.n_shared)
            unit_counts["embed"] = arch.d_model
            unit_counts["vocab"] = arch.vocab
            for name, count in (
                ("heads", _gcd_all(heads)),
                ("kv_heads", _gcd_all(kv_heads)),
                ("mlp", _gcd_all(mlp)),
                ("experts", _gcd_all(experts)),
            ):
                if count is not None:
                    unit_counts[name] = count
        return ShardingRules(rules=rules, unit_counts=unit_counts)


def resolve_pspec(dims, shape, mesh, rules: ShardingRules) -> P:
    """Resolve per-dim logical names to a valid ``PartitionSpec``.

    For each dim: take the rule's mesh axes (skipping axes already used by an
    earlier dim and trivial size-1 axes), then keep the order-preserving
    subset with the *largest* mesh extent such that both the dim's unit count
    and its actual size divide it — so ``batch: ("pod", "data")`` with a
    batch of 8 on a ``{pod: 2, data: 8}`` mesh shards 8-way over ``data``
    rather than 2-way over ``pod``.  Ties prefer earlier axes.  No valid
    subset -> the dim replicates.
    """
    used: set = set()
    entries = []
    for name, dim in zip(dims, shape):
        want = rules.rules.get(name) if name is not None else None
        if not want:
            entries.append(None)
            continue
        candidates = tuple(
            a for a in want
            if a in mesh.shape and mesh.shape[a] > 1 and a not in used
        )
        units = rules.unit_counts.get(name, dim)
        axes, best_extent = (), 1
        for mask in range(1, 1 << len(candidates)):
            subset = tuple(a for i, a in enumerate(candidates) if mask >> i & 1)
            extent = _prod(mesh.shape[a] for a in subset)
            if extent > best_extent and units % extent == 0 and dim % extent == 0:
                axes, best_extent = subset, extent
        if not axes:
            entries.append(None)
            continue
        used.update(axes)
        entries.append(axes[0] if len(axes) == 1 else axes)
    return P(*entries)


def param_specs(boxed_tree, mesh, rules: ShardingRules):
    """Boxed-param tree -> ``PartitionSpec`` tree (unboxed structure).

    Works on real arrays and on ``jax.eval_shape`` trees alike (the dry-run
    never allocates).  Plain (non-boxed) leaves replicate.
    """

    def one(leaf):
        if isinstance(leaf, Boxed):
            return resolve_pspec(leaf.axes, leaf.shape, mesh, rules)
        return P(*([None] * getattr(leaf, "ndim", 0)))

    return jax.tree.map(one, boxed_tree, is_leaf=lambda x: isinstance(x, Boxed))


def cache_specs(cache_tree, mesh, rules: ShardingRules):
    """Decode-cache tree -> ``PartitionSpec`` tree.

    Cache leaves are stacked ``(layers, batch, ...)`` arrays
    (``init_stack_cache``); the batch dim shards over the batch axes when
    divisible (``long_500k``'s batch=1 replicates via the same fallback) and
    the sequence dims stay local so a decode step never gathers its cache.
    Head-carrying leaves additionally shard their head dim over the model
    axis, mirroring the TP layout of the K/V projections that fill them:
    GQA ``k``/``v`` are ``(layers, batch, slots, kv_heads, head_dim)`` and
    take the ``kv_heads`` rule on dim 3; SSM states ``S`` are
    ``(layers, batch, heads, ...)`` and take the ``heads`` rule on dim 2.
    The unit-count fallback applies as everywhere: smollm's 3 kv_heads never
    split over a 16-way model axis — those leaves replicate the head dim.

    Paged layouts (``serve/paged_cache.py``) have no batch dim: block pools
    ``kp``/``vp`` are ``(layers, num_blocks, block_size, kv_heads, head_dim)``
    — any sequence may own any block, so the block axis stays *local*
    (replicated over the batch axes) while the head dim keeps the same TP
    sharding as the projections that fill it.  MLA latent pools
    ``ckvp``/``kpep`` and the block table ``bt (slots, max_blocks)`` carry no
    shardable parameter dim at all (the table rides with the batch).

    int8 pools (``kv_quant``) change dtype, not layout — the same specs
    apply — and add per-slot fp32 scale pools: GQA ``kps``/``vps``
    ``(layers, NB, bs, kv_heads)`` shard their trailing head dim over
    ``model`` exactly like the code pools they scale (a TP shard must hold
    the scales for its own heads); MLA ``ckvs``/``kpes`` ``(layers, NB, bs)``
    carry nothing shardable and replicate.

    Allocator bookkeeping leaves (``PagedKVCache.device_state``): the write
    watermarks ``wm (slots,)`` ride with the batch axes like the block table
    row they describe; the block refcounts ``rc (num_blocks,)`` replicate —
    copy-on-write decisions need the whole allocator state on every shard,
    mirroring the block axis being local in the pools.
    """

    def one(path, leaf):
        keys = [k.key for k in path if hasattr(k, "key")]
        name = keys[-1] if keys else None
        if name == "wm":
            # per-slot write watermarks (speculative rollback bookkeeping):
            # one scalar per sequence — rides with the batch like the table
            return resolve_pspec(("batch",) + (None,) * (leaf.ndim - 1), leaf.shape, mesh, rules)
        if name == "rc":
            # per-block refcounts (CoW/prefix-sharing bookkeeping): block
            # axis is local like the pools it counts — every shard must see
            # the whole allocator state, so it replicates
            return P(*([None] * leaf.ndim))
        if leaf.ndim < 2:
            return P(*([None] * leaf.ndim))
        if name == "bt":
            return resolve_pspec(("batch",) + (None,) * (leaf.ndim - 1), leaf.shape, mesh, rules)
        if name in ("kp", "vp", "ckvp", "kpep", "kps", "vps", "ckvs", "kpes"):
            dims = ["layers"] + [None] * (leaf.ndim - 1)
            if name in ("kp", "vp") and leaf.ndim == 5:
                dims[3] = "kv_heads"
            elif name in ("kps", "vps") and leaf.ndim == 4:
                dims[3] = "kv_heads"
            return resolve_pspec(tuple(dims), leaf.shape, mesh, rules)
        dims = ["layers", "batch"] + [None] * (leaf.ndim - 2)
        if name in ("k", "v") and leaf.ndim == 5:
            dims[3] = "kv_heads"
        elif name == "S" and leaf.ndim == 5:
            dims[2] = "heads"
        return resolve_pspec(tuple(dims), leaf.shape, mesh, rules)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    return jax.tree_util.tree_unflatten(treedef, [one(p, l) for p, l in flat])


def constrain(x, mesh, spec: P):
    """``with_sharding_constraint`` that is a no-op without a mesh (tests /
    single device) — the model forward pass calls this unconditionally."""
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
