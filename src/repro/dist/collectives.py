"""Accumulator-aware compressed collectives.

``compressed_psum`` extends A2Q's per-device guarantee (paper Sec. 3-4:
invert the accumulator bound into a constraint on what gets summed) to the
cross-device reduction.  It is the standard two-phase compressed all-reduce
(1-bit-Adam / EF-SGD lineage), with every quantization error folded into a
single shard-local *error-feedback residual*:

1. each shard adds its residual to the payload (what compression dropped last
   round re-enters this round, so per-step quantization error does not
   accumulate over training) and quantizes to ``bits``-bit integers on a
   *shared* scale (a ``pmax`` across the axis — one scalar per tensor, or one
   fp32 scalar per output column with ``scale_axis="column"``, the A2Q+-style
   per-channel granularity);
2. **phase 1 (scatter)**: the flat int8/int16 payload is split into one chunk
   per shard and exchanged with ``all_to_all`` — each shard becomes the owner
   of one chunk and accumulates the ``n_shards`` quantized contributions
   locally in int32, exactly;
3. **phase 2 (gather)**: the owner requantizes its chunk-sum back to ``bits``
   wide integers on the statically-widened scale ``n_shards * scale`` (safe:
   ``|sum| <= n_shards * qmax``) and ``all_gather``\\ s the low-bit result.
   The requantization error is scattered into the owner's residual at the
   owned positions, so both phases are error-fed-back.

What crosses the wire per call is therefore ~``2 * bits/8`` bytes per element
(one all-to-all + one all-gather of ``bits``-wide integers) versus ~8 bytes
per element for a ring fp32 all-reduce — a ~4x wire-byte reduction at int8,
independent of the axis size.

Overflow avoidance is by construction, mirroring paper Eq. 12: every summand
is bounded by ``qmax = 2**(bits-1) - 1``, so the int32 chunk accumulation over
``n_shards`` devices is exact whenever ``n_shards * qmax <= 2**31 - 1`` —
for int8 that holds up to ~16.9M devices.  The axis size is resolved
*statically* from the trace-time axis environment and the guard raises at
trace time (a traced ``psum(1, axis)`` would silently never fire).

Use inside ``jax.shard_map``; both the payload and the residual are
shard-local (``P(axis, ...)`` in and out).

**Two transports, one wire format.**  ``compressed_psum`` is the
*fully-manual* transport: it spells out the collectives (``all_to_all`` /
``all_gather``) and is the right tool inside a shard_map that is manual over
every mesh axis.  The train step, however, runs the model under GSPMD (TP
over ``model`` etc.), and on the pinned jaxlib XLA's SPMD partitioner
*fatally rejects* gather-family collectives and ``axis_index`` inside a
partially-manual (``auto``-axes) shard_map — scanned attention blocks crash
``hlo_sharding_util`` outright.  ``compressed_allreduce`` is therefore the
*global-view* twin used by ``build_train_step``: same quantization, same
two-phase wire (the all-to-all and all-gather are expressed as
``with_sharding_constraint`` reshards that GSPMD lowers to the identical s8
collectives), same error-feedback algebra — but phase-2 requantization error
lands in an explicit per-owner ``server`` residual instead of being scattered
by ``axis_index``.  Residual state for the global form is the pair
``{"local", "server"}`` (see ``train.state.init_grad_err``).
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "GradCompressConfig",
    "resolve_grad_compress",
    "quantize_shared_scale",
    "compressed_psum",
    "compressed_psum_tree",
    "compressed_allreduce",
    "compressed_allreduce_tree",
    "owner_dim",
    "server_shape",
    "strip_axis",
]

_I32_MAX = 2**31 - 1


@dataclasses.dataclass(frozen=True)
class GradCompressConfig:
    """Wire format for the data-parallel gradient reduction.

    ``bits``        integer width of the wire payload (2..16).
    ``scale_axis``  "tensor": one shared fp32 scale per gradient leaf;
                    "column": one fp32 scale per output column (last dim) of
                    rank>=2 leaves — A2Q+-style per-channel granularity;
                    rank<2 leaves fall back to the tensor scale.
    ``axis``        mesh axis to reduce over; ``None`` resolves to ``"pod"``
                    when the mesh has one (the DCN-crossing reduction — the
                    expensive wire), else ``"data"``.
    """

    bits: int = 8
    scale_axis: Literal["tensor", "column"] = "tensor"
    axis: Optional[str] = None


def resolve_grad_compress(cfg: Optional[GradCompressConfig], mesh) -> Optional[GradCompressConfig]:
    """Pin ``cfg.axis`` to a concrete mesh axis, or return ``None`` when
    compression cannot apply (no mesh / axis absent / axis extent 1)."""
    if cfg is None or mesh is None:
        return None
    axis = cfg.axis or ("pod" if "pod" in mesh.shape else "data")
    if axis not in mesh.shape or mesh.shape[axis] <= 1:
        return None
    return dataclasses.replace(cfg, axis=axis)


def _static_axis_size(axis) -> Optional[int]:
    """Resolve a mesh axis size at trace time, or ``None`` if unbound.

    ``jax.lax.psum(1, axis)`` alone is unreliable: depending on the jax
    version it may come back traced inside ``shard_map``, so a guard keyed on
    ``isinstance(..., int)`` silently never fires.  Prefer the axis
    environment, which is static whenever the axis is bound.
    """
    axes = (axis,) if isinstance(axis, (str, int)) else tuple(axis)
    size = 1
    for a in axes:
        n: Optional[int] = None
        axis_size = getattr(jax.lax, "axis_size", None)
        if axis_size is not None:
            try:
                n = int(axis_size(a))
            except Exception:
                n = None
        if n is None:
            try:
                from jax._src.core import get_axis_env

                n = int(get_axis_env().axis_size(a))
            except Exception:
                n = None
        if n is None:
            try:
                m = jax.lax.psum(1, a)
                n = m if isinstance(m, int) else None
            except Exception:
                n = None
        if n is None:
            return None
        size *= n
    return size


def quantize_shared_scale(y: jnp.ndarray, axis, bits: int, scale_axis: str = "tensor"):
    """Symmetric integer quantization on a scale agreed across ``axis``.

    Returns ``(q, scale)`` — the wire payload (int8 for ``bits <= 8``, else
    int16) and the fp32 scale, broadcastable against ``y``: shape ``()`` for
    ``scale_axis="tensor"``, ``(1, ..., 1, C)`` (one scale per output column)
    for ``scale_axis="column"`` on rank>=2 payloads.
    """
    qmax = 2 ** (bits - 1) - 1
    wire_dtype = jnp.int8 if bits <= 8 else jnp.int16
    if scale_axis == "column" and y.ndim >= 2:
        absmax = jnp.max(jnp.abs(y), axis=tuple(range(y.ndim - 1)), keepdims=True)
    else:
        absmax = jnp.max(jnp.abs(y))
    gmax = jax.lax.pmax(absmax, axis)
    scale = jnp.maximum(gmax, jnp.finfo(jnp.float32).tiny) / qmax
    q = jnp.clip(jnp.round(y / scale), -qmax, qmax).astype(wire_dtype)
    return q, scale


def compressed_psum(
    x: jnp.ndarray,
    axis,
    err: jnp.ndarray,
    bits: int = 8,
    scale_axis: str = "tensor",
):
    """int-quantized all-reduce over mesh axis ``axis`` with error feedback.

    Args:
        x:    shard-local payload (e.g. this shard's gradient contribution).
        axis: mesh axis name to reduce over.
        err:  shard-local residual carried from the previous call
              (``jnp.zeros_like(x)`` on the first).
        bits: integer width of the wire format (2..16).
        scale_axis: "tensor" (one shared scale) or "column" (one fp32 scale
              per last-dim column of rank>=2 payloads; rank<2 payloads use
              the tensor scale).

    Returns ``(total, new_err)``: the (dequantized) sum, replicated along
    ``axis``, and the residual to feed back next call.
    """
    if not 2 <= bits <= 16:
        raise ValueError(f"bits must be in [2, 16], got {bits}")
    if scale_axis not in ("tensor", "column"):
        raise ValueError(f"scale_axis must be 'tensor' or 'column', got {scale_axis!r}")
    n_shards = _static_axis_size(axis)
    if n_shards is None:
        raise ValueError(
            f"compressed_psum: axis {axis!r} is not bound to a static size — "
            "call it inside jax.shard_map over that mesh axis"
        )
    qmax = 2 ** (bits - 1) - 1
    if n_shards * qmax > _I32_MAX:
        raise ValueError(
            f"int32 accumulator can overflow: {n_shards} shards * qmax {qmax} "
            f"= {n_shards * qmax} > {_I32_MAX}"
        )

    y = (x + err).astype(jnp.float32)
    q, scale = quantize_shared_scale(y, axis, bits, scale_axis)
    err1 = y - q.astype(jnp.float32) * scale  # phase-1 EF: what quantization dropped

    # flat chunk layout: shard i owns elements [i*chunk, (i+1)*chunk)
    nelem = q.size
    chunk = -(-nelem // n_shards)
    pad = chunk * n_shards - nelem
    scale_flat = jnp.pad(
        jnp.broadcast_to(scale, y.shape).reshape(-1), (0, pad), constant_values=1.0
    )
    idx = jax.lax.axis_index(axis)
    my_scale = jax.lax.dynamic_slice(scale_flat, (idx * chunk,), (chunk,))

    # phase 1: all_to_all the low-bit chunks; owner accumulates in int32
    # (exact by the static guard above)
    sent = jnp.pad(q.reshape(-1), (0, pad)).reshape(n_shards, chunk)
    recv = jax.lax.all_to_all(sent, axis, split_axis=0, concat_axis=0)
    chunk_sum = jnp.sum(recv.astype(jnp.int32), axis=0)

    # phase 2: requantize the chunk-sum onto the statically-widened scale
    # (|sum| <= n_shards * qmax, so sum / n_shards fits back in qmax) and
    # all-gather the low-bit result; the requantization error is the owner's
    # to feed back
    value_sum = chunk_sum.astype(jnp.float32) * my_scale
    wide = my_scale * n_shards
    q2 = jnp.clip(jnp.round(chunk_sum.astype(jnp.float32) / n_shards), -qmax, qmax)
    q2 = q2.astype(q.dtype)
    err2_chunk = value_sum - q2.astype(jnp.float32) * wide
    gathered = jax.lax.all_gather(q2, axis, tiled=True)
    total = gathered.astype(jnp.float32)[:nelem] * scale_flat[:nelem] * n_shards
    total = total.reshape(x.shape)

    # phase-2 EF: scatter the owner's requantization error into its owned
    # positions of the (param-shaped) residual
    err2_flat = jax.lax.dynamic_update_slice(
        jnp.zeros((chunk * n_shards,), jnp.float32), err2_chunk, (idx * chunk,)
    )
    new_err = err1 + err2_flat[:nelem].reshape(x.shape)
    return total.astype(x.dtype), new_err.astype(err.dtype)


def compressed_psum_tree(tree, axis, err_tree, bits: int = 8, scale_axis: str = "tensor"):
    """``compressed_psum`` over a pytree (e.g. a gradient tree).

    Returns ``(total_tree, new_err_tree)`` with the input structures.
    """
    flat, treedef = jax.tree_util.tree_flatten(tree)
    err_flat = treedef.flatten_up_to(err_tree)
    totals, errs = [], []
    for leaf, err in zip(flat, err_flat):
        t, e = compressed_psum(leaf, axis, err, bits, scale_axis)
        totals.append(t)
        errs.append(e)
    return (
        jax.tree_util.tree_unflatten(treedef, totals),
        jax.tree_util.tree_unflatten(treedef, errs),
    )


# ---------------------------------------------------------------------------
# Global-view transport (GSPMD / jit world) — see module docstring for why
# the train step cannot use the shard_map transport on this jaxlib.
# ---------------------------------------------------------------------------


def owner_dim(pspec, ndim: int, axis: str) -> int:
    """Payload dim that carries the ownership split after the all-to-all.

    Prefer the dim the param layout already shards over ``axis`` (the FSDP
    dim): ownership then coincides with the param's own slice, the phase-2
    result *is* the param layout and costs zero wire (ZeRO-style: each
    device ends up with exactly its gradient slice).  Otherwise the first
    dim that claims no other mesh axis — a TP-sharded dim (e.g. ``vocab``
    over ``model`` on the embedding table) keeps its sharding on the wire
    and only ``1/tp``-th of the payload crosses each link.

    A dim counts as the FSDP dim whether the spec spells it bare
    (``P("data", ...)``) or inside a multi-axis tuple (``P(("pod", "data"),
    ...)`` — the multi-pod batch layout): missing the tuple form used to
    push ownership onto a free dim and cost an extra all-gather on the wire
    for every FSDP leaf of a multi-pod mesh."""
    entries = (list(pspec or ()) + [None] * ndim)[:ndim]
    for i, e in enumerate(entries):
        if e == axis or (isinstance(e, tuple) and axis in e):
            return i
    for i, e in enumerate(entries):
        if e is None:
            return i
    return 0


def server_shape(shape, n_shards: int, owner: int = 0) -> tuple:
    """Shape of the phase-2 (server) residual for a payload of ``shape``:
    the payload with dim ``owner`` padded up to a multiple of ``n_shards``
    (that dim carries the ownership split after the all-to-all); scalars
    stack to ``(n_shards,)``."""
    eff = tuple(int(d) for d in shape) or (1,)
    padded = -(-eff[owner] // n_shards) * n_shards
    return eff[:owner] + (padded,) + eff[owner + 1:]


def strip_axis(entries, axis):
    """Remove ``axis`` from a list of PartitionSpec entries (replaced by
    ``None`` / dropped from tuples) — a spec may not mention one mesh axis
    twice, and the residual/wire layouts reserve ``axis`` for the shard or
    owner dim."""
    out = []
    for e in entries:
        if e == axis:
            out.append(None)
        elif isinstance(e, tuple):
            kept = tuple(a for a in e if a != axis)
            out.append(kept[0] if len(kept) == 1 else (kept or None))
        else:
            out.append(e)
    return out


def _constrain(x, mesh, spec):
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def compressed_allreduce(
    g: jnp.ndarray,
    err_local: jnp.ndarray,
    err_server: jnp.ndarray,
    *,
    mesh,
    axis: str,
    bits: int = 8,
    scale_axis: str = "tensor",
    pspec=None,
):
    """Global-view compressed sum over the leading (per-shard) dim of ``g``.

    Args:
        g:          ``(n_shards, *shape)`` stacked per-shard contributions,
                    sharded ``P(axis, ...)`` (each device row holds its own
                    shard; payload dims may carry any other-axis sharding).
        err_local:  fp32 ``(n_shards, *shape)`` phase-1 residual (same layout).
        err_server: fp32 ``server_shape(shape, n_shards, owner)`` phase-2
                    (requantization) residual, owner-dim-sharded over ``axis``.
        mesh/axis:  mesh and axis the shard dim is laid out on.
        bits/scale_axis: wire format, as in ``compressed_psum``.
        pspec:      the payload's param ``PartitionSpec`` (its layout in the
                    optimizer state).  Picks the ownership dim
                    (``owner_dim``) and keeps every *other* mesh axis's
                    sharding intact through the wire, so TP-sharded leaves
                    move only their local slice.  ``None`` = unsharded layout
                    (ownership on dim 0).

    Returns ``(total, new_err_local, new_err_server)``; ``total`` has shape
    ``shape``, replicated over ``axis`` (other axes keep the param layout).
    The s8/s16 wire traffic is emitted by GSPMD from the sharding-constraint
    reshards: the all-to-all moves the ``axis`` shard from the stack dim to
    the payload's owner dim, the all-gather removes it again after the int32
    accumulation.
    """
    if not 2 <= bits <= 16:
        raise ValueError(f"bits must be in [2, 16], got {bits}")
    if scale_axis not in ("tensor", "column"):
        raise ValueError(f"scale_axis must be 'tensor' or 'column', got {scale_axis!r}")
    n = int(mesh.shape[axis])
    if g.shape[0] != n:
        raise ValueError(f"leading dim {g.shape[0]} != axis {axis!r} extent {n}")
    qmax = 2 ** (bits - 1) - 1
    if n * qmax > _I32_MAX:
        raise ValueError(
            f"int32 accumulator can overflow: {n} shards * qmax {qmax} > {_I32_MAX}"
        )
    wire_dtype = jnp.int8 if bits <= 8 else jnp.int16
    shape = g.shape[1:]
    scalar = shape == ()
    if scalar:
        g = g[:, None]
        err_local = err_local[:, None]
        shape = (1,)
    ndim = len(shape)
    od = owner_dim(pspec, ndim, axis)
    entries_orig = (list(pspec or ()) + [None] * ndim)[:ndim]
    entries = strip_axis(entries_orig, axis)

    y = g.astype(jnp.float32) + err_local
    # scale shared across shards: the max over the (sharded) leading dim is
    # the global-view pmax — a tiny fp32 all-reduce
    if scale_axis == "column" and y.ndim >= 3:
        absmax = jnp.max(jnp.abs(y), axis=tuple(range(y.ndim - 1)), keepdims=True)
    else:
        absmax = jnp.max(jnp.abs(y))
    scale = jnp.maximum(absmax, jnp.finfo(jnp.float32).tiny) / qmax
    q = jnp.clip(jnp.round(y / scale), -qmax, qmax).astype(wire_dtype)
    new_local = y - q.astype(jnp.float32) * scale

    d_own = shape[od]
    d_pad = -(-d_own // n) * n
    if d_pad != d_own:  # pad rows quantize to 0 and stay 0 in the server residual
        pads = [(0, 0)] * q.ndim
        pads[1 + od] = (0, d_pad - d_own)
        q = jnp.pad(q, pads)

    scale1 = scale[0] if scale.ndim else scale  # drop the stack dim
    if d_pad != d_own and scale1.ndim and od == ndim - 1 and scale1.shape[-1] > 1:
        # per-column scales ride along when the owner dim IS the column dim
        scale1 = jnp.pad(scale1, [(0, 0)] * (scale1.ndim - 1) + [(0, d_pad - d_own)],
                         constant_values=1.0)

    # phase 1: move the `axis` shard from the stack dim to the payload's
    # owner dim — an s8/s16 all-to-all
    own = lambda e: entries[:od] + [e] + entries[od + 1:]
    q = _constrain(q, mesh, [axis] + own(None))
    moved = _constrain(q, mesh, [None] + own(axis))
    part_sum = jnp.sum(moved.astype(jnp.int32), axis=0)  # owner-local

    # phase 2: requantize onto the statically-widened scale and un-shard the
    # owner dim — an s8/s16 all-gather; the requantization error stays with
    # the owner as the server residual
    value_sum = part_sum.astype(jnp.float32) * scale1 + err_server
    wide = scale1 * n
    q2 = jnp.clip(jnp.round(value_sum / wide), -qmax, qmax).astype(wire_dtype)
    q2 = _constrain(q2, mesh, own(axis))
    new_server = value_sum - q2.astype(jnp.float32) * wide
    # land the total in the *param* layout: when the owner dim is the
    # param's own `axis` (FSDP) dim this is a no-op — each device already
    # holds exactly its slice of the summed gradient (ZeRO) — otherwise an
    # s8/s16 all-gather over `axis` on the owner dim
    gathered = _constrain(q2, mesh, entries_orig)
    total = gathered.astype(jnp.float32) * wide
    if d_pad != d_own:
        total = jax.lax.slice_in_dim(total, 0, d_own, axis=od)
    if scalar:
        total = total[:, 0].reshape(()) if total.ndim == 2 else total.reshape(())

    return (
        total.astype(g.dtype).reshape(() if scalar else shape),
        new_local[:, 0].astype(err_local.dtype) if scalar else new_local.astype(err_local.dtype),
        new_server.astype(err_server.dtype),
    )


def compressed_allreduce_tree(
    tree, err_tree, *, mesh, axis: str, bits: int = 8, scale_axis: str = "tensor",
    pspec_tree=None,
):
    """``compressed_allreduce`` over a stacked-gradient pytree.

    ``tree`` leaves are ``(n_shards, *shape)``; ``err_tree`` is the residual
    pair ``{"local": like tree, "server": server_shape per leaf}`` produced
    by ``train.state.init_grad_err``; ``pspec_tree`` optionally carries the
    per-leaf param PartitionSpecs (same structure) so TP-sharded leaves keep
    their layout on the wire.  Returns ``(total_tree, new_err_tree)``.
    """
    flat, treedef = jax.tree_util.tree_flatten(tree)
    local_flat = treedef.flatten_up_to(err_tree["local"])
    server_flat = treedef.flatten_up_to(err_tree["server"])
    pspec_flat = (
        treedef.flatten_up_to(pspec_tree) if pspec_tree is not None else [None] * len(flat)
    )
    totals, locals_, servers = [], [], []
    for g, el, es, ps in zip(flat, local_flat, server_flat, pspec_flat):
        t, nl, ns = compressed_allreduce(
            g, el, es, mesh=mesh, axis=axis, bits=bits, scale_axis=scale_axis, pspec=ps
        )
        totals.append(t)
        locals_.append(nl)
        servers.append(ns)
    unflatten = jax.tree_util.tree_unflatten
    return (
        unflatten(treedef, totals),
        {
            "local": unflatten(treedef, locals_),
            "server": unflatten(treedef, servers),
        },
    )
