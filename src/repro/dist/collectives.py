"""Accumulator-aware compressed collectives.

``compressed_psum`` extends A2Q's per-device guarantee (paper Sec. 3-4:
invert the accumulator bound into a constraint on what gets summed) to the
cross-device reduction.  Each shard:

1. adds its local *error-feedback residual* to the payload (what compression
   dropped last round re-enters this round, so per-step quantization error
   does not accumulate over training — the 1-bit-Adam / EF-SGD mechanism);
2. quantizes to ``bits``-bit integers on a *shared* scale (a ``pmax`` of the
   per-shard absmax, one scalar on the wire), all-gathers the int8/int16
   payload — so the collective genuinely transports ``bits``-wide elements —
   and accumulates the gathered shards locally in int32;
3. keeps ``payload - dequantized`` locally as the next residual.

Overflow avoidance is by construction, mirroring paper Eq. 12: every summand
is bounded by ``qmax = 2**(bits-1) - 1``, so the local int32 accumulation over
``n_shards`` devices is exact whenever ``n_shards * qmax <= 2**31 - 1`` —
for int8 that holds up to ~16.9M devices, checked statically at trace time.

Use inside ``jax.shard_map``; both the payload and the residual are
shard-local (``P(axis, ...)`` in and out).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["compressed_psum", "compressed_psum_tree"]

_I32_MAX = 2**31 - 1


def _quantize_shared_scale(y: jnp.ndarray, axis, bits: int):
    """Symmetric integer quantization on a scale agreed across the axis."""
    qmax = 2 ** (bits - 1) - 1
    wire_dtype = jnp.int8 if bits <= 8 else jnp.int16
    absmax = jnp.max(jnp.abs(y))
    gmax = jax.lax.pmax(absmax, axis)
    scale = jnp.maximum(gmax, jnp.finfo(jnp.float32).tiny) / qmax
    q = jnp.clip(jnp.round(y / scale), -qmax, qmax).astype(wire_dtype)
    return q, scale


def compressed_psum(x: jnp.ndarray, axis, err: jnp.ndarray, bits: int = 8):
    """int-quantized all-reduce over mesh axis ``axis`` with error feedback.

    Args:
        x:    shard-local payload (e.g. this shard's gradient contribution).
        axis: mesh axis name to reduce over.
        err:  shard-local residual carried from the previous call
              (``jnp.zeros_like(x)`` on the first).
        bits: integer width of the wire format (2..16).

    Returns ``(total, new_err)``: the (dequantized) sum, replicated along
    ``axis``, and the residual to feed back next call.
    """
    if not 2 <= bits <= 16:
        raise ValueError(f"bits must be in [2, 16], got {bits}")
    n_shards = jax.lax.psum(1, axis)  # static: the axis size
    qmax = 2 ** (bits - 1) - 1
    if isinstance(n_shards, int) and n_shards * qmax > _I32_MAX:
        raise ValueError(
            f"int32 accumulator can overflow: {n_shards} shards * qmax {qmax}"
        )
    y = (x + err).astype(jnp.float32)
    q, scale = _quantize_shared_scale(y, axis, bits)
    # all-gather the low-bit payload (this is what crosses the wire), then
    # accumulate locally in int32 — exact by the static guard above
    gathered = jax.lax.all_gather(q, axis)
    total = jnp.sum(gathered.astype(jnp.int32), axis=0).astype(jnp.float32) * scale
    new_err = y - q.astype(jnp.float32) * scale
    return total.astype(x.dtype), new_err.astype(err.dtype)


def compressed_psum_tree(tree, axis, err_tree, bits: int = 8):
    """``compressed_psum`` over a pytree (e.g. a gradient tree).

    Returns ``(total_tree, new_err_tree)`` with the input structures.
    """
    flat, treedef = jax.tree_util.tree_flatten(tree)
    err_flat = treedef.flatten_up_to(err_tree)
    totals, errs = [], []
    for leaf, err in zip(flat, err_flat):
        t, e = compressed_psum(leaf, axis, err, bits)
        totals.append(t)
        errs.append(e)
    return (
        jax.tree_util.tree_unflatten(treedef, totals),
        jax.tree_util.tree_unflatten(treedef, errs),
    )
