"""Distribution layer: logical-axis sharding rules + compressed collectives.

``sharding``    — logical axis name -> mesh axis resolution with
                  divisibility-aware fallback (``ShardingRules``,
                  ``resolve_pspec``, ``param_specs``, ``cache_specs``,
                  ``constrain``).
``collectives`` — accumulator-aware compressed all-reduce
                  (``compressed_psum``) with error-feedback residuals.
"""

from repro.dist.sharding import (  # noqa: F401
    ShardingRules,
    cache_specs,
    constrain,
    param_specs,
    resolve_pspec,
)
from repro.dist.collectives import (  # noqa: F401
    GradCompressConfig,
    compressed_psum,
    compressed_psum_tree,
    quantize_shared_scale,
    resolve_grad_compress,
)
