from repro.data.synthetic import BinaryMnistStream, ImageClassStream, SuperResStream, TokenStream, shard  # noqa: F401
