"""Deterministic synthetic data streams (DESIGN.md Sec. 8: CIFAR10/BSD300/
MNIST are unavailable offline; these generators match shapes/statistics and
are *learnable*, so the paper's relative claims — overflow collapse, sparsity
growth, Pareto dominance — reproduce).

Every stream is **stateless**: batch ``i`` is a pure function of ``(seed, i)``,
so checkpoint/resume and elastic re-sharding need no iterator state — the
trainer just records the step index (fault-tolerance substrate, Sec. 4).
Shard-awareness: ``shard(batch, n, idx)`` slices the global batch for a data
shard; generation itself is identical on every host (deterministic), so no
host ever needs another host's stream.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

__all__ = ["TokenStream", "BinaryMnistStream", "ImageClassStream", "SuperResStream", "shard"]


def _rng(seed: int, step: int) -> np.random.Generator:
    # step -1 is the conventional "fixed structure" stream (templates/protos)
    return np.random.default_rng(np.random.SeedSequence([seed & 0xFFFFFFFF, step & 0xFFFFFFFF]))


def shard(batch: dict, n_shards: int, shard_idx: int) -> dict:
    """Slice a global batch along axis 0 for data shard ``shard_idx``."""
    out = {}
    for k, v in batch.items():
        b = v.shape[0]
        assert b % n_shards == 0, (k, b, n_shards)
        per = b // n_shards
        out[k] = v[shard_idx * per : (shard_idx + 1) * per]
    return out


@dataclasses.dataclass(frozen=True)
class TokenStream:
    """LM token batches with a learnable bigram structure: token t+1 is a
    deterministic function of t with seeded noise, so cross-entropy decreases
    under training (used by the end-to-end ~100M-param driver)."""

    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.1

    def batch(self, step: int) -> dict:
        r = _rng(self.seed, step)
        B, S, V = self.global_batch, self.seq_len, self.vocab
        # fixed learnable permutation "grammar": next = (a * tok + b) % V
        a = 31 if V % 31 else 37
        start = r.integers(0, V, (B, 1))
        toks = [start]
        for _ in range(S):
            nxt = (a * toks[-1] + 17) % V
            flip = r.random((B, 1)) < self.noise
            nxt = np.where(flip, r.integers(0, V, (B, 1)), nxt)
            toks.append(nxt)
        seq = np.concatenate(toks, axis=1).astype(np.int32)  # (B, S+1)
        return {"tokens": seq[:, :-1], "targets": seq[:, 1:]}


@dataclasses.dataclass(frozen=True)
class BinaryMnistStream:
    """Paper App. A setup: 784-dim 1-bit unsigned vectors, 2 classes.  Two
    fixed prototype masks + per-sample bit flips — linearly separable at the
    ~92% level, matching the paper's 91.5% 1-layer baseline regime."""

    global_batch: int
    seed: int = 0
    flip: float = 0.18

    def batch(self, step: int) -> dict:
        r = _rng(self.seed, step)
        proto_rng = _rng(self.seed, -1)
        protos = (proto_rng.random((2, 784)) < 0.35).astype(np.int8)  # fixed
        labels = r.integers(0, 2, (self.global_batch,))
        base = protos[labels]
        flips = r.random((self.global_batch, 784)) < self.flip
        x = np.where(flips, 1 - base, base).astype(np.float32)  # 1-bit unsigned
        return {"x": x, "y": labels.astype(np.int32)}


@dataclasses.dataclass(frozen=True)
class ImageClassStream:
    """CIFAR10-shaped (32x32x3, 10 classes): class = fixed random template +
    Gaussian noise; learnable by small convnets to high accuracy."""

    global_batch: int
    n_classes: int = 10
    seed: int = 0
    noise: float = 0.35

    def batch(self, step: int) -> dict:
        r = _rng(self.seed, step)
        tmpl_rng = _rng(self.seed, -1)
        templates = tmpl_rng.normal(0, 1, (self.n_classes, 32, 32, 3)).astype(np.float32)
        labels = r.integers(0, self.n_classes, (self.global_batch,))
        x = templates[labels] + r.normal(0, self.noise, (self.global_batch, 32, 32, 3))
        return {"x": x.astype(np.float32), "y": labels.astype(np.int32)}


@dataclasses.dataclass(frozen=True)
class SuperResStream:
    """BSD300-shaped SISR patches: smooth random fields; input is the 3x
    box-downsampled field, target the full-res field (PSNR-meaningful)."""

    global_batch: int
    hr: int = 48
    factor: int = 3
    seed: int = 0

    def batch(self, step: int) -> dict:
        r = _rng(self.seed, step)
        B, H = self.global_batch, self.hr
        base = r.normal(0, 1, (B, H // 4, H // 4, 1)).astype(np.float32)
        # smooth upsample -> natural-image-ish low-frequency content
        import math

        hr = base
        while hr.shape[1] < H:
            nh = min(hr.shape[1] * 2, H)
            hr = _bilinear(hr, nh)
        lr = hr.reshape(B, H // self.factor, self.factor, H // self.factor, self.factor, 1).mean((2, 4))
        return {"lr": lr.astype(np.float32), "hr": hr.astype(np.float32)}


def _bilinear(x: np.ndarray, size: int) -> np.ndarray:
    B, H, W, C = x.shape
    idx = np.linspace(0, H - 1, size)
    i0 = np.floor(idx).astype(int)
    i1 = np.minimum(i0 + 1, H - 1)
    w1 = (idx - i0)[None, :, None, None]
    rows = x[:, i0] * (1 - w1) + x[:, i1] * w1
    cols = rows[:, :, i0] * (1 - w1.transpose(0, 2, 1, 3)) + rows[:, :, i1] * w1.transpose(0, 2, 1, 3)
    return cols
