"""Mixture-of-experts FFN with expert parallelism over the ``model`` mesh axis.

Design (DESIGN.md Sec. 6.3): no ``(T, E, C)`` one-hot dispatch tensors — for
deepseek-v3 (256 experts, 1M tokens/pod) that would be ~10^13 elements.
Instead, inside ``shard_map`` each model-shard owns ``E/tp`` experts and:

1. computes routing (replicated — the router is tiny),
2. sorts the (token, expert) assignments owned by this shard by local expert,
3. packs them into a capacity-bounded buffer (static shapes; overflow rows are
   dropped, standard token-dropping semantics),
4. runs the expert FFNs with ``jax.lax.ragged_dot`` over the packed groups,
5. scatter-adds gate-weighted outputs back to token order (``segment_sum``),
6. one ``psum`` over ``model`` combines shards — the same wire cost as a dense
   TP FFN all-reduce, no all_to_all needed because activations enter the MoE
   replicated over ``model`` (Megatron-style TP block layout).

With ``ep_axis=None`` (tests / single device) the same packed-ragged path runs
with all experts local — one code path, two mesh bindings.

Expert weights are QuantLinear-style tensors ``(E, d_in, d_ff)`` so A2Q's
per-output-channel budget applies per expert row (each expert output channel
is its own accumulator).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoEConfig, QuantConfig
from repro.core.a2q import a2q_norm_cap, apply_a2q, init_a2q
from repro.core.quantizers import apply_act_quant, init_act_quant
from repro.nn.linear import (
    apply_linear,
    init_linear,
    linear_penalty,
    _record,
    _warn_fallback_once,
)
from repro.nn.module import box, kaiming

__all__ = ["init_moe", "apply_moe", "moe_penalty"]


def _init_expert_weight(key, e: int, d_in: int, d_out: int, q: QuantConfig, axes) -> dict:
    w = kaiming(key, (e, d_in, d_out), fan_in=d_in)
    if q.mode in ("none", "qat"):
        # Baseline QAT on experts uses per-(expert, channel) scales folded into
        # the standard per-channel machinery (channel axis is last).
        p = {"w": box(w, axes)}
        if q.mode == "qat":
            absmax = jnp.maximum(jnp.max(jnp.abs(w), axis=1), 1e-8)  # (E, d_out)
            pmax = 2.0 ** (q.weight_bits - 1) - 1
            p["wq"] = {"log2_scale": box(jnp.log2(absmax / pmax).astype(jnp.float32), (axes[0], axes[-1]))}
        return p
    # a2q: per-(expert, channel) t/d. core.a2q reduces all-but-last axes, so it
    # is applied per expert slice inside the compute (vmap over E).
    a = jax.vmap(lambda wi: init_a2q(wi, q.weight_bits, q.acc_bits, q.act_bits, True))(w)
    return {
        "v": box(a["v"], axes),
        "t": box(a["t"], (axes[0], axes[-1])),
        "d": box(a["d"], (axes[0], axes[-1])),
    }


def _expert_weight_view(p: dict, q: QuantConfig) -> jnp.ndarray:
    """Quantized (fake-quant) view of an (E_local, d_in, d_out) expert weight."""
    if "q8" in p:  # deployed int8 storage
        return p["q8"].astype(jnp.float32) * p["s8"][:, None, :]
    if q.mode == "none":
        return p["w"]
    if q.mode == "qat":
        scale = jnp.exp2(p["wq"]["log2_scale"])[:, None, :]
        pmax = 2.0 ** (q.weight_bits - 1) - 1
        from repro.core.quantizers import ste_round

        qw = jnp.clip(ste_round(p["w"] / scale), -pmax - 1, pmax)
        return qw * scale
    return jax.vmap(
        lambda v, t, d: apply_a2q(
            {"v": v, "t": t, "d": d}, q.weight_bits, q.acc_bits, q.act_bits, True
        )
    )(p["v"], p["t"], p["d"])


def init_moe(key, d_model: int, cfg: MoEConfig, q: QuantConfig) -> dict:
    ks = jax.random.split(key, 6)
    p = {
        "router": box(kaiming(ks[0], (d_model, cfg.n_experts), fan_in=d_model), ("embed", None)),
        "w_in": _init_expert_weight(ks[1], cfg.n_experts, d_model, cfg.d_ff, q, ("experts", "embed", None)),
        "w_gate": _init_expert_weight(ks[2], cfg.n_experts, d_model, cfg.d_ff, q, ("experts", "embed", None)),
        "w_out": _init_expert_weight(ks[3], cfg.n_experts, cfg.d_ff, d_model, q, ("experts", None, "embed")),
    }
    if q.mode != "none":
        p["aq"] = {"log2_scale": box(init_act_quant(q.act_bits, True)["log2_scale"], ())}
    if cfg.n_shared:
        ff = cfg.shared_d_ff or cfg.d_ff * cfg.n_shared
        p["shared_in"] = init_linear(ks[4], d_model, ff, q, axes=("embed", "mlp"))
        p["shared_gate"] = init_linear(jax.random.fold_in(ks[4], 1), d_model, ff, q, axes=("embed", "mlp"))
        p["shared_out"] = init_linear(ks[5], ff, d_model, q, axes=("mlp", "embed"))
    return p


def _local_expert_ffn(x_buf, w_in, w_gate, w_out, group_sizes, q: QuantConfig, compute_dtype):
    """Packed ragged FFN: x_buf (L, d) grouped rows, weights (E_loc, ...)."""
    cd = compute_dtype
    h_in = jax.lax.ragged_dot(x_buf.astype(cd), w_in.astype(cd), group_sizes)
    h_gate = jax.lax.ragged_dot(x_buf.astype(cd), w_gate.astype(cd), group_sizes)
    h = jax.nn.silu(h_gate.astype(jnp.float32)).astype(cd) * h_in
    return jax.lax.ragged_dot(h, w_out.astype(cd), group_sizes)


def _dispatch_compute_combine(
    x2d: jnp.ndarray,  # (T_loc, d) tokens on this shard
    probs: jnp.ndarray,  # (T_loc, E) full router probabilities
    w_in: jnp.ndarray,  # (E_loc, d, f) this shard's experts (quantized view)
    w_gate: jnp.ndarray,
    w_out: jnp.ndarray,
    cfg: MoEConfig,
    q: QuantConfig,
    shard_idx: jnp.ndarray,  # scalar: which expert shard am I
    n_shards: int,
    compute_dtype,
) -> jnp.ndarray:
    T, d = x2d.shape
    E = cfg.n_experts
    E_loc = E // n_shards
    k = cfg.top_k
    capacity = max(int(T * k * cfg.capacity_factor / E), 1)
    L = E_loc * capacity

    top_p, top_e = jax.lax.top_k(probs, k)  # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    flat_e = top_e.reshape(-1)  # (T*k,)
    flat_p = top_p.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T), k)

    first = shard_idx * E_loc
    local_e = flat_e - first
    is_local = (local_e >= 0) & (local_e < E_loc)
    sort_key = jnp.where(is_local, local_e, E_loc)  # non-local sorts last
    order = jnp.argsort(sort_key, stable=True)
    se, st, sp = sort_key[order], flat_tok[order], flat_p[order]

    counts = jnp.bincount(se, length=E_loc + 1)[:E_loc]  # local expert loads
    capped = jnp.minimum(counts, capacity)
    offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(capped)[:-1]])
    seg_start = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)])
    pos_in_group = jnp.arange(se.shape[0]) - seg_start[jnp.clip(se, 0, E_loc)]
    keep = (se < E_loc) & (pos_in_group < capacity)
    dest = jnp.where(keep, offsets[jnp.clip(se, 0, E_loc - 1)] + pos_in_group, L)

    x_buf = jnp.zeros((L + 1, d), x2d.dtype).at[dest].set(x2d[st])
    y_buf = _local_expert_ffn(
        x_buf[:L], w_in, w_gate, w_out, capped.astype(jnp.int32), q, compute_dtype
    )
    y_buf = jnp.concatenate([y_buf, jnp.zeros((1, d), y_buf.dtype)], axis=0)
    contrib = y_buf[dest] * sp[:, None].astype(y_buf.dtype)  # dropped rows read zeros
    out = jax.ops.segment_sum(
        jnp.where(keep[:, None], contrib, 0.0), st, num_segments=T
    )
    return out.astype(x2d.dtype)


def apply_moe(
    params: dict,
    x: jnp.ndarray,  # (B, T, d) — replicated over the model axis
    cfg: MoEConfig,
    q: QuantConfig,
    *,
    ep_axis: Optional[str] = None,
    mesh=None,
    compute_dtype=jnp.bfloat16,
    int_forward: bool = False,
    int_chain: bool = False,
) -> jnp.ndarray:
    B, T, d = x.shape
    if int_forward and "q8" in params.get("w_in", {}):
        # Routed experts run ragged_dot over the dequantized 3D weight view;
        # there is no fused integer path here, so the entry act-quant stays a
        # dequant-style fallback in the chain report (never "standalone").
        _record("fallback", "moe.experts")
        _warn_fallback_once(
            "moe.experts",
            "ragged expert dispatch keeps the dequantized weight view",
        )
    if q.mode != "none" and "aq" in params:
        x = apply_act_quant({"log2_scale": params["aq"]["log2_scale"]}, x, q.act_bits, signed=True)
    x2d = x.reshape(B * T, d)
    logits = x2d.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)

    w_in = _expert_weight_view(params["w_in"], q)
    w_gate = _expert_weight_view(params["w_gate"], q)
    w_out = _expert_weight_view(params["w_out"], q)

    if ep_axis is None:
        out2d = _dispatch_compute_combine(
            x2d, probs, w_in, w_gate, w_out, cfg, q,
            jnp.zeros((), jnp.int32), 1, compute_dtype,
        )
    elif isinstance(ep_axis, tuple):
        # EP over multiple mesh axes (e.g. ('model', 'data') for serving:
        # 1 expert/chip on 256 chips, no weight gathering).  Tokens replicate;
        # the combine is one psum over both axes.
        assert mesh is not None
        n_shards = 1
        for a in ep_axis:
            n_shards *= mesh.shape[a]
        assert cfg.n_experts % n_shards == 0, (cfg.n_experts, n_shards)

        def shard_fn2(x_l, probs_l, wi, wg, wo):
            idx = jnp.zeros((), jnp.int32)
            for a in ep_axis:  # row-major over the listed axes
                idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
            out = _dispatch_compute_combine(
                x_l, probs_l, wi, wg, wo, cfg, q, idx, n_shards, compute_dtype
            )
            return jax.lax.psum(out, ep_axis)

        espec = P(ep_axis, None, None)
        out2d = jax.shard_map(
            shard_fn2,
            mesh=mesh,
            in_specs=(P(None, None), P(None, None), espec, espec, espec),
            out_specs=P(None, None),
            check_vma=False,
        )(x2d, probs, w_in, w_gate, w_out)
    else:
        assert mesh is not None, "ep_axis requires a mesh"
        n_shards = mesh.shape[ep_axis]
        other_axes = tuple(n for n in mesh.axis_names if n != ep_axis)
        # tokens shard over the non-EP axes only when divisible (a single
        # decode token at long_500k batch=1 replicates instead)
        n_tok_shards = 1
        for a in other_axes:
            n_tok_shards *= mesh.shape[a]
        token_axes = other_axes if (other_axes and (B * T) % n_tok_shards == 0) else None

        def shard_fn(x_l, probs_l, wi, wg, wo):
            idx = jax.lax.axis_index(ep_axis)
            out = _dispatch_compute_combine(
                x_l, probs_l, wi, wg, wo, cfg, q, idx, n_shards, compute_dtype
            )
            return jax.lax.psum(out, ep_axis)

        out2d = jax.shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(
                P(token_axes, None),
                P(token_axes, None),
                P(ep_axis, None, None),
                P(ep_axis, None, None),
                P(ep_axis, None, None),
            ),
            out_specs=P(token_axes, None),
            check_vma=False,
        )(x2d, probs, w_in, w_gate, w_out)

    out = out2d.reshape(B, T, d)
    if "shared_in" in params:
        # Shared experts are plain 2D linears; the silu gate makes every one a
        # chain break, but they still ride the fused int path when deployed.
        lin = functools.partial(
            apply_linear, cfg=q, compute_dtype=compute_dtype,
            int_forward=int_forward, int_chain=int_chain,
        )
        h = jax.nn.silu(
            lin(params["shared_gate"], x=x, site="moe.shared_gate").astype(jnp.float32)
        ).astype(compute_dtype)
        h = h * lin(params["shared_in"], x=x, site="moe.shared_in")
        out = out + lin(params["shared_out"], x=h, site="moe.shared_out")
    return out


def moe_penalty(params: dict, cfg: MoEConfig, q: QuantConfig) -> jnp.ndarray:
    """A2Q regularizer over expert + shared weights."""
    total = jnp.zeros((), jnp.float32)
    if q.mode != "a2q":
        return total
    for name in ("w_in", "w_gate", "w_out"):
        p = params[name]
        T_cap = a2q_norm_cap(p["d"], q.acc_bits, q.act_bits, True)
        total = total + jnp.sum(jnp.maximum(p["t"] - T_cap, 0.0))
    for name in ("shared_in", "shared_gate", "shared_out"):
        if name in params:
            total = total + linear_penalty(params[name], q, False, True)
    return total
