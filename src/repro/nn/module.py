"""Minimal functional module system: param pytrees with logical sharding axes.

Every parameter is created as a :class:`Boxed` leaf carrying ``(value, axes)``
where ``axes`` is a tuple of *logical* axis names (one per array dim, ``None``
for replicated dims).  ``dist/sharding.py`` maps logical names to mesh axes
with divisibility-aware fallback.  Train/optimizer code operates on the
*unboxed* value tree; the box tree is kept once per model to derive shardings.

This is the flax ``param_with_axes`` idea without the framework: pure dicts,
pure functions, scan-over-layers friendly (stacked leaves get a leading
``'layers'`` axis added by ``stack_axes``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "Boxed",
    "box",
    "unbox",
    "axes_tree",
    "with_layers_axis",
    "kaiming",
    "normal_init",
    "zeros_init",
    "ones_init",
]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Boxed:
    """A parameter value tagged with per-dim logical axis names."""

    value: Any
    axes: tuple

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)

    @property
    def shape(self):
        return self.value.shape


def box(value: jnp.ndarray, axes: Sequence[Optional[str]]) -> Boxed:
    axes = tuple(axes)
    if hasattr(value, "ndim") and value.ndim != len(axes):
        raise ValueError(f"axes {axes} do not match array rank {value.ndim}")
    return Boxed(value, axes)


def _is_boxed(x) -> bool:
    return isinstance(x, Boxed)


def unbox(tree):
    """Boxed tree -> plain value tree (what training code sees).  Non-boxed
    leaves pass through (some trees mix boxed params with plain arrays)."""
    return jax.tree.map(lambda b: b.value if _is_boxed(b) else b, tree, is_leaf=_is_boxed)


def axes_tree(tree):
    """Boxed tree -> tree of logical-axes tuples (same structure as unbox)."""
    return jax.tree.map(
        lambda b: b.axes if _is_boxed(b) else (None,) * getattr(b, "ndim", 0),
        tree,
        is_leaf=_is_boxed,
    )


def with_layers_axis(tree, name: str = "layers"):
    """Prepend a stacked-layers logical axis to every box (scan-over-layers)."""
    return jax.tree.map(lambda b: Boxed(b.value, (name,) + b.axes), tree, is_leaf=_is_boxed)


# ---------------------------------------------------------------------------
# Initializers (explicit, no flax dependency)
# ---------------------------------------------------------------------------


def kaiming(key, shape, fan_in: Optional[int] = None, dtype=jnp.float32):
    fan_in = fan_in if fan_in is not None else shape[0] if len(shape) >= 1 else 1
    std = (2.0 / max(fan_in, 1)) ** 0.5
    return jax.random.normal(key, shape, dtype) * std


def normal_init(key, shape, std: float = 0.02, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * std


def zeros_init(key, shape, dtype=jnp.float32):
    del key
    return jnp.zeros(shape, dtype)


def ones_init(key, shape, dtype=jnp.float32):
    del key
    return jnp.ones(shape, dtype)
