"""Functional layer zoo: boxed param pytrees, quantized linears/convs,
attention (GQA/MLA/SWA/chunked-local), SSM mixers, MoE, scanned stacks."""
