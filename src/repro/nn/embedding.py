"""Token embeddings + rotary position embeddings.

Embedding lookups have K=1 (no accumulation), so A2Q never attaches here
(DESIGN.md Sec. 5); tables stay in the param dtype.  RoPE tables are computed
on the fly from positions — no (max_seq, dim) table is materialized, which
matters at 500k context.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.module import box, normal_init

__all__ = ["init_embedding", "apply_embedding", "apply_rope"]


def init_embedding(key, vocab: int, d_model: int) -> dict:
    return {"table": box(normal_init(key, (vocab, d_model), std=0.02), ("vocab", "embed"))}


def apply_embedding(params: dict, tokens: jnp.ndarray, dtype=jnp.bfloat16) -> jnp.ndarray:
    return jnp.take(params["table"].astype(dtype), tokens, axis=0)


def apply_rope(
    x: jnp.ndarray,
    positions: jnp.ndarray,
    theta: float = 10000.0,
    rotary_dim: Optional[int] = None,
) -> jnp.ndarray:
    """Rotate ``x (B, T, H, Dh)`` by ``positions (B, T)`` (absolute).

    Pairs (x[2i], x[2i+1]); ``rotary_dim`` (default Dh) allows partial rotary.
    fp32 trig, output in x.dtype.
    """
    B, T, H, Dh = x.shape
    rd = rotary_dim or Dh
    half = rd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)  # (half,)
    angles = positions.astype(jnp.float32)[:, :, None] * freqs[None, None, :]  # (B,T,half)
    cos = jnp.cos(angles)[:, :, None, :]  # (B,T,1,half)
    sin = jnp.sin(angles)[:, :, None, :]
    xr = x[..., :rd].astype(jnp.float32).reshape(B, T, H, half, 2)
    x0, x1 = xr[..., 0], xr[..., 1]
    r0 = x0 * cos - x1 * sin
    r1 = x0 * sin + x1 * cos
    rotated = jnp.stack([r0, r1], axis=-1).reshape(B, T, H, rd)
    if rd < Dh:
        rotated = jnp.concatenate([rotated, x[..., rd:].astype(jnp.float32)], axis=-1)
    return rotated.astype(x.dtype)
