"""RMSNorm / LayerNorm (fp32 accumulation, cast back to compute dtype)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.nn.module import Boxed, box

__all__ = ["init_norm", "apply_norm"]


def init_norm(d: int, kind: str = "rmsnorm", axis_name: str = "embed") -> dict:
    params = {"scale": box(jnp.ones((d,), jnp.float32), (axis_name,))}
    if kind == "layernorm":
        params["bias"] = box(jnp.zeros((d,), jnp.float32), (axis_name,))
    return params


def apply_norm(params: dict, x: jnp.ndarray, kind: str = "rmsnorm", eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * (var + eps) ** -0.5
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * (var + eps) ** -0.5
    else:
        raise ValueError(kind)
    y = y * params["scale"].astype(jnp.float32)
    if "bias" in params:
        y = y + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)
