"""Attention layers: GQA (+RoPE/NoPE, sliding-window, chunked-local) and MLA.

All projections are QuantLinear instances, so A2Q attaches to q/k/v/o (and the
MLA down/up projections) exactly as to any other matmul (DESIGN.md Sec. 5).

The softmax path is the memory-bounded *query-chunked* jnp implementation —
``lax.map`` over query blocks keeps the live score buffer at
``(B, tc, H, S)`` — which is both the CPU/dry-run execution path and the
oracle for the Pallas flash kernel (``kernels/flash_attention.py``, the TPU
fast path).

KV caches (the *cache view* interface — all layouts share one ``_sdpa``):
* full      — ``(B, S_max, KV, Dh)``, decode writes at ``pos``;
* ring      — ``(B, W, KV, Dh)`` for sliding-window / chunked-local layers;
  slot ``pos % W`` plus an explicit per-slot absolute-position array, so a
  500k-token decode holds only W entries (this is what makes h2o-danube /
  hymba / llama4-local long-context cells runnable);
* MLA       — compressed latent ``(B, S_max, kv_lora)`` + shared rope key;
* paged     — pools of fixed-size token blocks ``(NB, bs, KV, Dh)`` (keys
  ``kp``/``vp``; MLA: ``ckvp``/``kpep``) indexed through a per-sequence block
  table ``view["bt"] (B, MB)`` owned by ``serve/paged_cache.py``.  Cache
  memory scales with live tokens instead of ``batch x max_seq``.
* paged int8 — the same pools stored as int8 codes next to per-slot fp32
  scale pools (``kps``/``vps``; MLA: ``ckvs``/``kpes``), detected by the
  scale keys.  K/V are quantized on write (one scale per token per KV head,
  absmax over the head dim) and dequantized on read — in-register inside the
  Pallas decode kernel, on the gathered view otherwise — cutting KV HBM
  footprint and decode bandwidth ~4x.

Cache updates accept ``T >= 1`` tokens per call (chunked prefill): non-ring
caches write a contiguous span at each row's start position, ring caches
scatter modulo the window, paged caches scatter through the block table.

Masking is always computed from *absolute* positions (slot positions for ring
caches, block-table positions for paged ones), so every layout and decode
path shares one `_sdpa`.  The paged decode read has two executions: the
gathered-view ``_sdpa`` (portable truth, bit-identical to the contiguous
layout) and the Pallas kernel ``kernels/paged_attention.py`` selected with
``decode_kernel=True`` (the TPU fast path — no materialized gather).
"""

from __future__ import annotations

import functools
from typing import Literal, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttnConfig, QuantConfig
from repro.nn.embedding import apply_rope
from repro.nn.linear import apply_linear, init_linear, linear_penalty
from repro.nn.norms import apply_norm, init_norm

__all__ = [
    "init_attention",
    "apply_attention",
    "init_attn_cache",
    "attention_penalty",
]

_NEG = -1e30


# ---------------------------------------------------------------------------
# Core scaled-dot-product with absolute-position masking, grouped KV heads,
# and query chunking.
# ---------------------------------------------------------------------------


def _sdpa(
    q: jnp.ndarray,  # (B, T, H, Dh)
    k: jnp.ndarray,  # (B, S, KV, Dh)
    v: jnp.ndarray,  # (B, S, KV, Dv)
    qpos: jnp.ndarray,  # (B, T) absolute positions
    kpos: jnp.ndarray,  # (B, S) absolute positions, -1 = empty slot
    *,
    causal: bool,
    window: Optional[int],
    chunk: Optional[int],
    q_chunk: int,
) -> jnp.ndarray:
    B, T, H, Dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    Dv = v.shape[-1]  # may differ from Dh (MLA: nope+rope query vs v_head_dim)
    G = H // KV
    scale = Dh**-0.5

    def block(q_c: jnp.ndarray, qpos_c: jnp.ndarray) -> jnp.ndarray:
        # q_c (B, tc, KV, G, Dh); qpos_c (B, tc)
        s = jnp.einsum(
            "btkgd,bskd->btkgs",
            q_c.astype(jnp.float32) * scale,
            k.astype(jnp.float32),
        )
        qp = qpos_c[:, :, None]  # (B, tc, 1)
        kp = kpos[:, None, :]  # (B, 1, S)
        mask = kp >= 0
        if causal:
            mask &= kp <= qp
        if window is not None:
            mask &= kp > qp - window
        if chunk is not None:
            mask &= (kp // chunk) == (qp // chunk)
        m4 = mask[:, :, None, None, :]
        s = jnp.where(m4, s, _NEG)
        s_max = jax.lax.stop_gradient(jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - s_max)
        p = jnp.where(m4, p, 0.0)
        denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
        o = jnp.einsum("btkgs,bskd->btkgd", p / denom, v.astype(jnp.float32))
        return o

    qg = q.reshape(B, T, KV, G, Dh)
    if T <= q_chunk:
        out = block(qg, qpos)
    else:
        nc, rem = divmod(T, q_chunk)
        Tm = nc * q_chunk
        q_blocks = qg[:, :Tm].reshape(B, nc, q_chunk, KV, G, Dh).swapaxes(0, 1)
        p_blocks = qpos[:, :Tm].reshape(B, nc, q_chunk).swapaxes(0, 1)
        out = jax.lax.map(lambda args: block(*args), (q_blocks, p_blocks))
        out = out.swapaxes(0, 1).reshape(B, Tm, KV, G, Dv)
        if rem:
            out = jnp.concatenate([out, block(qg[:, Tm:], qpos[:, Tm:])], axis=1)
    return out.reshape(B, T, H, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA layer
# ---------------------------------------------------------------------------


def _init_gqa(key, d_model: int, a: AttnConfig, q: QuantConfig, use_bias: bool) -> dict:
    ks = jax.random.split(key, 4)
    HD, KD = a.heads * a.head_dim, a.kv_heads * a.head_dim
    return {
        "wq": init_linear(ks[0], d_model, HD, q, axes=("embed", "heads"), use_bias=use_bias),
        "wk": init_linear(ks[1], d_model, KD, q, axes=("embed", "kv_heads"), use_bias=use_bias),
        "wv": init_linear(ks[2], d_model, KD, q, axes=("embed", "kv_heads"), use_bias=use_bias),
        "wo": init_linear(ks[3], HD, d_model, q, axes=("heads", "embed"), use_bias=use_bias),
    }


def _init_mla(key, d_model: int, a: AttnConfig, q: QuantConfig) -> dict:
    ks = jax.random.split(key, 5)
    qh = a.qk_nope_dim + a.qk_rope_dim
    return {
        "wq_a": init_linear(ks[0], d_model, a.q_lora_rank, q, axes=("embed", None)),
        "q_norm": init_norm(a.q_lora_rank, "rmsnorm", axis_name=None),
        "wq_b": init_linear(ks[1], a.q_lora_rank, a.heads * qh, q, axes=(None, "heads")),
        "wkv_a": init_linear(
            ks[2], d_model, a.kv_lora_rank + a.qk_rope_dim, q, axes=("embed", None)
        ),
        "kv_norm": init_norm(a.kv_lora_rank, "rmsnorm", axis_name=None),
        "wkv_b": init_linear(
            ks[3], a.kv_lora_rank, a.heads * (a.qk_nope_dim + a.v_head_dim), q,
            axes=(None, "heads"),
        ),
        "wo": init_linear(ks[4], a.heads * a.v_head_dim, d_model, q, axes=("heads", "embed")),
    }


def init_attention(key, d_model: int, a: AttnConfig, q: QuantConfig, use_bias: bool = False) -> dict:
    if a.kind == "mla":
        return _init_mla(key, d_model, a, q)
    return _init_gqa(key, d_model, a, q, use_bias)


def init_attn_cache(
    batch: int, a: AttnConfig, max_seq: int, dtype=jnp.bfloat16
) -> dict:
    """Allocate the decode cache for one layer of this attention kind."""
    if a.kind == "mla":
        return {
            "ckv": jnp.zeros((batch, max_seq, a.kv_lora_rank), dtype),
            "kpe": jnp.zeros((batch, max_seq, a.qk_rope_dim), dtype),
            "kpos": jnp.full((batch, max_seq), -1, jnp.int32),
        }
    slots = max_seq
    ring = a.window or a.chunk
    if ring is not None:
        slots = min(ring, max_seq)
    return {
        "k": jnp.zeros((batch, slots, a.kv_heads, a.head_dim), dtype),
        "v": jnp.zeros((batch, slots, a.kv_heads, a.head_dim), dtype),
        "kpos": jnp.full((batch, slots), -1, jnp.int32),
    }


def _write_cache(cache: dict, updates: dict, pos: jnp.ndarray, ring: bool) -> dict:
    """Write a ``T``-token update into the cache (``T == 1`` decode, ``T > 1``
    chunked prefill).

    ``pos`` may be a scalar or a per-row ``(B,)`` vector of *start* positions
    — the serve engine's continuous batching advances slots at different
    positions, so writes are vmapped per batch row.  Non-ring caches take a
    contiguous ``[pos, pos + T)`` span; ring caches scatter at
    ``(pos + t) % slots``.
    """
    new = dict(cache)
    B, slots = cache["kpos"].shape
    T = next(iter(updates.values())).shape[1]
    pos_vec = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))
    abs_pos = pos_vec[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]  # (B, T)

    if ring:
        slot_idx = abs_pos % slots
        if T > slots:
            # A chunk longer than the ring maps several tokens to one slot
            # (t and t + slots).  Scatter order for duplicate indices is
            # implementation-defined, so drop every write a later token in
            # this chunk supersedes: only t >= T - slots survive (redirected
            # out of range otherwise, removed by mode="drop").
            keep = jnp.arange(T, dtype=jnp.int32) >= T - slots
            slot_idx = jnp.where(keep[None, :], slot_idx, slots)

        def write_row(c_row, u_row, s):
            return c_row.at[s].set(u_row, mode="drop")

    else:
        slot_idx = abs_pos

        def write_row(c_row, u_row, s):
            start = (s[0],) + (0,) * (c_row.ndim - 1)
            return jax.lax.dynamic_update_slice(c_row, u_row, start)

    for name, val in updates.items():  # val (B, T, ...)
        new[name] = jax.vmap(write_row)(cache[name], val.astype(cache[name].dtype), slot_idx)
    new["kpos"] = jax.vmap(write_row)(cache["kpos"], abs_pos, slot_idx)
    return new


# ---------------------------------------------------------------------------
# Paged cache view: block pools indexed through per-sequence block tables.
# ---------------------------------------------------------------------------


def _paged_write(pool: jnp.ndarray, val: jnp.ndarray, bt: jnp.ndarray, abs_pos: jnp.ndarray) -> jnp.ndarray:
    """Scatter ``val (B, T, ...)`` into ``pool (NB, bs, ...)`` at the blocks the
    table assigns: token at absolute position p lands in
    ``pool[bt[b, p // bs], p % bs]``.  Rows never share live blocks (the
    allocator hands each sequence its own), so writes cannot collide except in
    the reserved trash block that dead slots point at."""
    bs = pool.shape[1]
    blk = jnp.take_along_axis(bt, abs_pos // bs, axis=1)  # (B, T)
    off = abs_pos % bs
    return pool.at[blk, off].set(val.astype(pool.dtype), mode="drop")


def _paged_gather(pool: jnp.ndarray, bt: jnp.ndarray) -> jnp.ndarray:
    """Materialize the per-row contiguous view ``(B, MB * bs, ...)`` of a pool
    through the block table.  Because the allocator assigns a sequence's
    blocks in logical order, row b of the result is exactly the contiguous
    cache lane the non-paged layout would hold — the portable decode path and
    the oracle for the Pallas paged-attention kernel."""
    B, MB = bt.shape
    g = pool[bt]  # (B, MB, bs, ...)
    return g.reshape(B, MB * pool.shape[1], *pool.shape[2:])


def _paged_kpos(positions: jnp.ndarray, S: int) -> jnp.ndarray:
    """Absolute key positions of the gathered view: ``[0, len)`` valid, -1
    beyond, where ``len`` = each row's position after this call's write."""
    new_len = positions[:, -1] + 1  # (B,)
    ar = jnp.arange(S, dtype=jnp.int32)[None, :]
    return jnp.where(ar < new_len[:, None], ar, -1)


def _kv_quantize(val: jnp.ndarray, bits: int = 8) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric ``bits``-bit quantization of a K/V update along its feature
    dim: ``val (B, T, ..., D)`` -> (codes int8 in [-qmax, qmax], per-
    ``(B, T, ...)`` fp32 scales).  One scale per written token (per KV head
    for GQA pools, per latent row for MLA), absmax-calibrated — the write is
    the only time the fp value exists, so quantize-on-write is the whole
    encoder."""
    qmax = (1 << (bits - 1)) - 1  # 127 (int8) or 7 (int4)
    vf = val.astype(jnp.float32)
    amax = jnp.max(jnp.abs(vf), axis=-1)
    scale = jnp.maximum(amax, jnp.finfo(jnp.float32).tiny) / qmax
    codes = jnp.clip(jnp.round(vf / scale[..., None]), -qmax, qmax).astype(jnp.int8)
    return codes, scale


def _pack_nibbles(codes: jnp.ndarray) -> jnp.ndarray:
    """int4 codes ``(..., D)`` (int8 values in [-7, 7]) -> packed uint8
    ``(..., D // 2)``: element 2i in the low nibble, 2i+1 in the high."""
    u = codes.astype(jnp.uint8) & 0xF
    return (u[..., 0::2] | (u[..., 1::2] << 4)).astype(jnp.uint8)


def _unpack_nibbles(packed: jnp.ndarray) -> jnp.ndarray:
    """Packed uint8 ``(..., D // 2)`` -> sign-extended int32 ``(..., D)``."""
    lo = (packed & 0xF).astype(jnp.int32)
    hi = (packed >> 4).astype(jnp.int32)
    se = lambda x: (x ^ 8) - 8  # 4-bit two's-complement sign extension
    out = jnp.stack([se(lo), se(hi)], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


def _paged_write_q8(
    pool: jnp.ndarray,
    scales: jnp.ndarray,
    val: jnp.ndarray,
    bt: jnp.ndarray,
    abs_pos: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize-on-write into an integer pool + its per-slot scale pool.
    An int8 pool stores the codes directly; a uint8 pool is the packed int4
    layout (two codes per byte, half the feature width) — detected by dtype,
    so the scale-pool machinery is byte-width agnostic."""
    if pool.dtype == jnp.uint8:
        codes, s = _kv_quantize(val, bits=4)
        codes = _pack_nibbles(codes)
    else:
        codes, s = _kv_quantize(val, bits=8)
    return _paged_write(pool, codes, bt, abs_pos), _paged_write(scales, s, bt, abs_pos)


def _paged_gather_deq(pool: jnp.ndarray, scales: jnp.ndarray, bt: jnp.ndarray) -> jnp.ndarray:
    """Gathered contiguous view of an integer pool, dequantized against its
    per-slot scales (fp32) — the portable read path and the oracle layout for
    the q8 decode kernel.  uint8 pools are the packed int4 layout and are
    unpacked before the rescale."""
    g = _paged_gather(pool, bt)
    if pool.dtype == jnp.uint8:
        g = _unpack_nibbles(g)
    return g.astype(jnp.float32) * _paged_gather(scales, bt)[..., None]


def apply_attention(
    params: dict,
    x: jnp.ndarray,
    a: AttnConfig,
    q: QuantConfig,
    positions: jnp.ndarray,  # (B, T) absolute
    cache: Optional[dict] = None,
    *,
    q_chunk: int = 256,
    compute_dtype=jnp.bfloat16,
    mla_absorb: bool = False,
    view: Optional[dict] = None,
    decode_kernel: bool = False,
    int_forward: bool = False,
    int_chain: bool = False,
) -> tuple[jnp.ndarray, Optional[dict]]:
    """Returns (output, updated cache).  ``cache`` given => cached step over
    ``T >= 1`` new tokens (decode or chunked prefill).  A paged cache (keys
    ``kp``/``vp`` or ``ckvp``/``kpep``) additionally needs the block-table
    ``view``; ``decode_kernel=True`` routes the paged ``T == 1`` read through
    the Pallas paged-attention kernel instead of the gathered-view ``_sdpa``.
    ``int_forward`` routes deployed projections through the fused W8A8 path.

    Every attention projection is a chain break — wq/wk/wv feed rope + the
    attention core and wo sits behind it — so ``int_chain`` folds each
    act-quant into the kernel prologue (no int8 handoff between them).
    """
    if a.kind == "mla":
        return _apply_mla(
            params, x, a, q, positions, cache,
            q_chunk=q_chunk, compute_dtype=compute_dtype, absorb=mla_absorb,
            view=view, decode_kernel=decode_kernel, int_forward=int_forward,
            int_chain=int_chain,
        )
    B, T, D = x.shape
    H, KV, Dh = a.heads, a.kv_heads, a.head_dim
    lin = functools.partial(
        apply_linear, cfg=q, compute_dtype=compute_dtype,
        int_forward=int_forward, int_chain=int_chain,
    )
    qh = lin(params["wq"], x=x, site="attn.wq").reshape(B, T, H, Dh)
    kh = lin(params["wk"], x=x, site="attn.wk").reshape(B, T, KV, Dh)
    vh = lin(params["wv"], x=x, site="attn.wv").reshape(B, T, KV, Dh)
    if a.rope_theta is not None:
        qh = apply_rope(qh, positions, a.rope_theta)
        kh = apply_rope(kh, positions, a.rope_theta)

    if cache is None:
        kpos = jnp.where(jnp.ones((B, T), bool), positions, -1)
        out = _sdpa(
            qh, kh, vh, positions, kpos,
            causal=a.causal, window=a.window, chunk=a.chunk, q_chunk=q_chunk,
        )
        new_cache = None
    elif "kp" in cache:  # paged view
        assert view is not None, "paged attention cache needs a block-table view"
        bt = view["bt"]
        quant = "kps" in cache  # int8 pools carry per-slot scale pools
        if quant:
            kp_new, kps_new = _paged_write_q8(cache["kp"], cache["kps"], kh, bt, positions)
            vp_new, vps_new = _paged_write_q8(cache["vp"], cache["vps"], vh, bt, positions)
            new_cache = {"kp": kp_new, "kps": kps_new, "vp": vp_new, "vps": vps_new}
        else:
            new_cache = {
                "kp": _paged_write(cache["kp"], kh, bt, positions),
                "vp": _paged_write(cache["vp"], vh, bt, positions),
            }
        # int8 and packed-int4 pools both ride the kernel (it detects the
        # byte width from the pool dtype); windowed decode is covered via
        # the kernel's window mask
        kernel_ok = decode_kernel and T == 1 and a.causal and a.chunk is None
        if kernel_ok:
            from repro.kernels import ops

            out = ops.paged_attention(
                qh[:, 0], new_cache["kp"], new_cache["vp"], bt, positions[:, 0] + 1,
                kps=new_cache.get("kps"), vps=new_cache.get("vps"), window=a.window,
            )[:, None]
        else:
            if quant:
                k_all = _paged_gather_deq(new_cache["kp"], new_cache["kps"], bt)
                v_all = _paged_gather_deq(new_cache["vp"], new_cache["vps"], bt)
            else:
                k_all = _paged_gather(new_cache["kp"], bt)
                v_all = _paged_gather(new_cache["vp"], bt)
            kpos = _paged_kpos(positions, k_all.shape[1])
            out = _sdpa(
                qh, k_all, v_all, positions, kpos,
                causal=a.causal, window=a.window, chunk=a.chunk, q_chunk=q_chunk,
            )
    else:
        ring = (a.window or a.chunk) is not None
        new_cache = _write_cache(cache, {"k": kh, "v": vh}, positions[:, 0], ring)
        if ring and T > 1:
            # Chunked prefill over a ring: the chunk's own writes overwrite
            # slots whose keys the chunk's *early* queries still need (any
            # position in [start - W + T', start) for later offsets T').
            # Attend the pre-write ring snapshot + the chunk's fresh K/V
            # instead — absolute-position masking drops stale/out-of-window
            # entries, and ctx positions (< start) never collide with chunk
            # positions.
            k_all = jnp.concatenate([cache["k"], kh.astype(cache["k"].dtype)], axis=1)
            v_all = jnp.concatenate([cache["v"], vh.astype(cache["v"].dtype)], axis=1)
            kpos = jnp.concatenate([cache["kpos"], positions], axis=1)
        else:
            k_all, v_all, kpos = new_cache["k"], new_cache["v"], new_cache["kpos"]
        out = _sdpa(
            qh, k_all, v_all, positions, kpos,
            causal=a.causal, window=a.window, chunk=a.chunk, q_chunk=q_chunk,
        )
    out = out.reshape(B, T, H * Dh)
    return lin(params["wo"], x=out, site="attn.wo"), new_cache


# ---------------------------------------------------------------------------
# MLA (deepseek-v3): low-rank compressed q and kv, shared rope key.
# ---------------------------------------------------------------------------


def _apply_mla(
    params: dict,
    x: jnp.ndarray,
    a: AttnConfig,
    q: QuantConfig,
    positions: jnp.ndarray,
    cache: Optional[dict],
    *,
    q_chunk: int,
    compute_dtype,
    absorb: bool,
    view: Optional[dict] = None,
    decode_kernel: bool = False,
    int_forward: bool = False,
    int_chain: bool = False,
) -> tuple[jnp.ndarray, Optional[dict]]:
    B, T, D = x.shape
    H = a.heads
    nope, rope, vd = a.qk_nope_dim, a.qk_rope_dim, a.v_head_dim
    # All MLA projections are chain breaks: norms, rope, reshapes, and the
    # attention core sit between every producer/consumer pair.
    lin = functools.partial(
        apply_linear, cfg=q, compute_dtype=compute_dtype,
        int_forward=int_forward, int_chain=int_chain,
    )

    cq = apply_norm(params["q_norm"], lin(params["wq_a"], x=x, site="mla.wq_a"))
    qh = lin(params["wq_b"], x=cq, site="mla.wq_b").reshape(B, T, H, nope + rope)
    q_nope, q_pe = qh[..., :nope], qh[..., nope:]
    q_pe = apply_rope(q_pe, positions, a.rope_theta or 10000.0)

    kv_a = lin(params["wkv_a"], x=x, site="mla.wkv_a")
    ckv = apply_norm(params["kv_norm"], kv_a[..., : a.kv_lora_rank])
    kpe = kv_a[..., a.kv_lora_rank :].reshape(B, T, 1, rope)
    kpe = apply_rope(kpe, positions, a.rope_theta or 10000.0).reshape(B, T, rope)

    # Absorbed single-token decode over a paged latent cache routes through
    # the Pallas MLA latent-attention kernel: scores and PV run directly on
    # the pool blocks, so the gathered (B, S, R) latent view is never built.
    use_kernel = (
        decode_kernel and absorb and T == 1 and a.causal
        and cache is not None and "ckvp" in cache
    )
    if cache is not None and "ckvp" in cache:  # paged latent cache
        assert view is not None, "paged MLA cache needs a block-table view"
        bt = view["bt"]
        if "ckvs" in cache:  # int8 latent pools, per-token fp32 scales
            ckvp_new, ckvs_new = _paged_write_q8(cache["ckvp"], cache["ckvs"], ckv, bt, positions)
            kpep_new, kpes_new = _paged_write_q8(cache["kpep"], cache["kpes"], kpe, bt, positions)
            cache = {"ckvp": ckvp_new, "ckvs": ckvs_new, "kpep": kpep_new, "kpes": kpes_new}
            if not use_kernel:
                ckv_all = _paged_gather_deq(cache["ckvp"], cache["ckvs"], bt)
                kpe_all = _paged_gather_deq(cache["kpep"], cache["kpes"], bt)
        else:
            cache = {
                "ckvp": _paged_write(cache["ckvp"], ckv, bt, positions),
                "kpep": _paged_write(cache["kpep"], kpe, bt, positions),
            }
            if not use_kernel:
                ckv_all = _paged_gather(cache["ckvp"], bt)
                kpe_all = _paged_gather(cache["kpep"], bt)
        if use_kernel:
            ckv_all = kpe_all = kpos = None
        else:
            kpos = _paged_kpos(positions, ckv_all.shape[1])
    elif cache is not None:
        cache = _write_cache(cache, {"ckv": ckv, "kpe": kpe}, positions[:, 0], ring=False)
        ckv_all, kpe_all, kpos = cache["ckv"], cache["kpe"], cache["kpos"]
    else:
        ckv_all, kpe_all = ckv, kpe
        kpos = jnp.broadcast_to(positions, (B, T))

    wkv_b = params["wkv_b"]
    if absorb and cache is not None:
        # Beyond-paper decode optimization: fold wkv_b into the query/output
        # so scores are taken directly against the compressed latent cache.
        # Numerically identical to the materialized path (incl. the activation
        # quantizer, applied to the latent exactly as lin(wkv_b, .) would).
        w_full = _mla_up_matrix(wkv_b, a, q)  # (kv_lora, H, nope+vd)
        has_aq = q.mode != "none" and "aq" in wkv_b
        w_k, w_v = w_full[..., :nope], w_full[..., nope:]
        q_lat = jnp.einsum("bthn,lhn->bthl", q_nope.astype(jnp.float32), w_k.astype(jnp.float32))
        scale = (nope + rope) ** -0.5
        if use_kernel:
            from repro.kernels import ops

            aq_scale = None
            if has_aq:
                aq_scale = jnp.exp2(wkv_b["aq"]["log2_scale"].astype(jnp.float32))
            o_lat = ops.paged_mla_attention(
                q_lat[:, 0], q_pe[:, 0].astype(jnp.float32),
                cache["ckvp"], cache["kpep"], bt, positions[:, 0] + 1,
                ckvs=cache.get("ckvs"), kpes=cache.get("kpes"), scale=scale,
                aq_scale=aq_scale,
                act_bits=q.act_bits if aq_scale is not None else None,
            )[:, None]
        else:
            if has_aq:
                from repro.core.quantizers import apply_act_quant

                ckv_all = apply_act_quant(
                    {"log2_scale": wkv_b["aq"]["log2_scale"]}, ckv_all, q.act_bits, signed=True
                )
            s = jnp.einsum("bthl,bsl->bths", q_lat, ckv_all.astype(jnp.float32))
            s += jnp.einsum("bthr,bsr->bths", q_pe.astype(jnp.float32), kpe_all.astype(jnp.float32))
            s *= scale
            qp = positions[:, :, None]
            kp = kpos[:, None, :]
            mask = (kp >= 0) & (kp <= qp)
            s = jnp.where(mask[:, :, None, :], s, _NEG)
            p = jax.nn.softmax(s, axis=-1)
            o_lat = jnp.einsum("bths,bsl->bthl", p, ckv_all.astype(jnp.float32))
        out = jnp.einsum("bthl,lhv->bthv", o_lat, w_v.astype(jnp.float32))
        out = out.astype(compute_dtype).reshape(B, T, H * vd)
        return lin(params["wo"], x=out, site="mla.wo"), cache

    # Materialized path (paper-faithful baseline): expand per-head K/V.
    S = ckv_all.shape[1]
    kv = lin(wkv_b, x=ckv_all, site="mla.wkv_b").reshape(B, S, H, nope + vd)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kpe_all[:, :, None, :], (B, S, H, rope))], axis=-1
    )
    qfull = jnp.concatenate([q_nope, q_pe], axis=-1)
    out = _sdpa(
        qfull, k, v, positions, kpos,
        causal=a.causal, window=None, chunk=None, q_chunk=q_chunk,
    )
    out = out.reshape(B, T, H * vd)
    return lin(params["wo"], x=out, site="mla.wo"), cache


def _mla_up_matrix(wkv_b_params: dict, a: AttnConfig, q: QuantConfig) -> jnp.ndarray:
    from repro.nn.linear import _quant_weights  # quantized view of the up-proj

    w = _quant_weights(wkv_b_params, q, boundary=False, input_signed=True)
    kv_lora = w.shape[0]
    return w.reshape(kv_lora, a.heads, a.qk_nope_dim + a.v_head_dim)


def attention_penalty(params: dict, a: AttnConfig, q: QuantConfig) -> jnp.ndarray:
    """Sum of A2Q regularizer terms over this layer's projections."""
    total = jnp.zeros((), jnp.float32)
    for name, sub in params.items():
        if isinstance(sub, dict) and ("t" in sub or "w" in sub or "v" in sub):
            total = total + linear_penalty(sub, q, boundary=False, input_signed=True)
    return total
