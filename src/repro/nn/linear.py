"""QuantLinear / QuantConv — every matmul-bearing layer in the framework.

The paper's technique is a first-class mode of this layer:

* ``mode='none'`` — float weights (the floating-point baseline),
* ``mode='qat'``  — baseline quantization-aware training (paper Sec. 2.1):
  per-channel weight scales, per-tensor activation scales, z=0, half-way
  rounding, STE,
* ``mode='a2q'``  — accumulator-aware quantization (paper Sec. 4): l1
  weight-normalized reparameterization (v, t, d), norm cap from the target
  accumulator width P, round-toward-zero.  ``penalty()`` exposes the layer's
  regularizer term.

Hidden layers use (M, N, P) from :class:`~repro.configs.base.QuantConfig`;
layers flagged ``boundary=True`` (first/last) stay at 8-bit as in App. B.
``input_signed`` reflects the preceding nonlinearity (ReLU -> unsigned).

Deployment: ``deploy_linear`` converts a trained A2Q layer to (int8 weights,
per-channel scale) — the artifact whose l1 norm provably fits the P-bit
accumulator — used by the serve path and by the int8-weight-storage roofline
lever.

Integer-fast serving: with ``int_forward=True`` (``Runtime(int_forward=...)``
/ ``--int-forward``) a deployed layer skips the dequant + bf16 dot and runs
``act_quant(x) -> int8 @ int8 -> int32 -> scaled output`` through the fused
W8A8 kernel (``kernels/int_matmul.py``), with the int16 partial-sum spill
engaged automatically when the layer's A2Q ``acc_bits <= 16`` — the paper's
guarantee is exactly what makes both the integer accumulation and the narrow
carry safe on the serve path.

Int8-out chaining (``int_chain=True`` / ``--int-chain``): deployed layers
pass integer activations directly instead of round-tripping through fp32
between every pair of linears.

* A producer whose consumer is chain-eligible (``chain_out_aq`` returns the
  consumer's quantizer descriptor) requantizes in its own epilogue and
  returns an :class:`IntAct` — ``(codes int8, scale, bits, signed)`` —
  killing the consumer's standalone act-quant dispatch *and* the fp32
  activation materialization between them.
* At chain-break points (residual adds, norms, attention cores — anywhere
  the fp32 value is needed) the consumer instead folds its act-quant into
  the kernel *prologue* (``aq_scale``): the fp32 input is quantized
  in-register, so no deployed linear anywhere on the serve path pays a
  standalone act-quant dispatch.
* Unsigned 8-bit activations (rwkv6's post-relu² channel-mix ``wv``) ride
  the fused path via signed symmetrization: codes travel as ``q - 128`` and
  the kernel adds ``128 * colsum(w)`` back at flush — exact in int32.

Every apply_linear call site reports its disposition (``folded`` /
``chained`` / ``standalone`` / ``fallback``) into the active
``chain_report_scope`` at trace time; the serve engine exposes the counts as
stats-contract fields (``int_chain_requant_dispatches`` must be 0 when
chaining is on — CI-gated).
"""

from __future__ import annotations

import contextlib
import warnings
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import QuantConfig
from repro.core.a2q import a2q_int_weights, a2q_norm_cap, apply_a2q, init_a2q
from repro.core.quantizers import (
    act_quant_int,
    apply_act_quant,
    apply_weight_qat,
    init_act_quant,
    init_weight_qat,
    weight_qat_int,
)
from repro.nn.module import Boxed, box, kaiming

__all__ = [
    "init_linear",
    "apply_linear",
    "linear_penalty",
    "deploy_linear",
    "init_conv",
    "apply_conv",
    "IntAct",
    "chain_out_aq",
    "chain_report_scope",
    "acc_probe_scope",
]


class IntAct(NamedTuple):
    """A chained integer activation: the ``(codes, scale)`` convention.

    ``codes`` are int8 with the layer-output shape; unsigned-domain codes
    (``signed=False, bits=8``) are stored *symmetrized* (``true_code - 128``)
    so they always fit the int8 MXU operand — the consuming kernel adds the
    ``128 * colsum(w)`` correction at flush.  ``scale`` is the (per-tensor)
    activation scale the codes were quantized with, i.e. the *consumer's*
    ``exp2(aq.log2_scale)``.
    """

    codes: jnp.ndarray
    scale: jnp.ndarray
    bits: int
    signed: bool


def _int_act_to_fp(a: IntAct, dtype) -> jnp.ndarray:
    """Re-materialize an IntAct to floating point (chain-repair fallback)."""
    q = a.codes.astype(jnp.float32)
    if not a.signed and a.bits == 8:
        q = q + 128.0
    return (q * a.scale).astype(dtype)


# --- chain-report collector ------------------------------------------------
#
# apply_linear has no Runtime handle, so call-site dispositions are collected
# through a module-level scope stack.  The scope is entered around a model
# forward (models/lm.apply_lm) and populated at *trace* time — a jitted
# forward traces each call site exactly once (the decode megastep's lax.scan
# included), so the lists are per-dispatch-site counts of what the compiled
# program actually launches.

_ACTIVE_REPORT: list = []
_WARNED: set = set()


def _fresh_report() -> dict:
    return {"folded": [], "chained": [], "standalone": [], "fallback": []}


@contextlib.contextmanager
def chain_report_scope(report: dict):
    """Collect apply_linear dispositions into ``report`` (cleared on entry).

    ``folded``     — act-quant ran inside the fused kernel (prologue or a
                     chained IntAct consumption): zero standalone dispatches.
    ``chained``    — the layer requantized in its epilogue and emitted int8
                     codes for its consumer.
    ``standalone`` — a deployed layer paid a separate act-quant dispatch
                     before the fused kernel (the unchained int-forward
                     baseline; must be empty under ``int_chain``).
    ``fallback``   — the fused path was unavailable (non-deployed params,
                     unsupported weight rank, MoE ragged experts, ...).
    """
    report.clear()
    report.update(_fresh_report())
    _ACTIVE_REPORT.append(report)
    try:
        yield report
    finally:
        _ACTIVE_REPORT.pop()


def _record(kind: str, site: str):
    if _ACTIVE_REPORT:
        _ACTIVE_REPORT[-1][kind].append(site)


# --- accumulator-headroom probe --------------------------------------------
#
# The A2Q guarantee is proved statically from the deployed weights' l1 norms;
# this probe makes it *observable*: inside an acc_probe_scope, each eager
# fused-path call samples the worst partial-sum magnitude its actual integer
# operands could produce and records it against the layer's accumulator
# bound.  The serve obs layer (obs/headroom.py) exports the samples as
# acc_headroom gauges next to the static per-channel utilization report.

_ACTIVE_ACC_PROBE: list = []


@contextlib.contextmanager
def acc_probe_scope(samples: list):
    """Sample observed accumulator magnitudes from the fused W8A8 path.

    Inside the scope, every *eager* ``_apply_linear_int8`` call appends one
    record per call site::

        {"site", "acc_max", "acc_bits", "bound", "spill_int16",
         "in_bits", "in_signed"}

    ``acc_max`` is ``max(|x_codes| @ |q8|)`` over output channels in int64 —
    an upper bound on the magnitude of *any* partial sum, in any
    accumulation order, for the actual integer operands (the runtime twin of
    the paper's Eq. 11 check, which bounds the same quantity by
    ``||w||_1 * 2**(N - 1_signed)`` over all possible inputs).  Jitted call
    sites skip the probe (their operands are tracers); ``obs/headroom.py``
    drives one eager forward to populate it.
    """
    samples.clear()
    _ACTIVE_ACC_PROBE.append(samples)
    try:
        yield samples
    finally:
        _ACTIVE_ACC_PROBE.pop()


def _probe_acc(site, codes, q8, *, in_bits, in_signed, acc_bits, spill_int16,
               symmetrized=False):
    if not _ACTIVE_ACC_PROBE:
        return
    if isinstance(codes, jax.core.Tracer) or isinstance(q8, jax.core.Tracer):
        return  # abstract operands (jit/vmap/scan): nothing to sample
    import numpy as np

    xc = np.asarray(codes, dtype=np.int64)
    if symmetrized:
        xc = xc + 128  # stored codes are true - 128 (unsigned-8 ride-along)
    xc = np.abs(xc).reshape(-1, xc.shape[-1])
    wq = np.abs(np.asarray(q8, dtype=np.int64))
    acc_max = int((xc @ wq).max()) if xc.size and wq.size else 0
    _ACTIVE_ACC_PROBE[-1].append({
        "site": site,
        "acc_max": acc_max,
        "acc_bits": int(acc_bits),
        "bound": 2 ** (int(acc_bits) - 1) - 1,
        "spill_int16": bool(spill_int16),
        "in_bits": int(in_bits),
        "in_signed": bool(in_signed),
    })


def _warn_fallback_once(site: str, reason: str):
    key = (site, reason)
    if key not in _WARNED:
        _WARNED.add(key)
        warnings.warn(
            f"int_forward fallback at {site or '<unlabeled linear>'}: {reason} "
            "(dequant path; counted in the chain report)",
            stacklevel=3,
        )


def _bits(cfg: QuantConfig, boundary: bool) -> tuple[int, int]:
    if boundary:
        return cfg.boundary_bits, cfg.boundary_bits
    return cfg.weight_bits, cfg.act_bits


def init_linear(
    key,
    d_in: int,
    d_out: int,
    cfg: QuantConfig,
    *,
    axes: Sequence[Optional[str]] = ("embed", "mlp"),
    use_bias: bool = False,
    boundary: bool = False,
    input_signed: bool = True,
    w_std: Optional[float] = None,
    act_absmax: float = 6.0,
) -> dict:
    """Weights are stored ``(d_in, d_out)`` — output channels (accumulators)
    on the last axis, matching ``core.a2q`` conventions."""
    k_w, _ = jax.random.split(key)
    if w_std is None:
        w = kaiming(k_w, (d_in, d_out), fan_in=d_in)
    else:
        w = jax.random.normal(k_w, (d_in, d_out)) * w_std
    M, N = _bits(cfg, boundary)
    out_axis = axes[-1]
    p: dict = {}
    if cfg.mode == "none":
        p["w"] = box(w, tuple(axes))
    elif cfg.mode == "qat":
        p["w"] = box(w, tuple(axes))
        wq = init_weight_qat(w, M)
        p["wq"] = {"log2_scale": box(wq["log2_scale"], (out_axis,))}
        aq = init_act_quant(N, input_signed, init_absmax=act_absmax)
        p["aq"] = {"log2_scale": box(aq["log2_scale"], ())}
    elif cfg.mode == "a2q":
        a = init_a2q(w, M, cfg.acc_bits, N, input_signed)
        p["v"] = box(a["v"], tuple(axes))
        p["t"] = box(a["t"], (out_axis,))
        p["d"] = box(a["d"], (out_axis,))
        aq = init_act_quant(N, input_signed, init_absmax=act_absmax)
        p["aq"] = {"log2_scale": box(aq["log2_scale"], ())}
    else:
        raise ValueError(cfg.mode)
    if use_bias:
        p["b"] = box(jnp.zeros((d_out,), jnp.float32), (out_axis,))
    return p


def _quant_weights(params: dict, cfg: QuantConfig, boundary: bool, input_signed: bool):
    M, N = _bits(cfg, boundary)
    if "q8" in params:  # deployed int8 storage (beyond-paper serve lever)
        # s8 is per-output-channel; stacked leaves carry leading batch dims
        # (q8 (..., K, N), s8 (..., N)), so align it explicitly
        return params["q8"].astype(jnp.float32) * params["s8"][..., None, :]
    if cfg.mode == "none":
        return params["w"]
    if cfg.mode == "qat":
        return apply_weight_qat({"log2_scale": params["wq"]["log2_scale"]}, params["w"], M)
    if cfg.mode == "a2q":
        return apply_a2q(
            {"v": params["v"], "t": params["t"], "d": params["d"]},
            M,
            cfg.acc_bits,
            N,
            input_signed,
        )
    raise ValueError(cfg.mode)


def _int_forward_mode(params: dict, x, N: int) -> str:
    """How this call can take the fused W8A8 path: ``'fused'`` (2D weights),
    ``'vmap'`` (stacked 3D weight leaves batched over the kernel — the
    leading axes of ``x`` and ``q8`` must line up), or ``''`` (dequant
    fallback).  Needs deployed int8 storage, an activation quantizer to
    produce the int8 operand, and ``N <= 8`` — unsigned 8-bit codes ride via
    signed symmetrization (``q - 128`` + the colsum correction at flush), so
    the old ``N <= 7`` unsigned restriction is gone."""
    if "q8" not in params or "aq" not in params or N > 8:
        return ""
    q8 = params["q8"]
    if q8.ndim == 2:
        return "fused"
    xc = x.codes if isinstance(x, IntAct) else x
    if q8.ndim == 3 and xc.ndim >= 3 and xc.shape[0] == q8.shape[0]:
        return "vmap"
    return ""


def chain_out_aq(
    consumer: dict,
    cfg: QuantConfig,
    *,
    boundary: bool = False,
    input_signed: bool = True,
    act_fn: Optional[str] = None,
) -> Optional[dict]:
    """The *consumer's* activation-quantizer descriptor, if the producer can
    requantize into it (int8-out chaining).  ``None`` means the edge is a
    chain break — the consumer is not deployed / not fusable — detected
    statically from the deployed params, so the producer emits fp32 and the
    consumer falls back to its own (prologue) quantization.

    ``act_fn`` names the elementwise activation sitting *between* the two
    linears (``'relu2'`` / ``'gelu'`` / ``None``); the producer's epilogue
    replays it bit-exactly before requantizing.
    """
    N = _bits(cfg, boundary)[1]
    if "q8" not in consumer or "aq" not in consumer or N > 8:
        return None
    if consumer["q8"].ndim != 2:
        return None
    return {
        "log2_scale": consumer["aq"]["log2_scale"],
        "bits": N,
        "signed": input_signed,
        "act_fn": act_fn,
    }


def _apply_linear_int8(
    params: dict,
    x,
    cfg: QuantConfig,
    *,
    boundary: bool,
    input_signed: bool,
    compute_dtype,
    int_chain: bool = False,
    out_aq: Optional[dict] = None,
    site: str = "",
):
    """Fused W8A8 forward: one ``pallas_call`` from activations to output.
    The activation scale folds into the per-channel weight scale, so the
    epilogue is a single per-column fp32 rescale (+ bias); the int16
    partial-sum spill engages when A2Q guarantees ``acc_bits <= 16``.

    Chaining changes where the activation quantizer runs:

    * ``x`` is an :class:`IntAct` — the producer already requantized; the
      codes feed the kernel directly (``folded``: no dispatch at all here).
    * ``int_chain`` and ``x`` is fp — the quantizer folds into the kernel
      *prologue* (``folded``).
    * plain ``int_forward`` — the quantizer runs as its own dispatch ahead
      of the kernel (``standalone``), with unsigned 8-bit codes symmetrized
      into the int8 operand.

    With ``out_aq`` (the consumer's quantizer) the epilogue requantizes and
    the call returns an :class:`IntAct` instead of a float array.
    """
    from repro.kernels import ops

    M, N = _bits(cfg, boundary)
    a2q = cfg.mode == "a2q"
    kw = dict(
        acc_bits=cfg.acc_bits if a2q else 32,
        mode="exact",
        spill_int16=a2q and cfg.acc_bits <= 16,
        bias=params.get("b"),
    )
    if out_aq is not None:
        kw.update(
            out_scale=jnp.exp2(out_aq["log2_scale"].astype(jnp.float32)),
            out_bits=out_aq["bits"],
            out_signed=out_aq["signed"],
            act_fn=out_aq["act_fn"],
            cast_dtype=compute_dtype,
        )
    s8 = params["s8"].astype(jnp.float32)
    if isinstance(x, IntAct):
        # chained handoff: the producer quantized into *this* layer's aq
        _record("folded", site)
        codes, x_scale = x.codes, x.scale
        _probe_acc(site, codes, params["q8"], in_bits=x.bits, in_signed=x.signed,
                   acc_bits=kw["acc_bits"], spill_int16=kw["spill_int16"],
                   symmetrized=not x.signed and x.bits == 8)
        K = codes.shape[-1]
        lead = codes.shape[:-1]
        y = ops.int_matmul(
            codes.reshape(-1, K), params["q8"],
            scale=x_scale * s8, in_bits=x.bits, in_signed=x.signed, **kw,
        )
    elif int_chain:
        # chain break: fold the act-quant into the kernel prologue
        _record("folded", site)
        x_scale = jnp.exp2(params["aq"]["log2_scale"].astype(jnp.float32))
        if _ACTIVE_ACC_PROBE and not isinstance(x, jax.core.Tracer):
            # replay the prologue's quantization so the probe sees the exact
            # codes the kernel folds in-register
            xq_p, _ = act_quant_int(
                {"log2_scale": params["aq"]["log2_scale"]},
                x.astype(jnp.float32), N, signed=input_signed,
            )
            _probe_acc(site, xq_p, params["q8"], in_bits=N, in_signed=input_signed,
                       acc_bits=kw["acc_bits"], spill_int16=kw["spill_int16"])
        K = x.shape[-1]
        lead = x.shape[:-1]
        y = ops.int_matmul(
            x.astype(jnp.float32).reshape(-1, K), params["q8"],
            scale=x_scale * s8, aq_scale=x_scale,
            in_bits=N, in_signed=input_signed, **kw,
        )
    else:
        # unchained int forward: the act-quant is its own dispatch
        _record("standalone", site)
        xq, x_scale = act_quant_int(
            {"log2_scale": params["aq"]["log2_scale"]},
            x.astype(jnp.float32), N, signed=input_signed,
        )
        _probe_acc(site, xq, params["q8"], in_bits=N, in_signed=input_signed,
                   acc_bits=kw["acc_bits"], spill_int16=kw["spill_int16"])
        if not input_signed and N == 8:
            xq = xq - 128.0  # symmetrize u8 codes into the int8 operand
        K = x.shape[-1]
        lead = x.shape[:-1]
        y = ops.int_matmul(
            xq.astype(jnp.int8).reshape(-1, K), params["q8"],
            scale=x_scale * s8, in_bits=N, in_signed=input_signed, **kw,
        )
    if out_aq is not None:
        _record("chained", site)
        return IntAct(
            codes=y.reshape(*lead, y.shape[-1]),
            scale=jnp.exp2(out_aq["log2_scale"].astype(jnp.float32)),
            bits=out_aq["bits"],
            signed=out_aq["signed"],
        )
    return y.reshape(*lead, y.shape[-1]).astype(compute_dtype)


def apply_linear(
    params: dict,
    x,
    cfg: QuantConfig,
    *,
    boundary: bool = False,
    input_signed: bool = True,
    compute_dtype=jnp.bfloat16,
    int_forward: bool = False,
    int_chain: bool = False,
    out_aq: Optional[dict] = None,
    site: str = "",
):
    """``y = act_quant(x) @ quant(w) (+ b)`` in ``compute_dtype``.

    ``int_forward=True`` on a deployed layer (``q8``/``s8`` present) runs the
    fused W8A8 integer path instead of dequant + ``compute_dtype`` dot.
    ``int_chain=True`` additionally folds the activation quantizer into the
    kernel (prologue at chain breaks, the producer's epilogue on chained
    edges); ``x`` may then be an :class:`IntAct`, and with ``out_aq`` (from
    :func:`chain_out_aq`) the result is one too.  ``site`` labels this call
    in the active chain report.
    """
    M, N = _bits(cfg, boundary)
    mode = _int_forward_mode(params, x, N) if int_forward else ""
    if mode == "fused":
        return _apply_linear_int8(
            params, x, cfg,
            boundary=boundary, input_signed=input_signed,
            compute_dtype=compute_dtype, int_chain=int_chain,
            out_aq=out_aq, site=site,
        )
    if mode == "vmap":
        # stacked weight leaves (vmapped layer stacks): batch the fused
        # kernel over the leading axis — jax.vmap batches the pallas_call
        fn = lambda p, xi: _apply_linear_int8(
            p, xi, cfg,
            boundary=boundary, input_signed=input_signed,
            compute_dtype=compute_dtype, int_chain=int_chain, site=site,
        )
        return jax.vmap(fn)(params, x)
    if int_forward and "q8" in params:
        if "aq" not in params:
            reason = "no activation quantizer in the deployed params"
        elif N > 8:
            reason = f"act bits N={N} > 8"
        else:
            reason = (f"stacked weight leaves (rank {params['q8'].ndim}) "
                      "without a matching batched input")
        _warn_fallback_once(site, reason)
        _record("fallback", site)
    if isinstance(x, IntAct):
        # chain repair: the consumer can't take codes — re-materialize fp
        _record("fallback", site)
        x = _int_act_to_fp(x, compute_dtype)
    if cfg.mode != "none" and "aq" in params:
        x = apply_act_quant(
            {"log2_scale": params["aq"]["log2_scale"]}, x, N, signed=input_signed
        )
    w = _quant_weights(params, cfg, boundary, input_signed).astype(compute_dtype)
    y = jnp.dot(x.astype(compute_dtype), w)
    if "b" in params:
        y = y + params["b"].astype(compute_dtype)
    return y


def linear_penalty(params: dict, cfg: QuantConfig, boundary: bool, input_signed: bool) -> jnp.ndarray:
    """This layer's ``R_l = sum_i max(t_i - T_i, 0)`` (zero unless a2q)."""
    if cfg.mode != "a2q" or "t" not in params:
        return jnp.zeros((), jnp.float32)
    _, N = _bits(cfg, boundary)
    T = a2q_norm_cap(params["d"], cfg.acc_bits, N, input_signed)
    return jnp.sum(jnp.maximum(params["t"] - T, 0.0))


def deploy_linear(params: dict, cfg: QuantConfig, *, boundary: bool = False, input_signed: bool = True) -> dict:
    """A2Q/QAT layer -> inference artifacts {q8 int8, s8 scale [, b, aq]}."""
    M, N = _bits(cfg, boundary)
    if cfg.mode == "a2q":
        q, s = a2q_int_weights(
            {"v": params["v"], "t": params["t"], "d": params["d"]},
            M,
            cfg.acc_bits,
            N,
            input_signed,
        )
    elif cfg.mode == "qat":
        q, s = weight_qat_int({"log2_scale": params["wq"]["log2_scale"]}, params["w"], M)
    else:
        raise ValueError("deploy requires a quantized mode")
    out = {"q8": q.astype(jnp.int8), "s8": s.astype(jnp.float32)}
    if "b" in params:
        out["b"] = params["b"]
    if "aq" in params:
        out["aq"] = params["aq"]
    return out


# ---------------------------------------------------------------------------
# Conv (vision benchmarks: MobileNetV1 / ResNet18 / ESPCN / UNet)
# ---------------------------------------------------------------------------


def init_conv(
    key,
    c_in: int,
    c_out: int,
    kernel: tuple[int, int],
    cfg: QuantConfig,
    *,
    groups: int = 1,
    use_bias: bool = False,
    boundary: bool = False,
    input_signed: bool = False,  # vision nets are ReLU nets -> unsigned acts
) -> dict:
    """HWIO weights ``(kh, kw, c_in/groups, c_out)`` — channel axis last, so
    A2Q's per-output-channel reduction (= per accumulator, K = kh*kw*c_in/g)
    applies unchanged."""
    kh, kw = kernel
    fan_in = kh * kw * (c_in // groups)
    w = kaiming(key, (kh, kw, c_in // groups, c_out), fan_in=fan_in)
    axes = (None, None, None, "conv_out")
    M, N = _bits(cfg, boundary)
    p: dict = {}
    if cfg.mode == "none":
        p["w"] = box(w, axes)
    elif cfg.mode == "qat":
        p["w"] = box(w, axes)
        p["wq"] = {"log2_scale": box(init_weight_qat(w, M)["log2_scale"], ("conv_out",))}
        p["aq"] = {"log2_scale": box(init_act_quant(N, input_signed)["log2_scale"], ())}
    elif cfg.mode == "a2q":
        a = init_a2q(w, M, cfg.acc_bits, N, input_signed)
        p["v"] = box(a["v"], axes)
        p["t"] = box(a["t"], ("conv_out",))
        p["d"] = box(a["d"], ("conv_out",))
        p["aq"] = {"log2_scale": box(init_act_quant(N, input_signed)["log2_scale"], ())}
    if use_bias:
        p["b"] = box(jnp.zeros((c_out,), jnp.float32), ("conv_out",))
    return p


def apply_conv(
    params: dict,
    x: jnp.ndarray,
    cfg: QuantConfig,
    *,
    stride: tuple[int, int] = (1, 1),
    padding: str = "SAME",
    groups: int = 1,
    boundary: bool = False,
    input_signed: bool = False,
    compute_dtype=jnp.float32,
) -> jnp.ndarray:
    """NHWC convolution with the same quant pipeline as apply_linear."""
    M, N = _bits(cfg, boundary)
    if cfg.mode != "none" and "aq" in params:
        x = apply_act_quant(
            {"log2_scale": params["aq"]["log2_scale"]}, x, N, signed=input_signed
        )
    w = _quant_weights(params, cfg, boundary, input_signed).astype(compute_dtype)
    y = jax.lax.conv_general_dilated(
        x.astype(compute_dtype),
        w,
        window_strides=stride,
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )
    if "b" in params:
        y = y + params["b"].astype(compute_dtype)
    return y
